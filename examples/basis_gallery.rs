//! Basis gallery: Table 1 live. Shows, for a real dataset shard, how each
//! Hessian basis represents the same local Hessian — coefficient counts,
//! wire costs, losslessness, and the PSD property BL3 relies on.
//!
//! ```bash
//! cargo run --release --example basis_gallery
//! ```

use basis_learn::basis::{HessianBasis, PsdBasis, StandardBasis, SubspaceBasis, SymTriBasis};
use basis_learn::data::{FederatedDataset, SyntheticSpec};
use basis_learn::linalg::sym_eigen;
use basis_learn::problem::{LocalProblem, LogisticProblem};

fn main() -> anyhow::Result<()> {
    let fed = FederatedDataset::synthetic(&SyntheticSpec {
        n_clients: 1,
        m_per_client: 120,
        dim: 40,
        intrinsic_dim: 9,
        noise: 0.0,
        seed: 11,
    });
    let shard = &fed.clients[0];
    let problem = LogisticProblem::new(shard.a.clone(), shard.b.clone());
    let d = shard.dim();
    let x: Vec<f64> = (0..d).map(|i| 0.05 * i as f64 - 1.0).collect();
    let hess = problem.hess(&x);
    println!(
        "client shard: m={} d={d}, intrinsic r={}, ‖∇²f‖_F = {:.4}\n",
        shard.m(),
        shard.intrinsic_dim(1e-9),
        hess.fro_norm()
    );

    let bases: Vec<Box<dyn HessianBasis>> = vec![
        Box::new(StandardBasis::new(d)),
        Box::new(SymTriBasis::new(d)),
        Box::new(SubspaceBasis::from_data(&shard.a, 1e-9)),
        Box::new(PsdBasis::new(d)),
    ];

    println!(
        "{:<18}{:>12}{:>12}{:>14}{:>14}{:>10}{:>8}",
        "basis", "coeffs", "nonzero", "decode err", "grad coeffs", "N_B", "PSD?"
    );
    for b in &bases {
        let h = b.encode(&hess);
        let rec = b.decode(&h);
        let err = (&rec - &hess).fro_norm() / hess.fro_norm();
        let (cr, cc) = b.coeff_shape();
        let nnz = h.data().iter().filter(|&&v| v.abs() > 1e-12).count();
        println!(
            "{:<18}{:>12}{:>12}{:>14.2e}{:>14}{:>10}{:>8}",
            b.name(),
            cr * cc,
            nnz,
            err,
            b.grad_coeff_len(),
            b.n_b() as usize,
            if b.is_psd_basis() { "yes" } else { "no" }
        );
        assert!(err < 1e-9, "{} must be lossless on a GLM data-Hessian", b.name());
    }

    // PSD-basis element check (BL3's foundation).
    let psd = PsdBasis::new(6);
    let mut min_eig = f64::INFINITY;
    for j in 0..6 {
        for l in 0..=j {
            let e = sym_eigen(&psd.element(j, l));
            min_eig = min_eig.min(*e.values.last().unwrap());
        }
    }
    println!("\nPSD basis: min eigenvalue over all B^jl = {min_eig:.2e} (≥ 0 ✓)");

    // The Table-1 punchline.
    let sub = SubspaceBasis::from_data(&shard.a, 1e-9);
    let r = sub.r();
    println!(
        "\nTable 1 — per-iteration floats: naive d²+d = {}, ours r²+r = {} ({}× smaller),\n\
         one-time basis transfer rd = {} floats.",
        d * d + d,
        r * r + r,
        (d * d + d) / (r * r + r),
        sub.setup_floats()
    );
    Ok(())
}
