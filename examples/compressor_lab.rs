//! Compressor laboratory: empirically estimate every compressor's
//! contraction/variance parameter and check it against the §3 theory —
//! including Proposition 3.2's composition parameter
//! `δ = R/(d(ω₁+1)(ω₂+1))` and Lemma 3.1's symmetrization claim.
//!
//! ```bash
//! cargo run --release --example compressor_lab
//! ```

use basis_learn::compressors::{CompressorClass, CompressorSpec};
use basis_learn::linalg::Mat;
use basis_learn::rng::Rng;

fn empirical(spec: &CompressorSpec, d: usize, trials: usize, rng: &mut Rng) -> (f64, f64, f64) {
    // Returns (E‖C(A)−A‖²/‖A‖², ‖E C(A) − A‖/‖A‖, avg bits).
    let comp = spec.build_mat(d);
    let mut rel_err = 0.0;
    let mut bits = 0.0;
    let mut a = Mat::from_fn(d, d, |_, _| rng.normal());
    a.symmetrize();
    let mut mean = Mat::zeros(d, d);
    for _ in 0..trials {
        let (c, cost) = comp.compress(&a, rng);
        rel_err += (&c - &a).fro_norm_sq() / a.fro_norm_sq();
        bits += cost.total_bits(64);
        mean.add_scaled(1.0 / trials as f64, &c);
    }
    let bias = (&mean - &a).fro_norm() / a.fro_norm();
    (rel_err / trials as f64, bias, bits / trials as f64)
}

fn main() -> anyhow::Result<()> {
    let d = 24;
    let mut rng = Rng::new(123);
    let specs = [
        "identity", "topk:24", "randk:24", "rank:1", "rank:4", "dith:5", "nat",
        "rrank:1", "nrank:1", "rtopk:24", "ntopk:24",
    ];
    println!("d = {d}; 400 trials per compressor; symmetric Gaussian input\n");
    println!(
        "{:<12}{:>16}{:>16}{:>12}{:>12}{:>14}",
        "compressor", "E‖C−A‖²/‖A‖²", "theory (1−δ)", "bias", "bits/msg", "class"
    );
    for s in specs {
        let spec = CompressorSpec::parse(s)?;
        let comp = spec.build_mat(d);
        let class = comp.class(d * d, d);
        let (err, bias, bits) = empirical(&spec, d, 400, &mut rng);
        let (theory, class_name) = match class {
            CompressorClass::Contractive { delta } => (format!("{:.4}", 1.0 - delta), "contract"),
            CompressorClass::Unbiased { omega } => (format!("ω={omega:.2}"), "unbiased"),
        };
        println!(
            "{:<12}{:>16.4}{:>16}{:>12.4}{:>12.0}{:>14}",
            s, err, theory, bias, bits, class_name
        );
        // Hard checks, mirroring the unit tests but at higher trial counts.
        match class {
            CompressorClass::Contractive { delta } => {
                assert!(err <= (1.0 - delta) * 1.05 + 1e-9, "{s}: contraction violated");
            }
            CompressorClass::Unbiased { omega } => {
                // The Monte-Carlo mean of an ω-variance estimator over T
                // trials deviates by ~√(ω/T); allow 3 standard errors.
                let tol = 3.0 * (omega / 400.0).sqrt() + 0.02;
                assert!(bias < tol, "{s}: biased output ({bias} > {tol})");
            }
        }
    }

    println!("\nProposition 3.2 spot check (RRank-1, varying dithering levels):");
    for levels in [1u32, 2, 4, 16] {
        let spec = CompressorSpec::RRank(1, Some(levels));
        let comp = spec.build_mat(d);
        let delta = match comp.class(d * d, d) {
            CompressorClass::Contractive { delta } => delta,
            _ => unreachable!(),
        };
        let (err, _, _) = empirical(&spec, d, 400, &mut rng);
        println!(
            "  s={levels:<3} δ_theory={delta:.5}  empirical E‖C−A‖²/‖A‖²={err:.4} ≤ 1−δ={:.5}",
            1.0 - delta
        );
        assert!(err <= 1.0 - delta + 0.03);
    }
    println!("\nall checks passed");
    Ok(())
}
