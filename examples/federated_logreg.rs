//! End-to-end driver over the **full three-layer stack** (DESIGN.md §5).
//!
//! ```bash
//! make artifacts && cargo run --release --example federated_logreg
//! ```
//!
//! Generates an a1a-shaped federated dataset (shape (m, d) = (100, 30) per
//! client, in the AOT shape grid), builds PJRT-backed local problems — every
//! loss/gradient/Hessian evaluation on the hot path executes the HLO
//! artifacts that were AOT-lowered from the JAX model (L2) calling the
//! Pallas kernels (L1) — and trains with BL1, FedNL and GD for a few hundred
//! rounds, logging gap-vs-bits curves to `runs/` and printing the headline
//! comparison. The run recorded in EXPERIMENTS.md §E2E comes from here.

use basis_learn::compressors::CompressorSpec;
use basis_learn::config::{Algorithm, RunConfig};
use basis_learn::coordinator::run_federated_with;
use basis_learn::data::{FederatedDataset, SyntheticSpec};
use basis_learn::linalg::Mat;
use basis_learn::problem::LocalProblem;
use basis_learn::runtime::{PjrtProblem, Runtime};
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let rt = Rc::new(Runtime::load(Path::new(&dir))?);
    println!(
        "PJRT runtime up: platform={}, lossgrad shapes={:?}",
        rt.platform(),
        rt.shapes("logreg_lossgrad")
    );

    // 8 clients × 100 points, d=30, r=6 — the (100, 30) artifact shape.
    let fed = FederatedDataset::synthetic(&SyntheticSpec {
        n_clients: 8,
        m_per_client: 100,
        dim: 30,
        intrinsic_dim: 6,
        noise: 0.0,
        seed: 7,
    });
    println!(
        "dataset {}: n={} d={} r={:.0}, {} points",
        fed.name,
        fed.n_clients(),
        fed.dim(),
        fed.avg_intrinsic_dim(1e-9),
        fed.total_points()
    );

    let build_locals = || -> anyhow::Result<Vec<Box<dyn LocalProblem>>> {
        fed.clients
            .iter()
            .map(|c| {
                Ok(Box::new(PjrtProblem::new(rt.clone(), c.a.clone(), c.b.clone())?)
                    as Box<dyn LocalProblem>)
            })
            .collect()
    };

    let runs = [
        ("bl1", RunConfig {
            algorithm: Algorithm::Bl1,
            hess_comp: CompressorSpec::TopK(6),
            rounds: 400,
            ..RunConfig::default()
        }),
        ("fednl", RunConfig {
            algorithm: Algorithm::FedNl,
            hess_comp: CompressorSpec::RankR(1),
            rounds: 400,
            ..RunConfig::default()
        }),
        ("gd", RunConfig {
            algorithm: Algorithm::Gd,
            rounds: 400,
            ..RunConfig::default()
        }),
    ];

    println!(
        "\n{:<10}{:>8}{:>12}{:>16}{:>14}{:>12}",
        "method", "rounds", "wall (s)", "bits/node", "final gap", "‖∇f‖"
    );
    for (name, mut cfg) in runs {
        cfg.lambda = 1e-3;
        cfg.target_gap = 1e-12;
        let locals = build_locals()?;
        let features: Vec<Option<Mat>> = fed.clients.iter().map(|c| Some(c.a.clone())).collect();
        let t0 = Instant::now();
        let out = run_federated_with(&locals, features, &cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        let last = out.history.records.last().unwrap();
        println!(
            "{:<10}{:>8}{:>12.2}{:>16.3e}{:>14.2e}{:>12.2e}",
            name,
            out.history.records.len(),
            wall,
            out.bits_per_node(),
            out.final_gap(),
            last.grad_norm
        );
        let mut hist = out.history;
        hist.label = format!("pjrt_{name}");
        let path = hist.write_csv(Path::new("runs"), "e2e")?;
        println!("          loss curve → {}", path.display());
    }

    println!("\nEvery local evaluation above ran through PJRT-loaded HLO (JAX L2 + Pallas L1).");
    Ok(())
}
