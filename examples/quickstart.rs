//! Quickstart: 30 seconds from zero to a converged Basis-Learn run.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Synthesizes a small federated dataset with low intrinsic dimension,
//! runs BL1 (the paper's Algorithm 1) against FedNL and gradient descent,
//! and prints how many bits per node each needed to reach a 1e-9 gap.

use basis_learn::compressors::CompressorSpec;
use basis_learn::prelude::*;

fn main() -> anyhow::Result<()> {
    // A federated dataset: 8 clients × 100 points, d = 30 features that
    // secretly live in an r = 6 dimensional subspace per client.
    let spec = SyntheticSpec {
        n_clients: 8,
        m_per_client: 100,
        dim: 30,
        intrinsic_dim: 6,
        noise: 0.0,
        seed: 2026,
    };
    let fed = FederatedDataset::synthetic(&spec);
    println!(
        "dataset: {} — n={}, d={}, measured r={:.0}",
        fed.name,
        fed.n_clients(),
        fed.dim(),
        fed.avg_intrinsic_dim(1e-9)
    );

    let runs = [
        ("BL1 (subspace basis, Top-r)", RunConfig {
            algorithm: Algorithm::Bl1,
            hess_comp: CompressorSpec::TopK(6),
            ..RunConfig::default()
        }),
        ("FedNL (Rank-1)", RunConfig {
            algorithm: Algorithm::FedNl,
            hess_comp: CompressorSpec::RankR(1),
            ..RunConfig::default()
        }),
        ("GD", RunConfig {
            algorithm: Algorithm::Gd,
            rounds: 100_000,
            ..RunConfig::default()
        }),
    ];

    println!(
        "\n{:<32}{:>10}{:>18}{:>14}",
        "method", "rounds", "bits/node→1e-9", "final gap"
    );
    for (name, mut cfg) in runs {
        cfg.lambda = 1e-3;
        cfg.target_gap = 1e-9;
        let out = run_federated(&fed, &cfg)?;
        let bits = out
            .history
            .records
            .iter()
            .find(|r| r.gap <= 1e-9)
            .map(|r| format!("{:.3e}", r.bits_up_per_node + out.history.setup_bits_per_node))
            .unwrap_or_else(|| "not reached".into());
        println!(
            "{:<32}{:>10}{:>18}{:>14.2e}",
            name,
            out.history.records.len(),
            bits,
            out.final_gap()
        );
    }
    println!(
        "\nBasis Learn wins because each client's Hessian is r×r = 36 coefficients\n\
         instead of d×d = 900 entries — see DESIGN.md and `repro experiment fig1-second-order`."
    );
    Ok(())
}
