"""Sweep-output analysis: dependency-light loaders for the Rust engine's
``runs.jsonl`` / ``summary.jsonl`` sinks and per-run history CSVs, plus a
gap-vs-bits plot script regenerating the paper's Figure-1-style curves.

Only the plot script needs matplotlib; everything in :mod:`analysis.loader`
is pure standard library so it can run anywhere the sweep output lands.
"""

from analysis.loader import (  # noqa: F401
    GroupSummary,
    RunRow,
    TargetAgg,
    TargetBits,
    load_history_csv,
    load_jsonl,
    load_runs,
    load_summary,
)
