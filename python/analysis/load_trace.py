"""Loader + validator for obs trace files (standard library only).

The Rust side (``repro run --trace`` / ``repro sweep --trace``) writes one
JSON object per line; the field-by-field schema is in ``docs/TRACING.md``.
Three event shapes share the stream:

* ``span`` — a timed phase (``round``, ``plan``, ``exchange``, ``absorb``,
  ``eval``, ``compute``, ``queue``, ``cell``) with ``ts_us`` + ``dur_us``.
* ``bits`` — one wire message (``name`` = ``msg``) with ``dir``/``kind``/
  ``floats``/``aux_bits``/``bits``.
* ``mark`` — an instant (``run``, ``dataset_cache``) with optional ``note``.

Usage::

    python -m analysis.load_trace trace.jsonl
    python -m analysis.load_trace trace.jsonl --chrome trace_chrome.json

The second form additionally cross-checks a ``repro trace --chrome`` export
against the JSONL it was derived from. Exit status is non-zero when
validation finds problems, so CI can use this as a schema gate.
"""

from __future__ import annotations

import argparse
import json
from collections import defaultdict
from dataclasses import dataclass
from pathlib import Path

from analysis.loader import PathLike, load_jsonl

EVENT_KINDS = ("span", "bits", "mark")

#: Tolerance for span-nesting comparisons. Timestamps come from a monotonic
#: clock, so a child span genuinely ends no later than its parent; the eps
#: only guards against f64 round-off in the microsecond arithmetic.
NEST_EPS_US = 1e-6


@dataclass
class TraceEvent:
    """One trace row. Optional fields are ``None`` when absent."""

    ev: str
    name: str
    lane: str
    ts_us: float
    dur_us: float | None = None
    cell: int | None = None
    round: int | None = None
    exchange: int | None = None
    client: int | None = None
    dir: str | None = None
    kind: str | None = None
    floats: float | None = None
    aux_bits: float | None = None
    bits: float | None = None
    note: str | None = None

    @classmethod
    def from_dict(cls, row: dict) -> "TraceEvent":
        for req in ("ev", "name", "lane", "ts_us"):
            if req not in row:
                raise ValueError(f"trace event missing required field {req!r}: {row}")

        def opt_int(key: str) -> int | None:
            return None if row.get(key) is None else int(row[key])

        def opt_float(key: str) -> float | None:
            return None if row.get(key) is None else float(row[key])

        return cls(
            ev=str(row["ev"]),
            name=str(row["name"]),
            lane=str(row["lane"]),
            ts_us=float(row["ts_us"]),
            dur_us=opt_float("dur_us"),
            cell=opt_int("cell"),
            round=opt_int("round"),
            exchange=opt_int("exchange"),
            client=opt_int("client"),
            dir=row.get("dir"),
            kind=row.get("kind"),
            floats=opt_float("floats"),
            aux_bits=opt_float("aux_bits"),
            bits=opt_float("bits"),
            note=row.get("note"),
        )

    @property
    def end_us(self) -> float:
        return self.ts_us + (self.dur_us or 0.0)


def load_trace(path: PathLike) -> list[TraceEvent]:
    """Load a trace JSONL file (a torn final line is dropped, as with runs)."""
    return [TraceEvent.from_dict(r) for r in load_jsonl(path)]


def validate(events: list[TraceEvent]) -> list[str]:
    """Schema + structural checks. Returns a list of problems (empty = OK).

    Beyond per-event field checks, verifies *span nesting*: within each
    (cell, lane) timeline, spans must form a forest — any two spans are
    either disjoint or one contains the other. Overlapping-but-not-nested
    spans mean the instrumentation (or the clock) is broken.
    """
    problems: list[str] = []
    for i, e in enumerate(events):
        where = f"event {i} ({e.ev} {e.name!r})"
        if e.ev not in EVENT_KINDS:
            problems.append(f"{where}: unknown ev {e.ev!r}")
        if e.ev == "span":
            if e.dur_us is None:
                problems.append(f"{where}: span without dur_us")
            elif e.dur_us < 0.0:
                problems.append(f"{where}: negative dur_us {e.dur_us}")
        else:
            if e.dur_us is not None:
                problems.append(f"{where}: {e.ev} event carries dur_us")
        if e.ev == "bits":
            for req in ("dir", "kind", "bits"):
                if getattr(e, req) is None:
                    problems.append(f"{where}: bits event without {req!r}")
            if e.dir not in (None, "up", "down"):
                problems.append(f"{where}: bad dir {e.dir!r}")
    problems.extend(check_span_nesting(events))
    return problems


def check_span_nesting(events: list[TraceEvent]) -> list[str]:
    """Per-(cell, lane) stack-discipline check over span intervals."""
    problems: list[str] = []
    timelines: dict[tuple[int | None, str], list[TraceEvent]] = defaultdict(list)
    for e in events:
        if e.ev == "span" and e.dur_us is not None and e.dur_us >= 0.0:
            timelines[(e.cell, e.lane)].append(e)
    for (cell, lane), spans in sorted(timelines.items(), key=lambda kv: str(kv[0])):
        # Widest-first at equal start so a parent precedes the children it
        # encloses; then simulate a stack of open spans.
        spans.sort(key=lambda s: (s.ts_us, -(s.dur_us or 0.0)))
        stack: list[TraceEvent] = []
        for s in spans:
            while stack and stack[-1].end_us <= s.ts_us + NEST_EPS_US:
                stack.pop()
            if stack and s.end_us > stack[-1].end_us + NEST_EPS_US:
                top = stack[-1]
                problems.append(
                    f"cell={cell} lane={lane}: span {s.name!r} "
                    f"[{s.ts_us:.1f}, {s.end_us:.1f}]us overlaps but is not "
                    f"nested in {top.name!r} [{top.ts_us:.1f}, {top.end_us:.1f}]us"
                )
            stack.append(s)
    return problems


def phase_totals(events: list[TraceEvent]) -> dict[str, float]:
    """Total self-reported duration (µs) per span name, largest first."""
    totals: dict[str, float] = defaultdict(float)
    for e in events:
        if e.ev == "span" and e.dur_us is not None:
            totals[e.name] += e.dur_us
    return dict(sorted(totals.items(), key=lambda kv: -kv[1]))


def bits_by_kind(events: list[TraceEvent]) -> dict[tuple[str, str], tuple[int, float]]:
    """(direction, message kind) → (message count, total bits)."""
    out: dict[tuple[str, str], tuple[int, float]] = {}
    for e in events:
        if e.ev == "bits" and e.dir is not None and e.kind is not None:
            n, b = out.get((e.dir, e.kind), (0, 0.0))
            out[(e.dir, e.kind)] = (n + 1, b + (e.bits or 0.0))
    return out


def round_flows(events: list[TraceEvent]) -> dict[tuple[int | None, int, str], float]:
    """(cell, round, direction) → total bits on the wire that round."""
    out: dict[tuple[int | None, int, str], float] = defaultdict(float)
    for e in events:
        if e.ev == "bits" and e.round is not None and e.dir is not None:
            out[(e.cell, e.round, e.dir)] += e.bits or 0.0
    return dict(out)


def load_chrome(path: PathLike) -> list[dict]:
    """Load a ``repro trace --chrome`` export's ``traceEvents`` array."""
    doc = json.loads(Path(path).read_text(encoding="utf-8"))
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: no traceEvents array — not a Chrome trace export")
    return events


def cross_check_chrome(events: list[TraceEvent], chrome: list[dict]) -> list[str]:
    """Verify a Chrome export is a faithful projection of the JSONL trace.

    Every span must appear as one "X" complete event and every bits/mark
    event as one "i" instant; total span time must agree exactly (both
    sides carry the same f64 microsecond values).
    """
    problems: list[str] = []
    by_ph: dict[str, int] = defaultdict(int)
    for c in chrome:
        by_ph[c.get("ph", "?")] += 1
    n_spans = sum(1 for e in events if e.ev == "span")
    n_instants = sum(1 for e in events if e.ev != "span")
    if by_ph.get("X", 0) != n_spans:
        problems.append(f"chrome has {by_ph.get('X', 0)} X events, trace has {n_spans} spans")
    if by_ph.get("i", 0) != n_instants:
        problems.append(
            f"chrome has {by_ph.get('i', 0)} instants, trace has {n_instants} bits/mark events"
        )
    if by_ph.get("M", 0) == 0:
        problems.append("chrome export has no thread_name metadata events")
    chrome_dur = sum(c.get("dur", 0.0) for c in chrome if c.get("ph") == "X")
    trace_dur = sum(e.dur_us or 0.0 for e in events if e.ev == "span")
    if chrome_dur != trace_dur:
        problems.append(f"chrome span time {chrome_dur}us != trace span time {trace_dur}us")
    return problems


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace JSONL written by repro --trace")
    ap.add_argument("--chrome", help="Chrome trace-event JSON to cross-check")
    args = ap.parse_args(argv)

    events = load_trace(args.trace)
    problems = validate(events)
    if args.chrome:
        problems += cross_check_chrome(events, load_chrome(args.chrome))

    n_spans = sum(1 for e in events if e.ev == "span")
    n_bits = sum(1 for e in events if e.ev == "bits")
    print(f"{args.trace}: {len(events)} events ({n_spans} spans, {n_bits} messages)")
    for name, total in phase_totals(events).items():
        print(f"  phase {name:<12} {total / 1e3:10.2f} ms")
    up = sum(b for (d, _), (_, b) in bits_by_kind(events).items() if d == "up")
    down = sum(b for (d, _), (_, b) in bits_by_kind(events).items() if d == "down")
    print(f"  bits: up {up:.0f}, down {down:.0f}")
    if problems:
        for p in problems:
            print(f"PROBLEM: {p}")
        return 1
    print("ok: schema valid, span nesting consistent")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
