"""Loaders for the sweep engine's output files (standard library only).

Three artifact kinds, all written by the Rust side:

* ``runs.jsonl``    — one row per executed run (``repro sweep``), appended
  durably in completion order; a crash can leave a torn final line, which
  the loader drops exactly like the Rust ``load_jsonl`` recovery path.
* ``summary.jsonl`` — ranked cross-seed aggregates, one row per group.
* ``*.csv``         — full per-round histories (``repro run --csv`` and the
  figure harness), columns ``round, bits_up_per_node, bits_down_per_node,
  bits_per_node, gap, grad_norm, dist_to_opt``.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Union

PathLike = Union[str, Path]


def load_jsonl(path: PathLike, *, tolerate_torn_tail: bool = True) -> list[dict]:
    """Parse a JSONL file into a list of dicts.

    A final line that does not parse is treated as the torn tail of an
    interrupted append and dropped (matching the Rust recovery loader); a
    malformed line anywhere else is a real error.
    """
    rows: list[dict] = []
    lines = Path(path).read_text(encoding="utf-8").splitlines()
    # Trailing blank lines are not "torn" — ignore them.
    while lines and not lines[-1].strip():
        lines.pop()
    for lineno, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            rows.append(json.loads(line))
        except json.JSONDecodeError:
            if tolerate_torn_tail and lineno == len(lines) - 1:
                break
            raise ValueError(f"{path}:{lineno + 1}: malformed JSONL line") from None
    return rows


@dataclass
class TargetBits:
    """Bits-to-reach one gap target, both accounting conventions."""

    target: float
    total: float | None
    uplink: float | None


@dataclass
class RunRow:
    """One executed sweep cell (a row of ``runs.jsonl``)."""

    cell: int
    group: str
    dataset: str
    seed: int
    ok: bool
    label: str | None = None
    rounds: int | None = None
    final_gap: float | None = None
    bits_per_node: float | None = None
    bits_up_per_node: float | None = None
    bits_to: list[TargetBits] = field(default_factory=list)
    error: str | None = None

    @classmethod
    def from_dict(cls, row: dict) -> "RunRow":
        return cls(
            cell=int(row["cell"]),
            group=row["group"],
            dataset=row.get("dataset", ""),
            seed=int(row["seed"]),
            ok=row.get("status") == "ok",
            label=row.get("label"),
            rounds=None if row.get("rounds") is None else int(row["rounds"]),
            final_gap=row.get("final_gap"),
            bits_per_node=row.get("bits_per_node"),
            bits_up_per_node=row.get("bits_up_per_node"),
            bits_to=[
                TargetBits(t["target"], t.get("total"), t.get("uplink"))
                for t in row.get("bits_to", [])
            ],
            error=row.get("error"),
        )

    def bits_for(self, target: float, *, uplink: bool = False) -> float | None:
        """Bits/node to first reach ``target`` (None if never reached)."""
        for t in self.bits_to:
            if t.target == target:
                return t.uplink if uplink else t.total
        return None


def load_runs(path: PathLike) -> list[RunRow]:
    """Load ``runs.jsonl`` rows, sorted back into declaration order."""
    rows = [RunRow.from_dict(r) for r in load_jsonl(path)]
    rows.sort(key=lambda r: r.cell)
    return rows


@dataclass
class TargetAgg:
    """Cross-seed aggregate for one gap target."""

    target: float
    reached: int
    bits_mean: float | None
    bits_std: float | None


@dataclass
class GroupSummary:
    """One group of ``summary.jsonl`` (ranked best-first by the engine)."""

    rank: int
    group: str
    n_runs: int
    n_ok: int
    final_gap_mean: float | None
    targets: list[TargetAgg]

    @classmethod
    def from_dict(cls, row: dict) -> "GroupSummary":
        return cls(
            rank=int(row["rank"]),
            group=row["group"],
            n_runs=int(row["n_runs"]),
            n_ok=int(row["n_ok"]),
            final_gap_mean=row.get("final_gap_mean"),
            targets=[
                TargetAgg(
                    t["target"], int(t["reached"]), t.get("bits_mean"), t.get("bits_std")
                )
                for t in row.get("targets", [])
            ],
        )


def load_summary(path: PathLike) -> list[GroupSummary]:
    """Load ``summary.jsonl`` rows in rank order."""
    rows = [GroupSummary.from_dict(r) for r in load_jsonl(path)]
    rows.sort(key=lambda r: r.rank)
    return rows


def load_history_csv(path: PathLike) -> dict[str, list[float]]:
    """Load a per-round history CSV into column lists.

    Returns a dict keyed by header name (``round``, ``bits_up_per_node``,
    ``bits_down_per_node``, ``bits_per_node``, ``gap``, ``grad_norm``,
    ``dist_to_opt``); every value parses as float (``round`` included, for
    uniformity).
    """
    lines = [ln for ln in Path(path).read_text(encoding="utf-8").splitlines() if ln.strip()]
    if not lines:
        raise ValueError(f"{path}: empty history CSV")
    header = [h.strip() for h in lines[0].split(",")]
    cols: dict[str, list[float]] = {h: [] for h in header}
    for lineno, line in enumerate(lines[1:], start=2):
        parts = line.split(",")
        if len(parts) != len(header):
            raise ValueError(
                f"{path}:{lineno}: expected {len(header)} columns, got {len(parts)}"
            )
        for h, v in zip(header, parts):
            cols[h].append(float(v))
    return cols
