"""Regenerate the paper's Figure-1-style gap-vs-bits curves from sweep
output.

Input: one or more per-round history CSVs (written by ``repro run --csv``
or the figure harness into ``runs/``), or a directory to glob them from.
One curve per file, labelled from the filename
(``<experiment>__<label>.csv`` → ``<label>``).

Usage::

    python -m analysis.plot_gap_vs_bits runs/fig1-second-order__*.csv \
        --out fig1.png
    python -m analysis.plot_gap_vs_bits runs/ --experiment fig1-second-order \
        --uplink --out fig1.png

Only this script needs matplotlib; the loaders are stdlib-only.
"""

from __future__ import annotations

import argparse
from pathlib import Path

from analysis.loader import load_history_csv


def series_label(path: Path) -> str:
    """``fig1__a1a-s__bl1.csv`` → ``a1a-s__bl1`` (fall back to the stem)."""
    stem = path.stem
    if "__" in stem:
        return stem.split("__", 1)[1]
    return stem


def collect_csvs(inputs: list[str], experiment: str | None) -> list[Path]:
    """Expand file and directory arguments into a sorted CSV list."""
    out: list[Path] = []
    for raw in inputs:
        p = Path(raw)
        if p.is_dir():
            pattern = f"{experiment}__*.csv" if experiment else "*.csv"
            out.extend(sorted(p.glob(pattern)))
        elif p.is_file():
            out.append(p)
        else:
            raise FileNotFoundError(f"no such file or directory: {p}")
    if not out:
        raise FileNotFoundError("no history CSVs matched the inputs")
    return out


def plot(csvs: list[Path], *, uplink: bool, out: Path, title: str | None) -> None:
    # Imported lazily so the loaders stay dependency-light.
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(6.0, 4.2))
    x_col = "bits_up_per_node" if uplink else "bits_per_node"
    for path in csvs:
        cols = load_history_csv(path)
        # Clamp to the resolution the run measured; log axes need positives.
        xs, ys = [], []
        for x, gap in zip(cols[x_col], cols["gap"]):
            if x > 0.0 and gap > 0.0:
                xs.append(x)
                ys.append(gap)
        if xs:
            ax.plot(xs, ys, label=series_label(path), linewidth=1.6)
    ax.set_xscale("log")
    ax.set_yscale("log")
    ax.set_xlabel(
        "communicated bits per node (uplink)" if uplink else "communicated bits per node"
    )
    ax.set_ylabel(r"$f(x^k) - f(x^*)$")
    if title:
        ax.set_title(title)
    ax.grid(True, which="both", alpha=0.25, linewidth=0.5)
    ax.legend(fontsize=8)
    fig.tight_layout()
    out.parent.mkdir(parents=True, exist_ok=True)
    fig.savefig(out, dpi=160)
    plt.close(fig)


def main(argv: list[str] | None = None) -> Path:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("inputs", nargs="+", help="history CSVs or directories holding them")
    ap.add_argument(
        "--experiment",
        help="when an input is a directory, only take `<experiment>__*.csv`",
    )
    ap.add_argument(
        "--uplink",
        action="store_true",
        help="x-axis = uplink bits only (the paper's Figs. 1-4 convention)",
    )
    ap.add_argument("--out", default="gap_vs_bits.png", help="output image path")
    ap.add_argument("--title", help="figure title")
    args = ap.parse_args(argv)

    csvs = collect_csvs(args.inputs, args.experiment)
    out = Path(args.out)
    plot(csvs, uplink=args.uplink, out=out, title=args.title)
    print(f"wrote {out} ({len(csvs)} curves)")
    return out


if __name__ == "__main__":
    main()
