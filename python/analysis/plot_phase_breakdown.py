"""Plot where round time goes: per-phase stacked bars from obs traces.

Input: one or more trace JSONL files written by ``repro run --trace`` or
``repro sweep --trace``. One bar per file (labelled from the filename),
one colored segment per span phase (``plan``, ``compute``, ``exchange``,
``absorb``, ``eval``, ...), sized by total time spent in that phase.

Usage::

    python -m analysis.plot_phase_breakdown runs/trace_*.jsonl \
        --out phase_breakdown.png

Only this script needs matplotlib; ``analysis.load_trace`` is stdlib-only.
"""

from __future__ import annotations

import argparse
from pathlib import Path

from analysis.load_trace import load_trace, phase_totals

#: Container spans are unions of the leaf phases below them; stacking both
#: would double-count, so they are dropped unless --all is given.
CONTAINER_PHASES = ("round", "cell")


def collect_breakdowns(
    paths: list[Path], *, keep_containers: bool
) -> tuple[list[str], list[dict[str, float]]]:
    labels: list[str] = []
    breakdowns: list[dict[str, float]] = []
    for path in paths:
        totals = phase_totals(load_trace(path))
        if not keep_containers:
            for name in CONTAINER_PHASES:
                totals.pop(name, None)
        labels.append(path.stem)
        breakdowns.append(totals)
    return labels, breakdowns


def plot(
    labels: list[str], breakdowns: list[dict[str, float]], *, out: Path, title: str | None
) -> None:
    # Imported lazily so the loaders stay dependency-light.
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    # Stable phase order across bars: by total time over all traces.
    order: dict[str, float] = {}
    for b in breakdowns:
        for name, total in b.items():
            order[name] = order.get(name, 0.0) + total
    phases = sorted(order, key=lambda n: -order[n])

    fig, ax = plt.subplots(figsize=(max(4.0, 1.2 * len(labels) + 2.0), 4.2))
    xs = range(len(labels))
    bottoms = [0.0] * len(labels)
    for phase in phases:
        heights = [b.get(phase, 0.0) / 1e3 for b in breakdowns]
        ax.bar(xs, heights, bottom=bottoms, label=phase, width=0.6)
        bottoms = [b + h for b, h in zip(bottoms, heights)]
    ax.set_xticks(list(xs))
    ax.set_xticklabels(labels, rotation=20, ha="right", fontsize=8)
    ax.set_ylabel("time in phase (ms)")
    if title:
        ax.set_title(title)
    ax.grid(True, axis="y", alpha=0.25, linewidth=0.5)
    ax.legend(fontsize=8)
    fig.tight_layout()
    out.parent.mkdir(parents=True, exist_ok=True)
    fig.savefig(out, dpi=160)
    plt.close(fig)


def main(argv: list[str] | None = None) -> Path:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="+", help="trace JSONL files from repro --trace")
    ap.add_argument(
        "--all",
        action="store_true",
        help="also stack container spans (round/cell); double-counts leaf time",
    )
    ap.add_argument("--out", default="phase_breakdown.png", help="output image path")
    ap.add_argument("--title", help="figure title")
    args = ap.parse_args(argv)

    paths = [Path(t) for t in args.traces]
    for p in paths:
        if not p.is_file():
            raise FileNotFoundError(f"no such trace file: {p}")
    labels, breakdowns = collect_breakdowns(paths, keep_containers=args.all)
    out = Path(args.out)
    plot(labels, breakdowns, out=out, title=args.title)
    print(f"wrote {out} ({len(labels)} traces)")
    return out


if __name__ == "__main__":
    main()
