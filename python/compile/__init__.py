"""Build-time compile path (L1 kernels, L2 model, AOT lowering).

Never imported at run time: the Rust binary consumes only the HLO text
artifacts this package emits.
"""
