"""L1 Pallas kernels + pure-jnp reference oracles."""
