"""L1 Pallas kernel: the scaled Gram product ``Aᵀ diag(s) A``.

This is the arithmetic hot-spot of the whole system — the GLM Hessian
assembly (paper eq. 3), `O(m·d²)` per client per round versus the server's
`O(d³)` solve. The kernel tiles the reduction dimension ``m`` and the output
``d×d`` into VMEM-resident blocks and walks the grid ``(d/bd, d/bd, m/bm)``:

* grid step ``(i, j, k)`` loads ``A[k·bm:, i·bd:]`` and ``A[k·bm:, j·bd:]``
  (plus the matching slice of ``s``), scales the right tile's rows on the VPU
  and accumulates ``bd×bd`` output tiles with an MXU matmul;
* the output BlockSpec pins tile ``(i, j)`` across all ``k`` so the
  accumulation happens in VMEM (standard reduction-tiled matmul schedule —
  see DESIGN.md §Hardware-Adaptation).

VMEM footprint per step: ``2·bm·bd + bm + bd²`` floats. With the default
``bm = bd = 128`` at f32 that is ≈ 197 KiB, comfortably inside a TPU core's
~16 MiB VMEM with room for double-buffering.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
under the Rust runtime. Real-TPU performance is *estimated* from the tiling
(see DESIGN.md §Perf), never measured here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, target: int) -> int:
    """Largest block ≤ target; n is padded to a multiple of the result."""
    return min(n, target) if n > 0 else 1


def _gram_kernel(a_i_ref, a_j_ref, s_ref, o_ref):
    """One grid step: ``o[i,j] += (A_k_i)ᵀ (s_k ⊙ A_k_j)``."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_i = a_i_ref[...]  # (bm, bd)
    sa_j = s_ref[...][:, None] * a_j_ref[...]  # VPU elementwise scale
    # MXU contraction over the bm rows.
    o_ref[...] += jax.lax.dot_general(
        a_i,
        sa_j,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bd", "interpret"))
def scaled_gram(a: jax.Array, s: jax.Array, *, bm: int = 128, bd: int = 128,
                interpret: bool = True) -> jax.Array:
    """``Aᵀ diag(s) A`` via the tiled Pallas kernel.

    Inputs of any ``(m, d)`` shape are zero-padded to block multiples
    (zero rows/columns contribute nothing to the Gram product, so padding is
    exact); the result is sliced back to ``(d, d)``.
    """
    m, d = a.shape
    assert s.shape == (m,), f"weights shape {s.shape} != ({m},)"
    bm = _pick_block(m, bm)
    bd = _pick_block(d, bd)
    m_pad = pl.cdiv(m, bm) * bm
    d_pad = pl.cdiv(d, bd) * bd
    a_p = jnp.pad(a, ((0, m_pad - m), (0, d_pad - d)))
    s_p = jnp.pad(s, (0, m_pad - m))

    grid = (d_pad // bd, d_pad // bd, m_pad // bm)
    out = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, k: (k, i)),
            pl.BlockSpec((bm, bd), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bd, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_pad, d_pad), a.dtype),
        interpret=interpret,
    )(a_p, a_p, s_p)
    return out[:d, :d]


def vmem_floats(bm: int, bd: int) -> int:
    """Estimated VMEM working set in floats (two A tiles, s tile, out tile).

    Used by DESIGN.md §Perf and the kernel-structure tests — not a runtime
    quantity.
    """
    return 2 * bm * bd + bm + bd * bd
