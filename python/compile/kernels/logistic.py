"""L1 Pallas kernel: fused logistic loss + gradient.

One pass over the data per evaluation: each grid step loads a ``(bm, d)``
tile of ``A`` plus the matching labels, computes the margins ``z = A x`` on
the MXU, the stable ``log(1+e^{−bz})`` / ``σ(−bz)`` terms on the VPU, and
accumulates both the scalar loss and the ``d``-vector gradient contribution
``Aᵀu`` in VMEM-resident output blocks (the output BlockSpecs pin the same
block for every grid step).

The model dimension ``d`` stays resident (the paper's problems have
``d ≤ 500`` — a ``128×500`` f32 tile is 256 KiB); the data dimension ``m``
is tiled. Zero-padding rows is exact: a padded row has ``b = 0``, and the
kernel masks padded rows explicitly via the label (``b = 0 ⇒`` the row is
excluded from both loss and gradient).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lossgrad_kernel(a_ref, b_ref, x_ref, loss_ref, grad_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        loss_ref[...] = jnp.zeros_like(loss_ref)
        grad_ref[...] = jnp.zeros_like(grad_ref)

    a = a_ref[...]  # (bm, d)
    b = b_ref[...]  # (bm,)
    x = x_ref[...]  # (d,)
    z = a @ x  # MXU matvec
    bz = b * z
    mask = (b != 0.0).astype(a.dtype)  # padded rows have b == 0
    loss_ref[...] += jnp.sum(mask * jnp.logaddexp(0.0, -bz))
    u = mask * (-b) * jax.nn.sigmoid(-bz)
    grad_ref[...] += u @ a  # VPU/MXU reduction to (d,)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def logistic_lossgrad(a: jax.Array, b: jax.Array, x: jax.Array, *,
                      bm: int = 128, interpret: bool = True):
    """Summed logistic loss and gradient (1/m normalization happens in L2).

    Returns ``(loss_scalar, grad_d)``.
    """
    m, d = a.shape
    assert b.shape == (m,) and x.shape == (d,)
    bm = min(m, bm) if m > 0 else 1
    m_pad = pl.cdiv(m, bm) * bm
    a_p = jnp.pad(a, ((0, m_pad - m), (0, 0)))
    b_p = jnp.pad(b, (0, m_pad - m))

    loss, grad = pl.pallas_call(
        _lossgrad_kernel,
        grid=(m_pad // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda k: (k, 0)),
            pl.BlockSpec((bm,), lambda k: (k,)),
            pl.BlockSpec((d,), lambda k: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((), lambda k: ()),
            pl.BlockSpec((d,), lambda k: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((), a.dtype),
            jax.ShapeDtypeStruct((d,), a.dtype),
        ],
        interpret=interpret,
    )(a_p, b_p, x)
    return loss, grad
