"""Pure-jnp oracles for the Pallas kernels (the L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here
written with plain jax.numpy ops; pytest asserts allclose agreement across a
hypothesis-driven sweep of shapes and dtypes (python/tests/test_kernels.py).
"""

import jax
import jax.numpy as jnp


def scaled_gram_ref(a: jax.Array, s: jax.Array) -> jax.Array:
    """``Aᵀ diag(s) A`` — the GLM Hessian core (paper eq. 3).

    Args:
        a: ``(m, d)`` feature matrix.
        s: ``(m,)`` per-row weights.

    Returns:
        ``(d, d)`` symmetric matrix.
    """
    return a.T @ (s[:, None] * a)


def logistic_lossgrad_ref(a: jax.Array, b: jax.Array, x: jax.Array):
    """Summed logistic loss and gradient (no 1/m factor; the model layer
    normalizes).

    ``loss = Σ_j log(1 + exp(−b_j a_jᵀx))``,
    ``grad = Aᵀ u`` with ``u_j = −b_j σ(−b_j a_jᵀx)``.
    """
    z = a @ x
    bz = b * z
    loss = jnp.sum(jnp.logaddexp(0.0, -bz))
    u = -b * jax.nn.sigmoid(-bz)
    grad = a.T @ u
    return loss, grad


def logistic_hess_weights_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """Per-row Hessian weights ``φ″(a_jᵀx) = σ(z)σ(−z)`` (label-free)."""
    z = a @ x
    return jax.nn.sigmoid(z) * jax.nn.sigmoid(-z)


def logistic_hess_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """Summed logistic Hessian ``Aᵀ diag(σσ′) A`` (no 1/m factor)."""
    return scaled_gram_ref(a, logistic_hess_weights_ref(a, x))
