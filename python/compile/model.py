"""L2: the local GLM objective as a JAX program calling the L1 kernels.

These are the functions AOT-lowered (per data shape) into the HLO artifacts
the Rust runtime executes on the coordinator's hot path:

* ``logreg_lossgrad(a, b, x) → (loss, grad)`` — the client's local loss and
  gradient (data term only; the ridge λ lives at the server, see
  DESIGN.md §6.3), fused into a single data pass via the Pallas
  ``logistic_lossgrad`` kernel;
* ``logreg_hess(a, x) → (hess,)`` — the local Hessian, whose scaled-Gram
  core is the Pallas ``scaled_gram`` kernel.

Everything is f64 (the coordinator drives gaps to 1e-12; see DESIGN.md).
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import gram, logistic  # noqa: E402


def logreg_lossgrad(a: jax.Array, b: jax.Array, x: jax.Array):
    """``f_i(x), ∇f_i(x)`` for ``f_i(x) = (1/m) Σ log(1+exp(−b a_jᵀx))``."""
    m = a.shape[0]
    loss_sum, grad_sum = logistic.logistic_lossgrad(a, b, x)
    return loss_sum / m, grad_sum / m


def logreg_hess(a: jax.Array, x: jax.Array):
    """``∇²f_i(x) = (1/m) Aᵀ diag(σ(z)σ(−z)) A`` (label-free weights)."""
    m = a.shape[0]
    z = a @ x
    s = jax.nn.sigmoid(z) * jax.nn.sigmoid(-z) / m
    h = gram.scaled_gram(a, s)
    # Exact symmetry for the coordinator's Cholesky path.
    return ((h + h.T) * 0.5,)


def logreg_loss_ref(a: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    """Plain-jnp local loss — autodiff oracle for the model tests."""
    z = a @ x
    return jnp.mean(jnp.logaddexp(0.0, -b * z))
