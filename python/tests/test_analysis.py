"""Loader round-trips for the sweep-output analysis module.

Fixtures mirror the Rust sinks byte-conventions: `runs.jsonl` rows as
written by `run_row`, `summary.jsonl` rows as written by `summary_jsonl`,
and the 7-column per-round history CSV of `History::to_csv`.
"""

import json
import textwrap

import pytest

from analysis import loader
from analysis.plot_gap_vs_bits import collect_csvs, main as plot_main, series_label

RUN_ROWS = [
    {
        "cell": 1,
        "group": "algo=fednl ds=a1a-s",
        "dataset": "a1a-s",
        "seed": 2,
        "rng_seed": "0x00000000deadbeef",
        "cfg": "0x0000000000000001",
        "status": "ok",
        "label": "fednl",
        "rounds": 40,
        "final_gap": 3.2e-11,
        "bits_per_node": 2.0e6,
        "bits_up_per_node": 1.5e6,
        "bits_to": [
            {"target": 1e-4, "total": 1.0e5, "uplink": 8.0e4},
            {"target": 1e-10, "total": None, "uplink": None},
        ],
    },
    {
        "cell": 0,
        "group": "algo=bl1 ds=a1a-s",
        "dataset": "a1a-s",
        "seed": 1,
        "rng_seed": "0x00000000cafef00d",
        "cfg": "0x0000000000000001",
        "status": "failed",
        "error": "diverged at round 7",
    },
]

SUMMARY_ROWS = [
    {
        "rank": 1,
        "group": "algo=bl1 ds=a1a-s",
        "n_runs": 3,
        "n_ok": 3,
        "final_gap_mean": 1e-12,
        "targets": [{"target": 1e-4, "reached": 3, "bits_mean": 9.5e4, "bits_std": 1.2e3}],
    },
    {
        "rank": 2,
        "group": "algo=fednl ds=a1a-s",
        "n_runs": 3,
        "n_ok": 2,
        "final_gap_mean": 4e-11,
        "targets": [{"target": 1e-4, "reached": 2, "bits_mean": 2.1e5, "bits_std": None}],
    },
]

HISTORY_CSV = textwrap.dedent(
    """\
    round,bits_up_per_node,bits_down_per_node,bits_per_node,gap,grad_norm,dist_to_opt
    0,1024.0,640.0,1664.0,5.000000e-01,1.200000e-01,9.000000e-01
    1,2048.0,1280.0,3328.0,2.500000e-02,6.000000e-02,4.000000e-01
    2,3072.0,1920.0,4992.0,1.000000e-09,1.000000e-05,1.000000e-04
    """
)


def write_jsonl(path, rows):
    path.write_text("".join(json.dumps(r) + "\n" for r in rows), encoding="utf-8")


def test_load_runs_roundtrip(tmp_path):
    path = tmp_path / "runs.jsonl"
    write_jsonl(path, RUN_ROWS)
    rows = loader.load_runs(path)
    # Sorted back into declaration (cell) order regardless of completion order.
    assert [r.cell for r in rows] == [0, 1]
    failed, ok = rows
    assert not failed.ok
    assert failed.error == "diverged at round 7"
    assert failed.final_gap is None and failed.bits_to == []
    assert ok.ok and ok.label == "fednl" and ok.rounds == 40
    assert ok.final_gap == pytest.approx(3.2e-11)
    assert ok.bits_for(1e-4) == pytest.approx(1.0e5)
    assert ok.bits_for(1e-4, uplink=True) == pytest.approx(8.0e4)
    assert ok.bits_for(1e-10) is None  # target present but never reached
    assert ok.bits_for(1e-7) is None  # target absent entirely


def test_load_jsonl_drops_torn_tail_only(tmp_path):
    path = tmp_path / "runs.jsonl"
    text = json.dumps(RUN_ROWS[0]) + "\n" + json.dumps(RUN_ROWS[1])
    path.write_text(text[: len(text) - 9], encoding="utf-8")  # tear the last row
    rows = loader.load_jsonl(path)
    assert len(rows) == 1
    # A malformed *interior* line is a real error, not a torn tail.
    path.write_text('{"broken\n' + json.dumps(RUN_ROWS[0]) + "\n", encoding="utf-8")
    with pytest.raises(ValueError, match="malformed"):
        loader.load_jsonl(path)


def test_load_summary_rank_order(tmp_path):
    path = tmp_path / "summary.jsonl"
    write_jsonl(path, list(reversed(SUMMARY_ROWS)))  # file order ≠ rank order
    groups = loader.load_summary(path)
    assert [g.rank for g in groups] == [1, 2]
    best = groups[0]
    assert best.group == "algo=bl1 ds=a1a-s"
    assert best.n_ok == 3
    assert best.targets[0].bits_mean == pytest.approx(9.5e4)
    # Nullable aggregate fields survive the round trip as None.
    assert groups[1].targets[0].bits_std is None


def test_load_history_csv(tmp_path):
    path = tmp_path / "fig1__a1a-s__bl1.csv"
    path.write_text(HISTORY_CSV, encoding="utf-8")
    cols = loader.load_history_csv(path)
    assert cols["round"] == [0.0, 1.0, 2.0]
    assert cols["gap"][-1] == pytest.approx(1e-9)
    # The Rust invariant: total = up + down on every row.
    for up, down, total in zip(
        cols["bits_up_per_node"], cols["bits_down_per_node"], cols["bits_per_node"]
    ):
        assert total == pytest.approx(up + down)
    # Column-count mismatches are loud.
    path.write_text(HISTORY_CSV + "3,1,2\n", encoding="utf-8")
    with pytest.raises(ValueError, match="columns"):
        loader.load_history_csv(path)


def test_series_label_and_collect(tmp_path):
    a = tmp_path / "fig1__a1a-s__bl1.csv"
    b = tmp_path / "fig1__a1a-s__fednl.csv"
    other = tmp_path / "fig2__a1a-s__newton.csv"
    for p in (a, b, other):
        p.write_text(HISTORY_CSV, encoding="utf-8")
    assert series_label(a) == "a1a-s__bl1"
    assert series_label(tmp_path / "bare.csv") == "bare"
    assert collect_csvs([str(tmp_path)], "fig1") == [a, b]
    assert collect_csvs([str(a), str(b)], None) == [a, b]
    with pytest.raises(FileNotFoundError):
        collect_csvs([str(tmp_path / "missing.csv")], None)
    with pytest.raises(FileNotFoundError):
        collect_csvs([str(tmp_path)], "fig9")


def test_plot_script_end_to_end(tmp_path):
    pytest.importorskip("matplotlib")
    for name in ("fig1__a1a-s__bl1.csv", "fig1__a1a-s__fednl.csv"):
        (tmp_path / name).write_text(HISTORY_CSV, encoding="utf-8")
    out = tmp_path / "fig1.png"
    written = plot_main(
        [str(tmp_path), "--experiment", "fig1", "--uplink", "--out", str(out)]
    )
    assert written == out
    assert out.stat().st_size > 0
