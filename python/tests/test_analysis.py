"""Loader round-trips for the sweep-output analysis module.

Fixtures mirror the Rust sinks byte-conventions: `runs.jsonl` rows as
written by `run_row`, `summary.jsonl` rows as written by `summary_jsonl`,
the 7-column per-round history CSV of `History::to_csv`, and obs trace
events as written by `Event::to_json` (docs/TRACING.md).
"""

import json
import textwrap

import pytest

from analysis import load_trace as lt
from analysis import loader
from analysis.plot_gap_vs_bits import collect_csvs, main as plot_main, series_label
from analysis.plot_phase_breakdown import collect_breakdowns, main as phase_main

RUN_ROWS = [
    {
        "cell": 1,
        "group": "algo=fednl ds=a1a-s",
        "dataset": "a1a-s",
        "seed": 2,
        "rng_seed": "0x00000000deadbeef",
        "cfg": "0x0000000000000001",
        "status": "ok",
        "label": "fednl",
        "rounds": 40,
        "final_gap": 3.2e-11,
        "bits_per_node": 2.0e6,
        "bits_up_per_node": 1.5e6,
        "bits_to": [
            {"target": 1e-4, "total": 1.0e5, "uplink": 8.0e4},
            {"target": 1e-10, "total": None, "uplink": None},
        ],
    },
    {
        "cell": 0,
        "group": "algo=bl1 ds=a1a-s",
        "dataset": "a1a-s",
        "seed": 1,
        "rng_seed": "0x00000000cafef00d",
        "cfg": "0x0000000000000001",
        "status": "failed",
        "error": "diverged at round 7",
    },
]

SUMMARY_ROWS = [
    {
        "rank": 1,
        "group": "algo=bl1 ds=a1a-s",
        "n_runs": 3,
        "n_ok": 3,
        "final_gap_mean": 1e-12,
        "targets": [{"target": 1e-4, "reached": 3, "bits_mean": 9.5e4, "bits_std": 1.2e3}],
    },
    {
        "rank": 2,
        "group": "algo=fednl ds=a1a-s",
        "n_runs": 3,
        "n_ok": 2,
        "final_gap_mean": 4e-11,
        "targets": [{"target": 1e-4, "reached": 2, "bits_mean": 2.1e5, "bits_std": None}],
    },
]

HISTORY_CSV = textwrap.dedent(
    """\
    round,bits_up_per_node,bits_down_per_node,bits_per_node,gap,grad_norm,dist_to_opt
    0,1024.0,640.0,1664.0,5.000000e-01,1.200000e-01,9.000000e-01
    1,2048.0,1280.0,3328.0,2.500000e-02,6.000000e-02,4.000000e-01
    2,3072.0,1920.0,4992.0,1.000000e-09,1.000000e-05,1.000000e-04
    """
)


def write_jsonl(path, rows):
    path.write_text("".join(json.dumps(r) + "\n" for r in rows), encoding="utf-8")


def test_load_runs_roundtrip(tmp_path):
    path = tmp_path / "runs.jsonl"
    write_jsonl(path, RUN_ROWS)
    rows = loader.load_runs(path)
    # Sorted back into declaration (cell) order regardless of completion order.
    assert [r.cell for r in rows] == [0, 1]
    failed, ok = rows
    assert not failed.ok
    assert failed.error == "diverged at round 7"
    assert failed.final_gap is None and failed.bits_to == []
    assert ok.ok and ok.label == "fednl" and ok.rounds == 40
    assert ok.final_gap == pytest.approx(3.2e-11)
    assert ok.bits_for(1e-4) == pytest.approx(1.0e5)
    assert ok.bits_for(1e-4, uplink=True) == pytest.approx(8.0e4)
    assert ok.bits_for(1e-10) is None  # target present but never reached
    assert ok.bits_for(1e-7) is None  # target absent entirely


def test_load_jsonl_drops_torn_tail_only(tmp_path):
    path = tmp_path / "runs.jsonl"
    text = json.dumps(RUN_ROWS[0]) + "\n" + json.dumps(RUN_ROWS[1])
    path.write_text(text[: len(text) - 9], encoding="utf-8")  # tear the last row
    rows = loader.load_jsonl(path)
    assert len(rows) == 1
    # A malformed *interior* line is a real error, not a torn tail.
    path.write_text('{"broken\n' + json.dumps(RUN_ROWS[0]) + "\n", encoding="utf-8")
    with pytest.raises(ValueError, match="malformed"):
        loader.load_jsonl(path)


def test_load_summary_rank_order(tmp_path):
    path = tmp_path / "summary.jsonl"
    write_jsonl(path, list(reversed(SUMMARY_ROWS)))  # file order ≠ rank order
    groups = loader.load_summary(path)
    assert [g.rank for g in groups] == [1, 2]
    best = groups[0]
    assert best.group == "algo=bl1 ds=a1a-s"
    assert best.n_ok == 3
    assert best.targets[0].bits_mean == pytest.approx(9.5e4)
    # Nullable aggregate fields survive the round trip as None.
    assert groups[1].targets[0].bits_std is None


def test_load_history_csv(tmp_path):
    path = tmp_path / "fig1__a1a-s__bl1.csv"
    path.write_text(HISTORY_CSV, encoding="utf-8")
    cols = loader.load_history_csv(path)
    assert cols["round"] == [0.0, 1.0, 2.0]
    assert cols["gap"][-1] == pytest.approx(1e-9)
    # The Rust invariant: total = up + down on every row.
    for up, down, total in zip(
        cols["bits_up_per_node"], cols["bits_down_per_node"], cols["bits_per_node"]
    ):
        assert total == pytest.approx(up + down)
    # Column-count mismatches are loud.
    path.write_text(HISTORY_CSV + "3,1,2\n", encoding="utf-8")
    with pytest.raises(ValueError, match="columns"):
        loader.load_history_csv(path)


def test_series_label_and_collect(tmp_path):
    a = tmp_path / "fig1__a1a-s__bl1.csv"
    b = tmp_path / "fig1__a1a-s__fednl.csv"
    other = tmp_path / "fig2__a1a-s__newton.csv"
    for p in (a, b, other):
        p.write_text(HISTORY_CSV, encoding="utf-8")
    assert series_label(a) == "a1a-s__bl1"
    assert series_label(tmp_path / "bare.csv") == "bare"
    assert collect_csvs([str(tmp_path)], "fig1") == [a, b]
    assert collect_csvs([str(a), str(b)], None) == [a, b]
    with pytest.raises(FileNotFoundError):
        collect_csvs([str(tmp_path / "missing.csv")], None)
    with pytest.raises(FileNotFoundError):
        collect_csvs([str(tmp_path)], "fig9")


def test_plot_script_end_to_end(tmp_path):
    pytest.importorskip("matplotlib")
    for name in ("fig1__a1a-s__bl1.csv", "fig1__a1a-s__fednl.csv"):
        (tmp_path / name).write_text(HISTORY_CSV, encoding="utf-8")
    out = tmp_path / "fig1.png"
    written = plot_main(
        [str(tmp_path), "--experiment", "fig1", "--uplink", "--out", str(out)]
    )
    assert written == out
    assert out.stat().st_size > 0


# --- obs trace loader -------------------------------------------------------

TRACE_ROWS = [
    {"ev": "mark", "name": "run", "lane": "server", "ts_us": 0.0, "note": "label=BL1"},
    {"ev": "span", "name": "round", "lane": "server", "ts_us": 1.0, "dur_us": 100.0, "round": 0},
    {
        "ev": "span",
        "name": "plan",
        "lane": "server",
        "ts_us": 2.0,
        "dur_us": 10.0,
        "round": 0,
        "exchange": 0,
    },
    {
        "ev": "bits",
        "name": "msg",
        "lane": "server",
        "ts_us": 13.0,
        "round": 0,
        "exchange": 0,
        "client": 1,
        "dir": "down",
        "kind": "model",
        "floats": 10,
        "aux_bits": 0,
        "bits": 640.0,
    },
    {
        "ev": "span",
        "name": "compute",
        "lane": "client:1",
        "ts_us": 15.0,
        "dur_us": 60.0,
        "round": 0,
        "exchange": 0,
        "client": 1,
    },
    {
        "ev": "bits",
        "name": "msg",
        "lane": "server",
        "ts_us": 80.0,
        "round": 0,
        "exchange": 0,
        "client": 1,
        "dir": "up",
        "kind": "hess_delta",
        "floats": 4,
        "aux_bits": 64,
        "bits": 320.0,
    },
    {"ev": "span", "name": "cell", "lane": "sweep:0", "ts_us": 0.0, "dur_us": 120.0, "cell": 3},
]


def test_load_trace_validates_clean_fixture(tmp_path):
    path = tmp_path / "trace.jsonl"
    write_jsonl(path, TRACE_ROWS)
    events = lt.load_trace(path)
    assert len(events) == len(TRACE_ROWS)
    assert lt.validate(events) == []
    # Optional fields survive as None; typed fields are coerced.
    run = events[0]
    assert run.ev == "mark" and run.dur_us is None and run.note == "label=BL1"
    msg = events[3]
    assert msg.dir == "down" and msg.kind == "model" and msg.bits == 640.0
    assert msg.client == 1 and isinstance(msg.client, int)


def test_load_trace_requires_base_fields(tmp_path):
    path = tmp_path / "trace.jsonl"
    write_jsonl(path, [{"name": "x", "lane": "server", "ts_us": 0.0}, TRACE_ROWS[0]])
    with pytest.raises(ValueError, match="missing required field 'ev'"):
        lt.load_trace(path)


def test_validate_flags_schema_problems():
    events = [
        lt.TraceEvent(ev="span", name="nodur", lane="server", ts_us=0.0),
        lt.TraceEvent(ev="span", name="neg", lane="server", ts_us=0.0, dur_us=-1.0),
        lt.TraceEvent(ev="bits", name="msg", lane="server", ts_us=0.0, bits=8.0),
        lt.TraceEvent(ev="bits", name="msg", lane="server", ts_us=0.0, dir="sideways",
                      kind="model", bits=8.0),
        lt.TraceEvent(ev="zap", name="x", lane="server", ts_us=0.0),
    ]
    problems = "\n".join(lt.validate(events))
    assert "span without dur_us" in problems
    assert "negative dur_us" in problems
    assert "bits event without 'dir'" in problems
    assert "bad dir 'sideways'" in problems
    assert "unknown ev 'zap'" in problems


def test_span_nesting_check():
    def span(name, ts, dur, lane="server", cell=None):
        return lt.TraceEvent(ev="span", name=name, lane=lane, ts_us=ts, dur_us=dur, cell=cell)

    # Properly nested + disjoint siblings: clean.
    good = [span("round", 0.0, 100.0), span("plan", 1.0, 10.0), span("absorb", 20.0, 30.0)]
    assert lt.check_span_nesting(good) == []
    # Straddling spans in one timeline: flagged.
    bad = [span("a", 0.0, 50.0), span("b", 40.0, 50.0)]
    assert any("overlaps but is not nested" in p for p in lt.check_span_nesting(bad))
    # The same intervals on different lanes (or cells) never conflict.
    assert lt.check_span_nesting([span("a", 0.0, 50.0), span("b", 40.0, 50.0, lane="client:0")
                                  ]) == []
    assert lt.check_span_nesting([span("a", 0.0, 50.0, cell=0), span("b", 40.0, 50.0, cell=1)
                                  ]) == []


def test_trace_aggregations():
    events = [lt.TraceEvent.from_dict(r) for r in TRACE_ROWS]
    totals = lt.phase_totals(events)
    assert totals == {"cell": 120.0, "round": 100.0, "compute": 60.0, "plan": 10.0}
    assert list(totals) == ["cell", "round", "compute", "plan"]  # largest first
    kinds = lt.bits_by_kind(events)
    assert kinds[("down", "model")] == (1, 640.0)
    assert kinds[("up", "hess_delta")] == (1, 320.0)
    flows = lt.round_flows(events)
    assert flows[(None, 0, "down")] == 640.0
    assert flows[(None, 0, "up")] == 320.0


def test_chrome_cross_check(tmp_path):
    events = [lt.TraceEvent.from_dict(r) for r in TRACE_ROWS]
    chrome = tmp_path / "chrome.json"
    x = [{"ph": "X", "dur": e.dur_us} for e in events if e.ev == "span"]
    i = [{"ph": "i"} for e in events if e.ev != "span"]
    meta = [{"ph": "M", "name": "thread_name"}]
    chrome.write_text(json.dumps({"traceEvents": x + i + meta}), encoding="utf-8")
    assert lt.cross_check_chrome(events, lt.load_chrome(chrome)) == []
    # Dropping a span or perturbing a duration is caught.
    chrome.write_text(json.dumps({"traceEvents": x[1:] + i + meta}), encoding="utf-8")
    problems = lt.cross_check_chrome(events, lt.load_chrome(chrome))
    assert any("X events" in p for p in problems)
    assert any("span time" in p for p in problems)
    # A non-export JSON file is rejected outright.
    chrome.write_text(json.dumps({"other": 1}), encoding="utf-8")
    with pytest.raises(ValueError, match="traceEvents"):
        lt.load_chrome(chrome)


def test_load_trace_cli_gate(tmp_path, capsys):
    path = tmp_path / "trace.jsonl"
    write_jsonl(path, TRACE_ROWS)
    assert lt.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "7 events" in out and "ok: schema valid" in out
    # Broken trace → non-zero exit for the CI gate.
    write_jsonl(path, TRACE_ROWS + [{"ev": "span", "name": "nodur", "lane": "x", "ts_us": 0.0}])
    assert lt.main([str(path)]) == 1
    assert "PROBLEM" in capsys.readouterr().out


def test_phase_breakdown_collect_and_plot(tmp_path):
    a = tmp_path / "trace_bl1.jsonl"
    b = tmp_path / "trace_fednl.jsonl"
    write_jsonl(a, TRACE_ROWS)
    write_jsonl(b, TRACE_ROWS[:3])  # run mark + round + plan only
    labels, breakdowns = collect_breakdowns([a, b], keep_containers=False)
    assert labels == ["trace_bl1", "trace_fednl"]
    # Container spans (round/cell) are dropped to avoid double counting.
    assert breakdowns[0] == {"compute": 60.0, "plan": 10.0}
    assert breakdowns[1] == {"plan": 10.0}
    pytest.importorskip("matplotlib")
    out = tmp_path / "phases.png"
    written = phase_main([str(a), str(b), "--out", str(out), "--title", "phases"])
    assert written == out
    assert out.stat().st_size > 0
