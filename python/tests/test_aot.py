"""AOT pipeline tests: lowering, manifest format, artifact content."""

import pathlib

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import aot, model  # noqa: E402
from compile.kernels import ref  # noqa: E402


def test_parse_shapes():
    assert aot.parse_shapes("50x40,30x10") == [(50, 40), (30, 10)]
    assert aot.parse_shapes(" 7X5 ") == [(7, 5)]
    assert aot.parse_shapes("") == []


def test_lower_entry_produces_hlo_text():
    text = aot.lower_entry("logreg_lossgrad", 7, 5)
    assert text.startswith("HloModule")
    # f64 throughout.
    assert "f64[7,5]" in text
    assert "f32" not in text
    hess = aot.lower_entry("logreg_hess", 7, 5)
    assert "f64[5,5]" in hess


def test_lower_entry_rejects_unknown():
    try:
        aot.lower_entry("nope", 2, 2)
    except ValueError as e:
        assert "nope" in str(e)
    else:
        raise AssertionError("expected ValueError")


def test_build_writes_manifest_and_artifacts(tmp_path: pathlib.Path):
    lines = aot.build(tmp_path, [(6, 4)])
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "logreg_lossgrad 6 4 logreg_lossgrad_6x4.hlo.txt" in manifest
    assert "logreg_hess 6 4 logreg_hess_6x4.hlo.txt" in manifest
    assert len([l for l in lines if not l.startswith("#")]) == 2
    for f in ("logreg_lossgrad_6x4.hlo.txt", "logreg_hess_6x4.hlo.txt"):
        assert (tmp_path / f).read_text().startswith("HloModule")


def test_lowered_computation_matches_eager(tmp_path: pathlib.Path):
    """Compile the lowered HLO back through jax and compare numerics —
    the python-side half of the round-trip the Rust integration test does."""
    m, d = 9, 4
    rng = np.random.default_rng(3)
    a = np.asarray(rng.normal(size=(m, d)))
    b = np.where(rng.uniform(size=m) < 0.5, -1.0, 1.0)
    x = rng.normal(size=(d,))

    f64 = jax.numpy.float64
    lowered = jax.jit(model.logreg_lossgrad).lower(
        jax.ShapeDtypeStruct((m, d), f64),
        jax.ShapeDtypeStruct((m,), f64),
        jax.ShapeDtypeStruct((d,), f64),
    )
    compiled = lowered.compile()
    loss, grad = compiled(a, b, x)
    rloss, rgrad = ref.logistic_lossgrad_ref(a, b, x)
    np.testing.assert_allclose(loss, rloss / m, rtol=1e-12)
    np.testing.assert_allclose(grad, rgrad / m, rtol=1e-10, atol=1e-15)


def test_default_shapes_cover_registry_and_tests():
    # The shapes the Rust side depends on must stay in the default grid.
    required = {(50, 40), (30, 10), (100, 30)}
    assert required.issubset(set(aot.DEFAULT_SHAPES))
