"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, block sizes and dtypes; every property asserts
allclose against `compile.kernels.ref`. This is the CORE correctness signal
for the kernels the AOT artifacts embed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import gram, logistic, ref  # noqa: E402

dims = st.integers(min_value=1, max_value=37)
rows = st.integers(min_value=1, max_value=150)
blocks = st.sampled_from([1, 2, 3, 8, 16, 128])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def make_data(m, d, seed, dtype=np.float64):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, d)).astype(dtype))
    s = jnp.asarray(rng.uniform(0.0, 1.0, size=(m,)).astype(dtype))
    b = jnp.asarray(np.where(rng.uniform(size=m) < 0.5, -1.0, 1.0).astype(dtype))
    x = jnp.asarray(rng.normal(size=(d,)).astype(dtype))
    return a, s, b, x


class TestScaledGram:
    @settings(max_examples=40, deadline=None)
    @given(m=rows, d=dims, seed=seeds)
    def test_matches_ref(self, m, d, seed):
        a, s, _, _ = make_data(m, d, seed)
        got = gram.scaled_gram(a, s)
        want = ref.scaled_gram_ref(a, s)
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)

    @settings(max_examples=25, deadline=None)
    @given(m=rows, d=dims, bm=blocks, bd=blocks, seed=seeds)
    def test_block_size_invariance(self, m, d, bm, bd, seed):
        """The result must not depend on the tiling."""
        a, s, _, _ = make_data(m, d, seed)
        got = gram.scaled_gram(a, s, bm=bm, bd=bd)
        want = ref.scaled_gram_ref(a, s)
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)

    @settings(max_examples=15, deadline=None)
    @given(m=rows, d=dims, seed=seeds)
    def test_output_symmetric(self, m, d, seed):
        a, s, _, _ = make_data(m, d, seed)
        g = np.asarray(gram.scaled_gram(a, s))
        np.testing.assert_allclose(g, g.T, rtol=0, atol=1e-11)

    @settings(max_examples=15, deadline=None)
    @given(m=rows, d=dims, seed=seeds)
    def test_psd_for_nonnegative_weights(self, m, d, seed):
        a, s, _, _ = make_data(m, d, seed)
        g = np.asarray(gram.scaled_gram(a, s))
        eig = np.linalg.eigvalsh((g + g.T) / 2)
        assert eig.min() >= -1e-9

    def test_float32(self):
        a, s, _, _ = make_data(64, 16, 0, dtype=np.float32)
        got = gram.scaled_gram(a, s)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(got, ref.scaled_gram_ref(a, s), rtol=1e-5, atol=1e-5)

    def test_zero_weights_give_zero(self):
        a, _, _, _ = make_data(20, 6, 1)
        z = gram.scaled_gram(a, jnp.zeros(20, dtype=a.dtype))
        np.testing.assert_array_equal(np.asarray(z), 0.0)

    def test_vmem_estimate(self):
        # 128×128 f32 default tiling working set ≈ 197 KiB.
        floats = gram.vmem_floats(128, 128)
        assert floats == 2 * 128 * 128 + 128 + 128 * 128
        assert floats * 4 < 16 * 2**20  # fits VMEM with headroom


class TestLogisticLossgrad:
    @settings(max_examples=40, deadline=None)
    @given(m=rows, d=dims, seed=seeds)
    def test_matches_ref(self, m, d, seed):
        a, _, b, x = make_data(m, d, seed)
        loss, grad = logistic.logistic_lossgrad(a, b, x)
        rloss, rgrad = ref.logistic_lossgrad_ref(a, b, x)
        np.testing.assert_allclose(loss, rloss, rtol=1e-10)
        np.testing.assert_allclose(grad, rgrad, rtol=1e-9, atol=1e-12)

    @settings(max_examples=25, deadline=None)
    @given(m=rows, d=dims, bm=blocks, seed=seeds)
    def test_block_size_invariance(self, m, d, bm, seed):
        a, _, b, x = make_data(m, d, seed)
        loss, grad = logistic.logistic_lossgrad(a, b, x, bm=bm)
        rloss, rgrad = ref.logistic_lossgrad_ref(a, b, x)
        np.testing.assert_allclose(loss, rloss, rtol=1e-10)
        np.testing.assert_allclose(grad, rgrad, rtol=1e-9, atol=1e-12)

    @settings(max_examples=20, deadline=None)
    @given(m=rows, d=dims, seed=seeds)
    def test_grad_matches_autodiff(self, m, d, seed):
        """Kernel gradient == jax.grad of the summed reference loss."""
        a, _, b, x = make_data(m, d, seed)
        _, grad = logistic.logistic_lossgrad(a, b, x)
        auto = jax.grad(lambda xx: ref.logistic_lossgrad_ref(a, b, xx)[0])(x)
        np.testing.assert_allclose(grad, auto, rtol=1e-9, atol=1e-12)

    def test_loss_at_zero_is_m_log2(self):
        a, _, b, _ = make_data(33, 5, 2)
        loss, grad = logistic.logistic_lossgrad(a, b, jnp.zeros(5, dtype=a.dtype))
        np.testing.assert_allclose(loss, 33 * np.log(2.0), rtol=1e-12)

    def test_extreme_margins_are_stable(self):
        """log1p/sigmoid must not overflow at |z| ~ 700."""
        a = jnp.asarray(np.full((4, 2), 500.0))
        b = jnp.asarray([1.0, -1.0, 1.0, -1.0])
        x = jnp.asarray([1.0, 1.0])
        loss, grad = logistic.logistic_lossgrad(a, b, x)
        assert np.isfinite(float(loss))
        assert np.isfinite(np.asarray(grad)).all()


class TestHessianComposition:
    """The L2 Hessian (gram kernel fed with σσ' weights) vs oracle."""

    @settings(max_examples=25, deadline=None)
    @given(m=rows, d=dims, seed=seeds)
    def test_hess_matches_ref(self, m, d, seed):
        a, _, _, x = make_data(m, d, seed)
        w = ref.logistic_hess_weights_ref(a, x)
        got = gram.scaled_gram(a, w)
        want = ref.logistic_hess_ref(a, x)
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(2, 60), d=st.integers(1, 20), seed=seeds)
    def test_hess_matches_jax_hessian(self, m, d, seed):
        a, _, b, x = make_data(m, d, seed)
        got = gram.scaled_gram(a, ref.logistic_hess_weights_ref(a, x))
        auto = jax.hessian(lambda xx: ref.logistic_lossgrad_ref(a, b, xx)[0])(x)
        np.testing.assert_allclose(got, auto, rtol=1e-8, atol=1e-10)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
