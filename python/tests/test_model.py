"""L2 correctness: the model entry points that get AOT-lowered."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402

seeds = st.integers(min_value=0, max_value=2**31 - 1)


def make(m, d, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, d)) / np.sqrt(d))
    b = jnp.asarray(np.where(rng.uniform(size=m) < 0.5, -1.0, 1.0))
    x = jnp.asarray(rng.normal(size=(d,)))
    return a, b, x


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 80), d=st.integers(1, 25), seed=seeds)
def test_lossgrad_is_mean_normalized(m, d, seed):
    a, b, x = make(m, d, seed)
    loss, grad = model.logreg_lossgrad(a, b, x)
    rloss, rgrad = ref.logistic_lossgrad_ref(a, b, x)
    np.testing.assert_allclose(loss, rloss / m, rtol=1e-10)
    np.testing.assert_allclose(grad, rgrad / m, rtol=1e-9, atol=1e-14)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 60), d=st.integers(1, 20), seed=seeds)
def test_grad_is_autodiff_of_loss(m, d, seed):
    a, b, x = make(m, d, seed)
    _, grad = model.logreg_lossgrad(a, b, x)
    auto = jax.grad(model.logreg_loss_ref, argnums=2)(a, b, x)
    np.testing.assert_allclose(grad, auto, rtol=1e-9, atol=1e-14)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(2, 50), d=st.integers(1, 15), seed=seeds)
def test_hess_is_autodiff_hessian(m, d, seed):
    a, b, x = make(m, d, seed)
    (h,) = model.logreg_hess(a, x)
    auto = jax.hessian(model.logreg_loss_ref, argnums=2)(a, b, x)
    np.testing.assert_allclose(h, auto, rtol=1e-8, atol=1e-11)


def test_hess_exactly_symmetric():
    a, b, x = make(40, 12, 7)
    (h,) = model.logreg_hess(a, x)
    h = np.asarray(h)
    np.testing.assert_array_equal(h, h.T)


def test_outputs_are_f64():
    a, b, x = make(10, 4, 0)
    loss, grad = model.logreg_lossgrad(a, b, x)
    (h,) = model.logreg_hess(a, x)
    assert loss.dtype == jnp.float64
    assert grad.dtype == jnp.float64
    assert h.dtype == jnp.float64
