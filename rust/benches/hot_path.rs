//! Hot-path micro-benchmarks (the §Perf targets of EXPERIMENTS.md):
//! linalg primitives, compressors, bases, local oracles, the server solve,
//! the wire codec, and the PJRT dispatch overhead vs the native oracle.
//!
//! ```bash
//! cargo bench --bench hot_path                     # all groups
//! cargo bench --bench hot_path -- gram             # filter by substring
//! cargo bench --bench hot_path -- --json out.json  # bench-v1 report (docs/PERF.md)
//! ```

use basis_learn::basis::{HessianBasis, PsdBasis, StandardBasis, SubspaceBasis};
use basis_learn::bench_util::{black_box, Bench, CountingAlloc};
use basis_learn::compressors::CompressorSpec;
use basis_learn::coordinator::project_psd;
use basis_learn::data::{FederatedDataset, SyntheticSpec};
use basis_learn::linalg::{cholesky_solve, svd, sym_eigen, Mat};
use basis_learn::problem::{LocalProblem, LogisticProblem};
use basis_learn::rng::Rng;

/// Every case reports gross heap bytes per iteration alongside its time.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Parsed bench CLI: positional args are substring name filters; `--json
/// PATH` writes the machine-readable report (the PATH value must *not*
/// leak into the filter set, so parsing consumes it explicitly); `--quick`
/// switches to the tiny CI smoke budget.
struct Cli {
    filters: Vec<String>,
    json: Option<String>,
    quick: bool,
}

fn parse_cli() -> Cli {
    let mut filters = Vec::new();
    let mut json = None;
    let mut quick = false;
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        if a == "--json" {
            json = it.next();
        } else if let Some(v) = a.strip_prefix("--json=") {
            json = Some(v.to_string());
        } else if a == "--quick" {
            quick = true;
        } else if !a.starts_with('-') {
            filters.push(a);
        }
    }
    Cli { filters, json, quick }
}

impl Cli {
    fn matches(&self, name: &str) -> bool {
        self.filters.is_empty() || self.filters.iter().any(|a| name.contains(a.as_str()))
    }
}

fn main() {
    let cli = parse_cli();
    let filter_match = |name: &str| cli.matches(name);
    let mut b = if cli.quick { Bench::quick() } else { Bench::new() };
    let mut rng = Rng::new(1);

    // ── linalg primitives ──
    if filter_match("linalg") {
        b.group("linalg (d=123, the a1a dimension)");
        let d = 123;
        let a = Mat::from_fn(d, d, |_, _| rng.normal());
        let mut spd = a.transpose().matmul(&a);
        spd.add_diag(1.0);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        b.bench("linalg/matmul 123x123", || a.matmul(&a));
        b.bench("linalg/matvec 123x123", || a.matvec(&x));
        b.bench("linalg/cholesky_solve 123", || cholesky_solve(&spd, &x).unwrap());
        b.bench("linalg/sym_eigen 123", || sym_eigen(&spd));
        b.bench("linalg/svd 123", || svd(&a));
        b.bench("linalg/project_psd 123", || project_psd(&spd, 1e-3));
    }

    // ── the Hessian assembly (native mirror of the L1 Pallas kernel) ──
    if filter_match("gram") {
        b.group("scaled Gram Aᵀdiag(s)A (the L1 kernel's native mirror)");
        for (m, d) in [(100, 123), (1000, 123), (500, 300)] {
            let a = Mat::from_fn(m, d, |_, _| rng.normal());
            let s: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
            b.bench(format!("gram/{m}x{d}"), || a.gram_scaled(&s));
        }
    }

    // ── local oracles ──
    if filter_match("oracle") {
        b.group("logistic oracle (m=100, d=123)");
        let fed = FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 1,
            m_per_client: 100,
            dim: 123,
            intrinsic_dim: 60,
            noise: 0.0,
            seed: 5,
        });
        let p = LogisticProblem::new(fed.clients[0].a.clone(), fed.clients[0].b.clone());
        let x: Vec<f64> = (0..123).map(|_| rng.normal() * 0.1).collect();
        b.bench("oracle/loss_grad", || p.loss_grad(&x));
        b.bench("oracle/hess", || p.hess(&x));
        b.bench("oracle/hess_vec", || p.hess_vec(&x, &x));
    }

    // ── compressors on d×d Hessian-difference-like inputs ──
    if filter_match("compress") {
        b.group("matrix compressors (64×64 symmetric input)");
        let d = 64;
        let mut a = Mat::from_fn(d, d, |_, _| rng.normal());
        a.symmetrize();
        for spec in ["topk:64", "randk:64", "rank:1", "dith:8", "nat", "rrank:1", "ntopk:64"] {
            let comp = CompressorSpec::parse(spec).unwrap().build_mat(d);
            let mut r = rng.derive(9);
            b.bench(format!("compress/{spec}"), || comp.compress(black_box(&a), &mut r));
        }
    }

    // ── bases ──
    if filter_match("basis") {
        b.group("basis encode/decode (d=123, r=60)");
        let d = 123;
        let v = basis_learn::basis::subspace::orthonormal_cols(d, 60, &mut rng);
        let bases: Vec<Box<dyn HessianBasis>> = vec![
            Box::new(StandardBasis::new(d)),
            Box::new(SubspaceBasis::new(v)),
            Box::new(PsdBasis::new(d)),
        ];
        let mut h = Mat::from_fn(d, d, |_, _| rng.normal());
        h.symmetrize();
        for basis in &bases {
            let coeff = basis.encode(&h);
            b.bench(format!("basis/encode/{}", basis.name()), || basis.encode(black_box(&h)));
            b.bench(format!("basis/decode/{}", basis.name()), || basis.decode(black_box(&coeff)));
        }
    }

    // ── packed symmetric kernels vs dense (the SymMat hot path) ──
    if filter_match("sym") {
        basis_learn::bench_util::bench_sym_group(&mut b, &mut rng);
    }

    // ── in-place kernels vs their allocating counterparts ──
    if filter_match("into") {
        basis_learn::bench_util::bench_into_group(&mut b, &mut rng);
    }

    // ── wire codec: packet encode/decode on the TCP backend's hot path ──
    if filter_match("wire") {
        basis_learn::bench_util::bench_wire_group(&mut b, &mut rng);
    }

    // ── transport backends: per-round wall time, serial vs concurrent ──
    if filter_match("transport") {
        bench_transport(&mut b);
    }

    // ── PJRT dispatch vs native (needs artifacts + the `pjrt` feature) ──
    if filter_match("pjrt") {
        bench_pjrt(&mut b, &mut rng);
    }

    println!("\n{} cases measured.", b.results().len());
    if let Some(path) = &cli.json {
        match std::fs::write(path, basis_learn::bench_util::json_report(b.results())) {
            Ok(()) => println!("wrote bench report {path}"),
            Err(e) => {
                eprintln!("error writing bench report {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// Per-round wall time of one BL1 round (d = 200, n = 8 clients, Top-K on
/// the 30×30 subspace coefficients) under `Lockstep` vs `Threaded:{2,4,8}`.
/// The client phase — Hessian evaluation + basis projection + compression —
/// dominates, which is exactly what the threaded backend parallelizes; the
/// serial server solve bounds the achievable speedup (Amdahl).
fn bench_transport(b: &mut Bench) {
    use basis_learn::config::{Algorithm, RunConfig};
    use basis_learn::coordinator::{
        build_split, estimate_smoothness, native_local, native_locals, run_one_round, Env,
        ServerState,
    };
    use basis_learn::transport::{client_rngs, Lockstep, Tcp, Threaded};

    b.group("transport backends (one BL1 round, d=200, n=8, m=60/client)");
    let fed = FederatedDataset::synthetic(&SyntheticSpec {
        n_clients: 8,
        m_per_client: 60,
        dim: 200,
        intrinsic_dim: 30,
        noise: 0.0,
        seed: 77,
    });
    let cfg = RunConfig {
        algorithm: Algorithm::Bl1,
        hess_comp: CompressorSpec::TopK(30),
        target_gap: 0.0,
        ..RunConfig::default()
    };
    let locals = native_locals(&fed);
    let features: Vec<Option<Mat>> = fed.clients.iter().map(|c| Some(c.a.clone())).collect();
    let smoothness = estimate_smoothness(&locals, cfg.lambda);
    let env = Env {
        locals: &locals,
        cfg: &cfg,
        d: fed.dim(),
        n: fed.n_clients(),
        smoothness,
        features,
        obs: basis_learn::obs::Obs::noop(),
    };

    {
        let (mut server, clients) = build_split(&env).unwrap();
        // Pooled, like the production factory: steady-state rounds reuse
        // packet buffers instead of allocating (visible in the B/it column).
        let mut transport = Lockstep::new(&locals, clients, client_rngs(cfg.seed, env.n))
            .with_pool(server.pool().cloned());
        let mut srv_rng = Rng::new(cfg.seed);
        let mut round = 0usize;
        b.bench("transport/lockstep", || {
            let tally =
                run_one_round(&env, server.as_mut(), &mut transport, round, &mut srv_rng).unwrap();
            round += 1;
            tally.up_bits
        });
    }
    let factory = |i: usize| native_local(&fed, i);
    for k in [2usize, 4, 8] {
        let (mut server, clients) = build_split(&env).unwrap();
        std::thread::scope(|scope| {
            let mut transport =
                Threaded::spawn(scope, k, clients, client_rngs(cfg.seed, env.n), &factory);
            let mut srv_rng = Rng::new(cfg.seed);
            let mut round = 0usize;
            b.bench(format!("transport/threaded:{k}"), || {
                let tally =
                    run_one_round(&env, server.as_mut(), &mut transport, round, &mut srv_rng)
                        .unwrap();
                round += 1;
                tally.up_bits
            });
        });
    }
    // Same round over real loopback sockets: adds the wire codec + kernel
    // socket round-trips on top of threaded:4's compute parallelism.
    {
        let (mut server, clients) = build_split(&env).unwrap();
        std::thread::scope(|scope| {
            let mut transport = Tcp::spawn(
                scope,
                4,
                clients,
                client_rngs(cfg.seed, env.n),
                &factory,
                basis_learn::obs::Obs::noop(),
            )
            .unwrap();
            let mut srv_rng = Rng::new(cfg.seed);
            let mut round = 0usize;
            b.bench("transport/tcp:4", || {
                let tally =
                    run_one_round(&env, server.as_mut(), &mut transport, round, &mut srv_rng)
                        .unwrap();
                round += 1;
                tally.up_bits
            });
        });
    }
}

#[cfg(feature = "pjrt")]
fn bench_pjrt(b: &mut Bench, rng: &mut Rng) {
    b.group("PJRT dispatch vs native oracle (m=100, d=30)");
    match basis_learn::runtime::Runtime::load(std::path::Path::new("artifacts")) {
        Ok(rt) => {
            let rt = std::rc::Rc::new(rt);
            let fed = FederatedDataset::synthetic(&SyntheticSpec {
                n_clients: 1,
                m_per_client: 100,
                dim: 30,
                intrinsic_dim: 6,
                noise: 0.0,
                seed: 6,
            });
            let c = &fed.clients[0];
            let native = LogisticProblem::new(c.a.clone(), c.b.clone());
            let pjrt =
                basis_learn::runtime::PjrtProblem::new(rt, c.a.clone(), c.b.clone()).unwrap();
            let x: Vec<f64> = (0..30).map(|_| rng.normal() * 0.1).collect();
            b.bench("pjrt/loss_grad native", || native.loss_grad(&x));
            b.bench("pjrt/loss_grad pjrt", || pjrt.loss_grad(&x));
            b.bench("pjrt/hess native", || native.hess(&x));
            b.bench("pjrt/hess pjrt", || pjrt.hess(&x));
        }
        Err(e) => println!("  (skipping PJRT group: {e:#})"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_pjrt(_b: &mut Bench, _rng: &mut Rng) {
    println!("  (skipping PJRT group: built without the `pjrt` feature)");
}
