//! A lightweight Rust tokenizer — just enough lexical structure for the
//! audit rules, with none of `syn`'s weight (the crate is zero-dependency
//! by policy, and the audit must not change that).
//!
//! The lexer's one job is to let rules match *code*, never prose: string
//! literals keep their decoded-ish text (rules need `push_vector("grad", …)`
//! kinds), comments are kept as tokens (the `audit:allow` escapes live
//! there), and everything else — identifiers, numbers, single-char
//! punctuation — comes out with a line number attached. Multi-character
//! operators are deliberately *not* fused: `::` is two `:` tokens, which
//! keeps the lexer trivial and makes rule patterns explicit.

/// Token classes the rules distinguish.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unwrap`, `mod`, `HashMap`, …).
    Ident,
    /// String literal; `text` holds the raw contents *between* the quotes
    /// (escapes unprocessed — rules only match simple tag strings).
    Str,
    /// Character literal (contents not exposed; rules never need them).
    Char,
    /// Numeric literal (integer part only; `1.5` is `Num . Num`).
    Num,
    /// Lifetime (`'a`) — distinct from `Char` so quotes cannot confuse
    /// string masking.
    Lifetime,
    /// Single punctuation character in `text`.
    Punct,
    /// Comment (line or block); `text` holds the full comment including
    /// its delimiters. Doc comments are comments too, which is what masks
    /// `.unwrap()` in rustdoc examples from the panic rule.
    Comment,
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Token {
    /// Is this an identifier with exactly this text?
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// Is this a punctuation token with exactly this character?
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }
}

/// Tokenize Rust source. Never fails: unterminated constructs run to end of
/// input, and any byte the lexer does not understand becomes a `Punct` —
/// the audit scans files that are known to compile, so graceful degradation
/// beats error plumbing.
pub fn lex(src: &str) -> Vec<Token> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    // Count newlines inside src[start..end) and advance `line`.
    let count_lines = |line: &mut u32, start: usize, end: usize| {
        *line += b[start..end].iter().filter(|&&c| c == b'\n').count() as u32;
    };

    while i < n {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_ascii_whitespace() => i += 1,
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                let end = src[i..].find('\n').map(|j| i + j).unwrap_or(n);
                toks.push(Token { kind: TokKind::Comment, text: src[i..end].into(), line });
                i = end;
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                // Block comments nest in Rust.
                let start = i;
                let tok_line = line;
                let mut depth = 1usize;
                i += 2;
                while i < n && depth > 0 {
                    if i + 1 < n && b[i] == b'/' && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if i + 1 < n && b[i] == b'*' && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                count_lines(&mut line, start, i);
                toks.push(Token {
                    kind: TokKind::Comment,
                    text: src[start..i].into(),
                    line: tok_line,
                });
            }
            b'"' => {
                let (end, text) = scan_string(src, i);
                let tok_line = line;
                count_lines(&mut line, i, end);
                toks.push(Token { kind: TokKind::Str, text, line: tok_line });
                i = end;
            }
            b'r' | b'b' if raw_string_hashes(&src[i..]).is_some() => {
                // r"…", r#"…"#, b"…", br#"…"# — find the matching close.
                // audit:allow(panic-safety): the match guard just checked is_some().
                let (prefix_len, hashes) = raw_string_hashes(&src[i..]).unwrap();
                let body_start = i + prefix_len;
                if hashes == 0 && src[i..].starts_with("b\"") {
                    // Plain byte string: ordinary escape rules.
                    let (end, text) = scan_string(src, i + 1);
                    let tok_line = line;
                    count_lines(&mut line, i, end);
                    toks.push(Token { kind: TokKind::Str, text, line: tok_line });
                    i = end;
                } else {
                    let close = format!("\"{}", "#".repeat(hashes));
                    let end = src[body_start..]
                        .find(&close)
                        .map(|j| body_start + j + close.len())
                        .unwrap_or(n);
                    let text_end = end.saturating_sub(close.len()).max(body_start);
                    let tok_line = line;
                    count_lines(&mut line, i, end);
                    toks.push(Token {
                        kind: TokKind::Str,
                        text: src[body_start..text_end].into(),
                        line: tok_line,
                    });
                    i = end;
                }
            }
            b'\'' => {
                // Char literal ('x', '\n', '\u{1F600}') vs lifetime ('a).
                if let Some(len) = char_literal_len(&src[i..]) {
                    let tok_line = line;
                    count_lines(&mut line, i, i + len);
                    toks.push(Token { kind: TokKind::Char, text: String::new(), line: tok_line });
                    i += len;
                } else {
                    let mut j = i + 1;
                    while j < n && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                        j += 1;
                    }
                    toks.push(Token {
                        kind: TokKind::Lifetime,
                        text: src[i..j].into(),
                        line,
                    });
                    i = j;
                }
            }
            c if c == b'_' || c.is_ascii_alphabetic() => {
                let mut j = i + 1;
                while j < n && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                    j += 1;
                }
                toks.push(Token { kind: TokKind::Ident, text: src[i..j].into(), line });
                i = j;
            }
            c if c.is_ascii_digit() => {
                // Loose: digits + alphanumerics + `_` (covers 0xFF, 1_000,
                // 2e3's mantissa). `1.5` splits at the dot, which is fine.
                let mut j = i + 1;
                while j < n && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                    j += 1;
                }
                toks.push(Token { kind: TokKind::Num, text: src[i..j].into(), line });
                i = j;
            }
            _ => {
                toks.push(Token {
                    kind: TokKind::Punct,
                    text: (c as char).to_string(),
                    line,
                });
                i += 1;
            }
        }
    }
    toks
}

/// If `rest` starts a raw (or raw byte) string — `r"`, `r#…#"`, `br"`,
/// `b"` — return `(prefix length through the opening quote, hash count)`.
fn raw_string_hashes(rest: &str) -> Option<(usize, usize)> {
    let bytes = rest.as_bytes();
    let mut i = 0usize;
    if bytes.first() == Some(&b'b') {
        i += 1;
    }
    if bytes.get(i) == Some(&b'r') {
        i += 1;
    } else if i == 1 && bytes.get(i) == Some(&b'"') {
        // b"…" — byte string without `r`.
        return Some((2, 0));
    } else {
        return None;
    }
    let mut hashes = 0usize;
    while bytes.get(i) == Some(&b'#') {
        hashes += 1;
        i += 1;
    }
    if bytes.get(i) == Some(&b'"') {
        Some((i + 1, hashes))
    } else {
        None
    }
}

/// Scan an ordinary `"…"` string starting at `start` (which must be the
/// opening quote). Returns `(index past the closing quote, contents)`.
fn scan_string(src: &str, start: usize) -> (usize, String) {
    let b = src.as_bytes();
    let n = b.len();
    let mut j = start + 1;
    while j < n {
        match b[j] {
            b'\\' => j = (j + 2).min(n),
            b'"' => return (j + 1, src[start + 1..j].into()),
            _ => j += 1,
        }
    }
    (n, src[(start + 1).min(n)..].into())
}

/// Length of a char literal at the start of `rest` (which begins with `'`),
/// or `None` if this is a lifetime / stray quote.
fn char_literal_len(rest: &str) -> Option<usize> {
    let b = rest.as_bytes();
    if b.len() < 3 {
        return None;
    }
    if b[1] == b'\\' {
        // Escaped: find the closing quote (handles \n, \', \u{…}).
        let mut j = 2;
        while j < b.len() && b[j] != b'\'' {
            j += 1;
        }
        return if j < b.len() { Some(j + 1) } else { None };
    }
    // Unescaped char literal is exactly '<one char>' — possibly multibyte.
    let mut chars = rest.char_indices().skip(1);
    let (_, c) = chars.next()?;
    if c == '\'' {
        return None; // `''` is not a char literal.
    }
    let (close_idx, close) = chars.next()?;
    if close == '\'' {
        Some(close_idx + 1)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_numbers() {
        let t = kinds("foo.bar(1, x_2);");
        assert_eq!(
            t,
            vec![
                (TokKind::Ident, "foo".into()),
                (TokKind::Punct, ".".into()),
                (TokKind::Ident, "bar".into()),
                (TokKind::Punct, "(".into()),
                (TokKind::Num, "1".into()),
                (TokKind::Punct, ",".into()),
                (TokKind::Ident, "x_2".into()),
                (TokKind::Punct, ")".into()),
                (TokKind::Punct, ";".into()),
            ]
        );
    }

    #[test]
    fn strings_keep_contents_and_mask_code() {
        let t = kinds(r#"push("grad .unwrap() inside", 1)"#);
        assert!(t.contains(&(TokKind::Str, "grad .unwrap() inside".into())));
        // The unwrap inside the string is not an Ident token.
        assert!(!t.iter().any(|(k, s)| *k == TokKind::Ident && s == "unwrap"));
    }

    #[test]
    fn raw_and_byte_strings() {
        let t = kinds(r###"let s = r#"a "quoted" b"#; let b = b"xyz";"###);
        assert!(t.contains(&(TokKind::Str, "a \"quoted\" b".into())));
        assert!(t.contains(&(TokKind::Str, "xyz".into())));
    }

    #[test]
    fn comments_are_tokens_with_text() {
        let t = lex("x // audit:allow(panic-safety): fine\n/* block\n.unwrap() */ y");
        let comments: Vec<_> =
            t.iter().filter(|t| t.kind == TokKind::Comment).collect();
        assert_eq!(comments.len(), 2);
        assert!(comments[0].text.contains("audit:allow"));
        assert_eq!(comments[0].line, 1);
        assert_eq!(comments[1].line, 2);
        // Code in comments never becomes idents.
        assert!(!t.iter().any(|t| t.is_ident("unwrap")));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let t = kinds("let c = 'x'; fn f<'a>(v: &'a str) { let n = '\\n'; }");
        let chars = t.iter().filter(|(k, _)| *k == TokKind::Char).count();
        let lifes = t.iter().filter(|(k, _)| *k == TokKind::Lifetime).count();
        assert_eq!(chars, 2);
        assert_eq!(lifes, 2);
    }

    #[test]
    fn line_numbers_advance_through_multiline_tokens() {
        let src = "a\n\"two\nline\"\nb";
        let t = lex(src);
        assert_eq!(t[0].line, 1); // a
        assert_eq!(t[1].line, 2); // the string starts on line 2
        assert_eq!(t[2].line, 4); // b — the string consumed line 3
    }

    #[test]
    fn nested_block_comments() {
        let t = lex("/* outer /* inner */ still */ x");
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].kind, TokKind::Comment);
        assert!(t[1].is_ident("x"));
    }
}
