//! `repro audit` — a zero-dependency static-analysis pass over the crate's
//! own source.
//!
//! The paper's claims rest on exact bit accounting and bit-for-bit
//! deterministic reproduction; the dynamic tests
//! (`tests/transport_equivalence.rs`, `tests/obs_trace.rs`) enforce those
//! invariants only on the configurations they happen to execute. This pass
//! enforces them *at the source level*: a new message kind that forgets to
//! declare its charge policy, a `HashMap` order leak, a stray wall-clock
//! read, or an algorithm missing from the equivalence test fails
//! `repro audit` (and CI) before any run executes.
//!
//! Structure: [`lexer`] tokenizes (no `syn` — the crate is
//! anyhow-only by policy), [`source`] shapes files (test-code exclusion,
//! `audit:allow` escapes), [`rules`] holds the rule registry, and
//! [`report`] renders human tables and JSONL. The rule catalogue, the
//! rationale for each rule, and the escape syntax are documented in
//! `docs/AUDIT.md`.

pub mod lexer;
pub mod report;
pub mod rules;
pub mod source;

use anyhow::{ensure, Context, Result};
use source::SourceFile;
use std::path::{Path, PathBuf};

/// What to audit and how strictly.
pub struct AuditConfig {
    /// Crate root: the directory containing `src/` (and, for the full rule
    /// set, `tests/` and a `docs/` beside or above it).
    pub root: PathBuf,
    /// Cross-check the text-parsed registries against the compiled-in ones
    /// (`transport::kinds::KINDS`, `Algorithm::all()`). True only when
    /// auditing this crate itself — fixture crates declare their own.
    pub check_runtime_registry: bool,
}

impl AuditConfig {
    /// Audit this crate's own source tree (the CI gate and the self-audit
    /// test). The root is baked in at compile time; pass `--root` to the
    /// CLI to audit a checkout living elsewhere.
    pub fn for_this_crate() -> AuditConfig {
        AuditConfig {
            root: PathBuf::from(env!("CARGO_MANIFEST_DIR")),
            check_runtime_registry: true,
        }
    }

    /// Audit an arbitrary crate-shaped tree (fixtures, other checkouts).
    pub fn for_root(root: impl Into<PathBuf>) -> AuditConfig {
        AuditConfig { root: root.into(), check_runtime_registry: false }
    }
}

/// One rule violation.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    /// Path relative to the scanned `src/` (or the literal `tests/…` /
    /// `docs/…` path for cross-file checks).
    pub file: String,
    pub line: u32,
    pub msg: String,
}

/// The outcome of one audit pass.
pub struct AuditReport {
    /// Violations after `audit:allow` suppression, sorted by
    /// (file, line, rule).
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// Findings suppressed by justified `audit:allow` escapes.
    pub allows_honored: usize,
}

impl AuditReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Everything the rules see.
pub struct AuditCtx<'a> {
    pub cfg: &'a AuditConfig,
    pub files: &'a [SourceFile],
    /// `docs/TRACING.md` contents (checked beside `root`, then above it).
    pub tracing_md: Option<String>,
    /// `tests/transport_equivalence.rs`, lexed with tests *included*.
    pub equivalence: Option<SourceFile>,
}

/// Run the full audit.
pub fn run(cfg: &AuditConfig) -> Result<AuditReport> {
    let src_dir = cfg.root.join("src");
    ensure!(
        src_dir.is_dir(),
        "audit root {} has no src/ directory",
        cfg.root.display()
    );
    let mut files = Vec::new();
    for path in source::walk_rs_files(&src_dir)? {
        let rel = rel_path(&path, &src_dir);
        files.push(SourceFile::load(&path, rel, true)?);
    }

    let tracing_md = [cfg.root.join("docs/TRACING.md"), cfg.root.join("../docs/TRACING.md")]
        .iter()
        .find(|p| p.is_file())
        .map(|p| {
            std::fs::read_to_string(p).with_context(|| format!("reading {}", p.display()))
        })
        .transpose()?;

    let eq_path = cfg.root.join("tests/transport_equivalence.rs");
    let equivalence = if eq_path.is_file() {
        Some(SourceFile::load(&eq_path, "tests/transport_equivalence.rs".into(), false)?)
    } else {
        None
    };

    let ctx = AuditCtx { cfg, files: &files, tracing_md, equivalence };
    let mut raw = Vec::new();
    rules::run_all(&ctx, &mut raw);
    if cfg.check_runtime_registry {
        cross_check_runtime(&ctx, &mut raw);
    }

    // Suppress findings covered by justified allows (marking them used).
    let mut findings = Vec::new();
    let mut allows_honored = 0usize;
    for f in raw {
        let allow = files
            .iter()
            .find(|sf| sf.rel == f.file)
            .and_then(|sf| sf.allow_for(f.rule, f.line));
        match allow {
            Some(a) => {
                a.used.set(true);
                allows_honored += 1;
            }
            None => findings.push(f),
        }
    }

    // Escape hygiene: malformed/unjustified directives are findings, and
    // so are justified ones that no longer suppress anything.
    for sf in &files {
        for a in &sf.allows {
            if !rules::is_allowable_rule(&a.rule) {
                findings.push(Finding {
                    rule: rules::ALLOW_SYNTAX,
                    file: sf.rel.clone(),
                    line: a.line,
                    msg: format!(
                        "audit:allow names unknown rule \"{}\"; known rules: {}",
                        a.rule,
                        rule_id_list()
                    ),
                });
            } else if !a.justified {
                findings.push(Finding {
                    rule: rules::ALLOW_SYNTAX,
                    file: sf.rel.clone(),
                    line: a.line,
                    msg: format!(
                        "audit:allow({}) needs a justification: \
                         `// audit:allow({}): <why this is sound>`",
                        a.rule, a.rule
                    ),
                });
            } else if !a.used.get() {
                findings.push(Finding {
                    rule: rules::UNUSED_ALLOW,
                    file: sf.rel.clone(),
                    line: a.line,
                    msg: format!(
                        "audit:allow({}) suppresses nothing; remove the stale escape",
                        a.rule
                    ),
                });
            }
        }
    }

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(AuditReport { findings, files_scanned: files.len(), allows_honored })
}

/// The text parsers double as the fixtures' ground truth, so when auditing
/// this crate they must agree exactly with the compiled registries.
fn cross_check_runtime(ctx: &AuditCtx, out: &mut Vec<Finding>) {
    use crate::config::Algorithm;
    use crate::transport::kinds::KINDS;

    let mut parsed_kinds = Vec::new();
    for file in ctx.files {
        rules::bit_accounting::collect_registry(file, &mut parsed_kinds);
    }
    let mut parsed: Vec<&str> = parsed_kinds.iter().map(|e| e.name.as_str()).collect();
    let mut compiled: Vec<&str> = KINDS.iter().map(|k| k.name).collect();
    parsed.sort_unstable();
    compiled.sort_unstable();
    if parsed != compiled {
        out.push(Finding {
            rule: "registry-sync",
            file: "transport/kinds.rs".into(),
            line: 1,
            msg: format!(
                "text-parsed kind registry {parsed:?} disagrees with the compiled \
                 transport::kinds::KINDS {compiled:?}"
            ),
        });
    }

    // The codec table must exist in this crate, and the text parse must
    // agree with the compiled table *in order* (wire ids are positional).
    let wire_parsed: Vec<String> =
        rules::codec_sync::wire_tables(ctx).into_iter().map(|e| e.name).collect();
    let wire_compiled: Vec<String> =
        crate::transport::codec::WIRE_KINDS.iter().map(|k| k.to_string()).collect();
    if wire_parsed != wire_compiled {
        out.push(Finding {
            rule: "codec-sync",
            file: "transport/codec.rs".into(),
            line: 1,
            msg: format!(
                "text-parsed WIRE_KINDS {wire_parsed:?} disagrees with the compiled \
                 transport::codec::WIRE_KINDS {wire_compiled:?} (order matters: ids \
                 are positional)"
            ),
        });
    }
    let mut wire_sorted = wire_compiled;
    let mut kinds_sorted: Vec<String> = KINDS.iter().map(|k| k.name.to_string()).collect();
    wire_sorted.sort_unstable();
    kinds_sorted.sort_unstable();
    if wire_sorted != kinds_sorted {
        out.push(Finding {
            rule: "codec-sync",
            file: "transport/codec.rs".into(),
            line: 1,
            msg: format!(
                "compiled WIRE_KINDS {wire_sorted:?} and transport::kinds::KINDS \
                 {kinds_sorted:?} name different vocabularies"
            ),
        });
    }

    let mut parsed_algos: Vec<String> = rules::registry_sync::algorithm_variants(ctx)
        .into_iter()
        .map(|(n, _, _)| n)
        .collect();
    let mut compiled_algos: Vec<String> =
        Algorithm::all().iter().map(|a| format!("{a:?}")).collect();
    parsed_algos.sort_unstable();
    compiled_algos.sort_unstable();
    if parsed_algos != compiled_algos {
        out.push(Finding {
            rule: "registry-sync",
            file: "config.rs".into(),
            line: 1,
            msg: format!(
                "text-parsed Algorithm variants {parsed_algos:?} disagree with the \
                 compiled Algorithm::all() {compiled_algos:?}"
            ),
        });
    }
}

fn rule_id_list() -> String {
    rules::RULES.iter().map(|r| r.id).collect::<Vec<_>>().join(", ")
}

fn rel_path(path: &Path, base: &Path) -> String {
    path.strip_prefix(base)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}
