//! Rendering an [`AuditReport`] for humans (aligned table on stdout) and
//! machines (JSONL, same value model as the sweep sink).

use super::AuditReport;
use crate::sweep::jsonl::Json;

/// Human-readable report: one row per finding plus a summary line.
pub fn render_table(report: &AuditReport) -> String {
    let mut out = String::new();
    if !report.findings.is_empty() {
        let loc_w = report
            .findings
            .iter()
            .map(|f| f.file.len() + 1 + digits(f.line))
            .max()
            .unwrap_or(0);
        let rule_w =
            report.findings.iter().map(|f| f.rule.len()).max().unwrap_or(0);
        for f in &report.findings {
            let loc = format!("{}:{}", f.file, f.line);
            out.push_str(&format!(
                "{loc:<loc_w$}  {rule:<rule_w$}  {msg}\n",
                rule = f.rule,
                msg = f.msg
            ));
        }
    }
    out.push_str(&format!(
        "audit: {} finding(s) in {} file(s) scanned, {} allow(s) honored\n",
        report.findings.len(),
        report.files_scanned,
        report.allows_honored
    ));
    out
}

/// Machine-readable report: one `finding` row per violation, then one
/// `summary` row (always last, so a consumer can detect truncation).
pub fn render_jsonl(report: &AuditReport) -> String {
    let mut out = String::new();
    for f in &report.findings {
        let row = Json::Obj(vec![
            ("ev".into(), Json::str("finding")),
            ("rule".into(), Json::str(f.rule)),
            ("file".into(), Json::str(f.file.as_str())),
            ("line".into(), Json::num(f.line as f64)),
            ("msg".into(), Json::str(f.msg.as_str())),
        ]);
        out.push_str(&row.render());
        out.push('\n');
    }
    let summary = Json::Obj(vec![
        ("ev".into(), Json::str("summary")),
        ("findings".into(), Json::num(report.findings.len() as f64)),
        ("files_scanned".into(), Json::num(report.files_scanned as f64)),
        ("allows_honored".into(), Json::num(report.allows_honored as f64)),
    ]);
    out.push_str(&summary.render());
    out.push('\n');
    out
}

fn digits(mut n: u32) -> usize {
    let mut d = 1;
    while n >= 10 {
        n /= 10;
        d += 1;
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::Finding;

    fn report() -> AuditReport {
        AuditReport {
            findings: vec![Finding {
                rule: "panic-safety",
                file: "a/b.rs".into(),
                line: 12,
                msg: "`.unwrap()` can panic".into(),
            }],
            files_scanned: 3,
            allows_honored: 2,
        }
    }

    #[test]
    fn table_lists_findings_and_summary() {
        let t = render_table(&report());
        assert!(t.contains("a/b.rs:12"));
        assert!(t.contains("panic-safety"));
        assert!(t.contains("audit: 1 finding(s) in 3 file(s) scanned, 2 allow(s) honored"));
    }

    #[test]
    fn jsonl_rows_parse_back() {
        let j = render_jsonl(&report());
        let lines: Vec<_> = j.lines().collect();
        assert_eq!(lines.len(), 2);
        let row = Json::parse(lines[0]).unwrap();
        assert_eq!(row.get("ev").and_then(|v| v.as_str()), Some("finding"));
        assert_eq!(row.get("line").and_then(|v| v.as_f64()), Some(12.0));
        let sum = Json::parse(lines[1]).unwrap();
        assert_eq!(sum.get("ev").and_then(|v| v.as_str()), Some("summary"));
        assert_eq!(sum.get("findings").and_then(|v| v.as_f64()), Some(1.0));
    }
}
