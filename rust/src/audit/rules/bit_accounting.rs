//! `bit-accounting`: the wire vocabulary is closed and every kind's charge
//! policy is declared exactly once.
//!
//! Ground truth is the `Kind { name: …, dir: …, charge: … }` table in
//! `transport/kinds.rs` — parsed from *source text*, not from the compiled
//! registry, so fixture crates under `tests/audit_fixtures/` can declare
//! their own vocabularies and the rule still applies. When auditing the
//! real crate the orchestrator additionally cross-checks the parsed table
//! against the compiled-in `transport::kinds::KINDS`, so the text parser
//! cannot silently drift from the code.
//!
//! Checks:
//! 1. every `push_vector/matrix/scalars/flags` call uses a *string-literal*
//!    kind (a computed kind defeats static accounting);
//! 2. every pushed kind is declared in the registry;
//! 3. a `Charge::Charged` kind is never pushed with `BitCost::zero()`;
//! 4. a `Charge::Free` kind is always pushed with exactly `BitCost::zero()`
//!    (`Charge::Mixed` skips 3–4);
//! 5. every registered kind has at least one push site (no dead vocabulary);
//! 6. registry names are unique.

use super::super::{AuditCtx, Finding};
use super::{is_bitcost_zero, is_method_call, top_level_args};
use crate::audit::lexer::TokKind;

const RULE: &str = "bit-accounting";
const PUSHERS: [&str; 4] = ["push_vector", "push_matrix", "push_scalars", "push_flags"];

struct PushSite {
    file: String,
    line: u32,
    /// `None` ⇒ the kind argument was not a string literal.
    kind: Option<String>,
    /// Whether the cost argument is literally `BitCost::zero()`.
    zero_cost: bool,
}

pub(crate) struct RegEntry {
    pub file: String,
    pub line: u32,
    pub name: String,
    pub charge: String,
}

pub fn check(ctx: &AuditCtx, out: &mut Vec<Finding>) {
    let mut pushes = Vec::new();
    let mut registry = Vec::new();
    for file in ctx.files {
        collect_push_sites(file, &mut pushes);
        collect_registry(file, &mut registry);
    }

    // 6. duplicate registry names.
    for (i, e) in registry.iter().enumerate() {
        if registry[..i].iter().any(|p| p.name == e.name) {
            out.push(Finding {
                rule: RULE,
                file: e.file.clone(),
                line: e.line,
                msg: format!("message kind \"{}\" is registered more than once", e.name),
            });
        }
    }

    for p in &pushes {
        let Some(kind) = &p.kind else {
            // 1. computed kind.
            out.push(Finding {
                rule: RULE,
                file: p.file.clone(),
                line: p.line,
                msg: "message kind must be a string literal so its charge policy \
                      can be statically accounted for"
                    .into(),
            });
            continue;
        };
        let Some(entry) = registry.iter().find(|e| &e.name == kind) else {
            // 2. unregistered kind.
            out.push(Finding {
                rule: RULE,
                file: p.file.clone(),
                line: p.line,
                msg: format!(
                    "message kind \"{kind}\" is not declared in the kinds registry \
                     (transport/kinds.rs); register it with its charge policy"
                ),
            });
            continue;
        };
        // 3./4. charge policy vs. the cost argument.
        match entry.charge.as_str() {
            "Charged" if p.zero_cost => out.push(Finding {
                rule: RULE,
                file: p.file.clone(),
                line: p.line,
                msg: format!(
                    "kind \"{kind}\" is registered Charged but pushed with BitCost::zero(); \
                     either charge its bits or register it Free"
                ),
            }),
            "Free" if !p.zero_cost => out.push(Finding {
                rule: RULE,
                file: p.file.clone(),
                line: p.line,
                msg: format!(
                    "kind \"{kind}\" is registered Free but pushed with a non-zero cost; \
                     either push BitCost::zero() or register it Charged"
                ),
            }),
            _ => {}
        }
    }

    // 5. dead vocabulary.
    for e in &registry {
        let used = pushes.iter().any(|p| p.kind.as_deref() == Some(e.name.as_str()));
        if !used {
            out.push(Finding {
                rule: RULE,
                file: e.file.clone(),
                line: e.line,
                msg: format!(
                    "registered kind \"{}\" has no push site; remove it or wire it up",
                    e.name
                ),
            });
        }
    }
}

fn collect_push_sites(file: &crate::audit::source::SourceFile, out: &mut Vec<PushSite>) {
    let code = &file.code;
    for i in 0..code.len() {
        if code[i].kind != TokKind::Ident
            || !PUSHERS.contains(&code[i].text.as_str())
            || !is_method_call(code, i, &code[i].text)
        {
            continue;
        }
        let (args, _) = top_level_args(code, i + 1);
        let kind = args.first().and_then(|&(a, b)| {
            if b - a == 1 && code[a].kind == TokKind::Str {
                Some(code[a].text.clone())
            } else {
                None
            }
        });
        let zero_cost = args.last().is_some_and(|&r| is_bitcost_zero(code, r));
        out.push(PushSite { file: file.rel.clone(), line: code[i].line, kind, zero_cost });
    }
}

/// Parse `Kind { name: "…", dir: Direction::…, charge: Charge::… }` struct
/// literals out of the token stream (skipping the `struct Kind { … }`
/// declaration itself).
pub(crate) fn collect_registry(
    file: &crate::audit::source::SourceFile,
    out: &mut Vec<RegEntry>,
) {
    let code = &file.code;
    for i in 0..code.len() {
        if !code[i].is_ident("Kind")
            || !code.get(i + 1).is_some_and(|t| t.is_punct('{'))
            || (i > 0 && code[i - 1].is_ident("struct"))
        {
            continue;
        }
        let end = super::match_brace(code, i + 1);
        let body = &code[i + 2..end.saturating_sub(1).max(i + 2)];
        let mut name = None;
        let mut charge = None;
        let mut j = 0usize;
        while j + 1 < body.len() {
            if body[j].kind == TokKind::Ident && body[j + 1].is_punct(':') {
                match body[j].text.as_str() {
                    "name" => {
                        if body.get(j + 2).map(|t| t.kind) == Some(TokKind::Str) {
                            name = body.get(j + 2).map(|t| t.text.clone());
                        }
                    }
                    "charge" => {
                        // charge: Charge::<Variant>
                        if body.get(j + 2).is_some_and(|t| t.is_ident("Charge")) {
                            charge = body.get(j + 5).map(|t| t.text.clone());
                        }
                    }
                    _ => {}
                }
            }
            j += 1;
        }
        if let Some(name) = name {
            out.push(RegEntry {
                file: file.rel.clone(),
                line: code[i].line,
                name,
                charge: charge.unwrap_or_default(),
            });
        }
    }
}
