//! `codec-sync`: every registered message kind has a wire-codec id — a new
//! kind cannot skip the byte-level codec.
//!
//! Ground truth is the `const WIRE_KINDS: &[&str] = &["…", …]` table
//! (`transport/codec.rs` in the real crate), parsed from *source text* like
//! the kinds registry itself, so fixture crates under
//! `tests/audit_fixtures/` can declare their own codec tables. The codec
//! encodes and decodes through this one positional table (id = index), so
//! table membership *is* having both an encode and a decode arm.
//!
//! Checks (all silent when the tree declares no `WIRE_KINDS` table at all —
//! most fixtures have no codec; the real crate's table presence is enforced
//! by the orchestrator's compiled cross-check):
//! 1. every `Kind { name: … }` registry entry appears in the table;
//! 2. every table entry names a registered kind (no orphan wire ids);
//! 3. table entries are unique (a duplicate would shadow an id).
//!
//! The rule also covers the *frame* level of the codec: the
//! `const FRAME_KINDS: &[(&str, u8)]` table and the C-like `enum FrameKind`
//! whose discriminants are the wire bytes. When a tree declares a
//! `FRAME_KINDS` table (silent otherwise, like the message-kind half):
//! 4. table names and bytes are unique, and byte `0` stays reserved;
//! 5. every `FrameKind` variant has a table entry (matched by lowercased
//!    name) with the *same* byte, and carries an explicit discriminant —
//!    an implicit one would silently renumber the wire format;
//! 6. every table entry names a variant (no orphan frame bytes).

use super::super::{AuditCtx, Finding};
use super::bit_accounting::collect_registry;
use crate::audit::lexer::TokKind;

const RULE: &str = "codec-sync";

/// One parsed `WIRE_KINDS` table entry.
pub(crate) struct WireEntry {
    pub file: String,
    pub line: u32,
    pub name: String,
}

/// Parse every `const WIRE_KINDS … = … [ "…", … ]` declaration in the tree,
/// in source order (the order *is* the wire id assignment). Only
/// declaration sites count — `WIRE_KINDS` uses inside function bodies are
/// not preceded by the `const` keyword.
pub(crate) fn wire_tables(ctx: &AuditCtx) -> Vec<WireEntry> {
    let mut out = Vec::new();
    for file in ctx.files {
        let code = &file.code;
        for i in 0..code.len() {
            if !code[i].is_ident("WIRE_KINDS") || i == 0 || !code[i - 1].is_ident("const") {
                continue;
            }
            // Skip the type annotation: scan to `=`, then to the first `[`
            // of the initializer, then collect string literals until the
            // bracket depth closes.
            let mut j = i + 1;
            while j < code.len() && !code[j].is_punct('=') {
                j += 1;
            }
            while j < code.len() && !code[j].is_punct('[') {
                j += 1;
            }
            let mut depth = 0isize;
            while j < code.len() {
                let t = &code[j];
                if t.is_punct('[') {
                    depth += 1;
                } else if t.is_punct(']') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t.kind == TokKind::Str {
                    out.push(WireEntry {
                        file: file.rel.clone(),
                        line: t.line,
                        name: t.text.clone(),
                    });
                }
                j += 1;
            }
        }
    }
    out
}

/// One parsed `FRAME_KINDS` table entry: `("name", byte)`.
struct FrameEntry {
    file: String,
    line: u32,
    name: String,
    byte: Option<u64>,
}

/// One parsed `enum FrameKind` variant with its explicit discriminant (the
/// wire byte), or `None` when the variant declares no discriminant.
struct FrameVariant {
    file: String,
    line: u32,
    name: String,
    byte: Option<u64>,
}

/// Parse a numeric-literal token (`1`, `0x1F`, `1_000`) to its value.
fn parse_num(text: &str) -> Option<u64> {
    let t = text.replace('_', "");
    if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        t.parse().ok()
    }
}

/// Parse every `const FRAME_KINDS … = … [ ("…", n), … ]` declaration in the
/// tree. Entries are string/number pairs: each string literal opens an
/// entry, and the first numeric literal after it supplies the wire byte.
fn frame_tables(ctx: &AuditCtx) -> Vec<FrameEntry> {
    let mut out = Vec::new();
    for file in ctx.files {
        let code = &file.code;
        for i in 0..code.len() {
            if !code[i].is_ident("FRAME_KINDS") || i == 0 || !code[i - 1].is_ident("const") {
                continue;
            }
            // Skip the type annotation (which contains its own brackets) by
            // scanning to `=` first, then walk the initializer's brackets.
            let mut j = i + 1;
            while j < code.len() && !code[j].is_punct('=') {
                j += 1;
            }
            while j < code.len() && !code[j].is_punct('[') {
                j += 1;
            }
            let mut depth = 0isize;
            while j < code.len() {
                let t = &code[j];
                if t.is_punct('[') {
                    depth += 1;
                } else if t.is_punct(']') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t.kind == TokKind::Str {
                    out.push(FrameEntry {
                        file: file.rel.clone(),
                        line: t.line,
                        name: t.text.clone(),
                        byte: None,
                    });
                } else if t.kind == TokKind::Num {
                    if let Some(last) = out.last_mut() {
                        if last.byte.is_none() {
                            last.byte = parse_num(&t.text);
                        }
                    }
                }
                j += 1;
            }
        }
    }
    out
}

/// Parse every `enum FrameKind { Variant = N, … }` declaration in the tree.
/// Only C-like variants are recognized: an identifier at brace depth 1
/// directly after `{` or `,`, optionally followed by `= <number>`.
fn frame_enums(ctx: &AuditCtx) -> Vec<FrameVariant> {
    let mut out = Vec::new();
    for file in ctx.files {
        let code = &file.code;
        for i in 0..code.len() {
            if !code[i].is_ident("FrameKind") || i == 0 || !code[i - 1].is_ident("enum") {
                continue;
            }
            let mut j = i + 1;
            while j < code.len() && !code[j].is_punct('{') {
                j += 1;
            }
            let mut depth = 0isize;
            while j < code.len() {
                let t = &code[j];
                if t.is_punct('{') {
                    depth += 1;
                } else if t.is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if depth == 1
                    && t.kind == TokKind::Ident
                    && (code[j - 1].is_punct('{') || code[j - 1].is_punct(','))
                {
                    let byte = (code.get(j + 1).is_some_and(|t| t.is_punct('='))
                        && code.get(j + 2).is_some_and(|t| t.kind == TokKind::Num))
                    .then(|| parse_num(&code[j + 2].text))
                    .flatten();
                    out.push(FrameVariant {
                        file: file.rel.clone(),
                        line: t.line,
                        name: t.text.clone(),
                        byte,
                    });
                }
                j += 1;
            }
        }
    }
    out
}

/// The frame-level checks (4–6 in the module docs). Runs only when the tree
/// declares a `FRAME_KINDS` table.
fn check_frames(ctx: &AuditCtx, out: &mut Vec<Finding>) {
    let table = frame_tables(ctx);
    if table.is_empty() {
        return; // no frame codec in this tree
    }
    let variants = frame_enums(ctx);

    // 4. table-local hygiene: unique names, unique bytes, byte 0 reserved.
    for (i, e) in table.iter().enumerate() {
        if table[..i].iter().any(|p| p.name == e.name) {
            out.push(Finding {
                rule: RULE,
                file: e.file.clone(),
                line: e.line,
                msg: format!("frame kind \"{}\" appears more than once in FRAME_KINDS", e.name),
            });
        }
        match e.byte {
            None => out.push(Finding {
                rule: RULE,
                file: e.file.clone(),
                line: e.line,
                msg: format!("frame kind \"{}\" has no wire byte in FRAME_KINDS", e.name),
            }),
            Some(0) => out.push(Finding {
                rule: RULE,
                file: e.file.clone(),
                line: e.line,
                msg: format!(
                    "frame kind \"{}\" uses reserved byte 0 (an all-zero buffer must \
                     never parse as a frame)",
                    e.name
                ),
            }),
            Some(b) => {
                if let Some(p) =
                    table[..i].iter().find(|p| p.byte == Some(b) && p.name != e.name)
                {
                    out.push(Finding {
                        rule: RULE,
                        file: e.file.clone(),
                        line: e.line,
                        msg: format!(
                            "frame byte {b} is assigned to both \"{}\" and \"{}\" in \
                             FRAME_KINDS",
                            p.name, e.name
                        ),
                    });
                }
            }
        }
    }

    // 5. every enum variant is in the table with a matching explicit byte.
    for v in &variants {
        let lower = v.name.to_ascii_lowercase();
        let entry = table.iter().find(|e| e.name == lower);
        match entry {
            None => out.push(Finding {
                rule: RULE,
                file: v.file.clone(),
                line: v.line,
                msg: format!(
                    "FrameKind::{} has no FRAME_KINDS entry; append (\"{lower}\", …) — \
                     the table is append-only, like WIRE_KINDS",
                    v.name
                ),
            }),
            Some(e) => match v.byte {
                None => out.push(Finding {
                    rule: RULE,
                    file: v.file.clone(),
                    line: v.line,
                    msg: format!(
                        "FrameKind::{} declares no explicit discriminant — frame \
                         discriminants are the wire bytes, so an implicit one can \
                         silently renumber the wire format",
                        v.name
                    ),
                }),
                Some(b) if e.byte.is_some() && e.byte != Some(b) => out.push(Finding {
                    rule: RULE,
                    file: v.file.clone(),
                    line: v.line,
                    msg: format!(
                        "FrameKind::{} = {b} disagrees with the FRAME_KINDS entry \
                         (\"{}\", {}) — the enum and the table must assign the same \
                         wire byte",
                        v.name,
                        e.name,
                        e.byte.unwrap_or(0)
                    ),
                }),
                Some(_) => {}
            },
        }
    }

    // 6. orphan table entries (no variant behind the wire byte).
    for e in &table {
        if !variants.iter().any(|v| v.name.to_ascii_lowercase() == e.name) {
            out.push(Finding {
                rule: RULE,
                file: e.file.clone(),
                line: e.line,
                msg: format!(
                    "frame kind \"{}\" has no FrameKind enum variant; bytes are part \
                     of the wire format — removal is a wire-format break, so add the \
                     variant back or bump VERSION",
                    e.name
                ),
            });
        }
    }
}

pub fn check(ctx: &AuditCtx, out: &mut Vec<Finding>) {
    check_frames(ctx, out);
    let table = wire_tables(ctx);
    if table.is_empty() {
        return; // no codec in this tree — nothing to hold in sync
    }
    let mut registry = Vec::new();
    for file in ctx.files {
        collect_registry(file, &mut registry);
    }

    // 3. duplicate wire ids.
    for (i, e) in table.iter().enumerate() {
        if table[..i].iter().any(|p| p.name == e.name) {
            out.push(Finding {
                rule: RULE,
                file: e.file.clone(),
                line: e.line,
                msg: format!("wire kind \"{}\" appears more than once in WIRE_KINDS", e.name),
            });
        }
    }

    // 1. registered kind without a wire id.
    for e in &registry {
        if !table.iter().any(|t| t.name == e.name) {
            out.push(Finding {
                rule: RULE,
                file: e.file.clone(),
                line: e.line,
                msg: format!(
                    "registered kind \"{}\" has no wire id; append it to the WIRE_KINDS \
                     table so it can cross the byte codec",
                    e.name
                ),
            });
        }
    }

    // 2. orphan wire id.
    for t in &table {
        if !registry.iter().any(|e| e.name == t.name) {
            out.push(Finding {
                rule: RULE,
                file: t.file.clone(),
                line: t.line,
                msg: format!(
                    "wire kind \"{}\" is not in the kinds registry; remove the dead wire \
                     id (ids are positional — removal is a wire-format break) or register \
                     the kind",
                    t.name
                ),
            });
        }
    }
}
