//! `codec-sync`: every registered message kind has a wire-codec id — a new
//! kind cannot skip the byte-level codec.
//!
//! Ground truth is the `const WIRE_KINDS: &[&str] = &["…", …]` table
//! (`transport/codec.rs` in the real crate), parsed from *source text* like
//! the kinds registry itself, so fixture crates under
//! `tests/audit_fixtures/` can declare their own codec tables. The codec
//! encodes and decodes through this one positional table (id = index), so
//! table membership *is* having both an encode and a decode arm.
//!
//! Checks (all silent when the tree declares no `WIRE_KINDS` table at all —
//! most fixtures have no codec; the real crate's table presence is enforced
//! by the orchestrator's compiled cross-check):
//! 1. every `Kind { name: … }` registry entry appears in the table;
//! 2. every table entry names a registered kind (no orphan wire ids);
//! 3. table entries are unique (a duplicate would shadow an id).

use super::super::{AuditCtx, Finding};
use super::bit_accounting::collect_registry;
use crate::audit::lexer::TokKind;

const RULE: &str = "codec-sync";

/// One parsed `WIRE_KINDS` table entry.
pub(crate) struct WireEntry {
    pub file: String,
    pub line: u32,
    pub name: String,
}

/// Parse every `const WIRE_KINDS … = … [ "…", … ]` declaration in the tree,
/// in source order (the order *is* the wire id assignment). Only
/// declaration sites count — `WIRE_KINDS` uses inside function bodies are
/// not preceded by the `const` keyword.
pub(crate) fn wire_tables(ctx: &AuditCtx) -> Vec<WireEntry> {
    let mut out = Vec::new();
    for file in ctx.files {
        let code = &file.code;
        for i in 0..code.len() {
            if !code[i].is_ident("WIRE_KINDS") || i == 0 || !code[i - 1].is_ident("const") {
                continue;
            }
            // Skip the type annotation: scan to `=`, then to the first `[`
            // of the initializer, then collect string literals until the
            // bracket depth closes.
            let mut j = i + 1;
            while j < code.len() && !code[j].is_punct('=') {
                j += 1;
            }
            while j < code.len() && !code[j].is_punct('[') {
                j += 1;
            }
            let mut depth = 0isize;
            while j < code.len() {
                let t = &code[j];
                if t.is_punct('[') {
                    depth += 1;
                } else if t.is_punct(']') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t.kind == TokKind::Str {
                    out.push(WireEntry {
                        file: file.rel.clone(),
                        line: t.line,
                        name: t.text.clone(),
                    });
                }
                j += 1;
            }
        }
    }
    out
}

pub fn check(ctx: &AuditCtx, out: &mut Vec<Finding>) {
    let table = wire_tables(ctx);
    if table.is_empty() {
        return; // no codec in this tree — nothing to hold in sync
    }
    let mut registry = Vec::new();
    for file in ctx.files {
        collect_registry(file, &mut registry);
    }

    // 3. duplicate wire ids.
    for (i, e) in table.iter().enumerate() {
        if table[..i].iter().any(|p| p.name == e.name) {
            out.push(Finding {
                rule: RULE,
                file: e.file.clone(),
                line: e.line,
                msg: format!("wire kind \"{}\" appears more than once in WIRE_KINDS", e.name),
            });
        }
    }

    // 1. registered kind without a wire id.
    for e in &registry {
        if !table.iter().any(|t| t.name == e.name) {
            out.push(Finding {
                rule: RULE,
                file: e.file.clone(),
                line: e.line,
                msg: format!(
                    "registered kind \"{}\" has no wire id; append it to the WIRE_KINDS \
                     table so it can cross the byte codec",
                    e.name
                ),
            });
        }
    }

    // 2. orphan wire id.
    for t in &table {
        if !registry.iter().any(|e| e.name == t.name) {
            out.push(Finding {
                rule: RULE,
                file: t.file.clone(),
                line: t.line,
                msg: format!(
                    "wire kind \"{}\" is not in the kinds registry; remove the dead wire \
                     id (ids are positional — removal is a wire-format break) or register \
                     the kind",
                    t.name
                ),
            });
        }
    }
}
