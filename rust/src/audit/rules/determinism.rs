//! The determinism rules: nothing that feeds `History`, fingerprints, or
//! JSONL output may depend on iteration order, wall clocks, or ambient
//! randomness.
//!
//! * `determinism-hash` — any `HashMap`/`HashSet` token. Std hash maps
//!   iterate in randomized order, which is exactly the class of bug the
//!   transport-equivalence contract exists to exclude; `BTreeMap`/`BTreeSet`
//!   or sorted iteration are the sanctioned replacements everywhere, not
//!   just on the output path — a hash map that is "only used for lookups"
//!   today is one refactor away from being iterated.
//! * `determinism-clock` — `Instant::now`/`SystemTime::now` call paths.
//!   Clocks are the observability layer's business: `obs/` and
//!   `bench_util.rs` are exempt wholesale, and the two progress-reporting
//!   sites outside them carry justified allows.
//! * `determinism-rng` — `Rng::new(…)` outside `rng.rs` must visibly take
//!   a seed: some argument identifier has to contain `seed`. Everything
//!   else must split streams via `Rng::derive`, so every random draw in a
//!   run is a pure function of the run seed.

use super::super::{AuditCtx, Finding};
use super::{path_call, top_level_args};
use crate::audit::lexer::TokKind;

pub fn check_hash(ctx: &AuditCtx, out: &mut Vec<Finding>) {
    const RULE: &str = "determinism-hash";
    for file in ctx.files {
        for t in &file.code {
            if t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
                out.push(Finding {
                    rule: RULE,
                    file: file.rel.clone(),
                    line: t.line,
                    msg: format!(
                        "`{}` iterates in randomized order; use the BTree form or sorted iteration",
                        t.text
                    ),
                });
            }
        }
    }
}

pub fn check_clock(ctx: &AuditCtx, out: &mut Vec<Finding>) {
    const RULE: &str = "determinism-clock";
    for file in ctx.files {
        if file.rel.starts_with("obs/") || file.rel == "bench_util.rs" {
            continue;
        }
        let code = &file.code;
        for i in 0..code.len() {
            let t = &code[i];
            if t.kind != TokKind::Ident || (t.text != "Instant" && t.text != "SystemTime") {
                continue;
            }
            let is_now = code.get(i + 1).is_some_and(|a| a.is_punct(':'))
                && code.get(i + 2).is_some_and(|a| a.is_punct(':'))
                && code.get(i + 3).is_some_and(|a| a.is_ident("now"));
            if is_now {
                out.push(Finding {
                    rule: RULE,
                    file: file.rel.clone(),
                    line: t.line,
                    msg: format!(
                        "`{}::now` outside obs/ and bench_util; clocks must not reach run state",
                        t.text
                    ),
                });
            }
        }
    }
}

pub fn check_rng(ctx: &AuditCtx, out: &mut Vec<Finding>) {
    const RULE: &str = "determinism-rng";
    for file in ctx.files {
        if file.rel == "rng.rs" {
            continue; // the stream-derivation module itself
        }
        let code = &file.code;
        for i in 0..code.len() {
            let Some(open) = path_call(code, i, "Rng", "new") else { continue };
            let (args, _) = top_level_args(code, open);
            let seeded = args.iter().any(|&(a, b)| {
                code[a..b].iter().any(|t| {
                    t.kind == TokKind::Ident && t.text.to_ascii_lowercase().contains("seed")
                })
            });
            if !seeded {
                out.push(Finding {
                    rule: RULE,
                    file: file.rel.clone(),
                    line: code[i].line,
                    msg: "`Rng::new` without an explicit seed argument; derive streams from \
                          the run seed (`Rng::derive`) so draws are reproducible"
                        .into(),
                });
            }
        }
    }
}
