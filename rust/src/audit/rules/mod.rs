//! The audit's rule registry and the shared token-pattern helpers the
//! rules are built from.
//!
//! Each rule is a plain function over the [`AuditCtx`]: it scans the
//! lexed `code` views (non-test tokens only) and appends [`Finding`]s.
//! Suppression by `audit:allow` happens *after* all rules run, in the
//! orchestrator — rules never see allows, which keeps them honest.

pub mod bit_accounting;
pub mod codec_sync;
pub mod determinism;
pub mod panic_safety;
pub mod registry_sync;

use super::lexer::{TokKind, Token};
use super::{AuditCtx, Finding};

/// One registered rule.
pub struct RuleInfo {
    /// The id used in reports and in `audit:allow` escapes.
    pub id: &'static str,
    /// One-line summary for `docs/AUDIT.md` and the rule list.
    pub summary: &'static str,
    pub run: fn(&AuditCtx, &mut Vec<Finding>),
}

/// Every scan rule, in report order. `allow-syntax` and `unused-allow`
/// findings are emitted by the orchestrator itself and cannot be
/// suppressed.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "panic-safety",
        summary: "no unwrap()/expect()/panic! in library paths",
        run: panic_safety::check,
    },
    RuleInfo {
        id: "determinism-hash",
        summary: "no HashMap/HashSet — iteration order must be deterministic",
        run: determinism::check_hash,
    },
    RuleInfo {
        id: "determinism-clock",
        summary: "no Instant::now/SystemTime::now outside obs/ and bench_util",
        run: determinism::check_clock,
    },
    RuleInfo {
        id: "determinism-rng",
        summary: "RNG streams must derive from an explicit seed",
        run: determinism::check_rng,
    },
    RuleInfo {
        id: "bit-accounting",
        summary: "every wire message kind is registered with its charge policy",
        run: bit_accounting::check,
    },
    RuleInfo {
        id: "registry-sync",
        summary: "algorithms, message kinds and trace names stay registered and documented",
        run: registry_sync::check,
    },
    RuleInfo {
        id: "codec-sync",
        summary: "every registered message kind has a wire-codec id (WIRE_KINDS stays in sync)",
        run: codec_sync::check,
    },
];

/// Orchestrator-emitted rule ids.
pub const ALLOW_SYNTAX: &str = "allow-syntax";
pub const UNUSED_ALLOW: &str = "unused-allow";

/// Is `id` a scan rule that `audit:allow` may name?
pub fn is_allowable_rule(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

/// Run every scan rule.
pub fn run_all(ctx: &AuditCtx, out: &mut Vec<Finding>) {
    for rule in RULES {
        (rule.run)(ctx, out);
    }
}

// ── token-pattern helpers ──────────────────────────────────────────────

/// Does `code[i..]` start the method-call pattern `.name(`?
pub(crate) fn is_method_call(code: &[Token], i: usize, name: &str) -> bool {
    i > 0
        && code[i - 1].is_punct('.')
        && code[i].is_ident(name)
        && code.get(i + 1).is_some_and(|t| t.is_punct('('))
}

/// Does `code[i..]` start the path-call pattern `Type::name(`? Returns the
/// index of the opening parenthesis.
pub(crate) fn path_call(code: &[Token], i: usize, ty: &str, name: &str) -> Option<usize> {
    if code[i].is_ident(ty)
        && code.get(i + 1).is_some_and(|t| t.is_punct(':'))
        && code.get(i + 2).is_some_and(|t| t.is_punct(':'))
        && code.get(i + 3).is_some_and(|t| t.is_ident(name))
        && code.get(i + 4).is_some_and(|t| t.is_punct('('))
    {
        Some(i + 4)
    } else {
        None
    }
}

/// Split the arguments of a call whose opening `(` is at `open` into
/// top-level token ranges (tracking nested `()`/`[]`/`{}`). Returns the
/// half-open ranges and the index of the closing `)`. Unbalanced input
/// (never produced by compiling code) yields what was seen up to EOF.
pub(crate) fn top_level_args(
    code: &[Token],
    open: usize,
) -> (Vec<(usize, usize)>, usize) {
    let mut args = Vec::new();
    let mut depth = 0isize;
    let mut start = open + 1;
    let mut j = open;
    while j < code.len() {
        let t = &code[j];
        if t.kind == TokKind::Punct {
            match t.text.as_bytes().first() {
                Some(b'(') | Some(b'[') | Some(b'{') => depth += 1,
                Some(b')') | Some(b']') | Some(b'}') => {
                    depth -= 1;
                    if depth == 0 {
                        if j > start {
                            args.push((start, j));
                        }
                        return (args, j);
                    }
                }
                Some(b',') if depth == 1 => {
                    args.push((start, j));
                    start = j + 1;
                }
                _ => {}
            }
        }
        j += 1;
    }
    (args, code.len())
}

/// Is this token range exactly the literal `BitCost::zero()`?
pub(crate) fn is_bitcost_zero(code: &[Token], range: (usize, usize)) -> bool {
    let (a, b) = range;
    b - a == 6
        && code[a].is_ident("BitCost")
        && code[a + 1].is_punct(':')
        && code[a + 2].is_punct(':')
        && code[a + 3].is_ident("zero")
        && code[a + 4].is_punct('(')
        && code[a + 5].is_punct(')')
}

/// Index just past the `}` matching the `{` at `open` (token view).
pub(crate) fn match_brace(code: &[Token], open: usize) -> usize {
    let mut depth = 0isize;
    let mut j = open;
    while j < code.len() {
        if code[j].is_punct('{') {
            depth += 1;
        } else if code[j].is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    code.len()
}
