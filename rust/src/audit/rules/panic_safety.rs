//! `panic-safety`: library paths must not be able to abort the process.
//!
//! Flags `.unwrap()`, `.expect(…)`, `panic!`, `unimplemented!` and `todo!`
//! in non-test code. The fallible-adjacent combinators (`unwrap_or`,
//! `unwrap_or_else`, `unwrap_or_default`, …) are distinct identifiers and
//! are deliberately *not* flagged — they cannot panic. `unreachable!` and
//! the `assert*` family are also exempt: they state invariants, and
//! converting them to `Result` would bury programming errors as runtime
//! conditions.
//!
//! Provably-infallible sites (an element pushed on the previous line, a
//! value checked by the surrounding guard) may carry an `audit:allow`
//! escape naming this rule, with a justification for why it cannot fire.

use super::super::{AuditCtx, Finding};
use super::is_method_call;
use crate::audit::lexer::TokKind;

const RULE: &str = "panic-safety";

pub fn check(ctx: &AuditCtx, out: &mut Vec<Finding>) {
    for file in ctx.files {
        let code = &file.code;
        for i in 0..code.len() {
            let t = &code[i];
            if t.kind != TokKind::Ident {
                continue;
            }
            let bang = code.get(i + 1).is_some_and(|n| n.is_punct('!'));
            let msg = match t.text.as_str() {
                "unwrap" | "expect" if is_method_call(code, i, &t.text) => {
                    format!(
                        "`.{}(…)` can panic; return an anyhow error with context instead",
                        t.text
                    )
                }
                "panic" if bang => {
                    "`panic!` in a library path; bail with an anyhow error instead".into()
                }
                "unimplemented" | "todo" if bang => {
                    format!("`{}!` must not ship in library paths", t.text)
                }
                _ => continue,
            };
            out.push(Finding { rule: RULE, file: file.rel.clone(), line: t.line, msg });
        }
    }
}
