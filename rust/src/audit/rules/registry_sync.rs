//! `registry-sync`: the hand-maintained registries cannot drift.
//!
//! Rust's exhaustive `match` already protects the dispatch sites, but
//! three registries are plain lists the compiler cannot check:
//!
//! 1. every `enum Algorithm` variant must appear in the `fn all()` body of
//!    the same file — `all()` drives CLI parsing and sweep-grid expansion,
//!    so a variant missing there is silently unreachable;
//! 2. every variant must appear in `tests/transport_equivalence.rs` — the
//!    cross-backend determinism contract only covers algorithms the test
//!    enumerates;
//! 3. every span/mark name literal passed to `Obs::span`/`span_at`/`mark`,
//!    and every registered message kind, must appear (backticked) in
//!    `docs/TRACING.md` — trace consumers read the doc, not the code.
//!
//! When auditing the real crate the orchestrator also cross-checks the
//! text-parsed variant list against the compiled `Algorithm::all()`, so
//! this parser cannot drift from the enum it audits.

use super::super::{AuditCtx, Finding};
use super::{bit_accounting, match_brace};
use crate::audit::lexer::TokKind;

const RULE: &str = "registry-sync";

/// Text-parsed `enum Algorithm` variants (exposed for the runtime
/// cross-check in the orchestrator).
pub(crate) fn algorithm_variants(ctx: &AuditCtx) -> Vec<(String, String, u32)> {
    let mut variants = Vec::new();
    for file in ctx.files {
        let code = &file.code;
        for i in 0..code.len() {
            if !(code[i].is_ident("enum")
                && code.get(i + 1).is_some_and(|t| t.is_ident("Algorithm"))
                && code.get(i + 2).is_some_and(|t| t.is_punct('{')))
            {
                continue;
            }
            let end = match_brace(code, i + 2);
            let mut depth = 0isize;
            for j in i + 2..end {
                let t = &code[j];
                if t.is_punct('{') || t.is_punct('(') {
                    depth += 1;
                } else if t.is_punct('}') || t.is_punct(')') {
                    depth -= 1;
                } else if depth == 1
                    && t.kind == TokKind::Ident
                    && code
                        .get(j + 1)
                        .is_some_and(|n| n.is_punct(',') || n.is_punct('}'))
                {
                    variants.push((t.text.clone(), file.rel.clone(), t.line));
                }
            }
        }
    }
    variants
}

pub fn check(ctx: &AuditCtx, out: &mut Vec<Finding>) {
    let variants = algorithm_variants(ctx);

    // 1. every variant is in `fn all()` of the declaring file.
    for (name, rel, line) in &variants {
        let Some(file) = ctx.files.iter().find(|f| &f.rel == rel) else { continue };
        match fn_body_idents(file, "all") {
            None => {
                // Report once, anchored to the first variant.
                if variants.iter().position(|(_, r, _)| r == rel)
                    == variants.iter().position(|(n, r, _)| n == name && r == rel)
                {
                    out.push(Finding {
                        rule: RULE,
                        file: rel.clone(),
                        line: *line,
                        msg: "enum Algorithm has no `fn all()` registry in this file".into(),
                    });
                }
            }
            Some(body) => {
                if !body.iter().any(|id| id == name) {
                    out.push(Finding {
                        rule: RULE,
                        file: rel.clone(),
                        line: *line,
                        msg: format!("algorithm variant `{name}` is missing from Algorithm::all()"),
                    });
                }
            }
        }
    }

    // 2. every variant appears in the transport-equivalence test.
    if !variants.is_empty() {
        match &ctx.equivalence {
            None => out.push(Finding {
                rule: RULE,
                file: "tests/transport_equivalence.rs".into(),
                line: 1,
                msg: "tests/transport_equivalence.rs not found; every algorithm must be \
                      covered by the cross-backend determinism test"
                    .into(),
            }),
            Some(eq) => {
                for (name, rel, line) in &variants {
                    let covered = eq.code.iter().any(|t| t.is_ident(name));
                    if !covered {
                        out.push(Finding {
                            rule: RULE,
                            file: rel.clone(),
                            line: *line,
                            msg: format!(
                                "algorithm variant `{name}` is not exercised by \
                                 tests/transport_equivalence.rs"
                            ),
                        });
                    }
                }
            }
        }
    }

    // 3. trace names and message kinds are documented.
    let mut doc_items: Vec<(String, String, u32, &str)> = Vec::new();
    for file in ctx.files {
        let code = &file.code;
        for i in 0..code.len() {
            if code[i].kind != TokKind::Ident {
                continue;
            }
            let is_obs = matches!(code[i].text.as_str(), "span" | "span_at" | "mark");
            if is_obs
                && i > 0
                && code[i - 1].is_punct('.')
                && code.get(i + 1).is_some_and(|t| t.is_punct('('))
                && code.get(i + 2).map(|t| t.kind) == Some(TokKind::Str)
            {
                let name = code[i + 2].text.clone();
                doc_items.push((name, file.rel.clone(), code[i].line, "trace span/mark"));
            }
        }
    }
    let mut registry = Vec::new();
    for file in ctx.files {
        bit_accounting::collect_registry(file, &mut registry);
    }
    for e in &registry {
        doc_items.push((e.name.clone(), e.file.clone(), e.line, "message kind"));
    }

    if !doc_items.is_empty() {
        let Some(doc) = &ctx.tracing_md else {
            out.push(Finding {
                rule: RULE,
                file: "docs/TRACING.md".into(),
                line: 1,
                msg: "docs/TRACING.md not found, but the crate declares trace names / \
                      message kinds that must be documented there"
                    .into(),
            });
            return;
        };
        let mut reported: Vec<String> = Vec::new();
        for (name, rel, line, what) in &doc_items {
            let key = format!("{what}:{name}");
            if reported.contains(&key) {
                continue;
            }
            if !doc.contains(&format!("`{name}`")) {
                reported.push(key);
                out.push(Finding {
                    rule: RULE,
                    file: rel.clone(),
                    line: *line,
                    msg: format!("{what} `{name}` is not documented in docs/TRACING.md"),
                });
            }
        }
    }
}

/// Identifiers in the body of `fn <name>` in this file, or `None` if the
/// function is absent.
fn fn_body_idents(
    file: &crate::audit::source::SourceFile,
    name: &str,
) -> Option<Vec<String>> {
    let code = &file.code;
    for i in 0..code.len() {
        if !(code[i].is_ident("fn") && code.get(i + 1).is_some_and(|t| t.is_ident(name))) {
            continue;
        }
        // Walk from the signature to its body brace.
        let mut j = i + 2;
        while j < code.len() && !code[j].is_punct('{') && !code[j].is_punct(';') {
            j += 1;
        }
        if j >= code.len() || code[j].is_punct(';') {
            continue; // trait method declaration without a body
        }
        let end = match_brace(code, j);
        return Some(
            code[j..end]
                .iter()
                .filter(|t| t.kind == TokKind::Ident)
                .map(|t| t.text.clone())
                .collect(),
        );
    }
    None
}
