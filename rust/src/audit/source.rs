//! Scanned-source model: one lexed file plus the structure the rules need —
//! which tokens are test-only code, and which `audit:allow` escapes the
//! author wrote.
//!
//! Test exclusion is *textual*, mirroring what the rules are: `#[cfg(test)]`
//! items (almost always `mod tests { … }`) are located by token pattern and
//! brace matching, and every token inside them is dropped from the `code`
//! view. Integration tests and benches live outside `src/` and are never
//! scanned, so "code" here means exactly the library/binary paths that run
//! in production.

use super::lexer::{lex, TokKind, Token};
use anyhow::{Context, Result};
use std::cell::Cell;
use std::path::{Path, PathBuf};

/// One `audit:allow` directive — rule id in parentheses, then a
/// `: <justification>` tail — found in a comment.
#[derive(Debug)]
pub struct Allow {
    /// Rule id between the parentheses (validated upstream against the
    /// rule registry).
    pub rule: String,
    /// Line the comment sits on. The allow applies to findings on this
    /// line and the next one (comment-above-the-offending-line style).
    pub line: u32,
    /// Whether a non-empty `: justification` followed the rule id.
    pub justified: bool,
    /// Set when a finding is suppressed by this allow; an allow that
    /// suppresses nothing is itself a finding (`unused-allow`).
    pub used: Cell<bool>,
}

/// A lexed source file ready for rule scans.
pub struct SourceFile {
    /// Path relative to the scan root, `/`-separated (stable across OSes
    /// for JSONL output and sorting).
    pub rel: String,
    pub path: PathBuf,
    /// Non-comment tokens *outside* `#[cfg(test)]` items — the only view
    /// rules scan.
    pub code: Vec<Token>,
    /// Allow directives from comments outside `#[cfg(test)]` items.
    pub allows: Vec<Allow>,
}

impl SourceFile {
    /// Read, lex, and structure one file. `exclude_tests` is true for
    /// `src/` scans and false for files that are *supposed* to be tests
    /// (e.g. `tests/transport_equivalence.rs`, which registry-sync reads).
    pub fn load(path: &Path, rel: String, exclude_tests: bool) -> Result<SourceFile> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let tokens = lex(&src);

        // Indices of non-comment tokens (the view brace matching uses).
        let nc: Vec<usize> =
            (0..tokens.len()).filter(|&i| tokens[i].kind != TokKind::Comment).collect();
        let test_mask = if exclude_tests {
            test_token_mask(&tokens, &nc)
        } else {
            vec![false; tokens.len()]
        };

        let mut code = Vec::new();
        let mut allows = Vec::new();
        for (i, t) in tokens.iter().enumerate() {
            if test_mask[i] {
                continue;
            }
            if t.kind == TokKind::Comment {
                parse_allows(&t.text, t.line, &mut allows);
            } else {
                code.push(t.clone());
            }
        }
        Ok(SourceFile { rel, path: path.to_path_buf(), code, allows })
    }

    /// The allow (if any) that covers a finding of `rule` at `line`.
    /// Only justified directives count; unjustified ones are inert (and
    /// flagged separately), so a suppression can never lack a rationale.
    pub fn allow_for(&self, rule: &str, line: u32) -> Option<&Allow> {
        self.allows.iter().find(|a| {
            a.justified && a.rule == rule && (a.line == line || a.line + 1 == line)
        })
    }
}

/// Mark every token belonging to a `#[cfg(test)]` item. Works on the
/// non-comment view `nc` (attributes split by comments still match), then
/// widens each item span back to raw token indices.
fn test_token_mask(tokens: &[Token], nc: &[usize]) -> Vec<bool> {
    let mut mask = vec![false; tokens.len()];
    let is = |vi: usize, pred: &dyn Fn(&Token) -> bool| {
        nc.get(vi).is_some_and(|&i| pred(&tokens[i]))
    };
    let mut vi = 0usize;
    while vi < nc.len() {
        let hit = is(vi, &|t| t.is_punct('#'))
            && is(vi + 1, &|t| t.is_punct('['))
            && is(vi + 2, &|t| t.is_ident("cfg"))
            && is(vi + 3, &|t| t.is_punct('('))
            && is(vi + 4, &|t| t.is_ident("test"))
            && is(vi + 5, &|t| t.is_punct(')'))
            && is(vi + 6, &|t| t.is_punct(']'));
        if !hit {
            vi += 1;
            continue;
        }
        let start = vi;
        let mut j = vi + 7;
        // Skip any further attributes on the same item.
        while is(j, &|t| t.is_punct('#')) && is(j + 1, &|t| t.is_punct('[')) {
            j = match_delim(tokens, nc, j + 1, '[', ']');
        }
        // Walk to the end of the item: its body `{…}` or a trailing `;`.
        let end = loop {
            if j >= nc.len() {
                break nc.len().saturating_sub(1);
            }
            let t = &tokens[nc[j]];
            if t.is_punct('{') {
                break match_delim(tokens, nc, j, '{', '}').saturating_sub(1);
            }
            if t.is_punct(';') {
                break j;
            }
            if t.is_punct('(') {
                j = match_delim(tokens, nc, j, '(', ')');
            } else if t.is_punct('[') {
                j = match_delim(tokens, nc, j, '[', ']');
            } else {
                j += 1;
            }
        };
        let end = end.min(nc.len() - 1);
        // Widen [start, end] in view indices to raw indices, catching the
        // comments interleaved with the item.
        for raw in nc[start]..=nc[end] {
            mask[raw] = true;
        }
        vi = end + 1;
    }
    mask
}

/// From view index `open` (which must hold the opening delimiter), return
/// the view index just past the matching closer. Unbalanced input returns
/// the end of the view (graceful, like the lexer).
fn match_delim(tokens: &[Token], nc: &[usize], open: usize, o: char, c: char) -> usize {
    let mut depth = 0usize;
    let mut j = open;
    while j < nc.len() {
        let t = &tokens[nc[j]];
        if t.is_punct(o) {
            depth += 1;
        } else if t.is_punct(c) {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    nc.len()
}

/// Extract every `audit:allow` directive (parenthesised rule id plus an
/// optional `: justification` tail) from one comment.
fn parse_allows(comment: &str, line: u32, out: &mut Vec<Allow>) {
    const NEEDLE: &str = "audit:allow(";
    let mut rest = comment;
    while let Some(at) = rest.find(NEEDLE) {
        let after = &rest[at + NEEDLE.len()..];
        let Some(close) = after.find(')') else {
            // Unterminated directive: record it malformed (empty rule id
            // never validates) so it surfaces instead of silently doing
            // nothing.
            out.push(Allow {
                rule: String::new(),
                line,
                justified: false,
                used: Cell::new(false),
            });
            return;
        };
        let rule = after[..close].trim().to_string();
        let tail = &after[close + 1..];
        let justified = tail
            .strip_prefix(':')
            .map(|j| !j.trim().is_empty())
            .unwrap_or(false);
        out.push(Allow { rule, line, justified, used: Cell::new(false) });
        rest = &after[close + 1..];
    }
}

/// Recursively collect `*.rs` files under `dir`, sorted by path for
/// deterministic reports.
pub fn walk_rs_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries = std::fs::read_dir(&d)
            .with_context(|| format!("listing {}", d.display()))?;
        for entry in entries {
            let entry = entry.with_context(|| format!("listing {}", d.display()))?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn load_src(src: &str, exclude_tests: bool) -> SourceFile {
        static SEQ: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
        let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "audit_source_test_{}_{}",
            std::process::id(),
            seq
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("f.rs");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(src.as_bytes()).unwrap();
        SourceFile::load(&path, "f.rs".into(), exclude_tests).unwrap()
    }

    fn has_ident(sf: &SourceFile, name: &str) -> bool {
        sf.code.iter().any(|t| t.is_ident(name))
    }

    #[test]
    fn cfg_test_mod_is_excluded() {
        let sf = load_src(
            "fn live() {}\n#[cfg(test)]\nmod tests {\n fn dead() { x.unwrap(); }\n}\nfn live2() {}",
            true,
        );
        assert!(has_ident(&sf, "live"));
        assert!(has_ident(&sf, "live2"));
        assert!(!has_ident(&sf, "dead"));
        assert!(!has_ident(&sf, "unwrap"));
    }

    #[test]
    fn cfg_test_non_mod_items_are_excluded() {
        let sf = load_src(
            "#[cfg(test)]\nuse crate::testing::helper;\n#[cfg(test)]\nfn fixture() { y.unwrap(); }\nfn live() {}",
            true,
        );
        assert!(!has_ident(&sf, "helper"));
        assert!(!has_ident(&sf, "fixture"));
        assert!(has_ident(&sf, "live"));
    }

    #[test]
    fn cfg_not_test_is_kept() {
        let sf = load_src("#[cfg(not(test))]\nfn live() {}", true);
        assert!(has_ident(&sf, "live"));
    }

    #[test]
    fn allows_are_parsed_with_justification() {
        let sf = load_src(
            "// audit:allow(panic-safety): element pushed above\nfn a() {}\n// audit:allow(determinism-clock)\nfn b() {}",
            true,
        );
        assert_eq!(sf.allows.len(), 2);
        assert_eq!(sf.allows[0].rule, "panic-safety");
        assert!(sf.allows[0].justified);
        assert_eq!(sf.allows[0].line, 1);
        assert_eq!(sf.allows[1].rule, "determinism-clock");
        assert!(!sf.allows[1].justified);
    }

    #[test]
    fn allow_matches_same_and_next_line() {
        let sf = load_src("// audit:allow(panic-safety): ok\nfn a() {}", true);
        assert!(sf.allow_for("panic-safety", 1).is_some());
        assert!(sf.allow_for("panic-safety", 2).is_some());
        assert!(sf.allow_for("panic-safety", 3).is_none());
        assert!(sf.allow_for("determinism-hash", 2).is_none());
    }

    #[test]
    fn allows_inside_test_mods_are_ignored() {
        let sf = load_src(
            "#[cfg(test)]\nmod tests {\n // audit:allow(panic-safety): test-only\n}\n",
            true,
        );
        assert!(sf.allows.is_empty());
    }
}
