//! Hessian representation bases (paper §2.3, §4, §5).
//!
//! The central abstraction of *Basis Learn*: a client's Hessian
//! `∇²f_i(x) ∈ R^{d×d}` is re-expressed as a coefficient matrix
//! `h^i(∇²f_i(x))` with respect to a basis `{B_i^{jl}}` of (a subspace of)
//! the matrix space, the *coefficients* are compressed and learned
//! (`L_i^k`), and the server decodes `Σ_{jl} (L_i^k)_{jl} B_i^{jl}`.
//! Choosing a basis adapted to the client's data makes `h` dramatically
//! sparser / smaller than the raw Hessian — communication savings for free.
//!
//! Implementations:
//! * [`StandardBasis`] — Example 4.1, `h(A) = A`. BL1/BL2 with this basis are
//!   exactly FedNL / FedNL-PP / FedNL-BC.
//! * [`SymTriBasis`] — Example 4.2, `h(A)` = lower-triangular packing of a
//!   symmetric matrix (halves the float count).
//! * [`SubspaceBasis`] — §2.3: the data-driven basis `{v_t v_lᵀ}` built from
//!   an orthonormal basis `V ∈ R^{d×r}` of the client's data span;
//!   `h(A) = VᵀAV ∈ R^{r×r}` and gradients compress to `r` coefficients.
//! * [`PsdBasis`] — Example 5.1: a basis of `S^d` whose elements are PSD,
//!   enabling BL3's projection-free positive-definiteness trick.

mod psd;
mod standard;
pub mod subspace;

pub use psd::PsdBasis;
pub use standard::{StandardBasis, SymTriBasis};
pub use subspace::SubspaceBasis;

use crate::linalg::Mat;

/// Caller-owned scratch for the allocation-free basis transforms
/// ([`HessianBasis::encode_into`] / [`HessianBasis::decode_into`]).
///
/// Two-step transforms (e.g. [`SubspaceBasis`]'s `VᵀAV`) stage their
/// intermediate product here so steady-state calls reuse the same buffers.
#[derive(Default)]
pub struct BasisScratch {
    /// Intermediate product (`A·V`, `V·h`, …).
    pub tmp: Mat,
}

/// A basis of (a subspace of) the space of `d×d` matrices, with the
/// coefficient transforms the Basis-Learn algorithms need.
pub trait HessianBasis: Send + Sync {
    /// Ambient dimension `d`.
    fn dim(&self) -> usize;

    /// Shape of the coefficient object `h(A)` (rows, cols).
    fn coeff_shape(&self) -> (usize, usize);

    /// Coefficients `h(A)` of a (symmetric) matrix in this basis.
    ///
    /// For bases spanning a strict subspace (e.g. [`SubspaceBasis`]) this is
    /// the orthogonal projection onto the span — lossless whenever `A` lies
    /// in the span, which holds for GLM data-Hessians by construction (§2.3).
    fn encode(&self, a: &Mat) -> Mat;

    /// Reconstruct `Σ_{jl} h_{jl} B^{jl}` from coefficients.
    fn decode(&self, h: &Mat) -> Mat;

    /// [`HessianBasis::encode`] into caller-owned storage. Implementations
    /// must produce bit-identical coefficients to `encode`; the default
    /// delegates (and therefore still allocates) — hot bases override it.
    fn encode_into(&self, a: &Mat, out: &mut Mat, scratch: &mut BasisScratch) {
        let _ = scratch;
        out.copy_from(&self.encode(a));
    }

    /// [`HessianBasis::decode`] into caller-owned storage (same
    /// bit-identity contract as [`HessianBasis::encode_into`]).
    fn decode_into(&self, h: &Mat, out: &mut Mat, scratch: &mut BasisScratch) {
        let _ = scratch;
        out.copy_from(&self.decode(h));
    }

    /// `N_B` of eq. (10): 1 if the basis matrices are mutually orthogonal
    /// (in the Frobenius inner product), `d²` otherwise.
    fn n_b(&self) -> f64;

    /// `R` of Assumption 4.7: `max_{jl} ‖B^{jl}‖_F`.
    fn max_fro(&self) -> f64;

    /// Whether every basis element is PSD (required by BL3, §5).
    fn is_psd_basis(&self) -> bool {
        false
    }

    /// Number of float coefficients in the gradient representation.
    /// Defaults to `d` (standard coordinates).
    fn grad_coeff_len(&self) -> usize {
        self.dim()
    }

    /// Gradient coefficients (defaults to identity).
    fn encode_grad(&self, g: &[f64]) -> Vec<f64> {
        g.to_vec()
    }

    /// Reconstruct a gradient from its coefficients.
    fn decode_grad(&self, c: &[f64]) -> Vec<f64> {
        c.to_vec()
    }

    /// [`HessianBasis::encode_grad`] into caller-owned storage
    /// (bit-identical; the default delegates).
    fn encode_grad_into(&self, g: &[f64], out: &mut Vec<f64>) {
        let enc = self.encode_grad(g);
        out.clear();
        out.extend_from_slice(&enc);
    }

    /// [`HessianBasis::decode_grad`] into caller-owned storage
    /// (bit-identical; the default delegates).
    fn decode_grad_into(&self, c: &[f64], out: &mut Vec<f64>) {
        let dec = self.decode_grad(c);
        out.clear();
        out.extend_from_slice(&dec);
    }

    /// Human-readable name.
    fn name(&self) -> String;
}

/// Round-trip checks shared by all basis tests (and reused by integration
/// tests): encode∘decode and decode∘encode identities on in-span matrices.
#[cfg(test)]
pub(crate) fn check_roundtrip(basis: &dyn HessianBasis, a: &Mat, tol: f64) {
    let h = basis.encode(a);
    assert_eq!((h.rows(), h.cols()), basis.coeff_shape(), "{}", basis.name());
    let rec = basis.decode(&h);
    let err = (&rec - a).fro_norm() / (1.0 + a.fro_norm());
    assert!(err < tol, "{}: decode(encode(A)) err={err}", basis.name());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// decode must be linear: decode(αh₁ + h₂) = α·decode(h₁) + decode(h₂).
    #[test]
    fn decode_linearity_all_bases() {
        let mut rng = Rng::new(70);
        let d = 6;
        let v = crate::basis::subspace::orthonormal_cols(d, 3, &mut rng);
        let bases: Vec<Box<dyn HessianBasis>> = vec![
            Box::new(StandardBasis::new(d)),
            Box::new(SymTriBasis::new(d)),
            Box::new(SubspaceBasis::new(v)),
            Box::new(PsdBasis::new(d)),
        ];
        for b in &bases {
            let (r, c) = b.coeff_shape();
            let h1 = Mat::from_fn(r, c, |_, _| rng.normal());
            let h2 = Mat::from_fn(r, c, |_, _| rng.normal());
            let alpha = 0.7;
            let mut comb = h1.clone();
            comb.data_mut().iter_mut().zip(h2.data()).for_each(|(x, y)| *x = alpha * *x + y);
            let lhs = b.decode(&comb);
            let mut rhs = b.decode(&h1);
            rhs.data_mut()
                .iter_mut()
                .zip(b.decode(&h2).data())
                .for_each(|(x, y)| *x = alpha * *x + y);
            let err = (&lhs - &rhs).fro_norm();
            assert!(err < 1e-10, "{}: decode not linear, err={err}", b.name());
        }
    }
}
