//! The PSD basis of `S^d` (Example 5.1) used by BL3.
//!
//! Basis elements: for `j ≠ l`, `B^{jl}` has ones at `(j,l), (l,j), (j,j),
//! (l,l)` (PSD: it is the Gram matrix of `e_j + e_l` restricted to the 2×2
//! block); for `j = l`, `B^{jj} = e_j e_jᵀ`. Every element is PSD, which lets
//! BL3 keep its Hessian estimator `Σ (β(L+2γ) − 2γ)_{jl} B^{jl}` provably
//! `⪰ ∇²f_i` without eigenvalue projections.
//!
//! Coefficient convention (paper §5): `h̃(A)` is stored as a *symmetric* `d×d`
//! matrix with `h̃(A)_{jl} = ½·c_{jl}` for `j ≠ l` and `h̃(A)_{jj} = c_{jj}`,
//! where `c` are the unique expansion coefficients over ordered pairs
//! `j ≥ l`. With the convention `B^{lj} := B^{jl}`, decoding sums over *all*
//! `(j,l)` pairs, so `decode(h̃) = Σ_{j,l} h̃_{jl} B^{jl}`.
//!
//! Closed forms (no `Ñ×Ñ` matrix inversion needed):
//! `c_{jl} = A_{jl}` for `j ≠ l`, and `c_{jj} = A_{jj} − Σ_{l≠j} A_{jl}`.

use super::HessianBasis;
use crate::linalg::Mat;

/// Example 5.1 PSD basis of the symmetric matrix space.
#[derive(Clone, Copy, Debug)]
pub struct PsdBasis {
    d: usize,
}

impl PsdBasis {
    pub fn new(d: usize) -> Self {
        PsdBasis { d }
    }

    /// Materialize basis element `B^{jl}` (test/diagnostic helper).
    pub fn element(&self, j: usize, l: usize) -> Mat {
        let mut b = Mat::zeros(self.d, self.d);
        if j == l {
            b[(j, j)] = 1.0;
        } else {
            b[(j, l)] = 1.0;
            b[(l, j)] = 1.0;
            b[(j, j)] = 1.0;
            b[(l, l)] = 1.0;
        }
        b
    }

    /// The matrix `Σ_{j,l} w_{jl} B^{jl}` for a symmetric weight matrix `w` —
    /// shared by [`HessianBasis::decode`] and by BL3's `A_i^k`/`C_i^k`
    /// bookkeeping where the weights are affine transforms of `L_i^k`.
    pub fn weighted_sum(&self, w: &Mat) -> Mat {
        let d = self.d;
        debug_assert_eq!(w.rows(), d);
        let mut out = Mat::zeros(d, d);
        // Off-diagonal (p≠q): out_pq = w_pq + w_qp.
        // Diagonal: out_pp = w_pp + Σ_{q≠p} (w_pq + w_qp).
        for p in 0..d {
            let mut diag = w[(p, p)];
            for q in 0..d {
                if q == p {
                    continue;
                }
                let s = w[(p, q)] + w[(q, p)];
                out[(p, q)] = s;
                diag += s;
            }
            out[(p, p)] = diag;
        }
        out
    }
}

impl HessianBasis for PsdBasis {
    fn dim(&self) -> usize {
        self.d
    }

    fn coeff_shape(&self) -> (usize, usize) {
        (self.d, self.d)
    }

    fn encode(&self, a: &Mat) -> Mat {
        debug_assert!(a.is_symmetric(1e-9), "PsdBasis expects symmetric input");
        let d = self.d;
        // c_{jl} = A_{jl} (j≠l), c_{jj} = A_{jj} − Σ_{l≠j} A_{jl};
        // stored with the ½ convention off-diagonal.
        let mut h = Mat::zeros(d, d);
        for j in 0..d {
            let mut off_sum = 0.0;
            for l in 0..d {
                if l == j {
                    continue;
                }
                off_sum += a[(j, l)];
                h[(j, l)] = 0.5 * a[(j, l)];
            }
            h[(j, j)] = a[(j, j)] - off_sum;
        }
        h
    }

    fn decode(&self, h: &Mat) -> Mat {
        self.weighted_sum(h)
    }

    fn n_b(&self) -> f64 {
        // Elements overlap on diagonals ⇒ not orthogonal.
        (self.d * self.d) as f64
    }

    fn max_fro(&self) -> f64 {
        2.0 // ‖B^{jl}‖_F = 2 for j ≠ l (four unit entries)
    }

    fn is_psd_basis(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        "psd".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::check_roundtrip;
    use crate::linalg::sym_eigen;
    use crate::rng::Rng;

    #[test]
    fn elements_are_psd() {
        let b = PsdBasis::new(5);
        for j in 0..5 {
            for l in 0..=j {
                let e = sym_eigen(&b.element(j, l));
                assert!(
                    e.values.iter().all(|&lam| lam >= -1e-12),
                    "B^{{{j},{l}}} not PSD: {:?}",
                    e.values
                );
            }
        }
    }

    #[test]
    fn roundtrip_symmetric() {
        let mut rng = Rng::new(90);
        for d in [1, 2, 3, 6, 11] {
            let mut a = Mat::from_fn(d, d, |_, _| rng.normal());
            a.symmetrize();
            check_roundtrip(&PsdBasis::new(d), &a, 1e-12);
        }
    }

    #[test]
    fn decode_matches_explicit_basis_expansion() {
        let mut rng = Rng::new(91);
        let d = 4;
        let basis = PsdBasis::new(d);
        let mut h = Mat::from_fn(d, d, |_, _| rng.normal());
        h.symmetrize();
        let fast = basis.decode(&h);
        // Explicit Σ_{j,l} h_jl B^{jl} (over all ordered pairs).
        let mut explicit = Mat::zeros(d, d);
        for j in 0..d {
            for l in 0..d {
                explicit.add_scaled(h[(j, l)], &basis.element(j, l));
            }
        }
        assert!((&fast - &explicit).fro_norm() < 1e-12);
    }

    #[test]
    fn encode_identity_matrix() {
        // I = Σ_j B^{jj}: coefficients are 1 on the diagonal, 0 elsewhere.
        let d = 5;
        let h = PsdBasis::new(d).encode(&Mat::eye(d));
        for j in 0..d {
            for l in 0..d {
                let expect = if j == l { 1.0 } else { 0.0 };
                assert!((h[(j, l)] - expect).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn coefficients_of_single_element() {
        // encode(B^{jl}) should give ½ at (j,l),(l,j) and 0 diag contributions.
        let d = 4;
        let basis = PsdBasis::new(d);
        let h = basis.encode(&basis.element(2, 0));
        assert!((h[(2, 0)] - 0.5).abs() < 1e-14);
        assert!((h[(0, 2)] - 0.5).abs() < 1e-14);
        assert!(h[(1, 1)].abs() < 1e-14);
        assert!(h[(0, 0)].abs() < 1e-14, "h00={}", h[(0, 0)]);
        assert!(h[(2, 2)].abs() < 1e-14);
    }

    #[test]
    fn decode_always_symmetric() {
        let mut rng = Rng::new(92);
        let mut h = Mat::from_fn(6, 6, |_, _| rng.normal());
        h.symmetrize();
        assert!(PsdBasis::new(6).decode(&h).is_symmetric(1e-12));
    }
}
