//! Standard bases of `R^{d×d}` and of the symmetric subspace (Ex. 4.1/4.2).

use super::{BasisScratch, HessianBasis};
use crate::linalg::Mat;

/// Example 4.1: the canonical basis `E_{jl}`; `h(A) = A`.
///
/// BL1/BL2 instantiated with this basis reduce exactly to FedNL variants —
/// that identity is exploited by the FedNL implementations in
/// `coordinator::fednl` and asserted by integration tests.
#[derive(Clone, Copy, Debug)]
pub struct StandardBasis {
    d: usize,
}

impl StandardBasis {
    pub fn new(d: usize) -> Self {
        StandardBasis { d }
    }
}

impl HessianBasis for StandardBasis {
    fn dim(&self) -> usize {
        self.d
    }

    fn coeff_shape(&self) -> (usize, usize) {
        (self.d, self.d)
    }

    fn encode(&self, a: &Mat) -> Mat {
        a.clone()
    }

    fn decode(&self, h: &Mat) -> Mat {
        h.clone()
    }

    fn encode_into(&self, a: &Mat, out: &mut Mat, _scratch: &mut BasisScratch) {
        out.copy_from(a);
    }

    fn decode_into(&self, h: &Mat, out: &mut Mat, _scratch: &mut BasisScratch) {
        out.copy_from(h);
    }

    fn encode_grad_into(&self, g: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(g);
    }

    fn decode_grad_into(&self, c: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(c);
    }

    fn n_b(&self) -> f64 {
        1.0 // canonical basis is orthonormal
    }

    fn max_fro(&self) -> f64 {
        1.0
    }

    fn name(&self) -> String {
        "standard".into()
    }
}

/// Example 4.2: a basis adapted to symmetric matrices. For symmetric `A`,
/// `h(A)` is the lower-triangular packing (strict lower triangle + diagonal,
/// upper triangle zero), so only `d(d+1)/2` coefficients are non-zero.
///
/// `B^{jl}` (`j>l`) has ones at `(j,l)` and `(l,j)` (`‖B‖_F = √2`), the
/// diagonal elements are `E_{jj}`; the antisymmetric completion of the basis
/// is never exercised because all encoded matrices are symmetric.
#[derive(Clone, Copy, Debug)]
pub struct SymTriBasis {
    d: usize,
}

impl SymTriBasis {
    pub fn new(d: usize) -> Self {
        SymTriBasis { d }
    }
}

impl HessianBasis for SymTriBasis {
    fn dim(&self) -> usize {
        self.d
    }

    fn coeff_shape(&self) -> (usize, usize) {
        (self.d, self.d)
    }

    fn encode(&self, a: &Mat) -> Mat {
        debug_assert!(a.is_symmetric(1e-9), "SymTriBasis expects symmetric input");
        let d = self.d;
        Mat::from_fn(d, d, |j, l| if j >= l { a[(j, l)] } else { 0.0 })
    }

    fn decode(&self, h: &Mat) -> Mat {
        let d = self.d;
        // Lower-triangular coefficients; reflect across the diagonal. Upper
        // coefficients, if a compressor produced any, map to the same basis
        // elements (B^{jl} = B^{lj} convention) and are folded in.
        let mut out = Mat::zeros(d, d);
        for j in 0..d {
            for l in 0..d {
                let c = h[(j, l)];
                if c == 0.0 {
                    continue;
                }
                if j == l {
                    out[(j, j)] += c;
                } else {
                    out[(j, l)] += c;
                    out[(l, j)] += c;
                }
            }
        }
        out
    }

    fn encode_into(&self, a: &Mat, out: &mut Mat, _scratch: &mut BasisScratch) {
        debug_assert!(a.is_symmetric(1e-9), "SymTriBasis expects symmetric input");
        let d = self.d;
        out.resize_zeroed(d, d);
        for j in 0..d {
            for l in 0..=j {
                out[(j, l)] = a[(j, l)];
            }
        }
    }

    fn decode_into(&self, h: &Mat, out: &mut Mat, _scratch: &mut BasisScratch) {
        let d = self.d;
        out.resize_zeroed(d, d);
        for j in 0..d {
            for l in 0..d {
                let c = h[(j, l)];
                if c == 0.0 {
                    continue;
                }
                if j == l {
                    out[(j, j)] += c;
                } else {
                    out[(j, l)] += c;
                    out[(l, j)] += c;
                }
            }
        }
    }

    fn encode_grad_into(&self, g: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(g);
    }

    fn decode_grad_into(&self, c: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(c);
    }

    fn n_b(&self) -> f64 {
        1.0 // elements are mutually Frobenius-orthogonal
    }

    fn max_fro(&self) -> f64 {
        std::f64::consts::SQRT_2
    }

    fn name(&self) -> String {
        "symtri".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::check_roundtrip;
    use crate::rng::Rng;

    #[test]
    fn standard_is_identity() {
        let mut rng = Rng::new(71);
        let a = Mat::from_fn(5, 5, |_, _| rng.normal());
        let b = StandardBasis::new(5);
        assert_eq!(b.encode(&a), a);
        assert_eq!(b.decode(&a), a);
        check_roundtrip(&b, &a, 1e-14);
    }

    #[test]
    fn symtri_roundtrip() {
        let mut rng = Rng::new(72);
        for d in [1, 2, 3, 7, 12] {
            let mut a = Mat::from_fn(d, d, |_, _| rng.normal());
            a.symmetrize();
            check_roundtrip(&SymTriBasis::new(d), &a, 1e-13);
        }
    }

    #[test]
    fn symtri_encode_is_lower_triangular() {
        let mut rng = Rng::new(73);
        let mut a = Mat::from_fn(4, 4, |_, _| rng.normal());
        a.symmetrize();
        let h = SymTriBasis::new(4).encode(&a);
        for j in 0..4 {
            for l in (j + 1)..4 {
                assert_eq!(h[(j, l)], 0.0);
            }
        }
        assert_eq!(h[(2, 1)], a[(2, 1)]);
        assert_eq!(h[(3, 3)], a[(3, 3)]);
    }

    #[test]
    fn symtri_decode_always_symmetric() {
        // Even on arbitrary (compressor-mangled) coefficients.
        let mut rng = Rng::new(74);
        let h = Mat::from_fn(5, 5, |_, _| rng.normal());
        let out = SymTriBasis::new(5).decode(&h);
        assert!(out.is_symmetric(1e-12));
    }

    #[test]
    fn symtri_nonzero_coeff_count() {
        let d = 6;
        let mut a = Mat::from_fn(d, d, |i, j| (i + j) as f64 + 1.0);
        a.symmetrize();
        let h = SymTriBasis::new(d).encode(&a);
        let nnz = h.data().iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nnz, d * (d + 1) / 2);
    }
}
