//! The data-driven subspace basis of §2.3 — the paper's headline trick.
//!
//! If all of a client's data vectors lie in an `r`-dimensional subspace
//! `G_i = span(V)` with `V ∈ R^{d×r}` orthonormal, then every GLM
//! data-Hessian (eq. 3) lies in `span{v_t v_lᵀ}` and its coefficient matrix
//! in that basis is `h(A) = VᵀAV ∈ R^{r×r}` (eq. 5); gradients lie in `G_i`
//! itself with coefficients `Vᵀg ∈ R^r`. Communication per round drops from
//! `O(d²)` to `O(r²)` — lossless.
//!
//! `V` is extracted once per client before training (the paper uses
//! `scipy.linalg.orth`; we use our one-sided-Jacobi SVD), at a one-time cost
//! of `r·d` floats (Table 1).

use super::{BasisScratch, HessianBasis};
use crate::linalg::{svd, Mat};
use crate::rng::Rng;

/// Basis `{v_t v_lᵀ : t,l ∈ [r]}` for orthonormal columns `V = [v_1 … v_r]`.
#[derive(Clone, Debug)]
pub struct SubspaceBasis {
    /// `d×r` orthonormal matrix.
    v: Mat,
    /// Precomputed `Vᵀ`, so the hot `encode_into`/`decode_into` paths never
    /// re-transpose (bit-identical to transposing on the fly).
    vt: Mat,
}

impl SubspaceBasis {
    /// Build from an orthonormal `d×r` matrix (validated).
    pub fn new(v: Mat) -> Self {
        let r = v.cols();
        let vtv = v.transpose().matmul(&v);
        let err = (&vtv - &Mat::eye(r)).fro_norm();
        assert!(
            err < 1e-8,
            "SubspaceBasis requires orthonormal columns (‖VᵀV−I‖={err:.2e})"
        );
        SubspaceBasis { vt: v.transpose(), v }
    }

    /// Extract an orthonormal basis of the row space of a data matrix
    /// `A ∈ R^{m×d}` (rows are data points), keeping singular directions
    /// above `rel_tol·σ_max` — the `linalg.orth` step of §6.1.
    pub fn from_data(a: &Mat, rel_tol: f64) -> Self {
        let dec = svd(&a.transpose()); // columns of U span the row space of A
        let rank = dec.rank(rel_tol).max(1);
        let d = a.cols();
        let mut v = Mat::zeros(d, rank);
        for k in 0..rank {
            for i in 0..d {
                v[(i, k)] = dec.u[(i, k)];
            }
        }
        SubspaceBasis::new(v)
    }

    /// Subspace dimension `r`.
    pub fn r(&self) -> usize {
        self.v.cols()
    }

    /// The orthonormal matrix `V`.
    pub fn v(&self) -> &Mat {
        &self.v
    }

    /// One-time setup communication in floats (`r·d`, Table 1 row
    /// "initial communication cost").
    pub fn setup_floats(&self) -> usize {
        self.v.rows() * self.v.cols()
    }
}

impl HessianBasis for SubspaceBasis {
    fn dim(&self) -> usize {
        self.v.rows()
    }

    fn coeff_shape(&self) -> (usize, usize) {
        (self.r(), self.r())
    }

    fn encode(&self, a: &Mat) -> Mat {
        // h(A) = Vᵀ A V  — the orthogonal projection coefficients.
        let av = a.matmul(&self.v);
        self.v.transpose().matmul(&av)
    }

    fn decode(&self, h: &Mat) -> Mat {
        // A = V h Vᵀ
        let vh = self.v.matmul(h);
        vh.matmul(&self.v.transpose())
    }

    fn encode_into(&self, a: &Mat, out: &mut Mat, scratch: &mut BasisScratch) {
        a.matmul_into(&self.v, &mut scratch.tmp);
        self.vt.matmul_into(&scratch.tmp, out);
    }

    fn decode_into(&self, h: &Mat, out: &mut Mat, scratch: &mut BasisScratch) {
        self.v.matmul_into(h, &mut scratch.tmp);
        scratch.tmp.matmul_into(&self.vt, out);
    }

    fn encode_grad_into(&self, g: &[f64], out: &mut Vec<f64>) {
        self.v.matvec_t_into(g, out);
    }

    fn decode_grad_into(&self, c: &[f64], out: &mut Vec<f64>) {
        self.v.matvec_into(c, out);
    }

    fn n_b(&self) -> f64 {
        1.0 // {v_t v_lᵀ} is Frobenius-orthonormal for orthonormal v's
    }

    fn max_fro(&self) -> f64 {
        1.0 // ‖v_t v_lᵀ‖_F = ‖v_t‖‖v_l‖ = 1
    }

    fn grad_coeff_len(&self) -> usize {
        self.r()
    }

    fn encode_grad(&self, g: &[f64]) -> Vec<f64> {
        self.v.matvec_t(g)
    }

    fn decode_grad(&self, c: &[f64]) -> Vec<f64> {
        self.v.matvec(c)
    }

    fn name(&self) -> String {
        format!("subspace(r={})", self.r())
    }
}

/// Random orthonormal `d×r` matrix (QR of a Gaussian via Gram–Schmidt);
/// shared by tests and the synthetic data generator.
pub fn orthonormal_cols(d: usize, r: usize, rng: &mut Rng) -> Mat {
    assert!(r <= d);
    let mut v = Mat::zeros(d, r);
    for k in 0..r {
        let mut col: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        // Gram–Schmidt against previous columns (twice, for stability).
        for _ in 0..2 {
            for prev in 0..k {
                let pc = v.col(prev);
                let proj = crate::linalg::dot(&col, &pc);
                crate::linalg::axpy(-proj, &pc, &mut col);
            }
        }
        let nrm = crate::linalg::norm2(&col);
        assert!(nrm > 1e-12, "degenerate random draw");
        for i in 0..d {
            v[(i, k)] = col[i] / nrm;
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::check_roundtrip;
    use crate::linalg::norm2;

    #[test]
    fn roundtrip_on_in_span_matrices() {
        let mut rng = Rng::new(80);
        let (d, r) = (10, 4);
        let v = orthonormal_cols(d, r, &mut rng);
        let basis = SubspaceBasis::new(v.clone());
        // A = V C Vᵀ for random C — exactly in the span.
        let c = Mat::from_fn(r, r, |_, _| rng.normal());
        let a = v.matmul(&c).matmul(&v.transpose());
        check_roundtrip(&basis, &a, 1e-12);
        // And the coefficients are exactly C.
        let h = basis.encode(&a);
        assert!((&h - &c).fro_norm() < 1e-10);
    }

    #[test]
    fn encode_is_projection_for_out_of_span() {
        let mut rng = Rng::new(81);
        let (d, r) = (8, 3);
        let v = orthonormal_cols(d, r, &mut rng);
        let basis = SubspaceBasis::new(v);
        let a = Mat::from_fn(d, d, |_, _| rng.normal());
        let p = basis.decode(&basis.encode(&a));
        // Projection is idempotent.
        let p2 = basis.decode(&basis.encode(&p));
        assert!((&p2 - &p).fro_norm() < 1e-10);
        // And never increases the Frobenius norm.
        assert!(p.fro_norm() <= a.fro_norm() + 1e-12);
    }

    #[test]
    fn gradient_coefficients_roundtrip() {
        let mut rng = Rng::new(82);
        let (d, r) = (12, 5);
        let v = orthonormal_cols(d, r, &mut rng);
        let basis = SubspaceBasis::new(v.clone());
        // g in the span.
        let c: Vec<f64> = (0..r).map(|_| rng.normal()).collect();
        let g = v.matvec(&c);
        let enc = basis.encode_grad(&g);
        assert_eq!(enc.len(), r);
        for (x, y) in enc.iter().zip(&c) {
            assert!((x - y).abs() < 1e-10);
        }
        let back = basis.decode_grad(&enc);
        assert!(norm2(&crate::linalg::sub(&back, &g)) < 1e-10);
    }

    #[test]
    fn from_data_recovers_planted_subspace() {
        let mut rng = Rng::new(83);
        let (d, r, m) = (15, 4, 40);
        let v = orthonormal_cols(d, r, &mut rng);
        // m data points in span(V).
        let mut a = Mat::zeros(m, d);
        for i in 0..m {
            let coef: Vec<f64> = (0..r).map(|_| rng.normal()).collect();
            let x = v.matvec(&coef);
            a.row_mut(i).copy_from_slice(&x);
        }
        let basis = SubspaceBasis::from_data(&a, 1e-9);
        assert_eq!(basis.r(), r);
        // Every data point reconstructs through the basis.
        for i in 0..m {
            let g = a.row(i).to_vec();
            let back = basis.decode_grad(&basis.encode_grad(&g));
            assert!(norm2(&crate::linalg::sub(&back, &g)) < 1e-8);
        }
    }

    #[test]
    fn from_data_full_rank_data() {
        let mut rng = Rng::new(84);
        let a = Mat::from_fn(30, 6, |_, _| rng.normal());
        let basis = SubspaceBasis::from_data(&a, 1e-9);
        assert_eq!(basis.r(), 6);
    }

    #[test]
    fn setup_cost_matches_table_1() {
        let mut rng = Rng::new(85);
        let v = orthonormal_cols(9, 3, &mut rng);
        let basis = SubspaceBasis::new(v);
        assert_eq!(basis.setup_floats(), 27);
    }

    #[test]
    #[should_panic]
    fn rejects_non_orthonormal() {
        let v = Mat::from_vec(2, 2, vec![1.0, 1.0, 0.0, 1.0]);
        SubspaceBasis::new(v);
    }

    #[test]
    fn orthonormal_cols_is_orthonormal() {
        let mut rng = Rng::new(86);
        for (d, r) in [(5, 5), (20, 7), (3, 1)] {
            let v = orthonormal_cols(d, r, &mut rng);
            let vtv = v.transpose().matmul(&v);
            assert!((&vtv - &Mat::eye(r)).fro_norm() < 1e-10);
        }
    }
}
