//! Minimal benchmarking harness (criterion is not available in this
//! environment's crate registry, so we ship our own).
//!
//! Provides warmup, adaptive iteration counts targeting a fixed measurement
//! window, and robust statistics (median + MAD), with the familiar
//! `group/bench` shape. Used by both `rust/benches/*` entry points.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` under the name bench code expects.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Median time per iteration.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    /// Iterations per sample.
    pub iters: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn ns(&self) -> f64 {
        self.median.as_secs_f64() * 1e9
    }

    /// Pretty "value unit" like criterion's output.
    pub fn human(&self) -> String {
        let ns = self.ns();
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.3} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

/// Benchmark runner with a per-case time budget.
pub struct Bench {
    /// Target wall-clock per measured case.
    pub budget: Duration,
    pub warmup: Duration,
    pub min_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget: Duration::from_millis(600),
            warmup: Duration::from_millis(120),
            min_samples: 10,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile (used by CI-ish test runs): tiny budget.
    pub fn quick() -> Self {
        Bench {
            budget: Duration::from_millis(60),
            warmup: Duration::from_millis(10),
            min_samples: 5,
            results: Vec::new(),
        }
    }

    /// Run one case: `f` is called repeatedly; its return value is
    /// black-boxed to keep the optimizer honest.
    pub fn bench<T>(&mut self, name: impl Into<String>, mut f: impl FnMut() -> T) -> &BenchResult {
        let name = name.into();
        // Warmup and iteration-count calibration.
        let t0 = Instant::now();
        let mut calib_iters: u64 = 0;
        while t0.elapsed() < self.warmup {
            bb(f());
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        // Aim for ~min_samples..64 samples within the budget.
        let samples = self.min_samples.max(
            ((self.budget.as_secs_f64() / per_iter.max(1e-9)) as usize).min(64).max(self.min_samples),
        );
        let iters =
            ((self.budget.as_secs_f64() / samples as f64 / per_iter.max(1e-9)).ceil() as u64).max(1);

        let mut times: Vec<f64> = Vec::with_capacity(samples);
        for _ in 0..samples {
            let s = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            times.push(s.elapsed().as_secs_f64() / iters as f64);
        }
        times.sort_by(|a, b| a.total_cmp(b));
        let median = times[times.len() / 2];
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(|a, b| a.total_cmp(b));
        let mad = devs[devs.len() / 2];

        let result = BenchResult {
            name,
            median: Duration::from_secs_f64(median),
            mad: Duration::from_secs_f64(mad),
            iters,
            samples,
        };
        println!(
            "{:<52} {:>12}  (±{:.1}%, {} samples × {} iters)",
            result.name,
            result.human(),
            100.0 * result.mad.as_secs_f64() / result.median.as_secs_f64().max(1e-12),
            result.samples,
            result.iters
        );
        self.results.push(result);
        // audit:allow(panic-safety): the element was pushed on the line above.
        self.results.last().unwrap()
    }

    /// Section header in the output.
    pub fn group(&mut self, title: &str) {
        println!("\n── {title} ──");
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::quick();
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(bb(i) * i);
            }
            s
        });
        assert!(r.median.as_nanos() > 0);
        assert!(r.samples >= 5);
    }

    #[test]
    fn slower_work_measures_slower() {
        let mut b = Bench::quick();
        // black_box inside the loop so LLVM cannot closed-form the sum.
        let work = |n: u64| {
            let mut s = 0u64;
            for i in 0..n {
                s = s.wrapping_add(bb(i));
            }
            s
        };
        let fast = b.bench("fast", || work(100)).ns();
        let slow = b.bench("slow", || work(100_000)).ns();
        assert!(slow > fast * 5.0, "fast={fast}ns slow={slow}ns");
    }

    #[test]
    fn human_formatting() {
        let r = BenchResult {
            name: "x".into(),
            median: Duration::from_nanos(1500),
            mad: Duration::ZERO,
            iters: 1,
            samples: 1,
        };
        assert_eq!(r.human(), "1.50 µs");
    }
}
