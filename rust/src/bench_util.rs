//! Minimal benchmarking harness (criterion is not available in this
//! environment's crate registry, so we ship our own).
//!
//! Provides warmup, adaptive iteration counts targeting a fixed measurement
//! window, robust statistics (median + MAD), per-case allocation accounting
//! (when [`CountingAlloc`] is installed as the global allocator), and a
//! machine-readable JSON report ([`json_report`], schema in `docs/PERF.md`),
//! with the familiar `group/bench` shape. Used by the `rust/benches/*`
//! entry points and the `repro bench` subcommand ([`run_cli_suite`]).

use std::alloc::{GlobalAlloc, Layout, System};
use std::hint::black_box as bb;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` under the name bench code expects.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Gross bytes requested through [`CountingAlloc`] since process start
/// (frees are not subtracted: steady-state code that allocates and frees
/// every round still shows up).
static ALLOCATED_BYTES: AtomicU64 = AtomicU64::new(0);
/// Number of allocation requests through [`CountingAlloc`].
static ALLOCATION_COUNT: AtomicU64 = AtomicU64::new(0);

/// Byte-counting wrapper around the system allocator.
///
/// Install it as the binary's global allocator to get per-case
/// bytes-per-iteration in [`Bench`] output and the JSON report, and to
/// write allocation-regression tests:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: basis_learn::bench_util::CountingAlloc = basis_learn::bench_util::CountingAlloc;
/// ```
///
/// Overhead is two relaxed atomic increments per allocation, so leaving it
/// installed for ordinary runs is harmless. Counters are process-global and
/// monotonic; measure deltas, not absolutes.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        ALLOCATION_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        ALLOCATION_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // Only growth counts as fresh bytes; shrinks release, not request.
        if new_size > layout.size() {
            ALLOCATED_BYTES.fetch_add((new_size - layout.size()) as u64, Ordering::Relaxed);
            ALLOCATION_COUNT.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

impl CountingAlloc {
    /// Gross bytes allocated so far (0 forever unless installed globally).
    pub fn allocated_bytes() -> u64 {
        ALLOCATED_BYTES.load(Ordering::Relaxed)
    }

    /// Allocation requests so far (0 forever unless installed globally).
    pub fn allocation_count() -> u64 {
        ALLOCATION_COUNT.load(Ordering::Relaxed)
    }

    /// Whether this process's global allocator routes through the counter
    /// (probed with one boxed byte; the counters only ever move when the
    /// wrapper is installed, so any movement is proof).
    pub fn is_counting() -> bool {
        let before = Self::allocation_count();
        drop(bb(Box::new(0u8)));
        Self::allocation_count() != before
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Median time per iteration.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    /// Iterations per sample.
    pub iters: u64,
    pub samples: usize,
    /// Gross heap bytes per iteration, averaged over the measured samples.
    /// Always 0 unless [`CountingAlloc`] is the process's global allocator.
    pub bytes_per_iter: u64,
}

impl BenchResult {
    pub fn ns(&self) -> f64 {
        self.median.as_secs_f64() * 1e9
    }

    /// Pretty "value unit" like criterion's output.
    pub fn human(&self) -> String {
        let ns = self.ns();
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.3} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

/// Benchmark runner with a per-case time budget.
pub struct Bench {
    /// Target wall-clock per measured case.
    pub budget: Duration,
    pub warmup: Duration,
    pub min_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget: Duration::from_millis(600),
            warmup: Duration::from_millis(120),
            min_samples: 10,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile (used by CI-ish test runs): tiny budget.
    pub fn quick() -> Self {
        Bench {
            budget: Duration::from_millis(60),
            warmup: Duration::from_millis(10),
            min_samples: 5,
            results: Vec::new(),
        }
    }

    /// Run one case: `f` is called repeatedly; its return value is
    /// black-boxed to keep the optimizer honest.
    pub fn bench<T>(&mut self, name: impl Into<String>, mut f: impl FnMut() -> T) -> &BenchResult {
        let name = name.into();
        // Warmup and iteration-count calibration.
        let t0 = Instant::now();
        let mut calib_iters: u64 = 0;
        while t0.elapsed() < self.warmup {
            bb(f());
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        // Aim for ~min_samples..64 samples within the budget.
        let samples = self.min_samples.max(
            ((self.budget.as_secs_f64() / per_iter.max(1e-9)) as usize).min(64).max(self.min_samples),
        );
        let iters =
            ((self.budget.as_secs_f64() / samples as f64 / per_iter.max(1e-9)).ceil() as u64).max(1);

        let mut times: Vec<f64> = Vec::with_capacity(samples);
        let bytes_before = CountingAlloc::allocated_bytes();
        for _ in 0..samples {
            let s = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            times.push(s.elapsed().as_secs_f64() / iters as f64);
        }
        let bytes = CountingAlloc::allocated_bytes().saturating_sub(bytes_before);
        times.sort_by(|a, b| a.total_cmp(b));
        let median = times[times.len() / 2];
        let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(|a, b| a.total_cmp(b));
        let mad = devs[devs.len() / 2];

        let result = BenchResult {
            name,
            median: Duration::from_secs_f64(median),
            mad: Duration::from_secs_f64(mad),
            iters,
            samples,
            bytes_per_iter: bytes / (samples as u64 * iters).max(1),
        };
        let alloc_col = if CountingAlloc::is_counting() {
            format!("  {:>12}", format!("{} B/it", result.bytes_per_iter))
        } else {
            String::new()
        };
        println!(
            "{:<52} {:>12}  (±{:.1}%, {} samples × {} iters){}",
            result.name,
            result.human(),
            100.0 * result.mad.as_secs_f64() / result.median.as_secs_f64().max(1e-12),
            result.samples,
            result.iters,
            alloc_col
        );
        self.results.push(result);
        // audit:allow(panic-safety): the element was pushed on the line above.
        self.results.last().unwrap()
    }

    /// Section header in the output.
    pub fn group(&mut self, title: &str) {
        println!("\n── {title} ──");
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Minimal JSON string escape (quotes, backslashes, control characters).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render bench results as the `bench-v1` JSON report (one result object
/// per line; full schema in `docs/PERF.md`). `bytes_per_iter` is only
/// meaningful when `alloc_counted` is `true`.
pub fn json_report(results: &[BenchResult]) -> String {
    let mut out = String::from("{\n");
    out.push_str("  \"schema\": \"bench-v1\",\n");
    out.push_str(&format!("  \"alloc_counted\": {},\n", CountingAlloc::is_counting()));
    out.push_str("  \"results\": [\n");
    for (k, r) in results.iter().enumerate() {
        let comma = if k + 1 < results.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"ns_per_iter\": {:.3}, \"mad_ns\": {:.3}, \
             \"iters\": {}, \"samples\": {}, \"bytes_per_iter\": {}}}{comma}\n",
            json_escape(&r.name),
            r.ns(),
            r.mad.as_secs_f64() * 1e9,
            r.iters,
            r.samples,
            r.bytes_per_iter
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// The packed-vs-dense `sym` bench group: every [`crate::linalg::SymMat`]
/// kernel against its dense [`crate::linalg::Mat`] counterpart on the a1a
/// dimension. Shared by `repro bench` and `benches/hot_path.rs` so both
/// feed the same case names into the JSON trajectory.
pub fn bench_sym_group(b: &mut Bench, rng: &mut crate::rng::Rng) {
    use crate::linalg::{cholesky_solve, Mat, SymCholesky, SymMat};

    b.group("packed symmetric kernels (d=123, packed vs dense)");
    let d = 123;
    let mut sym = Mat::from_fn(d, d, |_, _| rng.normal());
    sym.symmetrize();
    let mut spd = sym.transpose().matmul(&sym);
    spd.add_diag(d as f64);
    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let psym = SymMat::from_mat(&sym);
    let pspd = SymMat::from_mat(&spd);

    let mut packed = SymMat::default();
    let mut dense = Mat::default();
    b.bench("sym/pack 123", || {
        packed.pack_from(&sym);
        packed.data()[0]
    });
    b.bench("sym/unpack 123", || {
        psym.unpack_into(&mut dense);
        dense[(0, 0)]
    });

    // Accumulation A += αB — the per-client Hessian-learning update. The
    // tiny α keeps the accumulator finite over millions of iterations.
    let mut acc_dense = spd.clone();
    b.bench("sym/add_scaled dense 123", || {
        acc_dense.add_scaled(1e-9, &sym);
        acc_dense[(0, 0)]
    });
    let mut acc_packed = pspd.clone();
    b.bench("sym/add_scaled packed 123", || {
        acc_packed.add_scaled(1e-9, &psym);
        acc_packed.data()[0]
    });

    b.bench("sym/matvec dense 123", || sym.matvec(&x));
    let mut y = Vec::new();
    b.bench("sym/matvec packed 123", || {
        psym.matvec_into(&x, &mut y);
        y[0]
    });

    // Scaled Gram accumulation (the GLM Hessian assembly kernel).
    let m = 200;
    let feat = Mat::from_fn(m, d, |_, _| rng.normal());
    let s: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
    b.bench("sym/gram dense 200x123", || feat.gram_scaled(&s));
    let mut gram = SymMat::default();
    b.bench("sym/gram packed 200x123", || {
        gram.gram_scaled_from(&feat, &s);
        gram.data()[0]
    });

    // SPD solve: one-shot dense vs reusable packed factor.
    b.bench("sym/cholesky dense 123", || {
        cholesky_solve(&spd, &x).map(|v| v[0]).unwrap_or(f64::NAN)
    });
    let mut f = SymCholesky::new();
    let mut sol = Vec::new();
    b.bench("sym/cholesky packed 123", || {
        if f.factor_sym(&pspd).is_ok() {
            f.solve_into(&x, &mut sol);
        }
        sol.first().copied().unwrap_or(f64::NAN)
    });
}

/// Allocation-free `*_into` kernels vs their allocating counterparts (the
/// pairs `tests/packed_kernels.rs` pins bitwise-equal).
pub fn bench_into_group(b: &mut Bench, rng: &mut crate::rng::Rng) {
    use crate::basis::{BasisScratch, HessianBasis, SubspaceBasis};
    use crate::compressors::{CompressScratch, CompressorSpec};
    use crate::linalg::Mat;

    b.group("in-place kernels vs allocating (d=123, r=60)");
    let d = 123;
    let a = Mat::from_fn(d, d, |_, _| rng.normal());
    let mut out = Mat::default();
    b.bench("into/matmul alloc 123", || a.matmul(&a));
    b.bench("into/matmul into 123", || {
        a.matmul_into(&a, &mut out);
        out[(0, 0)]
    });
    b.bench("into/transpose alloc 123", || a.transpose());
    b.bench("into/transpose into 123", || {
        a.transpose_into(&mut out);
        out[(0, 0)]
    });

    let v = crate::basis::subspace::orthonormal_cols(d, 60, rng);
    let basis = SubspaceBasis::new(v);
    let mut h = Mat::from_fn(d, d, |_, _| rng.normal());
    h.symmetrize();
    let mut scratch = BasisScratch::default();
    let mut coeff = Mat::default();
    b.bench("into/encode alloc subspace", || basis.encode(&h));
    b.bench("into/encode into subspace", || {
        basis.encode_into(&h, &mut coeff, &mut scratch);
        coeff[(0, 0)]
    });
    let code = basis.encode(&h);
    let mut dec = Mat::default();
    b.bench("into/decode alloc subspace", || basis.decode(&code));
    b.bench("into/decode into subspace", || {
        basis.decode_into(&code, &mut dec, &mut scratch);
        dec[(0, 0)]
    });

    let comp = CompressorSpec::TopK(60).build_mat(code.rows());
    let mut r1 = rng.derive(7);
    b.bench("into/compress alloc topk:60", || comp.compress(&code, &mut r1));
    let mut cs = CompressScratch::default();
    let mut cout = Mat::default();
    let mut r2 = rng.derive(7);
    b.bench("into/compress into topk:60", || {
        let _cost = comp.compress_mat_into(&code, &mut cout, &mut cs, &mut r2);
        cout.data().first().copied().unwrap_or(f64::NAN)
    });
}

/// Steady-state second-order rounds over the pooled `Lockstep` transport:
/// after the warm-up phase these run with zero heap allocations per round
/// (pinned by `tests/alloc_regression.rs`), which the bytes column shows
/// directly when [`CountingAlloc`] is installed.
pub fn bench_round_group(b: &mut Bench) {
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::{
        build_split, estimate_smoothness, native_locals, run_one_round, Env, ServerState,
    };
    use crate::data::{FederatedDataset, SyntheticSpec};
    use crate::transport::{client_rngs, Lockstep};

    b.group("steady-state rounds (pooled lockstep; d=60, n=4, m=40/client)");
    let fed = FederatedDataset::synthetic(&SyntheticSpec {
        n_clients: 4,
        m_per_client: 40,
        dim: 60,
        intrinsic_dim: 10,
        noise: 0.0,
        seed: 77,
    });
    for (label, algorithm) in [("bl1", Algorithm::Bl1), ("fednl", Algorithm::FedNl)] {
        let cfg = RunConfig {
            algorithm,
            hess_comp: CompressorSpec::TopK(10),
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let locals = native_locals(&fed);
        let features: Vec<Option<crate::linalg::Mat>> =
            fed.clients.iter().map(|c| Some(c.a.clone())).collect();
        let smoothness = estimate_smoothness(&locals, cfg.lambda);
        let env = Env {
            locals: &locals,
            cfg: &cfg,
            d: fed.dim(),
            n: fed.n_clients(),
            smoothness,
            features,
            obs: crate::obs::Obs::noop(),
        };
        let Ok((mut server, clients)) = build_split(&env) else {
            println!("  (skipping round/{label}: split failed)");
            continue;
        };
        let mut transport = Lockstep::new(&locals, clients, client_rngs(cfg.seed, env.n))
            .with_pool(server.pool().cloned());
        let mut srv_rng = crate::rng::Rng::new(cfg.seed);
        let mut round = 0usize;
        b.bench(format!("round/{label} lockstep"), || {
            let bits = run_one_round(&env, server.as_mut(), &mut transport, round, &mut srv_rng)
                .map(|t| t.up_bits)
                .unwrap_or(f64::NAN);
            round += 1;
            bits
        });
    }
}

/// The wire-codec hot path: encode/decode of a realistic BL1 round's packet
/// set (d = 200) through `transport::codec` — the per-exchange work the
/// `Tcp` backend adds on top of the in-process backends. Encoding reuses a
/// scratch buffer (the `Session` steady state); decode allocates fresh
/// payload buffers by design.
pub fn bench_wire_group(b: &mut Bench, rng: &mut crate::rng::Rng) {
    use crate::compressors::BitCost;
    use crate::linalg::Mat;
    use crate::transport::codec::{decode_packet, encode_packet_into};
    use crate::transport::Packet;

    b.group("wire codec (BL1 round packets, d=200)");
    let d = 200;

    // Downlink: compressed model step + the lazy-gradient flag.
    let mut down = Packet::empty();
    down.push_vector("model_delta", (0..d).map(|_| rng.normal()).collect(), BitCost::floats(d));
    down.push_flags("xi", vec![true], BitCost::bits(1.0));

    // Uplink: TopK(30)-compressed Hessian coefficient matrix + gradient.
    let mut up = Packet::empty();
    up.push_matrix(
        "hess_delta",
        Mat::from_fn(d, d, |_, _| rng.normal()),
        BitCost { floats: 30.0, aux_bits: 480.0 },
    );
    up.push_vector("grad_coeff", (0..d).map(|_| rng.normal()).collect(), BitCost::floats(d));

    let mut buf = Vec::new();
    b.bench("wire/encode down d=200", || {
        buf.clear();
        let ok = encode_packet_into(&down, &mut buf).is_ok();
        (buf.len(), ok)
    });
    let mut buf_up = Vec::new();
    b.bench("wire/encode up 200x200", || {
        buf_up.clear();
        let ok = encode_packet_into(&up, &mut buf_up).is_ok();
        (buf_up.len(), ok)
    });

    let down_bytes = crate::transport::codec::encode_packet(&down).unwrap_or_default();
    let up_bytes = crate::transport::codec::encode_packet(&up).unwrap_or_default();
    b.bench("wire/decode down d=200", || {
        decode_packet(&down_bytes).map(|p| p.msgs.len()).unwrap_or(0)
    });
    b.bench("wire/decode up 200x200", || {
        decode_packet(&up_bytes).map(|p| p.msgs.len()).unwrap_or(0)
    });

    let mut rt = Vec::new();
    b.bench("wire/round-trip exchange d=200", || {
        rt.clear();
        let mut n = 0usize;
        if encode_packet_into(&down, &mut rt).is_ok() {
            n += decode_packet(&rt).map(|p| p.msgs.len()).unwrap_or(0);
        }
        rt.clear();
        if encode_packet_into(&up, &mut rt).is_ok() {
            n += decode_packet(&rt).map(|p| p.msgs.len()).unwrap_or(0);
        }
        n
    });
}

/// The `repro bench` suite. `keep` filters by group key: `sym` (packed vs
/// dense symmetric kernels), `into` (in-place vs allocating kernels),
/// `round` (steady-state pooled rounds), `wire` (byte codec encode/decode).
pub fn run_cli_suite(b: &mut Bench, keep: &dyn Fn(&str) -> bool) {
    // Fixed suite seed: bench inputs are reproducible across runs/machines.
    let bench_seed = 1;
    let mut rng = crate::rng::Rng::new(bench_seed);
    if keep("sym") {
        bench_sym_group(b, &mut rng);
    }
    if keep("into") {
        bench_into_group(b, &mut rng);
    }
    if keep("round") {
        bench_round_group(b);
    }
    if keep("wire") {
        bench_wire_group(b, &mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::quick();
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(bb(i) * i);
            }
            s
        });
        assert!(r.median.as_nanos() > 0);
        assert!(r.samples >= 5);
    }

    #[test]
    fn slower_work_measures_slower() {
        let mut b = Bench::quick();
        // black_box inside the loop so LLVM cannot closed-form the sum.
        let work = |n: u64| {
            let mut s = 0u64;
            for i in 0..n {
                s = s.wrapping_add(bb(i));
            }
            s
        };
        let fast = b.bench("fast", || work(100)).ns();
        let slow = b.bench("slow", || work(100_000)).ns();
        assert!(slow > fast * 5.0, "fast={fast}ns slow={slow}ns");
    }

    #[test]
    fn human_formatting() {
        let r = BenchResult {
            name: "x".into(),
            median: Duration::from_nanos(1500),
            mad: Duration::ZERO,
            iters: 1,
            samples: 1,
            bytes_per_iter: 0,
        };
        assert_eq!(r.human(), "1.50 µs");
    }

    #[test]
    fn json_report_shape() {
        let r = BenchResult {
            name: "group/case \"q\"".into(),
            median: Duration::from_nanos(1500),
            mad: Duration::from_nanos(10),
            iters: 7,
            samples: 3,
            bytes_per_iter: 42,
        };
        let json = json_report(&[r]);
        assert!(json.contains("\"schema\": \"bench-v1\""), "{json}");
        assert!(json.contains("\"name\": \"group/case \\\"q\\\"\""), "{json}");
        assert!(json.contains("\"ns_per_iter\": 1500.000"), "{json}");
        assert!(json.contains("\"iters\": 7"), "{json}");
        assert!(json.contains("\"bytes_per_iter\": 42"), "{json}");
        // Balanced braces/brackets (cheap well-formedness check).
        let opens = json.matches('{').count();
        assert_eq!(opens, json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn json_escape_control_chars() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\u000ad");
    }

    #[test]
    fn counting_alloc_counters_are_monotonic() {
        // Whether or not the wrapper is installed in this test binary, the
        // counters must never move backwards.
        let b0 = CountingAlloc::allocated_bytes();
        let c0 = CountingAlloc::allocation_count();
        let v: Vec<u64> = (0..1024).collect();
        assert_eq!(v.len(), 1024);
        assert!(CountingAlloc::allocated_bytes() >= b0);
        assert!(CountingAlloc::allocation_count() >= c0);
    }
}
