//! Sparsification compressors: Identity, Top-K (greedy, contractive),
//! Rand-K (random, unbiased) and the lazy Bernoulli compressor of App. A.8.

use super::{BitCost, CompressScratch, CompressorClass, MatCompressor, VecCompressor};
use crate::linalg::Mat;
use crate::rng::Rng;

/// Identity "compressor": sends everything, loses nothing.
///
/// Contractive with `δ = 1` and simultaneously unbiased with `ω = 0`;
/// we report it as unbiased (`ω = 0`), which yields stepsize 1 under both
/// stepsize rules.
#[derive(Clone, Copy, Debug, Default)]
pub struct Identity;

impl MatCompressor for Identity {
    fn compress(&self, a: &Mat, _rng: &mut Rng) -> (Mat, BitCost) {
        (a.clone(), BitCost::floats(a.rows() * a.cols()))
    }

    fn compress_mat_into(
        &self,
        a: &Mat,
        out: &mut Mat,
        _scratch: &mut CompressScratch,
        _rng: &mut Rng,
    ) -> BitCost {
        out.copy_from(a);
        BitCost::floats(a.rows() * a.cols())
    }

    fn class(&self, _numel: usize, _dim: usize) -> CompressorClass {
        CompressorClass::Unbiased { omega: 0.0 }
    }

    fn name(&self) -> String {
        "identity".into()
    }
}

impl VecCompressor for Identity {
    fn compress_vec(&self, x: &[f64], _rng: &mut Rng) -> (Vec<f64>, BitCost) {
        (x.to_vec(), BitCost::floats(x.len()))
    }

    fn compress_vec_into(
        &self,
        x: &[f64],
        out: &mut Vec<f64>,
        _scratch: &mut CompressScratch,
        _rng: &mut Rng,
    ) -> BitCost {
        out.clear();
        out.extend_from_slice(x);
        BitCost::floats(x.len())
    }

    fn class_vec(&self, _n: usize) -> CompressorClass {
        CompressorClass::Unbiased { omega: 0.0 }
    }

    fn name(&self) -> String {
        "identity".into()
    }
}

/// Greedy sparsifier Top-K (eq. 21): keep the `K` largest-magnitude entries.
///
/// Contractive with `δ = K/N` where `N` is the number of entries
/// (the paper's App. A.2 states `δ = d²/K` with the fraction inverted — an
/// obvious typo; the standard parameter is `K/d²`).
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    pub k: usize,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "TopK requires k ≥ 1");
        TopK { k }
    }

    fn top_indices(&self, data: &[f64]) -> Vec<usize> {
        let k = self.k.min(data.len());
        let mut idx: Vec<usize> = (0..data.len()).collect();
        // Partial selection: O(N) average via select_nth_unstable.
        idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
            data[b].abs().total_cmp(&data[a].abs())
        });
        idx.truncate(k);
        idx
    }

    fn apply(&self, data: &[f64]) -> (Vec<f64>, BitCost) {
        let k = self.k.min(data.len());
        let idx = self.top_indices(data);
        let mut out = vec![0.0; data.len()];
        for &i in &idx {
            out[i] = data[i];
        }
        (out, BitCost::floats(k) + BitCost::indices(k, data.len()))
    }

    /// [`TopK::top_indices`] into caller-owned storage (identical selection).
    fn top_indices_into(&self, data: &[f64], idx: &mut Vec<usize>) {
        let k = self.k.min(data.len());
        idx.clear();
        idx.extend(0..data.len());
        idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
            data[b].abs().total_cmp(&data[a].abs())
        });
        idx.truncate(k);
    }

    /// [`TopK::apply`] scattering into a caller-owned zeroed slice; returns
    /// the wire cost. `out` must already be `data.len()` zeros.
    fn scatter_into(&self, data: &[f64], out: &mut [f64], idx: &mut Vec<usize>) -> BitCost {
        let k = self.k.min(data.len());
        self.top_indices_into(data, idx);
        for &i in idx.iter() {
            out[i] = data[i];
        }
        BitCost::floats(k) + BitCost::indices(k, data.len())
    }
}

impl MatCompressor for TopK {
    fn compress(&self, a: &Mat, _rng: &mut Rng) -> (Mat, BitCost) {
        let (v, cost) = self.apply(a.data());
        (Mat::from_vec(a.rows(), a.cols(), v), cost)
    }

    fn compress_mat_into(
        &self,
        a: &Mat,
        out: &mut Mat,
        scratch: &mut CompressScratch,
        _rng: &mut Rng,
    ) -> BitCost {
        out.resize_zeroed(a.rows(), a.cols());
        self.scatter_into(a.data(), out.data_mut(), &mut scratch.idx)
    }

    fn class(&self, numel: usize, _dim: usize) -> CompressorClass {
        CompressorClass::Contractive { delta: (self.k as f64 / numel as f64).min(1.0) }
    }

    fn name(&self) -> String {
        format!("top{}", self.k)
    }
}

impl VecCompressor for TopK {
    fn compress_vec(&self, x: &[f64], _rng: &mut Rng) -> (Vec<f64>, BitCost) {
        self.apply(x)
    }

    fn compress_vec_into(
        &self,
        x: &[f64],
        out: &mut Vec<f64>,
        scratch: &mut CompressScratch,
        _rng: &mut Rng,
    ) -> BitCost {
        out.clear();
        out.resize(x.len(), 0.0);
        self.scatter_into(x, out, &mut scratch.idx)
    }

    fn class_vec(&self, n: usize) -> CompressorClass {
        CompressorClass::Contractive { delta: (self.k as f64 / n as f64).min(1.0) }
    }

    fn name(&self) -> String {
        format!("top{}", self.k)
    }
}

/// Random sparsifier Rand-K (eq. 22): keep `K` uniformly random entries,
/// scaled by `N/K`. Unbiased with `ω = N/K − 1`.
#[derive(Clone, Copy, Debug)]
pub struct RandK {
    pub k: usize,
}

impl RandK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "RandK requires k ≥ 1");
        RandK { k }
    }

    fn apply(&self, data: &[f64], rng: &mut Rng) -> (Vec<f64>, BitCost) {
        let n = data.len();
        let k = self.k.min(n);
        let scale = n as f64 / k as f64;
        let idx = rng.sample_without_replacement(n, k);
        let mut out = vec![0.0; n];
        for &i in &idx {
            out[i] = data[i] * scale;
        }
        // With shared randomness the indices are derivable from a seed, but we
        // charge them explicitly (conservative, matches the paper's plots where
        // Rand-K costs K floats + indices).
        (out, BitCost::floats(k) + BitCost::indices(k, n))
    }

    /// [`RandK::apply`] scattering into a caller-owned zeroed slice (identical
    /// RNG draws and values). `out` must already be `data.len()` zeros.
    fn scatter_into(&self, data: &[f64], out: &mut [f64], idx: &mut Vec<usize>, rng: &mut Rng) -> BitCost {
        let n = data.len();
        let k = self.k.min(n);
        let scale = n as f64 / k as f64;
        rng.sample_without_replacement_into(n, k, idx);
        for &i in idx.iter() {
            out[i] = data[i] * scale;
        }
        BitCost::floats(k) + BitCost::indices(k, n)
    }
}

impl MatCompressor for RandK {
    fn compress(&self, a: &Mat, rng: &mut Rng) -> (Mat, BitCost) {
        let (v, cost) = self.apply(a.data(), rng);
        (Mat::from_vec(a.rows(), a.cols(), v), cost)
    }

    fn compress_mat_into(
        &self,
        a: &Mat,
        out: &mut Mat,
        scratch: &mut CompressScratch,
        rng: &mut Rng,
    ) -> BitCost {
        out.resize_zeroed(a.rows(), a.cols());
        self.scatter_into(a.data(), out.data_mut(), &mut scratch.idx, rng)
    }

    fn class(&self, numel: usize, _dim: usize) -> CompressorClass {
        CompressorClass::Unbiased { omega: (numel as f64 / self.k as f64 - 1.0).max(0.0) }
    }

    fn name(&self) -> String {
        format!("rand{}", self.k)
    }
}

impl VecCompressor for RandK {
    fn compress_vec(&self, x: &[f64], rng: &mut Rng) -> (Vec<f64>, BitCost) {
        self.apply(x, rng)
    }

    fn compress_vec_into(
        &self,
        x: &[f64],
        out: &mut Vec<f64>,
        scratch: &mut CompressScratch,
        rng: &mut Rng,
    ) -> BitCost {
        out.clear();
        out.resize(x.len(), 0.0);
        self.scatter_into(x, out, &mut scratch.idx, rng)
    }

    fn class_vec(&self, n: usize) -> CompressorClass {
        CompressorClass::Unbiased { omega: (n as f64 / self.k as f64 - 1.0).max(0.0) }
    }

    fn name(&self) -> String {
        format!("rand{}", self.k)
    }
}

/// Lazy Bernoulli compressor (App. A.8): transmit `x/p` with probability `p`,
/// nothing otherwise. Unbiased with `ω = 1/p − 1`.
#[derive(Clone, Copy, Debug)]
pub struct LazyBernoulli {
    pub p: f64,
}

impl LazyBernoulli {
    pub fn new(p: f64) -> Self {
        assert!(p > 0.0 && p <= 1.0, "LazyBernoulli requires p ∈ (0, 1]");
        LazyBernoulli { p }
    }
}

impl VecCompressor for LazyBernoulli {
    fn compress_vec(&self, x: &[f64], rng: &mut Rng) -> (Vec<f64>, BitCost) {
        if rng.bernoulli(self.p) {
            (
                x.iter().map(|v| v / self.p).collect(),
                BitCost::floats(x.len()) + BitCost::bits(1.0),
            )
        } else {
            (vec![0.0; x.len()], BitCost::bits(1.0))
        }
    }

    fn class_vec(&self, _n: usize) -> CompressorClass {
        CompressorClass::Unbiased { omega: 1.0 / self.p - 1.0 }
    }

    fn name(&self) -> String {
        format!("bern{:.2}", self.p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::testing::{verify_class_mat, verify_class_vec};

    #[test]
    fn identity_roundtrip_and_cost() {
        let mut rng = Rng::new(1);
        let a = Mat::from_fn(3, 4, |i, j| (i + j) as f64);
        let (b, cost) = MatCompressor::compress(&Identity, &a, &mut rng);
        assert_eq!(a, b);
        assert_eq!(cost, BitCost::floats(12));
    }

    #[test]
    fn topk_keeps_largest() {
        let mut rng = Rng::new(2);
        let x = vec![0.1, -5.0, 2.0, 0.0, 3.0];
        let (y, cost) = TopK::new(2).compress_vec(&x, &mut rng);
        assert_eq!(y, vec![0.0, -5.0, 0.0, 0.0, 3.0]);
        assert_eq!(cost.floats, 2.0);
        assert!(cost.aux_bits > 0.0);
    }

    #[test]
    fn topk_k_larger_than_input() {
        let mut rng = Rng::new(3);
        let x = vec![1.0, 2.0];
        let (y, _) = TopK::new(10).compress_vec(&x, &mut rng);
        assert_eq!(y, x);
    }

    #[test]
    fn topk_contraction_is_exact_for_deterministic() {
        // For Top-K the error equals the squared norm of the dropped tail,
        // which is ≤ (1−K/N)‖x‖² with equality iff all |entries| equal.
        let mut rng = Rng::new(4);
        let x = vec![1.0; 8];
        let (y, _) = TopK::new(2).compress_vec(&x, &mut rng);
        let err: f64 = x.iter().zip(&y).map(|(a, b)| (a - b).powi(2)).sum();
        let bound = (1.0 - 2.0 / 8.0) * 8.0;
        assert!((err - bound).abs() < 1e-12);
    }

    #[test]
    fn topk_class_verified_empirically() {
        verify_class_mat(&TopK::new(5), 6, 3, 11);
        verify_class_vec(&TopK::new(3), 20, 12);
    }

    #[test]
    fn randk_unbiased_and_cost() {
        verify_class_mat(&RandK::new(8), 5, 3, 13);
        verify_class_vec(&RandK::new(4), 16, 14);
        let mut rng = Rng::new(5);
        let x = vec![1.0; 10];
        let (y, cost) = RandK::new(3).compress_vec(&x, &mut rng);
        assert_eq!(y.iter().filter(|&&v| v != 0.0).count(), 3);
        assert!(y.iter().all(|&v| v == 0.0 || (v - 10.0 / 3.0).abs() < 1e-12));
        assert_eq!(cost.floats, 3.0);
    }

    #[test]
    fn lazy_bernoulli_class() {
        verify_class_vec(&LazyBernoulli::new(0.5), 12, 15);
        verify_class_vec(&LazyBernoulli::new(1.0), 12, 16);
    }

    #[test]
    fn lazy_bernoulli_all_or_nothing() {
        let mut rng = Rng::new(6);
        let x = vec![2.0, 4.0];
        let c = LazyBernoulli::new(0.5);
        for _ in 0..50 {
            let (y, _) = c.compress_vec(&x, &mut rng);
            assert!(y == vec![0.0, 0.0] || y == vec![4.0, 8.0], "y={y:?}");
        }
    }

    #[test]
    #[should_panic]
    fn topk_zero_k_panics() {
        TopK::new(0);
    }

    #[test]
    #[should_panic]
    fn bernoulli_zero_p_panics() {
        LazyBernoulli::new(0.0);
    }
}
