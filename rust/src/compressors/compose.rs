//! Composed compressors (paper §3 and Qian et al. 2021):
//!
//! * [`ComposeRank`] — the paper's `C₁`: Rank-R decomposition with the
//!   retained singular-vector pairs passed through unbiased compressors
//!   (`RRank-R` = Rank-R ∘ random dithering, `NRank-R` = Rank-R ∘ natural
//!   compression). Contractive with `δ = R / (d(ω₁+1)(ω₂+1))`
//!   (Proposition 3.2).
//! * [`Compose`] — greedy-sparsifier composition: Top-K selects the support,
//!   an unbiased compressor quantizes the retained values, and the result is
//!   scaled by `1/(ω+1)` (`RTop-K`, `NTop-K` of App. A.5). Contractive with
//!   `δ = (K/N) / (ω+1)`.

use super::{BitCost, CompressorClass, MatCompressor, TopK, VecCompressor};
use crate::linalg::{svd, Mat};
use crate::rng::Rng;

/// `C₁` of §3: Rank-R with unbiased compression of the factor vectors.
pub struct ComposeRank<Q1, Q2> {
    pub r: usize,
    pub q_left: Q1,
    pub q_right: Q2,
}

impl<Q1: VecCompressor, Q2: VecCompressor> ComposeRank<Q1, Q2> {
    pub fn new(r: usize, q_left: Q1, q_right: Q2) -> Self {
        assert!(r > 0, "ComposeRank requires r ≥ 1");
        ComposeRank { r, q_left, q_right }
    }
}

impl<Q1: VecCompressor, Q2: VecCompressor> MatCompressor for ComposeRank<Q1, Q2> {
    fn compress(&self, a: &Mat, rng: &mut Rng) -> (Mat, BitCost) {
        let (m, n) = (a.rows(), a.cols());
        let d = m.min(n);
        let r = self.r.min(d);
        let dec = svd(a);

        let omega1 = match self.q_left.class_vec(m) {
            CompressorClass::Unbiased { omega } => omega,
            // audit:allow(panic-safety): type-level misuse (App. A.5 requires an unbiased factor); caught by every test that constructs one.
            _ => panic!("ComposeRank requires unbiased left compressor"),
        };
        let omega2 = match self.q_right.class_vec(n) {
            CompressorClass::Unbiased { omega } => omega,
            // audit:allow(panic-safety): same unbiasedness precondition as the left factor above.
            _ => panic!("ComposeRank requires unbiased right compressor"),
        };
        let scale = 1.0 / ((omega1 + 1.0) * (omega2 + 1.0));

        let mut out = Mat::zeros(m, n);
        let mut cost = BitCost::floats(r); // the σ_i
        // Reused column buffers — one fill per retained pair instead of a
        // fresh `Mat::col` vector per factor per iteration.
        let mut ucol = Vec::with_capacity(m);
        let mut vcol = Vec::with_capacity(n);
        for i in 0..r {
            let sigma = dec.s[i];
            if sigma == 0.0 {
                continue;
            }
            dec.u.col_into(i, &mut ucol);
            dec.v.col_into(i, &mut vcol);
            let (qu, cu) = self.q_left.compress_vec(&ucol, rng);
            let (qv, cv) = self.q_right.compress_vec(&vcol, rng);
            cost += cu;
            cost += cv;
            let f = sigma * scale;
            for row in 0..m {
                let urf = qu[row] * f;
                if urf == 0.0 {
                    continue;
                }
                for colj in 0..n {
                    out[(row, colj)] += urf * qv[colj];
                }
            }
        }
        (out, cost)
    }

    fn class(&self, _numel: usize, dim: usize) -> CompressorClass {
        let omega1 = match self.q_left.class_vec(dim) {
            CompressorClass::Unbiased { omega } => omega,
            _ => unreachable!(),
        };
        let omega2 = match self.q_right.class_vec(dim) {
            CompressorClass::Unbiased { omega } => omega,
            _ => unreachable!(),
        };
        CompressorClass::Contractive {
            delta: (self.r as f64 / (dim as f64 * (omega1 + 1.0) * (omega2 + 1.0))).min(1.0),
        }
    }

    fn name(&self) -> String {
        format!("rank{}∘{}", self.r, self.q_left.name())
    }
}

/// Top-K support selection + unbiased quantization of the retained values,
/// output scaled by `1/(ω+1)` so the composition stays contractive
/// (App. A.5; Qian et al. 2021).
pub struct Compose<Q> {
    pub top: TopK,
    pub q: Q,
}

impl<Q: VecCompressor> Compose<Q> {
    pub fn new(k: usize, q: Q) -> Self {
        Compose { top: TopK::new(k), q }
    }

    fn apply(&self, data: &[f64], rng: &mut Rng) -> (Vec<f64>, BitCost) {
        let n = data.len();
        let k = self.top.k.min(n);
        // Select support.
        let mut idx: Vec<usize> = (0..n).collect();
        idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
            data[b].abs().total_cmp(&data[a].abs())
        });
        idx.truncate(k);
        let values: Vec<f64> = idx.iter().map(|&i| data[i]).collect();
        // Quantize the retained values.
        let omega = match self.q.class_vec(k) {
            CompressorClass::Unbiased { omega } => omega,
            // audit:allow(panic-safety): contractiveness of Top-K ∘ Q (App. A.5) needs unbiased Q; construction-time invariant.
            _ => panic!("Compose requires an unbiased value compressor"),
        };
        let (qv, qcost) = self.q.compress_vec(&values, rng);
        let scale = 1.0 / (omega + 1.0);
        let mut out = vec![0.0; n];
        for (&i, &v) in idx.iter().zip(&qv) {
            out[i] = v * scale;
        }
        (out, BitCost::indices(k, n) + qcost)
    }
}

impl<Q: VecCompressor> MatCompressor for Compose<Q> {
    fn compress(&self, a: &Mat, rng: &mut Rng) -> (Mat, BitCost) {
        let (v, cost) = self.apply(a.data(), rng);
        (Mat::from_vec(a.rows(), a.cols(), v), cost)
    }

    fn class(&self, numel: usize, _dim: usize) -> CompressorClass {
        let omega = match self.q.class_vec(self.top.k.min(numel)) {
            CompressorClass::Unbiased { omega } => omega,
            _ => unreachable!(),
        };
        CompressorClass::Contractive {
            delta: ((self.top.k as f64 / numel as f64) / (omega + 1.0)).min(1.0),
        }
    }

    fn name(&self) -> String {
        format!("top{}∘{}", self.top.k, self.q.name())
    }
}

impl<Q: VecCompressor> VecCompressor for Compose<Q> {
    fn compress_vec(&self, x: &[f64], rng: &mut Rng) -> (Vec<f64>, BitCost) {
        self.apply(x, rng)
    }

    fn class_vec(&self, n: usize) -> CompressorClass {
        MatCompressor::class(self, n, n)
    }

    fn name(&self) -> String {
        MatCompressor::name(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::testing::{verify_class_mat, verify_class_vec};
    use crate::compressors::{NaturalCompression, RandDithering};

    #[test]
    fn compose_rank_contraction_prop_3_2() {
        // RRank-1 and NRank-1 on small matrices.
        let c = ComposeRank::new(1, RandDithering::new(3), RandDithering::new(3));
        verify_class_mat(&c, 5, 2, 51);
        let n = ComposeRank::new(2, NaturalCompression, NaturalCompression);
        verify_class_mat(&n, 6, 2, 52);
    }

    #[test]
    fn compose_rank_identityish_with_weak_quantizer() {
        // With many dithering levels the composition approaches plain Rank-R.
        let mut rng = Rng::new(16);
        let a = Mat::outer(&[1.0, 2.0, 0.5], &[1.0, -1.0, 2.0]);
        let c = ComposeRank::new(1, RandDithering::new(1 << 14), RandDithering::new(1 << 14));
        let (b, _) = c.compress(&a, &mut rng);
        // Rank-1 input: expect near-exact recovery up to the 1/(ω+1)² scale ≈ 1.
        let rel = (&b - &a).fro_norm() / a.fro_norm();
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn compose_topk_class() {
        let c = Compose::new(4, RandDithering::new(2));
        verify_class_mat(&c, 5, 2, 53);
        verify_class_vec(&c, 18, 54);
        let n = Compose::new(3, NaturalCompression);
        verify_class_vec(&n, 12, 55);
    }

    #[test]
    fn compose_topk_support_is_topk() {
        let mut rng = Rng::new(17);
        let x = vec![10.0, 0.1, -9.0, 0.2, 8.0];
        let c = Compose::new(3, NaturalCompression);
        let (y, _) = c.compress_vec(&x, &mut rng);
        assert!(y[1] == 0.0 && y[3] == 0.0);
        assert!(y[0] != 0.0 && y[2] != 0.0 && y[4] != 0.0);
    }

    #[test]
    fn compose_cost_cheaper_than_plain_floats() {
        // NTop-K sends 9 bits/value instead of 64 — the whole point of A.5.
        let mut rng = Rng::new(18);
        let x: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let k = 20;
        let (_, c_plain) = TopK::new(k).compress_vec(&x, &mut rng);
        let nc = Compose::new(k, NaturalCompression);
        let (_, c_nat) = nc.compress_vec(&x, &mut rng);
        assert!(c_nat.total_bits(64) < c_plain.total_bits(64));
    }
}
