//! Rank-R low-rank approximation compressor (App. A.2 eq. 19–20).
//!
//! `C(X) = Σ_{i≤R} σ_i u_i v_iᵀ` — contractive with `δ = R/d` for `d×d`
//! inputs [Safaryan et al. 2021]. Symmetric inputs go through the symmetric
//! eigendecomposition (cheaper and exactly symmetric output, which matters
//! for Hessian learning); general inputs through one-sided Jacobi SVD.
//!
//! Wire cost: `R · (2d + 1)` floats (`u_i`, `v_i`, `σ_i` per retained pair);
//! for symmetric inputs `R · (d + 1)` (`v_i`, `λ_i`).

use super::{BitCost, CompressorClass, MatCompressor};
use crate::linalg::{svd, sym_eigen, Mat};
use crate::rng::Rng;

/// Rank-R compressor.
#[derive(Clone, Copy, Debug)]
pub struct RankR {
    pub r: usize,
}

impl RankR {
    pub fn new(r: usize) -> Self {
        assert!(r > 0, "RankR requires r ≥ 1");
        RankR { r }
    }
}

impl MatCompressor for RankR {
    fn compress(&self, a: &Mat, _rng: &mut Rng) -> (Mat, BitCost) {
        let d = a.rows().min(a.cols());
        let r = self.r.min(d);
        if a.is_symmetric(0.0) {
            // Fast path (§Perf L3-2): for small r, subspace iteration finds
            // the top-|λ| pairs in O(r·d²·iters) instead of full Jacobi's
            // O(d³·sweeps). The result is only accepted if it certifiably
            // satisfies the contraction inequality ‖A−B‖²_F ≤ (1−r/d)‖A‖²_F
            // — so the compressor's advertised class holds unconditionally —
            // and we fall back to exact Jacobi otherwise (clustered
            // semicircle-like spectra where the iteration stalls).
            if let Some((vals, vecs)) = crate::linalg::top_eigenpairs(a, r, 150, 1e-6) {
                let n = a.rows();
                let mut out = Mat::zeros(n, n);
                for k in 0..r {
                    let lam = vals[k];
                    if lam == 0.0 {
                        continue;
                    }
                    for i in 0..n {
                        let f = lam * vecs[(i, k)];
                        if f == 0.0 {
                            continue;
                        }
                        for j in 0..n {
                            out[(i, j)] += f * vecs[(j, k)];
                        }
                    }
                }
                let delta = r as f64 / a.rows() as f64;
                if (&out - a).fro_norm_sq() <= (1.0 - delta) * a.fro_norm_sq() + 1e-300 {
                    return (out, BitCost::floats(r * (n + 1)));
                }
            }
            let e = sym_eigen(a);
            let out = e.rank_r(r);
            (out, BitCost::floats(r * (a.rows() + 1)))
        } else {
            let s = svd(a);
            let out = s.truncate(r);
            (out, BitCost::floats(r * (a.rows() + a.cols() + 1)))
        }
    }

    fn class(&self, _numel: usize, dim: usize) -> CompressorClass {
        CompressorClass::Contractive { delta: (self.r as f64 / dim as f64).min(1.0) }
    }

    fn name(&self) -> String {
        format!("rank{}", self.r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::testing::verify_class_mat;
    use crate::rng::Rng;

    #[test]
    fn exact_when_r_geq_rank() {
        let mut rng = Rng::new(12);
        let a = Mat::outer(&[1.0, 2.0, 3.0], &[1.0, -1.0, 0.5]);
        let (b, _) = RankR::new(1).compress(&a, &mut rng);
        assert!((&b - &a).fro_norm() < 1e-10);
        let (c, _) = RankR::new(3).compress(&a, &mut rng);
        assert!((&c - &a).fro_norm() < 1e-10);
    }

    #[test]
    fn symmetric_output_is_symmetric() {
        let mut rng = Rng::new(13);
        let mut a = Mat::from_fn(8, 8, |_, _| rng.normal());
        a.symmetrize();
        let (b, cost) = RankR::new(2).compress(&a, &mut rng);
        assert!(b.is_symmetric(1e-12));
        assert_eq!(cost.floats, 2.0 * 9.0); // r(d+1)
    }

    #[test]
    fn general_cost_formula() {
        let mut rng = Rng::new(14);
        let a = Mat::from_fn(6, 4, |_, _| rng.normal());
        let (_, cost) = RankR::new(2).compress(&a, &mut rng);
        assert_eq!(cost.floats, 2.0 * (6.0 + 4.0 + 1.0));
    }

    #[test]
    fn contraction_class_empirical() {
        verify_class_mat(&RankR::new(2), 7, 3, 41);
        verify_class_mat(&RankR::new(1), 5, 3, 42);
    }

    #[test]
    fn error_decreases_with_rank() {
        let mut rng = Rng::new(15);
        let mut a = Mat::from_fn(10, 10, |_, _| rng.normal());
        a.symmetrize();
        let mut prev = f64::INFINITY;
        for r in 1..=10 {
            let (b, _) = RankR::new(r).compress(&a, &mut rng);
            let err = (&b - &a).fro_norm();
            assert!(err <= prev + 1e-10, "rank {r}: err={err} prev={prev}");
            prev = err;
        }
        assert!(prev < 1e-9, "full rank should be exact");
    }
}
