//! Communication-compression operators (paper §3 and Appendix A.2–A.3).
//!
//! Two classes, exactly as in the paper:
//!
//! * **contractive** — `E‖A − C(A)‖²_F ≤ (1−δ)‖A‖²_F` (eq. 6): Top-K, Rank-R,
//!   and compositions of a contractive with an unbiased compressor;
//! * **unbiased** — `E C(A) = A`, `E‖C(A)‖²_F ≤ (ω+1)‖A‖²_F` (eq. 7): Rand-K,
//!   random dithering, natural compression, lazy Bernoulli.
//!
//! Every compressor reports an exact [`BitCost`] for its wire encoding, which
//! is what the paper's x-axes ("communicated bits per node") plot.
//!
//! Compressors implement [`MatCompressor`] and/or [`VecCompressor`]. A matrix
//! compressor can always be used on vectors (a vector is a `d×1` matrix) and
//! vice-versa via [`MatFromVec`]; symmetry is preserved through the
//! [`Symmetrized`] wrapper (Lemma 3.1).

mod basic;
mod compose;
mod lowrank;
mod quantize;
mod spec;

pub use basic::{Identity, LazyBernoulli, RandK, TopK};
pub use compose::{Compose, ComposeRank};
pub use lowrank::RankR;
pub use quantize::{NaturalCompression, RandDithering};
pub use spec::CompressorSpec;

use crate::linalg::Mat;
use crate::rng::Rng;

/// Exact wire-size accounting for one compressed message.
///
/// `floats` are full-precision values (counted at the configured float width,
/// 32 or 64 bits — the paper plots use 64-bit doubles via NumPy, and we default
/// to the same); `aux_bits` are exact bit counts for indices, signs, exponents
/// and quantization levels.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BitCost {
    /// Number of full-precision floats on the wire.
    pub floats: f64,
    /// Exact auxiliary bits (indices, signs, levels, exponents).
    pub aux_bits: f64,
}

impl BitCost {
    /// Cost of `n` raw floats.
    pub fn floats(n: usize) -> Self {
        BitCost { floats: n as f64, aux_bits: 0.0 }
    }

    /// Cost of `n` indices drawn from a universe of size `range`.
    pub fn indices(n: usize, range: usize) -> Self {
        let bits_per = (range.max(2) as f64).log2().ceil();
        BitCost { floats: 0.0, aux_bits: n as f64 * bits_per }
    }

    /// Raw auxiliary bits.
    pub fn bits(b: f64) -> Self {
        BitCost { floats: 0.0, aux_bits: b }
    }

    /// Zero cost (nothing sent).
    pub fn zero() -> Self {
        BitCost::default()
    }

    /// Total bits at a given float width.
    pub fn total_bits(&self, float_bits: u32) -> f64 {
        self.floats * float_bits as f64 + self.aux_bits
    }
}

impl std::ops::Add for BitCost {
    type Output = BitCost;
    fn add(self, other: BitCost) -> BitCost {
        BitCost {
            floats: self.floats + other.floats,
            aux_bits: self.aux_bits + other.aux_bits,
        }
    }
}

impl std::ops::AddAssign for BitCost {
    fn add_assign(&mut self, other: BitCost) {
        self.floats += other.floats;
        self.aux_bits += other.aux_bits;
    }
}

/// Compressor class with its theoretical parameter, at a given input size.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompressorClass {
    /// `E‖A − C(A)‖² ≤ (1−δ)‖A‖²`.
    Contractive { delta: f64 },
    /// `E C(A) = A`, `E‖C(A)‖² ≤ (ω+1)‖A‖²`.
    Unbiased { omega: f64 },
}

impl CompressorClass {
    /// The paper's default learning rate for Hessian learning:
    /// `α = 1` for contractive, `α = 1/(ω+1)` for unbiased (Asm. 4.5/4.6).
    pub fn default_stepsize(&self) -> f64 {
        match self {
            CompressorClass::Contractive { .. } => 1.0,
            CompressorClass::Unbiased { omega } => 1.0 / (omega + 1.0),
        }
    }
}

/// Caller-owned scratch for the allocation-free compressor paths
/// ([`MatCompressor::compress_mat_into`] /
/// [`VecCompressor::compress_vec_into`]).
#[derive(Default)]
pub struct CompressScratch {
    /// Index workspace (Top-K selection, Rand-K sampling).
    pub idx: Vec<usize>,
}

/// Compressor acting on matrices.
pub trait MatCompressor: Send + Sync {
    /// Compress `a`, returning the decompressed-at-receiver matrix and its
    /// wire cost.
    fn compress(&self, a: &Mat, rng: &mut Rng) -> (Mat, BitCost);

    /// [`MatCompressor::compress`] into caller-owned storage. Implementations
    /// must be bit-identical to `compress` (same RNG draws, same values); the
    /// default delegates (and therefore still allocates) — hot compressors
    /// override it.
    fn compress_mat_into(
        &self,
        a: &Mat,
        out: &mut Mat,
        scratch: &mut CompressScratch,
        rng: &mut Rng,
    ) -> BitCost {
        let _ = scratch;
        let (c, cost) = self.compress(a, rng);
        out.copy_from(&c);
        cost
    }

    /// Theoretical class/parameter for an input with `numel` entries
    /// (`d²` for `d×d` matrices) and leading dimension `dim`.
    fn class(&self, numel: usize, dim: usize) -> CompressorClass;

    /// Human-readable name (used in experiment CSV headers).
    fn name(&self) -> String;
}

/// Compressor acting on vectors.
pub trait VecCompressor: Send + Sync {
    /// Compress `x`, returning the decompressed vector and its wire cost.
    fn compress_vec(&self, x: &[f64], rng: &mut Rng) -> (Vec<f64>, BitCost);

    /// [`VecCompressor::compress_vec`] into caller-owned storage (same
    /// bit-identity contract as [`MatCompressor::compress_mat_into`]).
    fn compress_vec_into(
        &self,
        x: &[f64],
        out: &mut Vec<f64>,
        scratch: &mut CompressScratch,
        rng: &mut Rng,
    ) -> BitCost {
        let _ = scratch;
        let (c, cost) = self.compress_vec(x, rng);
        out.clear();
        out.extend_from_slice(&c);
        cost
    }

    /// Theoretical class/parameter for a length-`n` input.
    fn class_vec(&self, n: usize) -> CompressorClass;

    /// Human-readable name.
    fn name(&self) -> String;
}

/// Symmetrization wrapper (paper Lemma 3.1): `C̃(A) = (C(A) + C(A)ᵀ)/2` for
/// symmetric inputs. Preserves the contraction parameter δ; the wire cost is
/// unchanged (the receiver symmetrizes locally).
pub struct Symmetrized<C>(pub C);

impl<C: MatCompressor> MatCompressor for Symmetrized<C> {
    fn compress(&self, a: &Mat, rng: &mut Rng) -> (Mat, BitCost) {
        let (mut c, cost) = self.0.compress(a, rng);
        if a.is_symmetric(0.0) {
            c.symmetrize();
        }
        (c, cost)
    }

    fn compress_mat_into(
        &self,
        a: &Mat,
        out: &mut Mat,
        scratch: &mut CompressScratch,
        rng: &mut Rng,
    ) -> BitCost {
        let cost = self.0.compress_mat_into(a, out, scratch, rng);
        if a.is_symmetric(0.0) {
            out.symmetrize();
        }
        cost
    }

    fn class(&self, numel: usize, dim: usize) -> CompressorClass {
        self.0.class(numel, dim)
    }

    fn name(&self) -> String {
        format!("sym({})", self.0.name())
    }
}

/// Adapter: use any [`MatCompressor`] on a vector (treated as `n×1`).
pub struct MatFromVec<C>(pub C);

impl<C: VecCompressor> MatCompressor for MatFromVec<C> {
    fn compress(&self, a: &Mat, rng: &mut Rng) -> (Mat, BitCost) {
        let (v, cost) = self.0.compress_vec(a.data(), rng);
        (Mat::from_vec(a.rows(), a.cols(), v), cost)
    }

    fn class(&self, numel: usize, _dim: usize) -> CompressorClass {
        self.0.class_vec(numel)
    }

    fn name(&self) -> String {
        self.0.name()
    }
}

#[cfg(test)]
pub(crate) mod testing {
    //! Shared empirical-verification helpers used by every compressor's
    //! tests: Monte-Carlo checks of the contraction inequality (6) and the
    //! unbiasedness/variance inequality (7).

    use super::*;

    /// Empirically verify a compressor's advertised class on random inputs.
    pub fn verify_class_mat(c: &dyn MatCompressor, dim: usize, trials: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let cls = c.class(dim * dim, dim);
        for t in 0..trials {
            let a = Mat::from_fn(dim, dim, |_, _| rng.normal());
            verify_one_mat(c, &a, cls, 400, seed ^ (t as u64 + 1));
        }
        // Also on a symmetric input (the algorithms compress Hessian diffs).
        let mut s = Mat::from_fn(dim, dim, |_, _| rng.normal());
        s.symmetrize();
        verify_one_mat(c, &s, cls, 400, seed ^ 0xABCD);
    }

    fn verify_one_mat(c: &dyn MatCompressor, a: &Mat, cls: CompressorClass, reps: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let norm_sq = a.fro_norm_sq().max(1e-30);
        let mut err_sq = 0.0;
        let mut out_sq = 0.0;
        let mut mean = Mat::zeros(a.rows(), a.cols());
        for _ in 0..reps {
            let (ca, _) = c.compress(a, &mut rng);
            err_sq += (&ca - a).fro_norm_sq();
            out_sq += ca.fro_norm_sq();
            mean.add_scaled(1.0 / reps as f64, &ca);
        }
        err_sq /= reps as f64;
        out_sq /= reps as f64;
        match cls {
            CompressorClass::Contractive { delta } => {
                // Allow Monte-Carlo slack.
                assert!(
                    err_sq <= (1.0 - delta) * norm_sq * 1.12 + 1e-12,
                    "{}: contraction violated: E err² {err_sq:.4} > (1-δ)‖A‖² {:.4}",
                    c.name(),
                    (1.0 - delta) * norm_sq
                );
            }
            CompressorClass::Unbiased { omega } => {
                let bias = (&mean - a).fro_norm() / norm_sq.sqrt();
                assert!(
                    bias < 0.35,
                    "{}: bias too large: {bias:.4} (reps={reps})",
                    c.name()
                );
                assert!(
                    out_sq <= (omega + 1.0) * norm_sq * 1.15 + 1e-12,
                    "{}: second moment violated: E‖C‖² {out_sq:.4} > (ω+1)‖A‖² {:.4}",
                    c.name(),
                    (omega + 1.0) * norm_sq
                );
            }
        }
    }

    pub fn verify_class_vec(c: &dyn VecCompressor, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let cls = c.class_vec(n);
        let norm_sq = crate::linalg::norm2_sq(&x).max(1e-30);
        let reps = 600;
        let mut err_sq = 0.0;
        let mut out_sq = 0.0;
        let mut mean = vec![0.0; n];
        for _ in 0..reps {
            let (cx, _) = c.compress_vec(&x, &mut rng);
            err_sq += crate::linalg::norm2_sq(&crate::linalg::sub(&cx, &x));
            out_sq += crate::linalg::norm2_sq(&cx);
            crate::linalg::axpy(1.0 / reps as f64, &cx, &mut mean);
        }
        err_sq /= reps as f64;
        out_sq /= reps as f64;
        match cls {
            CompressorClass::Contractive { delta } => {
                assert!(
                    err_sq <= (1.0 - delta) * norm_sq * 1.12 + 1e-12,
                    "{}: vec contraction violated",
                    c.name()
                );
            }
            CompressorClass::Unbiased { omega } => {
                let bias = crate::linalg::norm2(&crate::linalg::sub(&mean, &x)) / norm_sq.sqrt();
                assert!(bias < 0.35, "{}: vec bias {bias:.4}", c.name());
                assert!(
                    out_sq <= (omega + 1.0) * norm_sq * 1.15 + 1e-12,
                    "{}: vec second moment violated ({out_sq:.4} vs {:.4})",
                    c.name(),
                    (omega + 1.0) * norm_sq
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitcost_arithmetic() {
        let a = BitCost::floats(3) + BitCost::indices(4, 256);
        assert_eq!(a.floats, 3.0);
        assert_eq!(a.aux_bits, 32.0);
        assert_eq!(a.total_bits(64), 3.0 * 64.0 + 32.0);
        assert_eq!(a.total_bits(32), 3.0 * 32.0 + 32.0);
        let mut b = BitCost::zero();
        b += a;
        assert_eq!(b, a);
    }

    #[test]
    fn index_cost_rounds_up() {
        assert_eq!(BitCost::indices(1, 2).aux_bits, 1.0);
        assert_eq!(BitCost::indices(1, 3).aux_bits, 2.0);
        assert_eq!(BitCost::indices(1, 1024).aux_bits, 10.0);
        assert_eq!(BitCost::indices(1, 1025).aux_bits, 11.0);
    }

    #[test]
    fn default_stepsize_rules() {
        let c = CompressorClass::Contractive { delta: 0.25 };
        assert_eq!(c.default_stepsize(), 1.0);
        let u = CompressorClass::Unbiased { omega: 3.0 };
        assert!((u.default_stepsize() - 0.25).abs() < 1e-15);
    }

    #[test]
    fn symmetrized_preserves_symmetry() {
        let mut rng = Rng::new(21);
        let mut a = Mat::from_fn(6, 6, |_, _| rng.normal());
        a.symmetrize();
        let c = Symmetrized(RandK::new(7));
        let (out, _) = c.compress(&a, &mut rng);
        assert!(out.is_symmetric(1e-12));
    }

    #[test]
    fn symmetrized_contraction_lemma_3_1() {
        // Lemma 3.1(ii): symmetrization keeps the contraction parameter.
        let c = Symmetrized(TopK::new(6));
        testing::verify_class_mat(&c, 5, 3, 99);
    }
}
