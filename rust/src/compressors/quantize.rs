//! Quantization compressors: random dithering (QSGD-style, App. A.2 eq. 17–18)
//! and natural compression (power-of-two rounding).

use super::{BitCost, CompressorClass, MatCompressor, VecCompressor};
use crate::linalg::Mat;
use crate::rng::Rng;

/// Random dithering with `s` levels and the Euclidean norm (`q = 2`),
/// eq. (17)–(18):
///
/// `C(x) = sign(x) · ‖x‖₂ · ξ_s / s`, where `ξ_s[i] ∈ {l, l+1}` randomly
/// rounds `s·|x_i|/‖x‖` to a neighbouring level.
///
/// Unbiased with `ω ≤ min(d/s², √d/s)` (Alistarh et al. 2017). Wire cost:
/// one float for the norm plus `(1 + ⌈log₂(s+1)⌉)` bits per entry
/// (sign + level).
#[derive(Clone, Copy, Debug)]
pub struct RandDithering {
    /// Number of quantization levels `s ≥ 1`.
    pub levels: u32,
}

impl RandDithering {
    pub fn new(levels: u32) -> Self {
        assert!(levels >= 1, "dithering needs at least one level");
        RandDithering { levels }
    }

    /// The paper's default: `s = √d` levels for dimension-`d` inputs.
    pub fn sqrt_dim(d: usize) -> Self {
        RandDithering::new((d as f64).sqrt().round().max(1.0) as u32)
    }

    fn apply(&self, x: &[f64], rng: &mut Rng) -> (Vec<f64>, BitCost) {
        let norm = crate::linalg::norm2(x);
        if norm == 0.0 {
            // Still costs the norm float (the receiver must learn it is 0).
            return (vec![0.0; x.len()], BitCost::floats(1));
        }
        let s = self.levels as f64;
        let out = x
            .iter()
            .map(|&xi| {
                let y = xi.abs() / norm * s; // in [0, s]
                let l = y.floor();
                let level = if rng.uniform() < y - l { l + 1.0 } else { l };
                xi.signum() * norm * level / s
            })
            .collect();
        let bits_per_entry = 1.0 + ((self.levels + 1) as f64).log2().ceil();
        (out, BitCost::floats(1) + BitCost::bits(bits_per_entry * x.len() as f64))
    }

    fn omega(&self, n: usize) -> f64 {
        let s = self.levels as f64;
        let d = n as f64;
        (d / (s * s)).min(d.sqrt() / s)
    }
}

impl VecCompressor for RandDithering {
    fn compress_vec(&self, x: &[f64], rng: &mut Rng) -> (Vec<f64>, BitCost) {
        self.apply(x, rng)
    }

    fn class_vec(&self, n: usize) -> CompressorClass {
        CompressorClass::Unbiased { omega: self.omega(n) }
    }

    fn name(&self) -> String {
        format!("dith{}", self.levels)
    }
}

impl MatCompressor for RandDithering {
    fn compress(&self, a: &Mat, rng: &mut Rng) -> (Mat, BitCost) {
        let (v, cost) = self.apply(a.data(), rng);
        (Mat::from_vec(a.rows(), a.cols(), v), cost)
    }

    fn class(&self, numel: usize, _dim: usize) -> CompressorClass {
        CompressorClass::Unbiased { omega: self.omega(numel) }
    }

    fn name(&self) -> String {
        format!("dith{}", self.levels)
    }
}

/// Natural compression: randomized rounding of each entry to one of the two
/// nearest powers of two. Unbiased with `ω = 1/8`; wire cost 9 bits per entry
/// (sign + 8-bit exponent), 0-entries included.
#[derive(Clone, Copy, Debug, Default)]
pub struct NaturalCompression;

impl NaturalCompression {
    fn round_one(&self, x: f64, rng: &mut Rng) -> f64 {
        if x == 0.0 || !x.is_finite() {
            return x;
        }
        let a = x.abs();
        let lo_exp = a.log2().floor();
        let lo = lo_exp.exp2();
        let hi = 2.0 * lo;
        // P(round up) = (a − lo)/(hi − lo): unbiased.
        let p_up = (a - lo) / (hi - lo);
        let mag = if rng.uniform() < p_up { hi } else { lo };
        x.signum() * mag
    }

    fn apply(&self, x: &[f64], rng: &mut Rng) -> (Vec<f64>, BitCost) {
        let out = x.iter().map(|&v| self.round_one(v, rng)).collect();
        (out, BitCost::bits(9.0 * x.len() as f64))
    }
}

impl VecCompressor for NaturalCompression {
    fn compress_vec(&self, x: &[f64], rng: &mut Rng) -> (Vec<f64>, BitCost) {
        self.apply(x, rng)
    }

    fn class_vec(&self, _n: usize) -> CompressorClass {
        CompressorClass::Unbiased { omega: 0.125 }
    }

    fn name(&self) -> String {
        "nat".into()
    }
}

impl MatCompressor for NaturalCompression {
    fn compress(&self, a: &Mat, rng: &mut Rng) -> (Mat, BitCost) {
        let (v, cost) = self.apply(a.data(), rng);
        (Mat::from_vec(a.rows(), a.cols(), v), cost)
    }

    fn class(&self, _numel: usize, _dim: usize) -> CompressorClass {
        CompressorClass::Unbiased { omega: 0.125 }
    }

    fn name(&self) -> String {
        "nat".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::testing::{verify_class_mat, verify_class_vec};

    #[test]
    fn dithering_class_empirical() {
        verify_class_vec(&RandDithering::new(4), 16, 31);
        verify_class_vec(&RandDithering::new(1), 9, 32);
        verify_class_mat(&RandDithering::new(3), 5, 2, 33);
    }

    #[test]
    fn dithering_output_on_grid() {
        let mut rng = Rng::new(7);
        let x = vec![0.3, -1.2, 0.7, 2.0];
        let norm = crate::linalg::norm2(&x);
        let c = RandDithering::new(4);
        for _ in 0..20 {
            let (y, _) = c.compress_vec(&x, &mut rng);
            for (&yi, &xi) in y.iter().zip(&x) {
                // Each output is sign(x)·norm·level/4 for an integer level.
                let level = yi.abs() * 4.0 / norm;
                assert!((level - level.round()).abs() < 1e-10, "level={level}");
                assert!(yi == 0.0 || yi.signum() == xi.signum());
            }
        }
    }

    #[test]
    fn dithering_zero_vector() {
        let mut rng = Rng::new(8);
        let (y, cost) = RandDithering::new(4).compress_vec(&[0.0, 0.0], &mut rng);
        assert_eq!(y, vec![0.0, 0.0]);
        assert_eq!(cost.floats, 1.0);
    }

    #[test]
    fn sqrt_dim_constructor() {
        assert_eq!(RandDithering::sqrt_dim(100).levels, 10);
        assert_eq!(RandDithering::sqrt_dim(1).levels, 1);
    }

    #[test]
    fn natural_rounds_to_power_of_two() {
        let mut rng = Rng::new(9);
        let c = NaturalCompression;
        for &x in &[0.3, -1.7, 5.0, 1e-8, -3e6] {
            for _ in 0..10 {
                let y = c.round_one(x, &mut rng);
                let frac = y.abs().log2();
                assert!((frac - frac.round()).abs() < 1e-12, "y={y} not a power of two");
                assert_eq!(y.signum(), x.signum());
                // Within a factor of two of the input.
                assert!(y.abs() >= x.abs() / 2.0 - 1e-300 && y.abs() <= x.abs() * 2.0 + 1e-300);
            }
        }
    }

    #[test]
    fn natural_exact_on_powers_of_two() {
        let mut rng = Rng::new(10);
        let c = NaturalCompression;
        for &x in &[1.0, 2.0, 0.5, -4.0, 1024.0] {
            assert_eq!(c.round_one(x, &mut rng), x);
        }
    }

    #[test]
    fn natural_class_empirical() {
        verify_class_vec(&NaturalCompression, 16, 34);
        verify_class_mat(&NaturalCompression, 5, 2, 35);
    }

    #[test]
    fn natural_cost_is_9_bits_per_entry() {
        let mut rng = Rng::new(11);
        let (_, cost) = NaturalCompression.compress_vec(&[1.0; 10], &mut rng);
        assert_eq!(cost.aux_bits, 90.0);
        assert_eq!(cost.floats, 0.0);
    }
}
