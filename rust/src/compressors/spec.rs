//! Textual compressor specifications for the CLI / config system.
//!
//! Grammar (case-insensitive):
//!
//! ```text
//! identity            full-precision
//! topk:<K>            greedy sparsification
//! randk:<K>           random sparsification
//! rank:<R>            low-rank (Rank-R)
//! dith:<S>            random dithering with S levels
//! dith:sqrtd          random dithering with √d levels (resolved per input)
//! nat                 natural compression
//! bern:<P>            lazy Bernoulli (vectors only)
//! rrank:<R>[:<S>]     Rank-R ∘ random dithering (default S = √d)
//! nrank:<R>           Rank-R ∘ natural compression
//! rtopk:<K>[:<S>]     Top-K ∘ random dithering (default S = √K)
//! ntopk:<K>           Top-K ∘ natural compression
//! ```

use super::{
    BitCost, Compose, ComposeRank, CompressorClass, Identity, LazyBernoulli, MatCompressor,
    NaturalCompression, RandDithering, RandK, RankR, Symmetrized, TopK, VecCompressor,
};
use crate::linalg::Mat;
use crate::rng::Rng;
use anyhow::{bail, Context, Result};

/// Parsed compressor description; call [`CompressorSpec::build_mat`] /
/// [`CompressorSpec::build_vec`] with the ambient dimension to instantiate.
#[derive(Clone, Debug, PartialEq)]
pub enum CompressorSpec {
    Identity,
    TopK(usize),
    RandK(usize),
    RankR(usize),
    Dithering(Option<u32>),
    Natural,
    Bernoulli(f64),
    /// Rank-R ∘ dithering; `None` level means √d.
    RRank(usize, Option<u32>),
    NRank(usize),
    /// Top-K ∘ dithering; `None` level means √K.
    RTopK(usize, Option<u32>),
    NTopK(usize),
}

impl CompressorSpec {
    /// Parse the textual grammar above.
    pub fn parse(s: &str) -> Result<Self> {
        let lower = s.trim().to_ascii_lowercase();
        let parts: Vec<&str> = lower.split(':').collect();
        let arg = |i: usize| -> Result<usize> {
            parts
                .get(i)
                .with_context(|| format!("compressor '{s}' missing argument {i}"))?
                .parse::<usize>()
                .with_context(|| format!("compressor '{s}': bad integer argument"))
        };
        Ok(match parts[0] {
            "identity" | "id" | "none" => CompressorSpec::Identity,
            "topk" | "top" => CompressorSpec::TopK(arg(1)?),
            "randk" | "rand" => CompressorSpec::RandK(arg(1)?),
            "rank" | "rankr" => CompressorSpec::RankR(arg(1)?),
            "dith" | "dithering" => {
                if parts.get(1).map(|p| *p == "sqrtd").unwrap_or(false) {
                    CompressorSpec::Dithering(None)
                } else {
                    CompressorSpec::Dithering(Some(arg(1)? as u32))
                }
            }
            "nat" | "natural" => CompressorSpec::Natural,
            "bern" | "bernoulli" => {
                let p: f64 = parts
                    .get(1)
                    .context("bern:<p> missing probability")?
                    .parse()
                    .context("bern:<p>: bad float")?;
                CompressorSpec::Bernoulli(p)
            }
            "rrank" => CompressorSpec::RRank(arg(1)?, parts.get(2).map(|_| arg(2)).transpose()?.map(|v| v as u32)),
            "nrank" => CompressorSpec::NRank(arg(1)?),
            "rtopk" | "rtop" => CompressorSpec::RTopK(arg(1)?, parts.get(2).map(|_| arg(2)).transpose()?.map(|v| v as u32)),
            "ntopk" | "ntop" => CompressorSpec::NTopK(arg(1)?),
            other => bail!("unknown compressor '{other}' (from '{s}')"),
        })
    }

    /// Instantiate a matrix compressor for `dim × dim` inputs, symmetrized
    /// per Lemma 3.1 so Hessian estimates stay symmetric.
    pub fn build_mat(&self, dim: usize) -> Box<dyn MatCompressor> {
        let numel = dim * dim;
        match *self {
            CompressorSpec::Identity => Box::new(Identity),
            CompressorSpec::TopK(k) => Box::new(Symmetrized(TopK::new(k.min(numel).max(1)))),
            CompressorSpec::RandK(k) => Box::new(Symmetrized(RandK::new(k.min(numel).max(1)))),
            CompressorSpec::RankR(r) => Box::new(RankR::new(r.max(1))),
            CompressorSpec::Dithering(s) => {
                let levels = s.unwrap_or_else(|| (numel as f64).sqrt().round().max(1.0) as u32);
                Box::new(Symmetrized(RandDithering::new(levels)))
            }
            CompressorSpec::Natural => Box::new(Symmetrized(NaturalCompression)),
            CompressorSpec::Bernoulli(p) => Box::new(MatBernoulli(LazyBernoulli::new(p))),
            CompressorSpec::RRank(r, s) => {
                let levels = s.unwrap_or_else(|| (dim as f64).sqrt().round().max(1.0) as u32);
                Box::new(Symmetrized(ComposeRank::new(
                    r.max(1),
                    RandDithering::new(levels),
                    RandDithering::new(levels),
                )))
            }
            CompressorSpec::NRank(r) => Box::new(Symmetrized(ComposeRank::new(
                r.max(1),
                NaturalCompression,
                NaturalCompression,
            ))),
            CompressorSpec::RTopK(k, s) => {
                let k = k.min(numel).max(1);
                let levels = s.unwrap_or_else(|| (k as f64).sqrt().round().max(1.0) as u32);
                Box::new(Symmetrized(Compose::new(k, RandDithering::new(levels))))
            }
            CompressorSpec::NTopK(k) => {
                Box::new(Symmetrized(Compose::new(k.min(numel).max(1), NaturalCompression)))
            }
        }
    }

    /// Instantiate a vector compressor for length-`dim` inputs.
    pub fn build_vec(&self, dim: usize) -> Box<dyn VecCompressor> {
        match *self {
            CompressorSpec::Identity => Box::new(Identity),
            CompressorSpec::TopK(k) => Box::new(TopK::new(k.min(dim).max(1))),
            CompressorSpec::RandK(k) => Box::new(RandK::new(k.min(dim).max(1))),
            CompressorSpec::Dithering(s) => {
                let levels = s.unwrap_or_else(|| (dim as f64).sqrt().round().max(1.0) as u32);
                Box::new(RandDithering::new(levels))
            }
            CompressorSpec::Natural => Box::new(NaturalCompression),
            CompressorSpec::Bernoulli(p) => Box::new(LazyBernoulli::new(p)),
            CompressorSpec::RTopK(k, s) => {
                let k = k.min(dim).max(1);
                let levels = s.unwrap_or_else(|| (k as f64).sqrt().round().max(1.0) as u32);
                Box::new(Compose::new(k, RandDithering::new(levels)))
            }
            CompressorSpec::NTopK(k) => Box::new(Compose::new(k.min(dim).max(1), NaturalCompression)),
            CompressorSpec::RankR(_) | CompressorSpec::RRank(_, _) | CompressorSpec::NRank(_) => {
                // audit:allow(panic-safety): the sweep executor relies on this panic for its broken-cell isolation tests (failed_cell_does_not_kill_the_sweep, broken_config_does_not_hang_under_threaded).
                panic!("rank-based compressors are matrix-only; got {self:?} for a vector")
            }
        }
    }
}

/// Lazy Bernoulli lifted to matrices (all-or-nothing transmission).
struct MatBernoulli(LazyBernoulli);

impl MatCompressor for MatBernoulli {
    fn compress(&self, a: &Mat, rng: &mut Rng) -> (Mat, BitCost) {
        let (v, cost) = self.0.compress_vec(a.data(), rng);
        (Mat::from_vec(a.rows(), a.cols(), v), cost)
    }

    fn class(&self, numel: usize, _dim: usize) -> CompressorClass {
        self.0.class_vec(numel)
    }

    fn name(&self) -> String {
        VecCompressor::name(&self.0)
    }
}

impl std::str::FromStr for CompressorSpec {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        CompressorSpec::parse(s)
    }
}

impl std::fmt::Display for CompressorSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompressorSpec::Identity => write!(f, "identity"),
            CompressorSpec::TopK(k) => write!(f, "topk:{k}"),
            CompressorSpec::RandK(k) => write!(f, "randk:{k}"),
            CompressorSpec::RankR(r) => write!(f, "rank:{r}"),
            CompressorSpec::Dithering(Some(s)) => write!(f, "dith:{s}"),
            CompressorSpec::Dithering(None) => write!(f, "dith:sqrtd"),
            CompressorSpec::Natural => write!(f, "nat"),
            CompressorSpec::Bernoulli(p) => write!(f, "bern:{p}"),
            CompressorSpec::RRank(r, Some(s)) => write!(f, "rrank:{r}:{s}"),
            CompressorSpec::RRank(r, None) => write!(f, "rrank:{r}"),
            CompressorSpec::NRank(r) => write!(f, "nrank:{r}"),
            CompressorSpec::RTopK(k, Some(s)) => write!(f, "rtopk:{k}:{s}"),
            CompressorSpec::RTopK(k, None) => write!(f, "rtopk:{k}"),
            CompressorSpec::NTopK(k) => write!(f, "ntopk:{k}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_forms() {
        assert_eq!(CompressorSpec::parse("identity").unwrap(), CompressorSpec::Identity);
        assert_eq!(CompressorSpec::parse("TopK:5").unwrap(), CompressorSpec::TopK(5));
        assert_eq!(CompressorSpec::parse("randk:3").unwrap(), CompressorSpec::RandK(3));
        assert_eq!(CompressorSpec::parse("rank:1").unwrap(), CompressorSpec::RankR(1));
        assert_eq!(CompressorSpec::parse("dith:8").unwrap(), CompressorSpec::Dithering(Some(8)));
        assert_eq!(CompressorSpec::parse("dith:sqrtd").unwrap(), CompressorSpec::Dithering(None));
        assert_eq!(CompressorSpec::parse("nat").unwrap(), CompressorSpec::Natural);
        assert_eq!(CompressorSpec::parse("bern:0.5").unwrap(), CompressorSpec::Bernoulli(0.5));
        assert_eq!(CompressorSpec::parse("rrank:1").unwrap(), CompressorSpec::RRank(1, None));
        assert_eq!(CompressorSpec::parse("rrank:2:16").unwrap(), CompressorSpec::RRank(2, Some(16)));
        assert_eq!(CompressorSpec::parse("nrank:1").unwrap(), CompressorSpec::NRank(1));
        assert_eq!(CompressorSpec::parse("rtopk:7").unwrap(), CompressorSpec::RTopK(7, None));
        assert_eq!(CompressorSpec::parse("ntopk:7").unwrap(), CompressorSpec::NTopK(7));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(CompressorSpec::parse("frobnicate").is_err());
        assert!(CompressorSpec::parse("topk").is_err());
        assert!(CompressorSpec::parse("topk:xyz").is_err());
        assert!(CompressorSpec::parse("bern").is_err());
    }

    #[test]
    fn display_roundtrip() {
        for s in [
            "identity", "topk:5", "randk:3", "rank:1", "dith:8", "dith:sqrtd", "nat",
            "bern:0.5", "rrank:1", "rrank:2:16", "nrank:1", "rtopk:7", "ntopk:7",
        ] {
            let spec = CompressorSpec::parse(s).unwrap();
            let round = CompressorSpec::parse(&spec.to_string()).unwrap();
            assert_eq!(spec, round, "roundtrip failed for {s}");
        }
    }

    #[test]
    fn build_and_run_every_mat_spec() {
        let mut rng = Rng::new(60);
        let mut a = Mat::from_fn(6, 6, |_, _| rng.normal());
        a.symmetrize();
        for s in [
            "identity", "topk:5", "randk:3", "rank:1", "dith:4", "nat", "bern:0.5",
            "rrank:1", "nrank:1", "rtopk:7", "ntopk:7",
        ] {
            let c = CompressorSpec::parse(s).unwrap().build_mat(6);
            let (out, cost) = c.compress(&a, &mut rng);
            assert_eq!(out.rows(), 6);
            assert!(cost.total_bits(64) >= 0.0);
            // Symmetric input → symmetric output for all built mats.
            assert!(out.is_symmetric(1e-9), "{s} broke symmetry");
        }
    }

    #[test]
    fn build_and_run_every_vec_spec() {
        let mut rng = Rng::new(61);
        let x: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        for s in ["identity", "topk:4", "randk:4", "dith:3", "nat", "bern:0.3", "rtopk:4", "ntopk:4"] {
            let c = CompressorSpec::parse(s).unwrap().build_vec(10);
            let (out, _) = c.compress_vec(&x, &mut rng);
            assert_eq!(out.len(), 10);
        }
    }

    #[test]
    #[should_panic]
    fn rank_as_vector_panics() {
        CompressorSpec::RankR(1).build_vec(10);
    }
}
