//! Run configuration: which algorithm, which compressors, which basis,
//! stepsizes, participation and stopping rules.
//!
//! Configuration is plain data + `FromStr` parsers so it can be driven from
//! the CLI, from experiment harness code, and from library users alike.

use crate::compressors::CompressorSpec;
use anyhow::{bail, Result};

/// Every optimization method in the paper's experimental sections.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    // ── second order ────────────────────────────────────────────────
    /// Classical Newton, naive communication (§2.1); with a custom basis it
    /// becomes the §2.3 implementation (Figure 2).
    Newton,
    /// BL1 — basis learn + bidirectional compression (Algorithm 1).
    Bl1,
    /// BL2 — + partial participation, PD via compression-error shift (Alg. 2).
    Bl2,
    /// BL3 — partial participation with the PSD basis (Algorithm 3).
    Bl3,
    /// FedNL family [Safaryan et al. 2021] = BL1/BL2 with the standard basis.
    FedNl,
    /// FedNL with partial participation.
    FedNlPp,
    /// FedNL with bidirectional compression.
    FedNlBc,
    /// NL1 / NewtonLearn [Islamov et al. 2021].
    Nl1,
    /// DINGO [Crane & Roosta 2019].
    Dingo,
    // ── first order ─────────────────────────────────────────────────
    /// Vanilla distributed gradient descent.
    Gd,
    /// DIANA [Mishchenko et al. 2019].
    Diana,
    /// ADIANA [Li et al. 2020] (accelerated DIANA).
    Adiana,
    /// Shifted local gradient descent [Gorbunov et al. 2021].
    SLocalGd,
    /// Artemis [Philippenko & Dieuleveut 2021] (bidirectional + PP).
    Artemis,
    /// DORE [Liu et al. 2020] (double residual compression).
    Dore,
}

impl Algorithm {
    pub fn all() -> &'static [Algorithm] {
        use Algorithm::*;
        &[
            Newton, Bl1, Bl2, Bl3, FedNl, FedNlPp, FedNlBc, Nl1, Dingo, Gd, Diana, Adiana,
            SLocalGd, Artemis, Dore,
        ]
    }

    pub fn is_second_order(&self) -> bool {
        use Algorithm::*;
        matches!(self, Newton | Bl1 | Bl2 | Bl3 | FedNl | FedNlPp | FedNlBc | Nl1 | Dingo)
    }

    pub fn name(&self) -> &'static str {
        use Algorithm::*;
        match self {
            Newton => "newton",
            Bl1 => "bl1",
            Bl2 => "bl2",
            Bl3 => "bl3",
            FedNl => "fednl",
            FedNlPp => "fednl-pp",
            FedNlBc => "fednl-bc",
            Nl1 => "nl1",
            Dingo => "dingo",
            Gd => "gd",
            Diana => "diana",
            Adiana => "adiana",
            SLocalGd => "s-local-gd",
            Artemis => "artemis",
            Dore => "dore",
        }
    }
}

impl std::str::FromStr for Algorithm {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        let norm = s.trim().to_ascii_lowercase().replace('_', "-");
        for a in Algorithm::all() {
            if a.name() == norm {
                return Ok(*a);
            }
        }
        bail!(
            "unknown algorithm '{s}'; expected one of: {}",
            Algorithm::all().iter().map(|a| a.name()).collect::<Vec<_>>().join(", ")
        )
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Which Hessian basis a Basis-Learn method uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BasisKind {
    /// Canonical `E_{jl}` basis (BL → FedNL).
    Standard,
    /// Symmetric lower-triangular basis (Example 4.2).
    SymTri,
    /// Data-driven subspace basis of §2.3 (the paper's default for BL1/BL2).
    Subspace,
    /// PSD basis of Example 5.1 (BL3's default).
    Psd,
}

impl BasisKind {
    pub fn name(&self) -> &'static str {
        match self {
            BasisKind::Standard => "standard",
            BasisKind::SymTri => "symtri",
            BasisKind::Subspace => "subspace",
            BasisKind::Psd => "psd",
        }
    }
}

impl std::fmt::Display for BasisKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

impl std::str::FromStr for BasisKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "standard" | "std" => BasisKind::Standard,
            "symtri" | "tri" => BasisKind::SymTri,
            "subspace" | "data" => BasisKind::Subspace,
            "psd" => BasisKind::Psd,
            other => bail!("unknown basis '{other}' (standard|symtri|subspace|psd)"),
        })
    }
}

/// BL3's β update options (Algorithm 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bl3Option {
    /// β from the previous iterate's coefficients.
    One,
    /// β from the current iterate's coefficients (the paper's experiments).
    Two,
}

/// Which [`crate::transport`] backend carries the round messages.
///
/// All backends produce bit-identical [`crate::metrics::History`] traces
/// (the determinism contract of the transport layer), so this is an
/// execution knob, not a semantic one — it is deliberately excluded from
/// [`RunConfig::fingerprint`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum TransportSpec {
    /// In-process reference backend: clients run one after another on the
    /// calling thread. Works with any [`crate::problem::LocalProblem`],
    /// including non-thread-safe oracles (PJRT).
    #[default]
    Lockstep,
    /// Concurrent in-round backend: a scoped worker pool executes each
    /// client's per-round work in parallel. `0` ⇒ one worker per hardware
    /// core (resolved at run time). Requires rebuildable local problems
    /// (see `run_federated`); `run_federated_with` rejects it.
    Threaded(usize),
    /// Real-socket backend: like [`TransportSpec::Threaded`], but every
    /// packet is serialized by the wire codec and crosses a TCP loopback
    /// connection (one per worker thread). `0` ⇒ one worker per hardware
    /// core. Requires rebuildable local problems, like `Threaded`.
    Tcp(usize),
    /// Multi-process backend: bind `addr` (`host:port`, port `0` = OS
    /// pick) and wait for `workers` standalone `repro worker --connect`
    /// processes to complete the Join/Assign handshake (docs/WIRE.md).
    /// Requires a dataset with a [`crate::data::DataRecipe`] so workers
    /// can rebuild their shards locally.
    Listen {
        addr: String,
        workers: usize,
    },
}

impl TransportSpec {
    /// Worker count to actually spawn for `n` clients (resolves the `0` =
    /// auto sentinel and never exceeds the client count).
    pub fn resolved_workers(&self, n_clients: usize) -> usize {
        match self {
            TransportSpec::Lockstep => 1,
            TransportSpec::Threaded(0) | TransportSpec::Tcp(0) => {
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(1)
                    .min(n_clients.max(1))
            }
            TransportSpec::Threaded(k)
            | TransportSpec::Tcp(k)
            | TransportSpec::Listen { workers: k, .. } => (*k).min(n_clients.max(1)).max(1),
        }
    }
}

impl std::fmt::Display for TransportSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportSpec::Lockstep => write!(f, "lockstep"),
            TransportSpec::Threaded(0) => write!(f, "threaded"),
            TransportSpec::Threaded(k) => write!(f, "threaded:{k}"),
            TransportSpec::Tcp(0) => write!(f, "tcp"),
            TransportSpec::Tcp(k) => write!(f, "tcp:{k}"),
            TransportSpec::Listen { addr, workers } => write!(f, "listen:{addr}:{workers}"),
        }
    }
}

impl std::str::FromStr for TransportSpec {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        let t = s.trim().to_ascii_lowercase();
        if t == "lockstep" {
            return Ok(TransportSpec::Lockstep);
        }
        if t == "threaded" {
            return Ok(TransportSpec::Threaded(0));
        }
        if let Some(k) = t.strip_prefix("threaded:") {
            let k: usize = k
                .parse()
                .map_err(|e| anyhow::anyhow!("bad worker count in '{s}': {e}"))?;
            return Ok(TransportSpec::Threaded(k));
        }
        if t == "tcp" {
            return Ok(TransportSpec::Tcp(0));
        }
        if let Some(k) = t.strip_prefix("tcp:") {
            let k: usize = k
                .parse()
                .map_err(|e| anyhow::anyhow!("bad worker count in '{s}': {e}"))?;
            return Ok(TransportSpec::Tcp(k));
        }
        if let Some(rest) = t.strip_prefix("listen:") {
            // `listen:<host>:<port>:<workers>` — the worker count is the
            // final `:`-separated field; everything before it is the
            // socket address.
            let (addr, k) = rest
                .rsplit_once(':')
                .ok_or_else(|| anyhow::anyhow!("'{s}' needs listen:<host:port>:<workers>"))?;
            let workers: usize = k
                .parse()
                .map_err(|e| anyhow::anyhow!("bad worker count in '{s}': {e}"))?;
            if workers == 0 {
                bail!("listen transport needs an explicit worker count ≥ 1 in '{s}'");
            }
            if !addr.contains(':') {
                bail!("'{s}': listen address must be <host>:<port>");
            }
            return Ok(TransportSpec::Listen { addr: addr.to_string(), workers });
        }
        bail!(
            "unknown transport '{s}' (lockstep | threaded[:<k>] | tcp[:<k>] | \
             listen:<host:port>:<workers>)"
        )
    }
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub algorithm: Algorithm,
    /// Maximum communication rounds.
    pub rounds: usize,
    /// Ridge parameter λ of eq. (16).
    pub lambda: f64,
    /// Hessian/matrix compressor `C_i^k`.
    pub hess_comp: CompressorSpec,
    /// Model compressor `Q^k` (bidirectional compression; identity = off).
    pub model_comp: CompressorSpec,
    /// Gradient compressor for first-order methods.
    pub grad_comp: CompressorSpec,
    /// Gradient-send probability `p` (the ξ^k Bernoulli schedule).
    pub p: f64,
    /// Expected participating clients per round `τ` (`None` ⇒ all).
    pub tau: Option<usize>,
    /// Model learning rate η (`None` ⇒ rule from Asm. 4.3/4.4).
    pub eta: Option<f64>,
    /// Hessian learning rate α (`None` ⇒ rule from Asm. 4.5/4.6).
    pub alpha: Option<f64>,
    /// First-order stepsize (`None` ⇒ theoretical 1/L etc.).
    pub gamma: Option<f64>,
    /// Basis for BL methods (`None` ⇒ each algorithm's paper default).
    pub basis: Option<BasisKind>,
    /// Relative tolerance for subspace extraction from data.
    pub subspace_tol: f64,
    /// BL3: positive constant `c`.
    pub bl3_c: f64,
    /// BL3: β option.
    pub bl3_option: Bl3Option,
    /// Float width for bit accounting (the paper plots 64-bit doubles).
    pub float_bits: u32,
    /// Stop once `f(x^k) − f(x*) ≤ target_gap` (0 ⇒ run all rounds).
    pub target_gap: f64,
    /// Stop once bits/node exceeds this budget (`None` ⇒ unlimited).
    pub max_bits_per_node: Option<f64>,
    /// RNG seed.
    pub seed: u64,
    /// Message-passing backend for the round loop (results are identical
    /// across backends; see [`TransportSpec`]).
    pub transport: TransportSpec,
    /// How long the socket backends wait for all workers to connect and
    /// complete the handshake (remote workers may build large datasets
    /// before greeting). Execution knob like `transport` — excluded from
    /// [`RunConfig::fingerprint`].
    pub handshake_timeout_ms: u64,
}

/// Default [`RunConfig::handshake_timeout_ms`] (the historical hard-coded
/// socket-backend handshake deadline).
pub const DEFAULT_HANDSHAKE_TIMEOUT_MS: u64 = 30_000;

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algorithm: Algorithm::Bl1,
            rounds: 200,
            lambda: 1e-3,
            hess_comp: CompressorSpec::TopK(1),
            model_comp: CompressorSpec::Identity,
            grad_comp: CompressorSpec::Identity,
            p: 1.0,
            tau: None,
            eta: None,
            alpha: None,
            gamma: None,
            basis: None,
            subspace_tol: 1e-9,
            bl3_c: 0.1,
            bl3_option: Bl3Option::Two,
            float_bits: 64,
            target_gap: 1e-12,
            max_bits_per_node: None,
            seed: 1,
            transport: TransportSpec::Lockstep,
            handshake_timeout_ms: DEFAULT_HANDSHAKE_TIMEOUT_MS,
        }
    }
}

impl RunConfig {
    /// Stable fingerprint of the *entire semantic* configuration (FNV-1a
    /// over the `Debug` rendering, which is stable for every field type used
    /// here). Two runs with equal fingerprints execute identically on the
    /// same data; the sweep resume path uses this to refuse rows recorded
    /// under different parameters (rounds, λ, stopping rules, master seed,
    /// ...) that the group string doesn't encode.
    ///
    /// The `transport` backend (and its `handshake_timeout_ms` companion
    /// knob) are canonicalized away before hashing: all backends produce
    /// bit-identical histories (the transport layer's determinism contract,
    /// enforced by `tests/transport_equivalence.rs`), so a sweep resumed
    /// under a different `--transport`, or a remote worker validating a
    /// wire-decoded config against the server's, must agree regardless of
    /// execution knobs.
    pub fn fingerprint(&self) -> u64 {
        let canon = RunConfig {
            transport: TransportSpec::Lockstep,
            handshake_timeout_ms: DEFAULT_HANDSHAKE_TIMEOUT_MS,
            ..self.clone()
        };
        crate::rng::fnv1a(format!("{canon:?}").as_bytes())
    }

    /// Render the *semantic* configuration as `key=value` lines for the
    /// wire (the `Assign` frame of the multi-process handshake). Every f64
    /// travels as its hex `to_bits` pattern, so [`RunConfig::from_wire`]
    /// reconstructs a config whose [`RunConfig::fingerprint`] matches this
    /// one's exactly. The execution knobs (`transport`,
    /// `handshake_timeout_ms`) are excluded, mirroring the fingerprint.
    pub fn to_wire(&self) -> String {
        let f = f64_to_wire;
        let opt_f = |v: Option<f64>| v.map(f).unwrap_or_else(|| "none".into());
        let mut out = String::new();
        for (k, v) in [
            ("algorithm", self.algorithm.to_string()),
            ("rounds", self.rounds.to_string()),
            ("lambda", f(self.lambda)),
            ("hess_comp", self.hess_comp.to_string()),
            ("model_comp", self.model_comp.to_string()),
            ("grad_comp", self.grad_comp.to_string()),
            ("p", f(self.p)),
            ("tau", self.tau.map(|t| t.to_string()).unwrap_or_else(|| "none".into())),
            ("eta", opt_f(self.eta)),
            ("alpha", opt_f(self.alpha)),
            ("gamma", opt_f(self.gamma)),
            ("basis", self.basis.map(|b| b.to_string()).unwrap_or_else(|| "none".into())),
            ("subspace_tol", f(self.subspace_tol)),
            ("bl3_c", f(self.bl3_c)),
            (
                "bl3_option",
                match self.bl3_option {
                    Bl3Option::One => "one".into(),
                    Bl3Option::Two => "two".into(),
                },
            ),
            ("float_bits", self.float_bits.to_string()),
            ("target_gap", f(self.target_gap)),
            ("max_bits_per_node", opt_f(self.max_bits_per_node)),
            ("seed", self.seed.to_string()),
        ] {
            out.push_str(k);
            out.push('=');
            out.push_str(&v);
            out.push('\n');
        }
        out
    }

    /// Parse a [`RunConfig::to_wire`] rendering. Strict: every semantic key
    /// must appear exactly once and unknown keys are errors, so a version
    /// skew between server and worker binaries fails loudly instead of
    /// silently running under different parameters. The decoded config
    /// carries default execution knobs (`transport`, `handshake_timeout_ms`)
    /// — irrelevant to the fingerprint the caller verifies.
    pub fn from_wire(text: &str) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        let mut seen = std::collections::BTreeSet::new();
        let opt = |v: &str| -> Result<Option<f64>> {
            Ok(if v == "none" { None } else { Some(f64_from_wire(v)?) })
        };
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("malformed config line {line:?}"))?;
            if !seen.insert(k.to_string()) {
                bail!("duplicate config key {k:?}");
            }
            match k {
                "algorithm" => cfg.algorithm = v.parse()?,
                "rounds" => cfg.rounds = v.parse()?,
                "lambda" => cfg.lambda = f64_from_wire(v)?,
                "hess_comp" => cfg.hess_comp = v.parse()?,
                "model_comp" => cfg.model_comp = v.parse()?,
                "grad_comp" => cfg.grad_comp = v.parse()?,
                "p" => cfg.p = f64_from_wire(v)?,
                "tau" => cfg.tau = if v == "none" { None } else { Some(v.parse()?) },
                "eta" => cfg.eta = opt(v)?,
                "alpha" => cfg.alpha = opt(v)?,
                "gamma" => cfg.gamma = opt(v)?,
                "basis" => cfg.basis = if v == "none" { None } else { Some(v.parse()?) },
                "subspace_tol" => cfg.subspace_tol = f64_from_wire(v)?,
                "bl3_c" => cfg.bl3_c = f64_from_wire(v)?,
                "bl3_option" => {
                    cfg.bl3_option = match v {
                        "one" => Bl3Option::One,
                        "two" => Bl3Option::Two,
                        other => bail!("unknown bl3_option {other:?}"),
                    }
                }
                "float_bits" => cfg.float_bits = v.parse()?,
                "target_gap" => cfg.target_gap = f64_from_wire(v)?,
                "max_bits_per_node" => cfg.max_bits_per_node = opt(v)?,
                "seed" => cfg.seed = v.parse()?,
                other => bail!("unknown config key {other:?} (version skew?)"),
            }
        }
        let want = [
            "algorithm", "rounds", "lambda", "hess_comp", "model_comp", "grad_comp", "p",
            "tau", "eta", "alpha", "gamma", "basis", "subspace_tol", "bl3_c", "bl3_option",
            "float_bits", "target_gap", "max_bits_per_node", "seed",
        ];
        for k in want {
            if !seen.contains(k) {
                bail!("config key {k:?} missing from the wire rendering (version skew?)");
            }
        }
        Ok(cfg)
    }

    /// The basis each algorithm uses when none is specified.
    pub fn effective_basis(&self) -> BasisKind {
        if let Some(b) = self.basis {
            return b;
        }
        match self.algorithm {
            Algorithm::Bl1 | Algorithm::Bl2 => BasisKind::Subspace,
            Algorithm::Bl3 => BasisKind::Psd,
            _ => BasisKind::Standard,
        }
    }
}

/// An f64 as its hex `to_bits` pattern — the wire rendering that survives
/// any value (NaN payloads, −0.0, subnormals) bit-for-bit, so a decoded
/// config's `Debug` rendering (hence its fingerprint) matches the
/// encoder's exactly.
pub(crate) fn f64_to_wire(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

pub(crate) fn f64_from_wire(s: &str) -> Result<f64> {
    let bits = u64::from_str_radix(s, 16)
        .map_err(|e| anyhow::anyhow!("bad f64 bit pattern {s:?}: {e}"))?;
    Ok(f64::from_bits(bits))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in Algorithm::all() {
            let parsed: Algorithm = a.name().parse().unwrap();
            assert_eq!(*a, parsed);
        }
        assert!("warp-drive".parse::<Algorithm>().is_err());
        assert_eq!("FEDNL_PP".parse::<Algorithm>().unwrap(), Algorithm::FedNlPp);
    }

    #[test]
    fn second_order_classification() {
        assert!(Algorithm::Bl1.is_second_order());
        assert!(Algorithm::Dingo.is_second_order());
        assert!(!Algorithm::Gd.is_second_order());
        assert!(!Algorithm::Dore.is_second_order());
    }

    #[test]
    fn basis_parse() {
        assert_eq!("subspace".parse::<BasisKind>().unwrap(), BasisKind::Subspace);
        assert_eq!("STD".parse::<BasisKind>().unwrap(), BasisKind::Standard);
        assert!("fourier".parse::<BasisKind>().is_err());
        for b in [BasisKind::Standard, BasisKind::SymTri, BasisKind::Subspace, BasisKind::Psd] {
            assert_eq!(b.to_string().parse::<BasisKind>().unwrap(), b);
        }
    }

    #[test]
    fn fingerprint_tracks_every_field() {
        let base = RunConfig::default();
        assert_eq!(base.fingerprint(), RunConfig::default().fingerprint());
        for cfg in [
            RunConfig { rounds: 201, ..RunConfig::default() },
            RunConfig { lambda: 2e-3, ..RunConfig::default() },
            RunConfig { target_gap: 1e-10, ..RunConfig::default() },
            RunConfig { max_bits_per_node: Some(1e6), ..RunConfig::default() },
            RunConfig { seed: 2, ..RunConfig::default() },
            RunConfig { float_bits: 32, ..RunConfig::default() },
            RunConfig { eta: Some(0.1), ..RunConfig::default() },
        ] {
            assert_ne!(cfg.fingerprint(), base.fingerprint(), "{cfg:?}");
        }
    }

    #[test]
    fn transport_parse_and_display() {
        assert_eq!("lockstep".parse::<TransportSpec>().unwrap(), TransportSpec::Lockstep);
        assert_eq!("threaded".parse::<TransportSpec>().unwrap(), TransportSpec::Threaded(0));
        assert_eq!("threaded:4".parse::<TransportSpec>().unwrap(), TransportSpec::Threaded(4));
        assert_eq!("THREADED:2".parse::<TransportSpec>().unwrap(), TransportSpec::Threaded(2));
        assert_eq!("tcp".parse::<TransportSpec>().unwrap(), TransportSpec::Tcp(0));
        assert_eq!("tcp:4".parse::<TransportSpec>().unwrap(), TransportSpec::Tcp(4));
        assert_eq!("TCP:2".parse::<TransportSpec>().unwrap(), TransportSpec::Tcp(2));
        assert_eq!(
            "listen:127.0.0.1:7700:4".parse::<TransportSpec>().unwrap(),
            TransportSpec::Listen { addr: "127.0.0.1:7700".into(), workers: 4 }
        );
        assert_eq!(
            "listen:0.0.0.0:0:2".parse::<TransportSpec>().unwrap(),
            TransportSpec::Listen { addr: "0.0.0.0:0".into(), workers: 2 }
        );
        assert!("sockets".parse::<TransportSpec>().is_err());
        assert!("threaded:x".parse::<TransportSpec>().is_err());
        assert!("tcp:x".parse::<TransportSpec>().is_err());
        assert!("listen:127.0.0.1:7700".parse::<TransportSpec>().is_err(), "missing workers");
        assert!("listen:7700:2".parse::<TransportSpec>().is_err(), "missing host");
        assert!("listen:127.0.0.1:7700:0".parse::<TransportSpec>().is_err(), "zero workers");
        let all = [
            TransportSpec::Lockstep,
            TransportSpec::Threaded(0),
            TransportSpec::Threaded(8),
            TransportSpec::Tcp(0),
            TransportSpec::Tcp(8),
            TransportSpec::Listen { addr: "127.0.0.1:7700".into(), workers: 3 },
        ];
        for t in all {
            assert_eq!(t.to_string().parse::<TransportSpec>().unwrap(), t);
        }
    }

    #[test]
    fn transport_worker_resolution() {
        assert_eq!(TransportSpec::Lockstep.resolved_workers(16), 1);
        assert_eq!(TransportSpec::Threaded(4).resolved_workers(16), 4);
        // Never more workers than clients; auto resolves to ≥ 1.
        assert_eq!(TransportSpec::Threaded(8).resolved_workers(3), 3);
        assert!(TransportSpec::Threaded(0).resolved_workers(64) >= 1);
        assert_eq!(TransportSpec::Threaded(4).resolved_workers(0), 1);
        // Tcp resolves exactly like Threaded.
        assert_eq!(TransportSpec::Tcp(4).resolved_workers(16), 4);
        assert_eq!(TransportSpec::Tcp(8).resolved_workers(3), 3);
        assert!(TransportSpec::Tcp(0).resolved_workers(64) >= 1);
        // Listen clamps its explicit worker count the same way.
        let listen = |workers| TransportSpec::Listen { addr: "127.0.0.1:0".into(), workers };
        assert_eq!(listen(4).resolved_workers(16), 4);
        assert_eq!(listen(8).resolved_workers(3), 3);
    }

    #[test]
    fn fingerprint_ignores_transport_backend() {
        // Backends are bit-identical by contract, so resume must treat rows
        // recorded under either backend as the same run.
        let lock = RunConfig { transport: TransportSpec::Lockstep, ..RunConfig::default() };
        let thr = RunConfig { transport: TransportSpec::Threaded(4), ..RunConfig::default() };
        let tcp = RunConfig { transport: TransportSpec::Tcp(2), ..RunConfig::default() };
        let listen = RunConfig {
            transport: TransportSpec::Listen { addr: "127.0.0.1:0".into(), workers: 2 },
            ..RunConfig::default()
        };
        let slow = RunConfig { handshake_timeout_ms: 600_000, ..RunConfig::default() };
        assert_eq!(lock.fingerprint(), thr.fingerprint());
        assert_eq!(lock.fingerprint(), tcp.fingerprint());
        assert_eq!(lock.fingerprint(), listen.fingerprint());
        assert_eq!(lock.fingerprint(), slow.fingerprint());
    }

    #[test]
    fn wire_round_trip_preserves_fingerprint() {
        // The multi-process handshake's contract: a worker that decodes the
        // Assign frame's config string must compute the server's exact
        // fingerprint — including gnarly f64 fields that a decimal
        // rendering would mangle.
        let cfgs = [
            RunConfig::default(),
            RunConfig {
                algorithm: Algorithm::Bl3,
                rounds: 77,
                lambda: 0.1 + 0.2, // not exactly 0.3
                hess_comp: CompressorSpec::RandK(3),
                model_comp: CompressorSpec::TopK(5),
                grad_comp: CompressorSpec::Dithering(Some(4)),
                p: 0.5,
                tau: Some(3),
                eta: Some(1e-3),
                alpha: Some(f64::MIN_POSITIVE),
                gamma: None,
                basis: Some(BasisKind::Psd),
                subspace_tol: 1e-9,
                bl3_c: 0.25,
                bl3_option: Bl3Option::One,
                float_bits: 32,
                target_gap: 0.0,
                max_bits_per_node: Some(3e8),
                seed: 99,
                transport: TransportSpec::Tcp(4),
                handshake_timeout_ms: 1_000,
            },
        ];
        for cfg in cfgs {
            let decoded = RunConfig::from_wire(&cfg.to_wire()).unwrap();
            assert_eq!(decoded.fingerprint(), cfg.fingerprint(), "{cfg:?}");
            // Execution knobs decode to defaults, not the encoder's.
            assert_eq!(decoded.transport, TransportSpec::Lockstep);
            assert_eq!(decoded.handshake_timeout_ms, DEFAULT_HANDSHAKE_TIMEOUT_MS);
        }
        // Strictness: missing keys, unknown keys and duplicates all fail.
        let wire = RunConfig::default().to_wire();
        let missing: String =
            wire.lines().filter(|l| !l.starts_with("seed=")).map(|l| format!("{l}\n")).collect();
        assert!(RunConfig::from_wire(&missing).is_err(), "missing key accepted");
        assert!(RunConfig::from_wire(&format!("{wire}mystery=1\n")).is_err());
        assert!(RunConfig::from_wire(&format!("{wire}seed=2\n")).is_err(), "duplicate accepted");
    }

    #[test]
    fn effective_basis_defaults() {
        let mut cfg = RunConfig::default();
        cfg.algorithm = Algorithm::Bl1;
        assert_eq!(cfg.effective_basis(), BasisKind::Subspace);
        cfg.algorithm = Algorithm::Bl3;
        assert_eq!(cfg.effective_basis(), BasisKind::Psd);
        cfg.algorithm = Algorithm::FedNl;
        assert_eq!(cfg.effective_basis(), BasisKind::Standard);
        cfg.basis = Some(BasisKind::SymTri);
        assert_eq!(cfg.effective_basis(), BasisKind::SymTri);
    }
}
