//! Run configuration: which algorithm, which compressors, which basis,
//! stepsizes, participation and stopping rules.
//!
//! Configuration is plain data + `FromStr` parsers so it can be driven from
//! the CLI, from experiment harness code, and from library users alike.

use crate::compressors::CompressorSpec;
use anyhow::{bail, Result};

/// Every optimization method in the paper's experimental sections.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    // ── second order ────────────────────────────────────────────────
    /// Classical Newton, naive communication (§2.1); with a custom basis it
    /// becomes the §2.3 implementation (Figure 2).
    Newton,
    /// BL1 — basis learn + bidirectional compression (Algorithm 1).
    Bl1,
    /// BL2 — + partial participation, PD via compression-error shift (Alg. 2).
    Bl2,
    /// BL3 — partial participation with the PSD basis (Algorithm 3).
    Bl3,
    /// FedNL family [Safaryan et al. 2021] = BL1/BL2 with the standard basis.
    FedNl,
    /// FedNL with partial participation.
    FedNlPp,
    /// FedNL with bidirectional compression.
    FedNlBc,
    /// NL1 / NewtonLearn [Islamov et al. 2021].
    Nl1,
    /// DINGO [Crane & Roosta 2019].
    Dingo,
    // ── first order ─────────────────────────────────────────────────
    /// Vanilla distributed gradient descent.
    Gd,
    /// DIANA [Mishchenko et al. 2019].
    Diana,
    /// ADIANA [Li et al. 2020] (accelerated DIANA).
    Adiana,
    /// Shifted local gradient descent [Gorbunov et al. 2021].
    SLocalGd,
    /// Artemis [Philippenko & Dieuleveut 2021] (bidirectional + PP).
    Artemis,
    /// DORE [Liu et al. 2020] (double residual compression).
    Dore,
}

impl Algorithm {
    pub fn all() -> &'static [Algorithm] {
        use Algorithm::*;
        &[
            Newton, Bl1, Bl2, Bl3, FedNl, FedNlPp, FedNlBc, Nl1, Dingo, Gd, Diana, Adiana,
            SLocalGd, Artemis, Dore,
        ]
    }

    pub fn is_second_order(&self) -> bool {
        use Algorithm::*;
        matches!(self, Newton | Bl1 | Bl2 | Bl3 | FedNl | FedNlPp | FedNlBc | Nl1 | Dingo)
    }

    pub fn name(&self) -> &'static str {
        use Algorithm::*;
        match self {
            Newton => "newton",
            Bl1 => "bl1",
            Bl2 => "bl2",
            Bl3 => "bl3",
            FedNl => "fednl",
            FedNlPp => "fednl-pp",
            FedNlBc => "fednl-bc",
            Nl1 => "nl1",
            Dingo => "dingo",
            Gd => "gd",
            Diana => "diana",
            Adiana => "adiana",
            SLocalGd => "s-local-gd",
            Artemis => "artemis",
            Dore => "dore",
        }
    }
}

impl std::str::FromStr for Algorithm {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        let norm = s.trim().to_ascii_lowercase().replace('_', "-");
        for a in Algorithm::all() {
            if a.name() == norm {
                return Ok(*a);
            }
        }
        bail!(
            "unknown algorithm '{s}'; expected one of: {}",
            Algorithm::all().iter().map(|a| a.name()).collect::<Vec<_>>().join(", ")
        )
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Which Hessian basis a Basis-Learn method uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BasisKind {
    /// Canonical `E_{jl}` basis (BL → FedNL).
    Standard,
    /// Symmetric lower-triangular basis (Example 4.2).
    SymTri,
    /// Data-driven subspace basis of §2.3 (the paper's default for BL1/BL2).
    Subspace,
    /// PSD basis of Example 5.1 (BL3's default).
    Psd,
}

impl BasisKind {
    pub fn name(&self) -> &'static str {
        match self {
            BasisKind::Standard => "standard",
            BasisKind::SymTri => "symtri",
            BasisKind::Subspace => "subspace",
            BasisKind::Psd => "psd",
        }
    }
}

impl std::fmt::Display for BasisKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

impl std::str::FromStr for BasisKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "standard" | "std" => BasisKind::Standard,
            "symtri" | "tri" => BasisKind::SymTri,
            "subspace" | "data" => BasisKind::Subspace,
            "psd" => BasisKind::Psd,
            other => bail!("unknown basis '{other}' (standard|symtri|subspace|psd)"),
        })
    }
}

/// BL3's β update options (Algorithm 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bl3Option {
    /// β from the previous iterate's coefficients.
    One,
    /// β from the current iterate's coefficients (the paper's experiments).
    Two,
}

/// Which [`crate::transport`] backend carries the round messages.
///
/// All backends produce bit-identical [`crate::metrics::History`] traces
/// (the determinism contract of the transport layer), so this is an
/// execution knob, not a semantic one — it is deliberately excluded from
/// [`RunConfig::fingerprint`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportSpec {
    /// In-process reference backend: clients run one after another on the
    /// calling thread. Works with any [`crate::problem::LocalProblem`],
    /// including non-thread-safe oracles (PJRT).
    #[default]
    Lockstep,
    /// Concurrent in-round backend: a scoped worker pool executes each
    /// client's per-round work in parallel. `0` ⇒ one worker per hardware
    /// core (resolved at run time). Requires rebuildable local problems
    /// (see `run_federated`); `run_federated_with` rejects it.
    Threaded(usize),
    /// Real-socket backend: like [`TransportSpec::Threaded`], but every
    /// packet is serialized by the wire codec and crosses a TCP loopback
    /// connection (one per worker thread). `0` ⇒ one worker per hardware
    /// core. Requires rebuildable local problems, like `Threaded`.
    Tcp(usize),
}

impl TransportSpec {
    /// Worker count to actually spawn for `n` clients (resolves the `0` =
    /// auto sentinel and never exceeds the client count).
    pub fn resolved_workers(&self, n_clients: usize) -> usize {
        match self {
            TransportSpec::Lockstep => 1,
            TransportSpec::Threaded(0) | TransportSpec::Tcp(0) => {
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(1)
                    .min(n_clients.max(1))
            }
            TransportSpec::Threaded(k) | TransportSpec::Tcp(k) => (*k).min(n_clients.max(1)),
        }
    }
}

impl std::fmt::Display for TransportSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportSpec::Lockstep => write!(f, "lockstep"),
            TransportSpec::Threaded(0) => write!(f, "threaded"),
            TransportSpec::Threaded(k) => write!(f, "threaded:{k}"),
            TransportSpec::Tcp(0) => write!(f, "tcp"),
            TransportSpec::Tcp(k) => write!(f, "tcp:{k}"),
        }
    }
}

impl std::str::FromStr for TransportSpec {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        let t = s.trim().to_ascii_lowercase();
        if t == "lockstep" {
            return Ok(TransportSpec::Lockstep);
        }
        if t == "threaded" {
            return Ok(TransportSpec::Threaded(0));
        }
        if let Some(k) = t.strip_prefix("threaded:") {
            let k: usize = k
                .parse()
                .map_err(|e| anyhow::anyhow!("bad worker count in '{s}': {e}"))?;
            return Ok(TransportSpec::Threaded(k));
        }
        if t == "tcp" {
            return Ok(TransportSpec::Tcp(0));
        }
        if let Some(k) = t.strip_prefix("tcp:") {
            let k: usize = k
                .parse()
                .map_err(|e| anyhow::anyhow!("bad worker count in '{s}': {e}"))?;
            return Ok(TransportSpec::Tcp(k));
        }
        bail!("unknown transport '{s}' (lockstep | threaded | threaded:<k> | tcp | tcp:<k>)")
    }
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub algorithm: Algorithm,
    /// Maximum communication rounds.
    pub rounds: usize,
    /// Ridge parameter λ of eq. (16).
    pub lambda: f64,
    /// Hessian/matrix compressor `C_i^k`.
    pub hess_comp: CompressorSpec,
    /// Model compressor `Q^k` (bidirectional compression; identity = off).
    pub model_comp: CompressorSpec,
    /// Gradient compressor for first-order methods.
    pub grad_comp: CompressorSpec,
    /// Gradient-send probability `p` (the ξ^k Bernoulli schedule).
    pub p: f64,
    /// Expected participating clients per round `τ` (`None` ⇒ all).
    pub tau: Option<usize>,
    /// Model learning rate η (`None` ⇒ rule from Asm. 4.3/4.4).
    pub eta: Option<f64>,
    /// Hessian learning rate α (`None` ⇒ rule from Asm. 4.5/4.6).
    pub alpha: Option<f64>,
    /// First-order stepsize (`None` ⇒ theoretical 1/L etc.).
    pub gamma: Option<f64>,
    /// Basis for BL methods (`None` ⇒ each algorithm's paper default).
    pub basis: Option<BasisKind>,
    /// Relative tolerance for subspace extraction from data.
    pub subspace_tol: f64,
    /// BL3: positive constant `c`.
    pub bl3_c: f64,
    /// BL3: β option.
    pub bl3_option: Bl3Option,
    /// Float width for bit accounting (the paper plots 64-bit doubles).
    pub float_bits: u32,
    /// Stop once `f(x^k) − f(x*) ≤ target_gap` (0 ⇒ run all rounds).
    pub target_gap: f64,
    /// Stop once bits/node exceeds this budget (`None` ⇒ unlimited).
    pub max_bits_per_node: Option<f64>,
    /// RNG seed.
    pub seed: u64,
    /// Message-passing backend for the round loop (results are identical
    /// across backends; see [`TransportSpec`]).
    pub transport: TransportSpec,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algorithm: Algorithm::Bl1,
            rounds: 200,
            lambda: 1e-3,
            hess_comp: CompressorSpec::TopK(1),
            model_comp: CompressorSpec::Identity,
            grad_comp: CompressorSpec::Identity,
            p: 1.0,
            tau: None,
            eta: None,
            alpha: None,
            gamma: None,
            basis: None,
            subspace_tol: 1e-9,
            bl3_c: 0.1,
            bl3_option: Bl3Option::Two,
            float_bits: 64,
            target_gap: 1e-12,
            max_bits_per_node: None,
            seed: 1,
            transport: TransportSpec::Lockstep,
        }
    }
}

impl RunConfig {
    /// Stable fingerprint of the *entire semantic* configuration (FNV-1a
    /// over the `Debug` rendering, which is stable for every field type used
    /// here). Two runs with equal fingerprints execute identically on the
    /// same data; the sweep resume path uses this to refuse rows recorded
    /// under different parameters (rounds, λ, stopping rules, master seed,
    /// ...) that the group string doesn't encode.
    ///
    /// The `transport` backend is canonicalized away before hashing: both
    /// backends produce bit-identical histories (the transport layer's
    /// determinism contract, enforced by `tests/transport_equivalence.rs`),
    /// so a sweep resumed under a different `--transport` must still accept
    /// its previously recorded rows.
    pub fn fingerprint(&self) -> u64 {
        let canon = RunConfig { transport: TransportSpec::Lockstep, ..self.clone() };
        crate::rng::fnv1a(format!("{canon:?}").as_bytes())
    }

    /// The basis each algorithm uses when none is specified.
    pub fn effective_basis(&self) -> BasisKind {
        if let Some(b) = self.basis {
            return b;
        }
        match self.algorithm {
            Algorithm::Bl1 | Algorithm::Bl2 => BasisKind::Subspace,
            Algorithm::Bl3 => BasisKind::Psd,
            _ => BasisKind::Standard,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in Algorithm::all() {
            let parsed: Algorithm = a.name().parse().unwrap();
            assert_eq!(*a, parsed);
        }
        assert!("warp-drive".parse::<Algorithm>().is_err());
        assert_eq!("FEDNL_PP".parse::<Algorithm>().unwrap(), Algorithm::FedNlPp);
    }

    #[test]
    fn second_order_classification() {
        assert!(Algorithm::Bl1.is_second_order());
        assert!(Algorithm::Dingo.is_second_order());
        assert!(!Algorithm::Gd.is_second_order());
        assert!(!Algorithm::Dore.is_second_order());
    }

    #[test]
    fn basis_parse() {
        assert_eq!("subspace".parse::<BasisKind>().unwrap(), BasisKind::Subspace);
        assert_eq!("STD".parse::<BasisKind>().unwrap(), BasisKind::Standard);
        assert!("fourier".parse::<BasisKind>().is_err());
        for b in [BasisKind::Standard, BasisKind::SymTri, BasisKind::Subspace, BasisKind::Psd] {
            assert_eq!(b.to_string().parse::<BasisKind>().unwrap(), b);
        }
    }

    #[test]
    fn fingerprint_tracks_every_field() {
        let base = RunConfig::default();
        assert_eq!(base.fingerprint(), RunConfig::default().fingerprint());
        for cfg in [
            RunConfig { rounds: 201, ..RunConfig::default() },
            RunConfig { lambda: 2e-3, ..RunConfig::default() },
            RunConfig { target_gap: 1e-10, ..RunConfig::default() },
            RunConfig { max_bits_per_node: Some(1e6), ..RunConfig::default() },
            RunConfig { seed: 2, ..RunConfig::default() },
            RunConfig { float_bits: 32, ..RunConfig::default() },
            RunConfig { eta: Some(0.1), ..RunConfig::default() },
        ] {
            assert_ne!(cfg.fingerprint(), base.fingerprint(), "{cfg:?}");
        }
    }

    #[test]
    fn transport_parse_and_display() {
        assert_eq!("lockstep".parse::<TransportSpec>().unwrap(), TransportSpec::Lockstep);
        assert_eq!("threaded".parse::<TransportSpec>().unwrap(), TransportSpec::Threaded(0));
        assert_eq!("threaded:4".parse::<TransportSpec>().unwrap(), TransportSpec::Threaded(4));
        assert_eq!("THREADED:2".parse::<TransportSpec>().unwrap(), TransportSpec::Threaded(2));
        assert_eq!("tcp".parse::<TransportSpec>().unwrap(), TransportSpec::Tcp(0));
        assert_eq!("tcp:4".parse::<TransportSpec>().unwrap(), TransportSpec::Tcp(4));
        assert_eq!("TCP:2".parse::<TransportSpec>().unwrap(), TransportSpec::Tcp(2));
        assert!("sockets".parse::<TransportSpec>().is_err());
        assert!("threaded:x".parse::<TransportSpec>().is_err());
        assert!("tcp:x".parse::<TransportSpec>().is_err());
        let all = [
            TransportSpec::Lockstep,
            TransportSpec::Threaded(0),
            TransportSpec::Threaded(8),
            TransportSpec::Tcp(0),
            TransportSpec::Tcp(8),
        ];
        for t in all {
            assert_eq!(t.to_string().parse::<TransportSpec>().unwrap(), t);
        }
    }

    #[test]
    fn transport_worker_resolution() {
        assert_eq!(TransportSpec::Lockstep.resolved_workers(16), 1);
        assert_eq!(TransportSpec::Threaded(4).resolved_workers(16), 4);
        // Never more workers than clients; auto resolves to ≥ 1.
        assert_eq!(TransportSpec::Threaded(8).resolved_workers(3), 3);
        assert!(TransportSpec::Threaded(0).resolved_workers(64) >= 1);
        assert_eq!(TransportSpec::Threaded(4).resolved_workers(0), 1);
        // Tcp resolves exactly like Threaded.
        assert_eq!(TransportSpec::Tcp(4).resolved_workers(16), 4);
        assert_eq!(TransportSpec::Tcp(8).resolved_workers(3), 3);
        assert!(TransportSpec::Tcp(0).resolved_workers(64) >= 1);
    }

    #[test]
    fn fingerprint_ignores_transport_backend() {
        // Backends are bit-identical by contract, so resume must treat rows
        // recorded under either backend as the same run.
        let lock = RunConfig { transport: TransportSpec::Lockstep, ..RunConfig::default() };
        let thr = RunConfig { transport: TransportSpec::Threaded(4), ..RunConfig::default() };
        let tcp = RunConfig { transport: TransportSpec::Tcp(2), ..RunConfig::default() };
        assert_eq!(lock.fingerprint(), thr.fingerprint());
        assert_eq!(lock.fingerprint(), tcp.fingerprint());
    }

    #[test]
    fn effective_basis_defaults() {
        let mut cfg = RunConfig::default();
        cfg.algorithm = Algorithm::Bl1;
        assert_eq!(cfg.effective_basis(), BasisKind::Subspace);
        cfg.algorithm = Algorithm::Bl3;
        assert_eq!(cfg.effective_basis(), BasisKind::Psd);
        cfg.algorithm = Algorithm::FedNl;
        assert_eq!(cfg.effective_basis(), BasisKind::Standard);
        cfg.basis = Some(BasisKind::SymTri);
        assert_eq!(cfg.effective_basis(), BasisKind::SymTri);
    }
}
