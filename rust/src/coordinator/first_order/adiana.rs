//! ADIANA [Li, Kovalev, Qian, Richtárik 2020]: Nesterov-accelerated DIANA.
//!
//! Three sequences `y, z, w` plus shift memories. Per round:
//!
//! `x^k = θ₁ z^k + θ₂ w^k + (1−θ₁−θ₂) y^k`
//! `g^k = (1/n) Σ [h_i + Q(∇f_i(x^k) − h_i)]`
//! `y^{k+1} = x^k − η g^k`
//! `z^{k+1} = β z^k + (1−β) x^k + (γ/η)(y^{k+1} − x^k)`
//! `w^{k+1} = y^k` with probability `q`, else unchanged (shift anchor), and
//! on anchor renewal the shifts absorb a compressed correction toward
//! `∇f_i(w)`.
//!
//! Parameters follow the strongly convex setting of the ADIANA paper:
//! `α = 1/(ω+1)`, `q = α/2`,
//! `η = min{ 1/(2L(1+2ω/n)), n/(64ω L) }` (second term only when ω>0),
//! `θ₂ = ½`, `θ₁ = min{¼, √(ημ/q)/2}…` capped below ½,
//! `γ = η/(2(θ₁+ημ))`, `β = 1 − γμ`.
//!
//! Exchanges: 0 broadcasts the extrapolated point (`d` floats down,
//! compressed innovation up); on `q`-renewal rounds, exchange 1 sends the
//! new anchor `w = y^k` (uncharged, as the reference accounting — clients
//! could reconstruct it from accepted history) and takes the compressed
//! shift correction up.

use crate::compressors::{BitCost, CompressorClass, VecCompressor};
use crate::coordinator::{Env, RoundPlan, ServerState};
use crate::linalg::Vector;
use crate::problem::LocalProblem;
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::Result;

/// ADIANA server.
pub struct AdianaServer {
    y: Vector,
    z: Vector,
    w: Vector,
    x: Vector,
    /// Server-side shift copies.
    shifts: Vec<Vector>,
    comp_name: String,
    eta: f64,
    theta1: f64,
    theta2: f64,
    gamma: f64,
    beta: f64,
    alpha: f64,
    q: f64,
    /// `y^{k+1}`, committed once the round's exchanges are done (the
    /// renewal anchor is the *old* `y^k`).
    pending_y: Option<Vector>,
}

/// ADIANA client.
pub struct AdianaClient {
    shift: Vector,
    comp: Box<dyn VecCompressor>,
    lambda: f64,
    alpha: f64,
}

/// Build the ADIANA split.
pub fn split(env: &Env) -> (AdianaServer, Vec<AdianaClient>) {
    let d = env.d;
    let n = env.n as f64;
    let probe = env.cfg.grad_comp.build_vec(d);
    let omega = match probe.class_vec(d) {
        CompressorClass::Unbiased { omega } => omega,
        CompressorClass::Contractive { delta } => 1.0 / delta - 1.0,
    };
    let ell = env.smoothness;
    let mu = env.cfg.lambda.max(1e-12);
    let alpha = 1.0 / (omega + 1.0);
    let q = alpha / 2.0;
    let mut eta = 1.0 / (2.0 * ell * (1.0 + 2.0 * omega / n));
    if omega > 0.0 {
        eta = eta.min(n / (64.0 * omega * ell));
    }
    if let Some(g) = env.cfg.gamma {
        eta = g;
    }
    let theta2 = 0.5;
    let theta1 = (eta * mu / q).sqrt().min(0.25).max(1e-6);
    let gamma = eta / (2.0 * (theta1 + eta * mu));
    let beta = (1.0 - gamma * mu).max(0.0);
    let x0 = vec![0.0; d];
    let clients = (0..env.n)
        .map(|_| AdianaClient {
            shift: vec![0.0; d],
            comp: env.cfg.grad_comp.build_vec(d),
            lambda: env.cfg.lambda,
            alpha,
        })
        .collect();
    let server = AdianaServer {
        y: x0.clone(),
        z: x0.clone(),
        w: x0.clone(),
        x: x0,
        shifts: vec![vec![0.0; d]; env.n],
        comp_name: VecCompressor::name(probe.as_ref()),
        eta,
        theta1,
        theta2,
        gamma,
        beta,
        alpha,
        q,
        pending_y: None,
    };
    (server, clients)
}

impl ServerState for AdianaServer {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        let d = env.d;
        match exchange {
            0 => {
                // Extrapolated point.
                for k in 0..d {
                    self.x[k] = self.theta1 * self.z[k]
                        + self.theta2 * self.w[k]
                        + (1.0 - self.theta1 - self.theta2) * self.y[k];
                }
                let mut down = Packet::empty();
                down.push_vector("model", self.x.clone(), BitCost::floats(d));
                Ok(Some(RoundPlan::broadcast(env.n, down)))
            }
            1 => {
                // Anchor renewal with probability q.
                if rng.bernoulli(self.q) {
                    self.w = self.y.clone();
                    let mut down = Packet::empty();
                    down.push_vector("anchor", self.w.clone(), BitCost::zero());
                    Ok(Some(RoundPlan::broadcast(env.n, down)))
                } else {
                    self.commit_y();
                    Ok(None)
                }
            }
            _ => {
                self.commit_y();
                Ok(None)
            }
        }
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        let n = env.n as f64;
        let d = env.d;
        match exchange {
            0 => {
                // Compressed gradient estimate at x.
                let mut g_est = vec![0.0; d];
                for (i, up) in replies {
                    let delta = up.vector("delta")?;
                    crate::linalg::axpy(1.0 / n, &self.shifts[*i], &mut g_est);
                    crate::linalg::axpy(1.0 / n, delta, &mut g_est);
                }
                // y, z updates (y commits at end of round).
                let y_next: Vector = self
                    .x
                    .iter()
                    .zip(&g_est)
                    .map(|(xi, gi)| xi - self.eta * gi)
                    .collect();
                for k in 0..d {
                    self.z[k] = self.beta * self.z[k]
                        + (1.0 - self.beta) * self.x[k]
                        + (self.gamma / self.eta) * (y_next[k] - self.x[k]);
                }
                self.pending_y = Some(y_next);
            }
            _ => {
                // Shifts absorb the compressed correction toward ∇f_i(w).
                for (i, up) in replies {
                    let delta = up.vector("delta")?;
                    crate::linalg::axpy(self.alpha, delta, &mut self.shifts[*i]);
                }
            }
        }
        Ok(())
    }

    /// ADIANA's deployable iterate is `y^k`.
    fn x(&self) -> &[f64] {
        &self.y
    }

    fn label(&self) -> String {
        format!("adiana[{}]", self.comp_name)
    }
}

impl AdianaServer {
    fn commit_y(&mut self) {
        if let Some(y) = self.pending_y.take() {
            self.y = y;
        }
    }
}

impl ClientStep for AdianaClient {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        exchange: usize,
        down: &Downlink,
        rng: &mut Rng,
    ) -> Result<Uplink> {
        let mut up = Packet::empty();
        if exchange == 0 {
            // Innovation at the extrapolated point; shifts do NOT move here
            // (only on anchor renewal — ADIANA's difference from DIANA).
            let x = down.vector("model")?;
            let mut gi = local.grad(x);
            crate::linalg::axpy(self.lambda, x, &mut gi);
            let diff = crate::linalg::sub(&gi, &self.shift);
            let (delta, cost) = self.comp.compress_vec(&diff, rng);
            up.push_vector("delta", delta, cost);
        } else {
            let w = down.vector("anchor")?;
            let mut gw = local.grad(w);
            crate::linalg::axpy(self.lambda, w, &mut gw);
            let diff = crate::linalg::sub(&gw, &self.shift);
            let (delta, cost) = self.comp.compress_vec(&diff, rng);
            crate::linalg::axpy(self.alpha, &delta, &mut self.shift);
            up.push_vector("delta", delta, cost);
        }
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 63,
        })
    }

    #[test]
    fn adiana_converges() {
        let cfg = RunConfig {
            algorithm: Algorithm::Adiana,
            rounds: 40_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Dithering(None),
            target_gap: 1e-8,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-8, "gap={}", out.final_gap());
    }

    #[test]
    fn adiana_acceleration_beats_plain_gd_on_ill_conditioned_quadratic() {
        // Acceleration check at ω = 0 (identity compressor), where ADIANA
        // reduces to accelerated compressed GD: on a κ = 10³ quadratic it
        // must need far fewer rounds than plain GD (√κ vs κ). Logistic
        // instances won't do — their *local* conditioning near x* is mild,
        // so constants dominate. (Against DIANA with both methods on
        // theoretical stepsizes the ordering is instance-dependent; the
        // paper's Fig. 1 row 2 likewise shows them close together and both
        // far behind BL1.)
        use crate::coordinator::run_federated_with;
        use crate::problem::{LocalProblem, QuadraticProblem};
        let d = 20;
        let mut rng = crate::rng::Rng::new(90);
        // Shared planted spectrum: log-spaced eigenvalues in [1e-3, 1].
        let q = crate::linalg::Mat::diag(
            &(0..d)
                .map(|i| 1e-3_f64 * (1e3_f64).powf(i as f64 / (d - 1) as f64))
                .collect::<Vec<_>>(),
        );
        let locals: Vec<Box<dyn LocalProblem>> = (0..4)
            .map(|_| {
                let c: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
                Box::new(QuadraticProblem::new(q.clone(), c)) as Box<dyn LocalProblem>
            })
            .collect();
        let features = vec![None; 4];
        let mk = |algorithm| RunConfig {
            algorithm,
            rounds: 2_000_000,
            lambda: 1e-3, // = μ of the planted spectrum (clients fold λ into their gradients)
            grad_comp: CompressorSpec::Identity,
            target_gap: 1e-8,
            ..RunConfig::default()
        };
        let gd = run_federated_with(&locals, features.clone(), &mk(Algorithm::Gd)).unwrap();
        let ad = run_federated_with(&locals, features, &mk(Algorithm::Adiana)).unwrap();
        assert!(
            (ad.history.records.len() as f64) < 0.35 * gd.history.records.len() as f64,
            "adiana {} rounds vs gd {}",
            ad.history.records.len(),
            gd.history.records.len()
        );
    }
}
