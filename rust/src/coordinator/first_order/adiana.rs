//! ADIANA [Li, Kovalev, Qian, Richtárik 2020]: Nesterov-accelerated DIANA.
//!
//! Three sequences `y, z, w` plus shift memories. Per round:
//!
//! `x^k = θ₁ z^k + θ₂ w^k + (1−θ₁−θ₂) y^k`
//! `g^k = (1/n) Σ [h_i + Q(∇f_i(x^k) − h_i)]`
//! `y^{k+1} = x^k − η g^k`
//! `z^{k+1} = β z^k + (1−β) x^k + (γ/η)(y^{k+1} − x^k)`
//! `w^{k+1} = y^k` with probability `q`, else unchanged (shift anchor), and
//! on anchor renewal the shifts absorb a compressed correction toward
//! `∇f_i(w)`.
//!
//! Parameters follow the strongly convex setting of the ADIANA paper:
//! `α = 1/(ω+1)`, `q = α/2`,
//! `η = min{ 1/(2L(1+2ω/n)), n/(64ω L) }` (second term only when ω>0),
//! `θ₂ = ½`, `θ₁ = min{¼, √(ημ/q)/2}…` capped below ½,
//! `γ = η/(2(θ₁+ημ))`, `β = 1 − γμ`.

use crate::compressors::{BitCost, CompressorClass, VecCompressor};
use crate::coordinator::{CommTally, Env, Method, StepInfo};
use crate::linalg::Vector;
use crate::rng::Rng;
use anyhow::Result;

/// ADIANA state.
pub struct Adiana {
    y: Vector,
    z: Vector,
    w: Vector,
    x: Vector,
    shifts: Vec<Vector>,
    comp: Box<dyn VecCompressor>,
    eta: f64,
    theta1: f64,
    theta2: f64,
    gamma: f64,
    beta: f64,
    alpha: f64,
    q: f64,
    mu: f64,
}

impl Adiana {
    pub fn new(env: &Env) -> Self {
        let d = env.d;
        let n = env.n as f64;
        let comp = env.cfg.grad_comp.build_vec(d);
        let omega = match comp.class_vec(d) {
            CompressorClass::Unbiased { omega } => omega,
            CompressorClass::Contractive { delta } => 1.0 / delta - 1.0,
        };
        let ell = env.smoothness;
        let mu = env.cfg.lambda.max(1e-12);
        let alpha = 1.0 / (omega + 1.0);
        let q = alpha / 2.0;
        let mut eta = 1.0 / (2.0 * ell * (1.0 + 2.0 * omega / n));
        if omega > 0.0 {
            eta = eta.min(n / (64.0 * omega * ell));
        }
        if let Some(g) = env.cfg.gamma {
            eta = g;
        }
        let theta2 = 0.5;
        let theta1 = (eta * mu / q).sqrt().min(0.25).max(1e-6);
        let gamma = eta / (2.0 * (theta1 + eta * mu));
        let beta = (1.0 - gamma * mu).max(0.0);
        let x0 = vec![0.0; d];
        Adiana {
            y: x0.clone(),
            z: x0.clone(),
            w: x0.clone(),
            x: x0.clone(),
            shifts: vec![vec![0.0; d]; env.n],
            comp,
            eta,
            theta1,
            theta2,
            gamma,
            beta,
            alpha,
            q,
            mu,
        }
    }
}

impl Method for Adiana {
    fn step(&mut self, env: &Env, _round: usize, rng: &mut Rng) -> Result<StepInfo> {
        let _ = self.mu;
        let mut tally = CommTally::default();
        let n = env.n as f64;
        let d = env.d;

        // Extrapolated point.
        for k in 0..d {
            self.x[k] = self.theta1 * self.z[k]
                + self.theta2 * self.w[k]
                + (1.0 - self.theta1 - self.theta2) * self.y[k];
        }

        // Compressed gradient estimate at x.
        let mut g_est = vec![0.0; d];
        for i in 0..env.n {
            let gi = env.grad_reg(i, &self.x);
            let diff = crate::linalg::sub(&gi, &self.shifts[i]);
            let (delta, cost) = self.comp.compress_vec(&diff, rng);
            tally.up(cost, env.cfg.float_bits);
            tally.down(BitCost::floats(d), env.cfg.float_bits);
            crate::linalg::axpy(1.0 / n, &self.shifts[i], &mut g_est);
            crate::linalg::axpy(1.0 / n, &delta, &mut g_est);
        }

        // y, z updates.
        let y_next: Vector = self
            .x
            .iter()
            .zip(&g_est)
            .map(|(xi, gi)| xi - self.eta * gi)
            .collect();
        for k in 0..d {
            self.z[k] = self.beta * self.z[k]
                + (1.0 - self.beta) * self.x[k]
                + (self.gamma / self.eta) * (y_next[k] - self.x[k]);
        }

        // Anchor renewal with probability q; shifts absorb a compressed
        // correction toward ∇f_i(w^{k+1}).
        if rng.bernoulli(self.q) {
            self.w = self.y.clone();
            for i in 0..env.n {
                let gw = env.grad_reg(i, &self.w);
                let diff = crate::linalg::sub(&gw, &self.shifts[i]);
                let (delta, cost) = self.comp.compress_vec(&diff, rng);
                tally.up(cost, env.cfg.float_bits);
                crate::linalg::axpy(self.alpha, &delta, &mut self.shifts[i]);
            }
        }
        self.y = y_next;

        Ok(tally.into_step())
    }

    /// ADIANA's deployable iterate is `y^k`.
    fn x(&self) -> &[f64] {
        &self.y
    }

    fn label(&self) -> String {
        format!("adiana[{}]", VecCompressor::name(self.comp.as_ref()))
    }
}

#[cfg(test)]
mod tests {
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 63,
        })
    }

    #[test]
    fn adiana_converges() {
        let cfg = RunConfig {
            algorithm: Algorithm::Adiana,
            rounds: 40_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Dithering(None),
            target_gap: 1e-8,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-8, "gap={}", out.final_gap());
    }

    #[test]
    fn adiana_acceleration_beats_plain_gd_on_ill_conditioned_quadratic() {
        // Acceleration check at ω = 0 (identity compressor), where ADIANA
        // reduces to accelerated compressed GD: on a κ = 10³ quadratic it
        // must need far fewer rounds than plain GD (√κ vs κ). Logistic
        // instances won't do — their *local* conditioning near x* is mild,
        // so constants dominate. (Against DIANA with both methods on
        // theoretical stepsizes the ordering is instance-dependent; the
        // paper's Fig. 1 row 2 likewise shows them close together and both
        // far behind BL1.)
        use crate::coordinator::run_federated_with;
        use crate::problem::{LocalProblem, QuadraticProblem};
        let d = 20;
        let mut rng = crate::rng::Rng::new(90);
        // Shared planted spectrum: log-spaced eigenvalues in [1e-3, 1].
        let q = crate::linalg::Mat::diag(
            &(0..d)
                .map(|i| 1e-3_f64 * (1e3_f64).powf(i as f64 / (d - 1) as f64))
                .collect::<Vec<_>>(),
        );
        let locals: Vec<Box<dyn LocalProblem>> = (0..4)
            .map(|_| {
                let c: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
                Box::new(QuadraticProblem::new(q.clone(), c)) as Box<dyn LocalProblem>
            })
            .collect();
        let features = vec![None; 4];
        let mk = |algorithm| RunConfig {
            algorithm,
            rounds: 2_000_000,
            lambda: 1e-3, // = μ of the planted spectrum (λ is folded via grad_reg)
            grad_comp: CompressorSpec::Identity,
            target_gap: 1e-8,
            ..RunConfig::default()
        };
        let gd = run_federated_with(&locals, features.clone(), &mk(Algorithm::Gd)).unwrap();
        let ad = run_federated_with(&locals, features, &mk(Algorithm::Adiana)).unwrap();
        assert!(
            (ad.history.records.len() as f64) < 0.35 * gd.history.records.len() as f64,
            "adiana {} rounds vs gd {}",
            ad.history.records.len(),
            gd.history.records.len()
        );
    }
}
