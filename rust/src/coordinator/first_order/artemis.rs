//! Artemis [Philippenko & Dieuleveut 2021]: bidirectional compression with
//! uplink memory and partial participation.
//!
//! Uplink: DIANA-style compressed gradient differences with shift memories
//! `h_i` (only participating clients upload; the estimate mixes their
//! innovations at rate n/τ). Downlink: the server compresses the model
//! *update* and every client (participating or not, per the preserved
//! central-model variant) applies the same broadcast.
//!
//! Exchanges: 0 polls the sampled participants (compressed innovation +
//! participation bit up); 1 broadcasts the compressed model update to
//! every client.

use crate::compressors::{BitCost, CompressorClass, VecCompressor};
use crate::coordinator::{sample_clients, Env, RoundPlan, ServerState};
use crate::linalg::Vector;
use crate::problem::LocalProblem;
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::Result;

/// Artemis server.
pub struct ArtemisServer {
    /// Server model.
    x: Vector,
    /// Server copy of the clients' shared model view.
    x_client: Vector,
    /// Server-side shift copies.
    shifts: Vec<Vector>,
    down_comp: Box<dyn VecCompressor>,
    gamma: f64,
    alpha: f64,
}

/// Artemis client.
pub struct ArtemisClient {
    /// This client's view of the model (identical across clients: same
    /// broadcast).
    x_view: Vector,
    shift: Vector,
    up_comp: Box<dyn VecCompressor>,
    lambda: f64,
    alpha: f64,
}

/// Build the Artemis split.
pub fn split(env: &Env) -> (ArtemisServer, Vec<ArtemisClient>) {
    let d = env.d;
    let probe_up = env.cfg.grad_comp.build_vec(d);
    let down_comp = env.cfg.model_comp.build_vec(d);
    let omega = match probe_up.class_vec(d) {
        CompressorClass::Unbiased { omega } => omega,
        CompressorClass::Contractive { delta } => 1.0 / delta - 1.0,
    };
    let omega_down = match down_comp.class_vec(d) {
        CompressorClass::Unbiased { omega } => omega,
        CompressorClass::Contractive { delta } => 1.0 / delta - 1.0,
    };
    let tau = env.cfg.tau.unwrap_or(env.n) as f64;
    let n = env.n as f64;
    // Stepsize shaped by both compressions and participation
    // (Artemis Thm. conditions, conservative form).
    let gamma = env.cfg.gamma.unwrap_or(
        1.0 / (env.smoothness * (1.0 + omega_down) * (1.0 + 8.0 * omega * (n / tau) / n)),
    );
    let alpha = 1.0 / (omega + 1.0);
    let clients = (0..env.n)
        .map(|_| ArtemisClient {
            x_view: vec![0.0; d],
            shift: vec![0.0; d],
            up_comp: env.cfg.grad_comp.build_vec(d),
            lambda: env.cfg.lambda,
            alpha,
        })
        .collect();
    let server = ArtemisServer {
        x: vec![0.0; d],
        x_client: vec![0.0; d],
        shifts: vec![vec![0.0; d]; env.n],
        down_comp,
        gamma,
        alpha,
    };
    (server, clients)
}

impl ServerState for ArtemisServer {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        match exchange {
            0 => {
                let selected = sample_clients(env.n, env.cfg.tau, rng);
                let sends = selected.into_iter().map(|i| (i, Packet::empty())).collect();
                Ok(Some(RoundPlan::to_clients(sends)))
            }
            1 => {
                // Server update + compressed model broadcast.
                let upd = crate::linalg::sub(&self.x, &self.x_client);
                let (cupd, dcost) = self.down_comp.compress_vec(&upd, rng);
                crate::linalg::axpy(1.0, &cupd, &mut self.x_client);
                let mut down = Packet::empty();
                down.push_vector("model_update", cupd, dcost);
                Ok(Some(RoundPlan::broadcast(env.n, down)))
            }
            _ => Ok(None),
        }
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        if exchange != 0 {
            return Ok(());
        }
        let n = env.n as f64;
        let tau_eff = replies.len() as f64;
        // All memories contribute (the server stores them); participants
        // add fresh innovations, reweighted by n/τ.
        let mut g_est = vec![0.0; env.d];
        for shift in &self.shifts {
            crate::linalg::axpy(1.0 / n, shift, &mut g_est);
        }
        for (i, up) in replies {
            let delta = up.vector("delta")?;
            crate::linalg::axpy(1.0 / tau_eff, delta, &mut g_est);
            crate::linalg::axpy(self.alpha, delta, &mut self.shifts[*i]);
        }
        crate::linalg::axpy(-self.gamma, &g_est, &mut self.x);
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        "artemis".into()
    }
}

impl ClientStep for ArtemisClient {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        exchange: usize,
        down: &Downlink,
        rng: &mut Rng,
    ) -> Result<Uplink> {
        let mut up = Packet::empty();
        if exchange == 0 {
            let mut gi = local.grad(&self.x_view);
            crate::linalg::axpy(self.lambda, &self.x_view, &mut gi);
            let diff = crate::linalg::sub(&gi, &self.shift);
            let (delta, cost) = self.up_comp.compress_vec(&diff, rng);
            crate::linalg::axpy(self.alpha, &delta, &mut self.shift);
            // The participation bit rides the uplink.
            up.push_vector("delta", delta, cost + BitCost::bits(1.0));
        } else {
            let cupd = down.vector("model_update")?;
            crate::linalg::axpy(1.0, cupd, &mut self.x_view);
        }
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 6,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 65,
        })
    }

    #[test]
    fn artemis_converges_full_participation() {
        let cfg = RunConfig {
            algorithm: Algorithm::Artemis,
            rounds: 60_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Dithering(None),
            model_comp: CompressorSpec::Dithering(None),
            target_gap: 1e-7,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-7, "gap={}", out.final_gap());
    }

    #[test]
    fn artemis_converges_partial_participation() {
        let cfg = RunConfig {
            algorithm: Algorithm::Artemis,
            rounds: 100_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Dithering(None),
            model_comp: CompressorSpec::Identity,
            tau: Some(3),
            target_gap: 1e-6,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-6, "gap={}", out.final_gap());
    }
}
