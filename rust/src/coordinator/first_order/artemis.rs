//! Artemis [Philippenko & Dieuleveut 2021]: bidirectional compression with
//! uplink memory and partial participation.
//!
//! Uplink: DIANA-style compressed gradient differences with shift memories
//! `h_i` (only participating clients upload; the estimate mixes their
//! innovations at rate n/τ). Downlink: the server compresses the model
//! *update* and every client (participating or not, per the preserved
//! central-model variant) applies the same broadcast.

use crate::compressors::{BitCost, CompressorClass, VecCompressor};
use crate::coordinator::{sample_clients, CommTally, Env, Method, StepInfo};
use crate::linalg::Vector;
use crate::rng::Rng;
use anyhow::Result;

/// Artemis state.
pub struct Artemis {
    /// Server model.
    x: Vector,
    /// Clients' view of the model (identical across clients: same broadcast).
    x_client: Vector,
    shifts: Vec<Vector>,
    up: Box<dyn VecCompressor>,
    down: Box<dyn VecCompressor>,
    gamma: f64,
    alpha: f64,
}

impl Artemis {
    pub fn new(env: &Env) -> Self {
        let d = env.d;
        let up = env.cfg.grad_comp.build_vec(d);
        let down = env.cfg.model_comp.build_vec(d);
        let omega = match up.class_vec(d) {
            CompressorClass::Unbiased { omega } => omega,
            CompressorClass::Contractive { delta } => 1.0 / delta - 1.0,
        };
        let omega_down = match down.class_vec(d) {
            CompressorClass::Unbiased { omega } => omega,
            CompressorClass::Contractive { delta } => 1.0 / delta - 1.0,
        };
        let tau = env.cfg.tau.unwrap_or(env.n) as f64;
        let n = env.n as f64;
        // Stepsize shaped by both compressions and participation
        // (Artemis Thm. conditions, conservative form).
        let gamma = env.cfg.gamma.unwrap_or(
            1.0 / (env.smoothness
                * (1.0 + omega_down)
                * (1.0 + 8.0 * omega * (n / tau) / n)),
        );
        Artemis {
            x: vec![0.0; d],
            x_client: vec![0.0; d],
            shifts: vec![vec![0.0; d]; env.n],
            up,
            down,
            gamma,
            alpha: 1.0 / (omega + 1.0),
        }
    }
}

impl Method for Artemis {
    fn step(&mut self, env: &Env, _round: usize, rng: &mut Rng) -> Result<StepInfo> {
        let mut tally = CommTally::default();
        let n = env.n as f64;
        let d = env.d;
        let selected = sample_clients(env.n, env.cfg.tau, rng);
        let tau_eff = selected.len() as f64;

        // Uplink: compressed innovations from participants.
        let mut g_est = vec![0.0; d];
        // All memories contribute (server stores them); participants add
        // fresh innovations, reweighted by n/τ.
        for i in 0..env.n {
            crate::linalg::axpy(1.0 / n, &self.shifts[i], &mut g_est);
        }
        for &i in &selected {
            let gi = env.grad_reg(i, &self.x_client);
            let diff = crate::linalg::sub(&gi, &self.shifts[i]);
            let (delta, cost) = self.up.compress_vec(&diff, rng);
            tally.up(cost + BitCost::bits(1.0), env.cfg.float_bits);
            crate::linalg::axpy(1.0 / tau_eff, &delta, &mut g_est);
            crate::linalg::axpy(self.alpha, &delta, &mut self.shifts[i]);
        }

        // Server update + compressed model broadcast.
        crate::linalg::axpy(-self.gamma, &g_est, &mut self.x);
        let upd = crate::linalg::sub(&self.x, &self.x_client);
        let (cupd, dcost) = self.down.compress_vec(&upd, rng);
        for _ in 0..env.n {
            tally.down(dcost, env.cfg.float_bits);
        }
        crate::linalg::axpy(1.0, &cupd, &mut self.x_client);

        Ok(tally.into_step())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        "artemis".into()
    }
}

#[cfg(test)]
mod tests {
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 6,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 65,
        })
    }

    #[test]
    fn artemis_converges_full_participation() {
        let cfg = RunConfig {
            algorithm: Algorithm::Artemis,
            rounds: 60_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Dithering(None),
            model_comp: CompressorSpec::Dithering(None),
            target_gap: 1e-7,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-7, "gap={}", out.final_gap());
    }

    #[test]
    fn artemis_converges_partial_participation() {
        let cfg = RunConfig {
            algorithm: Algorithm::Artemis,
            rounds: 100_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Dithering(None),
            model_comp: CompressorSpec::Identity,
            tau: Some(3),
            target_gap: 1e-6,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-6, "gap={}", out.final_gap());
    }
}
