//! DIANA [Mishchenko et al. 2019]: compressed gradient *differences* with
//! client-side shift memories.
//!
//! `Δ_i^k = Q(∇f_i(x^k) − h_i^k)`, `h_i^{k+1} = h_i^k + α Δ_i^k`,
//! `x^{k+1} = x^k − γ (1/n)Σ(h_i^k + Δ_i^k)`.
//!
//! Theoretical parameters (strongly convex case): `α = 1/(ω+1)`,
//! `γ = 1/(L(1 + 6ω/n))`.
//!
//! One exchange per round: model broadcast down (`d` floats), compressed
//! innovation `Δ_i` up. Shift memories live on both sides of the wire and
//! stay in sync by applying the identical `+ α Δ_i` update.

use crate::compressors::{BitCost, CompressorClass, VecCompressor};
use crate::coordinator::{Env, RoundPlan, ServerState};
use crate::linalg::Vector;
use crate::problem::LocalProblem;
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::Result;

/// DIANA server: model + server-side shift copies.
pub struct DianaServer {
    x: Vector,
    /// Shift memories `h_i` (server copies).
    shifts: Vec<Vector>,
    comp_name: String,
    gamma: f64,
    alpha: f64,
}

/// DIANA client: its shift memory + compressor.
pub struct DianaClient {
    shift: Vector,
    comp: Box<dyn VecCompressor>,
    lambda: f64,
    alpha: f64,
}

/// Build the DIANA split.
pub fn split(env: &Env) -> (DianaServer, Vec<DianaClient>) {
    let d = env.d;
    let probe = env.cfg.grad_comp.build_vec(d);
    let omega = match probe.class_vec(d) {
        CompressorClass::Unbiased { omega } => omega,
        CompressorClass::Contractive { delta } => 1.0 / delta - 1.0, // conservative mapping
    };
    let alpha = 1.0 / (omega + 1.0);
    let gamma = env
        .cfg
        .gamma
        .unwrap_or(1.0 / (env.smoothness * (1.0 + 6.0 * omega / env.n as f64)));
    let clients = (0..env.n)
        .map(|_| DianaClient {
            shift: vec![0.0; d],
            comp: env.cfg.grad_comp.build_vec(d),
            lambda: env.cfg.lambda,
            alpha,
        })
        .collect();
    let server = DianaServer {
        x: vec![0.0; d],
        shifts: vec![vec![0.0; d]; env.n],
        comp_name: VecCompressor::name(probe.as_ref()),
        gamma,
        alpha,
    };
    (server, clients)
}

impl ServerState for DianaServer {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        _rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        if exchange != 0 {
            return Ok(None);
        }
        let mut down = Packet::empty();
        down.push_vector("model", self.x.clone(), BitCost::floats(env.d));
        Ok(Some(RoundPlan::broadcast(env.n, down)))
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        _exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        let n = env.n as f64;
        let mut g_est = vec![0.0; env.d];
        for (i, up) in replies {
            let delta = up.vector("delta")?;
            crate::linalg::axpy(1.0 / n, &self.shifts[*i], &mut g_est);
            crate::linalg::axpy(1.0 / n, delta, &mut g_est);
            crate::linalg::axpy(self.alpha, delta, &mut self.shifts[*i]);
        }
        crate::linalg::axpy(-self.gamma, &g_est, &mut self.x);
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        format!("diana[{}]", self.comp_name)
    }
}

impl ClientStep for DianaClient {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        _exchange: usize,
        down: &Downlink,
        rng: &mut Rng,
    ) -> Result<Uplink> {
        let x = down.vector("model")?;
        let mut gi = local.grad(x);
        crate::linalg::axpy(self.lambda, x, &mut gi);
        let diff = crate::linalg::sub(&gi, &self.shift);
        let (delta, cost) = self.comp.compress_vec(&diff, rng);
        crate::linalg::axpy(self.alpha, &delta, &mut self.shift);
        let mut up = Packet::empty();
        up.push_vector("delta", delta, cost);
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 62,
        })
    }

    #[test]
    fn diana_converges_with_dithering() {
        let cfg = RunConfig {
            algorithm: Algorithm::Diana,
            rounds: 30_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Dithering(None), // √d levels, the paper's choice
            target_gap: 1e-8,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-8, "gap={}", out.final_gap());
    }

    #[test]
    fn diana_uplink_cheaper_than_gd_per_round() {
        let mk = |algorithm, grad_comp| RunConfig {
            algorithm,
            rounds: 3,
            lambda: 1e-2,
            grad_comp,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let gd = run_federated(&fed(), &mk(Algorithm::Gd, CompressorSpec::Identity)).unwrap();
        let di = run_federated(
            &fed(),
            &mk(Algorithm::Diana, CompressorSpec::Dithering(None)),
        )
        .unwrap();
        let up = |o: &crate::coordinator::RunOutput| o.history.records[0].bits_up_per_node;
        assert!(up(&di) < up(&gd), "diana {} vs gd {}", up(&di), up(&gd));
    }
}
