//! DIANA [Mishchenko et al. 2019]: compressed gradient *differences* with
//! client-side shift memories.
//!
//! `Δ_i^k = Q(∇f_i(x^k) − h_i^k)`, `h_i^{k+1} = h_i^k + α Δ_i^k`,
//! `x^{k+1} = x^k − γ (1/n)Σ(h_i^k + Δ_i^k)`.
//!
//! Theoretical parameters (strongly convex case): `α = 1/(ω+1)`,
//! `γ = 1/(L(1 + 6ω/n))`.

use crate::compressors::{CompressorClass, VecCompressor};
use crate::compressors::BitCost;
use crate::coordinator::{CommTally, Env, Method, StepInfo};
use crate::linalg::Vector;
use crate::rng::Rng;
use anyhow::Result;

/// DIANA state.
pub struct Diana {
    x: Vector,
    /// Shift memories `h_i`.
    shifts: Vec<Vector>,
    comp: Box<dyn VecCompressor>,
    gamma: f64,
    alpha: f64,
}

impl Diana {
    pub fn new(env: &Env) -> Self {
        let d = env.d;
        let comp = env.cfg.grad_comp.build_vec(d);
        let omega = match comp.class_vec(d) {
            CompressorClass::Unbiased { omega } => omega,
            CompressorClass::Contractive { delta } => 1.0 / delta - 1.0, // conservative mapping
        };
        let alpha = 1.0 / (omega + 1.0);
        let gamma = env
            .cfg
            .gamma
            .unwrap_or(1.0 / (env.smoothness * (1.0 + 6.0 * omega / env.n as f64)));
        Diana {
            x: vec![0.0; d],
            shifts: vec![vec![0.0; d]; env.n],
            comp,
            gamma,
            alpha,
        }
    }
}

impl Method for Diana {
    fn step(&mut self, env: &Env, _round: usize, rng: &mut Rng) -> Result<StepInfo> {
        let mut tally = CommTally::default();
        let n = env.n as f64;
        let d = env.d;
        let mut g_est = vec![0.0; d];
        for i in 0..env.n {
            let gi = env.grad_reg(i, &self.x);
            let diff = crate::linalg::sub(&gi, &self.shifts[i]);
            let (delta, cost) = self.comp.compress_vec(&diff, rng);
            tally.up(cost, env.cfg.float_bits);
            tally.down(BitCost::floats(d), env.cfg.float_bits);
            crate::linalg::axpy(1.0 / n, &self.shifts[i], &mut g_est);
            crate::linalg::axpy(1.0 / n, &delta, &mut g_est);
            crate::linalg::axpy(self.alpha, &delta, &mut self.shifts[i]);
        }
        crate::linalg::axpy(-self.gamma, &g_est, &mut self.x);
        Ok(tally.into_step())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        format!("diana[{}]", VecCompressor::name(self.comp.as_ref()))
    }
}

#[cfg(test)]
mod tests {
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 62,
        })
    }

    #[test]
    fn diana_converges_with_dithering() {
        let cfg = RunConfig {
            algorithm: Algorithm::Diana,
            rounds: 30_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Dithering(None), // √d levels, the paper's choice
            target_gap: 1e-8,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-8, "gap={}", out.final_gap());
    }

    #[test]
    fn diana_uplink_cheaper_than_gd_per_round() {
        let mk = |algorithm, grad_comp| RunConfig {
            algorithm,
            rounds: 3,
            lambda: 1e-2,
            grad_comp,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let gd = run_federated(&fed(), &mk(Algorithm::Gd, CompressorSpec::Identity)).unwrap();
        let di = run_federated(
            &fed(),
            &mk(Algorithm::Diana, CompressorSpec::Dithering(None)),
        )
        .unwrap();
        let up = |o: &crate::coordinator::RunOutput| o.history.records[0].bits_up_per_node;
        assert!(up(&di) < up(&gd), "diana {} vs gd {}", up(&di), up(&gd));
    }
}
