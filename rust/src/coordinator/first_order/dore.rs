//! DORE [Liu, Li, Tang, Yan 2020]: DOuble REsidual compression.
//!
//! Uplink compresses gradient residuals against client memories `h_i`
//! (DIANA-style); downlink compresses the *model-update residual* with a
//! server-side error accumulator `e` so no information is permanently lost.
//! Clients therefore track a compressed mirror `x̂` of the server model and
//! the server corrects the residual next round.
//!
//! Exchanges: 0 polls every client at its mirror (compressed residual up);
//! 1 broadcasts the compressed model residual (both sides apply the same
//! damped update to their mirror copy).

use crate::compressors::{CompressorClass, VecCompressor};
use crate::coordinator::{Env, RoundPlan, ServerState};
use crate::linalg::Vector;
use crate::problem::LocalProblem;
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::Result;

/// DORE server.
pub struct DoreServer {
    /// Server model.
    x: Vector,
    /// Server copy of the clients' compressed mirror.
    x_hat: Vector,
    /// Server-side downlink residual accumulator.
    err: Vector,
    /// Server-side shift copies.
    shifts: Vec<Vector>,
    down_comp: Box<dyn VecCompressor>,
    gamma: f64,
    alpha: f64,
    /// Residual damping (DORE's β/η knob; 1 = plain residual).
    damping: f64,
}

/// DORE client.
pub struct DoreClient {
    /// Compressed mirror `x̂` of the server model.
    x_hat: Vector,
    shift: Vector,
    up_comp: Box<dyn VecCompressor>,
    lambda: f64,
    alpha: f64,
    damping: f64,
}

/// Build the DORE split.
pub fn split(env: &Env) -> (DoreServer, Vec<DoreClient>) {
    let d = env.d;
    let probe_up = env.cfg.grad_comp.build_vec(d);
    let down_comp = env.cfg.model_comp.build_vec(d);
    let omega = match probe_up.class_vec(d) {
        CompressorClass::Unbiased { omega } => omega,
        CompressorClass::Contractive { delta } => 1.0 / delta - 1.0,
    };
    let omega_d = match down_comp.class_vec(d) {
        CompressorClass::Unbiased { omega } => omega,
        CompressorClass::Contractive { delta } => 1.0 / delta - 1.0,
    };
    let gamma = env
        .cfg
        .gamma
        .unwrap_or(1.0 / (env.smoothness * (1.0 + 4.0 * omega / env.n as f64) * (1.0 + omega_d)));
    let alpha = 1.0 / (omega + 1.0);
    let damping = 1.0 / (omega_d + 1.0);
    let clients = (0..env.n)
        .map(|_| DoreClient {
            x_hat: vec![0.0; d],
            shift: vec![0.0; d],
            up_comp: env.cfg.grad_comp.build_vec(d),
            lambda: env.cfg.lambda,
            alpha,
            damping,
        })
        .collect();
    let server = DoreServer {
        x: vec![0.0; d],
        x_hat: vec![0.0; d],
        err: vec![0.0; d],
        shifts: vec![vec![0.0; d]; env.n],
        down_comp,
        gamma,
        alpha,
        damping,
    };
    (server, clients)
}

impl ServerState for DoreServer {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        match exchange {
            0 => Ok(Some(RoundPlan::broadcast(env.n, Packet::empty()))),
            1 => {
                // Downlink: compress (model residual + accumulated error).
                let mut q = crate::linalg::sub(&self.x, &self.x_hat);
                crate::linalg::axpy(1.0, &self.err, &mut q);
                let (cq, dcost) = self.down_comp.compress_vec(&q, rng);
                // Error feedback: whatever the compressor dropped carries
                // over to next round.
                self.err = crate::linalg::sub(&q, &cq);
                crate::linalg::axpy(self.damping, &cq, &mut self.x_hat);
                let mut down = Packet::empty();
                down.push_vector("model_residual", cq, dcost);
                Ok(Some(RoundPlan::broadcast(env.n, down)))
            }
            _ => Ok(None),
        }
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        if exchange != 0 {
            return Ok(());
        }
        let n = env.n as f64;
        let mut g_est = vec![0.0; env.d];
        for (i, up) in replies {
            let delta = up.vector("delta")?;
            crate::linalg::axpy(1.0 / n, &self.shifts[*i], &mut g_est);
            crate::linalg::axpy(1.0 / n, delta, &mut g_est);
            crate::linalg::axpy(self.alpha, delta, &mut self.shifts[*i]);
        }
        // Server model step.
        crate::linalg::axpy(-self.gamma, &g_est, &mut self.x);
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        "dore".into()
    }
}

impl ClientStep for DoreClient {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        exchange: usize,
        down: &Downlink,
        rng: &mut Rng,
    ) -> Result<Uplink> {
        let mut up = Packet::empty();
        if exchange == 0 {
            // Compressed gradient residual at the mirror x̂.
            let mut gi = local.grad(&self.x_hat);
            crate::linalg::axpy(self.lambda, &self.x_hat, &mut gi);
            let diff = crate::linalg::sub(&gi, &self.shift);
            let (delta, cost) = self.up_comp.compress_vec(&diff, rng);
            crate::linalg::axpy(self.alpha, &delta, &mut self.shift);
            up.push_vector("delta", delta, cost);
        } else {
            let cq = down.vector("model_residual")?;
            crate::linalg::axpy(self.damping, cq, &mut self.x_hat);
        }
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 66,
        })
    }

    #[test]
    fn dore_converges_bidirectional() {
        let cfg = RunConfig {
            algorithm: Algorithm::Dore,
            rounds: 100_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Dithering(None),
            model_comp: CompressorSpec::Dithering(None),
            target_gap: 1e-7,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-7, "gap={}", out.final_gap());
    }

    #[test]
    fn dore_identity_reduces_to_gd_like() {
        let cfg = RunConfig {
            algorithm: Algorithm::Dore,
            rounds: 20_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Identity,
            model_comp: CompressorSpec::Identity,
            target_gap: 1e-9,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-9, "gap={}", out.final_gap());
    }
}
