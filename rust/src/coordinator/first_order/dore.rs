//! DORE [Liu, Li, Tang, Yan 2020]: DOuble REsidual compression.
//!
//! Uplink compresses gradient residuals against client memories `h_i`
//! (DIANA-style); downlink compresses the *model-update residual* with a
//! server-side error accumulator `e` so no information is permanently lost.
//! Clients therefore track a compressed mirror `x̂` of the server model and
//! the server corrects the residual next round.

use crate::compressors::{CompressorClass, VecCompressor};
use crate::coordinator::{CommTally, Env, Method, StepInfo};
use crate::linalg::Vector;
use crate::rng::Rng;
use anyhow::Result;

/// DORE state.
pub struct Dore {
    /// Server model.
    x: Vector,
    /// Clients' compressed mirror of the model.
    x_hat: Vector,
    /// Server-side downlink residual accumulator.
    err: Vector,
    shifts: Vec<Vector>,
    up: Box<dyn VecCompressor>,
    down: Box<dyn VecCompressor>,
    gamma: f64,
    alpha: f64,
    /// Residual damping (DORE's β/η knob; 1 = plain residual).
    damping: f64,
}

impl Dore {
    pub fn new(env: &Env) -> Self {
        let d = env.d;
        let up = env.cfg.grad_comp.build_vec(d);
        let down = env.cfg.model_comp.build_vec(d);
        let omega = match up.class_vec(d) {
            CompressorClass::Unbiased { omega } => omega,
            CompressorClass::Contractive { delta } => 1.0 / delta - 1.0,
        };
        let omega_d = match down.class_vec(d) {
            CompressorClass::Unbiased { omega } => omega,
            CompressorClass::Contractive { delta } => 1.0 / delta - 1.0,
        };
        let gamma = env
            .cfg
            .gamma
            .unwrap_or(1.0 / (env.smoothness * (1.0 + 4.0 * omega / env.n as f64) * (1.0 + omega_d)));
        Dore {
            x: vec![0.0; d],
            x_hat: vec![0.0; d],
            err: vec![0.0; d],
            shifts: vec![vec![0.0; d]; env.n],
            up,
            down,
            gamma,
            alpha: 1.0 / (omega + 1.0),
            damping: 1.0 / (omega_d + 1.0),
        }
    }
}

impl Method for Dore {
    fn step(&mut self, env: &Env, _round: usize, rng: &mut Rng) -> Result<StepInfo> {
        let mut tally = CommTally::default();
        let n = env.n as f64;
        let d = env.d;

        // Uplink: compressed gradient residuals at the client mirror x̂.
        let mut g_est = vec![0.0; d];
        for i in 0..env.n {
            let gi = env.grad_reg(i, &self.x_hat);
            let diff = crate::linalg::sub(&gi, &self.shifts[i]);
            let (delta, cost) = self.up.compress_vec(&diff, rng);
            tally.up(cost, env.cfg.float_bits);
            crate::linalg::axpy(1.0 / n, &self.shifts[i], &mut g_est);
            crate::linalg::axpy(1.0 / n, &delta, &mut g_est);
            crate::linalg::axpy(self.alpha, &delta, &mut self.shifts[i]);
        }

        // Server model step.
        crate::linalg::axpy(-self.gamma, &g_est, &mut self.x);

        // Downlink: compress (model residual + accumulated error).
        let mut q = crate::linalg::sub(&self.x, &self.x_hat);
        crate::linalg::axpy(1.0, &self.err, &mut q);
        let (cq, dcost) = self.down.compress_vec(&q, rng);
        for _ in 0..env.n {
            tally.down(dcost, env.cfg.float_bits);
        }
        // Error feedback: whatever the compressor dropped is carried over.
        self.err = crate::linalg::sub(&q, &cq);
        crate::linalg::axpy(self.damping, &cq, &mut self.x_hat);

        Ok(tally.into_step())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        "dore".into()
    }
}

#[cfg(test)]
mod tests {
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 66,
        })
    }

    #[test]
    fn dore_converges_bidirectional() {
        let cfg = RunConfig {
            algorithm: Algorithm::Dore,
            rounds: 100_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Dithering(None),
            model_comp: CompressorSpec::Dithering(None),
            target_gap: 1e-7,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-7, "gap={}", out.final_gap());
    }

    #[test]
    fn dore_identity_reduces_to_gd_like() {
        let cfg = RunConfig {
            algorithm: Algorithm::Dore,
            rounds: 20_000,
            lambda: 1e-2,
            grad_comp: CompressorSpec::Identity,
            model_comp: CompressorSpec::Identity,
            target_gap: 1e-9,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-9, "gap={}", out.final_gap());
    }
}
