//! Vanilla distributed gradient descent: `x ← x − γ ∇f(x)`, `γ = 1/L`.
//! Clients upload exact gradients (`d` floats), server broadcasts the model.

use crate::compressors::BitCost;
use crate::coordinator::{CommTally, Env, Method, StepInfo};
use crate::linalg::Vector;
use crate::rng::Rng;
use anyhow::Result;

/// Distributed GD.
pub struct Gd {
    x: Vector,
    gamma: f64,
}

impl Gd {
    pub fn new(env: &Env) -> Self {
        let gamma = env.cfg.gamma.unwrap_or(1.0 / env.smoothness);
        Gd { x: vec![0.0; env.d], gamma }
    }
}

impl Method for Gd {
    fn step(&mut self, env: &Env, _round: usize, _rng: &mut Rng) -> Result<StepInfo> {
        let mut tally = CommTally::default();
        let n = env.n as f64;
        let d = env.d;
        let mut g = vec![0.0; d];
        for i in 0..env.n {
            crate::linalg::axpy(1.0 / n, &env.grad_reg(i, &self.x), &mut g);
            tally.up(BitCost::floats(d), env.cfg.float_bits);
            tally.down(BitCost::floats(d), env.cfg.float_bits);
        }
        crate::linalg::axpy(-self.gamma, &g, &mut self.x);
        Ok(tally.into_step())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        "gd".into()
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 61,
        })
    }

    #[test]
    fn gd_monotone_decrease_and_linear_rate() {
        let cfg = RunConfig {
            algorithm: Algorithm::Gd,
            rounds: 3000,
            lambda: 1e-2,
            target_gap: 1e-9,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        let gaps: Vec<f64> = out.history.records.iter().map(|r| r.gap).collect();
        for w in gaps.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "gap increased {} → {}", w[0], w[1]);
        }
        assert!(out.final_gap() <= 1e-9, "gap={}", out.final_gap());
    }

    #[test]
    fn gd_is_condition_number_limited() {
        // Smaller λ ⇒ worse conditioning ⇒ more rounds to the same gap.
        let mk = |lambda: f64| RunConfig {
            algorithm: Algorithm::Gd,
            rounds: 20_000,
            lambda,
            target_gap: 1e-6,
            ..RunConfig::default()
        };
        let fast = run_federated(&fed(), &mk(1e-1)).unwrap();
        let slow = run_federated(&fed(), &mk(1e-3)).unwrap();
        assert!(
            slow.history.records.len() > 2 * fast.history.records.len(),
            "λ=1e-3 took {} rounds, λ=1e-1 took {}",
            slow.history.records.len(),
            fast.history.records.len()
        );
    }
}
