//! Vanilla distributed gradient descent: `x ← x − γ ∇f(x)`, `γ = 1/L`.
//! One exchange per round: the server broadcasts the model (`d` floats
//! down), clients upload exact regularized gradients (`d` floats up).

use crate::compressors::BitCost;
use crate::coordinator::{Env, RoundPlan, ServerState};
use crate::linalg::Vector;
use crate::problem::LocalProblem;
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::Result;

/// GD server.
pub struct GdServer {
    x: Vector,
    gamma: f64,
}

/// GD client (stateless beyond the ridge constant).
pub struct GdClient {
    lambda: f64,
}

/// Build the GD split.
pub fn split(env: &Env) -> (GdServer, Vec<GdClient>) {
    let gamma = env.cfg.gamma.unwrap_or(1.0 / env.smoothness);
    let clients = (0..env.n).map(|_| GdClient { lambda: env.cfg.lambda }).collect();
    (GdServer { x: vec![0.0; env.d], gamma }, clients)
}

impl ServerState for GdServer {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        _rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        if exchange != 0 {
            return Ok(None);
        }
        let mut down = Packet::empty();
        down.push_vector("model", self.x.clone(), BitCost::floats(env.d));
        Ok(Some(RoundPlan::broadcast(env.n, down)))
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        _exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        let n = env.n as f64;
        let mut g = vec![0.0; env.d];
        for (_, up) in replies {
            crate::linalg::axpy(1.0 / n, up.vector("grad")?, &mut g);
        }
        crate::linalg::axpy(-self.gamma, &g, &mut self.x);
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        "gd".into()
    }
}

impl ClientStep for GdClient {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        _exchange: usize,
        down: &Downlink,
        _rng: &mut Rng,
    ) -> Result<Uplink> {
        let x = down.vector("model")?;
        // Regularized local gradient ∇f_i(x) + λx.
        let mut g = local.grad(x);
        crate::linalg::axpy(self.lambda, x, &mut g);
        let d = g.len();
        let mut up = Packet::empty();
        up.push_vector("grad", g, BitCost::floats(d));
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 61,
        })
    }

    #[test]
    fn gd_monotone_decrease_and_linear_rate() {
        let cfg = RunConfig {
            algorithm: Algorithm::Gd,
            rounds: 3000,
            lambda: 1e-2,
            target_gap: 1e-9,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        let gaps: Vec<f64> = out.history.records.iter().map(|r| r.gap).collect();
        for w in gaps.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "gap increased {} → {}", w[0], w[1]);
        }
        assert!(out.final_gap() <= 1e-9, "gap={}", out.final_gap());
    }

    #[test]
    fn gd_is_condition_number_limited() {
        // Smaller λ ⇒ worse conditioning ⇒ more rounds to the same gap.
        let mk = |lambda: f64| RunConfig {
            algorithm: Algorithm::Gd,
            rounds: 20_000,
            lambda,
            target_gap: 1e-6,
            ..RunConfig::default()
        };
        let fast = run_federated(&fed(), &mk(1e-1)).unwrap();
        let slow = run_federated(&fed(), &mk(1e-3)).unwrap();
        assert!(
            slow.history.records.len() > 2 * fast.history.records.len(),
            "λ=1e-3 took {} rounds, λ=1e-1 took {}",
            slow.history.records.len(),
            fast.history.records.len()
        );
    }
}
