//! First-order baselines the paper compares against (Figure 1 row 2,
//! Figures 4–5): GD, DIANA, ADIANA, S-Local-GD, Artemis, DORE — each as a
//! `ServerState` + `ClientStep` pair built by the module's `split`
//! constructor.
//!
//! All fold the ridge into the local gradients (`∇f_i + λx`) and use the
//! theoretical stepsizes from their respective papers, instantiated with the
//! smoothness bound computed by [`crate::coordinator::estimate_smoothness`]
//! and `μ = λ` — matching the paper's "theoretical stepsizes were used for
//! gradient type methods".

pub mod adiana;
pub mod artemis;
pub mod diana;
pub mod dore;
pub mod gd;
pub mod slocal;

pub use adiana::{AdianaClient, AdianaServer};
pub use artemis::{ArtemisClient, ArtemisServer};
pub use diana::{DianaClient, DianaServer};
pub use dore::{DoreClient, DoreServer};
pub use gd::{GdClient, GdServer};
pub use slocal::{SLocalClient, SLocalServer};
