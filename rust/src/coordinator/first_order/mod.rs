//! First-order baselines the paper compares against (Figure 1 row 2,
//! Figures 4–5): GD, DIANA, ADIANA, S-Local-GD, Artemis, DORE.
//!
//! All fold the ridge into the local gradients (`∇f_i + λx`) and use the
//! theoretical stepsizes from their respective papers, instantiated with the
//! smoothness bound computed by [`crate::coordinator::estimate_smoothness`]
//! and `μ = λ` — matching the paper's "theoretical stepsizes were used for
//! gradient type methods".

mod adiana;
mod artemis;
mod diana;
mod dore;
mod gd;
mod slocal;

pub use adiana::Adiana;
pub use artemis::Artemis;
pub use diana::Diana;
pub use dore::Dore;
pub use gd::Gd;
pub use slocal::SLocalGd;
