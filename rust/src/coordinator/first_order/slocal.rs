//! S-Local-GD [Gorbunov, Hanzely, Richtárik 2021] — shifted local gradient
//! descent from the unified local-SGD framework.
//!
//! Clients run *local* shifted gradient steps
//! `x_i ← x_i − γ(∇f_i(x_i) − h_i)` and communicate only on
//! `ξ^k ~ Bernoulli(p)` rounds, where the server averages the local models
//! and the shifts are updated toward the local gradients with probability
//! `q` (`h_i ← h_i + qp/γ·(x̄ − x_i)` in the framework's formulation;
//! we use the gradient-tracking form `h_i ← ∇f_i(x_i) − (1/n)Σ∇f_j(x_j)`
//! at sync which the framework covers). The paper's experiments use
//! `p = q = 1/n`.

use crate::compressors::BitCost;
use crate::coordinator::{CommTally, Env, Method, StepInfo};
use crate::linalg::Vector;
use crate::rng::Rng;
use anyhow::Result;

/// S-Local-GD state.
pub struct SLocalGd {
    /// Server model (last synced average).
    x: Vector,
    /// Local models.
    xi: Vec<Vector>,
    /// Shifts `h_i` (Σ h_i = 0 invariant).
    shifts: Vec<Vector>,
    gamma: f64,
    /// Communication probability.
    p: f64,
    /// Shift update probability.
    q: f64,
}

impl SLocalGd {
    pub fn new(env: &Env) -> Self {
        let d = env.d;
        let gamma = env.cfg.gamma.unwrap_or(1.0 / (4.0 * env.smoothness));
        let p = 1.0 / env.n as f64;
        SLocalGd {
            x: vec![0.0; d],
            xi: vec![vec![0.0; d]; env.n],
            shifts: vec![vec![0.0; d]; env.n],
            gamma,
            p,
            q: 1.0 / env.n as f64,
        }
    }
}

impl Method for SLocalGd {
    fn step(&mut self, env: &Env, _round: usize, rng: &mut Rng) -> Result<StepInfo> {
        let mut tally = CommTally::default();
        let n = env.n as f64;
        let d = env.d;

        // Local shifted steps (no communication).
        for i in 0..env.n {
            let gi = env.grad_reg(i, &self.xi[i]);
            for k in 0..d {
                self.xi[i][k] -= self.gamma * (gi[k] - self.shifts[i][k]);
            }
        }

        // Synchronization round with probability p.
        if rng.bernoulli(self.p) {
            let mut avg = vec![0.0; d];
            for i in 0..env.n {
                crate::linalg::axpy(1.0 / n, &self.xi[i], &mut avg);
                tally.up(BitCost::floats(d), env.cfg.float_bits);
                tally.down(BitCost::floats(d), env.cfg.float_bits);
            }
            // Shift refresh with probability q: gradient-tracking form,
            // preserving Σ h_i = 0.
            if rng.bernoulli(self.q) {
                let grads: Vec<Vector> =
                    (0..env.n).map(|i| env.grad_reg(i, &self.xi[i])).collect();
                let mut gbar = vec![0.0; d];
                for g in &grads {
                    crate::linalg::axpy(1.0 / n, g, &mut gbar);
                }
                for i in 0..env.n {
                    self.shifts[i] = crate::linalg::sub(&grads[i], &gbar);
                }
            }
            for i in 0..env.n {
                self.xi[i] = avg.clone();
            }
            self.x = avg;
        }

        Ok(tally.into_step())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        "s-local-gd".into()
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 64,
        })
    }

    #[test]
    fn slocal_gd_converges() {
        let cfg = RunConfig {
            algorithm: Algorithm::SLocalGd,
            rounds: 60_000,
            lambda: 1e-2,
            target_gap: 1e-8,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-8, "gap={}", out.final_gap());
    }

    #[test]
    fn communicates_rarely() {
        // p = 1/n ⇒ most rounds are local-only (zero bits).
        let cfg = RunConfig {
            algorithm: Algorithm::SLocalGd,
            rounds: 400,
            lambda: 1e-2,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        let recs = &out.history.records;
        let silent = recs
            .windows(2)
            .filter(|w| w[1].bits_up_per_node == w[0].bits_up_per_node)
            .count();
        assert!(
            silent as f64 > 0.5 * recs.len() as f64,
            "only {silent}/{} silent rounds",
            recs.len()
        );
    }
}
