//! S-Local-GD [Gorbunov, Hanzely, Richtárik 2021] — shifted local gradient
//! descent from the unified local-SGD framework.
//!
//! Clients run *local* shifted gradient steps
//! `x_i ← x_i − γ(∇f_i(x_i) − h_i)` and communicate only on
//! `ξ^k ~ Bernoulli(p)` rounds, where the server averages the local models
//! and the shifts are updated toward the local gradients with probability
//! `q` (we use the gradient-tracking form `h_i ← ∇f_i(x_i) − (1/n)Σ∇f_j(x_j)`
//! at sync, which the framework covers). The paper's experiments use
//! `p = q = 1/n`.
//!
//! Exchanges: 0 carries the sync/refresh control bits down (uncharged) and
//! — on sync rounds — the local models up (`d` floats; refresh rounds also
//! ride the local gradients up uncharged, the framework-message convention
//! of the reference accounting); exchange 1 broadcasts the average (`d`
//! floats, plus the uncharged gradient mean on refresh rounds).

use crate::compressors::BitCost;
use crate::coordinator::{Env, RoundPlan, ServerState};
use crate::linalg::Vector;
use crate::problem::LocalProblem;
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::Result;

/// S-Local-GD server.
pub struct SLocalServer {
    /// Server model (last synced average).
    x: Vector,
    /// Communication probability.
    p: f64,
    /// Shift update probability.
    q: f64,
    // ── per-round scratch ──
    sync: bool,
    refresh: bool,
    avg: Vector,
    gbar: Vector,
}

/// S-Local-GD client.
pub struct SLocalClient {
    /// Local model `x_i`.
    x: Vector,
    /// Shift `h_i` (Σ h_i = 0 invariant).
    shift: Vector,
    /// Local gradient at sync (for the tracking-form refresh).
    g_last: Vector,
    gamma: f64,
    lambda: f64,
}

/// Build the S-Local-GD split.
pub fn split(env: &Env) -> (SLocalServer, Vec<SLocalClient>) {
    let d = env.d;
    let gamma = env.cfg.gamma.unwrap_or(1.0 / (4.0 * env.smoothness));
    let clients = (0..env.n)
        .map(|_| SLocalClient {
            x: vec![0.0; d],
            shift: vec![0.0; d],
            g_last: vec![0.0; d],
            gamma,
            lambda: env.cfg.lambda,
        })
        .collect();
    let server = SLocalServer {
        x: vec![0.0; d],
        p: 1.0 / env.n as f64,
        q: 1.0 / env.n as f64,
        sync: false,
        refresh: false,
        avg: vec![0.0; d],
        gbar: vec![0.0; d],
    };
    (server, clients)
}

impl ServerState for SLocalServer {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        match exchange {
            0 => {
                self.sync = rng.bernoulli(self.p);
                self.refresh = self.sync && rng.bernoulli(self.q);
                let mut down = Packet::empty();
                down.push_flags("ctl", vec![self.sync, self.refresh], BitCost::zero());
                Ok(Some(RoundPlan::broadcast(env.n, down)))
            }
            1 if self.sync => {
                let mut down = Packet::empty();
                down.push_vector("avg", self.avg.clone(), BitCost::floats(env.d));
                if self.refresh {
                    down.push_vector("gbar", self.gbar.clone(), BitCost::zero());
                }
                Ok(Some(RoundPlan::broadcast(env.n, down)))
            }
            _ => Ok(None),
        }
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        if exchange != 0 || !self.sync {
            return Ok(());
        }
        let n = env.n as f64;
        let d = env.d;
        let mut avg = vec![0.0; d];
        let mut gbar = vec![0.0; d];
        for (_, up) in replies {
            crate::linalg::axpy(1.0 / n, up.vector("model")?, &mut avg);
            if self.refresh {
                crate::linalg::axpy(1.0 / n, up.vector("grad_report")?, &mut gbar);
            }
        }
        self.x = avg.clone();
        self.avg = avg;
        self.gbar = gbar;
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        "s-local-gd".into()
    }
}

impl ClientStep for SLocalClient {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        exchange: usize,
        down: &Downlink,
        _rng: &mut Rng,
    ) -> Result<Uplink> {
        let mut up = Packet::empty();
        if exchange == 0 {
            // Local shifted step (every round; no communication cost).
            let mut gi = local.grad(&self.x);
            crate::linalg::axpy(self.lambda, &self.x, &mut gi);
            for (xk, (gk, hk)) in self.x.iter_mut().zip(gi.iter().zip(&self.shift)) {
                *xk -= self.gamma * (gk - hk);
            }
            let ctl = down.flags("ctl")?;
            let (sync, refresh) = (ctl[0], ctl[1]);
            if sync {
                let d = self.x.len();
                up.push_vector("model", self.x.clone(), BitCost::floats(d));
                if refresh {
                    // Post-step local gradient, for the tracking refresh.
                    let mut g = local.grad(&self.x);
                    crate::linalg::axpy(self.lambda, &self.x, &mut g);
                    self.g_last = g.clone();
                    // Distinct kind from the charged "grad" uplinks of
                    // GD/NL1/DINGO: this one is a framework ride-along.
                    up.push_vector("grad_report", g, BitCost::zero());
                }
            }
        } else {
            // Sync broadcast: refresh shifts (preserving Σ h_i = 0), then
            // adopt the average.
            if let Some(gbar) = down.vector_opt("gbar")? {
                self.shift = crate::linalg::sub(&self.g_last, gbar);
            }
            self.x = down.vector("avg")?.to_vec();
        }
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 64,
        })
    }

    #[test]
    fn slocal_gd_converges() {
        let cfg = RunConfig {
            algorithm: Algorithm::SLocalGd,
            rounds: 60_000,
            lambda: 1e-2,
            target_gap: 1e-8,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-8, "gap={}", out.final_gap());
    }

    #[test]
    fn communicates_rarely() {
        // p = 1/n ⇒ most rounds are local-only (zero bits).
        let cfg = RunConfig {
            algorithm: Algorithm::SLocalGd,
            rounds: 400,
            lambda: 1e-2,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(), &cfg).unwrap();
        let recs = &out.history.records;
        let silent = recs
            .windows(2)
            .filter(|w| w[1].bits_up_per_node == w[0].bits_up_per_node)
            .count();
        assert!(
            silent as f64 > 0.5 * recs.len() as f64,
            "only {silent}/{} silent rounds",
            recs.len()
        );
    }
}
