//! The federated coordinator — the paper's system contribution, in Rust.
//!
//! Architecture: a [`Method`] is a server+clients state machine advancing one
//! communication round per [`Method::step`] call, with *exact bit accounting*
//! of everything that would cross the wire (messages are materialized as
//! compressed payloads with [`crate::compressors::BitCost`]s — the simulated
//! network of DESIGN.md §6.2). [`run_federated`] owns the round loop,
//! convergence tracking against the Newton reference optimum, and stopping
//! rules.
//!
//! Method implementations:
//! * `second_order/` — BL1 (Alg. 1), BL2 (Alg. 2), BL3 (Alg. 3), the FedNL
//!   family (standard-basis specializations), NL1, DINGO, and classical
//!   Newton with either basis.
//! * `first_order/` — GD, DIANA, ADIANA, S-Local-GD, Artemis, DORE.

pub mod first_order;
pub mod second_order;

use crate::basis::{HessianBasis, PsdBasis, StandardBasis, SubspaceBasis, SymTriBasis};
use crate::config::{Algorithm, BasisKind, RunConfig};
use crate::data::FederatedDataset;
use crate::linalg::{Mat, Vector};
use crate::metrics::{History, RoundRecord};
use crate::problem::{GlobalObjective, LocalProblem, LogisticProblem};
use crate::rng::Rng;
use anyhow::Result;

/// Shared, read-only run environment handed to methods each round.
pub struct Env<'a> {
    /// Per-client local objectives (data terms only; λ is global).
    pub locals: &'a [Box<dyn LocalProblem>],
    pub cfg: &'a RunConfig,
    /// Model dimension.
    pub d: usize,
    /// Number of clients.
    pub n: usize,
    /// Global smoothness constant `L` (for first-order stepsizes).
    pub smoothness: f64,
    /// Per-client feature matrices, when available (basis extraction, NL1).
    pub features: Vec<Option<Mat>>,
}

impl<'a> Env<'a> {
    /// Global objective (data average + ridge).
    pub fn objective(&self) -> GlobalObjective<'_, dyn LocalProblem> {
        GlobalObjective::new(self.locals, self.cfg.lambda)
    }

    /// Regularized local gradient `∇f_i(x) + λx` (first-order methods fold
    /// the ridge into each client).
    pub fn grad_reg(&self, i: usize, x: &[f64]) -> Vector {
        let mut g = self.locals[i].grad(x);
        crate::linalg::axpy(self.cfg.lambda, x, &mut g);
        g
    }

    /// Regularized local Hessian `∇²f_i(x) + λI`.
    pub fn hess_reg(&self, i: usize, x: &[f64]) -> Mat {
        let mut h = self.locals[i].hess(x);
        h.add_diag(self.cfg.lambda);
        h
    }

    /// Build the configured Hessian basis for client `i`.
    pub fn build_basis(&self, i: usize) -> Box<dyn HessianBasis> {
        let kind = self.cfg.effective_basis();
        match kind {
            BasisKind::Standard => Box::new(StandardBasis::new(self.d)),
            BasisKind::SymTri => Box::new(SymTriBasis::new(self.d)),
            BasisKind::Psd => Box::new(PsdBasis::new(self.d)),
            BasisKind::Subspace => match &self.features[i] {
                Some(a) => Box::new(SubspaceBasis::from_data(a, self.cfg.subspace_tol)),
                // No feature access (e.g. a pure oracle): fall back to the
                // standard basis — BL degrades gracefully to FedNL.
                None => Box::new(StandardBasis::new(self.d)),
            },
        }
    }
}

/// Per-round communication tally (sums over clients, in bits).
#[derive(Clone, Copy, Debug, Default)]
pub struct CommTally {
    pub up_bits: f64,
    pub down_bits: f64,
}

impl CommTally {
    /// Record an uplink message from one client.
    pub fn up(&mut self, cost: crate::compressors::BitCost, float_bits: u32) {
        self.up_bits += cost.total_bits(float_bits);
    }

    /// Record a downlink message to one client.
    pub fn down(&mut self, cost: crate::compressors::BitCost, float_bits: u32) {
        self.down_bits += cost.total_bits(float_bits);
    }

    pub fn into_step(self) -> StepInfo {
        StepInfo { up_bits_total: self.up_bits, down_bits_total: self.down_bits }
    }
}

/// What a method reports after one round.
pub struct StepInfo {
    /// Sum over clients of uplink bits this round.
    pub up_bits_total: f64,
    /// Sum over clients of downlink bits this round.
    pub down_bits_total: f64,
}

/// One federated optimization method (server + clients).
pub trait Method {
    /// Advance one communication round.
    fn step(&mut self, env: &Env, round: usize, rng: &mut Rng) -> Result<StepInfo>;

    /// Current global iterate `x^k` (the model the server would deploy).
    fn x(&self) -> &[f64];

    /// One-time setup bits per node (basis transfer, data revelation, ...).
    fn setup_bits_per_node(&self, _env: &Env) -> f64 {
        0.0
    }

    /// Method label for CSV/legends.
    fn label(&self) -> String;
}

/// Output of a federated run.
pub struct RunOutput {
    pub history: History,
    pub x_final: Vector,
    pub x_star: Vector,
    pub f_star: f64,
}

impl RunOutput {
    pub fn final_gap(&self) -> f64 {
        self.history.final_gap()
    }

    pub fn bits_per_node(&self) -> f64 {
        self.history.final_bits_per_node()
    }
}

/// Build native local problems from a dataset.
pub fn native_locals(fed: &FederatedDataset) -> Vec<Box<dyn LocalProblem>> {
    fed.clients
        .iter()
        .map(|c| Box::new(LogisticProblem::new(c.a.clone(), c.b.clone())) as Box<dyn LocalProblem>)
        .collect()
}

/// Run a federated optimization over native (Rust) local problems.
pub fn run_federated(fed: &FederatedDataset, cfg: &RunConfig) -> Result<RunOutput> {
    let locals = native_locals(fed);
    let features: Vec<Option<Mat>> = fed.clients.iter().map(|c| Some(c.a.clone())).collect();
    run_federated_with(&locals, features, cfg)
}

/// Run over caller-supplied local problems (e.g. PJRT-backed ones).
/// `features[i]` supplies client `i`'s raw data matrix when the subspace
/// basis or NL1 is in play (pass `None` to withhold it).
pub fn run_federated_with(
    locals: &[Box<dyn LocalProblem>],
    features: Vec<Option<Mat>>,
    cfg: &RunConfig,
) -> Result<RunOutput> {
    anyhow::ensure!(!locals.is_empty(), "need at least one client");
    anyhow::ensure!(features.len() == locals.len(), "features/locals length mismatch");
    let d = locals[0].dim();
    let n = locals.len();
    let obj = GlobalObjective::new(locals, cfg.lambda);
    let (x_star, f_star) = obj.reference_optimum()?;
    let smoothness = estimate_smoothness(locals, cfg.lambda);
    let env = Env { locals, cfg, d, n, smoothness, features };

    let mut method = build_method(&env)?;
    let mut rng = Rng::new(cfg.seed);
    let mut history = History::new(method.label());
    history.setup_bits_per_node = method.setup_bits_per_node(&env);

    let mut up_cum = 0.0; // per-node cumulative
    let mut down_cum = 0.0;
    for round in 0..cfg.rounds {
        let info = method.step(&env, round, &mut rng)?;
        up_cum += info.up_bits_total / n as f64;
        down_cum += info.down_bits_total / n as f64;
        let x = method.x();
        let gap = obj.loss(x) - f_star;
        let grad_norm = crate::linalg::norm2(&obj.grad(x));
        let dist = crate::linalg::norm2(&crate::linalg::sub(x, &x_star));
        history.push(RoundRecord {
            round,
            bits_up_per_node: up_cum,
            bits_down_per_node: down_cum,
            gap,
            grad_norm,
            dist_to_opt: dist,
        });
        if !gap.is_finite() {
            anyhow::bail!("{} diverged at round {round} (gap = {gap})", method.label());
        }
        if cfg.target_gap > 0.0 && gap <= cfg.target_gap {
            break;
        }
        if let Some(budget) = cfg.max_bits_per_node {
            // Setup bits (basis transfer etc.) count against the budget —
            // the same accounting `final_bits_per_node`/`bits_to_reach`
            // report, so methods with an initial communication cost can't
            // overshoot what the figures charge them for.
            if history.setup_bits_per_node + up_cum + down_cum >= budget {
                break;
            }
        }
    }

    Ok(RunOutput { history, x_final: method.x().to_vec(), x_star, f_star })
}

/// Global smoothness bound `L = λ_max(4·avg ∇²f_i(0)) + λ` for logistic data
/// terms (`φ″(0) = ¼` is the global max of `φ″`), used by the first-order
/// theoretical stepsizes.
pub fn estimate_smoothness(locals: &[Box<dyn LocalProblem>], lambda: f64) -> f64 {
    let d = locals[0].dim();
    let n = locals.len() as f64;
    let mut h = Mat::zeros(d, d);
    let zero = vec![0.0; d];
    for p in locals.iter() {
        h.add_scaled(4.0 / n, &p.hess(&zero));
    }
    let e = crate::linalg::sym_eigen(&h);
    e.values.first().copied().unwrap_or(0.0) + lambda
}

/// Dispatch an algorithm to its implementation.
fn build_method(env: &Env) -> Result<Box<dyn Method>> {
    use Algorithm::*;
    Ok(match env.cfg.algorithm {
        Newton => Box::new(second_order::NewtonMethod::new(env)),
        Bl1 => Box::new(second_order::Bl1::new(env)),
        Bl2 => Box::new(second_order::Bl2::new(env)),
        Bl3 => Box::new(second_order::Bl3::new(env)?),
        FedNl => Box::new(second_order::Bl1::fednl(env)),
        FedNlBc => Box::new(second_order::Bl1::fednl_bc(env)),
        FedNlPp => Box::new(second_order::Bl2::fednl_pp(env)),
        Nl1 => Box::new(second_order::Nl1::new(env)?),
        Dingo => Box::new(second_order::Dingo::new(env)),
        Gd => Box::new(first_order::Gd::new(env)),
        Diana => Box::new(first_order::Diana::new(env)),
        Adiana => Box::new(first_order::Adiana::new(env)),
        SLocalGd => Box::new(first_order::SLocalGd::new(env)),
        Artemis => Box::new(first_order::Artemis::new(env)),
        Dore => Box::new(first_order::Dore::new(env)),
    })
}

/// Projection `[M]_μ` onto `{A : A = Aᵀ, A ⪰ μI}` (BL1's PD safeguard):
/// symmetrize, then clamp eigenvalues at μ.
///
/// Perf (EXPERIMENTS.md §Perf L3-1): once the Hessian estimate is learned,
/// `M − μI` is almost always PD already, so we first attempt a Cholesky
/// factorization of `M − μI` (`O(d³/3)`, ~100× cheaper than Jacobi) and only
/// fall back to the eigenvalue clamp when it fails.
pub fn project_psd(m: &Mat, mu: f64) -> Mat {
    let mut sym = m.clone();
    sym.symmetrize();
    let mut shifted = sym.clone();
    // Tiny slack so "barely ⪰ μI" doesn't bounce between paths.
    shifted.add_diag(-mu * (1.0 - 1e-12));
    if crate::linalg::CholeskyFactor::new(&shifted).is_ok() {
        return sym;
    }
    let e = crate::linalg::sym_eigen(&sym);
    e.reconstruct(|l| l.max(mu))
}

/// Independent-inclusion client sampling with `P[i ∈ S] = τ/n`
/// (the participation model of Algorithms 2–3). Guarantees at least one
/// participant by resampling empty draws.
pub fn sample_clients(n: usize, tau: Option<usize>, rng: &mut Rng) -> Vec<usize> {
    let tau = tau.unwrap_or(n).min(n);
    if tau >= n {
        return (0..n).collect();
    }
    let p = tau as f64 / n as f64;
    loop {
        let s: Vec<usize> = (0..n).filter(|_| rng.bernoulli(p)).collect();
        if !s.is_empty() {
            return s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    fn tiny_fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 10,
            intrinsic_dim: 4,
            noise: 0.0,
            seed,
        })
    }

    #[test]
    fn project_psd_floors_eigenvalues() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // λ = 3, −1
        let p = project_psd(&a, 0.5);
        let e = crate::linalg::sym_eigen(&p);
        assert!((e.values[0] - 3.0).abs() < 1e-10);
        assert!((e.values[1] - 0.5).abs() < 1e-10);
    }

    #[test]
    fn project_psd_identity_on_pd() {
        let mut rng = Rng::new(30);
        let b = Mat::from_fn(5, 5, |_, _| rng.normal());
        let mut a = b.transpose().matmul(&b);
        a.add_diag(1.0);
        let p = project_psd(&a, 1e-6);
        assert!((&p - &a).fro_norm() < 1e-8);
    }

    #[test]
    fn sample_clients_full_and_partial() {
        let mut rng = Rng::new(31);
        assert_eq!(sample_clients(5, None, &mut rng), vec![0, 1, 2, 3, 4]);
        assert_eq!(sample_clients(5, Some(5), &mut rng), vec![0, 1, 2, 3, 4]);
        assert_eq!(sample_clients(5, Some(9), &mut rng), vec![0, 1, 2, 3, 4]);
        // τ/n inclusion rate over many rounds.
        let mut total = 0usize;
        let rounds = 4000;
        for _ in 0..rounds {
            total += sample_clients(10, Some(3), &mut rng).len();
        }
        let avg = total as f64 / rounds as f64;
        assert!((avg - 3.0).abs() < 0.25, "avg={avg}");
    }

    #[test]
    fn smoothness_upper_bounds_hessian() {
        let fed = tiny_fed(40);
        let locals = native_locals(&fed);
        let lambda = 1e-3;
        let ell = estimate_smoothness(&locals, lambda);
        let obj = GlobalObjective::new(&locals, lambda);
        let mut rng = Rng::new(41);
        for _ in 0..5 {
            let x: Vec<f64> = (0..fed.dim()).map(|_| rng.normal()).collect();
            let h = obj.hess(&x);
            let e = crate::linalg::sym_eigen(&h);
            assert!(e.values[0] <= ell + 1e-9, "λmax={} > L={}", e.values[0], ell);
        }
    }

    #[test]
    fn run_federated_newton_reaches_target() {
        let fed = tiny_fed(42);
        let cfg = RunConfig {
            algorithm: Algorithm::Newton,
            rounds: 30,
            lambda: 1e-3,
            target_gap: 1e-12,
            ..RunConfig::default()
        };
        let out = run_federated(&fed, &cfg).unwrap();
        assert!(out.final_gap() <= 1e-12, "gap={}", out.final_gap());
        // Newton should get there in well under 30 rounds.
        assert!(out.history.records.len() < 20);
    }

    #[test]
    fn bits_budget_stops_run() {
        let fed = tiny_fed(43);
        let cfg = RunConfig {
            algorithm: Algorithm::Gd,
            rounds: 10_000,
            target_gap: 0.0,
            max_bits_per_node: Some(50_000.0),
            ..RunConfig::default()
        };
        let out = run_federated(&fed, &cfg).unwrap();
        let last = out.history.records.last().unwrap();
        assert!(last.bits_per_node() >= 50_000.0);
        assert!(out.history.records.len() < 10_000);
    }

    #[test]
    fn bits_budget_includes_setup_cost() {
        // BL1's default subspace basis has a one-time r·d-float transfer
        // (Table 1's initial communication cost). The budget check must
        // charge it, like final_bits_per_node/bits_to_reach do — the old
        // comparison of up+down alone let nonzero-setup methods overshoot.
        let fed = tiny_fed(44);
        let budget = 60_000.0;
        let cfg = RunConfig {
            algorithm: Algorithm::Bl1,
            rounds: 10_000,
            target_gap: 0.0,
            max_bits_per_node: Some(budget),
            ..RunConfig::default()
        };
        let out = run_federated(&fed, &cfg).unwrap();
        let h = &out.history;
        assert!(h.setup_bits_per_node > 0.0, "need a nonzero-setup method");
        assert!(h.records.len() < 10_000, "budget never triggered");
        // Stops at the *first* round where setup+up+down crosses the
        // budget: every earlier round is still under it, setup included.
        assert!(h.final_bits_per_node() >= budget);
        for r in &h.records[..h.records.len() - 1] {
            assert!(
                r.bits_per_node() + h.setup_bits_per_node < budget,
                "round {} already over budget: {} + {} setup",
                r.round,
                r.bits_per_node(),
                h.setup_bits_per_node
            );
        }
    }
}
