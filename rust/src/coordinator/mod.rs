//! The federated coordinator — the paper's system contribution, in Rust.
//!
//! # Architecture: explicit server/client rounds over a transport
//!
//! Every method is split into two halves that only talk through
//! [`crate::transport`] messages:
//!
//! * a [`ServerState`] — the aggregate model: it **plans** each exchange of
//!   a round (who participates, what rides on the downlink), and
//!   **absorbs** the uplinks (decode, aggregate, Newton/gradient step);
//! * a [`crate::transport::ClientStep`] per client — the local worker: it
//!   receives a [`crate::transport::Downlink`], runs the expensive local
//!   work (oracle calls, basis projection, compression) against its
//!   [`crate::problem::LocalProblem`], and replies with an
//!   [`crate::transport::Uplink`].
//!
//! [`run_federated_with`] drives the generic round loop through a chosen
//! [`crate::transport::Transport`] backend — [`crate::transport::Lockstep`]
//! (serial reference) or [`crate::transport::Threaded`] (concurrent
//! in-round workers) — and both produce bit-identical histories (see the
//! transport module for the determinism contract). The per-round
//! communication tally is derived from the [`crate::compressors::BitCost`]s
//! of the packets that actually crossed the simulated wire, with exact bit
//! accounting of indices/flags/floats (DESIGN.md §6.2); convergence is
//! tracked against the Newton reference optimum with the paper's stopping
//! rules.
//!
//! Method implementations:
//! * `second_order/` — BL1 (Alg. 1), BL2 (Alg. 2), BL3 (Alg. 3), the FedNL
//!   family (standard-basis specializations), NL1, DINGO, and classical
//!   Newton with either basis.
//! * `first_order/` — GD, DIANA, ADIANA, S-Local-GD, Artemis, DORE.

pub mod first_order;
pub mod remote;
pub mod second_order;

pub use remote::{run_federated_listen, run_worker};

use crate::basis::{HessianBasis, PsdBasis, StandardBasis, SubspaceBasis, SymTriBasis};
use crate::config::{Algorithm, BasisKind, RunConfig, TransportSpec};
use crate::data::FederatedDataset;
use crate::linalg::{Mat, Vector};
use crate::metrics::{History, RoundRecord};
use crate::obs::{Ctx, Dir, Lane, Obs, Recorder, NOOP};
use crate::problem::{GlobalObjective, LocalProblem, LogisticProblem};
use crate::rng::Rng;
use crate::transport::{
    client_rngs, ClientStep, Downlink, Lockstep, ProblemFactory, Tcp, Threaded, Transport, Uplink,
};
use anyhow::Result;

/// Shared, read-only run environment handed to the server each round (and
/// to both halves at construction time).
pub struct Env<'a> {
    /// Per-client local objectives (data terms only; λ is global).
    pub locals: &'a [Box<dyn LocalProblem>],
    pub cfg: &'a RunConfig,
    /// Model dimension.
    pub d: usize,
    /// Number of clients.
    pub n: usize,
    /// Global smoothness constant `L` (for first-order stepsizes).
    pub smoothness: f64,
    /// Per-client feature matrices, when available (basis extraction, NL1).
    pub features: Vec<Option<Mat>>,
    /// Trace recorder handle — [`Obs::noop`] unless the run is traced.
    pub obs: Obs<'a>,
}

impl<'a> Env<'a> {
    /// Global objective (data average + ridge).
    pub fn objective(&self) -> GlobalObjective<'_, dyn LocalProblem> {
        GlobalObjective::new(self.locals, self.cfg.lambda)
    }

    /// Build the configured Hessian basis for client `i`.
    pub fn build_basis(&self, i: usize) -> Box<dyn HessianBasis> {
        let kind = self.cfg.effective_basis();
        match kind {
            BasisKind::Standard => Box::new(StandardBasis::new(self.d)),
            BasisKind::SymTri => Box::new(SymTriBasis::new(self.d)),
            BasisKind::Psd => Box::new(PsdBasis::new(self.d)),
            BasisKind::Subspace => match &self.features[i] {
                Some(a) => Box::new(SubspaceBasis::from_data(a, self.cfg.subspace_tol)),
                // No feature access (e.g. a pure oracle): fall back to the
                // standard basis — BL degrades gracefully to FedNL.
                None => Box::new(StandardBasis::new(self.d)),
            },
        }
    }
}

/// Per-round communication tally (sums over clients, in bits). Derived by
/// the round loop from the packets that actually crossed the transport.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommTally {
    pub up_bits: f64,
    pub down_bits: f64,
}

impl CommTally {
    /// Record an uplink message from one client.
    pub fn up(&mut self, cost: crate::compressors::BitCost, float_bits: u32) {
        self.up_bits += cost.total_bits(float_bits);
    }

    /// Record a downlink message to one client.
    pub fn down(&mut self, cost: crate::compressors::BitCost, float_bits: u32) {
        self.down_bits += cost.total_bits(float_bits);
    }
}

/// What the server plans for one exchange: per-addressed-client downlinks,
/// in **ascending client order**.
pub struct RoundPlan {
    pub sends: Vec<(usize, Downlink)>,
}

impl RoundPlan {
    /// Address a set of clients (must already be ascending, as
    /// [`sample_clients`] returns).
    pub fn to_clients(sends: Vec<(usize, Downlink)>) -> Self {
        RoundPlan { sends }
    }

    /// The same downlink to every client (per-client clones, each charged).
    ///
    /// The clone per client is deliberate: packets are owned values so they
    /// can cross threads (and, later, sockets) without a shared-buffer
    /// protocol, and the O(d) copy is noise next to the O(m·d²) oracle work
    /// each delivery triggers client-side.
    pub fn broadcast(n: usize, down: Downlink) -> Self {
        RoundPlan { sends: (0..n).map(|i| (i, down.clone())).collect() }
    }
}

/// The server half of a federated method.
///
/// A round is a sequence of exchanges: the round loop calls
/// [`ServerState::plan`] with `exchange = 0, 1, …` until it returns `None`,
/// running [`ServerState::absorb`] on the replies in between. Most methods
/// plan one or two exchanges; DINGO's line search plans one per gradient
/// round trip.
pub trait ServerState {
    /// Plan exchange `exchange` of `round`; `None` ⇒ round complete.
    /// Server-side randomness (participation, ξ schedules, broadcast
    /// compression) must draw from `rng` — the run's single server stream.
    fn plan(
        &mut self,
        env: &Env,
        round: usize,
        exchange: usize,
        rng: &mut Rng,
    ) -> Result<Option<RoundPlan>>;

    /// Absorb the uplinks of the exchange just executed (ascending client
    /// order, exactly the clients the plan addressed).
    fn absorb(
        &mut self,
        env: &Env,
        round: usize,
        exchange: usize,
        replies: &[(usize, Uplink)],
        rng: &mut Rng,
    ) -> Result<()>;

    /// Current global iterate `x^k` (the model the server would deploy).
    fn x(&self) -> &[f64];

    /// One-time setup bits per node (basis transfer, data revelation, ...).
    fn setup_bits_per_node(&self, _env: &Env) -> f64 {
        0.0
    }

    /// The packet pool shared by this method's halves, if it recycles wire
    /// objects. The round loop returns absorbed uplinks here, and the
    /// `Lockstep` backend recycles consumed downlinks. `None` (the default)
    /// keeps the plain allocate-and-drop flow.
    fn pool(&self) -> Option<&crate::transport::PacketPool> {
        None
    }

    /// Method label for CSV/legends.
    fn label(&self) -> String;
}

/// Output of a federated run.
pub struct RunOutput {
    pub history: History,
    pub x_final: Vector,
    pub x_star: Vector,
    pub f_star: f64,
}

impl RunOutput {
    pub fn final_gap(&self) -> f64 {
        self.history.final_gap()
    }

    pub fn bits_per_node(&self) -> f64 {
        self.history.final_bits_per_node()
    }
}

/// Build client `i`'s native local problem from a dataset — the single
/// construction point shared by [`native_locals`] and the `Threaded`
/// backend's worker-side problem factories, so the two can never diverge.
pub fn native_local(fed: &FederatedDataset, i: usize) -> Box<dyn LocalProblem> {
    let c = &fed.clients[i];
    Box::new(LogisticProblem::new(c.a.clone(), c.b.clone()))
}

/// Build native local problems from a dataset.
pub fn native_locals(fed: &FederatedDataset) -> Vec<Box<dyn LocalProblem>> {
    (0..fed.clients.len()).map(|i| native_local(fed, i)).collect()
}

/// Run a federated optimization over native (Rust) local problems, through
/// the backend selected by `cfg.transport`. The dataset doubles as the
/// problem factory the `Threaded` backend needs (each worker thread builds
/// its own oracles — [`LocalProblem`] is non-`Send`).
pub fn run_federated(fed: &FederatedDataset, cfg: &RunConfig) -> Result<RunOutput> {
    run_federated_traced(fed, cfg, &NOOP)
}

/// [`run_federated`] with a trace recorder observing the run. With
/// [`crate::obs::NoopRecorder`] this is exactly `run_federated` (byte-
/// identical output — the neutrality contract in `rust/src/obs/`).
pub fn run_federated_traced(
    fed: &FederatedDataset,
    cfg: &RunConfig,
    rec: &dyn Recorder,
) -> Result<RunOutput> {
    let locals = native_locals(fed);
    let features: Vec<Option<Mat>> = fed.clients.iter().map(|c| Some(c.a.clone())).collect();
    let factory = |i: usize| native_local(fed, i);
    let factory: ProblemFactory<'_> = &factory;
    run_federated_factory_traced(&locals, features, cfg, Some(factory), rec)
}

/// Run over caller-supplied local problems (e.g. PJRT-backed ones).
/// `features[i]` supplies client `i`'s raw data matrix when the subspace
/// basis or NL1 is in play (pass `None` to withhold it).
///
/// Only the `Lockstep` backend is available here: arbitrary oracles are
/// non-`Send`, so the `Threaded` backend cannot move them onto workers —
/// use [`run_federated`] (or [`run_federated_factory`] with a factory) for
/// threaded execution.
pub fn run_federated_with(
    locals: &[Box<dyn LocalProblem>],
    features: Vec<Option<Mat>>,
    cfg: &RunConfig,
) -> Result<RunOutput> {
    run_federated_factory_traced(locals, features, cfg, None, &NOOP)
}

/// [`run_federated_with`] with a trace recorder observing the run.
pub fn run_federated_with_traced(
    locals: &[Box<dyn LocalProblem>],
    features: Vec<Option<Mat>>,
    cfg: &RunConfig,
    rec: &dyn Recorder,
) -> Result<RunOutput> {
    run_federated_factory_traced(locals, features, cfg, None, rec)
}

/// The generic entry point: drives the round loop through `cfg.transport`.
/// `factory` rebuilds client oracles on worker threads; without one, only
/// `Lockstep` is possible and `Threaded` is rejected with a clear error.
pub fn run_federated_factory(
    locals: &[Box<dyn LocalProblem>],
    features: Vec<Option<Mat>>,
    cfg: &RunConfig,
    factory: Option<ProblemFactory<'_>>,
) -> Result<RunOutput> {
    run_federated_factory_traced(locals, features, cfg, factory, &NOOP)
}

/// [`run_federated_factory`] with a trace recorder observing the run.
pub fn run_federated_factory_traced<'a>(
    locals: &'a [Box<dyn LocalProblem>],
    features: Vec<Option<Mat>>,
    cfg: &'a RunConfig,
    factory: Option<ProblemFactory<'a>>,
    rec: &'a dyn Recorder,
) -> Result<RunOutput> {
    anyhow::ensure!(!locals.is_empty(), "need at least one client");
    anyhow::ensure!(features.len() == locals.len(), "features/locals length mismatch");
    let d = locals[0].dim();
    let n = locals.len();
    let smoothness = estimate_smoothness(locals, cfg.lambda);
    let env = Env { locals, cfg, d, n, smoothness, features, obs: Obs::new(rec) };

    let (mut server, clients) = build_split(&env)?;
    let rngs = client_rngs(cfg.seed, n);
    match &cfg.transport {
        TransportSpec::Lockstep => {
            let mut transport = Lockstep::new(env.locals, clients, rngs)
                .with_obs(env.obs)
                .with_pool(server.pool().cloned());
            drive(&env, server.as_mut(), &mut transport)
        }
        TransportSpec::Listen { .. } => {
            anyhow::bail!(
                "the listen transport serves standalone worker processes and needs \
                 the full dataset recipe — drive it through run_federated_listen \
                 (CLI: `repro run --listen <host:port>`)"
            )
        }
        TransportSpec::Threaded(_) | TransportSpec::Tcp(_) => {
            let Some(factory) = factory else {
                anyhow::bail!(
                    "transport '{}' needs rebuildable local problems (oracles are \
                     non-Send); run through run_federated / run_federated_factory, \
                     or use --transport lockstep",
                    cfg.transport
                )
            };
            let workers = cfg.transport.resolved_workers(n);
            std::thread::scope(|scope| {
                if let TransportSpec::Tcp(_) = &cfg.transport {
                    let timeout = std::time::Duration::from_millis(cfg.handshake_timeout_ms);
                    let mut transport =
                        Tcp::spawn(scope, workers, clients, rngs, factory, env.obs, timeout)?;
                    drive(&env, server.as_mut(), &mut transport)
                } else {
                    let mut transport =
                        Threaded::spawn_obs(scope, workers, clients, rngs, factory, env.obs);
                    drive(&env, server.as_mut(), &mut transport)
                }
            })
        }
    }
}

/// Execute one full round (all its exchanges) through a transport and
/// return the bits that crossed. Public so benches and the equivalence
/// tests can drive the protocol directly.
pub fn run_one_round(
    env: &Env,
    server: &mut dyn ServerState,
    transport: &mut dyn Transport,
    round: usize,
    rng: &mut Rng,
) -> Result<CommTally> {
    let mut tally = CommTally::default();
    let fb = env.cfg.float_bits;
    let obs = env.obs;
    let mut exchange = 0usize;
    loop {
        let ctx = Ctx::round(round, exchange);
        let plan = {
            let _span = obs.span("plan", Lane::Server, ctx);
            server.plan(env, round, exchange, rng)?
        };
        let Some(plan) = plan else { break };
        debug_assert!(
            plan.sends.windows(2).all(|w| w[0].0 < w[1].0),
            "plan sends must be ascending and unique"
        );
        for (i, down) in &plan.sends {
            tally.down(down.cost(), fb);
            obs.packet(Dir::Down, Lane::Server, Ctx::client(round, exchange, *i), down, fb);
        }
        let replies = {
            let _span = obs.span("exchange", Lane::Server, ctx);
            transport.exchange(round, exchange, plan.sends)?
        };
        for (i, up) in &replies {
            tally.up(up.cost(), fb);
            obs.packet(Dir::Up, Lane::Server, Ctx::client(round, exchange, *i), up, fb);
        }
        {
            let _span = obs.span("absorb", Lane::Server, ctx);
            server.absorb(env, round, exchange, &replies, rng)?;
        }
        // Absorb only borrows the uplinks, so their buffers can go back to
        // the method's pool (when it has one) for the next exchange's sends.
        if let Some(pool) = server.pool() {
            pool.recycle_batch(replies);
        }
        exchange += 1;
    }
    Ok(tally)
}

/// The round loop: convergence tracking against the Newton reference
/// optimum, stopping rules, and message-derived bit accounting.
fn drive(
    env: &Env,
    server: &mut dyn ServerState,
    transport: &mut dyn Transport,
) -> Result<RunOutput> {
    let cfg = env.cfg;
    let n = env.n;
    let obs = env.obs;
    let obj = env.objective();
    let (x_star, f_star) = obj.reference_optimum()?;
    let mut rng = Rng::new(cfg.seed);
    let mut history = History::new(server.label());
    history.setup_bits_per_node = server.setup_bits_per_node(env);
    if obs.enabled() {
        obs.mark(
            "run",
            Lane::Server,
            Ctx::default(),
            Some(format!(
                "label={} n={} d={} transport={}",
                history.label, n, env.d, cfg.transport
            )),
        );
    }

    let mut up_cum = 0.0; // per-node cumulative
    let mut down_cum = 0.0;
    for round in 0..cfg.rounds {
        let round_ctx = Ctx { round: Some(round), ..Ctx::default() };
        let _round_span = obs.span("round", Lane::Server, round_ctx);
        let tally = run_one_round(env, server, transport, round, &mut rng)?;
        up_cum += tally.up_bits / n as f64;
        down_cum += tally.down_bits / n as f64;
        let eval_span = obs.span("eval", Lane::Server, round_ctx);
        let x = server.x();
        let gap = obj.loss(x) - f_star;
        let grad_norm = crate::linalg::norm2(&obj.grad(x));
        let dist = crate::linalg::norm2(&crate::linalg::sub(x, &x_star));
        history.push(RoundRecord {
            round,
            bits_up_per_node: up_cum,
            bits_down_per_node: down_cum,
            gap,
            grad_norm,
            dist_to_opt: dist,
        });
        drop(eval_span);
        if !gap.is_finite() {
            anyhow::bail!("{} diverged at round {round} (gap = {gap})", server.label());
        }
        if cfg.target_gap > 0.0 && gap <= cfg.target_gap {
            break;
        }
        if let Some(budget) = cfg.max_bits_per_node {
            // Setup bits (basis transfer etc.) count against the budget —
            // the same accounting `final_bits_per_node`/`bits_to_reach`
            // report, so methods with an initial communication cost can't
            // overshoot what the figures charge them for.
            if history.setup_bits_per_node + up_cum + down_cum >= budget {
                break;
            }
        }
    }

    Ok(RunOutput { history, x_final: server.x().to_vec(), x_star, f_star })
}

/// Global smoothness bound `L = λ_max(4·avg ∇²f_i(0)) + λ` for logistic data
/// terms (`φ″(0) = ¼` is the global max of `φ″`), used by the first-order
/// theoretical stepsizes.
pub fn estimate_smoothness(locals: &[Box<dyn LocalProblem>], lambda: f64) -> f64 {
    let d = locals[0].dim();
    let n = locals.len() as f64;
    let mut h = Mat::zeros(d, d);
    let zero = vec![0.0; d];
    for p in locals.iter() {
        h.add_scaled(4.0 / n, &p.hess(&zero));
    }
    let e = crate::linalg::sym_eigen(&h);
    e.values.first().copied().unwrap_or(0.0) + lambda
}

fn boxed<S, C>(pair: (S, Vec<C>)) -> (Box<dyn ServerState>, Vec<Box<dyn ClientStep>>)
where
    S: ServerState + 'static,
    C: ClientStep + 'static,
{
    let (server, clients) = pair;
    (
        Box::new(server),
        clients.into_iter().map(|c| Box::new(c) as Box<dyn ClientStep>).collect(),
    )
}

/// Dispatch an algorithm to its server/client split.
pub fn build_split(env: &Env) -> Result<(Box<dyn ServerState>, Vec<Box<dyn ClientStep>>)> {
    use Algorithm::*;
    Ok(match env.cfg.algorithm {
        Newton => boxed(second_order::newton::split(env)),
        Bl1 => boxed(second_order::bl1::split(env, None)),
        Bl2 => boxed(second_order::bl2::split(env, None)),
        Bl3 => boxed(second_order::bl3::split(env)?),
        FedNl => boxed(second_order::bl1::split(env, Some("fednl"))),
        FedNlBc => boxed(second_order::bl1::split(env, Some("fednl-bc"))),
        FedNlPp => boxed(second_order::bl2::split(env, Some("fednl-pp"))),
        Nl1 => boxed(second_order::nl1::split(env)?),
        Dingo => boxed(second_order::dingo::split(env)),
        Gd => boxed(first_order::gd::split(env)),
        Diana => boxed(first_order::diana::split(env)),
        Adiana => boxed(first_order::adiana::split(env)),
        SLocalGd => boxed(first_order::slocal::split(env)),
        Artemis => boxed(first_order::artemis::split(env)),
        Dore => boxed(first_order::dore::split(env)),
    })
}

/// Projection `[M]_μ` onto `{A : A = Aᵀ, A ⪰ μI}` (BL1's PD safeguard):
/// symmetrize, then clamp eigenvalues at μ.
///
/// Perf (EXPERIMENTS.md §Perf L3-1): once the Hessian estimate is learned,
/// `M − μI` is almost always PD already, so we first attempt a Cholesky
/// factorization of `M − μI` (`O(d³/3)`, ~100× cheaper than Jacobi) and only
/// fall back to the eigenvalue clamp when it fails.
pub fn project_psd(m: &Mat, mu: f64) -> Mat {
    let mut sym = m.clone();
    sym.symmetrize();
    let mut shifted = sym.clone();
    // Tiny slack so "barely ⪰ μI" doesn't bounce between paths.
    shifted.add_diag(-mu * (1.0 - 1e-12));
    if crate::linalg::CholeskyFactor::new(&shifted).is_ok() {
        return sym;
    }
    let e = crate::linalg::sym_eigen(&sym);
    e.reconstruct(|l| l.max(mu))
}

/// Independent-inclusion client sampling with `P[i ∈ S] = τ/n`
/// (the participation model of Algorithms 2–3). Guarantees at least one
/// participant by resampling empty draws. Output is ascending.
pub fn sample_clients(n: usize, tau: Option<usize>, rng: &mut Rng) -> Vec<usize> {
    let tau = tau.unwrap_or(n).min(n);
    if tau >= n {
        return (0..n).collect();
    }
    let p = tau as f64 / n as f64;
    loop {
        let s: Vec<usize> = (0..n).filter(|_| rng.bernoulli(p)).collect();
        if !s.is_empty() {
            return s;
        }
    }
}

/// Test-only serial protocol driver over *concrete* (unboxed) halves, so
/// method unit tests can drive rounds and then inspect internal state on
/// both sides of the wire.
#[cfg(test)]
pub(crate) fn step_rounds_manual(
    env: &Env,
    server: &mut dyn ServerState,
    clients: &mut [&mut dyn ClientStep],
    rounds: usize,
) -> Result<()> {
    let mut rng = Rng::new(env.cfg.seed);
    let mut rngs = client_rngs(env.cfg.seed, clients.len());
    for round in 0..rounds {
        let mut exchange = 0usize;
        while let Some(plan) = server.plan(env, round, exchange, &mut rng)? {
            let mut replies = Vec::with_capacity(plan.sends.len());
            for (i, down) in plan.sends {
                let up = clients[i].compute(
                    env.locals[i].as_ref(),
                    round,
                    exchange,
                    &down,
                    &mut rngs[i],
                )?;
                replies.push((i, up));
            }
            server.absorb(env, round, exchange, &replies, &mut rng)?;
            exchange += 1;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    fn tiny_fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 10,
            intrinsic_dim: 4,
            noise: 0.0,
            seed,
        })
    }

    #[test]
    fn project_psd_floors_eigenvalues() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // λ = 3, −1
        let p = project_psd(&a, 0.5);
        let e = crate::linalg::sym_eigen(&p);
        assert!((e.values[0] - 3.0).abs() < 1e-10);
        assert!((e.values[1] - 0.5).abs() < 1e-10);
    }

    #[test]
    fn project_psd_identity_on_pd() {
        let mut rng = Rng::new(30);
        let b = Mat::from_fn(5, 5, |_, _| rng.normal());
        let mut a = b.transpose().matmul(&b);
        a.add_diag(1.0);
        let p = project_psd(&a, 1e-6);
        assert!((&p - &a).fro_norm() < 1e-8);
    }

    #[test]
    fn sample_clients_full_and_partial() {
        let mut rng = Rng::new(31);
        assert_eq!(sample_clients(5, None, &mut rng), vec![0, 1, 2, 3, 4]);
        assert_eq!(sample_clients(5, Some(5), &mut rng), vec![0, 1, 2, 3, 4]);
        assert_eq!(sample_clients(5, Some(9), &mut rng), vec![0, 1, 2, 3, 4]);
        // τ/n inclusion rate over many rounds.
        let mut total = 0usize;
        let rounds = 4000;
        for _ in 0..rounds {
            total += sample_clients(10, Some(3), &mut rng).len();
        }
        let avg = total as f64 / rounds as f64;
        assert!((avg - 3.0).abs() < 0.25, "avg={avg}");
    }

    #[test]
    fn smoothness_upper_bounds_hessian() {
        let fed = tiny_fed(40);
        let locals = native_locals(&fed);
        let lambda = 1e-3;
        let ell = estimate_smoothness(&locals, lambda);
        let obj = GlobalObjective::new(&locals, lambda);
        let mut rng = Rng::new(41);
        for _ in 0..5 {
            let x: Vec<f64> = (0..fed.dim()).map(|_| rng.normal()).collect();
            let h = obj.hess(&x);
            let e = crate::linalg::sym_eigen(&h);
            assert!(e.values[0] <= ell + 1e-9, "λmax={} > L={}", e.values[0], ell);
        }
    }

    #[test]
    fn run_federated_newton_reaches_target() {
        let fed = tiny_fed(42);
        let cfg = RunConfig {
            algorithm: Algorithm::Newton,
            rounds: 30,
            lambda: 1e-3,
            target_gap: 1e-12,
            ..RunConfig::default()
        };
        let out = run_federated(&fed, &cfg).unwrap();
        assert!(out.final_gap() <= 1e-12, "gap={}", out.final_gap());
        // Newton should get there in well under 30 rounds.
        assert!(out.history.records.len() < 20);
    }

    #[test]
    fn bits_budget_stops_run() {
        let fed = tiny_fed(43);
        let cfg = RunConfig {
            algorithm: Algorithm::Gd,
            rounds: 10_000,
            target_gap: 0.0,
            max_bits_per_node: Some(50_000.0),
            ..RunConfig::default()
        };
        let out = run_federated(&fed, &cfg).unwrap();
        let last = out.history.records.last().unwrap();
        assert!(last.bits_per_node() >= 50_000.0);
        assert!(out.history.records.len() < 10_000);
    }

    #[test]
    fn bits_budget_includes_setup_cost() {
        // BL1's default subspace basis has a one-time r·d-float transfer
        // (Table 1's initial communication cost). The budget check must
        // charge it, like final_bits_per_node/bits_to_reach do — the old
        // comparison of up+down alone let nonzero-setup methods overshoot.
        let fed = tiny_fed(44);
        let budget = 60_000.0;
        let cfg = RunConfig {
            algorithm: Algorithm::Bl1,
            rounds: 10_000,
            target_gap: 0.0,
            max_bits_per_node: Some(budget),
            ..RunConfig::default()
        };
        let out = run_federated(&fed, &cfg).unwrap();
        let h = &out.history;
        assert!(h.setup_bits_per_node > 0.0, "need a nonzero-setup method");
        assert!(h.records.len() < 10_000, "budget never triggered");
        // Stops at the *first* round where setup+up+down crosses the
        // budget: every earlier round is still under it, setup included.
        assert!(h.final_bits_per_node() >= budget);
        for r in &h.records[..h.records.len() - 1] {
            assert!(
                r.bits_per_node() + h.setup_bits_per_node < budget,
                "round {} already over budget: {} + {} setup",
                r.round,
                r.bits_per_node(),
                h.setup_bits_per_node
            );
        }
    }

    #[test]
    fn threaded_transport_runs_and_matches_lockstep() {
        // The determinism contract in miniature (every algorithm is covered
        // by tests/transport_equivalence.rs): same seed, different backend,
        // byte-identical trace.
        let fed = tiny_fed(45);
        let mut cfg = RunConfig {
            algorithm: Algorithm::Bl1,
            rounds: 25,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let a = run_federated(&fed, &cfg).unwrap();
        cfg.transport = TransportSpec::Threaded(3);
        let b = run_federated(&fed, &cfg).unwrap();
        assert_eq!(a.history.records, b.history.records);
        assert_eq!(a.x_final, b.x_final);
    }

    #[test]
    fn run_federated_with_rejects_threaded() {
        // Caller-supplied oracles can't be rebuilt on worker threads.
        let fed = tiny_fed(46);
        let locals = native_locals(&fed);
        let features: Vec<Option<Mat>> = vec![None; locals.len()];
        let cfg = RunConfig {
            algorithm: Algorithm::Gd,
            rounds: 2,
            transport: TransportSpec::Threaded(2),
            ..RunConfig::default()
        };
        match run_federated_with(&locals, features, &cfg) {
            Ok(_) => panic!("threaded transport must be rejected without a factory"),
            Err(e) => assert!(format!("{e:#}").contains("lockstep"), "{e:#}"),
        }
    }
}
