//! Multi-process federation: the listening server loop and the standalone
//! worker entry point behind `repro worker --connect`.
//!
//! The server side ([`run_federated_listen`]) binds a real TCP listener,
//! hands every joining worker process an [`Assignment`] — the run
//! fingerprint, the full wire-rendered [`RunConfig`], and the dataset's
//! [`DataRecipe`] — and then drives the ordinary round loop over the
//! connected [`crate::transport::Tcp`] transport. The worker side
//! ([`run_worker`]) rebuilds the dataset and its half of the algorithm
//! split locally from that assignment: dataset construction and
//! [`super::build_split`] are pure functions of (recipe, config), so the
//! rebuilt `ClientStep`s and `LocalProblem`s are bit-identical to the ones
//! an in-process backend would hold, and the equivalence contract of
//! `tests/transport_equivalence.rs` extends across process boundaries
//! without a single feature byte crossing the wire.
//!
//! Handshake (docs/WIRE.md): worker dials and sends `Join`; the server
//! replies `Assign` (index in the header's `client` field); the worker
//! decodes the config, cross-checks the run fingerprint, rebuilds its
//! shards, and greets with `Hello` — or reports an `Error` frame, which the
//! server surfaces as a rejected assignment on its side.

use super::{build_split, drive, estimate_smoothness, native_local, native_locals, Env, RunOutput};
use crate::config::{RunConfig, TransportSpec};
use crate::data::{DataRecipe, FederatedDataset};
use crate::linalg::Mat;
use crate::obs::{Obs, Recorder};
use crate::transport::codec::{Assignment, FrameHeader, FrameKind};
use crate::transport::session::{FramePayload, Session};
use crate::transport::worker::{serve_connection, ClientTable};
use crate::transport::{client_rngs, TcpServer};
use anyhow::{bail, Context, Result};
use std::net::TcpStream;
use std::time::Duration;

/// Drive a federated run as the listening side of a multi-process
/// federation: bind the `listen:` transport's address, report the resolved
/// address through `announce` (so a port-0 bind can be printed before the
/// accept phase blocks), handshake the registered number of `repro worker`
/// processes, and run the round loop over their connections.
///
/// Requires a dataset that carries a [`DataRecipe`] — workers rebuild their
/// shards locally from it, so file-loaded datasets cannot serve
/// multi-process runs.
pub fn run_federated_listen(
    fed: &FederatedDataset,
    cfg: &RunConfig,
    rec: &dyn Recorder,
    announce: &mut dyn FnMut(std::net::SocketAddr),
) -> Result<RunOutput> {
    let TransportSpec::Listen { addr, .. } = &cfg.transport else {
        bail!("run_federated_listen needs a listen transport (got '{}')", cfg.transport)
    };
    let recipe = fed.recipe.as_ref().with_context(|| {
        format!(
            "dataset '{}' carries no construction recipe — remote workers rebuild \
             their shards locally, so only registry/synthetic datasets can serve \
             multi-process runs",
            fed.name
        )
    })?;
    anyhow::ensure!(!fed.clients.is_empty(), "need at least one client");
    let locals = native_locals(fed);
    let features: Vec<Option<Mat>> = fed.clients.iter().map(|c| Some(c.a.clone())).collect();
    let d = locals[0].dim();
    let n = locals.len();
    let smoothness = estimate_smoothness(&locals, cfg.lambda);
    let env = Env { locals: &locals, cfg, d, n, smoothness, features, obs: Obs::new(rec) };
    // Only the server half lives here; every worker process rebuilds its
    // client halves from the assignment below.
    let (mut server, _clients) = build_split(&env)?;
    let workers = cfg.transport.resolved_workers(n);
    let assignment = Assignment {
        fingerprint: cfg.fingerprint(),
        workers: workers as u64,
        clients: n as u64,
        config: cfg.to_wire(),
        recipe: recipe.render(),
    };
    let endpoint =
        TcpServer::bind(addr, workers, Duration::from_millis(cfg.handshake_timeout_ms))?;
    announce(endpoint.local_addr()?);
    let mut transport = endpoint.accept_remote(&assignment)?;
    drive(&env, server.as_mut(), &mut transport)
}

/// The standalone worker process: dial the round loop at `addr`, complete
/// the `Join`/`Assign` handshake, rebuild the assigned dataset and client
/// halves locally, greet with `Hello`, and serve decoded downlinks until
/// the round loop says `Bye`. `log` receives human-readable progress lines
/// (the CLI prints them; tests pass a sink).
pub fn run_worker(addr: &str, log: &mut dyn FnMut(&str)) -> Result<()> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to the round loop at {addr}"))?;
    stream.set_nodelay(true).context("setting TCP_NODELAY")?;
    let mut sess = Session::new(stream);
    sess.send_control(FrameKind::Join, 0).context("sending the Join request")?;
    let (hdr, payload) = sess.recv().context("awaiting the run assignment")?;
    let assignment = match payload {
        FramePayload::Assign(a) => a,
        FramePayload::Error(msg) => bail!("the round loop refused the join: {msg}"),
        _ => bail!("expected an Assign frame, got a {:?} frame", hdr.kind),
    };
    let w = hdr.client as usize;
    log(&format!(
        "assigned worker {w} of {} ({} clients total); rebuilding shards",
        assignment.workers, assignment.clients
    ));
    // Anything that goes wrong between Assign and Hello is reported back as
    // an Error frame, so the server surfaces "worker rejected its
    // assignment: ..." instead of waiting out the handshake timeout.
    match prepare(&assignment, w) {
        Ok(table) => {
            log(&format!("serving {} clients as worker {w}", table.len()));
            sess.send_control(FrameKind::Hello, w).context("sending the Hello greeting")?;
            let result = serve_connection(sess.into_inner(), table, w, Obs::noop());
            log(&format!("worker {w} done"));
            result
        }
        Err(e) => {
            let _ = sess.send_error(&FrameHeader::control(FrameKind::Error, w), &format!("{e:#}"));
            Err(e)
        }
    }
}

/// Rebuild this worker's share of the run from its assignment: decode the
/// wire config, cross-check the run fingerprint, rebuild the dataset from
/// its recipe, run the algorithm split, and keep the clients of residue
/// class `w` — the same pinning every other backend uses.
fn prepare(assignment: &Assignment, w: usize) -> Result<ClientTable> {
    let workers = assignment.workers as usize;
    anyhow::ensure!(w < workers, "assigned index {w} out of range ({workers} workers)");
    let cfg =
        RunConfig::from_wire(&assignment.config).context("decoding the assigned run config")?;
    let fp = cfg.fingerprint();
    if fp != assignment.fingerprint {
        bail!(
            "run fingerprint mismatch: the round loop announced {:016x} but this \
             binary derives {fp:016x} from the same config — incompatible repro \
             versions on the two hosts?",
            assignment.fingerprint
        );
    }
    let recipe =
        DataRecipe::parse(&assignment.recipe).context("decoding the assigned data recipe")?;
    let fed = recipe.build().context("rebuilding the assigned dataset")?;
    anyhow::ensure!(
        fed.n_clients() as u64 == assignment.clients,
        "the recipe yields {} clients but the assignment says {}",
        fed.n_clients(),
        assignment.clients
    );
    let locals = native_locals(&fed);
    let features: Vec<Option<Mat>> = fed.clients.iter().map(|c| Some(c.a.clone())).collect();
    let d = locals[0].dim();
    let n = locals.len();
    let smoothness = estimate_smoothness(&locals, cfg.lambda);
    let env = Env { locals: &locals, cfg: &cfg, d, n, smoothness, features, obs: Obs::noop() };
    let (_server, clients) = build_split(&env)?;
    let rngs = client_rngs(cfg.seed, n);
    Ok(clients
        .into_iter()
        .zip(rngs)
        .enumerate()
        .filter(|(i, _)| i % workers == w)
        .map(|(i, (c, r))| (i, c, r, native_local(&fed, i)))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;
    use crate::coordinator::run_federated;
    use crate::data::SyntheticSpec;
    use crate::obs::NOOP;

    fn tiny_fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 5,
            m_per_client: 25,
            dim: 8,
            intrinsic_dim: 3,
            noise: 0.0,
            seed,
        })
    }

    #[test]
    fn listen_run_matches_lockstep_in_process() {
        let fed = tiny_fed(50);
        let base = RunConfig {
            algorithm: Algorithm::Bl1,
            rounds: 6,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let lockstep = run_federated(&fed, &base).unwrap();
        let cfg = RunConfig {
            transport: TransportSpec::Listen { addr: "127.0.0.1:0".into(), workers: 2 },
            ..base
        };
        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        let out = std::thread::scope(|s| {
            let server = s.spawn(|| {
                run_federated_listen(&fed, &cfg, &NOOP, &mut |a| addr_tx.send(a).unwrap())
            });
            let addr = addr_rx.recv().unwrap().to_string();
            let workers: Vec<_> = (0..2)
                .map(|_| {
                    let addr = addr.clone();
                    s.spawn(move || run_worker(&addr, &mut |_| {}))
                })
                .collect();
            for h in workers {
                h.join().unwrap().unwrap();
            }
            server.join().unwrap()
        })
        .unwrap();
        assert_eq!(lockstep.history.records, out.history.records);
        assert_eq!(lockstep.x_final, out.x_final);
    }

    #[test]
    fn fingerprint_mismatch_is_rejected_cleanly_on_both_sides() {
        let fed = tiny_fed(51);
        let cfg =
            RunConfig { algorithm: Algorithm::Gd, rounds: 2, ..RunConfig::default() };
        let assignment = Assignment {
            fingerprint: cfg.fingerprint() ^ 0xdead_beef,
            workers: 1,
            clients: fed.n_clients() as u64,
            config: cfg.to_wire(),
            recipe: fed.recipe.as_ref().unwrap().render(),
        };
        let endpoint = TcpServer::bind("127.0.0.1:0", 1, Duration::from_secs(10)).unwrap();
        let addr = endpoint.local_addr().unwrap().to_string();
        std::thread::scope(|s| {
            let worker = s.spawn(move || run_worker(&addr, &mut |_| {}));
            // Server side: a clean error naming the rejection, not a hang.
            let server_err = endpoint.accept_remote(&assignment).unwrap_err();
            let msg = format!("{server_err:#}");
            assert!(
                msg.contains("rejected its assignment") && msg.contains("fingerprint mismatch"),
                "{msg}"
            );
            // Worker side: a clean error naming the mismatch.
            let worker_err = worker.join().unwrap().unwrap_err();
            let msg = format!("{worker_err:#}");
            assert!(msg.contains("fingerprint mismatch"), "{msg}");
        });
    }

    #[test]
    fn recipeless_dataset_is_rejected_with_a_clear_error() {
        let mut fed = tiny_fed(52);
        fed.recipe = None;
        let cfg = RunConfig {
            transport: TransportSpec::Listen { addr: "127.0.0.1:0".into(), workers: 1 },
            ..RunConfig::default()
        };
        let err = run_federated_listen(&fed, &cfg, &NOOP, &mut |_| {}).unwrap_err();
        assert!(format!("{err:#}").contains("no construction recipe"), "{err:#}");
    }
}
