//! BL1 — Basis Learn with Bidirectional Compression (Algorithm 1).
//!
//! Clients learn the *coefficient matrix* `h^i(∇²f_i(z^k))` of their Hessian
//! in a custom basis via compressed differences (`L_i^k`), the server keeps
//! the decoded aggregate `H^k = (1/n) Σ_i Σ_{jl} (L_i^k)_{jl} B_i^{jl}`, and
//! the model update is a Newton step with `[H^k]_μ`. Gradients are
//! transmitted only on `ξ^k ~ Bernoulli(p)` rounds (in basis coefficients —
//! `r` floats under the subspace basis); on other rounds the server uses the
//! estimator `g^k = [H^k]_μ(z^k − w^k) + ∇f(w^k)`. The model broadcast is
//! compressed with `Q^k`.
//!
//! With the standard basis, `p = 1`, and identity `Q`, BL1 *is* FedNL;
//! with the standard basis and compressing `Q`, it is FedNL-BC — both are
//! exposed through [`split`]'s label override and exercised by the
//! equivalence tests.
//!
//! Round protocol: exchange 0 triggers the clients (who already hold `z^k`
//! and `ξ^k` from the previous broadcast) — the uplink carries the gradient
//! coefficients (ξ rounds only) and the compressed Hessian difference
//! `S_i^k`; exchange 1 broadcasts the compressed model delta `v^k` with the
//! next round's ξ bit riding along.
//!
//! Per the repo convention (DESIGN.md §6.3), the ridge λ of eq. (16) lives at
//! the server: local Hessians are data-only (inside the data span, keeping
//! the §2.3 basis lossless) and the server uses `[H^k + λI]_μ` with `μ = λ`.

use crate::basis::HessianBasis;
use crate::compressors::{BitCost, MatCompressor, VecCompressor};
use crate::coordinator::{project_psd, Env, RoundPlan, ServerState};
use crate::linalg::{cholesky_solve, lu_solve, Mat, Vector};
use crate::problem::LocalProblem;
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::Result;

/// BL1 server: decoded Hessian aggregate, gradient anchor, Newton solve.
pub struct Bl1Server {
    label: String,
    /// Current model iterate `x^k` (the server's latest Newton solve).
    x: Vector,
    /// Broadcast model `z^k` (what clients hold).
    z: Vector,
    /// Gradient anchor `w^k`.
    w: Vector,
    /// Aggregate decoded Hessian estimate `H^k` (data part).
    pub(crate) h_agg: Mat,
    /// `∇f(w^k)` (data avg + λw), cached from the last ξ=1 round.
    grad_w: Vector,
    /// Current round's ξ (sampled at the end of the previous round; ξ⁰ = 1).
    xi: bool,
    /// Server-side basis copies (decode side of the transfer).
    pub(crate) bases: Vec<Box<dyn HessianBasis>>,
    model_comp: Box<dyn VecCompressor>,
    eta: f64,
    alpha: f64,
}

/// BL1 client: learned coefficients `L_i^k` and the model mirror.
pub struct Bl1Client {
    basis: Box<dyn HessianBasis>,
    comp: Box<dyn MatCompressor>,
    /// Learned coefficient matrix `L_i^k`.
    pub(crate) l: Mat,
    /// Model mirror `z^k`.
    z: Vector,
    /// This round's ξ (delivered with the previous broadcast; ξ⁰ = 1).
    xi: bool,
    eta: f64,
    alpha: f64,
}

/// Build the BL1 split. `fednl_label = Some(..)` forces the standard basis
/// (the FedNL / FedNL-BC specializations).
pub fn split(env: &Env, fednl_label: Option<&str>) -> (Bl1Server, Vec<Bl1Client>) {
    let d = env.d;
    let force_standard = fednl_label.is_some();
    let x0 = vec![0.0; d];

    let build_basis = |i: usize| -> Box<dyn HessianBasis> {
        if force_standard {
            Box::new(crate::basis::StandardBasis::new(d))
        } else {
            env.build_basis(i)
        }
    };

    let mut server_bases: Vec<Box<dyn HessianBasis>> = Vec::with_capacity(env.n);
    let mut clients: Vec<Bl1Client> = Vec::with_capacity(env.n);
    let mut h_agg = Mat::zeros(d, d);
    // Probed from client 0's compressor/coefficient shape below.
    let model_comp = env.cfg.model_comp.build_vec(d);
    let eta = env.cfg.eta.unwrap_or_else(|| model_comp.class_vec(d).default_stepsize());
    let mut alpha = env.cfg.alpha.unwrap_or(0.0);
    for i in 0..env.n {
        let basis = build_basis(i);
        // Compressor operates on the coefficient object.
        let (cr, cc) = basis.coeff_shape();
        let comp = env.cfg.hess_comp.build_mat(cr);
        if i == 0 && env.cfg.alpha.is_none() {
            // α default from the compressor class (Asm. 4.5/4.6) — probe on
            // the first client's coefficient size.
            alpha = comp.class(cr * cc, cr).default_stepsize();
        }
        // L_i⁰ = h(∇²f_i(x⁰)) — the paper's initialization.
        let li = basis.encode(&env.locals[i].hess(&x0));
        h_agg.add_scaled(1.0 / env.n as f64, &basis.decode(&li));
        server_bases.push(build_basis(i));
        clients.push(Bl1Client {
            basis,
            comp,
            l: li,
            z: x0.clone(),
            xi: true,
            eta,
            alpha,
        });
    }

    let obj = env.objective();
    let grad_w = obj.grad(&x0);
    let label = match fednl_label {
        Some(name) => name.to_string(),
        None => format!("bl1[{}]", server_bases[0].name()),
    };
    let server = Bl1Server {
        label,
        x: x0.clone(),
        z: x0.clone(),
        w: x0,
        h_agg,
        grad_w,
        xi: true,
        bases: server_bases,
        model_comp,
        eta,
        alpha,
    };
    (server, clients)
}

impl Bl1Server {
    /// The PD-safeguarded system matrix `[H^k + λI]_μ`, μ = λ.
    fn system_matrix(&self, lambda: f64) -> Mat {
        let mut m = self.h_agg.clone();
        m.add_diag(lambda);
        project_psd(&m, lambda)
    }
}

impl ServerState for Bl1Server {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        Ok(match exchange {
            // Trigger: clients hold z^k and ξ^k already.
            0 => Some(RoundPlan::broadcast(env.n, Packet::empty())),
            // Model broadcast (lines 18–22): v^k = Q(x^{k+1} − z^k), with
            // ξ^{k+1} riding along (1 bit).
            1 => {
                let dx = crate::linalg::sub(&self.x, &self.z);
                let (v, vcost) = self.model_comp.compress_vec(&dx, rng);
                crate::linalg::axpy(self.eta, &v, &mut self.z);
                self.xi = rng.bernoulli(env.cfg.p);
                let mut down = Packet::empty();
                down.push_vector("model_delta", v, vcost);
                down.push_flags("xi", vec![self.xi], BitCost::bits(1.0));
                Some(RoundPlan::broadcast(env.n, down))
            }
            _ => None,
        })
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        if exchange != 0 {
            return Ok(());
        }
        let n = env.n as f64;
        let lambda = env.cfg.lambda;

        // ── gradient phase (lines 4–7 / 12–15) ──
        let h_mu = self.system_matrix(lambda);
        let g: Vector = if self.xi {
            self.w = self.z.clone();
            let mut g = vec![0.0; env.d];
            for (i, up) in replies {
                let gc = up.vector("grad_coeff")?;
                crate::linalg::axpy(1.0 / n, &self.bases[*i].decode_grad(gc), &mut g);
            }
            crate::linalg::axpy(lambda, &self.z, &mut g);
            self.grad_w = g.clone();
            g
        } else {
            // g^k = [H^k]_μ (z^k − w^k) + ∇f(w^k)
            let dz = crate::linalg::sub(&self.z, &self.w);
            let mut g = h_mu.matvec(&dz);
            crate::linalg::axpy(1.0, &self.grad_w, &mut g);
            g
        };

        // ── Newton step with the *current* H^k (line 16) ──
        let step = cholesky_solve(&h_mu, &g).or_else(|_| lu_solve(&h_mu, &g))?;
        self.x = crate::linalg::sub(&self.z, &step);

        // ── Hessian learning (lines 8–9 / 17): decode the compressed
        //    differences into the aggregate ──
        for (i, up) in replies {
            let s = up.matrix("hess_delta")?;
            self.h_agg.add_scaled(self.alpha / n, &self.bases[*i].decode(s));
        }
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn setup_bits_per_node(&self, env: &Env) -> f64 {
        // Subspace bases cost r·d floats once (Table 1).
        let total: f64 = self
            .bases
            .iter()
            .map(|b| {
                if b.grad_coeff_len() < b.dim() {
                    (b.grad_coeff_len() * b.dim()) as f64 * env.cfg.float_bits as f64
                } else {
                    0.0
                }
            })
            .sum();
        total / env.n as f64
    }

    fn label(&self) -> String {
        self.label.clone()
    }
}

impl ClientStep for Bl1Client {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        exchange: usize,
        down: &Downlink,
        rng: &mut Rng,
    ) -> Result<Uplink> {
        if exchange == 1 {
            // Apply the model broadcast; stash ξ^{k+1} for the next round.
            let v = down.vector("model_delta")?;
            crate::linalg::axpy(self.eta, v, &mut self.z);
            self.xi = down.flags("xi")?[0];
            return Ok(Packet::empty());
        }
        let mut up = Packet::empty();
        // Gradient in basis coefficients, on ξ rounds only.
        if self.xi {
            let gi = local.grad(&self.z);
            let gc = self.basis.encode_grad(&gi);
            let gcost = BitCost::floats(gc.len());
            up.push_vector("grad_coeff", gc, gcost);
        }
        // Compressed Hessian-coefficient difference; learn locally in sync
        // with the server's decoded aggregate.
        let hz = local.hess(&self.z);
        let target = self.basis.encode(&hz);
        let diff = &target - &self.l;
        let (s, cost) = self.comp.compress(&diff, rng);
        self.l.add_scaled(self.alpha, &s);
        up.push_matrix("hess_delta", s, cost);
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use super::split;
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, BasisKind, RunConfig};
    use crate::coordinator::{run_federated, step_rounds_manual, RunOutput};
    use crate::data::{FederatedDataset, SyntheticSpec};
    use crate::transport::ClientStep;

    fn fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 40,
            dim: 12,
            intrinsic_dim: 5,
            noise: 0.0,
            seed,
        })
    }

    fn cfg(algorithm: Algorithm) -> RunConfig {
        RunConfig {
            algorithm,
            rounds: 250,
            lambda: 1e-3,
            hess_comp: CompressorSpec::TopK(5),
            target_gap: 1e-11,
            ..RunConfig::default()
        }
    }

    fn run(c: &RunConfig) -> RunOutput {
        run_federated(&fed(11), c).unwrap()
    }

    #[test]
    fn bl1_converges_to_high_accuracy() {
        let out = run(&cfg(Algorithm::Bl1));
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn fednl_converges() {
        let mut c = cfg(Algorithm::FedNl);
        c.hess_comp = CompressorSpec::RankR(1);
        let out = run(&c);
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl1_with_standard_basis_equals_fednl() {
        // The generalization claim: BL1 + standard basis ≡ FedNL, identical
        // trajectories under identical seeds.
        let mut a = cfg(Algorithm::Bl1);
        a.basis = Some(BasisKind::Standard);
        a.hess_comp = CompressorSpec::RankR(1);
        let mut b = cfg(Algorithm::FedNl);
        b.hess_comp = CompressorSpec::RankR(1);
        let ra = run(&a);
        let rb = run(&b);
        assert_eq!(ra.history.records.len(), rb.history.records.len());
        for (x, y) in ra.x_final.iter().zip(&rb.x_final) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn bl1_subspace_beats_fednl_on_bits() {
        // The headline claim (Figure 1 row 1): with r ≪ d, BL1's uplink to a
        // fixed gap is far below FedNL's.
        let mut a = cfg(Algorithm::Bl1);
        a.hess_comp = CompressorSpec::TopK(5); // K = r
        let mut b = cfg(Algorithm::FedNl);
        b.hess_comp = CompressorSpec::RankR(1);
        let ra = run(&a);
        let rb = run(&b);
        let bits_a = ra
            .history
            .records
            .iter()
            .find(|r| r.gap <= 1e-9)
            .map(|r| r.bits_up_per_node)
            .expect("bl1 reached 1e-9");
        let bits_b = rb
            .history
            .records
            .iter()
            .find(|r| r.gap <= 1e-9)
            .map(|r| r.bits_up_per_node)
            .expect("fednl reached 1e-9");
        assert!(
            bits_a < bits_b,
            "bl1 bits {bits_a:.0} should beat fednl bits {bits_b:.0}"
        );
    }

    #[test]
    fn bl1_bidirectional_compression_still_converges() {
        let mut c = cfg(Algorithm::Bl1);
        c.model_comp = CompressorSpec::TopK(6); // d/2
        c.p = 0.5;
        c.rounds = 600;
        let out = run(&c);
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl1_with_unbiased_compressor_uses_omega_stepsize() {
        let mut c = cfg(Algorithm::Bl1);
        c.hess_comp = CompressorSpec::RandK(5);
        c.rounds = 2500;
        let out = run(&c);
        // Rand-K on a 5×5 coefficient matrix: ω = 25/5 − 1 = 4, α = 1/5.
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn server_aggregate_tracks_decoded_coefficients() {
        // The server's incrementally-maintained H^k must equal
        // (1/n) Σ decode(L_i^k) over the *clients'* learned coefficients
        // exactly after many compressed rounds — the two sides of the wire
        // may never drift, or every Newton step is silently corrupted.
        let f = fed(12);
        let locals = crate::coordinator::native_locals(&f);
        let cfg = cfg(Algorithm::Bl1);
        let features: Vec<_> = f.clients.iter().map(|c| Some(c.a.clone())).collect();
        let env = crate::coordinator::Env {
            locals: &locals,
            cfg: &cfg,
            d: f.dim(),
            n: f.n_clients(),
            smoothness: 1.0,
            features,
            obs: crate::obs::Obs::noop(),
        };
        let (mut server, mut clients) = split(&env, None);
        {
            let mut refs: Vec<&mut dyn ClientStep> =
                clients.iter_mut().map(|c| c as &mut dyn ClientStep).collect();
            step_rounds_manual(&env, &mut server, &mut refs, 25).unwrap();
        }
        let mut expect = crate::linalg::Mat::zeros(env.d, env.d);
        for (i, c) in clients.iter().enumerate() {
            expect.add_scaled(1.0 / env.n as f64, &server.bases[i].decode(&c.l));
        }
        let drift = (&expect - &server.h_agg).fro_norm();
        assert!(drift < 1e-10, "aggregate drift {drift}");
    }

    #[test]
    fn deterministic_given_seed() {
        let c = cfg(Algorithm::Bl1);
        let a = run(&c);
        let b = run(&c);
        assert_eq!(a.x_final, b.x_final);
        assert_eq!(
            a.history.records.last().unwrap().bits_up_per_node,
            b.history.records.last().unwrap().bits_up_per_node
        );
    }
}
