//! BL1 — Basis Learn with Bidirectional Compression (Algorithm 1).
//!
//! Clients learn the *coefficient matrix* `h^i(∇²f_i(z^k))` of their Hessian
//! in a custom basis via compressed differences (`L_i^k`), the server keeps
//! the decoded aggregate `H^k = (1/n) Σ_i Σ_{jl} (L_i^k)_{jl} B_i^{jl}`, and
//! the model update is a Newton step with `[H^k]_μ`. Gradients are
//! transmitted only on `ξ^k ~ Bernoulli(p)` rounds (in basis coefficients —
//! `r` floats under the subspace basis); on other rounds the server uses the
//! estimator `g^k = [H^k]_μ(z^k − w^k) + ∇f(w^k)`. The model broadcast is
//! compressed with `Q^k`.
//!
//! With the standard basis, `p = 1`, and identity `Q`, BL1 *is* FedNL;
//! with the standard basis and compressing `Q`, it is FedNL-BC — both are
//! exposed through [`split`]'s label override and exercised by the
//! equivalence tests.
//!
//! Round protocol: exchange 0 triggers the clients (who already hold `z^k`
//! and `ξ^k` from the previous broadcast) — the uplink carries the gradient
//! coefficients (ξ rounds only) and the compressed Hessian difference
//! `S_i^k`; exchange 1 broadcasts the compressed model delta `v^k` with the
//! next round's ξ bit riding along.
//!
//! Per the repo convention (DESIGN.md §6.3), the ridge λ of eq. (16) lives at
//! the server: local Hessians are data-only (inside the data span, keeping
//! the §2.3 basis lossless) and the server uses `[H^k + λI]_μ` with `μ = λ`.

use crate::basis::{BasisScratch, HessianBasis};
use crate::compressors::{BitCost, CompressScratch, MatCompressor, VecCompressor};
use crate::coordinator::{Env, RoundPlan, ServerState};
use crate::linalg::{lu_solve, sub_into, Mat, SymCholesky, Vector};
use crate::problem::{LocalProblem, OracleScratch};
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, PacketPool, Uplink};
use anyhow::Result;

/// Server-side reusable buffers: after the warm-up round every absorb/plan
/// runs entirely inside this arena (zero heap allocations — asserted by
/// `tests/alloc_regression.rs`).
#[derive(Default)]
struct ServerScratch {
    /// The PD-safeguarded system matrix `[H^k + λI]_μ`.
    sym: Mat,
    /// Probe matrix `sym − μ(1−ε)I` for the cheap-PD check.
    shifted: Mat,
    /// Packed Cholesky workspace (probe + Newton solve).
    chol: SymCholesky,
    /// `x^{k+1} − z^k` (model-delta input).
    dx: Vector,
    /// Compressed model delta `v^k`.
    v: Vector,
    /// `z^k − w^k`.
    dz: Vector,
    /// Gradient accumulator `g^k`.
    g: Vector,
    /// One client's decoded gradient.
    gdec: Vector,
    /// Newton step.
    step: Vector,
    /// One client's decoded Hessian difference.
    hdec: Mat,
    basis: BasisScratch,
    comp: CompressScratch,
}

/// BL1 server: decoded Hessian aggregate, gradient anchor, Newton solve.
pub struct Bl1Server {
    label: String,
    /// Current model iterate `x^k` (the server's latest Newton solve).
    x: Vector,
    /// Broadcast model `z^k` (what clients hold).
    z: Vector,
    /// Gradient anchor `w^k`.
    w: Vector,
    /// Aggregate decoded Hessian estimate `H^k` (data part).
    pub(crate) h_agg: Mat,
    /// `∇f(w^k)` (data avg + λw), cached from the last ξ=1 round.
    grad_w: Vector,
    /// Current round's ξ (sampled at the end of the previous round; ξ⁰ = 1).
    xi: bool,
    /// Server-side basis copies (decode side of the transfer).
    pub(crate) bases: Vec<Box<dyn HessianBasis>>,
    model_comp: Box<dyn VecCompressor>,
    eta: f64,
    alpha: f64,
    /// Wire-object recycler shared with the clients and the round loop.
    pool: PacketPool,
    scratch: ServerScratch,
}

/// Client-side reusable buffers (same zero-allocation contract as
/// [`ServerScratch`]).
#[derive(Default)]
struct ClientScratch {
    /// Local gradient `∇f_i(z^k)`.
    grad: Vector,
    /// Local Hessian `∇²f_i(z^k)`.
    hz: Mat,
    /// Encoded coefficient target `h(∇²f_i(z^k))`.
    target: Mat,
    /// Coefficient difference `h(∇²f_i) − L_i`.
    diff: Mat,
    oracle: OracleScratch,
    basis: BasisScratch,
    comp: CompressScratch,
}

/// BL1 client: learned coefficients `L_i^k` and the model mirror.
pub struct Bl1Client {
    basis: Box<dyn HessianBasis>,
    comp: Box<dyn MatCompressor>,
    /// Learned coefficient matrix `L_i^k`.
    pub(crate) l: Mat,
    /// Model mirror `z^k`.
    z: Vector,
    /// This round's ξ (delivered with the previous broadcast; ξ⁰ = 1).
    xi: bool,
    eta: f64,
    alpha: f64,
    /// Handle to the server's recycler (uplink payloads draw from it).
    pool: PacketPool,
    scratch: ClientScratch,
}

/// Build the BL1 split. `fednl_label = Some(..)` forces the standard basis
/// (the FedNL / FedNL-BC specializations).
pub fn split(env: &Env, fednl_label: Option<&str>) -> (Bl1Server, Vec<Bl1Client>) {
    let d = env.d;
    let force_standard = fednl_label.is_some();
    let x0 = vec![0.0; d];

    let build_basis = |i: usize| -> Box<dyn HessianBasis> {
        if force_standard {
            Box::new(crate::basis::StandardBasis::new(d))
        } else {
            env.build_basis(i)
        }
    };

    let pool = PacketPool::new();
    let mut server_bases: Vec<Box<dyn HessianBasis>> = Vec::with_capacity(env.n);
    let mut clients: Vec<Bl1Client> = Vec::with_capacity(env.n);
    let mut h_agg = Mat::zeros(d, d);
    // Probed from client 0's compressor/coefficient shape below.
    let model_comp = env.cfg.model_comp.build_vec(d);
    let eta = env.cfg.eta.unwrap_or_else(|| model_comp.class_vec(d).default_stepsize());
    let mut alpha = env.cfg.alpha.unwrap_or(0.0);
    for i in 0..env.n {
        let basis = build_basis(i);
        // Compressor operates on the coefficient object.
        let (cr, cc) = basis.coeff_shape();
        let comp = env.cfg.hess_comp.build_mat(cr);
        if i == 0 && env.cfg.alpha.is_none() {
            // α default from the compressor class (Asm. 4.5/4.6) — probe on
            // the first client's coefficient size.
            alpha = comp.class(cr * cc, cr).default_stepsize();
        }
        // L_i⁰ = h(∇²f_i(x⁰)) — the paper's initialization.
        let li = basis.encode(&env.locals[i].hess(&x0));
        h_agg.add_scaled(1.0 / env.n as f64, &basis.decode(&li));
        server_bases.push(build_basis(i));
        clients.push(Bl1Client {
            basis,
            comp,
            l: li,
            z: x0.clone(),
            xi: true,
            eta,
            alpha,
            pool: pool.clone(),
            scratch: ClientScratch::default(),
        });
    }

    let obj = env.objective();
    let grad_w = obj.grad(&x0);
    let label = match fednl_label {
        Some(name) => name.to_string(),
        None => format!("bl1[{}]", server_bases[0].name()),
    };
    let server = Bl1Server {
        label,
        x: x0.clone(),
        z: x0.clone(),
        w: x0,
        h_agg,
        grad_w,
        xi: true,
        bases: server_bases,
        model_comp,
        eta,
        alpha,
        pool,
        scratch: ServerScratch::default(),
    };
    (server, clients)
}

impl Bl1Server {
    /// The PD-safeguarded system matrix `[H^k + λI]_μ`, μ = λ, left in
    /// `scratch.sym`. Allocation-free equivalent of
    /// [`crate::coordinator::project_psd`] on `H^k + λI`: the packed probe
    /// factorization performs the same arithmetic as the dense one, so the
    /// PD decision — and hence the trajectory — is bit-identical. Only the
    /// non-PD eigenvalue-clamp fallback still allocates (cold path).
    fn system_matrix_into(&mut self, lambda: f64) {
        let s = &mut self.scratch;
        s.sym.copy_from(&self.h_agg);
        s.sym.add_diag(lambda);
        s.sym.symmetrize();
        s.shifted.copy_from(&s.sym);
        s.shifted.add_diag(-lambda * (1.0 - 1e-12));
        if s.chol.factor(&s.shifted).is_err() {
            let e = crate::linalg::sym_eigen(&s.sym);
            s.sym.copy_from(&e.reconstruct(|l| l.max(lambda)));
        }
    }
}

impl ServerState for Bl1Server {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        Ok(match exchange {
            // Trigger: clients hold z^k and ξ^k already.
            0 => {
                let mut sends = self.pool.batch(env.n);
                for i in 0..env.n {
                    sends.push((i, self.pool.packet()));
                }
                Some(RoundPlan::to_clients(sends))
            }
            // Model broadcast (lines 18–22): v^k = Q(x^{k+1} − z^k), with
            // ξ^{k+1} riding along (1 bit).
            1 => {
                sub_into(&self.x, &self.z, &mut self.scratch.dx);
                let vcost = self.model_comp.compress_vec_into(
                    &self.scratch.dx,
                    &mut self.scratch.v,
                    &mut self.scratch.comp,
                    rng,
                );
                crate::linalg::axpy(self.eta, &self.scratch.v, &mut self.z);
                self.xi = rng.bernoulli(env.cfg.p);
                let mut sends = self.pool.batch(env.n);
                for i in 0..env.n {
                    let mut down = self.pool.packet();
                    down.push_vector("model_delta", self.pool.clone_slice(&self.scratch.v), vcost);
                    let mut xi = self.pool.vec_bool(1);
                    xi.push(self.xi);
                    down.push_flags("xi", xi, BitCost::bits(1.0));
                    sends.push((i, down));
                }
                Some(RoundPlan::to_clients(sends))
            }
            _ => None,
        })
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        if exchange != 0 {
            return Ok(());
        }
        let n = env.n as f64;
        let lambda = env.cfg.lambda;

        // ── gradient phase (lines 4–7 / 12–15) ──
        self.system_matrix_into(lambda); // h_mu, left in scratch.sym
        if self.xi {
            self.w.clone_from(&self.z);
            self.scratch.g.clear();
            self.scratch.g.resize(env.d, 0.0);
            for (i, up) in replies {
                let gc = up.vector("grad_coeff")?;
                self.bases[*i].decode_grad_into(gc, &mut self.scratch.gdec);
                crate::linalg::axpy(1.0 / n, &self.scratch.gdec, &mut self.scratch.g);
            }
            crate::linalg::axpy(lambda, &self.z, &mut self.scratch.g);
            self.grad_w.clone_from(&self.scratch.g);
        } else {
            // g^k = [H^k]_μ (z^k − w^k) + ∇f(w^k)
            sub_into(&self.z, &self.w, &mut self.scratch.dz);
            self.scratch.sym.matvec_into(&self.scratch.dz, &mut self.scratch.g);
            crate::linalg::axpy(1.0, &self.grad_w, &mut self.scratch.g);
        }

        // ── Newton step with the *current* H^k (line 16) ── packed Cholesky
        // first (bit-identical to the dense `cholesky_solve`), dense LU as
        // the cold fallback.
        if self.scratch.chol.factor(&self.scratch.sym).is_ok() {
            self.scratch.chol.solve_into(&self.scratch.g, &mut self.scratch.step);
        } else {
            let step = lu_solve(&self.scratch.sym, &self.scratch.g)?;
            self.scratch.step.clear();
            self.scratch.step.extend_from_slice(&step);
        }
        sub_into(&self.z, &self.scratch.step, &mut self.x);

        // ── Hessian learning (lines 8–9 / 17): decode the compressed
        //    differences into the aggregate ──
        for (i, up) in replies {
            let s = up.matrix("hess_delta")?;
            self.bases[*i].decode_into(s, &mut self.scratch.hdec, &mut self.scratch.basis);
            self.h_agg.add_scaled(self.alpha / n, &self.scratch.hdec);
        }
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn pool(&self) -> Option<&PacketPool> {
        Some(&self.pool)
    }

    fn setup_bits_per_node(&self, env: &Env) -> f64 {
        // Subspace bases cost r·d floats once (Table 1).
        let total: f64 = self
            .bases
            .iter()
            .map(|b| {
                if b.grad_coeff_len() < b.dim() {
                    (b.grad_coeff_len() * b.dim()) as f64 * env.cfg.float_bits as f64
                } else {
                    0.0
                }
            })
            .sum();
        total / env.n as f64
    }

    fn label(&self) -> String {
        self.label.clone()
    }
}

impl ClientStep for Bl1Client {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        exchange: usize,
        down: &Downlink,
        rng: &mut Rng,
    ) -> Result<Uplink> {
        if exchange == 1 {
            // Apply the model broadcast; stash ξ^{k+1} for the next round.
            let v = down.vector("model_delta")?;
            crate::linalg::axpy(self.eta, v, &mut self.z);
            self.xi = down.flags("xi")?[0];
            // Pooled even though empty: the round loop recycles every reply,
            // so acquires and recycles must balance to keep the free lists
            // from growing.
            return Ok(self.pool.packet());
        }
        let mut up = self.pool.packet();
        // Gradient in basis coefficients, on ξ rounds only.
        if self.xi {
            local.grad_into(&self.z, &mut self.scratch.grad, &mut self.scratch.oracle);
            let mut gc = self.pool.vec_f64(self.basis.grad_coeff_len());
            self.basis.encode_grad_into(&self.scratch.grad, &mut gc);
            let gcost = BitCost::floats(gc.len());
            up.push_vector("grad_coeff", gc, gcost);
        }
        // Compressed Hessian-coefficient difference; learn locally in sync
        // with the server's decoded aggregate. The compressed output lands
        // straight in a pooled matrix that then rides the wire.
        local.hess_into(&self.z, &mut self.scratch.hz, &mut self.scratch.oracle);
        self.basis.encode_into(&self.scratch.hz, &mut self.scratch.target, &mut self.scratch.basis);
        self.scratch.diff.sub_from(&self.scratch.target, &self.l);
        let (cr, cc) = self.basis.coeff_shape();
        let mut s = Mat::from_vec(0, 0, self.pool.vec_f64(cr * cc));
        let cost = self.comp.compress_mat_into(&self.scratch.diff, &mut s, &mut self.scratch.comp, rng);
        self.l.add_scaled(self.alpha, &s);
        up.push_matrix("hess_delta", s, cost);
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use super::split;
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, BasisKind, RunConfig};
    use crate::coordinator::{run_federated, step_rounds_manual, RunOutput};
    use crate::data::{FederatedDataset, SyntheticSpec};
    use crate::transport::ClientStep;

    fn fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 40,
            dim: 12,
            intrinsic_dim: 5,
            noise: 0.0,
            seed,
        })
    }

    fn cfg(algorithm: Algorithm) -> RunConfig {
        RunConfig {
            algorithm,
            rounds: 250,
            lambda: 1e-3,
            hess_comp: CompressorSpec::TopK(5),
            target_gap: 1e-11,
            ..RunConfig::default()
        }
    }

    fn run(c: &RunConfig) -> RunOutput {
        run_federated(&fed(11), c).unwrap()
    }

    #[test]
    fn bl1_converges_to_high_accuracy() {
        let out = run(&cfg(Algorithm::Bl1));
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn fednl_converges() {
        let mut c = cfg(Algorithm::FedNl);
        c.hess_comp = CompressorSpec::RankR(1);
        let out = run(&c);
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl1_with_standard_basis_equals_fednl() {
        // The generalization claim: BL1 + standard basis ≡ FedNL, identical
        // trajectories under identical seeds.
        let mut a = cfg(Algorithm::Bl1);
        a.basis = Some(BasisKind::Standard);
        a.hess_comp = CompressorSpec::RankR(1);
        let mut b = cfg(Algorithm::FedNl);
        b.hess_comp = CompressorSpec::RankR(1);
        let ra = run(&a);
        let rb = run(&b);
        assert_eq!(ra.history.records.len(), rb.history.records.len());
        for (x, y) in ra.x_final.iter().zip(&rb.x_final) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn bl1_subspace_beats_fednl_on_bits() {
        // The headline claim (Figure 1 row 1): with r ≪ d, BL1's uplink to a
        // fixed gap is far below FedNL's.
        let mut a = cfg(Algorithm::Bl1);
        a.hess_comp = CompressorSpec::TopK(5); // K = r
        let mut b = cfg(Algorithm::FedNl);
        b.hess_comp = CompressorSpec::RankR(1);
        let ra = run(&a);
        let rb = run(&b);
        let bits_a = ra
            .history
            .records
            .iter()
            .find(|r| r.gap <= 1e-9)
            .map(|r| r.bits_up_per_node)
            .expect("bl1 reached 1e-9");
        let bits_b = rb
            .history
            .records
            .iter()
            .find(|r| r.gap <= 1e-9)
            .map(|r| r.bits_up_per_node)
            .expect("fednl reached 1e-9");
        assert!(
            bits_a < bits_b,
            "bl1 bits {bits_a:.0} should beat fednl bits {bits_b:.0}"
        );
    }

    #[test]
    fn bl1_bidirectional_compression_still_converges() {
        let mut c = cfg(Algorithm::Bl1);
        c.model_comp = CompressorSpec::TopK(6); // d/2
        c.p = 0.5;
        c.rounds = 600;
        let out = run(&c);
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl1_with_unbiased_compressor_uses_omega_stepsize() {
        let mut c = cfg(Algorithm::Bl1);
        c.hess_comp = CompressorSpec::RandK(5);
        c.rounds = 2500;
        let out = run(&c);
        // Rand-K on a 5×5 coefficient matrix: ω = 25/5 − 1 = 4, α = 1/5.
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn server_aggregate_tracks_decoded_coefficients() {
        // The server's incrementally-maintained H^k must equal
        // (1/n) Σ decode(L_i^k) over the *clients'* learned coefficients
        // exactly after many compressed rounds — the two sides of the wire
        // may never drift, or every Newton step is silently corrupted.
        let f = fed(12);
        let locals = crate::coordinator::native_locals(&f);
        let cfg = cfg(Algorithm::Bl1);
        let features: Vec<_> = f.clients.iter().map(|c| Some(c.a.clone())).collect();
        let env = crate::coordinator::Env {
            locals: &locals,
            cfg: &cfg,
            d: f.dim(),
            n: f.n_clients(),
            smoothness: 1.0,
            features,
            obs: crate::obs::Obs::noop(),
        };
        let (mut server, mut clients) = split(&env, None);
        {
            let mut refs: Vec<&mut dyn ClientStep> =
                clients.iter_mut().map(|c| c as &mut dyn ClientStep).collect();
            step_rounds_manual(&env, &mut server, &mut refs, 25).unwrap();
        }
        let mut expect = crate::linalg::Mat::zeros(env.d, env.d);
        for (i, c) in clients.iter().enumerate() {
            expect.add_scaled(1.0 / env.n as f64, &server.bases[i].decode(&c.l));
        }
        let drift = (&expect - &server.h_agg).fro_norm();
        assert!(drift < 1e-10, "aggregate drift {drift}");
    }

    #[test]
    fn deterministic_given_seed() {
        let c = cfg(Algorithm::Bl1);
        let a = run(&c);
        let b = run(&c);
        assert_eq!(a.x_final, b.x_final);
        assert_eq!(
            a.history.records.last().unwrap().bits_up_per_node,
            b.history.records.last().unwrap().bits_up_per_node
        );
    }
}
