//! BL2 — Basis Learn with Bidirectional Compression **and Partial
//! Participation** (Algorithm 2).
//!
//! Each client carries its own model mirror `z_i^k` (updated only when it
//! participates) and gradient anchor `w_i^k`; the server maintains the
//! Stochastic-Newton-style aggregates
//!
//! `g^k = (1/n) Σ_i [([H_i^k]_s + l_i^k I) w_i^k − ∇f_i(w_i^k)]`,
//! `H^k = (1/n) Σ_i H_i^k`, `l^k = (1/n) Σ_i l_i^k`,
//!
//! and updates `x^{k+1} = ([H^k]_s + (l^k + λ) I)^{-1} g^k`. Positive
//! definiteness comes from the compression-error shift
//! `l_i^k = ‖[H_i^k]_s − ∇²f_i(z_i^k)‖_F` (no eigen-projection — BL2's
//! contribution vs BL1). Non-participating clients change nothing; for
//! participating clients with `ξ_i = 0` the server reconstructs the `g_i`
//! increment from the Hessian message alone (eq. 13), saving the `d`-float
//! gradient upload.
//!
//! With the standard basis this is exactly FedNL-PP (via [`split`]'s label
//! override).
//!
//! Round protocol (one exchange): the server solves with last round's
//! aggregates, samples the participants, and sends each one its compressed
//! model delta `v_i` plus its ξ_i bit; the uplink carries the compressed
//! Hessian difference `S_i`, the shift increment `Δl_i` (1 float + the ξ
//! bit, as the paper's accounting rides them along), and — on ξ_i = 1 —
//! the fresh `g_i` (`d` floats).

use crate::basis::{BasisScratch, HessianBasis};
use crate::compressors::{BitCost, MatCompressor, VecCompressor};
use crate::coordinator::{sample_clients, Env, RoundPlan, ServerState};
use crate::linalg::{lu_solve, sub_into, Mat, SymCholesky, Vector};
use crate::problem::{LocalProblem, OracleScratch};
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::Result;

/// Reusable server-side buffers: everything except the wire objects
/// themselves (the compressed `v_i` payloads) is computed in place.
#[derive(Default)]
struct ServerScratch {
    /// Symmetrized, shifted system matrix.
    sym: Mat,
    /// Packed Cholesky workspace for the Newton solve.
    chol: SymCholesky,
    /// `x^{k+1} − z_i^k`.
    dx: Vector,
    /// One client's decoded Hessian step (before the α scale).
    dec: Mat,
    /// `α · decode(S_i)`.
    delta_h: Mat,
    /// Symmetrized copy of `delta_h` for the eq. (13) reconstruction.
    sym_dh: Mat,
    /// Gradient increment buffer.
    dg: Vector,
    /// Previous `g_i` (for the aggregate delta).
    g_old: Vector,
    basis: BasisScratch,
}

/// Reusable client-side buffers (wire objects still allocate).
#[derive(Default)]
struct ClientScratch {
    /// Local Hessian `∇²f_i(z_i^{k+1})`.
    hz: Mat,
    /// Encoded coefficient target.
    target: Mat,
    /// Coefficient difference / generic matrix temp.
    diff: Mat,
    /// Decoded compressed step (before the α scale).
    dec: Mat,
    /// `α · decode(S_i)`.
    delta_h: Mat,
    /// Local gradient buffer.
    grad: Vector,
    oracle: OracleScratch,
    basis: BasisScratch,
}

/// Server-side view of one client (everything reconstructible from the
/// wire: the learned Hessian lives only in the aggregate).
struct ClientView {
    /// Mirror of the client's model mirror `z_i^k` (the server knows every
    /// `v_i` it sent).
    z: Vector,
    /// Gradient anchor `w_i^k`.
    w: Vector,
    /// `g_i^k = ([H_i]_s + l_i I) w_i − ∇f_i(w_i)`.
    g: Vector,
}

/// BL2 server.
pub struct Bl2Server {
    label: String,
    x: Vector,
    views: Vec<ClientView>,
    /// Server-side basis copies (decode side).
    bases: Vec<Box<dyn HessianBasis>>,
    /// Server aggregates.
    g_agg: Vector,
    pub(crate) h_agg: Mat,
    shift_agg: f64,
    model_comp: Box<dyn VecCompressor>,
    eta: f64,
    alpha: f64,
    /// ξ_i drawn in `plan` for this round's participants (client, ξ_i),
    /// consumed by `absorb`.
    pending_xi: Vec<(usize, bool)>,
    scratch: ServerScratch,
}

/// BL2 client.
pub struct Bl2Client {
    basis: Box<dyn HessianBasis>,
    comp: Box<dyn MatCompressor>,
    /// Learned coefficients `L_i^k`.
    pub(crate) l: Mat,
    /// Decoded Hessian estimate `H_i^k` (kept symmetric).
    pub(crate) h: Mat,
    /// Shift `l_i^k`.
    shift: f64,
    /// Local model mirror `z_i^k`.
    z: Vector,
    /// Gradient anchor `w_i^k`.
    w: Vector,
    eta: f64,
    alpha: f64,
    scratch: ClientScratch,
}

/// Build the BL2 split. `fednl_label = Some(..)` forces the standard basis
/// (FedNL-PP).
pub fn split(env: &Env, fednl_label: Option<&str>) -> (Bl2Server, Vec<Bl2Client>) {
    let d = env.d;
    let n = env.n as f64;
    let x0 = vec![0.0; d];
    let force_standard = fednl_label.is_some();
    let build_basis = |i: usize| -> Box<dyn HessianBasis> {
        if force_standard {
            Box::new(crate::basis::StandardBasis::new(d))
        } else {
            env.build_basis(i)
        }
    };

    let model_comp = env.cfg.model_comp.build_vec(d);
    let eta = env.cfg.eta.unwrap_or_else(|| model_comp.class_vec(d).default_stepsize());
    let mut alpha = env.cfg.alpha.unwrap_or(0.0);

    let mut clients = Vec::with_capacity(env.n);
    let mut views = Vec::with_capacity(env.n);
    let mut bases = Vec::with_capacity(env.n);
    let mut g_agg = vec![0.0; d];
    let mut h_agg = Mat::zeros(d, d);
    let mut shift_agg = 0.0;
    for i in 0..env.n {
        let basis = build_basis(i);
        let (cr, cc) = basis.coeff_shape();
        let comp = env.cfg.hess_comp.build_mat(cr);
        if i == 0 && env.cfg.alpha.is_none() {
            alpha = comp.class(cr * cc, cr).default_stepsize();
        }
        let hess0 = env.locals[i].hess(&x0);
        let l = basis.encode(&hess0);
        let mut h = basis.decode(&l);
        h.symmetrize();
        let shift = (&h - &hess0).fro_norm();
        // g_i⁰ = (H_i⁰ + l_i⁰ I) w⁰ − ∇f_i(w⁰); w⁰ = 0 ⇒ −∇f_i(0).
        let mut g = env.locals[i].grad(&x0);
        for v in g.iter_mut() {
            *v = -*v;
        }
        crate::linalg::axpy(1.0 / n, &g, &mut g_agg);
        h_agg.add_scaled(1.0 / n, &h);
        shift_agg += shift / n;
        views.push(ClientView { z: x0.clone(), w: x0.clone(), g: g.clone() });
        bases.push(build_basis(i));
        clients.push(Bl2Client {
            basis,
            comp,
            l,
            h,
            shift,
            z: x0.clone(),
            w: x0.clone(),
            eta,
            alpha,
            scratch: ClientScratch::default(),
        });
    }
    let label = match fednl_label {
        Some(name) => name.to_string(),
        None => format!("bl2[{}]", bases[0].name()),
    };
    let server = Bl2Server {
        label,
        x: x0,
        views,
        bases,
        g_agg,
        h_agg,
        shift_agg,
        model_comp,
        eta,
        alpha,
        pending_xi: Vec::new(),
        scratch: ServerScratch::default(),
    };
    (server, clients)
}

impl ServerState for Bl2Server {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        if exchange != 0 {
            return Ok(None);
        }
        let lambda = env.cfg.lambda;

        // ── server: Newton-type solve with last round's aggregates ──
        // packed Cholesky first (bit-identical to `cholesky_solve`), dense
        // LU as the cold fallback.
        self.scratch.sym.copy_from(&self.h_agg);
        self.scratch.sym.symmetrize();
        self.scratch.sym.add_diag(self.shift_agg + lambda);
        if self.scratch.chol.factor(&self.scratch.sym).is_ok() {
            self.scratch.chol.solve_into(&self.g_agg, &mut self.x);
        } else {
            self.x = lu_solve(&self.scratch.sym, &self.g_agg)?;
        }

        // ── participation + per-participant downlink ──
        let selected = sample_clients(env.n, env.cfg.tau, rng);
        self.pending_xi.clear();
        let mut sends = Vec::with_capacity(selected.len());
        for &i in &selected {
            // Model downlink: v_i = Q_i(x^{k+1} − z_i^k).
            sub_into(&self.x, &self.views[i].z, &mut self.scratch.dx);
            let (v, vcost) = self.model_comp.compress_vec(&self.scratch.dx, rng);
            crate::linalg::axpy(self.eta, &v, &mut self.views[i].z);
            let xi = rng.bernoulli(env.cfg.p);
            self.pending_xi.push((i, xi));
            let mut down = Packet::empty();
            down.push_vector("model_delta", v, vcost);
            // The ξ_i bit's cost rides the uplink (the paper's accounting).
            down.push_flags("xi", vec![xi], BitCost::zero());
            sends.push((i, down));
        }
        Ok(Some(RoundPlan::to_clients(sends)))
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        _exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        let n = env.n as f64;
        for ((i, up), (xi_client, xi)) in replies.iter().zip(&self.pending_xi) {
            debug_assert_eq!(i, xi_client, "absorb order must match plan order");
            // Decode the Hessian learning step exactly as the client did.
            let s = up.matrix("hess_delta")?;
            self.bases[*i].decode_into(s, &mut self.scratch.dec, &mut self.scratch.basis);
            self.scratch.delta_h.scale_from(&self.scratch.dec, self.alpha);
            let dshift = up.scalars("shift_delta")?[0];

            let view = &mut self.views[*i];
            self.scratch.g_old.clone_from(&view.g);
            if *xi {
                // w_i ← z_i^{k+1}; fresh g_i arrives on the wire.
                view.w.clone_from(&view.z);
                view.g.clear();
                view.g.extend_from_slice(up.vector("grad_update")?);
            } else {
                // Server reconstructs: Δg_i = (α·decode(S)_s + Δl·I) w_i
                // (eq. 13); no gradient upload.
                self.scratch.sym_dh.copy_from(&self.scratch.delta_h);
                self.scratch.sym_dh.symmetrize();
                self.scratch.sym_dh.matvec_into(&view.w, &mut self.scratch.dg);
                crate::linalg::axpy(dshift, &view.w, &mut self.scratch.dg);
                crate::linalg::axpy(1.0, &self.scratch.dg, &mut view.g);
            }

            // Server aggregate updates.
            sub_into(&view.g, &self.scratch.g_old, &mut self.scratch.dg);
            crate::linalg::axpy(1.0 / n, &self.scratch.dg, &mut self.g_agg);
            self.h_agg.add_scaled(1.0 / n, &self.scratch.delta_h);
            self.shift_agg += dshift / n;
        }
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn setup_bits_per_node(&self, env: &Env) -> f64 {
        let total: f64 = self
            .bases
            .iter()
            .map(|b| {
                if b.grad_coeff_len() < b.dim() {
                    (b.grad_coeff_len() * b.dim()) as f64 * env.cfg.float_bits as f64
                } else {
                    0.0
                }
            })
            .sum();
        total / env.n as f64
    }

    fn label(&self) -> String {
        self.label.clone()
    }
}

impl ClientStep for Bl2Client {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        _exchange: usize,
        down: &Downlink,
        rng: &mut Rng,
    ) -> Result<Uplink> {
        let d = self.z.len();
        // Apply the model downlink.
        let v = down.vector("model_delta")?;
        crate::linalg::axpy(self.eta, v, &mut self.z);
        let xi = down.flags("xi")?[0];

        // Hessian learning at z_i^{k+1}.
        local.hess_into(&self.z, &mut self.scratch.hz, &mut self.scratch.oracle);
        self.basis.encode_into(&self.scratch.hz, &mut self.scratch.target, &mut self.scratch.basis);
        self.scratch.diff.sub_from(&self.scratch.target, &self.l);
        let (s, scost) = self.comp.compress(&self.scratch.diff, rng);
        self.l.add_scaled(self.alpha, &s);
        self.basis.decode_into(&s, &mut self.scratch.dec, &mut self.scratch.basis);
        self.scratch.delta_h.scale_from(&self.scratch.dec, self.alpha);
        self.h += &self.scratch.delta_h;
        self.h.symmetrize();
        self.scratch.diff.sub_from(&self.h, &self.scratch.hz);
        let new_shift = self.scratch.diff.fro_norm();
        let dshift = new_shift - self.shift;
        self.shift = new_shift;

        let mut up = Packet::empty();
        up.push_matrix("hess_delta", s, scost);
        // Δl_i + the ξ_i bit always ride along.
        up.push_scalars("shift_delta", vec![dshift], BitCost::floats(1) + BitCost::bits(1.0));
        if xi {
            // w_i ← z_i^{k+1}; fresh g_i; send it whole (d floats).
            self.w.clone_from(&self.z);
            let mut g = self.h.matvec(&self.w);
            crate::linalg::axpy(self.shift, &self.w, &mut g);
            local.grad_into(&self.w, &mut self.scratch.grad, &mut self.scratch.oracle);
            crate::linalg::axpy(-1.0, &self.scratch.grad, &mut g);
            up.push_vector("grad_update", g, BitCost::floats(d));
        }
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::{run_federated, RunOutput};
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 6,
            m_per_client: 30,
            dim: 10,
            intrinsic_dim: 4,
            noise: 0.0,
            seed,
        })
    }

    fn base_cfg(algorithm: Algorithm) -> RunConfig {
        RunConfig {
            algorithm,
            rounds: 400,
            lambda: 1e-3,
            hess_comp: CompressorSpec::TopK(4),
            target_gap: 1e-11,
            ..RunConfig::default()
        }
    }

    fn run(c: &RunConfig) -> RunOutput {
        run_federated(&fed(21), c).unwrap()
    }

    #[test]
    fn bl2_full_participation_converges() {
        let out = run(&base_cfg(Algorithm::Bl2));
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl2_partial_participation_converges() {
        let mut c = base_cfg(Algorithm::Bl2);
        c.tau = Some(3);
        c.rounds = 1500;
        let out = run(&c);
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl2_lazy_gradients_converge() {
        let mut c = base_cfg(Algorithm::Bl2);
        c.p = 0.3;
        c.rounds = 1500;
        let out = run(&c);
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn fednl_pp_converges_and_costs_more_than_bl2() {
        let mut pp = base_cfg(Algorithm::FedNlPp);
        pp.hess_comp = CompressorSpec::RankR(1);
        pp.tau = Some(3);
        pp.rounds = 1500;
        let out_pp = run(&pp);
        assert!(out_pp.final_gap() <= 1e-11, "fednl-pp gap={}", out_pp.final_gap());

        let mut bl = base_cfg(Algorithm::Bl2);
        bl.tau = Some(3);
        bl.rounds = 1500;
        let out_bl = run(&bl);
        let bits = |o: &RunOutput| {
            o.history
                .records
                .iter()
                .find(|r| r.gap <= 1e-9)
                .map(|r| r.bits_up_per_node)
                .unwrap()
        };
        // Figure 4's shape: BL2 (subspace basis) is at least competitive.
        assert!(bits(&out_bl) <= bits(&out_pp) * 1.5);
    }

    #[test]
    fn bl2_bidirectional_and_pp_together() {
        // The Figure 6 regime: PP + BC simultaneously.
        let mut c = base_cfg(Algorithm::Bl2);
        c.tau = Some(3);
        c.model_comp = CompressorSpec::TopK(5); // ⌊d/2⌋
        c.p = 0.5;
        c.rounds = 2500;
        let out = run(&c);
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn xi_zero_reconstruction_matches_direct_computation() {
        // With p = 0 the server must still track g_i exactly via eq. (13):
        // compare a p=0 run's aggregate against recomputing from scratch.
        let f = fed(22);
        let mut c = base_cfg(Algorithm::Bl2);
        c.p = 1e-12; // ξ_i effectively always 0 after init
        c.rounds = 5;
        c.target_gap = 0.0;
        // Should not diverge or error; w_i stays at x⁰ and the model still
        // improves on the first solve.
        let out = run_federated(&f, &c).unwrap();
        assert!(out.final_gap().is_finite());
    }
}
