//! BL3 — Basis Learn over the symmetric space with a **PSD basis**
//! (Algorithm 3, §5).
//!
//! BL3 shares BL2's partial-participation / bidirectional structure but
//! guarantees positive definiteness *without* eigen-projections or Frobenius
//! shifts: using a basis of PSD matrices (Example 5.1), the estimator
//!
//! `H_i^k = Σ_{jl} ( β^k((L_i^k)_{jl} + 2γ_i^k) − 2γ_i^k ) B_i^{jl}`
//!
//! satisfies `H_i^k ⪰ ∇²f_i(z_i^k)` whenever
//! `β^k ≥ max_{jl} (h̃(∇²f_i)_{jl} + 2γ_i^k)/((L_i^k)_{jl} + 2γ_i^k)` —
//! every term of the difference is a non-negative multiple of a PSD matrix.
//! `γ_i^k = max{c, max_{jl}|(L_i^k)_{jl}|}` keeps denominators ≥ c > 0.
//!
//! The server maintains the split aggregates `A^k, C^k` (so the global
//! rescale by `β^k = max_i β_i^k` is free) and the split gradient shifts
//! `g_1^k, g_2^k` with `g^k = β^k g_1^k − g_2^k`.

use crate::basis::{HessianBasis, PsdBasis};
use crate::compressors::{BitCost, MatCompressor, VecCompressor};
use crate::config::Bl3Option;
use crate::coordinator::{sample_clients, CommTally, Env, Method, StepInfo};
use crate::linalg::{cholesky_solve, lu_solve, Mat, Vector};
use crate::rng::Rng;
use anyhow::Result;

struct ClientState {
    comp: Box<dyn MatCompressor>,
    /// Learned coefficients `L_i^k` (symmetric, the h̃ convention).
    l: Mat,
    /// `γ_i^k`.
    gamma: f64,
    /// `β_i^k`.
    beta: f64,
    /// `A_i^k = Σ ((L_i)_{jl} + 2γ_i) B^{jl}`.
    a: Mat,
    /// `C_i^k = Σ 2γ_i B^{jl}`.
    c: Mat,
    /// Model mirror and gradient anchor.
    z: Vector,
    w: Vector,
    /// `g_{i,1} = A_i w_i`, `g_{i,2} = C_i w_i + ∇f_i(w_i)`.
    g1: Vector,
    g2: Vector,
    /// Previous iterate's coefficient target (for β Option 1).
    prev_target: Mat,
}

/// BL3 state.
pub struct Bl3 {
    x: Vector,
    basis: PsdBasis,
    /// `Σ_{jl} B^{jl}` — the decode of the all-ones coefficient matrix,
    /// reused for the `2γ` rank-structure updates.
    ones_decoded: Mat,
    clients: Vec<ClientState>,
    beta: f64,
    a_agg: Mat,
    c_agg: Mat,
    g1_agg: Vector,
    g2_agg: Vector,
    model_comp: Box<dyn VecCompressor>,
    eta: f64,
    alpha: f64,
    c_const: f64,
    option: Bl3Option,
}

impl Bl3 {
    pub fn new(env: &Env) -> Result<Self> {
        let d = env.d;
        let n = env.n as f64;
        let x0 = vec![0.0; d];
        let basis = PsdBasis::new(d);
        let ones_decoded = basis.decode(&Mat::from_fn(d, d, |_, _| 1.0));
        let c_const = env.cfg.bl3_c;
        anyhow::ensure!(c_const > 0.0, "BL3 requires c > 0");

        let mut clients = Vec::with_capacity(env.n);
        let mut a_agg = Mat::zeros(d, d);
        let mut c_agg = Mat::zeros(d, d);
        let mut g1_agg = vec![0.0; d];
        let mut g2_agg = vec![0.0; d];
        for i in 0..env.n {
            let hess0 = env.locals[i].hess(&x0);
            let l = basis.encode(&hess0);
            let gamma = c_const.max(l.max_abs());
            // A_i = decode(L) + 2γ·decode(1), C_i = 2γ·decode(1).
            let mut a = basis.decode(&l);
            a.add_scaled(2.0 * gamma, &ones_decoded);
            let c = &ones_decoded * (2.0 * gamma);
            // β_i⁰: target == L ⇒ every ratio is 1.
            let beta = 1.0;
            // w⁰ = 0 ⇒ g1 = 0, g2 = ∇f_i(0).
            let g1 = vec![0.0; d];
            let g2 = env.locals[i].grad(&x0);
            a_agg.add_scaled(1.0 / n, &a);
            c_agg.add_scaled(1.0 / n, &c);
            crate::linalg::axpy(1.0 / n, &g1, &mut g1_agg);
            crate::linalg::axpy(1.0 / n, &g2, &mut g2_agg);
            let comp = env.cfg.hess_comp.build_mat(d);
            clients.push(ClientState {
                comp,
                prev_target: l.clone(),
                l,
                gamma,
                beta,
                a,
                c,
                z: x0.clone(),
                w: x0.clone(),
                g1,
                g2,
            });
        }

        let model_comp = env.cfg.model_comp.build_vec(d);
        let eta = env.cfg.eta.unwrap_or_else(|| model_comp.class_vec(d).default_stepsize());
        let alpha = env
            .cfg
            .alpha
            .unwrap_or_else(|| clients[0].comp.class(d * d, d).default_stepsize());
        Ok(Bl3 {
            x: x0,
            basis,
            ones_decoded,
            clients,
            beta: 1.0,
            a_agg,
            c_agg,
            g1_agg,
            g2_agg,
            model_comp,
            eta,
            alpha,
            c_const,
            option: env.cfg.bl3_option,
        })
    }

    /// Max ratio `(target_{jl} + 2γ)/(L_{jl} + 2γ)` over all entries.
    fn beta_for(target: &Mat, l: &Mat, gamma: f64) -> f64 {
        let mut beta = f64::NEG_INFINITY;
        for (t, li) in target.data().iter().zip(l.data()) {
            let denom = li + 2.0 * gamma;
            debug_assert!(denom > 0.0, "BL3 denominator not positive: {denom}");
            beta = beta.max((t + 2.0 * gamma) / denom);
        }
        beta
    }
}

impl Method for Bl3 {
    fn step(&mut self, env: &Env, _round: usize, rng: &mut Rng) -> Result<StepInfo> {
        let mut tally = CommTally::default();
        let n = env.n as f64;
        let lambda = env.cfg.lambda;
        let d = env.d;

        // ── server: x^{k+1} = (H^k + λI)^{-1} g^k, H = βA − C, g = βg₁ − g₂.
        let mut h = &self.a_agg * self.beta;
        h -= &self.c_agg;
        h.symmetrize();
        h.add_diag(lambda);
        let mut g = self.g1_agg.clone();
        for (gi, g2i) in g.iter_mut().zip(&self.g2_agg) {
            *gi = self.beta * *gi - g2i;
        }
        self.x = cholesky_solve(&h, &g).or_else(|_| lu_solve(&h, &g))?;

        // ── participation ──
        let selected = sample_clients(env.n, env.cfg.tau, rng);

        for &i in &selected {
            let c = &mut self.clients[i];

            // Model downlink.
            let dx = crate::linalg::sub(&self.x, &c.z);
            let (v, vcost) = self.model_comp.compress_vec(&dx, rng);
            tally.down(vcost, env.cfg.float_bits);
            crate::linalg::axpy(self.eta, &v, &mut c.z);

            // Hessian-coefficient learning at z_i^{k+1}.
            let target = self.basis.encode(&env.locals[i].hess(&c.z));
            let diff = &target - &c.l;
            let (s, scost) = c.comp.compress(&diff, rng);
            tally.up(scost, env.cfg.float_bits);
            let mut dl = s;
            dl.data_mut().iter_mut().for_each(|v| *v *= self.alpha);
            let l_new = &c.l + &dl;
            let gamma_new = self.c_const.max(l_new.max_abs());
            let dgamma = gamma_new - c.gamma;

            // β_i update (Option 1 uses the previous round's target).
            let beta_target = match self.option {
                Bl3Option::One => &c.prev_target,
                Bl3Option::Two => &target,
            };
            let beta_new = Self::beta_for(beta_target, &l_new, gamma_new);

            // A_i += decode(ΔL) + 2Δγ Σ B;  C_i += 2Δγ Σ B.
            let mut da = self.basis.decode(&dl);
            da.add_scaled(2.0 * dgamma, &self.ones_decoded);
            let dc = &self.ones_decoded * (2.0 * dgamma);
            c.a += &da;
            c.c += &dc;
            c.l = l_new;
            c.gamma = gamma_new;
            c.beta = beta_new;
            c.prev_target = target;

            // β_i, Δγ and ξ_i ride along every participating round.
            tally.up(BitCost::floats(2) + BitCost::bits(1.0), env.cfg.float_bits);

            let xi = rng.bernoulli(env.cfg.p);
            let g1_old = c.g1.clone();
            let g2_old = c.g2.clone();
            if xi {
                c.w = c.z.clone();
                c.g1 = c.a.matvec(&c.w);
                let mut g2 = c.c.matvec(&c.w);
                crate::linalg::axpy(1.0, &env.locals[i].grad(&c.w), &mut g2);
                c.g2 = g2;
                tally.up(BitCost::floats(2 * d), env.cfg.float_bits);
            } else {
                // Server reconstructs: Δg₁ = ΔA·w_i, Δg₂ = ΔC·w_i
                // (w_i unchanged, ∇f_i(w_i) unchanged).
                crate::linalg::axpy(1.0, &da.matvec(&c.w), &mut c.g1);
                crate::linalg::axpy(1.0, &dc.matvec(&c.w), &mut c.g2);
            }

            // Server aggregates.
            self.a_agg.add_scaled(1.0 / n, &da);
            self.c_agg.add_scaled(1.0 / n, &dc);
            crate::linalg::axpy(1.0 / n, &crate::linalg::sub(&c.g1, &g1_old), &mut self.g1_agg);
            crate::linalg::axpy(1.0 / n, &crate::linalg::sub(&c.g2, &g2_old), &mut self.g2_agg);
        }

        // β^{k+1} = max_i β_i (non-participants keep their β_i).
        self.beta = self.clients.iter().map(|c| c.beta).fold(f64::NEG_INFINITY, f64::max);

        Ok(tally.into_step())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        format!("bl3[opt{}]", if self.option == Bl3Option::One { 1 } else { 2 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::{run_federated, RunOutput};
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 5,
            m_per_client: 30,
            dim: 10,
            intrinsic_dim: 4,
            noise: 0.0,
            seed,
        })
    }

    fn base_cfg() -> RunConfig {
        RunConfig {
            algorithm: Algorithm::Bl3,
            rounds: 800,
            lambda: 1e-3,
            hess_comp: CompressorSpec::TopK(10), // K = d
            target_gap: 1e-11,
            ..RunConfig::default()
        }
    }

    #[test]
    fn bl3_converges_option_two() {
        let out = run_federated(&fed(31), &base_cfg()).unwrap();
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl3_converges_option_one() {
        let mut c = base_cfg();
        c.bl3_option = Bl3Option::One;
        let out = run_federated(&fed(31), &c).unwrap();
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl3_partial_participation() {
        let mut c = base_cfg();
        c.tau = Some(2);
        c.rounds = 3000;
        let out = run_federated(&fed(32), &c).unwrap();
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl3_lazy_gradients_and_model_compression() {
        let mut c = base_cfg();
        c.p = 0.5;
        c.model_comp = CompressorSpec::TopK(5);
        c.rounds = 3000;
        let out = run_federated(&fed(33), &c).unwrap();
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn estimator_dominates_local_hessians() {
        // The §5 PD claim: H^k + λI ⪰ λI (in fact H_i ⪰ ∇²f_i ⪰ 0). We
        // check the aggregate stays PD along a run by asserting the Cholesky
        // solve never falls back / errors, and spot-check H ⪰ avg ∇²f_i − ε.
        let f = fed(34);
        let locals = crate::coordinator::native_locals(&f);
        let cfg = base_cfg();
        let features: Vec<_> = f.clients.iter().map(|c| Some(c.a.clone())).collect();
        let env = Env {
            locals: &locals,
            cfg: &cfg,
            d: f.dim(),
            n: f.n_clients(),
            smoothness: 1.0,
            features,
        };
        let mut bl3 = Bl3::new(&env).unwrap();
        let mut rng = Rng::new(35);
        for round in 0..30 {
            bl3.step(&env, round, &mut rng).unwrap();
            // H = βA − C must dominate each client's Hessian at its mirror.
            let mut h = &bl3.a_agg * bl3.beta;
            h -= &bl3.c_agg;
            let mut avg_hess = Mat::zeros(env.d, env.d);
            for (i, c) in bl3.clients.iter().enumerate() {
                avg_hess.add_scaled(1.0 / env.n as f64, &locals[i].hess(&c.z));
            }
            let diff = &h - &avg_hess;
            let e = crate::linalg::sym_eigen(&diff);
            assert!(
                e.values.iter().all(|&l| l >= -1e-7),
                "round {round}: H − avg∇²f has eigenvalue {:?}",
                e.values.last()
            );
        }
    }

    #[test]
    fn bl3_deterministic() {
        let c = base_cfg();
        let a = run_federated(&fed(36), &c).unwrap();
        let b = run_federated(&fed(36), &c).unwrap();
        assert_eq!(a.x_final, b.x_final);
    }

    #[allow(dead_code)]
    fn bits(o: &RunOutput, gap: f64) -> Option<f64> {
        o.history.records.iter().find(|r| r.gap <= gap).map(|r| r.bits_up_per_node)
    }
}
