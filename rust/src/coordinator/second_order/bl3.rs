//! BL3 — Basis Learn over the symmetric space with a **PSD basis**
//! (Algorithm 3, §5).
//!
//! BL3 shares BL2's partial-participation / bidirectional structure but
//! guarantees positive definiteness *without* eigen-projections or Frobenius
//! shifts: using a basis of PSD matrices (Example 5.1), the estimator
//!
//! `H_i^k = Σ_{jl} ( β^k((L_i^k)_{jl} + 2γ_i^k) − 2γ_i^k ) B_i^{jl}`
//!
//! satisfies `H_i^k ⪰ ∇²f_i(z_i^k)` whenever
//! `β^k ≥ max_{jl} (h̃(∇²f_i)_{jl} + 2γ_i^k)/((L_i^k)_{jl} + 2γ_i^k)` —
//! every term of the difference is a non-negative multiple of a PSD matrix.
//! `γ_i^k = max{c, max_{jl}|(L_i^k)_{jl}|}` keeps denominators ≥ c > 0.
//!
//! The server maintains the split aggregates `A^k, C^k` (so the global
//! rescale by `β^k = max_i β_i^k` is free) and the split gradient shifts
//! `g_1^k, g_2^k` with `g^k = β^k g_1^k − g_2^k`.
//!
//! Round protocol (one exchange, like BL2): the downlink carries the
//! compressed model delta `v_i` + ξ_i; the uplink carries the compressed
//! coefficient difference `S_i`, the `(β_i, Δγ_i)` ride-alongs (2 floats +
//! the ξ bit), and — on ξ_i = 1 — the fresh split gradients `g_{i,1},
//! g_{i,2}` (2d floats). The server reconstructs `ΔA_i, ΔC_i` from the wire
//! exactly as the client applied them.

use crate::basis::{BasisScratch, HessianBasis, PsdBasis};
use crate::compressors::{BitCost, MatCompressor, VecCompressor};
use crate::config::Bl3Option;
use crate::coordinator::{sample_clients, Env, RoundPlan, ServerState};
use crate::linalg::{lu_solve, sub_into, Mat, SymCholesky, Vector};
use crate::problem::{LocalProblem, OracleScratch};
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::Result;

/// Reusable server-side buffers (wire objects still allocate).
#[derive(Default)]
struct ServerScratch {
    /// System matrix `βA − C + λI`.
    h: Mat,
    /// Packed Cholesky workspace for the Newton solve.
    chol: SymCholesky,
    /// Combined gradient `βg₁ − g₂`.
    g: Vector,
    /// `x^{k+1} − z_i^k`.
    dx: Vector,
    /// `α·S_i` and its decoded split increments.
    dl: Mat,
    da: Mat,
    dc: Mat,
    /// Matvec temp for the ξ=0 reconstruction.
    tmp: Vector,
    /// Previous split gradients (for the aggregate deltas).
    g1_old: Vector,
    g2_old: Vector,
    /// Gradient-delta buffer.
    dg: Vector,
    basis: BasisScratch,
}

/// Reusable client-side buffers (wire objects still allocate).
#[derive(Default)]
struct ClientScratch {
    /// Local Hessian at the fresh mirror.
    hz: Mat,
    /// Encoded coefficient target.
    target: Mat,
    /// Coefficient difference.
    diff: Mat,
    /// `α·S_i` and its decoded split increments.
    dl: Mat,
    da: Mat,
    dc: Mat,
    /// Local gradient buffer.
    grad: Vector,
    oracle: OracleScratch,
    basis: BasisScratch,
}

/// Server-side view of one client.
struct ClientView {
    /// Mirror of the client's model mirror.
    z: Vector,
    /// Gradient anchor `w_i^k`.
    w: Vector,
    /// `g_{i,1} = A_i w_i`, `g_{i,2} = C_i w_i + ∇f_i(w_i)`.
    g1: Vector,
    g2: Vector,
    /// `β_i^k` (non-participants keep theirs; the global β is the max).
    beta: f64,
}

/// BL3 server.
pub struct Bl3Server {
    x: Vector,
    basis: PsdBasis,
    /// `Σ_{jl} B^{jl}` — the decode of the all-ones coefficient matrix,
    /// reused for the `2γ` rank-structure updates.
    ones_decoded: Mat,
    views: Vec<ClientView>,
    pub(crate) beta: f64,
    pub(crate) a_agg: Mat,
    pub(crate) c_agg: Mat,
    g1_agg: Vector,
    g2_agg: Vector,
    model_comp: Box<dyn VecCompressor>,
    eta: f64,
    alpha: f64,
    option: Bl3Option,
    /// ξ_i drawn in `plan` for this round's participants.
    pending_xi: Vec<(usize, bool)>,
    scratch: ServerScratch,
}

/// BL3 client.
pub struct Bl3Client {
    basis: PsdBasis,
    ones_decoded: Mat,
    comp: Box<dyn MatCompressor>,
    /// Learned coefficients `L_i^k` (symmetric, the h̃ convention).
    l: Mat,
    /// `γ_i^k`.
    gamma: f64,
    /// `A_i^k = Σ ((L_i)_{jl} + 2γ_i) B^{jl}`, `C_i^k = Σ 2γ_i B^{jl}`.
    a: Mat,
    c: Mat,
    /// Model mirror and gradient anchor.
    pub(crate) z: Vector,
    w: Vector,
    /// Previous iterate's coefficient target (for β Option 1).
    prev_target: Mat,
    eta: f64,
    alpha: f64,
    c_const: f64,
    option: Bl3Option,
    scratch: ClientScratch,
}

/// Max ratio `(target_{jl} + 2γ)/(L_{jl} + 2γ)` over all entries.
fn beta_for(target: &Mat, l: &Mat, gamma: f64) -> f64 {
    let mut beta = f64::NEG_INFINITY;
    for (t, li) in target.data().iter().zip(l.data()) {
        let denom = li + 2.0 * gamma;
        debug_assert!(denom > 0.0, "BL3 denominator not positive: {denom}");
        beta = beta.max((t + 2.0 * gamma) / denom);
    }
    beta
}

/// Build the BL3 split.
pub fn split(env: &Env) -> Result<(Bl3Server, Vec<Bl3Client>)> {
    let d = env.d;
    let n = env.n as f64;
    let x0 = vec![0.0; d];
    let basis = PsdBasis::new(d);
    let ones_decoded = basis.decode(&Mat::from_fn(d, d, |_, _| 1.0));
    let c_const = env.cfg.bl3_c;
    anyhow::ensure!(c_const > 0.0, "BL3 requires c > 0");

    let model_comp = env.cfg.model_comp.build_vec(d);
    let eta = env.cfg.eta.unwrap_or_else(|| model_comp.class_vec(d).default_stepsize());
    let mut alpha = env.cfg.alpha.unwrap_or(0.0);

    let mut clients = Vec::with_capacity(env.n);
    let mut views = Vec::with_capacity(env.n);
    let mut a_agg = Mat::zeros(d, d);
    let mut c_agg = Mat::zeros(d, d);
    let mut g1_agg = vec![0.0; d];
    let mut g2_agg = vec![0.0; d];
    for i in 0..env.n {
        let hess0 = env.locals[i].hess(&x0);
        let l = basis.encode(&hess0);
        let gamma = c_const.max(l.max_abs());
        // A_i = decode(L) + 2γ·decode(1), C_i = 2γ·decode(1).
        let mut a = basis.decode(&l);
        a.add_scaled(2.0 * gamma, &ones_decoded);
        let c = &ones_decoded * (2.0 * gamma);
        // w⁰ = 0 ⇒ g1 = 0, g2 = ∇f_i(0); β_i⁰ = 1 (target == L).
        let g1 = vec![0.0; d];
        let g2 = env.locals[i].grad(&x0);
        a_agg.add_scaled(1.0 / n, &a);
        c_agg.add_scaled(1.0 / n, &c);
        crate::linalg::axpy(1.0 / n, &g1, &mut g1_agg);
        crate::linalg::axpy(1.0 / n, &g2, &mut g2_agg);
        let comp = env.cfg.hess_comp.build_mat(d);
        if i == 0 && env.cfg.alpha.is_none() {
            alpha = comp.class(d * d, d).default_stepsize();
        }
        views.push(ClientView {
            z: x0.clone(),
            w: x0.clone(),
            g1: g1.clone(),
            g2: g2.clone(),
            beta: 1.0,
        });
        clients.push(Bl3Client {
            basis: PsdBasis::new(d),
            ones_decoded: ones_decoded.clone(),
            comp,
            prev_target: l.clone(),
            l,
            gamma,
            a,
            c,
            z: x0.clone(),
            w: x0.clone(),
            eta,
            alpha,
            c_const,
            option: env.cfg.bl3_option,
            scratch: ClientScratch::default(),
        });
    }

    let server = Bl3Server {
        x: x0,
        basis,
        ones_decoded,
        views,
        beta: 1.0,
        a_agg,
        c_agg,
        g1_agg,
        g2_agg,
        model_comp,
        eta,
        alpha,
        option: env.cfg.bl3_option,
        pending_xi: Vec::new(),
        scratch: ServerScratch::default(),
    };
    Ok((server, clients))
}

impl ServerState for Bl3Server {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        if exchange != 0 {
            return Ok(None);
        }
        let lambda = env.cfg.lambda;

        // ── server: x^{k+1} = (H^k + λI)^{-1} g^k, H = βA − C, g = βg₁ − g₂.
        self.scratch.h.scale_from(&self.a_agg, self.beta);
        self.scratch.h -= &self.c_agg;
        self.scratch.h.symmetrize();
        self.scratch.h.add_diag(lambda);
        self.scratch.g.clone_from(&self.g1_agg);
        for (gi, g2i) in self.scratch.g.iter_mut().zip(&self.g2_agg) {
            *gi = self.beta * *gi - g2i;
        }
        // Packed Cholesky first (bit-identical to `cholesky_solve`), dense
        // LU as the cold fallback.
        if self.scratch.chol.factor(&self.scratch.h).is_ok() {
            self.scratch.chol.solve_into(&self.scratch.g, &mut self.x);
        } else {
            self.x = lu_solve(&self.scratch.h, &self.scratch.g)?;
        }

        // ── participation + per-participant downlink ──
        let selected = sample_clients(env.n, env.cfg.tau, rng);
        self.pending_xi.clear();
        let mut sends = Vec::with_capacity(selected.len());
        for &i in &selected {
            sub_into(&self.x, &self.views[i].z, &mut self.scratch.dx);
            let (v, vcost) = self.model_comp.compress_vec(&self.scratch.dx, rng);
            crate::linalg::axpy(self.eta, &v, &mut self.views[i].z);
            let xi = rng.bernoulli(env.cfg.p);
            self.pending_xi.push((i, xi));
            let mut down = Packet::empty();
            down.push_vector("model_delta", v, vcost);
            // The ξ_i bit's cost rides the uplink (the paper's accounting).
            down.push_flags("xi", vec![xi], BitCost::zero());
            sends.push((i, down));
        }
        Ok(Some(RoundPlan::to_clients(sends)))
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        _exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        let n = env.n as f64;
        for ((i, up), (xi_client, xi)) in replies.iter().zip(&self.pending_xi) {
            debug_assert_eq!(i, xi_client, "absorb order must match plan order");
            let s = up.matrix("hess_delta")?;
            let ride = up.scalars("beta_gamma")?;
            let (beta_new, dgamma) = (ride[0], ride[1]);

            // Reconstruct ΔA_i, ΔC_i exactly as the client applied them.
            self.scratch.dl.scale_from(s, self.alpha);
            self.basis.decode_into(&self.scratch.dl, &mut self.scratch.da, &mut self.scratch.basis);
            self.scratch.da.add_scaled(2.0 * dgamma, &self.ones_decoded);
            self.scratch.dc.scale_from(&self.ones_decoded, 2.0 * dgamma);

            let view = &mut self.views[*i];
            self.scratch.g1_old.clone_from(&view.g1);
            self.scratch.g2_old.clone_from(&view.g2);
            if *xi {
                view.w.clone_from(&view.z);
                view.g1.clear();
                view.g1.extend_from_slice(up.vector("g1")?);
                view.g2.clear();
                view.g2.extend_from_slice(up.vector("g2")?);
            } else {
                // Δg₁ = ΔA·w_i, Δg₂ = ΔC·w_i (w_i and ∇f_i(w_i) unchanged).
                self.scratch.da.matvec_into(&view.w, &mut self.scratch.tmp);
                crate::linalg::axpy(1.0, &self.scratch.tmp, &mut view.g1);
                self.scratch.dc.matvec_into(&view.w, &mut self.scratch.tmp);
                crate::linalg::axpy(1.0, &self.scratch.tmp, &mut view.g2);
            }
            view.beta = beta_new;

            // Server aggregates.
            self.a_agg.add_scaled(1.0 / n, &self.scratch.da);
            self.c_agg.add_scaled(1.0 / n, &self.scratch.dc);
            sub_into(&view.g1, &self.scratch.g1_old, &mut self.scratch.dg);
            crate::linalg::axpy(1.0 / n, &self.scratch.dg, &mut self.g1_agg);
            sub_into(&view.g2, &self.scratch.g2_old, &mut self.scratch.dg);
            crate::linalg::axpy(1.0 / n, &self.scratch.dg, &mut self.g2_agg);
        }

        // β^{k+1} = max_i β_i (non-participants keep their β_i).
        self.beta = self.views.iter().map(|v| v.beta).fold(f64::NEG_INFINITY, f64::max);
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        format!("bl3[opt{}]", if self.option == Bl3Option::One { 1 } else { 2 })
    }
}

impl ClientStep for Bl3Client {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        _exchange: usize,
        down: &Downlink,
        rng: &mut Rng,
    ) -> Result<Uplink> {
        let d = self.z.len();
        // Model downlink.
        let v = down.vector("model_delta")?;
        crate::linalg::axpy(self.eta, v, &mut self.z);
        let xi = down.flags("xi")?[0];

        // Hessian-coefficient learning at z_i^{k+1}.
        local.hess_into(&self.z, &mut self.scratch.hz, &mut self.scratch.oracle);
        self.basis.encode_into(&self.scratch.hz, &mut self.scratch.target, &mut self.scratch.basis);
        self.scratch.diff.sub_from(&self.scratch.target, &self.l);
        let (s, scost) = self.comp.compress(&self.scratch.diff, rng);
        self.scratch.dl.scale_from(&s, self.alpha);
        // L_i ← L_i + ΔL in place (`x + 1·y` is bit-identical to `x + y`).
        self.l.add_scaled(1.0, &self.scratch.dl);
        let gamma_new = self.c_const.max(self.l.max_abs());
        let dgamma = gamma_new - self.gamma;

        // β_i update (Option 1 uses the previous round's target).
        let beta_target = match self.option {
            Bl3Option::One => &self.prev_target,
            Bl3Option::Two => &self.scratch.target,
        };
        let beta_new = beta_for(beta_target, &self.l, gamma_new);

        // A_i += decode(ΔL) + 2Δγ Σ B;  C_i += 2Δγ Σ B.
        self.basis.decode_into(&self.scratch.dl, &mut self.scratch.da, &mut self.scratch.basis);
        self.scratch.da.add_scaled(2.0 * dgamma, &self.ones_decoded);
        self.scratch.dc.scale_from(&self.ones_decoded, 2.0 * dgamma);
        self.a += &self.scratch.da;
        self.c += &self.scratch.dc;
        self.gamma = gamma_new;
        self.prev_target.copy_from(&self.scratch.target);

        let mut up = Packet::empty();
        up.push_matrix("hess_delta", s, scost);
        // β_i, Δγ and ξ_i ride along every participating round.
        up.push_scalars(
            "beta_gamma",
            vec![beta_new, dgamma],
            BitCost::floats(2) + BitCost::bits(1.0),
        );
        if xi {
            self.w.clone_from(&self.z);
            let g1 = self.a.matvec(&self.w);
            let mut g2 = self.c.matvec(&self.w);
            local.grad_into(&self.w, &mut self.scratch.grad, &mut self.scratch.oracle);
            crate::linalg::axpy(1.0, &self.scratch.grad, &mut g2);
            up.push_vector("g1", g1, BitCost::floats(d));
            up.push_vector("g2", g2, BitCost::floats(d));
        }
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::{run_federated, RunOutput};
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 5,
            m_per_client: 30,
            dim: 10,
            intrinsic_dim: 4,
            noise: 0.0,
            seed,
        })
    }

    fn base_cfg() -> RunConfig {
        RunConfig {
            algorithm: Algorithm::Bl3,
            rounds: 800,
            lambda: 1e-3,
            hess_comp: CompressorSpec::TopK(10), // K = d
            target_gap: 1e-11,
            ..RunConfig::default()
        }
    }

    #[test]
    fn bl3_converges_option_two() {
        let out = run_federated(&fed(31), &base_cfg()).unwrap();
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl3_converges_option_one() {
        let mut c = base_cfg();
        c.bl3_option = Bl3Option::One;
        let out = run_federated(&fed(31), &c).unwrap();
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl3_partial_participation() {
        let mut c = base_cfg();
        c.tau = Some(2);
        c.rounds = 3000;
        let out = run_federated(&fed(32), &c).unwrap();
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn bl3_lazy_gradients_and_model_compression() {
        let mut c = base_cfg();
        c.p = 0.5;
        c.model_comp = CompressorSpec::TopK(5);
        c.rounds = 3000;
        let out = run_federated(&fed(33), &c).unwrap();
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn estimator_dominates_local_hessians() {
        // The §5 PD claim: H^k + λI ⪰ λI (in fact H_i ⪰ ∇²f_i ⪰ 0). We
        // drive the wire protocol directly and spot-check H ⪰ avg ∇²f_i − ε
        // at the clients' model mirrors.
        let f = fed(34);
        let locals = crate::coordinator::native_locals(&f);
        let cfg = base_cfg();
        let features: Vec<_> = f.clients.iter().map(|c| Some(c.a.clone())).collect();
        let env = Env {
            locals: &locals,
            cfg: &cfg,
            d: f.dim(),
            n: f.n_clients(),
            smoothness: 1.0,
            features,
            obs: crate::obs::Obs::noop(),
        };
        let (mut server, mut clients) = split(&env).unwrap();
        let mut rng = crate::rng::Rng::new(env.cfg.seed);
        let mut rngs = crate::transport::client_rngs(env.cfg.seed, clients.len());
        for round in 0..30 {
            // Drive one round of the wire protocol by hand.
            let mut exchange = 0usize;
            while let Some(plan) = server.plan(&env, round, exchange, &mut rng).unwrap() {
                let mut replies = Vec::with_capacity(plan.sends.len());
                for (i, down) in plan.sends {
                    let up = clients[i]
                        .compute(env.locals[i].as_ref(), round, exchange, &down, &mut rngs[i])
                        .unwrap();
                    replies.push((i, up));
                }
                server.absorb(&env, round, exchange, &replies, &mut rng).unwrap();
                exchange += 1;
            }
            // H = βA − C must dominate each client's Hessian at its mirror.
            let mut h = &server.a_agg * server.beta;
            h -= &server.c_agg;
            let mut avg_hess = Mat::zeros(env.d, env.d);
            for (i, c) in clients.iter().enumerate() {
                avg_hess.add_scaled(1.0 / env.n as f64, &locals[i].hess(&c.z));
            }
            let diff = &h - &avg_hess;
            let e = crate::linalg::sym_eigen(&diff);
            assert!(
                e.values.iter().all(|&l| l >= -1e-7),
                "round {round}: H − avg∇²f has eigenvalue {:?}",
                e.values.last()
            );
        }
    }

    #[test]
    fn bl3_deterministic() {
        let c = base_cfg();
        let a = run_federated(&fed(36), &c).unwrap();
        let b = run_federated(&fed(36), &c).unwrap();
        assert_eq!(a.x_final, b.x_final);
    }

    #[allow(dead_code)]
    fn bits(o: &RunOutput, gap: f64) -> Option<f64> {
        o.history.records.iter().find(|r| r.gap <= gap).map(|r| r.bits_up_per_node)
    }
}
