//! DINGO [Crane & Roosta, 2019] — Distributed Newton-type method for
//! Gradient-norm Optimization.
//!
//! Each iteration decreases `‖∇f‖²` via Hessian-vector style quantities:
//!
//! 1. Clients send `∇f_i(x)` → server averages `g` and broadcasts it.
//! 2. Clients send `H_i g` and `H̃_i^† g̃`, where `H̃_i = [H_i; φ I]`
//!    (Tikhonov-augmented) and `g̃ = [g; 0]`, so
//!    `H̃_i^† g̃ = (H_i² + φ²I)^{-1} H_i g`.
//! 3. Server broadcasts `h = (1/n) Σ H_i g`. Clients whose direction fails
//!    the alignment test `⟨H̃_i^†g̃, h⟩ ≥ θ‖g‖²` send the
//!    Lagrangian-corrected direction
//!    `p_i = −H̃_i^†g̃ − λ_i (H̃_iᵀH̃_i)^{-1} h` with the exact multiplier
//!    restoring equality (DINGO Case 3).
//! 4. Backtracking line search on `‖∇f(x + αp)‖²` over
//!    `α ∈ {1, 2⁻¹, …, 2⁻¹⁰}` — each trial is one gradient round trip,
//!    i.e. one exchange of the round.
//!
//! Parameters follow the authors' choice used in the paper's experiments:
//! `θ = 10⁻⁴, φ = 10⁻⁶, ρ = 10⁻⁴`. Local Hessians include the ridge
//! (DINGO has no server-side Hessian model to fold λ into).
//!
//! Wire-cost conventions match the pre-transport accounting: the model
//! point rides exchange 0 uncharged (its cost is the line-search trial
//! broadcasts), phase-2 uplinks are charged `2d` floats covering both
//! Hessian-vector quantities, and the phase-3 direction is covered by that
//! same charge.

use crate::compressors::BitCost;
use crate::coordinator::{Env, RoundPlan, ServerState};
use crate::linalg::{sym_eigen, EigenDecomposition, Vector};
use crate::problem::LocalProblem;
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::Result;

/// DINGO server: drives the 4-phase round as a sequence of exchanges.
pub struct DingoServer {
    x: Vector,
    rho: f64,
    // ── per-round scratch (reset at exchange 0) ──
    g: Vector,
    g_norm_sq: f64,
    h_g: Vector,
    p: Vector,
    pt_h: f64,
    proceed: bool,
    accepted: bool,
    x_try: Vector,
}

/// DINGO client: local spectral quantities, cached between exchanges.
pub struct DingoClient {
    lambda: f64,
    theta: f64,
    phi: f64,
    // ── per-round scratch ──
    x: Vector,
    g: Vector,
    eig: Option<EigenDecomposition>,
    pinv_g: Vector,
}

/// Build the DINGO split.
pub fn split(env: &Env) -> (DingoServer, Vec<DingoClient>) {
    let d = env.d;
    let server = DingoServer {
        x: vec![0.0; d],
        rho: 1e-4,
        g: vec![0.0; d],
        g_norm_sq: 0.0,
        h_g: vec![0.0; d],
        p: vec![0.0; d],
        pt_h: 0.0,
        proceed: false,
        accepted: false,
        x_try: vec![0.0; d],
    };
    let clients = (0..env.n)
        .map(|_| DingoClient {
            lambda: env.cfg.lambda,
            theta: 1e-4,
            phi: 1e-6,
            x: vec![0.0; d],
            g: vec![0.0; d],
            eig: None,
            pinv_g: vec![0.0; d],
        })
        .collect();
    (server, clients)
}

impl ServerState for DingoServer {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        _rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        let d = env.d;
        Ok(match exchange {
            // Phase 1a: ask for gradients at the current model (the model
            // point rides uncharged — see the module notes).
            0 => {
                self.proceed = false;
                self.accepted = false;
                let mut down = Packet::empty();
                down.push_vector("x", self.x.clone(), BitCost::zero());
                Some(RoundPlan::broadcast(env.n, down))
            }
            // Phase 1b: broadcast g (d floats), flagging whether the round
            // continues (a numerically-zero gradient ends it here, after
            // the charge — matching the reference accounting).
            1 => {
                let mut down = Packet::empty();
                down.push_vector("g", self.g.clone(), BitCost::floats(d));
                down.push_flags("proceed", vec![self.proceed], BitCost::zero());
                Some(RoundPlan::broadcast(env.n, down))
            }
            // Phase 2→3: broadcast h = avg H_i g (d floats).
            2 => {
                if !self.proceed {
                    return Ok(None);
                }
                let mut down = Packet::empty();
                down.push_vector("h_g", self.h_g.clone(), BitCost::floats(d));
                Some(RoundPlan::broadcast(env.n, down))
            }
            // Phase 4: line-search trials, one gradient round trip each.
            e => {
                if !self.proceed || self.accepted {
                    return Ok(None);
                }
                let t = e - 3;
                if t > 10 {
                    // Smallest step as a fallback (DINGO's theory guarantees
                    // acceptance; numerically we take the most conservative
                    // trial).
                    crate::linalg::axpy(0.5_f64.powi(10), &self.p, &mut self.x);
                    return Ok(None);
                }
                let alpha = 0.5_f64.powi(t as i32);
                self.x_try = self.x.clone();
                crate::linalg::axpy(alpha, &self.p, &mut self.x_try);
                let mut down = Packet::empty();
                down.push_vector("x_try", self.x_try.clone(), BitCost::floats(d));
                Some(RoundPlan::broadcast(env.n, down))
            }
        })
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        let n = env.n as f64;
        let d = env.d;
        match exchange {
            0 => {
                let mut g = vec![0.0; d];
                for (_, up) in replies {
                    crate::linalg::axpy(1.0 / n, up.vector("grad")?, &mut g);
                }
                crate::linalg::axpy(env.cfg.lambda, &self.x, &mut g);
                self.g_norm_sq = crate::linalg::norm2_sq(&g);
                self.g = g;
                self.proceed = self.g_norm_sq >= 1e-300;
            }
            1 => {
                if !self.proceed {
                    return Ok(());
                }
                let mut h_g = vec![0.0; d];
                for (_, up) in replies {
                    crate::linalg::axpy(1.0 / n, up.vector("hess_g")?, &mut h_g);
                }
                self.h_g = h_g;
            }
            2 => {
                let mut p = vec![0.0; d];
                for (_, up) in replies {
                    crate::linalg::axpy(1.0 / n, up.vector("direction")?, &mut p);
                }
                self.pt_h = crate::linalg::dot(&p, &self.h_g);
                self.p = p;
            }
            _ => {
                let mut g_try = vec![0.0; d];
                for (_, up) in replies {
                    crate::linalg::axpy(1.0 / n, up.vector("grad")?, &mut g_try);
                }
                crate::linalg::axpy(env.cfg.lambda, &self.x_try, &mut g_try);
                let t = exchange - 3;
                let alpha = 0.5_f64.powi(t as i32);
                if crate::linalg::norm2_sq(&g_try)
                    <= self.g_norm_sq + 2.0 * alpha * self.rho * self.pt_h
                {
                    self.x = self.x_try.clone();
                    self.accepted = true;
                }
            }
        }
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        "dingo".into()
    }
}

impl ClientStep for DingoClient {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        exchange: usize,
        down: &Downlink,
        _rng: &mut Rng,
    ) -> Result<Uplink> {
        let mut up = Packet::empty();
        match exchange {
            0 => {
                self.x = down.vector("x")?.to_vec();
                self.eig = None;
                let gi = local.grad(&self.x);
                let d = gi.len();
                up.push_vector("grad", gi, BitCost::floats(d));
            }
            1 => {
                self.g = down.vector("g")?.to_vec();
                if !down.flags("proceed")?[0] {
                    return Ok(up);
                }
                // Regularized local Hessian (DINGO folds λ in locally).
                let mut hi = local.hess(&self.x);
                hi.add_diag(self.lambda);
                let d = self.x.len();
                let hg = hi.matvec(&self.g);
                let e = sym_eigen(&hi);
                // In the eigenbasis of H_i: H̃^†g̃ = λ/(λ²+φ²) ⊙ ĝ.
                let vt_g = e.vectors.matvec_t(&self.g);
                let mut pinv_g = vec![0.0; d];
                for k in 0..d {
                    let lam = e.values[k];
                    let denom = lam * lam + self.phi * self.phi;
                    pinv_g[k] = lam / denom * vt_g[k];
                }
                self.pinv_g = e.vectors.matvec(&pinv_g);
                self.eig = Some(e);
                // H_i g and H̃^†g̃ up: 2d floats.
                up.push_vector("hess_g", hg, BitCost::floats(2 * d));
            }
            2 => {
                let h_g = down.vector("h_g")?;
                // audit:allow(panic-safety): phase 2 always follows phase 1 of the same round, which populated self.eig.
                let e = self.eig.as_ref().expect("phase-2 eigens cached");
                let d = self.x.len();
                // (H̃ᵀH̃)^{-1}h = V 1/(λ²+φ²) Vᵀ h.
                let vt_h = e.vectors.matvec_t(h_g);
                let mut inv_h = vec![0.0; d];
                for k in 0..d {
                    let lam = e.values[k];
                    inv_h[k] = 1.0 / (lam * lam + self.phi * self.phi) * vt_h[k];
                }
                let inv_h = e.vectors.matvec(&inv_h);

                let g_norm_sq = crate::linalg::norm2_sq(&self.g);
                let align = crate::linalg::dot(&self.pinv_g, h_g);
                let mut pi: Vector;
                if align >= self.theta * g_norm_sq {
                    // Case 1/2: the plain pseudo-inverse direction works.
                    pi = crate::linalg::scale(-1.0, &self.pinv_g);
                } else {
                    // Case 3: Lagrangian correction. λ_i > 0 restores
                    // ⟨−p_i, h⟩ = θ‖g‖² exactly.
                    let denom = crate::linalg::dot(&inv_h, h_g).max(1e-300);
                    let lam_i = (self.theta * g_norm_sq - align) / denom;
                    pi = crate::linalg::scale(-1.0, &self.pinv_g);
                    crate::linalg::axpy(-lam_i, &inv_h, &mut pi);
                }
                // Already covered by the 2d-float phase-2 charge.
                up.push_vector("direction", pi, BitCost::zero());
            }
            _ => {
                let x_try = down.vector("x_try")?;
                let gi = local.grad(x_try);
                let d = gi.len();
                up.push_vector("grad", gi, BitCost::floats(d));
            }
        }
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed,
        })
    }

    #[test]
    fn dingo_decreases_gradient_norm_monotonically() {
        let cfg = RunConfig {
            algorithm: Algorithm::Dingo,
            rounds: 25,
            lambda: 1e-3,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(51), &cfg).unwrap();
        let norms: Vec<f64> = out.history.records.iter().map(|r| r.grad_norm).collect();
        for w in norms.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "‖∇f‖ increased: {} → {}", w[0], w[1]);
        }
    }

    #[test]
    fn dingo_converges() {
        let cfg = RunConfig {
            algorithm: Algorithm::Dingo,
            rounds: 60,
            lambda: 1e-3,
            target_gap: 1e-10,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(52), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-10, "gap={}", out.final_gap());
    }

    #[test]
    fn dingo_communication_is_expensive() {
        // Line search makes DINGO's per-iteration cost ≫ d floats — the
        // reason BL1 dominates it in Figure 1.
        let cfg = RunConfig {
            algorithm: Algorithm::Dingo,
            rounds: 2,
            lambda: 1e-3,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(53), &cfg).unwrap();
        let per_round = out.history.records[0].bits_up_per_node;
        let d_floats = 8.0 * 64.0;
        assert!(per_round > 3.0 * d_floats, "per_round={per_round}");
    }
}
