//! DINGO [Crane & Roosta, 2019] — Distributed Newton-type method for
//! Gradient-norm Optimization.
//!
//! Each iteration decreases `‖∇f‖²` via Hessian-vector style quantities:
//!
//! 1. Clients send `∇f_i(x)` → server averages `g` and broadcasts it.
//! 2. Clients send `H_i g` and `H̃_i^† g̃`, where `H̃_i = [H_i; φ I]`
//!    (Tikhonov-augmented) and `g̃ = [g; 0]`, so
//!    `H̃_i^† g̃ = (H_i² + φ²I)^{-1} H_i g`.
//! 3. Server forms `h = (1/n) Σ H_i g`. Clients whose direction fails the
//!    alignment test `⟨H̃_i^†g̃, h⟩ ≥ θ‖g‖²` send the Lagrangian-corrected
//!    direction `p_i = −H̃_i^†g̃ − λ_i (H̃_iᵀH̃_i)^{-1} h` with the exact
//!    multiplier restoring equality (DINGO Case 3).
//! 4. Backtracking line search on `‖∇f(x + αp)‖²` over
//!    `α ∈ {1, 2⁻¹, …, 2⁻¹⁰}` (each trial costs a gradient round trip).
//!
//! Parameters follow the authors' choice used in the paper's experiments:
//! `θ = 10⁻⁴, φ = 10⁻⁶, ρ = 10⁻⁴`. Local Hessians include the ridge
//! (DINGO has no server-side Hessian model to fold λ into).

use crate::compressors::BitCost;
use crate::coordinator::{CommTally, Env, Method, StepInfo};
use crate::linalg::{sym_eigen, Vector};
use crate::rng::Rng;
use anyhow::Result;

/// DINGO state.
pub struct Dingo {
    x: Vector,
    theta: f64,
    phi: f64,
    rho: f64,
}

impl Dingo {
    pub fn new(env: &Env) -> Self {
        Dingo { x: vec![0.0; env.d], theta: 1e-4, phi: 1e-6, rho: 1e-4 }
    }

    /// Global regularized gradient.
    fn grad(env: &Env, x: &[f64]) -> Vector {
        let n = env.n as f64;
        let mut g = vec![0.0; env.d];
        for i in 0..env.n {
            crate::linalg::axpy(1.0 / n, &env.locals[i].grad(x), &mut g);
        }
        crate::linalg::axpy(env.cfg.lambda, x, &mut g);
        g
    }
}

impl Method for Dingo {
    fn step(&mut self, env: &Env, _round: usize, rng: &mut Rng) -> Result<StepInfo> {
        let _ = rng;
        let mut tally = CommTally::default();
        let n = env.n as f64;
        let d = env.d;
        let fb = env.cfg.float_bits;

        // 1. Gradient round.
        let g = Self::grad(env, &self.x);
        for _ in 0..env.n {
            tally.up(BitCost::floats(d), fb); // ∇f_i up
            tally.down(BitCost::floats(d), fb); // g broadcast
        }
        let g_norm_sq = crate::linalg::norm2_sq(&g);
        if g_norm_sq < 1e-300 {
            return Ok(tally.into_step());
        }

        // 2. Per-client spectral quantities via eigendecomposition of the
        //    regularized local Hessian (exact pseudo-inverse algebra).
        let mut h_g = vec![0.0; d]; // (1/n) Σ H_i g
        let mut eigs = Vec::with_capacity(env.n);
        for i in 0..env.n {
            let hi = env.hess_reg(i, &self.x);
            let e = sym_eigen(&hi);
            let hg = hi.matvec(&g);
            crate::linalg::axpy(1.0 / n, &hg, &mut h_g);
            tally.up(BitCost::floats(2 * d), fb); // H_i g and H̃^†g̃ up
            eigs.push(e);
        }
        for _ in 0..env.n {
            tally.down(BitCost::floats(d), fb); // h broadcast
        }

        // Per-client candidate directions with the case analysis.
        let mut p = vec![0.0; d];
        for e in &eigs {
            // In the eigenbasis of H_i: H̃^†g̃ = λ/(λ²+φ²) ⊙ ĝ,
            // (H̃ᵀH̃)^{-1}v = 1/(λ²+φ²) ⊙ v̂.
            let vt_g = e.vectors.matvec_t(&g);
            let vt_h = e.vectors.matvec_t(&h_g);
            let mut pinv_g = vec![0.0; d];
            let mut inv_h = vec![0.0; d];
            for k in 0..d {
                let lam = e.values[k];
                let denom = lam * lam + self.phi * self.phi;
                pinv_g[k] = lam / denom * vt_g[k];
                inv_h[k] = 1.0 / denom * vt_h[k];
            }
            let pinv_g = e.vectors.matvec(&pinv_g);
            let inv_h = e.vectors.matvec(&inv_h);

            let align = crate::linalg::dot(&pinv_g, &h_g);
            let mut pi: Vector;
            if align >= self.theta * g_norm_sq {
                // Case 1/2: the plain pseudo-inverse direction works.
                pi = crate::linalg::scale(-1.0, &pinv_g);
            } else {
                // Case 3: Lagrangian correction. λ_i > 0 restores
                // ⟨−p_i, h⟩ = θ‖g‖² exactly.
                let denom = crate::linalg::dot(&inv_h, &h_g).max(1e-300);
                let lam_i = (self.theta * g_norm_sq - align) / denom;
                pi = crate::linalg::scale(-1.0, &pinv_g);
                crate::linalg::axpy(-lam_i, &inv_h, &mut pi);
            }
            crate::linalg::axpy(1.0 / n, &pi, &mut p);
        }
        // Direction uplink already charged (2d); correction term reuse.

        // 3. Backtracking line search on ‖∇f‖².
        let pt_h = crate::linalg::dot(&p, &h_g);
        let mut accepted = false;
        for t in 0..=10 {
            let alpha = 0.5_f64.powi(t);
            let mut x_try = self.x.clone();
            crate::linalg::axpy(alpha, &p, &mut x_try);
            let g_try = Self::grad(env, &x_try);
            // One gradient round trip per trial.
            for _ in 0..env.n {
                tally.up(BitCost::floats(d), fb);
                tally.down(BitCost::floats(d), fb);
            }
            if crate::linalg::norm2_sq(&g_try) <= g_norm_sq + 2.0 * alpha * self.rho * pt_h {
                self.x = x_try;
                accepted = true;
                break;
            }
        }
        if !accepted {
            // Smallest step as a fallback (DINGO's theory guarantees
            // acceptance; numerically we take the most conservative trial).
            crate::linalg::axpy(0.5_f64.powi(10), &p, &mut self.x);
        }

        Ok(tally.into_step())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn label(&self) -> String {
        "dingo".into()
    }
}

#[cfg(test)]
mod tests {
    
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 30,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed,
        })
    }

    #[test]
    fn dingo_decreases_gradient_norm_monotonically() {
        let cfg = RunConfig {
            algorithm: Algorithm::Dingo,
            rounds: 25,
            lambda: 1e-3,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(51), &cfg).unwrap();
        let norms: Vec<f64> = out.history.records.iter().map(|r| r.grad_norm).collect();
        for w in norms.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "‖∇f‖ increased: {} → {}", w[0], w[1]);
        }
    }

    #[test]
    fn dingo_converges() {
        let cfg = RunConfig {
            algorithm: Algorithm::Dingo,
            rounds: 60,
            lambda: 1e-3,
            target_gap: 1e-10,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(52), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-10, "gap={}", out.final_gap());
    }

    #[test]
    fn dingo_communication_is_expensive() {
        // Line search makes DINGO's per-iteration cost ≫ d floats — the
        // reason BL1 dominates it in Figure 1.
        let cfg = RunConfig {
            algorithm: Algorithm::Dingo,
            rounds: 2,
            lambda: 1e-3,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(53), &cfg).unwrap();
        let per_round = out.history.records[0].bits_up_per_node;
        let d_floats = 8.0 * 64.0;
        assert!(per_round > 3.0 * d_floats, "per_round={per_round}");
    }
}
