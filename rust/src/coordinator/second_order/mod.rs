//! Second-order federated methods: the paper's BL1/BL2/BL3, their FedNL
//! specializations, and the NL1 / DINGO / Newton baselines.

mod bl1;
mod bl2;
mod bl3;
mod dingo;
mod newton;
mod nl1;

pub use bl1::Bl1;
pub use bl2::Bl2;
pub use bl3::Bl3;
pub use dingo::Dingo;
pub use newton::NewtonMethod;
pub use nl1::Nl1;
