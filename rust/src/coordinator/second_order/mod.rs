//! Second-order federated methods: the paper's BL1/BL2/BL3, their FedNL
//! specializations, and the NL1 / DINGO / Newton baselines — each as a
//! `ServerState` + `ClientStep` pair built by the module's `split`
//! constructor.

pub mod bl1;
pub mod bl2;
pub mod bl3;
pub mod dingo;
pub mod newton;
pub mod nl1;

pub use bl1::{Bl1Client, Bl1Server};
pub use bl2::{Bl2Client, Bl2Server};
pub use bl3::{Bl3Client, Bl3Server};
pub use dingo::{DingoClient, DingoServer};
pub use newton::{NewtonClient, NewtonServer};
pub use nl1::{Nl1Client, Nl1Server};
