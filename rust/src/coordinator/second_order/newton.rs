//! Classical Newton's method in the distributed setting.
//!
//! Three implementations from the paper's §2, selected by the configured
//! basis:
//! * **naive** (§2.1, standard basis): each client ships its full gradient
//!   (`d` floats) and Hessian (`d²` floats) every round;
//! * **symmetric packing** (Example 4.2 basis): `d(d+1)/2` Hessian floats;
//! * **basis implementation** (§2.3, subspace basis): `r` gradient
//!   coefficients + `r²` Hessian coefficients after an `r·d`-float one-time
//!   basis transfer — the Figure 2 comparison.
//!
//! The server reconstructs exact Hessians (the bases are lossless on GLM
//! data-Hessians), so iterates are identical across bases — only the wire
//! cost differs, which is precisely the point of Figure 2.
//!
//! Round protocol: exchange 0 polls every client for its gradient/Hessian
//! coefficients at the current model; exchange 1 broadcasts the solved
//! model (`d` floats) back.

use crate::basis::{BasisScratch, HessianBasis};
use crate::compressors::BitCost;
use crate::coordinator::{Env, RoundPlan, ServerState};
use crate::linalg::{lu_solve, Mat, SymCholesky, Vector};
use crate::problem::{LocalProblem, OracleScratch};
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::Result;

/// Reusable server-side buffers (wire objects still allocate).
#[derive(Default)]
struct ServerScratch {
    /// Averaged gradient.
    g: Vector,
    /// Averaged Hessian / system matrix.
    h: Mat,
    /// One client's decoded gradient.
    gdec: Vector,
    /// One client's decoded Hessian.
    hdec: Mat,
    /// Packed Cholesky workspace for the Newton solve.
    chol: SymCholesky,
    /// Newton step.
    step: Vector,
    basis: BasisScratch,
}

/// Reusable client-side buffers (wire objects still allocate).
#[derive(Default)]
struct ClientScratch {
    /// Local gradient.
    grad: Vector,
    /// Local Hessian.
    hess: Mat,
    oracle: OracleScratch,
}

/// Wire cost of one client's Hessian in its basis (floats).
fn hess_floats(basis: &dyn HessianBasis) -> usize {
    let (r, c) = basis.coeff_shape();
    if basis.name() == "symtri" {
        // Lower-triangular packing.
        r * (r + 1) / 2
    } else {
        r * c
    }
}

/// Newton server: decodes coefficients, solves, broadcasts the model.
pub struct NewtonServer {
    x: Vector,
    /// Server-side basis copies (decode side of the basis transfer).
    pub(crate) bases: Vec<Box<dyn HessianBasis>>,
    scratch: ServerScratch,
}

/// Newton client: encodes exact local gradient/Hessian at its model mirror.
pub struct NewtonClient {
    basis: Box<dyn HessianBasis>,
    /// Model mirror `x^k` (kept in sync by the exchange-1 broadcast).
    x: Vector,
    scratch: ClientScratch,
}

/// Build the server/client split for classical Newton.
pub fn split(env: &Env) -> (NewtonServer, Vec<NewtonClient>) {
    let server_bases: Vec<Box<dyn HessianBasis>> = (0..env.n).map(|i| env.build_basis(i)).collect();
    let clients = (0..env.n)
        .map(|i| NewtonClient {
            basis: env.build_basis(i),
            x: vec![0.0; env.d],
            scratch: ClientScratch::default(),
        })
        .collect();
    (
        NewtonServer { x: vec![0.0; env.d], bases: server_bases, scratch: ServerScratch::default() },
        clients,
    )
}

impl ServerState for NewtonServer {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        _rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        Ok(match exchange {
            // Poll every client for coefficients at the current model.
            0 => Some(RoundPlan::broadcast(env.n, Packet::empty())),
            // Broadcast the solved model.
            1 => {
                let mut down = Packet::empty();
                down.push_vector("model", self.x.clone(), BitCost::floats(env.d));
                Some(RoundPlan::broadcast(env.n, down))
            }
            _ => None,
        })
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        if exchange != 0 {
            return Ok(());
        }
        let n = env.n as f64;
        let d = env.d;
        self.scratch.g.clear();
        self.scratch.g.resize(d, 0.0);
        self.scratch.h.resize_zeroed(d, d);
        for (i, up) in replies {
            let basis = &self.bases[*i];
            let gc = up.vector("grad_coeff")?;
            let hc = up.matrix("hess_coeff")?;
            basis.decode_grad_into(gc, &mut self.scratch.gdec);
            crate::linalg::axpy(1.0 / n, &self.scratch.gdec, &mut self.scratch.g);
            basis.decode_into(hc, &mut self.scratch.hdec, &mut self.scratch.basis);
            self.scratch.h.add_scaled(1.0 / n, &self.scratch.hdec);
        }
        // Ridge term (server-side, eq. 16).
        crate::linalg::axpy(env.cfg.lambda, &self.x, &mut self.scratch.g);
        self.scratch.h.add_diag(env.cfg.lambda);
        // Packed Cholesky first (bit-identical to `cholesky_solve`), dense
        // LU as the cold fallback.
        if self.scratch.chol.factor(&self.scratch.h).is_ok() {
            self.scratch.chol.solve_into(&self.scratch.g, &mut self.scratch.step);
        } else {
            let step = lu_solve(&self.scratch.h, &self.scratch.g)?;
            self.scratch.step.clear();
            self.scratch.step.extend_from_slice(&step);
        }
        for (xi, si) in self.x.iter_mut().zip(&self.scratch.step) {
            *xi -= si;
        }
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn setup_bits_per_node(&self, env: &Env) -> f64 {
        // Basis transfer: rd floats for the subspace basis, none otherwise.
        let total: f64 = self
            .bases
            .iter()
            .map(|b| {
                if b.grad_coeff_len() < b.dim() {
                    (b.grad_coeff_len() * b.dim()) as f64 * env.cfg.float_bits as f64
                } else {
                    0.0
                }
            })
            .sum();
        total / env.n as f64
    }

    fn label(&self) -> String {
        format!("newton[{}]", self.bases.first().map(|b| b.name()).unwrap_or_default())
    }
}

impl ClientStep for NewtonClient {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        exchange: usize,
        down: &Downlink,
        _rng: &mut Rng,
    ) -> Result<Uplink> {
        if exchange == 1 {
            self.x.clear();
            self.x.extend_from_slice(down.vector("model")?);
            return Ok(Packet::empty());
        }
        local.grad_into(&self.x, &mut self.scratch.grad, &mut self.scratch.oracle);
        local.hess_into(&self.x, &mut self.scratch.hess, &mut self.scratch.oracle);
        // Encode → wire → decode (asserting losslessness is covered by
        // basis tests; here we just run the actual path).
        let gc = self.basis.encode_grad(&self.scratch.grad);
        let hc = self.basis.encode(&self.scratch.hess);
        let mut up = Packet::empty();
        let gcost = BitCost::floats(gc.len());
        up.push_vector("grad_coeff", gc, gcost);
        let hcost = BitCost::floats(hess_floats(self.basis.as_ref()));
        up.push_matrix("hess_coeff", hc, hcost);
        Ok(up)
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{Algorithm, BasisKind, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 40,
            dim: 12,
            intrinsic_dim: 5,
            noise: 0.0,
            seed: 7,
        })
    }

    fn run(basis: BasisKind) -> crate::coordinator::RunOutput {
        let cfg = RunConfig {
            algorithm: Algorithm::Newton,
            basis: Some(basis),
            rounds: 25,
            lambda: 1e-3,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        run_federated(&fed(), &cfg).unwrap()
    }

    #[test]
    fn quadratic_convergence_reaches_machine_precision() {
        let out = run(BasisKind::Standard);
        assert!(out.final_gap() < 1e-13, "gap={}", out.final_gap());
    }

    #[test]
    fn iterates_identical_across_bases() {
        // Lossless bases ⇒ identical Newton trajectories (Figure 2's premise).
        let std = run(BasisKind::Standard);
        let sub = run(BasisKind::Subspace);
        let tri = run(BasisKind::SymTri);
        for ((a, b), c) in std.x_final.iter().zip(&sub.x_final).zip(&tri.x_final) {
            assert!((a - b).abs() < 1e-9, "std vs subspace: {a} vs {b}");
            assert!((a - c).abs() < 1e-9, "std vs symtri");
        }
    }

    #[test]
    fn subspace_basis_is_cheaper_on_the_wire() {
        // r=5, d=12 ⇒ r² + r ≪ d² + d per round (Figure 2 / Table 1).
        let std = run(BasisKind::Standard);
        let sub = run(BasisKind::Subspace);
        let std_up = std.history.records.last().unwrap().bits_up_per_node;
        let sub_up = sub.history.records.last().unwrap().bits_up_per_node;
        assert!(
            sub_up < std_up / 3.0,
            "subspace {sub_up} should be ≪ standard {std_up}"
        );
        // And the setup cost is r·d floats.
        assert!(sub.history.setup_bits_per_node > 0.0);
        assert_eq!(std.history.setup_bits_per_node, 0.0);
    }

    #[test]
    fn symtri_halves_hessian_floats() {
        let std = run(BasisKind::Standard);
        let tri = run(BasisKind::SymTri);
        let rounds = std.history.records.len().min(tri.history.records.len());
        let std_up = std.history.records[rounds - 1].bits_up_per_node;
        let tri_up = tri.history.records[rounds - 1].bits_up_per_node;
        // d² + d vs d(d+1)/2 + d floats.
        let d = 12.0_f64;
        let expect_ratio = (d * (d + 1.0) / 2.0 + d) / (d * d + d);
        let ratio = tri_up / std_up;
        assert!((ratio - expect_ratio).abs() < 0.02, "ratio={ratio} expect={expect_ratio}");
    }
}
