//! Classical Newton's method in the distributed setting.
//!
//! Three implementations from the paper's §2, selected by the configured
//! basis:
//! * **naive** (§2.1, standard basis): each client ships its full gradient
//!   (`d` floats) and Hessian (`d²` floats) every round;
//! * **symmetric packing** (Example 4.2 basis): `d(d+1)/2` Hessian floats;
//! * **basis implementation** (§2.3, subspace basis): `r` gradient
//!   coefficients + `r²` Hessian coefficients after an `r·d`-float one-time
//!   basis transfer — the Figure 2 comparison.
//!
//! The server reconstructs exact Hessians (the bases are lossless on GLM
//! data-Hessians), so iterates are identical across bases — only the wire
//! cost differs, which is precisely the point of Figure 2.

use crate::basis::HessianBasis;
use crate::compressors::BitCost;
use crate::coordinator::{CommTally, Env, Method, StepInfo};
use crate::linalg::{cholesky_solve, lu_solve, Mat, Vector};
use crate::rng::Rng;
use anyhow::Result;

/// Distributed exact Newton.
pub struct NewtonMethod {
    x: Vector,
    bases: Vec<Box<dyn HessianBasis>>,
}

impl NewtonMethod {
    pub fn new(env: &Env) -> Self {
        let bases = (0..env.n).map(|i| env.build_basis(i)).collect();
        NewtonMethod { x: vec![0.0; env.d], bases }
    }

    /// Wire cost of one client's Hessian in its basis (floats).
    fn hess_floats(basis: &dyn HessianBasis) -> usize {
        let (r, c) = basis.coeff_shape();
        if basis.name() == "symtri" {
            // Lower-triangular packing.
            r * (r + 1) / 2
        } else {
            r * c
        }
    }
}

impl Method for NewtonMethod {
    fn step(&mut self, env: &Env, _round: usize, _rng: &mut Rng) -> Result<StepInfo> {
        let mut tally = CommTally::default();
        let n = env.n as f64;
        let d = env.d;

        // Clients send exact gradient + Hessian coefficients.
        let mut g = vec![0.0; d];
        let mut h = Mat::zeros(d, d);
        for i in 0..env.n {
            let basis = &self.bases[i];
            let gi = env.locals[i].grad(&self.x);
            let hi = env.locals[i].hess(&self.x);
            // Encode → wire → decode (asserting losslessness is covered by
            // basis tests; here we just run the actual path).
            let gc = basis.encode_grad(&gi);
            let hc = basis.encode(&hi);
            tally.up(
                BitCost::floats(gc.len()) + BitCost::floats(Self::hess_floats(basis.as_ref())),
                env.cfg.float_bits,
            );
            let gi_dec = basis.decode_grad(&gc);
            let hi_dec = basis.decode(&hc);
            crate::linalg::axpy(1.0 / n, &gi_dec, &mut g);
            h.add_scaled(1.0 / n, &hi_dec);
        }
        // Ridge term (server-side, eq. 16).
        crate::linalg::axpy(env.cfg.lambda, &self.x, &mut g);
        h.add_diag(env.cfg.lambda);

        let step = cholesky_solve(&h, &g).or_else(|_| lu_solve(&h, &g))?;
        for (xi, si) in self.x.iter_mut().zip(&step) {
            *xi -= si;
        }
        // Model broadcast.
        for _ in 0..env.n {
            tally.down(BitCost::floats(d), env.cfg.float_bits);
        }
        Ok(tally.into_step())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn setup_bits_per_node(&self, env: &Env) -> f64 {
        // Basis transfer: rd floats for the subspace basis, none otherwise.
        let total: f64 = self
            .bases
            .iter()
            .map(|b| {
                if b.grad_coeff_len() < b.dim() {
                    (b.grad_coeff_len() * b.dim()) as f64 * env.cfg.float_bits as f64
                } else {
                    0.0
                }
            })
            .sum();
        total / env.n as f64
    }

    fn label(&self) -> String {
        format!("newton[{}]", self.bases.first().map(|b| b.name()).unwrap_or_default())
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{Algorithm, BasisKind, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed() -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 40,
            dim: 12,
            intrinsic_dim: 5,
            noise: 0.0,
            seed: 7,
        })
    }

    fn run(basis: BasisKind) -> crate::coordinator::RunOutput {
        let cfg = RunConfig {
            algorithm: Algorithm::Newton,
            basis: Some(basis),
            rounds: 25,
            lambda: 1e-3,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        run_federated(&fed(), &cfg).unwrap()
    }

    #[test]
    fn quadratic_convergence_reaches_machine_precision() {
        let out = run(BasisKind::Standard);
        assert!(out.final_gap() < 1e-13, "gap={}", out.final_gap());
    }

    #[test]
    fn iterates_identical_across_bases() {
        // Lossless bases ⇒ identical Newton trajectories (Figure 2's premise).
        let std = run(BasisKind::Standard);
        let sub = run(BasisKind::Subspace);
        let tri = run(BasisKind::SymTri);
        for ((a, b), c) in std.x_final.iter().zip(&sub.x_final).zip(&tri.x_final) {
            assert!((a - b).abs() < 1e-9, "std vs subspace: {a} vs {b}");
            assert!((a - c).abs() < 1e-9, "std vs symtri");
        }
    }

    #[test]
    fn subspace_basis_is_cheaper_on_the_wire() {
        // r=5, d=12 ⇒ r² + r ≪ d² + d per round (Figure 2 / Table 1).
        let std = run(BasisKind::Standard);
        let sub = run(BasisKind::Subspace);
        let std_up = std.history.records.last().unwrap().bits_up_per_node;
        let sub_up = sub.history.records.last().unwrap().bits_up_per_node;
        assert!(
            sub_up < std_up / 3.0,
            "subspace {sub_up} should be ≪ standard {std_up}"
        );
        // And the setup cost is r·d floats.
        assert!(sub.history.setup_bits_per_node > 0.0);
        assert_eq!(std.history.setup_bits_per_node, 0.0);
    }

    #[test]
    fn symtri_halves_hessian_floats() {
        let std = run(BasisKind::Standard);
        let tri = run(BasisKind::SymTri);
        let rounds = std.history.records.len().min(tri.history.records.len());
        let std_up = std.history.records[rounds - 1].bits_up_per_node;
        let tri_up = tri.history.records[rounds - 1].bits_up_per_node;
        // d² + d vs d(d+1)/2 + d floats.
        let d = 12.0_f64;
        let expect_ratio = (d * (d + 1.0) / 2.0 + d) / (d * d + d);
        let ratio = tri_up / std_up;
        assert!((ratio - expect_ratio).abs() < 0.02, "ratio={ratio} expect={expect_ratio}");
    }
}
