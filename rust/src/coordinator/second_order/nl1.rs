//! NL1 / NewtonLearn [Islamov et al. 2021] — the §2.2 baseline.
//!
//! Exploits the GLM problem structure only: the server is assumed to know
//! every client's raw data `{a_{ij}}` (an `m·d`-float one-time upload, and a
//! privacy concession Table 1 calls out), after which the Hessian
//! `∇²f_i(x) = (1/m) Σ_j φ″_{ij}(a_{ij}ᵀx) a_{ij}a_{ij}ᵀ` is determined by
//! the `m` scalar coefficients `φ″_{ij}`. Clients *learn* those coefficients
//! on the server via unbiased compression of the differences (Rand-K with
//! `α = 1/(ω+1) = K/m` in the paper's experiments), and the server
//! incrementally maintains `H_i^k` with `K` rank-one updates per client per
//! round.
//!
//! Positive definiteness follows NL1's projection choice: the server clamps
//! the learned coefficients at 0 when assembling (logistic `φ″ ≥ 0`), so the
//! assembled matrix is always PSD and `+λI` makes it PD.
//!
//! Round protocol: exchange 0 polls every client — the uplink carries the
//! full local gradient (`d` floats, NL1 is not lazy) and the compressed
//! coefficient difference; exchange 1 broadcasts the solved model.

use crate::compressors::{BitCost, CompressorClass, VecCompressor};
use crate::coordinator::{Env, RoundPlan, ServerState};
use crate::linalg::{lu_solve, sub_into, Mat, SymCholesky, Vector};
use crate::problem::LocalProblem;
use crate::rng::Rng;
use crate::transport::{ClientStep, Downlink, Packet, Uplink};
use anyhow::{Context, Result};

/// Reusable server-side buffers (wire objects still allocate).
#[derive(Default)]
struct ServerScratch {
    /// System matrix `H^k + λI`.
    h: Mat,
    /// Packed Cholesky workspace for the Newton solve.
    chol: SymCholesky,
    /// Averaged gradient.
    g: Vector,
    /// Newton step.
    step: Vector,
}

/// NL1 server: revealed data + learned per-datapoint coefficients.
pub struct Nl1Server {
    x: Vector,
    z: Vector,
    /// Learned coefficients per client (server copy, kept in sync with the
    /// client's by applying the same wire updates).
    pub(crate) coeffs: Vec<Vector>,
    /// Server-side assembled Hessian estimate `(1/n)Σ H_i` with clamped
    /// coefficients, maintained incrementally.
    pub(crate) h_agg: Mat,
    alpha: f64,
    scratch: ServerScratch,
}

/// Reusable client-side buffers (wire objects still allocate).
#[derive(Default)]
struct ClientScratch {
    /// Margins `A z` for the φ″ targets.
    margins: Vector,
    /// Coefficient target `φ″(a_jᵀz)`.
    target: Vector,
    /// Coefficient difference.
    diff: Vector,
}

/// NL1 client: its own data (for the φ″ targets) and coefficient copy.
pub struct Nl1Client {
    /// This client's feature matrix (its own data — no revelation here;
    /// the *server's* copy is what Table 1 charges).
    features: Mat,
    /// Learned per-datapoint coefficients `l_{ij}^k` (length m).
    coeffs: Vector,
    comp: Box<dyn VecCompressor>,
    /// Model mirror `z^k`.
    z: Vector,
    alpha: f64,
    scratch: ClientScratch,
}

/// The Hessian's per-datapoint weights `φ″(a_jᵀx)` — for logistic
/// regression `σ(z)σ(−z)`, *without* the 1/m factor (NL1's convention keeps
/// 1/m in the assembly).
fn hess_coeffs(features: &Mat, x: &[f64]) -> Vector {
    features
        .matvec(x)
        .into_iter()
        .map(|z| {
            let s = crate::problem::sigmoid(z);
            s * (1.0 - s)
        })
        .collect()
}

/// Allocation-free [`hess_coeffs`] (bit-identical: same margins, same map).
fn hess_coeffs_into(features: &Mat, x: &[f64], margins: &mut Vector, out: &mut Vector) {
    features.matvec_into(x, margins);
    out.clear();
    out.extend(margins.iter().map(|&z| {
        let s = crate::problem::sigmoid(z);
        s * (1.0 - s)
    }));
}

/// Assemble `(1/m) Σ_j max(l_j, 0) a_j a_jᵀ` from coefficients.
pub(crate) fn assemble(features: &Mat, coeffs: &[f64]) -> Mat {
    let m = features.rows() as f64;
    let w: Vector = coeffs.iter().map(|&c| c.max(0.0) / m).collect();
    features.gram_scaled(&w)
}

/// Build the NL1 split.
pub fn split(env: &Env) -> Result<(Nl1Server, Vec<Nl1Client>)> {
    let d = env.d;
    let n = env.n as f64;
    let x0 = vec![0.0; d];
    let mut clients = Vec::with_capacity(env.n);
    let mut coeffs_srv = Vec::with_capacity(env.n);
    let mut h_agg = Mat::zeros(d, d);
    let mut alpha = env.cfg.alpha.unwrap_or(0.0);
    for i in 0..env.n {
        let features = env.features[i]
            .as_ref()
            .context("NL1 requires server access to client features (§2.2)")?
            .clone();
        let m = env.locals[i].n_points();
        anyhow::ensure!(m > 0, "NL1 requires data-based local problems");
        // Initialize with the exact coefficients at x⁰ — equivalently
        // H_i⁰ = ∇²f_i(x⁰), matching the other methods' initialization.
        let coeffs = hess_coeffs(&features, &x0);
        h_agg.add_scaled(1.0 / n, &assemble(&features, &coeffs));
        let comp = env.cfg.hess_comp_as_vec(m);
        if env.cfg.alpha.is_none() {
            alpha = match comp.class_vec(m) {
                CompressorClass::Unbiased { omega } => 1.0 / (omega + 1.0),
                CompressorClass::Contractive { .. } => 1.0,
            };
        }
        coeffs_srv.push(coeffs.clone());
        clients.push(Nl1Client {
            features,
            coeffs,
            comp,
            z: x0.clone(),
            alpha,
            scratch: ClientScratch::default(),
        });
    }
    // All clients share α (probed per client exactly as the pre-transport
    // implementation did — the last client's class wins on heterogeneous m).
    for c in clients.iter_mut() {
        c.alpha = alpha;
    }
    let server = Nl1Server {
        x: x0.clone(),
        z: x0,
        coeffs: coeffs_srv,
        h_agg,
        alpha,
        scratch: ServerScratch::default(),
    };
    Ok((server, clients))
}

impl ServerState for Nl1Server {
    fn plan(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        _rng: &mut Rng,
    ) -> Result<Option<RoundPlan>> {
        Ok(match exchange {
            0 => Some(RoundPlan::broadcast(env.n, Packet::empty())),
            1 => {
                // Model broadcast; clients re-anchor z ← x.
                let mut down = Packet::empty();
                down.push_vector("model", self.x.clone(), BitCost::floats(env.d));
                self.z.clone_from(&self.x);
                Some(RoundPlan::broadcast(env.n, down))
            }
            _ => None,
        })
    }

    fn absorb(
        &mut self,
        env: &Env,
        _round: usize,
        exchange: usize,
        replies: &[(usize, Uplink)],
        _rng: &mut Rng,
    ) -> Result<()> {
        if exchange != 0 {
            return Ok(());
        }
        let n = env.n as f64;
        let lambda = env.cfg.lambda;
        let d = env.d;

        // Gradient phase: full gradients every round (NL1 is not lazy).
        self.scratch.g.clear();
        self.scratch.g.resize(d, 0.0);
        for (_, up) in replies {
            crate::linalg::axpy(1.0 / n, up.vector("grad")?, &mut self.scratch.g);
        }
        crate::linalg::axpy(lambda, &self.z, &mut self.scratch.g);

        // Newton-type step with the current estimate: packed Cholesky first
        // (bit-identical to `cholesky_solve`), dense LU as the cold fallback.
        self.scratch.h.copy_from(&self.h_agg);
        self.scratch.h.add_diag(lambda);
        if self.scratch.chol.factor(&self.scratch.h).is_ok() {
            self.scratch.chol.solve_into(&self.scratch.g, &mut self.scratch.step);
        } else {
            let step = lu_solve(&self.scratch.h, &self.scratch.g)?;
            self.scratch.step.clear();
            self.scratch.step.extend_from_slice(&step);
        }
        sub_into(&self.z, &self.scratch.step, &mut self.x);

        // Coefficient learning: apply the compressed differences to the
        // server's copy, with incremental rank-one Gram updates (only
        // touched coefficients change the estimate).
        for (i, up) in replies {
            let s = up.vector("coeff_delta")?;
            // audit:allow(panic-safety): split() rejects environments with missing feature matrices before any round runs.
            let a = env.features[*i].as_ref().expect("validated in split()");
            let m = a.rows() as f64;
            for (j, &sj) in s.iter().enumerate() {
                if sj == 0.0 {
                    continue;
                }
                let old = self.coeffs[*i][j];
                let new = old + self.alpha * sj;
                let dw = (new.max(0.0) - old.max(0.0)) / m;
                self.coeffs[*i][j] = new;
                if dw != 0.0 {
                    // H += (dw/n) a_j a_jᵀ — `row` borrows the (non-self)
                    // feature matrix, so no copy is needed.
                    let row = a.row(j);
                    for p in 0..d {
                        let f = dw / n * row[p];
                        if f == 0.0 {
                            continue;
                        }
                        for (q, &rq) in row.iter().enumerate() {
                            self.h_agg[(p, q)] += f * rq;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn setup_bits_per_node(&self, env: &Env) -> f64 {
        // Data revelation: m·d floats per node (Table 1).
        let total: f64 = (0..env.n)
            .map(|i| (env.locals[i].n_points() * env.d) as f64 * env.cfg.float_bits as f64)
            .sum();
        total / env.n as f64
    }

    fn label(&self) -> String {
        "nl1".into()
    }
}

impl ClientStep for Nl1Client {
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        _round: usize,
        exchange: usize,
        down: &Downlink,
        rng: &mut Rng,
    ) -> Result<Uplink> {
        if exchange == 1 {
            self.z.clear();
            self.z.extend_from_slice(down.vector("model")?);
            return Ok(Packet::empty());
        }
        let d = self.z.len();
        let mut up = Packet::empty();
        // Raw data gradient; the server adds λz after averaging.
        let gi = local.grad(&self.z);
        up.push_vector("grad", gi, BitCost::floats(d));
        // Compressed coefficient difference; keep the local copy in sync.
        hess_coeffs_into(&self.features, &self.z, &mut self.scratch.margins, &mut self.scratch.target);
        sub_into(&self.scratch.target, &self.coeffs, &mut self.scratch.diff);
        let (s, cost) = self.comp.compress_vec(&self.scratch.diff, rng);
        for (c, &sj) in self.coeffs.iter_mut().zip(&s) {
            if sj != 0.0 {
                *c += self.alpha * sj;
            }
        }
        up.push_vector("coeff_delta", s, cost);
        Ok(up)
    }
}

impl crate::config::RunConfig {
    /// NL1 compresses an `m`-vector with the configured Hessian compressor;
    /// Rand-K/Top-K/dithering specs transfer directly.
    pub fn hess_comp_as_vec(&self, m: usize) -> Box<dyn VecCompressor> {
        self.hess_comp.build_vec(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::{run_federated, step_rounds_manual};
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 25,
            dim: 10,
            intrinsic_dim: 4,
            noise: 0.0,
            seed,
        })
    }

    #[test]
    fn nl1_converges_with_rand1() {
        let cfg = RunConfig {
            algorithm: Algorithm::Nl1,
            rounds: 2000,
            lambda: 1e-3,
            hess_comp: CompressorSpec::RandK(1),
            target_gap: 1e-11,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(41), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn nl1_setup_cost_reveals_data() {
        let cfg = RunConfig {
            algorithm: Algorithm::Nl1,
            rounds: 3,
            lambda: 1e-3,
            hess_comp: CompressorSpec::RandK(1),
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(42), &cfg).unwrap();
        // m·d floats = 25·10·64 bits per node.
        assert_eq!(out.history.setup_bits_per_node, 25.0 * 10.0 * 64.0);
    }

    #[test]
    fn nl1_incremental_assembly_matches_full() {
        // After several compressed rounds, the incrementally-maintained
        // aggregate must equal assembling from the learned coefficients —
        // and the server's coefficient copies must equal the clients'.
        let f = fed(43);
        let locals = crate::coordinator::native_locals(&f);
        let cfg = RunConfig {
            algorithm: Algorithm::Nl1,
            hess_comp: CompressorSpec::RandK(3),
            lambda: 1e-3,
            ..RunConfig::default()
        };
        let features: Vec<_> = f.clients.iter().map(|c| Some(c.a.clone())).collect();
        let env = Env {
            locals: &locals,
            cfg: &cfg,
            d: f.dim(),
            n: f.n_clients(),
            smoothness: 1.0,
            features,
            obs: crate::obs::Obs::noop(),
        };
        let (mut server, mut clients) = split(&env).unwrap();
        {
            let mut refs: Vec<&mut dyn ClientStep> =
                clients.iter_mut().map(|c| c as &mut dyn ClientStep).collect();
            step_rounds_manual(&env, &mut server, &mut refs, 10).unwrap();
        }
        let mut full = Mat::zeros(env.d, env.d);
        for i in 0..env.n {
            assert_eq!(server.coeffs[i], clients[i].coeffs, "client {i} desynced");
            full.add_scaled(
                1.0 / env.n as f64,
                &assemble(env.features[i].as_ref().unwrap(), &server.coeffs[i]),
            );
        }
        assert!(
            (&full - &server.h_agg).fro_norm() < 1e-9,
            "incremental drift {}",
            (&full - &server.h_agg).fro_norm()
        );
    }
}
