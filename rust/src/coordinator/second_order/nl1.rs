//! NL1 / NewtonLearn [Islamov et al. 2021] — the §2.2 baseline.
//!
//! Exploits the GLM problem structure only: the server is assumed to know
//! every client's raw data `{a_{ij}}` (an `m·d`-float one-time upload, and a
//! privacy concession Table 1 calls out), after which the Hessian
//! `∇²f_i(x) = (1/m) Σ_j φ″_{ij}(a_{ij}ᵀx) a_{ij}a_{ij}ᵀ` is determined by
//! the `m` scalar coefficients `φ″_{ij}`. Clients *learn* those coefficients
//! on the server via unbiased compression of the differences (Rand-K with
//! `α = 1/(ω+1) = K/m` in the paper's experiments), and the server
//! incrementally maintains `H_i^k` with `K` rank-one updates per client per
//! round.
//!
//! Positive definiteness follows NL1's projection choice: the server clamps
//! the learned coefficients at 0 when assembling (logistic `φ″ ≥ 0`), so the
//! assembled matrix is always PSD and `+λI` makes it PD.

use crate::compressors::{BitCost, CompressorClass, VecCompressor};
use crate::coordinator::{CommTally, Env, Method, StepInfo};
use crate::linalg::{cholesky_solve, lu_solve, Mat, Vector};
use crate::rng::Rng;
use anyhow::{Context, Result};

struct ClientState {
    /// Learned per-datapoint coefficients `l_{ij}^k` (length m).
    coeffs: Vector,
    comp: Box<dyn VecCompressor>,
}

/// NL1 state.
pub struct Nl1 {
    x: Vector,
    z: Vector,
    clients: Vec<ClientState>,
    /// Server-side assembled Hessian estimate `(1/n)Σ H_i` with clamped
    /// coefficients, maintained incrementally.
    h_agg: Mat,
    alpha: f64,
}

impl Nl1 {
    pub fn new(env: &Env) -> Result<Self> {
        let d = env.d;
        let n = env.n as f64;
        let x0 = vec![0.0; d];
        let mut clients = Vec::with_capacity(env.n);
        let mut h_agg = Mat::zeros(d, d);
        let mut alpha = env.cfg.alpha.unwrap_or(0.0);
        for i in 0..env.n {
            env.features[i]
                .as_ref()
                .context("NL1 requires server access to client features (§2.2)")?;
            let m = env.locals[i].n_points();
            anyhow::ensure!(m > 0, "NL1 requires data-based local problems");
            // Initialize with the exact coefficients at x⁰ — equivalently
            // H_i⁰ = ∇²f_i(x⁰), matching the other methods' initialization.
            let coeffs = hess_coeffs(env, i, &x0);
            h_agg.add_scaled(1.0 / n, &assemble(env, i, &coeffs));
            let comp = env.cfg.hess_comp_as_vec(m);
            if env.cfg.alpha.is_none() {
                alpha = match comp.class_vec(m) {
                    CompressorClass::Unbiased { omega } => 1.0 / (omega + 1.0),
                    CompressorClass::Contractive { .. } => 1.0,
                };
            }
            clients.push(ClientState { coeffs, comp });
        }
        Ok(Nl1 { x: x0.clone(), z: x0, clients, h_agg, alpha })
    }
}

/// The Hessian's per-datapoint weights `φ″(a_jᵀx)/1` — for logistic
/// regression `σ(z)σ(−z)`, *without* the 1/m factor (NL1's convention keeps
/// 1/m in the assembly).
fn hess_coeffs(env: &Env, i: usize, x: &[f64]) -> Vector {
    let a = env.features[i].as_ref().expect("validated in new()");
    a.matvec(x)
        .into_iter()
        .map(|z| {
            let s = crate::problem::sigmoid(z);
            s * (1.0 - s)
        })
        .collect()
}

/// Assemble `(1/m) Σ_j max(l_j, 0) a_j a_jᵀ` from coefficients.
fn assemble(env: &Env, i: usize, coeffs: &[f64]) -> Mat {
    let a = env.features[i].as_ref().expect("validated in new()");
    let m = a.rows() as f64;
    let w: Vector = coeffs.iter().map(|&c| c.max(0.0) / m).collect();
    a.gram_scaled(&w)
}

impl Method for Nl1 {
    fn step(&mut self, env: &Env, _round: usize, rng: &mut Rng) -> Result<StepInfo> {
        let mut tally = CommTally::default();
        let n = env.n as f64;
        let lambda = env.cfg.lambda;
        let d = env.d;

        // Gradient phase: full gradients every round (NL1 is not lazy).
        let mut g = vec![0.0; d];
        for i in 0..env.n {
            let gi = env.locals[i].grad(&self.z);
            tally.up(BitCost::floats(d), env.cfg.float_bits);
            crate::linalg::axpy(1.0 / n, &gi, &mut g);
        }
        crate::linalg::axpy(lambda, &self.z, &mut g);

        // Newton-type step with the current estimate.
        let mut h = self.h_agg.clone();
        h.add_diag(lambda);
        let step = cholesky_solve(&h, &g).or_else(|_| lu_solve(&h, &g))?;
        self.x = crate::linalg::sub(&self.z, &step);

        // Coefficient learning: compressed differences of the m-vectors.
        for i in 0..env.n {
            let target = hess_coeffs(env, i, &self.z);
            let diff = crate::linalg::sub(&target, &self.clients[i].coeffs);
            let (s, cost) = self.clients[i].comp.compress_vec(&diff, rng);
            tally.up(cost, env.cfg.float_bits);
            // Incremental server-side assembly: only touched coefficients
            // change the Gram estimate (K rank-one updates).
            let a = env.features[i].as_ref().unwrap();
            let m = a.rows() as f64;
            for (j, &sj) in s.iter().enumerate() {
                if sj == 0.0 {
                    continue;
                }
                let old = self.clients[i].coeffs[j];
                let new = old + self.alpha * sj;
                let dw = (new.max(0.0) - old.max(0.0)) / m;
                self.clients[i].coeffs[j] = new;
                if dw != 0.0 {
                    // H += (dw/n) a_j a_jᵀ
                    let row = a.row(j).to_vec();
                    for p in 0..d {
                        let f = dw / n * row[p];
                        if f == 0.0 {
                            continue;
                        }
                        for q in 0..d {
                            self.h_agg[(p, q)] += f * row[q];
                        }
                    }
                }
            }
        }

        // Model broadcast.
        for _ in 0..env.n {
            tally.down(BitCost::floats(d), env.cfg.float_bits);
        }
        self.z = self.x.clone();

        Ok(tally.into_step())
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn setup_bits_per_node(&self, env: &Env) -> f64 {
        // Data revelation: m·d floats per node (Table 1).
        let total: f64 = (0..env.n)
            .map(|i| (env.locals[i].n_points() * env.d) as f64 * env.cfg.float_bits as f64)
            .sum();
        total / env.n as f64
    }

    fn label(&self) -> String {
        "nl1".into()
    }
}

impl crate::config::RunConfig {
    /// NL1 compresses an `m`-vector with the configured Hessian compressor;
    /// Rand-K/Top-K/dithering specs transfer directly.
    pub fn hess_comp_as_vec(&self, m: usize) -> Box<dyn VecCompressor> {
        self.hess_comp.build_vec(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::coordinator::run_federated;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn fed(seed: u64) -> FederatedDataset {
        FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 25,
            dim: 10,
            intrinsic_dim: 4,
            noise: 0.0,
            seed,
        })
    }

    #[test]
    fn nl1_converges_with_rand1() {
        let cfg = RunConfig {
            algorithm: Algorithm::Nl1,
            rounds: 2000,
            lambda: 1e-3,
            hess_comp: CompressorSpec::RandK(1),
            target_gap: 1e-11,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(41), &cfg).unwrap();
        assert!(out.final_gap() <= 1e-11, "gap={}", out.final_gap());
    }

    #[test]
    fn nl1_setup_cost_reveals_data() {
        let cfg = RunConfig {
            algorithm: Algorithm::Nl1,
            rounds: 3,
            lambda: 1e-3,
            hess_comp: CompressorSpec::RandK(1),
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let out = run_federated(&fed(42), &cfg).unwrap();
        // m·d floats = 25·10·64 bits per node.
        assert_eq!(out.history.setup_bits_per_node, 25.0 * 10.0 * 64.0);
    }

    #[test]
    fn nl1_incremental_assembly_matches_full() {
        // After several compressed rounds, the incrementally-maintained
        // aggregate must equal assembling from the learned coefficients.
        let f = fed(43);
        let locals = crate::coordinator::native_locals(&f);
        let cfg = RunConfig {
            algorithm: Algorithm::Nl1,
            hess_comp: CompressorSpec::RandK(3),
            lambda: 1e-3,
            ..RunConfig::default()
        };
        let features: Vec<_> = f.clients.iter().map(|c| Some(c.a.clone())).collect();
        let env = Env {
            locals: &locals,
            cfg: &cfg,
            d: f.dim(),
            n: f.n_clients(),
            smoothness: 1.0,
            features,
        };
        let mut nl1 = Nl1::new(&env).unwrap();
        let mut rng = Rng::new(44);
        for round in 0..10 {
            nl1.step(&env, round, &mut rng).unwrap();
        }
        let mut full = Mat::zeros(env.d, env.d);
        for i in 0..env.n {
            full.add_scaled(1.0 / env.n as f64, &assemble(&env, i, &nl1.clients[i].coeffs));
        }
        assert!(
            (&full - &nl1.h_agg).fro_norm() < 1e-9,
            "incremental drift {}",
            (&full - &nl1.h_agg).fro_norm()
        );
    }
}
