//! LibSVM text format: `label idx:val idx:val ...` with 1-based indices.
//!
//! The parser is tolerant of the quirks found in real LibSVM files
//! (comments, blank lines, repeated whitespace, integer labels, scientific
//! notation) and the writer produces files the parser round-trips exactly —
//! the synthetic generator uses the writer + parser pair so the real-data
//! code path is always exercised.

use anyhow::{bail, Context, Result};

/// One parsed line: a label and sparse features (1-based indices).
#[derive(Clone, Debug, PartialEq)]
pub struct LibsvmRecord {
    pub label: f64,
    /// `(index ≥ 1, value)` pairs, in file order.
    pub features: Vec<(usize, f64)>,
}

impl LibsvmRecord {
    /// Largest feature index (0 for empty feature lists).
    pub fn max_index(&self) -> usize {
        self.features.iter().map(|&(i, _)| i).max().unwrap_or(0)
    }
}

/// Parse LibSVM text. `dim`, if given, validates that no index exceeds it.
pub fn parse_libsvm(text: &str, dim: Option<usize>) -> Result<Vec<LibsvmRecord>> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f64 = parts
            .next()
            .with_context(|| format!("line {}: missing label", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let mut features = Vec::new();
        let mut last_idx = 0usize;
        for tok in parts {
            let (idx_s, val_s) = tok
                .split_once(':')
                .with_context(|| format!("line {}: expected idx:val, got '{tok}'", lineno + 1))?;
            let idx: usize = idx_s
                .parse()
                .with_context(|| format!("line {}: bad feature index '{idx_s}'", lineno + 1))?;
            let val: f64 = val_s
                .parse()
                .with_context(|| format!("line {}: bad feature value '{val_s}'", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: LibSVM indices are 1-based, got 0", lineno + 1);
            }
            if idx <= last_idx {
                bail!(
                    "line {}: feature indices must be strictly increasing ({idx} after {last_idx})",
                    lineno + 1
                );
            }
            if let Some(d) = dim {
                if idx > d {
                    bail!("line {}: feature index {idx} exceeds declared dimension {d}", lineno + 1);
                }
            }
            last_idx = idx;
            features.push((idx, val));
        }
        out.push(LibsvmRecord { label, features });
    }
    Ok(out)
}

/// Serialize records back to LibSVM text (zero entries omitted).
pub fn write_libsvm(records: &[LibsvmRecord]) -> String {
    let mut s = String::new();
    for r in records {
        // Integer-valued labels print without a decimal point, like the
        // canonical files.
        if r.label.fract() == 0.0 {
            s.push_str(&format!("{}", r.label as i64));
        } else {
            s.push_str(&format!("{}", r.label));
        }
        for &(i, v) in &r.features {
            if v != 0.0 {
                s.push_str(&format!(" {}:{}", i, fmt_float(v)));
            }
        }
        s.push('\n');
    }
    s
}

/// Shortest round-trip float formatting.
fn fmt_float(v: f64) -> String {
    let s = format!("{v}");
    // audit:allow(panic-safety): debug-build self-check only; `{v}` always reparses.
    debug_assert_eq!(s.parse::<f64>().unwrap(), v);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let text = "+1 1:0.5 3:2\n-1 2:1e-3\n";
        let recs = parse_libsvm(text, None).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].label, 1.0);
        assert_eq!(recs[0].features, vec![(1, 0.5), (3, 2.0)]);
        assert_eq!(recs[1].features, vec![(2, 1e-3)]);
        assert_eq!(recs[0].max_index(), 3);
    }

    #[test]
    fn parse_skips_comments_and_blanks() {
        let text = "# header\n\n1 1:1 # trailing\n   \n-1 2:2\n";
        let recs = parse_libsvm(text, None).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].features, vec![(1, 1.0)]);
    }

    #[test]
    fn parse_rejects_zero_index() {
        assert!(parse_libsvm("1 0:5\n", None).is_err());
    }

    #[test]
    fn parse_rejects_decreasing_indices() {
        assert!(parse_libsvm("1 3:1 2:1\n", None).is_err());
        assert!(parse_libsvm("1 2:1 2:1\n", None).is_err());
    }

    #[test]
    fn parse_rejects_bad_tokens() {
        assert!(parse_libsvm("abc 1:1\n", None).is_err());
        assert!(parse_libsvm("1 11\n", None).is_err());
        assert!(parse_libsvm("1 x:1\n", None).is_err());
        assert!(parse_libsvm("1 1:y\n", None).is_err());
    }

    #[test]
    fn parse_enforces_dim() {
        assert!(parse_libsvm("1 5:1\n", Some(4)).is_err());
        assert!(parse_libsvm("1 4:1\n", Some(4)).is_ok());
    }

    #[test]
    fn empty_feature_line_ok() {
        let recs = parse_libsvm("1\n-1 1:2\n", None).unwrap();
        assert_eq!(recs[0].features.len(), 0);
        assert_eq!(recs[0].max_index(), 0);
    }

    #[test]
    fn write_parse_roundtrip() {
        let recs = vec![
            LibsvmRecord { label: 1.0, features: vec![(1, 0.123456789), (7, -2.5e-8)] },
            LibsvmRecord { label: -1.0, features: vec![(3, 1.0)] },
            LibsvmRecord { label: 1.0, features: vec![] },
        ];
        let text = write_libsvm(&recs);
        let back = parse_libsvm(&text, None).unwrap();
        assert_eq!(recs, back);
    }

    #[test]
    fn write_omits_zeros() {
        let recs = vec![LibsvmRecord { label: 1.0, features: vec![(1, 0.0), (2, 3.0)] }];
        let text = write_libsvm(&recs);
        assert!(!text.contains("1:"), "{text}");
        assert!(text.contains("2:3"));
    }
}
