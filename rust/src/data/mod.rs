//! Federated datasets: LibSVM parsing, synthetic generation with controlled
//! intrinsic dimensionality, partitioning, and the Table-2 dataset registry.
//!
//! The paper's experiments run on LibSVM datasets (a1a, a9a, phishing,
//! covtype, madelon, w2a, w8a) partitioned across `n` workers (Table 2).
//! This environment is offline, so the registry synthesizes datasets with
//! the same *shape signature* (workers, points, features, intrinsic
//! dimension) via [`FederatedDataset::synthetic`]; the generator **emits a
//! LibSVM text file and re-parses it** on request so the real-data code path
//! is exercised end-to-end, and real LibSVM files drop in unchanged through
//! [`FederatedDataset::from_libsvm_file`].

mod libsvm;
mod registry;
mod synthetic;

pub use libsvm::{parse_libsvm, write_libsvm, LibsvmRecord};
pub use registry::{find, registry, DatasetEntry};
pub use synthetic::SyntheticSpec;

use crate::linalg::Mat;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A deterministic *construction recipe* for a [`FederatedDataset`]: the
/// small value a listening round loop ships to standalone worker processes
/// (inside the `Assign` handshake frame, docs/WIRE.md) so each worker can
/// rebuild its data shards locally instead of receiving megabytes of
/// features over the wire — dataset builds are pure functions of the
/// recipe, so both sides end up with bit-identical shards.
///
/// Datasets loaded from ad-hoc files or records carry no recipe
/// ([`FederatedDataset::recipe`] is `None`) and cannot serve multi-process
/// runs.
#[derive(Clone, Debug, PartialEq)]
pub enum DataRecipe {
    /// A Table-2 registry dataset: `registry::find(name).build(seed, full_scale)`.
    Registry { name: String, seed: u64, full_scale: bool },
    /// A synthetic dataset: `FederatedDataset::synthetic(&spec)`.
    Synthetic(SyntheticSpec),
}

impl DataRecipe {
    /// Canonical wire rendering ([`DataRecipe::parse`] inverts it). The
    /// synthetic noise travels as its hex f64 bit pattern so the rebuilt
    /// dataset is bit-identical.
    pub fn render(&self) -> String {
        match self {
            DataRecipe::Registry { name, seed, full_scale } => {
                format!(
                    "registry name={name} seed={seed} scale={}",
                    if *full_scale { "paper" } else { "scaled" }
                )
            }
            DataRecipe::Synthetic(s) => format!(
                "synth n={} m={} d={} r={} noise={} seed={}",
                s.n_clients,
                s.m_per_client,
                s.dim,
                s.intrinsic_dim,
                crate::config::f64_to_wire(s.noise),
                s.seed
            ),
        }
    }

    /// Parse a [`DataRecipe::render`] string. Strict: unknown tags, unknown
    /// or duplicate keys, and missing keys are all errors.
    pub fn parse(text: &str) -> Result<DataRecipe> {
        let mut words = text.split_whitespace();
        let tag = words.next().context("empty data recipe")?;
        let mut kv = std::collections::BTreeMap::new();
        for w in words {
            let (k, v) = w
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("malformed recipe field {w:?}"))?;
            if kv.insert(k, v).is_some() {
                bail!("duplicate recipe key {k:?}");
            }
        }
        let take = |kv: &mut std::collections::BTreeMap<&str, &str>, k: &str| -> Result<String> {
            kv.remove(k).map(str::to_string).with_context(|| format!("recipe key {k:?} missing"))
        };
        let recipe = match tag {
            "registry" => DataRecipe::Registry {
                name: take(&mut kv, "name")?,
                seed: take(&mut kv, "seed")?.parse().context("recipe seed")?,
                full_scale: match take(&mut kv, "scale")?.as_str() {
                    "paper" => true,
                    "scaled" => false,
                    other => bail!("unknown recipe scale {other:?}"),
                },
            },
            "synth" => DataRecipe::Synthetic(SyntheticSpec {
                n_clients: take(&mut kv, "n")?.parse().context("recipe n")?,
                m_per_client: take(&mut kv, "m")?.parse().context("recipe m")?,
                dim: take(&mut kv, "d")?.parse().context("recipe d")?,
                intrinsic_dim: take(&mut kv, "r")?.parse().context("recipe r")?,
                noise: crate::config::f64_from_wire(&take(&mut kv, "noise")?)?,
                seed: take(&mut kv, "seed")?.parse().context("recipe seed")?,
            }),
            other => bail!("unknown data recipe tag {other:?}"),
        };
        if let Some((k, _)) = kv.into_iter().next() {
            bail!("unknown recipe key {k:?}");
        }
        Ok(recipe)
    }

    /// Rebuild the dataset this recipe describes (a pure function — every
    /// call yields bit-identical shards).
    pub fn build(&self) -> Result<FederatedDataset> {
        match self {
            DataRecipe::Registry { name, seed, full_scale } => {
                let entry = registry::find(name)
                    .with_context(|| format!("recipe names unknown dataset {name:?}"))?;
                Ok(entry.build(*seed, *full_scale))
            }
            DataRecipe::Synthetic(spec) => Ok(FederatedDataset::synthetic(spec)),
        }
    }
}

/// One client's local shard: `m` data points as rows of `a`, labels in
/// `b ∈ {−1, +1}^m`.
#[derive(Clone, Debug)]
pub struct ClientData {
    /// `m×d` feature matrix (rows are data points `a_{ij}ᵀ`).
    pub a: Mat,
    /// Labels `b_{ij} ∈ {−1, +1}`.
    pub b: Vec<f64>,
}

impl ClientData {
    /// Number of local data points `m`.
    pub fn m(&self) -> usize {
        self.a.rows()
    }

    /// Feature dimension `d`.
    pub fn dim(&self) -> usize {
        self.a.cols()
    }

    /// Numerical rank of the local data matrix — the client's intrinsic
    /// dimension `r` (Table 2, "average dimension r").
    pub fn intrinsic_dim(&self, rel_tol: f64) -> usize {
        crate::linalg::svd(&self.a).rank(rel_tol)
    }
}

/// A dataset partitioned across `n` clients.
#[derive(Clone, Debug)]
pub struct FederatedDataset {
    pub clients: Vec<ClientData>,
    /// Short name used in CSV/plots ("a1a-synth", "madelon-synth", ...).
    pub name: String,
    /// How to rebuild this dataset from scratch, when known — required for
    /// multi-process runs (see [`DataRecipe`]). `None` for datasets built
    /// from ad-hoc files/records.
    pub recipe: Option<DataRecipe>,
}

impl FederatedDataset {
    /// Number of clients `n`.
    pub fn n_clients(&self) -> usize {
        self.clients.len()
    }

    /// Feature dimension `d` (uniform across clients).
    pub fn dim(&self) -> usize {
        self.clients.first().map(|c| c.dim()).unwrap_or(0)
    }

    /// Total number of data points.
    pub fn total_points(&self) -> usize {
        self.clients.iter().map(|c| c.m()).sum()
    }

    /// Average intrinsic dimension across clients (Table 2's `r`).
    pub fn avg_intrinsic_dim(&self, rel_tol: f64) -> f64 {
        if self.clients.is_empty() {
            return 0.0;
        }
        let sum: usize = self.clients.iter().map(|c| c.intrinsic_dim(rel_tol)).sum();
        sum as f64 / self.clients.len() as f64
    }

    /// Generate a synthetic federated dataset (see [`SyntheticSpec`]).
    pub fn synthetic(spec: &SyntheticSpec) -> Self {
        let mut fed = synthetic::generate(spec);
        fed.recipe = Some(DataRecipe::Synthetic(*spec));
        fed
    }

    /// Load a LibSVM-format file and partition it evenly across `n` clients
    /// (points are dealt round-robin in file order, matching the paper's
    /// even splits).
    pub fn from_libsvm_file(path: &Path, n_clients: usize, dim: Option<usize>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let records = parse_libsvm(&text, dim)?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "libsvm".into());
        Ok(Self::from_records(records, n_clients, &name))
    }

    /// Partition parsed records across clients.
    pub fn from_records(records: Vec<LibsvmRecord>, n_clients: usize, name: &str) -> Self {
        assert!(n_clients > 0, "need at least one client");
        assert!(
            records.len() >= n_clients,
            "cannot split {} points across {} clients",
            records.len(),
            n_clients
        );
        let d = records.iter().map(|r| r.max_index()).max().unwrap_or(0);
        // Even split: first `len % n` clients get one extra point.
        let base = records.len() / n_clients;
        let extra = records.len() % n_clients;
        let mut clients = Vec::with_capacity(n_clients);
        let mut it = records.into_iter();
        for c in 0..n_clients {
            let m = base + usize::from(c < extra);
            let mut a = Mat::zeros(m, d);
            let mut b = Vec::with_capacity(m);
            for i in 0..m {
                // audit:allow(panic-safety): Σ(base + extra) = records.len() by construction, so the iterator cannot run dry.
                let rec = it.next().expect("record count mismatch");
                for &(idx, val) in &rec.features {
                    a[(i, idx - 1)] = val;
                }
                b.push(if rec.label > 0.0 { 1.0 } else { -1.0 });
            }
            clients.push(ClientData { a, b });
        }
        FederatedDataset { clients, name: name.to_string(), recipe: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_records() -> Vec<LibsvmRecord> {
        vec![
            LibsvmRecord { label: 1.0, features: vec![(1, 0.5), (3, -1.0)] },
            LibsvmRecord { label: -1.0, features: vec![(2, 2.0)] },
            LibsvmRecord { label: 1.0, features: vec![(1, 1.0), (2, 1.0), (3, 1.0)] },
            LibsvmRecord { label: 0.0, features: vec![(3, 4.0)] },
            LibsvmRecord { label: 2.0, features: vec![(1, -0.5)] },
        ]
    }

    #[test]
    fn from_records_shapes_and_labels() {
        let fed = FederatedDataset::from_records(tiny_records(), 2, "tiny");
        assert_eq!(fed.n_clients(), 2);
        assert_eq!(fed.dim(), 3);
        assert_eq!(fed.total_points(), 5);
        // 5 points over 2 clients: 3 + 2.
        assert_eq!(fed.clients[0].m(), 3);
        assert_eq!(fed.clients[1].m(), 2);
        // Labels mapped to ±1 (0 → −1, 2 → +1).
        assert_eq!(fed.clients[1].b, vec![-1.0, 1.0]);
        // Feature placement (1-based → 0-based).
        assert_eq!(fed.clients[0].a[(0, 0)], 0.5);
        assert_eq!(fed.clients[0].a[(0, 2)], -1.0);
        assert_eq!(fed.clients[0].a[(1, 1)], 2.0);
    }

    #[test]
    #[should_panic]
    fn too_many_clients_panics() {
        FederatedDataset::from_records(tiny_records(), 6, "tiny");
    }

    #[test]
    fn recipes_round_trip_and_rebuild_identically() {
        // Synthetic: recipe is attached, renders/parses losslessly, and a
        // rebuild from the parsed recipe is bit-identical.
        let spec = SyntheticSpec {
            n_clients: 2,
            m_per_client: 8,
            dim: 6,
            intrinsic_dim: 3,
            noise: 0.1 + 0.2, // not exactly representable in decimal
            seed: 7,
        };
        let fed = FederatedDataset::synthetic(&spec);
        let recipe = fed.recipe.clone().expect("synthetic datasets carry a recipe");
        let parsed = DataRecipe::parse(&recipe.render()).unwrap();
        assert_eq!(parsed, recipe);
        let rebuilt = parsed.build().unwrap();
        assert_eq!(rebuilt.name, fed.name);
        for (a, b) in fed.clients.iter().zip(&rebuilt.clients) {
            assert_eq!(a.b, b.b);
            for (x, y) in a.a.data().iter().zip(b.a.data()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }

        // Registry: the recipe survives the rename and rebuilds by name.
        let fed = registry::find("a1a").unwrap().build(3, false);
        let recipe = fed.recipe.clone().unwrap();
        assert_eq!(
            recipe,
            DataRecipe::Registry { name: "a1a".into(), seed: 3, full_scale: false }
        );
        let rebuilt = DataRecipe::parse(&recipe.render()).unwrap().build().unwrap();
        assert_eq!(rebuilt.name, "a1a-s");
        assert_eq!(rebuilt.n_clients(), fed.n_clients());

        // Ad-hoc records carry no recipe.
        assert!(FederatedDataset::from_records(tiny_records(), 2, "tiny").recipe.is_none());

        // Strictness: unknown tag / unknown key / duplicate key / missing key.
        assert!(DataRecipe::parse("mystery a=1").is_err());
        assert!(DataRecipe::parse("registry name=a1a seed=1 scale=paper extra=1").is_err());
        assert!(DataRecipe::parse("registry name=a1a seed=1 seed=2 scale=paper").is_err());
        assert!(DataRecipe::parse("registry name=a1a scale=paper").is_err());
        assert!(DataRecipe::parse("registry name=a1a seed=1 scale=huge").is_err());
        assert!(DataRecipe::parse("").is_err());

        // An unknown registry name parses but cannot build.
        let bad = DataRecipe::Registry { name: "nope".into(), seed: 1, full_scale: true };
        assert!(bad.build().is_err());
    }

    #[test]
    fn intrinsic_dim_of_planted_data() {
        let spec = SyntheticSpec {
            n_clients: 3,
            m_per_client: 25,
            dim: 12,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 5,
        };
        let fed = FederatedDataset::synthetic(&spec);
        for c in &fed.clients {
            assert_eq!(c.intrinsic_dim(1e-8), 4);
        }
        assert!((fed.avg_intrinsic_dim(1e-8) - 4.0).abs() < 1e-12);
    }
}
