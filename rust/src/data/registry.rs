//! The Table-2 dataset registry.
//!
//! Mirrors the paper's Table 2 (datasets, workers, points, features,
//! average intrinsic dimension). Each entry carries two shape signatures:
//! the paper's original one (`paper_*`) and a *scaled* one used by default so
//! every figure regenerates in minutes on a laptop. The scaling preserves
//! the ratios that drive the figures' comparative behaviour (`r/d`, `m` vs
//! `d²`, clients); pass `--full-scale` to the CLI to run the paper-sized
//! shapes.

use super::{DataRecipe, FederatedDataset, SyntheticSpec};

/// One dataset row of Table 2 plus its synthetic stand-in parameters.
#[derive(Clone, Copy, Debug)]
pub struct DatasetEntry {
    pub name: &'static str,
    /// Paper values (Table 2).
    pub paper_workers: usize,
    pub paper_points: usize,
    pub paper_features: usize,
    pub paper_r: usize,
    /// Scaled stand-in (defaults).
    pub workers: usize,
    pub m_per_client: usize,
    pub features: usize,
    pub r: usize,
}

impl DatasetEntry {
    /// Synthetic spec for the scaled stand-in.
    pub fn spec(&self, seed: u64) -> SyntheticSpec {
        SyntheticSpec {
            n_clients: self.workers,
            m_per_client: self.m_per_client,
            dim: self.features,
            intrinsic_dim: self.r,
            noise: 0.0,
            seed,
        }
    }

    /// Synthetic spec at the paper's original scale.
    pub fn paper_spec(&self, seed: u64) -> SyntheticSpec {
        SyntheticSpec {
            n_clients: self.paper_workers,
            m_per_client: (self.paper_points / self.paper_workers).max(1),
            dim: self.paper_features,
            intrinsic_dim: self.paper_r.min(self.paper_features),
            noise: 0.0,
            seed,
        }
    }

    /// Build the (scaled) dataset, named after the Table-2 row.
    pub fn build(&self, seed: u64, full_scale: bool) -> FederatedDataset {
        let spec = if full_scale { self.paper_spec(seed) } else { self.spec(seed) };
        let mut fed = FederatedDataset::synthetic(&spec);
        fed.name = format!("{}{}", self.name, if full_scale { "" } else { "-s" });
        // The registry build is itself a pure function of (name, seed, scale),
        // so remote workers rebuild via the registry rather than a raw spec —
        // this keeps the renamed dataset (and any future non-synthetic
        // registry sources) reproducible from the recipe alone.
        fed.recipe =
            Some(DataRecipe::Registry { name: self.name.to_string(), seed, full_scale });
        fed
    }

    /// Stable identity of `build(seed, full_scale)`'s recipe minus the seed
    /// — registry names are unique, and scale selects between the two shape
    /// signatures. Keys the sweep workers' per-thread dataset memo.
    pub fn cache_key(&self, full_scale: bool) -> String {
        format!("registry:{}:{}", self.name, if full_scale { "paper" } else { "scaled" })
    }
}

/// All Table-2 rows.
///
/// Scaled signatures keep `r/d` and `m` relative to `d` close to the paper's
/// (e.g. a1a: d=123, r=64 → d=40, r=13; madelon keeps its near-half ratio).
pub fn registry() -> Vec<DatasetEntry> {
    vec![
        DatasetEntry {
            name: "a1a",
            paper_workers: 16, paper_points: 1600, paper_features: 123, paper_r: 64,
            workers: 8, m_per_client: 50, features: 40, r: 13,
        },
        DatasetEntry {
            name: "a9a",
            paper_workers: 80, paper_points: 32560, paper_features: 123, paper_r: 82,
            workers: 12, m_per_client: 60, features: 40, r: 27,
        },
        DatasetEntry {
            name: "phishing",
            paper_workers: 100, paper_points: 110 * 100, paper_features: 68, paper_r: 35,
            workers: 10, m_per_client: 40, features: 34, r: 17,
        },
        DatasetEntry {
            name: "covtype",
            paper_workers: 200, paper_points: 581000, paper_features: 54, paper_r: 24,
            workers: 12, m_per_client: 80, features: 27, r: 12,
        },
        DatasetEntry {
            name: "madelon",
            paper_workers: 10, paper_points: 2000, paper_features: 500, paper_r: 200,
            workers: 5, m_per_client: 50, features: 60, r: 24,
        },
        DatasetEntry {
            name: "w2a",
            paper_workers: 50, paper_points: 3450, paper_features: 300, paper_r: 59,
            workers: 10, m_per_client: 35, features: 50, r: 10,
        },
        DatasetEntry {
            name: "w8a",
            paper_workers: 142, paper_points: 49700, paper_features: 300, paper_r: 133,
            workers: 12, m_per_client: 70, features: 50, r: 22,
        },
    ]
}

/// Look up a registry entry by name.
pub fn find(name: &str) -> Option<DatasetEntry> {
    registry().into_iter().find(|e| e.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_table_2() {
        let reg = registry();
        assert_eq!(reg.len(), 7);
        let a9a = find("a9a").unwrap();
        assert_eq!(a9a.paper_workers, 80);
        assert_eq!(a9a.paper_features, 123);
        assert_eq!(a9a.paper_r, 82);
        let madelon = find("MADELON").unwrap();
        assert_eq!(madelon.paper_features, 500);
        assert!(find("nope").is_none());
    }

    #[test]
    fn scaled_specs_preserve_low_dimensionality() {
        for e in registry() {
            assert!(e.r < e.features, "{}: r must stay below d", e.name);
            let paper_ratio = e.paper_r as f64 / e.paper_features as f64;
            let scaled_ratio = e.r as f64 / e.features as f64;
            assert!(
                (paper_ratio - scaled_ratio).abs() < 0.26,
                "{}: r/d drifted {paper_ratio:.2} → {scaled_ratio:.2}",
                e.name
            );
        }
    }

    #[test]
    fn build_scaled_dataset() {
        let e = find("a1a").unwrap();
        let fed = e.build(1, false);
        assert_eq!(fed.n_clients(), 8);
        assert_eq!(fed.dim(), 40);
        assert_eq!(fed.name, "a1a-s");
        // Planted intrinsic dimension is realized.
        assert_eq!(fed.clients[0].intrinsic_dim(1e-8), 13);
    }
}
