//! Synthetic GLM data with planted intrinsic dimensionality.
//!
//! Substitution for the paper's LibSVM datasets (offline environment, see
//! DESIGN.md §6): each client's data points are sampled inside a planted
//! `r`-dimensional subspace `G_i = span(V_i)` (per-client subspaces, as in
//! §2.3), labels come from a shared ground-truth logistic model, and an
//! optional isotropic noise term lets experiments probe approximate
//! low-dimensionality. Data points are normalized to unit norm — the same
//! preprocessing the paper applies to LibSVM data — which keeps the logistic
//! Hessian's scale dataset-independent.
//!
//! The generator goes **through the LibSVM writer + parser** so every
//! experiment exercises the real-data ingestion path.

use super::{parse_libsvm, write_libsvm, FederatedDataset, LibsvmRecord};
use crate::basis::subspace::orthonormal_cols;
use crate::rng::Rng;

/// Parameters of the synthetic federated dataset.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SyntheticSpec {
    /// Number of clients `n`.
    pub n_clients: usize,
    /// Points per client `m`.
    pub m_per_client: usize,
    /// Feature dimension `d`.
    pub dim: usize,
    /// Intrinsic dimension `r ≤ d` of each client's data subspace.
    pub intrinsic_dim: usize,
    /// Out-of-subspace noise magnitude (0 ⇒ exactly rank-`r` shards).
    pub noise: f64,
    /// RNG seed; the dataset is a pure function of the spec.
    pub seed: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec {
            n_clients: 10,
            m_per_client: 100,
            dim: 50,
            intrinsic_dim: 10,
            noise: 0.0,
            seed: 42,
        }
    }
}

impl SyntheticSpec {
    /// Stable identity of the generated data's *shape* — every field except
    /// `seed`, which sweeps override per cell. Two specs with equal shape
    /// keys and equal seeds generate identical datasets, which is what the
    /// sweep workers' per-thread dataset memo keys on.
    pub fn shape_key(&self) -> String {
        format!(
            "synth:n{}:m{}:d{}:r{}:noise{:?}",
            self.n_clients, self.m_per_client, self.dim, self.intrinsic_dim, self.noise
        )
    }

    /// The name the generated dataset carries — the single source both
    /// [`generate`] and the sweep engine's dataset references use, so sweep
    /// group strings (hence resume keys) always match built dataset names.
    /// Noise shows up because it changes the data; every field that does
    /// must split the name.
    pub fn name(&self) -> String {
        let mut name = format!(
            "synth-n{}-m{}-d{}-r{}",
            self.n_clients, self.m_per_client, self.dim, self.intrinsic_dim
        );
        if self.noise > 0.0 {
            name.push_str(&format!("-noise{:?}", self.noise));
        }
        name
    }
}

/// Generate the dataset described by `spec`.
pub fn generate(spec: &SyntheticSpec) -> FederatedDataset {
    assert!(spec.intrinsic_dim >= 1 && spec.intrinsic_dim <= spec.dim,
        "intrinsic_dim must be in [1, dim]");
    assert!(spec.m_per_client >= 1 && spec.n_clients >= 1 && spec.dim >= 1);
    let root = Rng::new(spec.seed);

    // Shared ground-truth model for labels.
    let mut wrng = root.derive(u64::MAX);
    let w_star: Vec<f64> = (0..spec.dim).map(|_| wrng.normal()).collect();

    let mut records: Vec<LibsvmRecord> = Vec::with_capacity(spec.n_clients * spec.m_per_client);
    for client in 0..spec.n_clients {
        let mut rng = root.derive(client as u64);
        // Per-client subspace basis.
        let v = orthonormal_cols(spec.dim, spec.intrinsic_dim, &mut rng);
        for _ in 0..spec.m_per_client {
            // a = V α (+ noise), normalized.
            let alpha: Vec<f64> = (0..spec.intrinsic_dim).map(|_| rng.normal()).collect();
            let mut a = v.matvec(&alpha);
            if spec.noise > 0.0 {
                for ai in a.iter_mut() {
                    *ai += spec.noise * rng.normal();
                }
            }
            let nrm = crate::linalg::norm2(&a).max(1e-12);
            for ai in a.iter_mut() {
                *ai /= nrm;
            }
            // Logistic label with margin-dependent flip probability. The
            // scale controls label noise: 2.0 gives ~15% flips on typical
            // margins, keeping the problem non-separable like the LibSVM
            // datasets (near-deterministic labels would push ‖x*‖ ≫ 1 and
            // make every local-theory method start far outside its basin).
            let logit = 2.0 * crate::linalg::dot(&a, &w_star);
            let p_pos = 1.0 / (1.0 + (-logit).exp());
            let label = if rng.uniform() < p_pos { 1.0 } else { -1.0 };
            let features: Vec<(usize, f64)> = a
                .iter()
                .enumerate()
                .filter(|(_, &x)| x != 0.0)
                .map(|(i, &x)| (i + 1, x))
                .collect();
            records.push(LibsvmRecord { label, features });
        }
    }

    // Round-trip through the LibSVM text format (see module docs).
    let text = write_libsvm(&records);
    // audit:allow(panic-safety): parsing back text this function just wrote; a failure is a bug in write_libsvm, not a runtime condition.
    let parsed = parse_libsvm(&text, Some(spec.dim)).expect("internal LibSVM roundtrip failed");
    let mut fed = FederatedDataset::from_records(parsed, spec.n_clients, &spec.name());
    // Sparse parse infers d from the max seen index; pad if the last features
    // happened to be zero everywhere.
    if fed.dim() < spec.dim {
        for c in fed.clients.iter_mut() {
            let mut a = crate::linalg::Mat::zeros(c.a.rows(), spec.dim);
            for i in 0..c.a.rows() {
                for j in 0..c.a.cols() {
                    a[(i, j)] = c.a[(i, j)];
                }
            }
            c.a = a;
        }
    }
    fed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let spec = SyntheticSpec { seed: 9, ..Default::default() };
        let f1 = FederatedDataset::synthetic(&spec);
        let f2 = FederatedDataset::synthetic(&spec);
        assert_eq!(f1.clients[0].b, f2.clients[0].b);
        assert_eq!(f1.clients[3].a.data(), f2.clients[3].a.data());
    }

    #[test]
    fn different_seed_differs() {
        let f1 = FederatedDataset::synthetic(&SyntheticSpec { seed: 1, ..Default::default() });
        let f2 = FederatedDataset::synthetic(&SyntheticSpec { seed: 2, ..Default::default() });
        assert_ne!(f1.clients[0].a.data(), f2.clients[0].a.data());
    }

    #[test]
    fn shapes_match_spec() {
        let spec = SyntheticSpec {
            n_clients: 7,
            m_per_client: 13,
            dim: 21,
            intrinsic_dim: 5,
            noise: 0.0,
            seed: 3,
        };
        let fed = FederatedDataset::synthetic(&spec);
        assert_eq!(fed.n_clients(), 7);
        assert_eq!(fed.dim(), 21);
        assert_eq!(fed.total_points(), 91);
        for c in &fed.clients {
            assert_eq!(c.m(), 13);
            assert_eq!(c.dim(), 21);
        }
    }

    #[test]
    fn rows_unit_norm() {
        let fed = FederatedDataset::synthetic(&SyntheticSpec { seed: 4, ..Default::default() });
        for c in &fed.clients {
            for i in 0..c.m() {
                let nrm = crate::linalg::norm2(c.a.row(i));
                assert!((nrm - 1.0).abs() < 1e-9, "row norm {nrm}");
            }
        }
    }

    #[test]
    fn labels_are_pm_one_and_mixed() {
        let fed = FederatedDataset::synthetic(&SyntheticSpec { seed: 6, ..Default::default() });
        let mut pos = 0;
        let mut neg = 0;
        for c in &fed.clients {
            for &b in &c.b {
                assert!(b == 1.0 || b == -1.0);
                if b > 0.0 { pos += 1 } else { neg += 1 }
            }
        }
        assert!(pos > 0 && neg > 0, "degenerate labels: {pos}+/{neg}-");
    }

    #[test]
    fn noise_raises_intrinsic_dim() {
        let clean = FederatedDataset::synthetic(&SyntheticSpec {
            intrinsic_dim: 3, dim: 15, m_per_client: 30, n_clients: 2, noise: 0.0, seed: 8,
        });
        let noisy = FederatedDataset::synthetic(&SyntheticSpec {
            intrinsic_dim: 3, dim: 15, m_per_client: 30, n_clients: 2, noise: 0.1, seed: 8,
        });
        assert_eq!(clean.clients[0].intrinsic_dim(1e-8), 3);
        assert!(noisy.clients[0].intrinsic_dim(1e-8) > 3);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_intrinsic_dim() {
        generate(&SyntheticSpec { intrinsic_dim: 60, dim: 50, ..Default::default() });
    }
}
