//! Figures 1–6: every curve family in the paper's evaluation, as
//! parameterized sweeps over ([`crate::config::RunConfig`], dataset) pairs.
//!
//! Each figure prints the paper's comparison in tabular form — bits per node
//! to reach optimality gaps of 1e-4 / 1e-7 / 1e-10 for every method — and
//! writes the full gap-vs-bits series to `runs/<figure>__<label>.csv` for
//! plotting. Unidirectional experiments (Figs. 1–4) report *uplink* bits;
//! bidirectional ones (Figs. 5–6) report uplink+downlink, matching the
//! paper's accounting.

use super::runs_dir;
use crate::compressors::CompressorSpec;
use crate::config::{Algorithm, BasisKind, RunConfig};
use crate::data::{registry, DatasetEntry, FederatedDataset};
use crate::sweep::{run_cells, CellStatus, DatasetRef, SweepCell};
use anyhow::{bail, Context, Result};

/// One labelled run in a figure.
pub struct Series {
    pub label: String,
    pub cfg: RunConfig,
}

/// A figure = datasets × series + an x-axis convention.
pub struct FigureSpec {
    pub id: &'static str,
    pub datasets: Vec<DatasetEntry>,
    pub count_downlink: bool,
    pub series: Vec<Series>,
}

/// Gap thresholds reported in the summary tables.
const TARGETS: [f64; 3] = [1e-4, 1e-7, 1e-10];

fn ds(names: &[&str]) -> Result<Vec<DatasetEntry>> {
    let reg = registry();
    names
        .iter()
        .map(|n| {
            reg.iter()
                .find(|e| e.name == *n)
                .copied()
                .with_context(|| format!("dataset {n} not in registry"))
        })
        .collect()
}

fn base(algorithm: Algorithm, seed: u64) -> RunConfig {
    RunConfig {
        algorithm,
        rounds: 4000,
        lambda: 1e-3,
        target_gap: 5e-12,
        max_bits_per_node: Some(3e8),
        seed,
        ..RunConfig::default()
    }
}

/// Build the spec for a figure id. `r` and `d` parameterize compressor
/// sizes per the paper's parameter sections (§6, App. A).
fn spec(id: &str, fed: &FederatedDataset, seed: u64) -> Result<Vec<Series>> {
    let d = fed.dim();
    let r = fed.avg_intrinsic_dim(1e-9).round() as usize;
    let n = fed.n_clients();
    let s = |label: &str, cfg: RunConfig| Series { label: label.into(), cfg };
    Ok(match id {
        // ── Figure 1 row 1: BL1 vs second-order methods (§6.2) ──
        "fig1-second-order" => vec![
            s("bl1", RunConfig {
                hess_comp: CompressorSpec::TopK(r),
                ..base(Algorithm::Bl1, seed)
            }),
            s("fednl", RunConfig {
                hess_comp: CompressorSpec::RankR(1),
                ..base(Algorithm::FedNl, seed)
            }),
            s("nl1", RunConfig {
                hess_comp: CompressorSpec::RandK(1),
                ..base(Algorithm::Nl1, seed)
            }),
            s("dingo", RunConfig { rounds: 100, ..base(Algorithm::Dingo, seed) }),
            s("newton", RunConfig { rounds: 50, ..base(Algorithm::Newton, seed) }),
        ],
        // ── Figure 1 row 2: BL1 vs first-order methods (§6.3) ──
        "fig1-first-order" => vec![
            s("bl1", RunConfig {
                hess_comp: CompressorSpec::TopK(r),
                ..base(Algorithm::Bl1, seed)
            }),
            s("gd", RunConfig { rounds: 200_000, ..base(Algorithm::Gd, seed) }),
            s("diana", RunConfig {
                grad_comp: CompressorSpec::Dithering(None),
                rounds: 200_000,
                ..base(Algorithm::Diana, seed)
            }),
            s("adiana", RunConfig {
                grad_comp: CompressorSpec::Dithering(None),
                rounds: 200_000,
                ..base(Algorithm::Adiana, seed)
            }),
            s("s-local-gd", RunConfig { rounds: 400_000, ..base(Algorithm::SLocalGd, seed) }),
        ],
        // ── Figure 1 row 3: composed Rank-R compressors in BL2 (§6.4);
        //     standard basis ⇒ BL2 ≡ FedNL ──
        "fig1-compose-rank" => {
            let mk = |comp: CompressorSpec| RunConfig {
                hess_comp: comp,
                basis: Some(BasisKind::Standard),
                p: 0.1,
                model_comp: CompressorSpec::TopK((d / 10).max(1)),
                rounds: 8000,
                ..base(Algorithm::Bl2, seed)
            };
            vec![
                s("rank1", mk(CompressorSpec::RankR(1))),
                s("rrank1", mk(CompressorSpec::RRank(1, None))),
                s("nrank1", mk(CompressorSpec::NRank(1))),
            ]
        }
        // ── Figure 2: Newton standard vs data basis (App. A.4) ──
        "fig2" => vec![
            s("newton-std", RunConfig {
                basis: Some(BasisKind::Standard),
                rounds: 50,
                ..base(Algorithm::Newton, seed)
            }),
            s("newton-basis", RunConfig {
                basis: Some(BasisKind::Subspace),
                rounds: 50,
                ..base(Algorithm::Newton, seed)
            }),
        ],
        // ── Figure 3: Top-K compositions in BL2 (App. A.5) ──
        "fig3" => {
            let p = (r as f64 / (2.0 * d as f64)).clamp(0.01, 1.0);
            let mk = |comp: CompressorSpec| RunConfig {
                hess_comp: comp,
                p,
                model_comp: CompressorSpec::TopK((r / 2).max(1)),
                rounds: 8000,
                ..base(Algorithm::Bl2, seed)
            };
            vec![
                s("topk", mk(CompressorSpec::TopK(r))),
                s("rtopk", mk(CompressorSpec::RTopK(r, None))),
                s("ntopk", mk(CompressorSpec::NTopK(r))),
            ]
        }
        // ── Figure 4: partial participation (App. A.6) ──
        "fig4" => {
            let tau = Some((n / 2).max(1));
            vec![
                s("fednl-pp", RunConfig {
                    hess_comp: CompressorSpec::RankR(1),
                    tau,
                    rounds: 8000,
                    ..base(Algorithm::FedNlPp, seed)
                }),
                s("bl2", RunConfig {
                    hess_comp: CompressorSpec::TopK(r),
                    tau,
                    rounds: 8000,
                    ..base(Algorithm::Bl2, seed)
                }),
                s("bl3", RunConfig {
                    hess_comp: CompressorSpec::TopK(d),
                    tau,
                    rounds: 8000,
                    ..base(Algorithm::Bl3, seed)
                }),
                s("artemis", RunConfig {
                    grad_comp: CompressorSpec::Dithering(None),
                    tau,
                    rounds: 400_000,
                    ..base(Algorithm::Artemis, seed)
                }),
            ]
        }
        // ── Figure 5: bidirectional compression (App. A.7) ──
        "fig5" => {
            let p_bl = (r as f64 / (2.0 * d as f64)).clamp(0.01, 1.0);
            vec![
                s("fednl-bc", RunConfig {
                    hess_comp: CompressorSpec::TopK((d * d / 2).max(1)),
                    model_comp: CompressorSpec::TopK((d / 2).max(1)),
                    rounds: 8000,
                    ..base(Algorithm::FedNlBc, seed)
                }),
                s("bl1", RunConfig {
                    hess_comp: CompressorSpec::TopK((r / 2).max(1)),
                    model_comp: CompressorSpec::TopK((r / 2).max(1)),
                    p: p_bl,
                    rounds: 8000,
                    ..base(Algorithm::Bl1, seed)
                }),
                s("bl2", RunConfig {
                    hess_comp: CompressorSpec::TopK((r / 2).max(1)),
                    model_comp: CompressorSpec::TopK((r / 2).max(1)),
                    p: p_bl,
                    rounds: 8000,
                    ..base(Algorithm::Bl2, seed)
                }),
                s("bl3", RunConfig {
                    hess_comp: CompressorSpec::TopK((d / 2).max(1)),
                    model_comp: CompressorSpec::TopK((d / 2).max(1)),
                    p: 0.5,
                    rounds: 8000,
                    ..base(Algorithm::Bl3, seed)
                }),
                s("dore", RunConfig {
                    grad_comp: CompressorSpec::Dithering(None),
                    model_comp: CompressorSpec::Dithering(None),
                    rounds: 400_000,
                    ..base(Algorithm::Dore, seed)
                }),
            ]
        }
        // ── Figure 6: BL2 vs BL3 under PP + BC, p ∈ {1, ⅓, ⅕} (App. A.8) ──
        "fig6" => {
            let tau = Some((n / 2).max(1));
            let mut series = Vec::new();
            for &p in &[1.0, 1.0 / 3.0, 0.2] {
                let k = ((p * d as f64).floor() as usize).max(1);
                series.push(s(&format!("bl2-p{p:.2}"), RunConfig {
                    hess_comp: CompressorSpec::TopK(k),
                    model_comp: CompressorSpec::TopK(k),
                    basis: Some(BasisKind::Standard),
                    p,
                    tau,
                    rounds: 12_000,
                    ..base(Algorithm::Bl2, seed)
                }));
                series.push(s(&format!("bl3-p{p:.2}"), RunConfig {
                    hess_comp: CompressorSpec::TopK(k),
                    model_comp: CompressorSpec::TopK(k),
                    p,
                    tau,
                    rounds: 12_000,
                    ..base(Algorithm::Bl3, seed)
                }));
            }
            series
        }
        // ── Ablations (not in the paper; design choices DESIGN.md calls out) ──
        // Basis ablation: identical BL1 configuration, only the Hessian
        // basis varies. Isolates how much of BL1's win is the basis itself.
        "ablation-basis" => vec![
            s("bl1-standard", RunConfig {
                basis: Some(BasisKind::Standard),
                hess_comp: CompressorSpec::TopK(r),
                ..base(Algorithm::Bl1, seed)
            }),
            s("bl1-symtri", RunConfig {
                basis: Some(BasisKind::SymTri),
                hess_comp: CompressorSpec::TopK(r),
                ..base(Algorithm::Bl1, seed)
            }),
            s("bl1-subspace", RunConfig {
                basis: Some(BasisKind::Subspace),
                hess_comp: CompressorSpec::TopK(r),
                ..base(Algorithm::Bl1, seed)
            }),
        ],
        // Hessian learning-rate ablation: α = 1 (the contractive rule) vs
        // smaller steps. Checks Asm. 4.6's α = 1 is actually the right call.
        "ablation-alpha" => [1.0, 0.5, 0.1]
            .iter()
            .map(|&alpha| {
                s(&format!("bl1-alpha{alpha}"), RunConfig {
                    alpha: Some(alpha),
                    hess_comp: CompressorSpec::TopK(r),
                    ..base(Algorithm::Bl1, seed)
                })
            })
            .collect(),
        // Compressor-budget ablation: Top-K at K ∈ {r/2, r, 2r, r²} on the
        // r×r coefficient matrix — where does more Hessian bandwidth stop
        // paying?
        "ablation-budget" => [(r / 2).max(1), r, 2 * r, r * r]
            .iter()
            .map(|&k| {
                s(&format!("bl1-top{k}"), RunConfig {
                    hess_comp: CompressorSpec::TopK(k),
                    ..base(Algorithm::Bl1, seed)
                })
            })
            .collect(),
        other => bail!("unknown figure '{other}'; known: {:?}", super::EXPERIMENTS),
    })
}

/// Which datasets each figure sweeps (paper uses several per row; we default
/// to a representative pair to keep runtimes short — pass `--full-scale` for
/// the full registry).
fn figure_datasets(id: &str, full: bool) -> Result<Vec<DatasetEntry>> {
    if full {
        return Ok(registry());
    }
    match id {
        "fig1-second-order" | "fig1-first-order" => ds(&["a1a", "w2a"]),
        "fig1-compose-rank" => ds(&["a1a"]),
        "fig2" => ds(&["a1a", "phishing"]),
        "fig3" => ds(&["w2a", "a1a"]),
        "fig4" => ds(&["a1a"]),
        "fig5" => ds(&["a1a"]),
        "fig6" => ds(&["a1a"]),
        _ => ds(&["a1a"]),
    }
}

/// Run one figure end to end: declare every (dataset × series) run as a
/// sweep cell, execute the whole list through the sweep engine's thread
/// pool, then print the paper-style tables in declaration order.
pub fn run_figure(id: &str, full_scale: bool, seed: u64, jobs: usize) -> Result<()> {
    let count_downlink = matches!(id, "fig5" | "fig6");

    // ── declare the run list ──
    let mut cells: Vec<SweepCell> = Vec::new();
    let mut labels: Vec<String> = Vec::new();
    // (first cell id of a dataset block, its table header).
    let mut headers: Vec<(usize, String)> = Vec::new();
    for entry in figure_datasets(id, full_scale)? {
        let fed = entry.build(seed, full_scale);
        headers.push((
            cells.len(),
            format!(
                "\n{id} on {} (n={}, d={}, r≈{:.0}) — bits/node ({}) to reach gap ≤ target",
                fed.name,
                fed.n_clients(),
                fed.dim(),
                fed.avg_intrinsic_dim(1e-9),
                if count_downlink { "up+down" } else { "uplink" },
            ),
        ));
        for sr in spec(id, &fed, seed)? {
            labels.push(sr.label.clone());
            cells.push(SweepCell {
                id: cells.len(),
                group: format!("{}::{}", fed.name, sr.label),
                data_seed: seed,
                dataset: DatasetRef::Registry { entry, full_scale },
                cfg: sr.cfg,
            });
        }
    }

    // ── execute across the thread pool (progress in completion order) ──
    let total = cells.len();
    let mut done = 0usize;
    let results = run_cells(&cells, jobs, |r| {
        done += 1;
        eprintln!("  [{done}/{total}] {} ({:.1}s)", r.group, r.wall_ms / 1e3);
    });

    // ── report in declaration order ──
    for (i, res) in results.iter().enumerate() {
        if let Some((_, header)) = headers.iter().find(|(first, _)| *first == i) {
            println!("{header}");
            println!(
                "{:<16}{:>14}{:>14}{:>14}{:>12}",
                "method", "1e-4", "1e-7", "1e-10", "final gap"
            );
        }
        let label = &labels[i];
        let hist = match (&res.status, &res.history) {
            (CellStatus::Ok, Some(h)) => h,
            (CellStatus::Failed(e), _) => {
                println!("{label:<16}  FAILED: {e}");
                continue;
            }
            _ => continue,
        };
        let bits_at = |target: f64| -> String {
            let bits = if count_downlink {
                hist.bits_to_reach(target)
            } else {
                hist.bits_to_reach_uplink(target)
            };
            bits.map(|b| format!("{b:.3e}")).unwrap_or_else(|| "—".into())
        };
        println!(
            "{:<16}{:>14}{:>14}{:>14}{:>12.2e}",
            label,
            bits_at(TARGETS[0]),
            bits_at(TARGETS[1]),
            bits_at(TARGETS[2]),
            hist.final_gap()
        );
        let mut hist = hist.clone();
        hist.label = format!("{}__{}", res.dataset, label);
        hist.write_csv(&runs_dir(), id)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    #[test]
    fn every_figure_has_a_spec() {
        let fed = FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 4,
            m_per_client: 20,
            dim: 10,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 1,
        });
        for id in super::super::EXPERIMENTS {
            if id.starts_with("fig") || id.starts_with("ablation") {
                let s = spec(id, &fed, 1).unwrap();
                assert!(s.len() >= 2, "{id} has {} series", s.len());
            }
        }
        assert!(spec("fig99", &fed, 1).is_err());
    }

    #[test]
    fn figure_datasets_resolve() {
        for id in super::super::EXPERIMENTS {
            if id.starts_with("fig") {
                assert!(!figure_datasets(id, false).unwrap().is_empty());
            }
        }
        assert_eq!(figure_datasets("fig2", true).unwrap().len(), registry().len());
    }
}
