//! Experiment harness: regenerates every table and figure of the paper.
//! See DESIGN.md §4 for the experiment index. Implemented in `tables.rs`
//! (Table 1, Table 2) and `figures.rs` (Figures 1–6).

mod figures;
mod tables;

pub use figures::{run_figure, FigureSpec, Series};
pub use tables::{table1, table2};

use anyhow::Result;
use std::path::PathBuf;

/// Where experiment CSVs land.
pub fn runs_dir() -> PathBuf {
    PathBuf::from("runs")
}

/// Every experiment id the CLI accepts.
pub const EXPERIMENTS: &[&str] = &[
    "table1",
    "table2",
    "fig1-second-order",
    "fig1-first-order",
    "fig1-compose-rank",
    "fig2",
    "fig3",
    "fig4",
    "fig5",
    "fig6",
    "ablation-basis",
    "ablation-alpha",
    "ablation-budget",
    "all",
];

/// Run an experiment by id, printing the paper-style rows/series and writing
/// CSVs under `runs/`. Run lists are declared as sweep cells and executed
/// through the [`crate::sweep`] engine across `jobs` worker threads, so
/// `repro experiment all --jobs N` parallelizes every figure for free.
pub fn run_experiment(id: &str, full_scale: bool, seed: u64, jobs: usize) -> Result<()> {
    match id {
        "table1" => table1(seed, jobs),
        "table2" => table2(full_scale, seed),
        "all" => {
            for e in EXPERIMENTS.iter().filter(|e| **e != "all") {
                println!("\n════════ {e} ════════");
                run_experiment(e, full_scale, seed, jobs)?;
            }
            Ok(())
        }
        fig => run_figure(fig, full_scale, seed, jobs),
    }
}
