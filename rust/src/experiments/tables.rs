//! Table 1 and Table 2 of the paper.

use crate::compressors::CompressorSpec;
use crate::config::{Algorithm, BasisKind, RunConfig};
use crate::data::{registry, FederatedDataset};
use crate::sweep::{run_cells, CellResult, CellStatus, DatasetRef, SweepCell};
use anyhow::{Context, Result};

/// Table 1: per-iteration communication (floats) of the three Newton
/// implementations — naive (§2.1), NL1-style problem-structure (§2.2,
/// [Islamov et al. 2021]) and ours (§2.3). The theory columns are printed
/// next to *measured* per-round floats from actual runs on an a1a-shaped
/// dataset, validating the accounting end to end.
pub fn table1(seed: u64, jobs: usize) -> Result<()> {
    let entry = registry()
        .into_iter()
        .find(|e| e.name == "a1a")
        .context("dataset 'a1a' missing from the Table 2 registry")?;
    let fed = entry.build(seed, false);
    let d = fed.dim();
    let m = fed.clients[0].m();
    let r = fed.avg_intrinsic_dim(1e-9).round() as usize;
    let n = fed.n_clients();
    println!("Table 1 — Newton implementations (dataset {}: n={n}, m={m}, d={d}, r={r})", fed.name);

    let float_bits = 64.0;
    // The three measurement runs, declared as sweep cells and executed
    // through the engine (table rows are independent runs like any others).
    let newton = |basis: BasisKind| RunConfig {
        algorithm: Algorithm::Newton,
        basis: Some(basis),
        rounds: 3,
        lambda: 1e-3,
        target_gap: 0.0,
        seed,
        ..RunConfig::default()
    };
    // NL1 measured: m-coefficients + d gradient (no compression → identity
    // gives the §2.2 exact implementation cost m + d).
    let nl1_cfg = RunConfig {
        algorithm: Algorithm::Nl1,
        hess_comp: CompressorSpec::RandK(m), // send all m coefficients
        rounds: 3,
        lambda: 1e-3,
        target_gap: 0.0,
        seed,
        ..RunConfig::default()
    };
    let cell = |id: usize, group: &str, cfg: RunConfig| SweepCell {
        id,
        group: group.into(),
        data_seed: seed,
        dataset: DatasetRef::Registry { entry, full_scale: false },
        cfg,
    };
    let cells = vec![
        cell(0, "newton-naive", newton(BasisKind::Standard)),
        cell(1, "newton-ours", newton(BasisKind::Subspace)),
        cell(2, "nl1-exact", nl1_cfg),
    ];
    let results = run_cells(&cells, jobs, |_| {});
    // Measured per-round uplink floats per node for each implementation.
    let per_round_floats = |res: &CellResult| -> Result<f64> {
        let h = res.history.as_ref().with_context(|| match &res.status {
            CellStatus::Failed(e) => format!("{} failed: {e}", res.group),
            CellStatus::Ok => format!("{} produced no history", res.group),
        })?;
        Ok((h.records[1].bits_up_per_node - h.records[0].bits_up_per_node) / float_bits)
    };
    let naive = per_round_floats(&results[0])?;
    let ours = per_round_floats(&results[1])?;
    let nl1 = per_round_floats(&results[2])?;
    let nl1_setup = results[2].require_history()?.setup_bits_per_node / float_bits;

    println!("{:<42}{:>14}{:>14}{:>14}", "", "Naive", "NL1 [Isl+21]", "Ours (§2.3)");
    println!(
        "{:<42}{:>14}{:>14}{:>14}",
        "gradient floats/iter (theory)", d, format!("min(m,d)={}", m.min(d)), r
    );
    println!(
        "{:<42}{:>14}{:>14}{:>14}",
        "hessian floats/iter (theory)",
        d * d,
        format!("min(m,d²)={}", m.min(d * d)),
        r * r
    );
    println!(
        "{:<42}{:>14.0}{:>14.0}{:>14.0}",
        "TOTAL measured floats/iter", naive, nl1, ours
    );
    println!(
        "{:<42}{:>14}{:>14.0}{:>14}",
        "initial cost floats (theory md | rd)", "-", nl1_setup, r * d
    );
    println!(
        "{:<42}{:>14}{:>14}{:>14}",
        "reveals local data?", "no", "YES", "no"
    );

    // The measured totals must match the theory rows (±index overhead is in
    // bits, not floats; Top-K style indices don't appear here).
    let naive_theory = (d * d + d) as f64;
    anyhow::ensure!(
        (naive - naive_theory).abs() < 1.0,
        "naive measured {naive} != theory {naive_theory}"
    );
    anyhow::ensure!((nl1_setup - (m * d) as f64).abs() < 1.0, "NL1 setup cost mismatch");
    Ok(())
}

/// Table 2: dataset shape signatures — paper values next to the synthetic
/// stand-ins actually used, with the *measured* average intrinsic dimension
/// (numerical rank of each client shard).
pub fn table2(full_scale: bool, seed: u64) -> Result<()> {
    println!(
        "Table 2 — datasets ({} scale)",
        if full_scale { "paper" } else { "laptop" }
    );
    println!(
        "{:<10}{:>9}{:>12}{:>11}{:>9}{:>14}{:>13}",
        "dataset", "workers", "points", "features", "r(tbl)", "r(measured)", "paper d/r"
    );
    for e in registry() {
        let fed: FederatedDataset = e.build(seed, full_scale);
        let r_measured = fed.avg_intrinsic_dim(1e-9);
        let (workers, features, r_target) = if full_scale {
            (e.paper_workers, e.paper_features, e.paper_r)
        } else {
            (e.workers, e.features, e.r)
        };
        println!(
            "{:<10}{:>9}{:>12}{:>11}{:>9}{:>14.1}{:>10}/{}",
            e.name,
            workers,
            fed.total_points(),
            features,
            r_target,
            r_measured,
            e.paper_features,
            e.paper_r,
        );
        anyhow::ensure!(fed.n_clients() == workers);
        anyhow::ensure!(fed.dim() == features);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_runs_and_validates() {
        // jobs = 2 exercises the parallel path end to end.
        table1(3, 2).unwrap();
    }

    #[test]
    fn table2_runs() {
        table2(false, 3).unwrap();
    }
}
