//! # basis-learn
//!
//! A production-quality reproduction of
//! *"Basis Matters: Better Communication-Efficient Second Order Methods for
//! Federated Learning"* (Qian, Islamov, Safaryan, Richtárik, 2021).
//!
//! The library implements the paper's three Basis-Learn algorithms (BL1, BL2,
//! BL3), the entire FedNL family they extend, the NL1 / DINGO / Newton
//! second-order baselines, and the first-order baselines the paper compares
//! against (GD, DIANA, ADIANA, S-Local-GD, Artemis, DORE), together with the
//! full matrix-compression calculus of the paper's §3 and the basis machinery
//! of §2.3/§4/§5.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the federated coordinator: per-algorithm server and
//!   client state machines split across an explicit message-passing
//!   [`transport`] layer (serial `Lockstep` reference backend and a
//!   concurrent in-round `Threaded` worker pool, bit-identical by contract),
//!   compressed messages with exact bit accounting, participation sampling,
//!   metrics, experiment harness and CLI.
//! * **L2 (python/compile/model.py)** — the local GLM loss/gradient/Hessian as
//!   a JAX program, AOT-lowered per data shape to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — the Pallas hot-spot kernels (scaled
//!   Gram Hessian, fused logistic gradient) called by L2.
//!
//! With the off-by-default `pjrt` cargo feature enabled, the Rust binary is
//! self-contained at run time: `runtime` loads the HLO artifacts through the
//! PJRT C API (`xla` crate) and serves local loss/grad/Hessian evaluations on
//! the coordinator's hot path. Python never runs on the request path. The
//! default build evaluates local objectives with the native Rust oracle.
//!
//! ## The sweep engine
//!
//! Every run in the paper is one point of a comparative grid — algorithm ×
//! dataset × compressor × basis × participation × seed. [`sweep`] makes those
//! grids first-class: a declarative [`sweep::SweepSpec`] expands into concrete
//! [`sweep::SweepCell`]s with deterministic per-cell seed derivation, a
//! thread-pool executor ([`sweep::run_cells`]) fans independent federated runs
//! out across cores with panic isolation, results stream to JSONL under
//! `runs/`, and an aggregation layer reduces seeds to mean/std
//! bits-to-target-gap with best-cell ranking. The experiment harness
//! ([`experiments`]) declares its figure/table run lists as sweep cells, so
//! `repro experiment <id> --jobs N` parallelizes across the same engine as
//! ad-hoc `repro sweep` grids.
//!
//! ## Quick start
//!
//! ```no_run
//! use basis_learn::prelude::*;
//!
//! // Synthesize an `a1a`-shaped federated dataset with intrinsic dimension 8.
//! let spec = SyntheticSpec { n_clients: 4, m_per_client: 100, dim: 30, intrinsic_dim: 8, noise: 0.0, seed: 7 };
//! let fed = FederatedDataset::synthetic(&spec);
//! let cfg = RunConfig { algorithm: Algorithm::Bl1, rounds: 50, lambda: 1e-3, ..RunConfig::default() };
//! let out = run_federated(&fed, &cfg).unwrap();
//! println!("final gap {:.3e} after {} bits/node", out.final_gap(), out.bits_per_node());
//! ```

pub mod audit;
pub mod bench_util;
pub mod basis;
pub mod compressors;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod problem;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sweep;
pub mod transport;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::basis::{HessianBasis, PsdBasis, StandardBasis, SubspaceBasis, SymTriBasis};
    pub use crate::compressors::{
        BitCost, Compose, Identity, MatCompressor, NaturalCompression, RandDithering, RandK,
        RankR, TopK, VecCompressor,
    };
    pub use crate::config::{Algorithm, RunConfig, TransportSpec};
    pub use crate::coordinator::{run_federated, run_federated_listen, run_worker, RunOutput};
    pub use crate::data::{DataRecipe, FederatedDataset, SyntheticSpec};
    pub use crate::linalg::{Mat, Vector};
    pub use crate::metrics::History;
    pub use crate::obs::{JsonlRecorder, NoopRecorder, Obs, Recorder};
    pub use crate::problem::{LocalProblem, LogisticProblem};
    pub use crate::rng::Rng;
    pub use crate::sweep::{run_cells, DatasetRef, SweepCell, SweepSpec};
    pub use crate::transport::{ClientStep, Lockstep, TcpServer, Threaded, Transport};
}
