//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Used by:
//! * the `[·]_μ` projection of BL1 (project onto `{A = Aᵀ, A ⪰ μI}` by
//!   clamping eigenvalues),
//! * the Rank-R compressor on symmetric matrices (top-|λ| truncation equals
//!   the best rank-R approximation in Frobenius norm),
//! * spectral diagnostics (condition numbers for EXPERIMENTS.md).
//!
//! Jacobi is `O(d³)` per sweep with typically 6–10 sweeps; at the paper's
//! dimensions (`d ≤ 500`) this is comfortably fast, and it is backward-stable
//! and embarrassingly simple to verify.

use super::Mat;

/// Result of a symmetric eigendecomposition `A = V diag(λ) Vᵀ`.
#[derive(Clone, Debug)]
pub struct EigenDecomposition {
    /// Eigenvalues, sorted descending.
    pub values: Vec<f64>,
    /// Orthonormal eigenvectors as *columns* of `vectors`
    /// (`vectors.col(k)` pairs with `values[k]`).
    pub vectors: Mat,
}

impl EigenDecomposition {
    /// Reconstruct `V diag(f(λ)) Vᵀ` for an eigenvalue transform `f`.
    pub fn reconstruct(&self, mut f: impl FnMut(f64) -> f64) -> Mat {
        let n = self.values.len();
        let mut out = Mat::zeros(n, n);
        for (k, &lam) in self.values.iter().enumerate() {
            let fl = f(lam);
            if fl == 0.0 {
                continue;
            }
            for i in 0..n {
                let vik = self.vectors[(i, k)] * fl;
                if vik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[(i, j)] += vik * self.vectors[(j, k)];
                }
            }
        }
        out
    }

    /// Best rank-`r` approximation by |λ| (equals Rank-R truncated SVD for
    /// symmetric matrices).
    pub fn rank_r(&self, r: usize) -> Mat {
        let n = self.values.len();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| self.values[b].abs().total_cmp(&self.values[a].abs()));
        let keep: std::collections::BTreeSet<usize> = order.into_iter().take(r).collect();
        let mut out = Mat::zeros(n, n);
        for (k, &lam) in self.values.iter().enumerate() {
            if !keep.contains(&k) || lam == 0.0 {
                continue;
            }
            for i in 0..n {
                let vik = self.vectors[(i, k)] * lam;
                for j in 0..n {
                    out[(i, j)] += vik * self.vectors[(j, k)];
                }
            }
        }
        out
    }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
///
/// The input is symmetrized defensively (`(A + Aᵀ)/2`) so tiny asymmetries
/// from accumulation order cannot derail the rotation count.
pub fn sym_eigen(a: &Mat) -> EigenDecomposition {
    assert!(a.is_square(), "sym_eigen requires a square matrix");
    let n = a.rows();
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::eye(n);

    if n <= 1 {
        return EigenDecomposition {
            values: (0..n).map(|i| m[(i, i)]).collect(),
            vectors: v,
        };
    }

    const MAX_SWEEPS: usize = 50;
    for _sweep in 0..MAX_SWEEPS {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= 1e-14 * (1.0 + m.fro_norm()) {
            break;
        }
        for p in 0..n - 1 {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                // tan of the rotation angle, the stable formula.
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // Apply the rotation J(p,q,θ)ᵀ M J(p,q,θ) in place.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract and sort descending by eigenvalue.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)], i)).collect();
    pairs.sort_by(|a, b| b.0.total_cmp(&a.0));
    let values: Vec<f64> = pairs.iter().map(|&(lam, _)| lam).collect();
    let mut vectors = Mat::zeros(n, n);
    for (new_k, &(_, old_k)) in pairs.iter().enumerate() {
        for i in 0..n {
            vectors[(i, new_k)] = v[(i, old_k)];
        }
    }
    EigenDecomposition { values, vectors }
}

/// Top-`r` eigenpairs of a symmetric matrix by largest |λ|, via orthogonal
/// (subspace) iteration with Rayleigh–Ritz extraction.
///
/// The workhorse of the Rank-R compressor's fast path
/// (EXPERIMENTS.md §Perf L3-2): `O(r·d²)` per iteration instead of Jacobi's
/// `O(d³)` per sweep. Returns `None` when the iteration has not met the
/// residual tolerance within `max_iters` (e.g. |λ_r| ≈ |λ_{r+1}| clusters) —
/// callers fall back to the full decomposition.
pub fn top_eigenpairs(a: &Mat, r: usize, max_iters: usize, tol: f64) -> Option<(Vec<f64>, Mat)> {
    let d = a.rows();
    assert!(a.is_square() && r >= 1);
    if r >= d || d <= 8 {
        return None; // full Jacobi is cheap/needed here
    }
    let scale = a.fro_norm();
    if scale == 0.0 {
        return Some((vec![0.0; r], Mat::zeros(d, r)));
    }
    // Oversampled subspace (s = r + 4): symmetric Gaussians routinely have
    // near-tied ±λ magnitudes at the cut, which stalls an exactly-r-dim
    // iteration; the buffer columns absorb the tie and restore the fast
    // (|λ_{s+1}|/|λ_r|)^k rate.
    let s = (r + 4).min(d - 1).max(r);
    // Deterministic pseudo-random start (decoupled from caller RNGs so the
    // compressor stays a pure function of its input).
    let mut v = Mat::from_fn(d, s, |i, k| {
        let h = (i.wrapping_mul(2654435761).wrapping_add(k * 40503 + 12345)) & 0xFFFF;
        h as f64 / 65536.0 - 0.5
    });
    orthonormalize(&mut v);
    for it in 0..max_iters {
        let mut w = a.matmul(&v);
        orthonormalize(&mut w);
        // Rayleigh–Ritz on the s-dim subspace.
        let aw = a.matmul(&w);
        let t = w.transpose().matmul(&aw);
        let small = sym_eigen(&t);
        // Rotate basis to Ritz vectors, sorted by |λ| descending.
        let mut order: Vec<usize> = (0..s).collect();
        order.sort_by(|&x, &y| small.values[y].abs().total_cmp(&small.values[x].abs()));
        let mut rot = Mat::zeros(s, s);
        let mut vals = vec![0.0; s];
        for (new_k, &old_k) in order.iter().enumerate() {
            vals[new_k] = small.values[old_k];
            for i in 0..s {
                rot[(i, new_k)] = small.vectors[(i, old_k)];
            }
        }
        v = w.matmul(&rot);
        // Check residuals of the *top r* Ritz pairs only (every few
        // iterations — the check costs a matmul).
        if it % 3 == 2 || it + 1 == max_iters {
            let av = a.matmul(&v);
            let mut ok = true;
            for k in 0..r {
                let mut res = 0.0;
                for i in 0..d {
                    let e = av[(i, k)] - vals[k] * v[(i, k)];
                    res += e * e;
                }
                if res.sqrt() > tol * scale {
                    ok = false;
                    break;
                }
            }
            if ok {
                let mut top = Mat::zeros(d, r);
                for k in 0..r {
                    for i in 0..d {
                        top[(i, k)] = v[(i, k)];
                    }
                }
                vals.truncate(r);
                return Some((vals, top));
            }
        }
    }
    None
}

/// In-place Gram–Schmidt orthonormalization of the columns (twice for
/// stability). Degenerate columns are replaced with fresh deterministic
/// directions.
fn orthonormalize(v: &mut Mat) {
    let (d, r) = (v.rows(), v.cols());
    for k in 0..r {
        for _pass in 0..2 {
            for prev in 0..k {
                let mut proj = 0.0;
                for i in 0..d {
                    proj += v[(i, k)] * v[(i, prev)];
                }
                for i in 0..d {
                    let vp = v[(i, prev)];
                    v[(i, k)] -= proj * vp;
                }
            }
        }
        let mut nrm = 0.0;
        for i in 0..d {
            nrm += v[(i, k)] * v[(i, k)];
        }
        let mut nrm = nrm.sqrt();
        if nrm < 1e-14 {
            for i in 0..d {
                v[(i, k)] = ((i * 48271 + k * 16807 + 7) % 101) as f64 / 101.0 - 0.5;
            }
            nrm = {
                let mut s = 0.0;
                for i in 0..d {
                    s += v[(i, k)] * v[(i, k)];
                }
                s.sqrt()
            };
        }
        for i in 0..d {
            v[(i, k)] /= nrm;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn top_eigenpairs_match_jacobi() {
        let mut rng = Rng::new(44);
        for d in [12, 30, 60] {
            let mut a = Mat::from_fn(d, d, |_, _| rng.normal());
            a.symmetrize();
            let full = sym_eigen(&a);
            let mut abs_sorted: Vec<f64> = full.values.clone();
            abs_sorted.sort_by(|x, y| y.abs().partial_cmp(&x.abs()).unwrap());
            for r in [1, 2, 4] {
                let (vals, vecs) = top_eigenpairs(&a, r, 600, 1e-9)
                    .unwrap_or_else(|| panic!("d={d} r={r} did not converge"));
                for k in 0..r {
                    assert!(
                        (vals[k].abs() - abs_sorted[k].abs()).abs() < 1e-6,
                        "d={d} r={r} k={k}: {} vs {}",
                        vals[k],
                        abs_sorted[k]
                    );
                    // Eigenpair residual.
                    let av = a.matvec(&vecs.col(k));
                    let mut res = 0.0;
                    for i in 0..d {
                        res += (av[i] - vals[k] * vecs[(i, k)]).powi(2);
                    }
                    assert!(res.sqrt() < 1e-6, "residual {res}");
                }
            }
        }
    }

    #[test]
    fn top_eigenpairs_declines_small_or_full() {
        let a = Mat::eye(5);
        assert!(top_eigenpairs(&a, 1, 100, 1e-10).is_none()); // d ≤ 8
        let b = Mat::eye(20);
        assert!(top_eigenpairs(&b, 20, 100, 1e-10).is_none()); // r = d
    }

    #[test]
    fn top_eigenpairs_zero_matrix() {
        let a = Mat::zeros(16, 16);
        let (vals, _) = top_eigenpairs(&a, 2, 100, 1e-10).unwrap();
        assert_eq!(vals, vec![0.0, 0.0]);
    }

    fn random_sym(n: usize, rng: &mut Rng) -> Mat {
        let mut a = Mat::from_fn(n, n, |_, _| rng.normal());
        a.symmetrize();
        a
    }

    fn reconstruct(e: &EigenDecomposition) -> Mat {
        e.reconstruct(|x| x)
    }

    #[test]
    fn diagonal_matrix() {
        let a = Mat::diag(&[3.0, 1.0, 2.0]);
        let e = sym_eigen(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = sym_eigen(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        let mut rng = Rng::new(4);
        for n in [1, 2, 3, 8, 25, 60] {
            let a = random_sym(n, &mut rng);
            let e = sym_eigen(&a);
            let rec = reconstruct(&e);
            let err = (&rec - &a).fro_norm() / (1.0 + a.fro_norm());
            assert!(err < 1e-10, "n={n} reconstruction err={err}");
            // VᵀV = I
            let vtv = e.vectors.transpose().matmul(&e.vectors);
            let id_err = (&vtv - &Mat::eye(n)).fro_norm();
            assert!(id_err < 1e-10, "n={n} orthogonality err={id_err}");
        }
    }

    #[test]
    fn eigenvalues_sorted_descending() {
        let mut rng = Rng::new(5);
        let a = random_sym(20, &mut rng);
        let e = sym_eigen(&a);
        for w in e.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn trace_and_frobenius_invariants() {
        let mut rng = Rng::new(6);
        let a = random_sym(15, &mut rng);
        let e = sym_eigen(&a);
        let tr: f64 = e.values.iter().sum();
        assert!((tr - a.trace()).abs() < 1e-9);
        let fro_sq: f64 = e.values.iter().map(|l| l * l).sum();
        assert!((fro_sq - a.fro_norm_sq()).abs() < 1e-8);
    }

    #[test]
    fn rank_r_is_best_approximation() {
        let mut rng = Rng::new(7);
        let a = random_sym(12, &mut rng);
        let e = sym_eigen(&a);
        // Error of rank-r truncation equals sqrt of the sum of discarded λ².
        for r in [0, 1, 3, 6, 12] {
            let approx = e.rank_r(r);
            let mut lams: Vec<f64> = e.values.iter().map(|l| l * l).collect();
            lams.sort_by(|x, y| y.partial_cmp(x).unwrap());
            let tail: f64 = lams.iter().skip(r).sum();
            let err = (&a - &approx).fro_norm();
            assert!((err - tail.sqrt()).abs() < 1e-8, "r={r} err={err} tail={}", tail.sqrt());
        }
    }

    #[test]
    fn psd_projection_via_reconstruct() {
        // Clamp eigenvalues at μ: the [·]_μ operator of BL1.
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // λ = 3, −1
        let e = sym_eigen(&a);
        let mu = 0.1;
        let proj = e.reconstruct(|l| l.max(mu));
        let pe = sym_eigen(&proj);
        assert!(pe.values.iter().all(|&l| l >= mu - 1e-12));
        assert!((pe.values[0] - 3.0).abs() < 1e-10);
    }
}
