//! Dense row-major matrix type.

use super::{dot, Vector};
use std::fmt;
use std::ops::{Add, AddAssign, Index, IndexMut, Mul, Sub, SubAssign};

/// Dense `rows × cols` matrix, row-major `f64` storage.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of order `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a closure over `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Build a diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    /// Rank-one outer product `u vᵀ`.
    pub fn outer(u: &[f64], v: &[f64]) -> Self {
        Mat::from_fn(u.len(), v.len(), |i, j| u[i] * v[j])
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Raw row-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the raw data vector.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j` as a fresh vector.
    pub fn col(&self, j: usize) -> Vector {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Column `j` into caller-owned storage (the allocation-free [`Mat::col`]).
    pub fn col_into(&self, j: usize, out: &mut Vector) {
        out.clear();
        out.extend((0..self.rows).map(|i| self.data[i * self.cols + j]));
    }

    /// Become a copy of `src`, reusing this matrix's storage.
    pub fn copy_from(&mut self, src: &Mat) {
        self.rows = src.rows;
        self.cols = src.cols;
        self.data.clone_from(&src.data);
    }

    /// Become `α · src`, reusing this matrix's storage. Elementwise products
    /// in the same order as `&src * α`.
    pub fn scale_from(&mut self, src: &Mat, alpha: f64) {
        self.rows = src.rows;
        self.cols = src.cols;
        self.data.clear();
        self.data.extend(src.data.iter().map(|a| a * alpha));
    }

    /// Become `a - b` elementwise, reusing this matrix's storage.
    /// Bit-identical to `&a - &b`.
    pub fn sub_from(&mut self, a: &Mat, b: &Mat) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        self.rows = a.rows;
        self.cols = a.cols;
        self.data.clear();
        self.data.extend(a.data.iter().zip(&b.data).map(|(x, y)| x - y));
    }

    /// Reshape to `rows × cols` and zero every entry (allocation-free within
    /// capacity).
    pub fn resize_zeroed(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on larger matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Transpose into caller-owned storage (same blocked kernel as
    /// [`Mat::transpose`]; every output entry is written).
    pub fn transpose_into(&self, out: &mut Mat) {
        out.resize_zeroed(self.cols, self.rows);
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    /// Matrix–vector product `A x`.
    pub fn matvec(&self, x: &[f64]) -> Vector {
        assert_eq!(self.cols, x.len(), "matvec shape mismatch");
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// `A x` into caller-owned storage; bit-identical to [`Mat::matvec`]
    /// (same per-row [`dot`] reductions).
    pub fn matvec_into(&self, x: &[f64], out: &mut Vector) {
        assert_eq!(self.cols, x.len(), "matvec shape mismatch");
        out.clear();
        out.extend((0..self.rows).map(|i| dot(self.row(i), x)));
    }

    /// Transposed matrix–vector product `Aᵀ x` without forming `Aᵀ`.
    pub fn matvec_t(&self, x: &[f64]) -> Vector {
        assert_eq!(self.rows, x.len(), "matvec_t shape mismatch");
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for j in 0..self.cols {
                y[j] += xi * row[j];
            }
        }
        y
    }

    /// `Aᵀ x` into caller-owned storage; bit-identical to
    /// [`Mat::matvec_t`] (zero-fill then the same accumulation order).
    pub fn matvec_t_into(&self, x: &[f64], out: &mut Vector) {
        assert_eq!(self.rows, x.len(), "matvec_t shape mismatch");
        out.clear();
        out.resize(self.cols, 0.0);
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for j in 0..self.cols {
                out[j] += xi * row[j];
            }
        }
    }

    /// Matrix product `A · B` (ikj loop order, blocked over k).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let c_row = &mut c.data[i * n..(i + 1) * n];
            for (p, &a_ip) in a_row.iter().enumerate().take(k) {
                if a_ip == 0.0 {
                    continue;
                }
                let b_row = &other.data[p * n..(p + 1) * n];
                for j in 0..n {
                    c_row[j] += a_ip * b_row[j];
                }
            }
        }
        c
    }

    /// `A · B` into caller-owned storage; bit-identical to [`Mat::matmul`]
    /// (zeroed accumulator, same ikj loop).
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        out.resize_zeroed(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let c_row = &mut out.data[i * n..(i + 1) * n];
            for (p, &a_ip) in a_row.iter().enumerate().take(k) {
                if a_ip == 0.0 {
                    continue;
                }
                let b_row = &other.data[p * n..(p + 1) * n];
                for j in 0..n {
                    c_row[j] += a_ip * b_row[j];
                }
            }
        }
    }

    /// `AᵀA`-style scaled Gram product: `Aᵀ diag(s) A` without forming the
    /// transpose or the diagonal. This is the native-Rust mirror of the L1
    /// Pallas kernel (used for oracle checks and CPU baselines).
    pub fn gram_scaled(&self, s: &[f64]) -> Mat {
        assert_eq!(self.rows, s.len(), "gram_scaled shape mismatch");
        let (m, d) = (self.rows, self.cols);
        let mut g = Mat::zeros(d, d);
        for r in 0..m {
            let w = s[r];
            if w == 0.0 {
                continue;
            }
            let row = self.row(r);
            // Accumulate the upper triangle of w · rowᵀ row.
            for i in 0..d {
                let wi = w * row[i];
                if wi == 0.0 {
                    continue;
                }
                let g_row = &mut g.data[i * d..(i + 1) * d];
                for j in i..d {
                    g_row[j] += wi * row[j];
                }
            }
        }
        // Mirror to the lower triangle.
        for i in 0..d {
            for j in (i + 1)..d {
                g.data[j * d + i] = g.data[i * d + j];
            }
        }
        g
    }

    /// `Aᵀ diag(s) A` into caller-owned dense storage; bit-identical to
    /// [`Mat::gram_scaled`]. For packed output see
    /// [`super::SymMat::gram_scaled_from`].
    pub fn gram_scaled_into(&self, s: &[f64], out: &mut Mat) {
        assert_eq!(self.rows, s.len(), "gram_scaled shape mismatch");
        let (m, d) = (self.rows, self.cols);
        out.resize_zeroed(d, d);
        for r in 0..m {
            let w = s[r];
            if w == 0.0 {
                continue;
            }
            let row = self.row(r);
            for i in 0..d {
                let wi = w * row[i];
                if wi == 0.0 {
                    continue;
                }
                let g_row = &mut out.data[i * d..(i + 1) * d];
                for j in i..d {
                    g_row[j] += wi * row[j];
                }
            }
        }
        for i in 0..d {
            for j in (i + 1)..d {
                out.data[j * d + i] = out.data[i * d + j];
            }
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Squared Frobenius norm.
    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>()
    }

    /// Frobenius inner product `⟨A, B⟩`.
    pub fn fro_dot(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        dot(&self.data, &other.data)
    }

    /// Spectral norm estimate via power iteration on `AᵀA` (tight enough for
    /// diagnostics; exact eigen-based norms are available through
    /// [`super::sym_eigen`]).
    pub fn spectral_norm_est(&self, iters: usize) -> f64 {
        let n = self.cols;
        if n == 0 || self.rows == 0 {
            return 0.0;
        }
        let mut v: Vector = (0..n).map(|i| ((i * 2654435761) % 1000) as f64 / 1000.0 + 0.1).collect();
        let mut sigma = 0.0;
        for _ in 0..iters {
            let av = self.matvec(&v);
            let atav = self.matvec_t(&av);
            let nrm = super::norm2(&atav);
            if nrm == 0.0 {
                return 0.0;
            }
            for (vi, ai) in v.iter_mut().zip(&atav) {
                *vi = ai / nrm;
            }
            sigma = super::norm2(&self.matvec(&v));
        }
        sigma
    }

    /// Symmetrize in place: `A ← (A + Aᵀ)/2` (the `[·]_s` operator of BL2).
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        let n = self.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.5 * (self.data[i * n + j] + self.data[j * n + i]);
                self.data[i * n + j] = v;
                self.data[j * n + i] = v;
            }
        }
    }

    /// Is the matrix exactly symmetric?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if !self.is_square() {
            return false;
        }
        let n = self.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                if (self.data[i * n + j] - self.data[j * n + i]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m: f64, &x| m.max(x.abs()))
    }

    /// `A ← A + αB`.
    pub fn add_scaled(&mut self, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Add `α` to the diagonal (`A + αI`).
    pub fn add_diag(&mut self, alpha: f64) {
        assert!(self.is_square());
        let n = self.rows;
        for i in 0..n {
            self.data[i * n + i] += alpha;
        }
    }

    /// Trace.
    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }
}

impl Default for Mat {
    /// Empty `0×0` matrix (no allocation) — the natural seed for
    /// scratch buffers later filled by the `*_into` kernels.
    fn default() -> Self {
        Mat { rows: 0, cols: 0, data: Vec::new() }
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }
}

impl AddAssign<&Mat> for Mat {
    fn add_assign(&mut self, other: &Mat) {
        self.add_scaled(1.0, other);
    }
}

impl SubAssign<&Mat> for Mat {
    fn sub_assign(&mut self, other: &Mat) {
        self.add_scaled(-1.0, other);
    }
}

impl Mul<f64> for &Mat {
    type Output = Mat;
    fn mul(self, alpha: f64) -> Mat {
        let data = self.data.iter().map(|a| a * alpha).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_and_index() {
        let m = Mat::eye(3);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 1)], 0.0);
        assert_eq!(m.trace(), 3.0);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let c = a.matmul(&Mat::eye(4));
        assert_eq!(c, a);
    }

    #[test]
    fn matmul_rectangular() {
        let a = Mat::from_fn(3, 5, |i, j| (i + j) as f64);
        let b = Mat::from_fn(5, 2, |i, j| (i as f64) - (j as f64));
        let c = a.matmul(&b);
        assert_eq!(c.rows(), 3);
        assert_eq!(c.cols(), 2);
        // Check one entry by hand: c[1][0] = Σ_p a[1][p]·b[p][0] = Σ_p (1+p)p
        let expect: f64 = (0..5).map(|p| ((1 + p) * p) as f64).sum();
        assert!((c[(1, 0)] - expect).abs() < 1e-12);
    }

    #[test]
    fn matvec_and_transpose_consistent() {
        let a = Mat::from_fn(4, 3, |i, j| ((i * 3 + j) as f64).sin());
        let x = vec![1.0, -2.0, 0.5];
        let y1 = a.matvec(&x);
        let at = a.transpose();
        let y2: Vec<f64> = (0..4).map(|i| dot(&at.col(i), &x)).collect();
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-12);
        }
        // matvec_t vs explicit transpose
        let z = vec![1.0, 2.0, 3.0, 4.0];
        let t1 = a.matvec_t(&z);
        let t2 = at.matvec(&z);
        for (u, v) in t1.iter().zip(&t2) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_scaled_matches_explicit() {
        let a = Mat::from_fn(7, 4, |i, j| ((i + 2 * j) as f64).cos());
        let s: Vec<f64> = (0..7).map(|i| 0.1 + i as f64 * 0.3).collect();
        let g = a.gram_scaled(&s);
        // Explicit: Aᵀ diag(s) A
        let at = a.transpose();
        let sa = Mat::from_fn(7, 4, |i, j| s[i] * a[(i, j)]);
        let g2 = at.matmul(&sa);
        for i in 0..4 {
            for j in 0..4 {
                assert!((g[(i, j)] - g2[(i, j)]).abs() < 1e-12);
            }
        }
        assert!(g.is_symmetric(1e-14));
    }

    #[test]
    fn symmetrize() {
        let mut a = Mat::from_vec(2, 2, vec![1.0, 3.0, 5.0, 2.0]);
        a.symmetrize();
        assert_eq!(a[(0, 1)], 4.0);
        assert_eq!(a[(1, 0)], 4.0);
        assert!(a.is_symmetric(0.0));
    }

    #[test]
    fn outer_product() {
        let m = Mat::outer(&[1.0, 2.0], &[3.0, 4.0, 5.0]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m[(1, 2)], 10.0);
    }

    #[test]
    fn fro_norms() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert!((a.fro_norm_sq() - 10.0).abs() < 1e-14);
        assert!((a.fro_norm() - 10f64.sqrt()).abs() < 1e-14);
        assert!((a.fro_dot(&a) - 10.0).abs() < 1e-14);
    }

    #[test]
    fn spectral_norm_of_diag() {
        let a = Mat::diag(&[3.0, -7.0, 2.0]);
        let s = a.spectral_norm_est(100);
        assert!((s - 7.0).abs() < 1e-6, "s={s}");
    }

    #[test]
    fn add_sub_scale_ops() {
        let a = Mat::eye(2);
        let b = &a + &a;
        assert_eq!(b[(0, 0)], 2.0);
        let c = &b - &a;
        assert_eq!(c, a);
        let d = &a * 5.0;
        assert_eq!(d[(1, 1)], 5.0);
        let mut e = a.clone();
        e.add_diag(2.5);
        assert_eq!(e[(0, 0)], 3.5);
    }
}
