//! Dense linear algebra kit, built from scratch (no BLAS/LAPACK deps).
//!
//! Everything the coordinator needs for the paper's algorithms:
//! matrix/vector arithmetic, Cholesky and LU solves (the Newton step),
//! symmetric Jacobi eigendecomposition (the `[·]_μ` PSD projection of BL1 and
//! the Rank-R compressor on symmetric matrices), and a general SVD (Rank-R on
//! arbitrary matrices, subspace extraction for the data-driven basis).
//!
//! Dimensions in the paper's experiments are small-to-moderate
//! (`d ≤ 500`), so `O(d³)` dense routines with good constants are the right
//! tool; the hot ones ([`Mat::matmul`], [`sym_eigen`]) are blocked/optimized
//! and covered by the bench harness.

mod eigen;
mod mat;
mod solve;
mod svd;
mod symmat;

pub use eigen::{sym_eigen, top_eigenpairs, EigenDecomposition};
pub use mat::Mat;
pub use solve::{cholesky_solve, lu_solve, CholeskyFactor};
pub use svd::{svd, Svd};
pub use symmat::{cholesky_solve_packed, packed_len, SymCholesky, SymMat};

/// Dense column vector.
pub type Vector = Vec<f64>;

/// Euclidean dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than a naive fold and
    // more accurate than a single running sum.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm `‖a‖₂`.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Infinity norm `max |a_i|`.
#[inline]
pub fn norm_inf(a: &[f64]) -> f64 {
    a.iter().fold(0.0, |m, &x| m.max(x.abs()))
}

/// `y ← y + αx`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Elementwise `a - b` as a new vector.
#[inline]
pub fn sub(a: &[f64], b: &[f64]) -> Vector {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Elementwise `a - b` into caller-owned storage; bit-identical to [`sub`].
#[inline]
pub fn sub_into(a: &[f64], b: &[f64], out: &mut Vector) {
    debug_assert_eq!(a.len(), b.len());
    out.clear();
    out.extend(a.iter().zip(b).map(|(x, y)| x - y));
}

/// Elementwise `a + b` as a new vector.
#[inline]
pub fn add(a: &[f64], b: &[f64]) -> Vector {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// `αa` as a new vector.
#[inline]
pub fn scale(alpha: f64, a: &[f64]) -> Vector {
    a.iter().map(|x| alpha * x).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5 - 3.0).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-10);
    }

    #[test]
    fn norms() {
        let a = vec![3.0, -4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-15);
        assert!((norm2_sq(&a) - 25.0).abs() < 1e-15);
        assert!((norm_inf(&a) - 4.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_and_elementwise() {
        let x = vec![1.0, 2.0];
        let mut y = vec![10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0]);
        assert_eq!(sub(&y, &x), vec![11.0, 22.0]);
        assert_eq!(add(&x, &x), vec![2.0, 4.0]);
        assert_eq!(scale(3.0, &x), vec![3.0, 6.0]);
    }
}
