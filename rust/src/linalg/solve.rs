//! Linear solves: Cholesky (SPD — the Newton step with `[H]_μ ⪰ μI`) and
//! partially-pivoted LU (general square fallback, used by DINGO's
//! least-squares pieces and by tests).

use super::Mat;
use anyhow::{bail, Result};

/// Cholesky factor `L` with `A = L Lᵀ` (lower triangular).
#[derive(Clone, Debug)]
pub struct CholeskyFactor {
    l: Mat,
}

impl CholeskyFactor {
    /// Factor a symmetric positive-definite matrix.
    ///
    /// Fails with a descriptive error if a non-positive pivot is found
    /// (i.e. the input was not numerically PD).
    pub fn new(a: &Mat) -> Result<Self> {
        if !a.is_square() {
            bail!("cholesky: matrix is {}x{}, not square", a.rows(), a.cols());
        }
        let n = a.rows();
        // Flat buffer + slice dot products: the inner reduction vectorizes
        // (EXPERIMENTS.md §Perf L3-3).
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            let ri = i * n;
            for j in 0..=i {
                let rj = j * n;
                let s = a[(i, j)] - super::dot(&l[ri..ri + j], &l[rj..rj + j]);
                if i == j {
                    if s <= 0.0 {
                        bail!("cholesky: non-positive pivot {s:.3e} at index {i} (matrix not PD)");
                    }
                    l[ri + j] = s.sqrt();
                } else {
                    l[ri + j] = s / l[rj + j];
                }
            }
        }
        Ok(CholeskyFactor { l: Mat::from_vec(n, n, l) })
    }

    /// Solve `A x = b` given the factorization.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        // Forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            let row = self.l.row(i);
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
        // Backward: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * x[k];
            }
            x[i] = s / self.l[(i, i)];
        }
        x
    }

    /// log-determinant of `A` (2·Σ log L_ii); handy for diagnostics.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

/// One-shot SPD solve `A x = b` via Cholesky.
pub fn cholesky_solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    Ok(CholeskyFactor::new(a)?.solve(b))
}

/// General square solve `A x = b` via LU with partial pivoting.
pub fn lu_solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    if !a.is_square() {
        bail!("lu_solve: matrix is {}x{}, not square", a.rows(), a.cols());
    }
    let n = a.rows();
    assert_eq!(b.len(), n);
    let mut lu = a.clone();
    let mut piv: Vec<usize> = (0..n).collect();

    for k in 0..n {
        // Partial pivot.
        let mut p = k;
        let mut max = lu[(k, k)].abs();
        for i in (k + 1)..n {
            let v = lu[(i, k)].abs();
            if v > max {
                max = v;
                p = i;
            }
        }
        if max < 1e-300 {
            bail!("lu_solve: matrix is singular to working precision (pivot {max:.3e} at col {k})");
        }
        if p != k {
            piv.swap(p, k);
            for j in 0..n {
                let tmp = lu[(k, j)];
                lu[(k, j)] = lu[(p, j)];
                lu[(p, j)] = tmp;
            }
        }
        let pivot = lu[(k, k)];
        for i in (k + 1)..n {
            let f = lu[(i, k)] / pivot;
            lu[(i, k)] = f;
            if f != 0.0 {
                for j in (k + 1)..n {
                    let v = lu[(k, j)];
                    lu[(i, j)] -= f * v;
                }
            }
        }
    }

    // Apply permutation to b, then forward/backward substitution.
    let mut x: Vec<f64> = piv.iter().map(|&i| b[i]).collect();
    for i in 1..n {
        let mut s = x[i];
        for k in 0..i {
            s -= lu[(i, k)] * x[k];
        }
        x[i] = s;
    }
    for i in (0..n).rev() {
        let mut s = x[i];
        for k in (i + 1)..n {
            s -= lu[(i, k)] * x[k];
        }
        x[i] = s / lu[(i, i)];
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norm2;
    use crate::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.transpose().matmul(&b);
        a.add_diag(0.5 * n as f64);
        a
    }

    #[test]
    fn cholesky_solves_spd() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 20, 60] {
            let a = random_spd(n, &mut rng);
            let xstar: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&xstar);
            let x = cholesky_solve(&a, &b).unwrap();
            let err = norm2(&crate::linalg::sub(&x, &xstar));
            assert!(err < 1e-8 * (1.0 + norm2(&xstar)), "n={n} err={err}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(CholeskyFactor::new(&a).is_err());
    }

    #[test]
    fn cholesky_rejects_nonsquare() {
        let a = Mat::zeros(2, 3);
        assert!(CholeskyFactor::new(&a).is_err());
    }

    #[test]
    fn cholesky_logdet() {
        let a = Mat::diag(&[2.0, 3.0, 4.0]);
        let f = CholeskyFactor::new(&a).unwrap();
        assert!((f.logdet() - 24f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn lu_solves_general() {
        let mut rng = Rng::new(2);
        for n in [1, 3, 10, 40] {
            let a = Mat::from_fn(n, n, |_, _| rng.normal());
            let xstar: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&xstar);
            let x = lu_solve(&a, &b).unwrap();
            let err = norm2(&crate::linalg::sub(&x, &xstar));
            assert!(err < 1e-7 * (1.0 + norm2(&xstar)), "n={n} err={err}");
        }
    }

    #[test]
    fn lu_needs_pivoting() {
        // Zero on the (0,0) pivot — requires row exchange.
        let a = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let x = lu_solve(&a, &[3.0, 5.0]).unwrap();
        assert!((x[0] - 5.0).abs() < 1e-14);
        assert!((x[1] - 3.0).abs() < 1e-14);
    }

    #[test]
    fn lu_rejects_singular() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(lu_solve(&a, &[1.0, 1.0]).is_err());
    }

    #[test]
    fn cholesky_and_lu_agree() {
        let mut rng = Rng::new(3);
        let a = random_spd(15, &mut rng);
        let b: Vec<f64> = (0..15).map(|_| rng.normal()).collect();
        let x1 = cholesky_solve(&a, &b).unwrap();
        let x2 = lu_solve(&a, &b).unwrap();
        for (u, v) in x1.iter().zip(&x2) {
            assert!((u - v).abs() < 1e-8);
        }
    }
}
