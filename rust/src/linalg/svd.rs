//! Singular value decomposition via one-sided Jacobi (Hestenes).
//!
//! Used by the Rank-R compressor on general matrices, by the data-driven
//! basis extraction (orthonormal range of a client's data matrix, the paper's
//! `scipy.linalg.orth` step in §6.1), and by the composed compressors `C₁/C₂`
//! of §3 which act on singular-vector pairs.
//!
//! One-sided Jacobi orthogonalizes the columns of `A` by plane rotations:
//! on convergence `A V = U Σ` with `V` orthogonal; singular values are the
//! column norms. It is slow-ish but extremely robust and simple — ideal for
//! `d ≤ 500`.

use super::{dot, Mat};

/// Thin SVD `A = U Σ Vᵀ` with `U: m×k`, `Σ: k`, `V: n×k`, `k = min(m, n)`.
#[derive(Clone, Debug)]
pub struct Svd {
    /// Left singular vectors (columns).
    pub u: Mat,
    /// Singular values, descending, non-negative.
    pub s: Vec<f64>,
    /// Right singular vectors (columns).
    pub v: Mat,
}

impl Svd {
    /// Reconstruct the rank-`r` truncation `Σ_{i<r} σ_i u_i v_iᵀ`.
    pub fn truncate(&self, r: usize) -> Mat {
        let m = self.u.rows();
        let n = self.v.rows();
        let r = r.min(self.s.len());
        let mut out = Mat::zeros(m, n);
        for k in 0..r {
            let sk = self.s[k];
            if sk == 0.0 {
                continue;
            }
            for i in 0..m {
                let uik = self.u[(i, k)] * sk;
                if uik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[(i, j)] += uik * self.v[(j, k)];
                }
            }
        }
        out
    }

    /// Numerical rank at tolerance `tol · σ_max`.
    pub fn rank(&self, rel_tol: f64) -> usize {
        let smax = self.s.first().copied().unwrap_or(0.0);
        if smax == 0.0 {
            return 0;
        }
        self.s.iter().filter(|&&s| s > rel_tol * smax).count()
    }
}

/// One-sided Jacobi SVD.
///
/// Works on the matrix with `m ≥ n` internally (transposing if needed) so the
/// rotation loop is over the smaller dimension.
pub fn svd(a: &Mat) -> Svd {
    if a.rows() < a.cols() {
        // svd(Aᵀ) = (V, Σ, U)
        let t = svd(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    let m = a.rows();
    let n = a.cols();
    // Work on a single flat column-major copy (column `j` = `colmaj[j*m..]`)
    // for cache-friendly rotation — one allocation, no per-column `Mat::col`
    // vectors.
    let mut colmaj = vec![0.0f64; m * n];
    for i in 0..m {
        let row = a.row(i);
        for (j, &x) in row.iter().enumerate() {
            colmaj[j * m + i] = x;
        }
    }
    let mut v = Mat::eye(n);

    const MAX_SWEEPS: usize = 60;
    let eps = 1e-15;
    for _ in 0..MAX_SWEEPS {
        let mut converged = true;
        for p in 0..n.saturating_sub(1) {
            for q in (p + 1)..n {
                // 2×2 Gram block of columns p, q.
                let (alpha, beta, gamma);
                {
                    let cp = &colmaj[p * m..(p + 1) * m];
                    let cq = &colmaj[q * m..(q + 1) * m];
                    alpha = dot(cp, cp);
                    beta = dot(cq, cq);
                    gamma = dot(cp, cq);
                }
                if gamma.abs() <= eps * (alpha * beta).sqrt() + 1e-300 {
                    continue;
                }
                converged = false;
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // Rotate the column pair.
                let (left, right) = colmaj.split_at_mut(q * m);
                let cp = &mut left[p * m..(p + 1) * m];
                let cq = &mut right[..m];
                for i in 0..m {
                    let xp = cp[i];
                    let xq = cq[i];
                    cp[i] = c * xp - s * xq;
                    cq[i] = s * xp + c * xq;
                }
                // Accumulate V.
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if converged {
            break;
        }
    }

    // Singular values = column norms; U = normalized columns.
    let mut triples: Vec<(f64, usize)> = colmaj
        .chunks_exact(m.max(1))
        .enumerate()
        .map(|(j, cj)| (dot(cj, cj).sqrt(), j))
        .collect();
    triples.sort_by(|a, b| b.0.total_cmp(&a.0));

    let k = n;
    let mut u = Mat::zeros(m, k);
    let mut s = Vec::with_capacity(k);
    let mut vperm = Mat::zeros(n, k);
    for (new_j, &(sig, old_j)) in triples.iter().enumerate() {
        s.push(sig);
        if sig > 1e-300 {
            for i in 0..m {
                u[(i, new_j)] = colmaj[old_j * m + i] / sig;
            }
        }
        for i in 0..n {
            vperm[(i, new_j)] = v[(i, old_j)];
        }
    }
    Svd { u, s, v: vperm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    fn check_svd(a: &Mat, tol: f64) {
        let d = svd(a);
        // Reconstruction.
        let rec = d.truncate(d.s.len());
        let err = (&rec - a).fro_norm() / (1.0 + a.fro_norm());
        assert!(err < tol, "reconstruction err={err}");
        // Orthonormal columns of U and V (up to numerical rank).
        let k = d.rank(1e-12);
        for p in 0..k {
            for q in 0..k {
                let up = d.u.col(p);
                let uq = d.u.col(q);
                let expect = if p == q { 1.0 } else { 0.0 };
                assert!((crate::linalg::dot(&up, &uq) - expect).abs() < 1e-8, "UᵀU");
            }
        }
        let vtv = d.v.transpose().matmul(&d.v);
        let id_err = (&vtv - &Mat::eye(d.v.cols())).fro_norm();
        assert!(id_err < 1e-8, "VᵀV err={id_err}");
        // Descending non-negative.
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(d.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn square_random() {
        let mut rng = Rng::new(8);
        for n in [1, 2, 3, 10, 30] {
            let a = Mat::from_fn(n, n, |_, _| rng.normal());
            check_svd(&a, 1e-9);
        }
    }

    #[test]
    fn tall_and_wide() {
        let mut rng = Rng::new(9);
        let tall = Mat::from_fn(20, 5, |_, _| rng.normal());
        check_svd(&tall, 1e-9);
        let wide = Mat::from_fn(4, 17, |_, _| rng.normal());
        check_svd(&wide, 1e-9);
    }

    #[test]
    fn known_singular_values() {
        // diag(3, 2, 1) — singular values are |entries| sorted.
        let a = Mat::diag(&[-3.0, 1.0, 2.0]);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-10);
        assert!((d.s[1] - 2.0).abs() < 1e-10);
        assert!((d.s[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn rank_deficient() {
        // Rank-1 matrix.
        let a = Mat::outer(&[1.0, 2.0, 3.0], &[4.0, 5.0]);
        let d = svd(&a);
        assert_eq!(d.rank(1e-10), 1);
        let err = (&d.truncate(1) - &a).fro_norm();
        assert!(err < 1e-10);
    }

    #[test]
    fn truncation_error_is_tail_norm() {
        let mut rng = Rng::new(10);
        let a = Mat::from_fn(12, 9, |_, _| rng.normal());
        let d = svd(&a);
        for r in [1, 3, 6, 9] {
            let tail: f64 = d.s.iter().skip(r).map(|s| s * s).sum();
            let err = (&d.truncate(r) - &a).fro_norm();
            assert!((err - tail.sqrt()).abs() < 1e-8, "r={r}");
        }
    }

    #[test]
    fn zero_matrix() {
        let a = Mat::zeros(5, 3);
        let d = svd(&a);
        assert!(d.s.iter().all(|&s| s == 0.0));
        assert_eq!(d.rank(1e-12), 0);
    }

    #[test]
    fn symmetric_matches_eigen_magnitudes() {
        let mut rng = Rng::new(11);
        let mut a = Mat::from_fn(10, 10, |_, _| rng.normal());
        a.symmetrize();
        let d = svd(&a);
        let e = crate::linalg::sym_eigen(&a);
        let mut abs_l: Vec<f64> = e.values.iter().map(|l| l.abs()).collect();
        abs_l.sort_by(|x, y| y.partial_cmp(x).unwrap());
        for (s, l) in d.s.iter().zip(&abs_l) {
            assert!((s - l).abs() < 1e-8, "σ={s} |λ|={l}");
        }
    }
}
