//! Packed symmetric matrices: lower-triangle row-major storage.
//!
//! Every object the Basis-Learn / FedNL round loop ships or learns is a
//! symmetric `d×d` matrix; storing it dense wastes half the memory bandwidth
//! the paper's whole premise says is precious. [`SymMat`] keeps only the
//! `d(d+1)/2` lower-triangle entries (row-major: `(i,j)` with `j ≤ i` lives
//! at `i(i+1)/2 + j`) and provides the kernels the hot path needs — packed
//! accumulation ([`SymMat::add_scaled`]), diagonal shifts, Frobenius norms,
//! matrix–vector products, a scaled-Gram accumulator mirroring
//! [`Mat::gram_scaled`], and a reusable packed Cholesky ([`SymCholesky`]).
//!
//! ## Bit-identity contract
//!
//! Two kernels here replace dense calls on numerical trajectories that are
//! pinned byte-identical by `tests/transport_equivalence.rs`, so their
//! floating-point operation *order* is locked to the dense originals:
//!
//! * [`SymMat::gram_scaled_from`] accumulates each packed entry `(i,j)` with
//!   exactly the per-row multiply/add sequence `Mat::gram_scaled` uses for
//!   its upper-triangle entry `(j,i)` (the mirror image), so the packed
//!   result equals the dense one entry-for-entry in exact `f64`.
//! * [`SymCholesky`] performs the same flat-buffer row-prefix dot products
//!   as `solve::CholeskyFactor` — packed row `i` (`i+1` entries starting at
//!   `i(i+1)/2`) holds the same contiguous prefix a dense row holds, so the
//!   factor and both substitution passes are bit-identical.
//!
//! `tests/packed_kernels.rs` asserts both equalities exactly (`==` on every
//! `f64`), across shapes.

use super::{dot, Mat};
use anyhow::{bail, Result};

/// Symmetric matrix in packed lower-triangle row-major storage.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct SymMat {
    n: usize,
    /// `n(n+1)/2` entries; `(i,j)` with `j ≤ i` at `i(i+1)/2 + j`.
    data: Vec<f64>,
}

/// Packed length for order `n`.
#[inline]
pub fn packed_len(n: usize) -> usize {
    n * (n + 1) / 2
}

impl SymMat {
    /// All-zero packed matrix of order `n`.
    pub fn zeros(n: usize) -> Self {
        SymMat { n, data: vec![0.0; packed_len(n)] }
    }

    /// Pack the lower triangle of a square matrix (entries above the
    /// diagonal are ignored; pass a symmetric matrix for a lossless pack).
    pub fn from_mat(a: &Mat) -> Self {
        let mut s = SymMat::default();
        s.pack_from(a);
        s
    }

    /// Order of the matrix.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Raw packed data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw packed data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Packed index of `(i,j)` with `j ≤ i`.
    #[inline]
    fn idx(i: usize, j: usize) -> usize {
        debug_assert!(j <= i);
        i * (i + 1) / 2 + j
    }

    /// Entry `(i,j)` (order-insensitive).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        if j <= i {
            self.data[Self::idx(i, j)]
        } else {
            self.data[Self::idx(j, i)]
        }
    }

    /// Set entry `(i,j)` (order-insensitive; one write, both mirror reads).
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        if j <= i {
            self.data[Self::idx(i, j)] = v;
        } else {
            self.data[Self::idx(j, i)] = v;
        }
    }

    /// Resize to order `n` and zero every entry (allocation-free within
    /// capacity).
    pub fn reset_zeros(&mut self, n: usize) {
        self.n = n;
        self.data.clear();
        self.data.resize(packed_len(n), 0.0);
    }

    /// Re-pack from the lower triangle of `a`, reusing storage.
    pub fn pack_from(&mut self, a: &Mat) {
        assert!(a.is_square(), "SymMat::pack_from requires a square matrix");
        let n = a.rows();
        self.n = n;
        self.data.clear();
        let src = a.data();
        for i in 0..n {
            self.data.extend_from_slice(&src[i * n..i * n + i + 1]);
        }
    }

    /// Unpack into a dense matrix (mirroring the lower triangle up),
    /// reusing the target's storage.
    pub fn unpack_into(&self, out: &mut Mat) {
        let n = self.n;
        out.resize_zeroed(n, n);
        let dst = out.data_mut();
        for i in 0..n {
            let off = Self::idx(i, 0);
            for j in 0..=i {
                let v = self.data[off + j];
                dst[i * n + j] = v;
                dst[j * n + i] = v;
            }
        }
    }

    /// Unpack into a fresh dense matrix.
    pub fn to_mat(&self) -> Mat {
        let mut m = Mat::zeros(self.n, self.n);
        self.unpack_into(&mut m);
        m
    }

    /// `A ← A + αB` on packed storage.
    pub fn add_scaled(&mut self, alpha: f64, other: &SymMat) {
        assert_eq!(self.n, other.n, "SymMat::add_scaled order mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Add `α` to the diagonal (`A + αI`).
    pub fn add_diag(&mut self, alpha: f64) {
        for i in 0..self.n {
            self.data[Self::idx(i, i)] += alpha;
        }
    }

    /// Squared Frobenius norm (off-diagonal entries counted twice).
    pub fn fro_norm_sq(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.n {
            let off = Self::idx(i, 0);
            for j in 0..i {
                let v = self.data[off + j];
                s += 2.0 * v * v;
            }
            let d = self.data[off + i];
            s += d * d;
        }
        s
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.fro_norm_sq().sqrt()
    }

    /// Frobenius inner product `⟨A, B⟩` (off-diagonals counted twice).
    pub fn fro_dot(&self, other: &SymMat) -> f64 {
        assert_eq!(self.n, other.n, "SymMat::fro_dot order mismatch");
        let mut s = 0.0;
        for i in 0..self.n {
            let off = Self::idx(i, 0);
            for j in 0..i {
                s += 2.0 * self.data[off + j] * other.data[off + j];
            }
            s += self.data[off + i] * other.data[off + i];
        }
        s
    }

    /// Matrix–vector product `y = A x` into caller-owned storage.
    ///
    /// Walks the packed rows once: the lower-triangle entry `(i,j)` feeds
    /// both `y_i += a_ij x_j` and (for `j < i`) `y_j += a_ij x_i`.
    pub fn matvec_into(&self, x: &[f64], y: &mut Vec<f64>) {
        assert_eq!(self.n, x.len(), "SymMat::matvec shape mismatch");
        y.clear();
        y.resize(self.n, 0.0);
        for i in 0..self.n {
            let off = Self::idx(i, 0);
            let xi = x[i];
            let mut s = 0.0;
            for j in 0..i {
                let a = self.data[off + j];
                s += a * x[j];
                y[j] += a * xi;
            }
            y[i] += s + self.data[off + i] * xi;
        }
    }

    /// Matrix–vector product `A x` as a fresh vector.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = Vec::new();
        self.matvec_into(x, &mut y);
        y
    }

    /// Scaled Gram accumulation `self = Aᵀ diag(s) A` straight into packed
    /// storage, resetting first.
    ///
    /// Bit-identical to [`Mat::gram_scaled`]: packed entry `(i,j)` (`j ≤ i`)
    /// receives, row by row, exactly the additions the dense kernel applies
    /// to its upper-triangle entry `(j,i)` — the products associate as
    /// `(s_r · a_rj) · a_ri` in both.
    pub fn gram_scaled_from(&mut self, a: &Mat, s: &[f64]) {
        assert_eq!(a.rows(), s.len(), "gram_scaled shape mismatch");
        let (m, d) = (a.rows(), a.cols());
        self.reset_zeros(d);
        for r in 0..m {
            let w = s[r];
            if w == 0.0 {
                continue;
            }
            let row = a.row(r);
            for j in 0..d {
                let wj = w * row[j];
                if wj == 0.0 {
                    continue;
                }
                for (i, &ri) in row.iter().enumerate().skip(j) {
                    self.data[Self::idx(i, j)] += wj * ri;
                }
            }
        }
    }
}

/// Reusable packed Cholesky factorization `A = L Lᵀ`.
///
/// Owns its packed factor and substitution scratch, so repeated
/// `factor`/`solve_into` cycles over same-order matrices perform zero heap
/// allocations — the shape the BL1/FedNL server solve needs every round.
/// Arithmetic is bit-identical to [`super::CholeskyFactor`] (see the module
/// docs).
#[derive(Clone, Debug, Default)]
pub struct SymCholesky {
    n: usize,
    /// Packed lower-triangle factor.
    l: Vec<f64>,
    /// Forward-substitution scratch.
    y: Vec<f64>,
}

impl SymCholesky {
    /// Fresh factor state (no storage until the first `factor`).
    pub fn new() -> Self {
        SymCholesky::default()
    }

    /// Factor a symmetric positive-definite dense matrix into packed
    /// storage, reusing the previous factor's buffers.
    ///
    /// Fails exactly when [`super::CholeskyFactor::new`] does (same pivot
    /// test, same scan order), leaving the partial factor unusable.
    pub fn factor(&mut self, a: &Mat) -> Result<()> {
        if !a.is_square() {
            bail!("cholesky: matrix is {}x{}, not square", a.rows(), a.cols());
        }
        let n = a.rows();
        self.n = n;
        self.l.clear();
        self.l.resize(packed_len(n), 0.0);
        for i in 0..n {
            let ri = SymMat::idx(i, 0);
            for j in 0..=i {
                let rj = SymMat::idx(j, 0);
                let s = a[(i, j)] - dot(&self.l[ri..ri + j], &self.l[rj..rj + j]);
                if i == j {
                    if s <= 0.0 {
                        bail!("cholesky: non-positive pivot {s:.3e} at index {i} (matrix not PD)");
                    }
                    self.l[ri + j] = s.sqrt();
                } else {
                    self.l[ri + j] = s / self.l[rj + j];
                }
            }
        }
        Ok(())
    }

    /// Factor a packed symmetric matrix (same arithmetic; the dense kernel
    /// only ever reads the lower triangle, which is exactly what `a` holds).
    pub fn factor_sym(&mut self, a: &SymMat) -> Result<()> {
        let n = a.n();
        self.n = n;
        self.l.clear();
        self.l.resize(packed_len(n), 0.0);
        for i in 0..n {
            let ri = SymMat::idx(i, 0);
            for j in 0..=i {
                let rj = SymMat::idx(j, 0);
                let s = a.data[ri + j] - dot(&self.l[ri..ri + j], &self.l[rj..rj + j]);
                if i == j {
                    if s <= 0.0 {
                        bail!("cholesky: non-positive pivot {s:.3e} at index {i} (matrix not PD)");
                    }
                    self.l[ri + j] = s.sqrt();
                } else {
                    self.l[ri + j] = s / self.l[rj + j];
                }
            }
        }
        Ok(())
    }

    /// Solve `A x = b` into caller-owned storage (allocation-free after the
    /// first same-order call). Bit-identical to
    /// [`super::CholeskyFactor::solve`].
    pub fn solve_into(&mut self, b: &[f64], x: &mut Vec<f64>) {
        let n = self.n;
        assert_eq!(b.len(), n, "SymCholesky::solve shape mismatch");
        // Forward: L y = b.
        self.y.clear();
        self.y.resize(n, 0.0);
        for i in 0..n {
            let ri = SymMat::idx(i, 0);
            let mut s = b[i];
            let row = &self.l[ri..ri + i + 1];
            for k in 0..i {
                s -= row[k] * self.y[k];
            }
            self.y[i] = s / row[i];
        }
        // Backward: Lᵀ x = y.
        x.clear();
        x.resize(n, 0.0);
        for i in (0..n).rev() {
            let mut s = self.y[i];
            for k in (i + 1)..n {
                s -= self.l[SymMat::idx(k, i)] * x[k];
            }
            x[i] = s / self.l[SymMat::idx(i, i)];
        }
    }

    /// log-determinant of `A` (2·Σ log L_ii).
    pub fn logdet(&self) -> f64 {
        (0..self.n).map(|i| self.l[SymMat::idx(i, i)].ln()).sum::<f64>() * 2.0
    }
}

/// One-shot packed SPD solve `A x = b`.
pub fn cholesky_solve_packed(a: &SymMat, b: &[f64]) -> Result<Vec<f64>> {
    let mut f = SymCholesky::new();
    f.factor_sym(a)?;
    let mut x = Vec::new();
    f.solve_into(b, &mut x);
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::CholeskyFactor;
    use crate::rng::Rng;

    fn random_sym(n: usize, rng: &mut Rng) -> Mat {
        let mut a = Mat::from_fn(n, n, |_, _| rng.normal());
        a.symmetrize();
        a
    }

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.transpose().matmul(&b);
        a.add_diag(0.5 * n as f64);
        a
    }

    #[test]
    fn roundtrip_is_lossless() {
        let mut rng = Rng::new(11);
        for n in [0, 1, 2, 3, 9, 24] {
            let a = random_sym(n, &mut rng);
            let s = SymMat::from_mat(&a);
            assert_eq!(s.data().len(), packed_len(n));
            let back = s.to_mat();
            assert_eq!(a, back, "n={n}");
        }
    }

    #[test]
    fn get_set_mirror() {
        let mut s = SymMat::zeros(4);
        s.set(1, 3, 7.5);
        assert_eq!(s.get(3, 1), 7.5);
        assert_eq!(s.get(1, 3), 7.5);
        s.set(2, 2, -1.0);
        assert_eq!(s.get(2, 2), -1.0);
    }

    #[test]
    fn packed_ops_match_dense() {
        let mut rng = Rng::new(12);
        let a = random_sym(8, &mut rng);
        let b = random_sym(8, &mut rng);
        let (mut pa, pb) = (SymMat::from_mat(&a), SymMat::from_mat(&b));
        pa.add_scaled(0.3, &pb);
        let mut da = a.clone();
        da.add_scaled(0.3, &b);
        assert_eq!(pa.to_mat(), da);
        pa.add_diag(1.25);
        da.add_diag(1.25);
        assert_eq!(pa.to_mat(), da);
        assert!((pa.fro_norm_sq() - da.fro_norm_sq()).abs() < 1e-9 * (1.0 + da.fro_norm_sq()));
        assert!((pa.fro_dot(&pb) - da.fro_dot(&b)).abs() < 1e-9 * (1.0 + da.fro_norm()));
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::new(13);
        for n in [1, 2, 5, 17] {
            let a = random_sym(n, &mut rng);
            let s = SymMat::from_mat(&a);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let yd = a.matvec(&x);
            let yp = s.matvec(&x);
            for (u, v) in yd.iter().zip(&yp) {
                assert!((u - v).abs() < 1e-12, "n={n}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn gram_scaled_from_is_bit_identical() {
        let mut rng = Rng::new(14);
        for (m, d) in [(1, 1), (7, 4), (30, 12), (5, 9)] {
            let a = Mat::from_fn(m, d, |_, _| rng.normal());
            let mut s: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
            if m > 2 {
                s[1] = 0.0; // exercise the skip path
            }
            let dense = a.gram_scaled(&s);
            let mut packed = SymMat::default();
            packed.gram_scaled_from(&a, &s);
            for i in 0..d {
                for j in 0..=i {
                    assert!(
                        packed.get(i, j) == dense[(i, j)],
                        "({i},{j}): {} vs {}",
                        packed.get(i, j),
                        dense[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn packed_cholesky_is_bit_identical_and_reusable() {
        let mut rng = Rng::new(15);
        let mut f = SymCholesky::new();
        let mut x = Vec::new();
        for n in [1, 2, 6, 20] {
            let a = random_spd(n, &mut rng);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let dense = CholeskyFactor::new(&a).unwrap();
            let xd = dense.solve(&b);
            f.factor(&a).unwrap();
            f.solve_into(&b, &mut x);
            assert_eq!(x, xd, "n={n} dense-input solve");
            assert!((f.logdet() - dense.logdet()).abs() < 1e-12);
            // Packed input: same factor, same solution.
            let pa = SymMat::from_mat(&a);
            f.factor_sym(&pa).unwrap();
            f.solve_into(&b, &mut x);
            assert_eq!(x, xd, "n={n} packed-input solve");
            let x2 = cholesky_solve_packed(&pa, &b).unwrap();
            assert_eq!(x2, xd);
        }
    }

    #[test]
    fn packed_cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        let mut f = SymCholesky::new();
        assert!(f.factor(&a).is_err());
        assert!(f.factor_sym(&SymMat::from_mat(&a)).is_err());
        let b = Mat::zeros(2, 3);
        assert!(f.factor(&b).is_err());
    }
}
