//! `repro` — the launcher for the Basis-Learn reproduction.
//!
//! ```text
//! repro experiment <id> [--full-scale] [--seed N] [--jobs N]   regenerate a paper table/figure
//! repro sweep [grid axes] [--jobs N]                           ad-hoc parallel run grid
//! repro run [options]                                          one federated run
//! repro worker --connect <host:port>                           standalone federation worker
//! repro trace <trace.jsonl> [--chrome OUT.json]                summarize / export a trace
//! repro data <name> [--full-scale]                             inspect a registry dataset
//! repro list                                                   algorithms / experiments / datasets
//! repro audit [--root DIR] [--jsonl OUT.jsonl]                 static repo-invariant lint pass
//! repro bench [--quick] [--filter KEY] [--json OUT.json]       in-tree micro-benchmarks
//! ```
//!
//! `repro sweep` grid axes (comma-separated values; the grid is the cartesian
//! product of all axes):
//! ```text
//! --algo a,b,...           algorithms (see `repro list`)                 [bl1]
//! --dataset d1,d2,...      registry names or synth                      [a1a]
//! --hess-comp s1,s2,...    matrix compressors (topk:K, rank:R, ...)     [topk:1]
//! --model-comp s1,...      model compressors Q                          [identity]
//! --grad-comp s1,...       gradient compressors                         [identity]
//! --basis b1,...           default|standard|symtri|subspace|psd         [default]
//! --p x1,x2,...            gradient-send probabilities ξ                [1.0]
//! --tau t1,...             participation levels (`all` or counts)       [all]
//! --seeds SPEC             `1..5` (inclusive) or `1,2,7`                [1]
//! --rounds N --lambda X --target-gap X --max-bits X    shared run template
//! --transport SPEC         lockstep | threaded[:<k>] | tcp[:<k>]   [lockstep]
//!                          (an in-run worker count <k> budgets --jobs down
//!                          so the total thread count stays ≈ --jobs)
//! --jobs N                 worker threads                  [all hardware cores]
//! --name NAME              sweep name (output dir under runs/)         [sweep]
//! --out DIR                explicit output directory       [runs/<name>]
//! --master-seed N          re-randomize all derived cell seeds            [0]
//! --full-scale             paper-sized datasets
//! --resume                 skip cells already completed in <out>/runs.jsonl
//! --trace PATH             record a trace JSONL (see docs/TRACING.md)
//! --progress [on|off]      live progress to stderr      [on when stderr is a TTY]
//! ```
//! Results land in `<out>/runs.jsonl` (one row per run, durably appended in
//! completion order) and `<out>/summary.jsonl` (cross-seed aggregates,
//! ranked best-first; byte-identical at any `--jobs` level).
//!
//! `--resume` recovers an interrupted sweep: the grid is re-expanded, rows
//! already in `runs.jsonl` are matched by their stable cell key plus the
//! full run-config fingerprint (a torn final line from a crash is dropped;
//! rows recorded under different `--rounds`/`--lambda`/... re-run), and
//! only missing or previously failed cells execute. The merged
//! `summary.jsonl` is byte-identical to an uninterrupted run's.
//!
//! `repro run` options:
//! ```text
//! --algo <name>            bl1|bl2|bl3|fednl|fednl-pp|fednl-bc|nl1|dingo|newton|
//!                          gd|diana|adiana|s-local-gd|artemis|dore       [bl1]
//! --dataset <name>         registry name (a1a, w2a, ...) or synth         [a1a]
//! --rounds N               communication rounds                           [500]
//! --lambda X               ridge λ                                        [1e-3]
//! --hess-comp SPEC         matrix compressor (topk:K, rank:R, rrank:R...) [topk:r]
//! --model-comp SPEC        model compressor Q                             [identity]
//! --grad-comp SPEC         gradient compressor (first-order methods)      [identity]
//! --basis KIND             standard|symtri|subspace|psd                   [per-algo]
//! --p X                    gradient-send probability ξ                    [1.0]
//! --tau N                  expected participants per round                [all]
//! --eta X --alpha X        stepsizes (defaults: compressor-class rules)
//! --target-gap X           stop at f(x)−f* ≤ X                            [1e-12]
//! --seed N                 RNG seed                                       [1]
//! --transport SPEC         lockstep | threaded[:<k>] | tcp[:<k>]          [lockstep]
//!                          (in-round client concurrency — tcp moves real
//!                          bytes over loopback sockets; results are
//!                          bit-identical across backends)
//! --listen HOST:PORT       serve the round loop to standalone `repro worker`
//!                          processes instead of in-process workers (port 0
//!                          picks a free port; the resolved address is printed)
//! --workers K              remote workers to register with --listen          [1]
//! --handshake-timeout SECS worker connect/greet deadline                    [30]
//! --pjrt                   evaluate loss/grad/Hessian via PJRT artifacts
//!                          (needs a build with `--features pjrt`)
//! --artifacts DIR          artifact directory for --pjrt                  [artifacts]
//! --csv PATH               write the run history CSV
//! --trace PATH             record a trace JSONL (see docs/TRACING.md)
//! ```
//!
//! `repro worker --connect <host:port>` dials a `repro run --listen` round
//! loop, receives its assignment (run fingerprint, config, data recipe,
//! client indices) over the `Join`/`Assign` handshake (docs/WIRE.md),
//! rebuilds its data shards locally, and serves rounds until the run ends.
//! Two-terminal quickstart:
//! ```text
//! # terminal 1 — the round loop, waiting for 2 workers
//! repro run --algo bl1 --dataset a1a --listen 127.0.0.1:7070 --workers 2
//! # terminal 2 (×2) — the workers
//! repro worker --connect 127.0.0.1:7070
//! ```
//!
//! `repro trace <trace.jsonl>` prints per-phase wall-time, per-message-kind
//! bit-flow, and sweep-worker-utilization tables from a `--trace` file;
//! `--chrome OUT.json` additionally exports Chrome trace-event JSON
//! loadable in `chrome://tracing` or <https://ui.perfetto.dev>.
//!
//! `repro audit` runs the static-analysis pass over the crate's own source
//! (panic-safety, determinism, bit-accounting, registry-sync — see
//! docs/AUDIT.md) and exits non-zero on findings; CI uses it as a gate.
//! ```text
//! --root DIR               crate root to audit       [this crate's source tree]
//! --jsonl PATH             also write machine-readable findings JSONL
//! ```
//!
//! `repro bench` runs the in-tree micro-benchmark suite (packed symmetric
//! kernels vs dense, in-place `*_into` kernels vs allocating, steady-state
//! pooled rounds, wire-codec encode/decode) with per-case heap-allocation
//! accounting; see docs/PERF.md.
//! ```text
//! --quick                  tiny time budget (CI smoke profile)
//! --filter KEY             only groups whose key contains KEY (sym|into|round|wire)
//! --json PATH              write the bench-v1 machine-readable report
//! ```

use anyhow::{bail, Context, Result};
use basis_learn::compressors::CompressorSpec;
use basis_learn::config::{Algorithm, BasisKind, RunConfig, TransportSpec};
use basis_learn::coordinator::{run_federated_traced, RunOutput};
use basis_learn::data::{registry, FederatedDataset, SyntheticSpec};
use basis_learn::experiments::{run_experiment, runs_dir, EXPERIMENTS};
use basis_learn::obs::{
    bits_table, chrome_trace, load_trace, phase_table, worker_table, JsonlRecorder, Obs,
    Recorder, NOOP,
};
use basis_learn::sweep::{
    aggregate, default_jobs, load_jsonl, parse_axis, parse_bases, parse_datasets, parse_seeds,
    parse_taus, plan_resume, ranked, rows_from_results, run_cells_obs, run_row, summary_jsonl,
    summary_table, CellStatus, Json, JsonlSink, RunRow, SweepSpec, SWEEP_TARGETS,
};
use std::io::IsTerminal;
use std::path::PathBuf;

/// Byte-accounting for `repro bench`: routing the whole binary through the
/// counting wrapper costs two relaxed atomic increments per allocation, so
/// the other subcommands are unaffected in any measurable way.
#[global_allocator]
static COUNTING_ALLOC: basis_learn::bench_util::CountingAlloc =
    basis_learn::bench_util::CountingAlloc;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny argv parser: positionals + `--flag [value]` pairs.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().cloned(),
                    _ => None,
                };
                flags.push((name.to_string(), value));
            } else {
                positional.push(a.clone());
            }
        }
        Args { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    fn parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name} {v}: {e}")),
        }
    }
}

fn real_main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    match args.positional.first().map(String::as_str) {
        Some("experiment") | Some("exp") => cmd_experiment(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("run") => cmd_run(&args),
        Some("worker") => cmd_worker(&args),
        Some("trace") => cmd_trace(&args),
        Some("data") => cmd_data(&args),
        Some("list") => cmd_list(),
        Some("audit") => cmd_audit(&args),
        Some("bench") => cmd_bench(&args),
        Some(other) => {
            bail!(
                "unknown command '{other}' \
                 (experiment|sweep|run|worker|trace|data|list|audit|bench)"
            )
        }
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!("repro — Basis Matters (Qian et al., 2021) reproduction");
    println!(
        "usage: repro <experiment|sweep|run|worker|trace|data|list|audit|bench> [options]   \
         (see README.md)"
    );
}

/// `--trace <path>`: open a buffered JSONL trace recorder (flushed by the
/// caller when the traced workload ends).
fn trace_recorder(args: &Args) -> Result<Option<(JsonlRecorder, PathBuf)>> {
    if !args.has("trace") {
        return Ok(None);
    }
    let path = PathBuf::from(args.flag("trace").context("--trace needs a file path")?);
    let rec = JsonlRecorder::create(&path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    Ok(Some((rec, path)))
}

fn cmd_list() -> Result<()> {
    println!("algorithms:");
    for a in Algorithm::all() {
        println!("  {a}");
    }
    println!("experiments:");
    for e in EXPERIMENTS {
        println!("  {e}");
    }
    println!("datasets (Table 2 registry):");
    for d in registry() {
        println!(
            "  {:<10} scaled: n={:<4} m={:<5} d={:<4} r={:<4} | paper: n={:<4} d={:<4} r={}",
            d.name, d.workers, d.m_per_client, d.features, d.r, d.paper_workers,
            d.paper_features, d.paper_r
        );
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .context("usage: repro experiment <id> (see `repro list`)")?;
    let seed: u64 = args.parsed("seed")?.unwrap_or(1);
    let jobs: usize = args.parsed("jobs")?.unwrap_or_else(default_jobs);
    run_experiment(id, args.has("full-scale"), seed, jobs)
}

/// Every flag `repro sweep` understands; anything else is rejected so a
/// typo'd axis (e.g. `--seed` for `--seeds`) can't silently run the wrong
/// grid.
const SWEEP_FLAGS: &[&str] = &[
    "algo", "dataset", "hess-comp", "model-comp", "grad-comp", "basis", "p", "tau", "seeds",
    "rounds", "lambda", "target-gap", "max-bits", "jobs", "name", "out", "master-seed",
    "full-scale", "resume", "transport", "trace", "progress",
];

/// Whether to emit live progress lines to stderr: explicit `--progress`
/// (`on`/`off`) wins; otherwise on exactly when stderr is a TTY (so
/// redirected/CI output stays clean without a flag).
fn progress_enabled(args: &Args) -> bool {
    match args.flag("progress") {
        Some("off") | Some("false") | Some("0") => false,
        Some(_) => true,
        None => args.has("progress") || std::io::stderr().is_terminal(),
    }
}

/// `1h02m`, `3m20s`, `45s` — compact ETA rendering.
fn fmt_duration(secs: f64) -> String {
    let s = secs.max(0.0).round() as u64;
    if s >= 3600 {
        format!("{}h{:02}m", s / 3600, (s % 3600) / 60)
    } else if s >= 60 {
        format!("{}m{:02}s", s / 60, s % 60)
    } else {
        format!("{s}s")
    }
}

/// `repro sweep` — expand the grid axes into cells, execute them across the
/// thread pool, stream per-run JSONL, and write ranked cross-seed aggregates.
fn cmd_sweep(args: &Args) -> Result<()> {
    for (flag, _) in &args.flags {
        if !SWEEP_FLAGS.contains(&flag.as_str()) {
            let hint = if flag == "seed" { " (did you mean --seeds?)" } else { "" };
            bail!(
                "unknown sweep flag '--{flag}'{hint}; valid flags: --{}",
                SWEEP_FLAGS.join(", --")
            );
        }
    }
    let full_scale = args.has("full-scale");
    let defaults = SweepSpec::default();
    let spec = SweepSpec {
        algos: match args.flag("algo") {
            Some(v) => parse_axis(v)?,
            None => defaults.algos,
        },
        datasets: match args.flag("dataset") {
            Some(v) => parse_datasets(v, full_scale)?,
            None => parse_datasets("a1a", full_scale)?,
        },
        hess_comps: match args.flag("hess-comp") {
            Some(v) => parse_axis(v)?,
            None => defaults.hess_comps,
        },
        model_comps: match args.flag("model-comp") {
            Some(v) => parse_axis(v)?,
            None => defaults.model_comps,
        },
        grad_comps: match args.flag("grad-comp") {
            Some(v) => parse_axis(v)?,
            None => defaults.grad_comps,
        },
        bases: match args.flag("basis") {
            Some(v) => parse_bases(v)?,
            None => defaults.bases,
        },
        ps: match args.flag("p") {
            Some(v) => parse_axis(v)?,
            None => defaults.ps,
        },
        taus: match args.flag("tau") {
            Some(v) => parse_taus(v)?,
            None => defaults.taus,
        },
        seeds: match args.flag("seeds") {
            Some(v) => parse_seeds(v)?,
            None => defaults.seeds,
        },
        base: RunConfig {
            rounds: args.parsed("rounds")?.unwrap_or(2000),
            lambda: args.parsed("lambda")?.unwrap_or(1e-3),
            target_gap: args.parsed("target-gap")?.unwrap_or(1e-12),
            max_bits_per_node: Some(args.parsed("max-bits")?.unwrap_or(3e8)),
            transport: args.parsed("transport")?.unwrap_or_default(),
            ..RunConfig::default()
        },
        master_seed: args.parsed("master-seed")?.unwrap_or(0),
    };

    if matches!(spec.base.transport, TransportSpec::Listen { .. }) {
        bail!(
            "sweep does not support the listen transport (one listener cannot serve \
             many concurrent runs) — use `repro run --listen` for multi-process runs"
        );
    }
    let cells = spec.expand();
    let mut jobs: usize = args.parsed("jobs")?.unwrap_or_else(default_jobs);
    // A threaded in-run transport multiplies thread counts: budget the
    // sweep's worker pool so jobs × in-run workers ≈ the requested jobs.
    if matches!(spec.base.transport, TransportSpec::Threaded(_) | TransportSpec::Tcp(_)) {
        let per_run = spec.base.transport.resolved_workers(usize::MAX);
        let budgeted = (jobs / per_run.max(1)).max(1);
        if budgeted != jobs {
            println!(
                "transport {}: budgeting sweep workers {jobs} → {budgeted} \
                 ({per_run} in-run client workers each)",
                spec.base.transport
            );
            jobs = budgeted;
        }
    }
    let name = args.flag("name").unwrap_or("sweep");
    let out_dir = args
        .flag("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| runs_dir().join(name));
    std::fs::create_dir_all(&out_dir)?;
    println!(
        "sweep '{name}': {} cells ({} groups × {} seeds), jobs={jobs} → {}",
        cells.len(),
        cells.len() / spec.seeds.len().max(1),
        spec.seeds.len(),
        out_dir.display()
    );

    // Crash-safe per-run sink: one durable append per completed run, so an
    // interrupted sweep leaves at most a torn final line for --resume.
    let runs_path = out_dir.join("runs.jsonl");
    let (mut sink, done_rows, todo) = if args.has("resume") {
        let (sink, plan_done, plan_todo) = resume_sweep(&cells, &runs_path)?;
        println!(
            "resume: {} of {} cells already complete; running {}",
            plan_done.len(),
            cells.len(),
            plan_todo.len()
        );
        (sink, plan_done, plan_todo)
    } else {
        (JsonlSink::create(&runs_path)?, Vec::new(), cells.clone())
    };

    let recorder = trace_recorder(args)?;
    let rec: &dyn Recorder = match &recorder {
        Some((r, _)) => r,
        None => &NOOP,
    };
    let progress = progress_enabled(args);
    // audit:allow(determinism-clock): progress/ETA display only; never reaches run state or JSONL rows.
    let sweep_start = std::time::Instant::now();
    let total = todo.len();
    let mut done = 0usize;
    let mut sink_err: Option<anyhow::Error> = None;
    let results = run_cells_obs(&todo, jobs, Obs::new(rec), |r| {
        done += 1;
        if let Err(e) = sink.push(&run_row(r, &SWEEP_TARGETS)) {
            if sink_err.is_none() {
                sink_err = Some(e);
            }
        }
        match (&r.status, &r.history) {
            (CellStatus::Ok, Some(h)) => println!(
                "  [{done:>4}/{total}] {} seed={} gap={:.2e} bits={:.3e} ({:.1}s)",
                r.group,
                r.data_seed,
                h.final_gap(),
                h.final_bits_per_node(),
                r.wall_ms / 1e3
            ),
            (CellStatus::Failed(e), _) => {
                println!("  [{done:>4}/{total}] {} seed={} FAILED: {e}", r.group, r.data_seed)
            }
            _ => {}
        }
        if progress {
            let elapsed = sweep_start.elapsed().as_secs_f64().max(1e-9);
            let rate = done as f64 / elapsed;
            let eta = (total - done) as f64 / rate.max(1e-9);
            eprintln!(
                "progress: {done}/{total} cells | {rate:.2} cells/s | ETA {} | {jobs} workers",
                fmt_duration(eta)
            );
        }
    });
    if let Some(e) = sink_err {
        return Err(e).context("writing runs.jsonl");
    }
    if let Some((r, path)) = &recorder {
        r.flush().with_context(|| format!("flushing trace {}", path.display()))?;
        println!("wrote trace {} (inspect with `repro trace {}`)", path.display(), path.display());
    }

    // Cross-seed aggregation, ranked best-first (deterministic bytes): kept
    // rows + fresh results, merged back into declaration order, aggregate
    // byte-identically to an uninterrupted run at any --jobs level.
    let mut rows = done_rows;
    rows.extend(rows_from_results(&results, &SWEEP_TARGETS));
    rows.sort_by_key(|r| r.id);
    let summaries = aggregate(&rows, &SWEEP_TARGETS);
    let order = ranked(&summaries);
    let summary_path = out_dir.join("summary.jsonl");
    std::fs::write(&summary_path, summary_jsonl(&summaries, &order))?;

    let failed = results.iter().filter(|r| !r.status.is_ok()).count();
    println!("\n{}", summary_table(&summaries, &order));
    println!(
        "{} runs ({failed} failed) → {} and {}",
        results.len(),
        runs_path.display(),
        summary_path.display()
    );
    Ok(())
}

/// The `--resume` path: recover completed rows from `runs.jsonl`, compact
/// the file (dropping the torn tail, stale duplicates, and rows for cells
/// being re-run) so appends never follow garbage, and return the sink plus
/// the done/todo split.
fn resume_sweep(
    cells: &[basis_learn::sweep::SweepCell],
    runs_path: &std::path::Path,
) -> Result<(JsonlSink, Vec<RunRow>, Vec<basis_learn::sweep::SweepCell>)> {
    if !runs_path.exists() {
        // Nothing to resume from — behave like a fresh sweep.
        return Ok((JsonlSink::create(runs_path)?, Vec::new(), cells.to_vec()));
    }
    let load = load_jsonl(runs_path)
        .with_context(|| format!("recovering {}", runs_path.display()))?;
    if load.torn_tail {
        println!("resume: dropped a torn final line in {}", runs_path.display());
    }
    // Rows that don't parse as run rows (foreign schemas) can't be resumed
    // — their cells re-run — but they are preserved through compaction.
    let parsed: Vec<(Json, Option<RunRow>)> = load
        .rows
        .into_iter()
        .map(|j| {
            let r = RunRow::from_json(&j).ok();
            (j, r)
        })
        .collect();
    let prior_rows: Vec<RunRow> = parsed.iter().filter_map(|(_, r)| r.clone()).collect();
    // Index into `parsed` for each entry of `prior_rows`.
    let orig_idx: Vec<usize> = parsed
        .iter()
        .enumerate()
        .filter(|(_, (_, r))| r.is_some())
        .map(|(i, _)| i)
        .collect();
    let plan = plan_resume(cells, &prior_rows, &SWEEP_TARGETS);

    // Compact to exactly what the plan selected: the rows backing
    // `plan.done`, plus rows outside the current grid (foreign schemas or
    // other specs' cells), which are preserved untouched. Rows for cells
    // being re-run — failed, stale duplicates, other parameters — drop.
    let kept: std::collections::BTreeSet<usize> =
        plan.kept_prior.iter().map(|&k| orig_idx[k]).collect();
    let grid_keys: std::collections::BTreeSet<String> =
        cells.iter().map(|c| c.key()).collect();
    let mut text = String::new();
    for (i, (j, r)) in parsed.iter().enumerate() {
        let keep = match r {
            _ if kept.contains(&i) => true,
            Some(r) => !grid_keys.contains(&r.key()),
            None => true, // not ours to judge — preserve
        };
        if keep {
            text.push_str(&j.render());
            text.push('\n');
        }
    }
    // Durable tmp-then-rename: sync the compacted bytes before the rename
    // lands, so a crash right after a resume starts can't replace the
    // fsync-per-row file with an empty or half-written one.
    let tmp = runs_path.with_extension("jsonl.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        std::io::Write::write_all(&mut f, text.as_bytes())?;
        f.sync_data()?;
    }
    std::fs::rename(&tmp, runs_path)
        .with_context(|| format!("compacting {}", runs_path.display()))?;

    Ok((JsonlSink::append(runs_path)?, plan.done, plan.todo))
}

fn load_dataset(args: &Args) -> Result<FederatedDataset> {
    let name = args.flag("dataset").unwrap_or("a1a");
    let seed: u64 = args.parsed("seed")?.unwrap_or(1);
    if name == "synth" {
        let spec = SyntheticSpec {
            n_clients: args.parsed("clients")?.unwrap_or(8),
            m_per_client: args.parsed("points")?.unwrap_or(50),
            dim: args.parsed("dim")?.unwrap_or(40),
            intrinsic_dim: args.parsed("intrinsic")?.unwrap_or(10),
            noise: args.parsed("noise")?.unwrap_or(0.0),
            seed,
        };
        return Ok(FederatedDataset::synthetic(&spec));
    }
    if let Some(path) = name.strip_prefix("file:") {
        let n = args.parsed("clients")?.unwrap_or(8);
        return FederatedDataset::from_libsvm_file(std::path::Path::new(path), n, None);
    }
    let entry = registry()
        .into_iter()
        .find(|e| e.name.eq_ignore_ascii_case(name))
        .with_context(|| format!("unknown dataset '{name}' (see `repro list`)"))?;
    Ok(entry.build(seed, args.has("full-scale")))
}

fn cmd_data(args: &Args) -> Result<()> {
    let fed = load_dataset(args)?;
    println!(
        "{}: n={} clients, {} points total, d={}, avg intrinsic r={:.1}",
        fed.name,
        fed.n_clients(),
        fed.total_points(),
        fed.dim(),
        fed.avg_intrinsic_dim(1e-9)
    );
    for (i, c) in fed.clients.iter().enumerate().take(8) {
        println!("  client {i}: m={} r={}", c.m(), c.intrinsic_dim(1e-9));
    }
    Ok(())
}

/// The `--pjrt` execution path: local objectives served by the AOT-compiled
/// JAX/Pallas artifacts through the PJRT C API.
#[cfg(feature = "pjrt")]
fn run_pjrt(
    args: &Args,
    fed: &FederatedDataset,
    cfg: &RunConfig,
    rec: &dyn Recorder,
) -> Result<RunOutput> {
    use basis_learn::coordinator::run_federated_with_traced;
    use basis_learn::problem::LocalProblem;
    use basis_learn::runtime::{PjrtProblem, Runtime};
    use std::rc::Rc;

    let dir = args.flag("artifacts").unwrap_or("artifacts");
    let rt = Rc::new(Runtime::load(std::path::Path::new(dir))?);
    println!("PJRT runtime up: platform={}", rt.platform());
    let locals: Vec<Box<dyn LocalProblem>> = fed
        .clients
        .iter()
        .map(|c| {
            PjrtProblem::new(rt.clone(), c.a.clone(), c.b.clone())
                .map(|p| Box::new(p) as Box<dyn LocalProblem>)
        })
        .collect::<Result<_>>()?;
    let features = fed.clients.iter().map(|c| Some(c.a.clone())).collect();
    run_federated_with_traced(&locals, features, cfg, rec)
}

#[cfg(not(feature = "pjrt"))]
fn run_pjrt(
    _args: &Args,
    _fed: &FederatedDataset,
    _cfg: &RunConfig,
    _rec: &dyn Recorder,
) -> Result<RunOutput> {
    bail!(
        "this binary was built without PJRT support; rebuild with \
         `cargo build --features pjrt` (after enabling the `xla` dependency \
         in rust/Cargo.toml)"
    )
}

fn cmd_run(args: &Args) -> Result<()> {
    let fed = load_dataset(args)?;
    let r = fed.avg_intrinsic_dim(1e-9).round() as usize;

    let transport = match args.flag("listen") {
        Some(addr) => {
            if args.has("transport") {
                bail!("--listen and --transport are mutually exclusive");
            }
            if !addr.contains(':') {
                bail!("--listen needs a host:port address (e.g. 127.0.0.1:0)");
            }
            let workers: usize = args.parsed("workers")?.unwrap_or(1);
            if workers == 0 {
                bail!("--workers must be at least 1");
            }
            TransportSpec::Listen { addr: addr.to_string(), workers }
        }
        None => args.parsed("transport")?.unwrap_or_default(),
    };
    let cfg = RunConfig {
        algorithm: args.parsed::<Algorithm>("algo")?.unwrap_or(Algorithm::Bl1),
        rounds: args.parsed("rounds")?.unwrap_or(500),
        lambda: args.parsed("lambda")?.unwrap_or(1e-3),
        hess_comp: args
            .parsed::<CompressorSpec>("hess-comp")?
            .unwrap_or(CompressorSpec::TopK(r.max(1))),
        model_comp: args.parsed("model-comp")?.unwrap_or(CompressorSpec::Identity),
        grad_comp: args.parsed("grad-comp")?.unwrap_or(CompressorSpec::Identity),
        basis: args.parsed::<BasisKind>("basis")?,
        p: args.parsed("p")?.unwrap_or(1.0),
        tau: args.parsed("tau")?,
        eta: args.parsed("eta")?,
        alpha: args.parsed("alpha")?,
        gamma: args.parsed("gamma")?,
        target_gap: args.parsed("target-gap")?.unwrap_or(1e-12),
        seed: args.parsed("seed")?.unwrap_or(1),
        transport,
        handshake_timeout_ms: args
            .parsed::<f64>("handshake-timeout")?
            .map(|secs| (secs * 1000.0).round() as u64)
            .unwrap_or(basis_learn::config::DEFAULT_HANDSHAKE_TIMEOUT_MS),
        ..RunConfig::default()
    };
    if args.has("pjrt") && cfg.transport != TransportSpec::Lockstep {
        bail!("--pjrt requires --transport lockstep (PJRT oracles are single-threaded)");
    }

    let recorder = trace_recorder(args)?;
    let rec: &dyn Recorder = match &recorder {
        Some((r, _)) => r,
        None => &NOOP,
    };
    let out = if args.has("pjrt") {
        run_pjrt(args, &fed, &cfg, rec)?
    } else if let TransportSpec::Listen { workers, .. } = &cfg.transport {
        let workers = *workers;
        basis_learn::coordinator::run_federated_listen(&fed, &cfg, rec, &mut |addr| {
            println!("listening on {addr} — waiting for {workers} worker(s)");
            println!("connect each with: repro worker --connect {addr}");
        })?
    } else {
        run_federated_traced(&fed, &cfg, rec)?
    };
    if let Some((r, path)) = &recorder {
        r.flush().with_context(|| format!("flushing trace {}", path.display()))?;
        println!("wrote trace {} (inspect with `repro trace {}`)", path.display(), path.display());
    }

    println!(
        "{} on {} — {} rounds, final gap {:.3e}, {:.3e} bits/node (up+down)",
        out.history.label,
        fed.name,
        out.history.records.len(),
        out.final_gap(),
        out.bits_per_node()
    );
    println!("{}", out.history.summary_table(16));
    if let Some(path) = args.flag("csv") {
        std::fs::write(path, out.history.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Every flag `repro worker` understands (same typo protection as sweep).
const WORKER_FLAGS: &[&str] = &["connect"];

/// `repro worker` — the standalone federation worker process: dial a
/// `repro run --listen` round loop, rebuild the assigned shards locally
/// from the Join/Assign handshake, and serve rounds until the run ends.
fn cmd_worker(args: &Args) -> Result<()> {
    for (flag, _) in &args.flags {
        if !WORKER_FLAGS.contains(&flag.as_str()) {
            bail!("unknown worker flag '--{flag}'; valid flags: --{}", WORKER_FLAGS.join(", --"));
        }
    }
    let addr = args
        .flag("connect")
        .context("usage: repro worker --connect <host:port>")?;
    basis_learn::coordinator::run_worker(addr, &mut |line| println!("{line}"))
}

/// `repro trace` — summarize a `--trace` JSONL file (per-phase wall time,
/// per-kind bit flows, sweep-worker utilization) and optionally export
/// Chrome trace-event JSON.
fn cmd_trace(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .context("usage: repro trace <trace.jsonl> [--chrome OUT.json]")?;
    let load = load_trace(std::path::Path::new(path))?;
    if load.torn_tail {
        eprintln!("note: dropped a torn final line (interrupted trace)");
    }
    println!("{path}: {} events", load.rows.len());
    println!("\nper-phase wall time:\n{}", phase_table(&load.rows));
    println!("bit flow by (direction, message kind):\n{}", bits_table(&load.rows));
    let workers = worker_table(&load.rows);
    if !workers.is_empty() {
        println!("sweep worker utilization:\n{workers}");
    }
    if args.has("chrome") {
        let out = args.flag("chrome").context("--chrome needs an output path")?;
        std::fs::write(out, chrome_trace(&load.rows))
            .with_context(|| format!("writing {out}"))?;
        println!(
            "wrote Chrome trace-event JSON to {out} — load it in chrome://tracing \
             or https://ui.perfetto.dev"
        );
    }
    Ok(())
}

/// Every flag `repro audit` understands (same typo protection as sweep).
const AUDIT_FLAGS: &[&str] = &["root", "jsonl"];

/// `repro audit` — the static repo-invariant lint pass (docs/AUDIT.md).
/// Prints the findings table, optionally writes findings JSONL, and exits
/// non-zero unless the tree is clean — the CI gate.
fn cmd_audit(args: &Args) -> Result<()> {
    for (flag, _) in &args.flags {
        if !AUDIT_FLAGS.contains(&flag.as_str()) {
            bail!("unknown audit flag '--{flag}'; valid flags: --{}", AUDIT_FLAGS.join(", --"));
        }
    }
    let cfg = match args.flag("root") {
        Some(root) => basis_learn::audit::AuditConfig::for_root(root),
        None => basis_learn::audit::AuditConfig::for_this_crate(),
    };
    let report = basis_learn::audit::run(&cfg)
        .with_context(|| format!("auditing {}", cfg.root.display()))?;
    if let Some(path) = args.flag("jsonl") {
        std::fs::write(path, basis_learn::audit::report::render_jsonl(&report))
            .with_context(|| format!("writing {path}"))?;
    }
    print!("{}", basis_learn::audit::report::render_table(&report));
    if !report.clean() {
        bail!("audit failed with {} finding(s)", report.findings.len());
    }
    Ok(())
}

/// Every flag `repro bench` understands (same typo protection as sweep).
const BENCH_FLAGS: &[&str] = &["quick", "filter", "json"];

/// `repro bench` — the in-tree micro-benchmark suite with per-case heap
/// accounting (the binary's allocator is the counting wrapper) and an
/// optional `bench-v1` JSON report for machine-readable perf trajectories
/// (docs/PERF.md).
fn cmd_bench(args: &Args) -> Result<()> {
    for (flag, _) in &args.flags {
        if !BENCH_FLAGS.contains(&flag.as_str()) {
            bail!("unknown bench flag '--{flag}'; valid flags: --{}", BENCH_FLAGS.join(", --"));
        }
    }
    let mut b = if args.has("quick") {
        basis_learn::bench_util::Bench::quick()
    } else {
        basis_learn::bench_util::Bench::new()
    };
    let filter = args.flag("filter");
    let keep = |key: &str| filter.map_or(true, |f| key.contains(f));
    basis_learn::bench_util::run_cli_suite(&mut b, &keep);
    println!("\n{} cases measured.", b.results().len());
    if let Some(path) = args.flag("json") {
        std::fs::write(path, basis_learn::bench_util::json_report(b.results()))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote bench report {path}");
    }
    Ok(())
}
