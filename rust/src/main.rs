//! `repro` — the launcher for the Basis-Learn reproduction.
//!
//! ```text
//! repro experiment <id> [--full-scale] [--seed N]      regenerate a paper table/figure
//! repro run [options]                                  one federated run
//! repro data <name> [--full-scale]                     inspect a registry dataset
//! repro list                                           algorithms / experiments / datasets
//! ```
//!
//! `repro run` options:
//! ```text
//! --algo <name>            bl1|bl2|bl3|fednl|fednl-pp|fednl-bc|nl1|dingo|newton|
//!                          gd|diana|adiana|s-local-gd|artemis|dore       [bl1]
//! --dataset <name>         registry name (a1a, w2a, ...) or synth         [a1a]
//! --rounds N               communication rounds                           [500]
//! --lambda X               ridge λ                                        [1e-3]
//! --hess-comp SPEC         matrix compressor (topk:K, rank:R, rrank:R...) [topk:r]
//! --model-comp SPEC        model compressor Q                             [identity]
//! --grad-comp SPEC         gradient compressor (first-order methods)      [identity]
//! --basis KIND             standard|symtri|subspace|psd                   [per-algo]
//! --p X                    gradient-send probability ξ                    [1.0]
//! --tau N                  expected participants per round                [all]
//! --eta X --alpha X        stepsizes (defaults: compressor-class rules)
//! --target-gap X           stop at f(x)−f* ≤ X                            [1e-12]
//! --seed N                 RNG seed                                       [1]
//! --pjrt                   evaluate loss/grad/Hessian via PJRT artifacts
//! --artifacts DIR          artifact directory for --pjrt                  [artifacts]
//! --csv PATH               write the run history CSV
//! ```

use anyhow::{bail, Context, Result};
use basis_learn::compressors::CompressorSpec;
use basis_learn::config::{Algorithm, BasisKind, RunConfig};
use basis_learn::coordinator::{run_federated, run_federated_with};
use basis_learn::data::{registry, FederatedDataset, SyntheticSpec};
use basis_learn::experiments::{run_experiment, EXPERIMENTS};
use basis_learn::problem::LocalProblem;
use basis_learn::runtime::{PjrtProblem, Runtime};
use std::rc::Rc;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny argv parser: positionals + `--flag [value]` pairs.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => Some(it.next().unwrap().clone()),
                    _ => None,
                };
                flags.push((name.to_string(), value));
            } else {
                positional.push(a.clone());
            }
        }
        Args { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    fn parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name} {v}: {e}")),
        }
    }
}

fn real_main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    match args.positional.first().map(String::as_str) {
        Some("experiment") | Some("exp") => cmd_experiment(&args),
        Some("run") => cmd_run(&args),
        Some("data") => cmd_data(&args),
        Some("list") => cmd_list(),
        Some(other) => bail!("unknown command '{other}' (experiment|run|data|list)"),
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!("repro — Basis Matters (Qian et al., 2021) reproduction");
    println!("usage: repro <experiment|run|data|list> [options]   (see README.md)");
}

fn cmd_list() -> Result<()> {
    println!("algorithms:");
    for a in Algorithm::all() {
        println!("  {a}");
    }
    println!("experiments:");
    for e in EXPERIMENTS {
        println!("  {e}");
    }
    println!("datasets (Table 2 registry):");
    for d in registry() {
        println!(
            "  {:<10} scaled: n={:<4} m={:<5} d={:<4} r={:<4} | paper: n={:<4} d={:<4} r={}",
            d.name, d.workers, d.m_per_client, d.features, d.r, d.paper_workers,
            d.paper_features, d.paper_r
        );
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .context("usage: repro experiment <id> (see `repro list`)")?;
    let seed: u64 = args.parsed("seed")?.unwrap_or(1);
    run_experiment(id, args.has("full-scale"), seed)
}

fn load_dataset(args: &Args) -> Result<FederatedDataset> {
    let name = args.flag("dataset").unwrap_or("a1a");
    let seed: u64 = args.parsed("seed")?.unwrap_or(1);
    if name == "synth" {
        let spec = SyntheticSpec {
            n_clients: args.parsed("clients")?.unwrap_or(8),
            m_per_client: args.parsed("points")?.unwrap_or(50),
            dim: args.parsed("dim")?.unwrap_or(40),
            intrinsic_dim: args.parsed("intrinsic")?.unwrap_or(10),
            noise: args.parsed("noise")?.unwrap_or(0.0),
            seed,
        };
        return Ok(FederatedDataset::synthetic(&spec));
    }
    if let Some(path) = name.strip_prefix("file:") {
        let n = args.parsed("clients")?.unwrap_or(8);
        return FederatedDataset::from_libsvm_file(std::path::Path::new(path), n, None);
    }
    let entry = registry()
        .into_iter()
        .find(|e| e.name.eq_ignore_ascii_case(name))
        .with_context(|| format!("unknown dataset '{name}' (see `repro list`)"))?;
    Ok(entry.build(seed, args.has("full-scale")))
}

fn cmd_data(args: &Args) -> Result<()> {
    let fed = load_dataset(args)?;
    println!(
        "{}: n={} clients, {} points total, d={}, avg intrinsic r={:.1}",
        fed.name,
        fed.n_clients(),
        fed.total_points(),
        fed.dim(),
        fed.avg_intrinsic_dim(1e-9)
    );
    for (i, c) in fed.clients.iter().enumerate().take(8) {
        println!("  client {i}: m={} r={}", c.m(), c.intrinsic_dim(1e-9));
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let fed = load_dataset(args)?;
    let r = fed.avg_intrinsic_dim(1e-9).round() as usize;

    let mut cfg = RunConfig::default();
    cfg.algorithm = args.parsed::<Algorithm>("algo")?.unwrap_or(Algorithm::Bl1);
    cfg.rounds = args.parsed("rounds")?.unwrap_or(500);
    cfg.lambda = args.parsed("lambda")?.unwrap_or(1e-3);
    cfg.hess_comp = args
        .parsed::<CompressorSpec>("hess-comp")?
        .unwrap_or(CompressorSpec::TopK(r.max(1)));
    if let Some(c) = args.parsed::<CompressorSpec>("model-comp")? {
        cfg.model_comp = c;
    }
    if let Some(c) = args.parsed::<CompressorSpec>("grad-comp")? {
        cfg.grad_comp = c;
    }
    cfg.basis = args.parsed::<BasisKind>("basis")?;
    cfg.p = args.parsed("p")?.unwrap_or(1.0);
    cfg.tau = args.parsed("tau")?;
    cfg.eta = args.parsed("eta")?;
    cfg.alpha = args.parsed("alpha")?;
    cfg.gamma = args.parsed("gamma")?;
    cfg.target_gap = args.parsed("target-gap")?.unwrap_or(1e-12);
    cfg.seed = args.parsed("seed")?.unwrap_or(1);

    let out = if args.has("pjrt") {
        let dir = args.flag("artifacts").unwrap_or("artifacts");
        let rt = Rc::new(Runtime::load(std::path::Path::new(dir))?);
        println!("PJRT runtime up: platform={}", rt.platform());
        let locals: Vec<Box<dyn LocalProblem>> = fed
            .clients
            .iter()
            .map(|c| {
                PjrtProblem::new(rt.clone(), c.a.clone(), c.b.clone())
                    .map(|p| Box::new(p) as Box<dyn LocalProblem>)
            })
            .collect::<Result<_>>()?;
        let features = fed.clients.iter().map(|c| Some(c.a.clone())).collect();
        run_federated_with(&locals, features, &cfg)?
    } else {
        run_federated(&fed, &cfg)?
    };

    println!(
        "{} on {} — {} rounds, final gap {:.3e}, {:.3e} bits/node (up+down)",
        out.history.label,
        fed.name,
        out.history.records.len(),
        out.final_gap(),
        out.bits_per_node()
    );
    println!("{}", out.history.summary_table(16));
    if let Some(path) = args.flag("csv") {
        std::fs::write(path, out.history.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}
