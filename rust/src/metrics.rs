//! Run metrics: per-round records of communication and convergence, the
//! quantities every figure in the paper plots (`f(x^k) − f(x*)` vs bits per
//! node), plus CSV serialization for the experiment harness.

use std::fmt::Write as _;
use std::path::Path;

/// One communication round's measurements.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// Cumulative uplink bits per node (client → server), averaged over nodes.
    pub bits_up_per_node: f64,
    /// Cumulative downlink bits per node (server → client).
    pub bits_down_per_node: f64,
    /// Optimality gap `f(x^k) − f(x*)`.
    pub gap: f64,
    /// `‖∇f(x^k)‖`.
    pub grad_norm: f64,
    /// `‖x^k − x*‖`.
    pub dist_to_opt: f64,
}

impl RoundRecord {
    /// Total bits per node (up + down), the paper's x-axis.
    pub fn bits_per_node(&self) -> f64 {
        self.bits_up_per_node + self.bits_down_per_node
    }
}

/// Full run trace.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub records: Vec<RoundRecord>,
    /// Label used for CSV column headers / plot legends.
    pub label: String,
    /// One-time setup communication (floats → bits), e.g. the basis transfer
    /// of Table 1's "initial communication cost".
    pub setup_bits_per_node: f64,
}

impl History {
    pub fn new(label: impl Into<String>) -> Self {
        History { records: Vec::new(), label: label.into(), setup_bits_per_node: 0.0 }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn final_gap(&self) -> f64 {
        self.records.last().map(|r| r.gap).unwrap_or(f64::INFINITY)
    }

    pub fn final_bits_per_node(&self) -> f64 {
        self.records.last().map(|r| r.bits_per_node()).unwrap_or(0.0) + self.setup_bits_per_node
    }

    /// Bits per node needed to first reach a gap ≤ `target`
    /// (`None` if never reached). The headline comparison metric.
    pub fn bits_to_reach(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.gap <= target)
            .map(|r| r.bits_per_node() + self.setup_bits_per_node)
    }

    /// Uplink-only bits per node to first reach a gap ≤ `target` (the
    /// accounting convention of the paper's unidirectional figures 1–4).
    pub fn bits_to_reach_uplink(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.gap <= target)
            .map(|r| r.bits_up_per_node + self.setup_bits_per_node)
    }

    /// Reduce the trace to the quantities the sweep engine serializes:
    /// final state plus bits-to-reach for each requested gap target.
    pub fn summarize(&self, targets: &[f64]) -> RunSummary {
        RunSummary {
            label: self.label.clone(),
            rounds: self.records.len(),
            final_gap: self.final_gap(),
            bits_per_node: self.final_bits_per_node(),
            bits_up_per_node: self
                .records
                .last()
                .map(|r| r.bits_up_per_node)
                .unwrap_or(0.0)
                + self.setup_bits_per_node,
            bits_to_targets: targets
                .iter()
                .map(|&t| TargetBits {
                    target: t,
                    total: self.bits_to_reach(t),
                    uplink: self.bits_to_reach_uplink(t),
                })
                .collect(),
        }
    }

    /// CSV text: `round,bits_up,bits_down,bits_total,gap,grad_norm,dist`.
    ///
    /// One-time setup bits (basis transfer) are folded into the *uplink*
    /// column — the same convention [`History::summarize`] and
    /// [`History::bits_to_reach_uplink`] use, and how the paper accounts
    /// Table 1's initial communication cost — so on every row
    /// `bits_per_node = bits_up_per_node + bits_down_per_node` holds
    /// exactly.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("round,bits_up_per_node,bits_down_per_node,bits_per_node,gap,grad_norm,dist_to_opt\n");
        for r in &self.records {
            let _ = writeln!(
                s,
                "{},{:.1},{:.1},{:.1},{:.6e},{:.6e},{:.6e}",
                r.round,
                r.bits_up_per_node + self.setup_bits_per_node,
                r.bits_down_per_node,
                r.bits_per_node() + self.setup_bits_per_node,
                r.gap,
                r.grad_norm,
                r.dist_to_opt
            );
        }
        s
    }

    /// Write the CSV next to other runs of an experiment.
    pub fn write_csv(&self, dir: &Path, experiment: &str) -> anyhow::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let safe: String = self
            .label
            .chars()
            .map(|c| if c.is_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
            .collect();
        let path = dir.join(format!("{experiment}__{safe}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }

    /// Down-sampled pretty table for terminal output: at most `max_rows`
    /// data rows — the final round always prints, and interior rounds fill
    /// the remaining `max_rows − 1` slots at a fixed stride.
    pub fn summary_table(&self, max_rows: usize) -> String {
        let mut s = format!(
            "{:<8} {:>16} {:>14} {:>12}\n",
            "round", "bits/node", "gap", "‖∇f‖"
        );
        let n = self.records.len();
        if n == 0 {
            return s;
        }
        // ⌈(n−1)/(max_rows−1)⌉ strides the n−1 interior rounds into at most
        // max_rows−1 printed rows (the old n/max_rows floor let one extra
        // row slip through, e.g. 11 rows at n=1000, max_rows=10).
        let stride = if max_rows <= 1 { n } else { (n - 1).div_ceil(max_rows - 1).max(1) };
        for (i, r) in self.records.iter().enumerate() {
            if (max_rows > 1 && i % stride == 0 && i + 1 != n) || i + 1 == n {
                let _ = writeln!(
                    s,
                    "{:<8} {:>16.0} {:>14.3e} {:>12.3e}",
                    r.round,
                    r.bits_per_node() + self.setup_bits_per_node,
                    r.gap,
                    r.grad_norm
                );
            }
        }
        s
    }
}

/// One run condensed against a set of gap targets — the JSONL payload of the
/// sweep result sink and the input to cross-seed aggregation.
#[derive(Clone, Debug, PartialEq)]
pub struct RunSummary {
    pub label: String,
    /// Rounds actually executed (stopping rules may cut `cfg.rounds` short).
    pub rounds: usize,
    pub final_gap: f64,
    /// Total (up+down+setup) bits per node at the end of the run.
    pub bits_per_node: f64,
    /// Uplink+setup bits per node at the end of the run.
    pub bits_up_per_node: f64,
    pub bits_to_targets: Vec<TargetBits>,
}

/// Bits-to-reach one gap target, under both accounting conventions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TargetBits {
    pub target: f64,
    /// Up+down+setup bits per node (`None` ⇒ target never reached).
    pub total: Option<f64>,
    /// Uplink+setup bits per node.
    pub uplink: Option<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, bits: f64, gap: f64) -> RoundRecord {
        RoundRecord {
            round,
            bits_up_per_node: bits,
            bits_down_per_node: bits / 2.0,
            gap,
            grad_norm: gap.sqrt(),
            dist_to_opt: gap.sqrt(),
        }
    }

    #[test]
    fn bits_accounting() {
        let r = rec(0, 100.0, 1.0);
        assert_eq!(r.bits_per_node(), 150.0);
    }

    #[test]
    fn bits_to_reach_with_setup() {
        let mut h = History::new("test");
        h.setup_bits_per_node = 10.0;
        h.push(rec(0, 100.0, 1.0));
        h.push(rec(1, 200.0, 1e-3));
        h.push(rec(2, 300.0, 1e-9));
        assert_eq!(h.bits_to_reach(1e-2), Some(310.0));
        assert_eq!(h.bits_to_reach(1e-12), None);
        assert_eq!(h.final_gap(), 1e-9);
        assert_eq!(h.final_bits_per_node(), 460.0);
    }

    #[test]
    fn summarize_condenses_targets() {
        let mut h = History::new("sum");
        h.setup_bits_per_node = 10.0;
        h.push(rec(0, 100.0, 1.0));
        h.push(rec(1, 200.0, 1e-3));
        let s = h.summarize(&[1e-2, 1e-8]);
        assert_eq!(s.rounds, 2);
        assert_eq!(s.final_gap, 1e-3);
        assert_eq!(s.bits_per_node, 310.0);
        assert_eq!(s.bits_up_per_node, 210.0);
        assert_eq!(s.bits_to_targets.len(), 2);
        assert_eq!(s.bits_to_targets[0].total, Some(310.0));
        assert_eq!(s.bits_to_targets[0].uplink, Some(210.0));
        assert_eq!(s.bits_to_targets[1].total, None);
        assert_eq!(s.bits_to_targets[1].uplink, None);
    }

    #[test]
    fn empty_history() {
        let h = History::new("empty");
        assert!(h.final_gap().is_infinite());
        assert_eq!(h.final_bits_per_node(), 0.0);
        assert_eq!(h.bits_to_reach(1.0), None);
    }

    #[test]
    fn csv_format() {
        let mut h = History::new("csv");
        h.push(rec(0, 64.0, 0.5));
        let csv = h.to_csv();
        let mut lines = csv.lines();
        assert!(lines.next().unwrap().starts_with("round,"));
        let row = lines.next().unwrap();
        assert!(row.starts_with("0,64.0,32.0,96.0,"), "{row}");
    }

    #[test]
    fn csv_folds_setup_into_uplink_and_columns_stay_consistent() {
        let mut h = History::new("csv-setup");
        h.setup_bits_per_node = 10.0;
        h.push(rec(0, 64.0, 0.5));
        h.push(rec(1, 128.0, 0.25));
        let csv = h.to_csv();
        let mut lines = csv.lines();
        lines.next(); // header
        // Setup rides the uplink column (the paper's accounting), so the
        // total column equals up + down on every row.
        assert!(lines.next().unwrap().starts_with("0,74.0,32.0,106.0,"), "{csv}");
        assert!(lines.next().unwrap().starts_with("1,138.0,64.0,202.0,"), "{csv}");
        for row in h.to_csv().lines().skip(1) {
            let cols: Vec<f64> =
                row.split(',').skip(1).take(3).map(|x| x.parse().unwrap()).collect();
            assert_eq!(cols[0] + cols[1], cols[2], "{row}");
        }
    }

    #[test]
    fn csv_write_sanitizes_label() {
        let dir = std::env::temp_dir().join("bl_metrics_test");
        let mut h = History::new("weird/label:1");
        h.push(rec(0, 1.0, 1.0));
        let path = h.write_csv(&dir, "exp").unwrap();
        assert!(path.to_string_lossy().contains("weird_label_1"));
        assert!(path.exists());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn summary_table_downsamples() {
        let mut h = History::new("big");
        for i in 0..1000 {
            h.push(rec(i, i as f64, 1.0 / (i + 1) as f64));
        }
        // ≤ max_rows data rows (+1 header), final round always present.
        let table = h.summary_table(10);
        let rows = table.lines().count();
        assert!(rows <= 11, "rows={rows}");
        assert!(table.contains("999"));
    }

    #[test]
    fn summary_table_respects_max_rows_exactly() {
        for (n, max_rows) in [(1000usize, 10usize), (1001, 10), (999, 10), (7, 3), (100, 7)] {
            let mut h = History::new("bound");
            for i in 0..n {
                h.push(rec(i, i as f64, 1.0));
            }
            let table = h.summary_table(max_rows);
            let data_rows = table.lines().count() - 1;
            assert!(data_rows <= max_rows, "n={n} max={max_rows} rows={data_rows}");
            assert!(table.contains(&format!("{}", n - 1)), "final round missing (n={n})");
        }
        // Degenerate sizes: tiny histories print whole, max_rows=1 prints
        // only the final round, empty history prints only the header.
        let mut h = History::new("tiny");
        for i in 0..4 {
            h.push(rec(i, i as f64, 1.0));
        }
        assert_eq!(h.summary_table(10).lines().count(), 5);
        assert_eq!(h.summary_table(1).lines().count(), 2);
        assert_eq!(History::new("empty").summary_table(5).lines().count(), 1);
    }
}
