//! Round-level tracing and bit-flow observability.
//!
//! The paper's claims are about *where bits and time go per round*; this
//! module is the measurement layer that makes those flows visible without
//! perturbing the runs that produce them.
//!
//! Two halves:
//!
//! * [`recorder`] — the write side. A [`Recorder`] trait with two
//!   implementations: [`NoopRecorder`] (the default everywhere; provably
//!   zero-impact — traced and untraced runs are byte-identical because the
//!   recorder has no channel back into the run) and [`JsonlRecorder`]
//!   (buffered JSONL trace events on disk). Instrumented code holds a
//!   cheap [`Obs`] handle and emits spans ([`Obs::span`]), per-packet
//!   bit-flow events ([`Obs::packet`]), and point marks ([`Obs::mark`]).
//! * [`trace`] — the read side. [`load_trace`] parses a trace file back
//!   into [`TraceRow`]s; [`phase_table`] / [`bits_table`] /
//!   [`worker_table`] summarize it for the `repro trace` subcommand; and
//!   [`chrome_trace`] exports Chrome trace-event JSON for
//!   `chrome://tracing` / <https://ui.perfetto.dev>.
//!
//! The event schema is documented field-by-field in `docs/TRACING.md`.

pub mod recorder;
pub mod trace;

pub use recorder::{
    CellScope, Ctx, Dir, Event, EventKind, JsonlRecorder, Lane, NoopRecorder, Obs, Recorder,
    SpanGuard, NOOP,
};
pub use trace::{
    bits_table, chrome_trace, load_trace, phase_table, worker_table, TraceLoad, TraceRow,
};
