//! The recorder core: trace [`Event`]s, the [`Recorder`] sink trait, the
//! zero-impact [`NoopRecorder`], the durable [`JsonlRecorder`], and the
//! copyable [`Obs`] handle instrumented code carries.
//!
//! # Neutrality contract
//!
//! The default recorder is [`NOOP`]: [`Obs::noop`] hands every
//! instrumentation site a handle whose `enabled()` is `false`, so spans,
//! marks, and bit-flow events all reduce to a branch on a constant — no
//! clock reads, no allocation, no I/O. A traced run and an untraced run
//! must produce **byte-identical** [`crate::metrics::History`] traces
//! (enforced by `tests/obs_trace.rs`): recording observes the run, it never
//! participates in it. That is why [`Recorder::record`] takes `&self` and
//! returns nothing — a recorder has no channel through which it could
//! perturb the computation.
//!
//! # Threading
//!
//! `Recorder: Sync` so a single recorder can be shared by reference across
//! the `Threaded` transport's workers and the sweep executor's threads
//! (`&dyn Recorder` is `Send` exactly because the trait requires `Sync`).
//! [`JsonlRecorder`] serializes concurrent `record` calls through a mutex;
//! event order in the file is therefore an arbitrary interleaving across
//! threads, and consumers order by timestamp (which is global: all
//! timestamps come from one monotonic epoch).

use crate::sweep::{Json, JsonlSink};
use crate::transport::Packet;
use anyhow::Result;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

/// What an [`Event`] describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A timed phase: `ts_us` is the start, `dur_us` the duration.
    Span,
    /// One message crossing the transport (an instant, with bit fields).
    Bits,
    /// A point annotation (run metadata, cache hit/miss, ...).
    Mark,
}

impl EventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::Span => "span",
            EventKind::Bits => "bits",
            EventKind::Mark => "mark",
        }
    }
}

/// Which logical timeline an event belongs to. Lanes are what the Chrome
/// export renders as threads: the server loop, each client's compute
/// stream, and each sweep worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// The coordinator round loop (plan/exchange/absorb/eval).
    Server,
    /// Client `i`'s local work (compute, queue wait).
    Client(usize),
    /// Sweep executor worker `w` (cell spans, cache events).
    Sweep(usize),
}

impl Lane {
    /// Stable serialized form: `server`, `client:3`, `sweep:0`.
    pub fn render(&self) -> String {
        match self {
            Lane::Server => "server".to_string(),
            Lane::Client(i) => format!("client:{i}"),
            Lane::Sweep(w) => format!("sweep:{w}"),
        }
    }
}

/// Where in the run an event happened. All fields optional: a sweep-level
/// event has only `cell`, a round-loop event has `round`/`exchange`, a
/// per-client event adds `client`. [`CellScope`] injects `cell` into every
/// event recorded inside one sweep cell.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Ctx {
    pub cell: Option<usize>,
    pub round: Option<usize>,
    pub exchange: Option<usize>,
    pub client: Option<usize>,
}

impl Ctx {
    /// Round-loop context (server lane).
    pub fn round(round: usize, exchange: usize) -> Ctx {
        Ctx { round: Some(round), exchange: Some(exchange), ..Ctx::default() }
    }

    /// Per-client context within an exchange.
    pub fn client(round: usize, exchange: usize, client: usize) -> Ctx {
        Ctx { client: Some(client), ..Ctx::round(round, exchange) }
    }
}

/// Message direction for bit-flow events.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Up,
    Down,
}

impl Dir {
    pub fn as_str(&self) -> &'static str {
        match self {
            Dir::Up => "up",
            Dir::Down => "down",
        }
    }
}

/// One trace event. Flat by design: every event carries the same base
/// fields (`ev`, `name`, `lane`, `ts_us`) plus kind-specific optionals, so
/// the JSONL schema (docs/TRACING.md) is a single row shape consumers can
/// filter rather than a tagged union they must dispatch on.
#[derive(Clone, Debug)]
pub struct Event {
    pub ev: EventKind,
    pub name: &'static str,
    /// Microseconds since the recorder's epoch (span start for spans).
    pub ts_us: f64,
    /// Span duration in microseconds (spans only).
    pub dur_us: Option<f64>,
    pub lane: Lane,
    pub ctx: Ctx,
    /// Direction of a bit-flow event.
    pub dir: Option<Dir>,
    /// Message kind tag of a bit-flow event (`"model"`, `"hess_delta"`, ...).
    pub kind: Option<&'static str>,
    /// Float payload count of the message ([`crate::compressors::BitCost`]).
    pub floats: Option<f64>,
    /// Auxiliary bits of the message (indices, flags).
    pub aux_bits: Option<f64>,
    /// Total wire bits: `floats · float_bits + aux_bits`.
    pub bits: Option<f64>,
    /// Free-form annotation (marks).
    pub note: Option<String>,
}

impl Event {
    /// Render as one JSONL row. Absent optionals are omitted, not null, so
    /// rows stay small at per-message granularity.
    pub fn to_json(&self) -> Json {
        let mut kvs: Vec<(String, Json)> = vec![
            ("ev".into(), Json::str(self.ev.as_str())),
            ("name".into(), Json::str(self.name)),
            ("lane".into(), Json::str(self.lane.render())),
            ("ts_us".into(), Json::num(self.ts_us)),
        ];
        if let Some(d) = self.dur_us {
            kvs.push(("dur_us".into(), Json::num(d)));
        }
        if let Some(c) = self.ctx.cell {
            kvs.push(("cell".into(), Json::num(c as f64)));
        }
        if let Some(r) = self.ctx.round {
            kvs.push(("round".into(), Json::num(r as f64)));
        }
        if let Some(x) = self.ctx.exchange {
            kvs.push(("exchange".into(), Json::num(x as f64)));
        }
        if let Some(i) = self.ctx.client {
            kvs.push(("client".into(), Json::num(i as f64)));
        }
        if let Some(d) = self.dir {
            kvs.push(("dir".into(), Json::str(d.as_str())));
        }
        if let Some(k) = self.kind {
            kvs.push(("kind".into(), Json::str(k)));
        }
        if let Some(f) = self.floats {
            kvs.push(("floats".into(), Json::num(f)));
        }
        if let Some(a) = self.aux_bits {
            kvs.push(("aux_bits".into(), Json::num(a)));
        }
        if let Some(b) = self.bits {
            kvs.push(("bits".into(), Json::num(b)));
        }
        if let Some(n) = &self.note {
            kvs.push(("note".into(), Json::str(n.clone())));
        }
        Json::Obj(kvs)
    }
}

/// A trace event sink. Implementations must be cheap when disabled and
/// must never influence the run they observe (no panics, no blocking on
/// anything the run waits for).
pub trait Recorder: Sync {
    /// Whether events are consumed at all. Instrumentation sites gate every
    /// clock read and allocation on this, so a disabled recorder costs one
    /// branch per site.
    fn enabled(&self) -> bool;

    /// Microseconds since this recorder's epoch (monotonic across threads).
    fn now_us(&self) -> f64;

    /// Consume one event. Infallible by signature: I/O errors are latched
    /// internally and surfaced by [`Recorder::flush`].
    fn record(&self, ev: Event);

    /// Drain buffered events to durable storage; returns the first latched
    /// write error, if any.
    fn flush(&self) -> Result<()>;
}

/// The default recorder: drops everything, reads no clock.
pub struct NoopRecorder;

/// The shared no-op instance [`Obs::noop`] points at.
pub static NOOP: NoopRecorder = NoopRecorder;

impl Recorder for NoopRecorder {
    fn enabled(&self) -> bool {
        false
    }

    fn now_us(&self) -> f64 {
        0.0
    }

    fn record(&self, _ev: Event) {}

    fn flush(&self) -> Result<()> {
        Ok(())
    }
}

struct JsonlRecorderInner {
    sink: JsonlSink,
    /// First write error, latched; `record` goes quiet after it and
    /// `flush` reports it.
    err: Option<anyhow::Error>,
}

/// Durable trace sink: one JSONL row per event, buffered in memory and
/// written in large chunks (per-event fsync would dominate a traced run —
/// a single round emits one row per message per client). [`Self::flush`]
/// drains the buffer and fsyncs; call it once when the traced workload
/// ends. A crash mid-trace loses at most the buffered tail plus a torn
/// final line, exactly what [`crate::sweep::load_jsonl`] recovers from.
pub struct JsonlRecorder {
    epoch: Instant,
    inner: Mutex<JsonlRecorderInner>,
}

impl JsonlRecorder {
    /// Create (truncate) the trace file at `path`.
    pub fn create(path: &Path) -> Result<JsonlRecorder> {
        Ok(JsonlRecorder {
            epoch: Instant::now(),
            inner: Mutex::new(JsonlRecorderInner {
                sink: JsonlSink::create_buffered(path)?,
                err: None,
            }),
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, JsonlRecorderInner> {
        // A panic while holding the lock only poisons buffered trace rows,
        // never run state — keep recording.
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }
}

impl Recorder for JsonlRecorder {
    fn enabled(&self) -> bool {
        true
    }

    fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    fn record(&self, ev: Event) {
        let row = ev.to_json();
        let mut inner = self.lock();
        if inner.err.is_some() {
            return;
        }
        if let Err(e) = inner.sink.push(&row) {
            inner.err = Some(e);
        }
    }

    fn flush(&self) -> Result<()> {
        let mut inner = self.lock();
        if let Some(e) = inner.err.take() {
            return Err(e);
        }
        inner.sink.flush()
    }
}

/// A recorder view that stamps a sweep-cell id onto every event passing
/// through it, so one shared trace file can attribute events to cells no
/// matter how the executor interleaves them.
pub struct CellScope<'a> {
    inner: &'a dyn Recorder,
    cell: usize,
}

impl<'a> CellScope<'a> {
    pub fn new(inner: &'a dyn Recorder, cell: usize) -> CellScope<'a> {
        CellScope { inner, cell }
    }
}

impl Recorder for CellScope<'_> {
    fn enabled(&self) -> bool {
        self.inner.enabled()
    }

    fn now_us(&self) -> f64 {
        self.inner.now_us()
    }

    fn record(&self, mut ev: Event) {
        ev.ctx.cell = Some(self.cell);
        self.inner.record(ev);
    }

    fn flush(&self) -> Result<()> {
        self.inner.flush()
    }
}

/// The handle instrumented code carries: a copyable reference to a
/// recorder plus convenience constructors for the three event shapes.
/// `Copy` so it rides into scoped worker closures for free.
#[derive(Clone, Copy)]
pub struct Obs<'a> {
    pub rec: &'a dyn Recorder,
}

impl<'a> Obs<'a> {
    pub fn new(rec: &'a dyn Recorder) -> Obs<'a> {
        Obs { rec }
    }

    /// The zero-impact default handle.
    pub fn noop() -> Obs<'static> {
        Obs { rec: &NOOP }
    }

    pub fn enabled(&self) -> bool {
        self.rec.enabled()
    }

    pub fn now_us(&self) -> f64 {
        self.rec.now_us()
    }

    /// Open a timed span; the returned guard records it when dropped.
    /// Disabled recorders get an inert guard (no clock read).
    #[must_use = "the span is recorded when the guard drops — bind it (`let _span = ...`)"]
    pub fn span(&self, name: &'static str, lane: Lane, ctx: Ctx) -> SpanGuard<'a> {
        if !self.rec.enabled() {
            return SpanGuard { rec: None, start_us: 0.0, name, lane, ctx };
        }
        SpanGuard { rec: Some(self.rec), start_us: self.rec.now_us(), name, lane, ctx }
    }

    /// Record a span with explicit endpoints — for durations measured
    /// across threads (e.g. queue wait: enqueue stamped on the sender,
    /// dequeue observed on the worker).
    pub fn span_at(&self, name: &'static str, lane: Lane, ctx: Ctx, start_us: f64, end_us: f64) {
        if !self.rec.enabled() {
            return;
        }
        self.rec.record(Event {
            ev: EventKind::Span,
            name,
            ts_us: start_us,
            dur_us: Some((end_us - start_us).max(0.0)),
            lane,
            ctx,
            dir: None,
            kind: None,
            floats: None,
            aux_bits: None,
            bits: None,
            note: None,
        });
    }

    /// Emit one bit-flow event per message of a packet crossing the
    /// transport. `ctx.client` identifies the peer; `dir` the direction.
    pub fn packet(&self, dir: Dir, lane: Lane, ctx: Ctx, packet: &Packet, float_bits: u32) {
        if !self.rec.enabled() {
            return;
        }
        let ts_us = self.rec.now_us();
        for m in &packet.msgs {
            self.rec.record(Event {
                ev: EventKind::Bits,
                name: "msg",
                ts_us,
                dur_us: None,
                lane,
                ctx,
                dir: Some(dir),
                kind: Some(m.kind),
                floats: Some(m.cost.floats),
                aux_bits: Some(m.cost.aux_bits),
                bits: Some(m.cost.total_bits(float_bits)),
                note: None,
            });
        }
    }

    /// Record a point annotation.
    pub fn mark(&self, name: &'static str, lane: Lane, ctx: Ctx, note: Option<String>) {
        if !self.rec.enabled() {
            return;
        }
        self.rec.record(Event {
            ev: EventKind::Mark,
            name,
            ts_us: self.rec.now_us(),
            dur_us: None,
            lane,
            ctx,
            dir: None,
            kind: None,
            floats: None,
            aux_bits: None,
            bits: None,
            note,
        });
    }
}

/// RAII guard for a timed span: records the `Span` event on drop. Inert
/// (no event, no clock read) when the recorder is disabled.
pub struct SpanGuard<'a> {
    rec: Option<&'a dyn Recorder>,
    start_us: f64,
    name: &'static str,
    lane: Lane,
    ctx: Ctx,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let Some(rec) = self.rec else { return };
        let end_us = rec.now_us();
        rec.record(Event {
            ev: EventKind::Span,
            name: self.name,
            ts_us: self.start_us,
            dur_us: Some((end_us - self.start_us).max(0.0)),
            lane: self.lane,
            ctx: self.ctx,
            dir: None,
            kind: None,
            floats: None,
            aux_bits: None,
            bits: None,
            note: None,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::BitCost;
    use crate::sweep::load_jsonl;

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("bl_obs_rec_{tag}_{}", std::process::id()))
    }

    #[test]
    fn noop_is_disabled_and_silent() {
        let obs = Obs::noop();
        assert!(!obs.enabled());
        assert_eq!(obs.now_us(), 0.0);
        // None of these may panic or do anything observable.
        let _span = obs.span("x", Lane::Server, Ctx::default());
        obs.span_at("y", Lane::Client(0), Ctx::default(), 0.0, 1.0);
        obs.mark("z", Lane::Sweep(0), Ctx::default(), Some("note".into()));
        let mut p = Packet::empty();
        p.push_scalars("s", vec![1.0], BitCost::floats(1));
        obs.packet(Dir::Up, Lane::Server, Ctx::default(), &p, 64);
        NOOP.flush().unwrap();
    }

    #[test]
    fn jsonl_recorder_writes_all_event_shapes() {
        let path = tmp_path("shapes");
        let rec = JsonlRecorder::create(&path).unwrap();
        let obs = Obs::new(&rec);
        assert!(obs.enabled());
        {
            let _span = obs.span("plan", Lane::Server, Ctx::round(3, 0));
        }
        obs.mark("dataset_cache", Lane::Sweep(1), Ctx::default(), Some("hit".into()));
        let mut p = Packet::empty();
        p.push_scalars("shift_delta", vec![1.0, 2.0], BitCost::floats(2));
        p.push_flags("xi", vec![true], BitCost::bits(1.0));
        obs.packet(Dir::Up, Lane::Server, Ctx::client(3, 0, 2), &p, 64);
        rec.flush().unwrap();

        let load = load_jsonl(&path).unwrap();
        assert!(!load.torn_tail);
        assert_eq!(load.rows.len(), 4); // span + mark + 2 msgs
        let span = &load.rows[0];
        assert_eq!(span.get("ev").unwrap().as_str(), Some("span"));
        assert_eq!(span.get("name").unwrap().as_str(), Some("plan"));
        assert_eq!(span.get("lane").unwrap().as_str(), Some("server"));
        assert_eq!(span.get("round").unwrap().as_usize(), Some(3));
        assert!(span.get("dur_us").unwrap().as_f64().unwrap() >= 0.0);
        let mark = &load.rows[1];
        assert_eq!(mark.get("ev").unwrap().as_str(), Some("mark"));
        assert_eq!(mark.get("lane").unwrap().as_str(), Some("sweep:1"));
        assert_eq!(mark.get("note").unwrap().as_str(), Some("hit"));
        let msg = &load.rows[2];
        assert_eq!(msg.get("ev").unwrap().as_str(), Some("bits"));
        assert_eq!(msg.get("dir").unwrap().as_str(), Some("up"));
        assert_eq!(msg.get("kind").unwrap().as_str(), Some("shift_delta"));
        assert_eq!(msg.get("client").unwrap().as_usize(), Some(2));
        assert_eq!(msg.get("floats").unwrap().as_f64(), Some(2.0));
        assert_eq!(msg.get("bits").unwrap().as_f64(), Some(128.0));
        let flags = &load.rows[3];
        assert_eq!(flags.get("kind").unwrap().as_str(), Some("xi"));
        assert_eq!(flags.get("bits").unwrap().as_f64(), Some(1.0));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn cell_scope_stamps_cell_ids() {
        let path = tmp_path("cellscope");
        let rec = JsonlRecorder::create(&path).unwrap();
        let scoped = CellScope::new(&rec, 7);
        let obs = Obs::new(&scoped);
        obs.mark("dataset_cache", Lane::Sweep(0), Ctx::default(), None);
        {
            let _span = obs.span("compute", Lane::Client(1), Ctx::client(0, 0, 1));
        }
        rec.flush().unwrap();
        let load = load_jsonl(&path).unwrap();
        assert_eq!(load.rows.len(), 2);
        for row in &load.rows {
            assert_eq!(row.get("cell").unwrap().as_usize(), Some(7), "{row:?}");
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn timestamps_are_monotonic() {
        let path = tmp_path("mono");
        let rec = JsonlRecorder::create(&path).unwrap();
        let a = rec.now_us();
        let b = rec.now_us();
        assert!(b >= a && a >= 0.0);
        rec.flush().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
