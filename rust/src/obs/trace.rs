//! Reading traces back: parse the JSONL rows [`super::JsonlRecorder`]
//! wrote, summarize them into per-phase / per-message-kind / per-worker
//! tables (the `repro trace` subcommand), and export Chrome trace-event
//! JSON loadable in `chrome://tracing` or <https://ui.perfetto.dev>.

use crate::sweep::{load_jsonl, Json};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// One parsed trace event (the read-side mirror of [`super::Event`], with
/// owned strings — the writer's `&'static str` tags don't survive a file
/// round trip).
#[derive(Clone, Debug)]
pub struct TraceRow {
    pub ev: String,
    pub name: String,
    pub lane: String,
    pub ts_us: f64,
    pub dur_us: Option<f64>,
    pub cell: Option<usize>,
    pub round: Option<usize>,
    pub exchange: Option<usize>,
    pub client: Option<usize>,
    pub dir: Option<String>,
    pub kind: Option<String>,
    pub floats: Option<f64>,
    pub aux_bits: Option<f64>,
    pub bits: Option<f64>,
    pub note: Option<String>,
}

impl TraceRow {
    pub fn from_json(j: &Json) -> Result<TraceRow> {
        let req_str = |key: &str| -> Result<String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .with_context(|| format!("trace row missing string field '{key}': {}", j.render()))
        };
        let opt_str = |key: &str| j.get(key).and_then(Json::as_str).map(str::to_string);
        let opt_num = |key: &str| j.get(key).and_then(Json::as_f64);
        let opt_idx = |key: &str| j.get(key).and_then(Json::as_usize);
        Ok(TraceRow {
            ev: req_str("ev")?,
            name: req_str("name")?,
            lane: req_str("lane")?,
            ts_us: j
                .get("ts_us")
                .and_then(Json::as_f64)
                .with_context(|| format!("trace row missing 'ts_us': {}", j.render()))?,
            dur_us: opt_num("dur_us"),
            cell: opt_idx("cell"),
            round: opt_idx("round"),
            exchange: opt_idx("exchange"),
            client: opt_idx("client"),
            dir: opt_str("dir"),
            kind: opt_str("kind"),
            floats: opt_num("floats"),
            aux_bits: opt_num("aux_bits"),
            bits: opt_num("bits"),
            note: opt_str("note"),
        })
    }

    pub fn is_span(&self) -> bool {
        self.ev == "span"
    }

    pub fn is_bits(&self) -> bool {
        self.ev == "bits"
    }
}

/// A loaded trace file.
#[derive(Debug)]
pub struct TraceLoad {
    /// Events in file order (an arbitrary cross-thread interleaving; order
    /// by `ts_us` for timelines).
    pub rows: Vec<TraceRow>,
    /// Whether a torn final line (interrupted trace) was dropped.
    pub torn_tail: bool,
}

/// Load a trace JSONL file, tolerating the torn final line an interrupted
/// run leaves behind.
pub fn load_trace(path: &Path) -> Result<TraceLoad> {
    let load = load_jsonl(path)?;
    let rows = load
        .rows
        .iter()
        .map(TraceRow::from_json)
        .collect::<Result<Vec<_>>>()
        .with_context(|| format!("parsing trace {}", path.display()))?;
    Ok(TraceLoad { rows, torn_tail: load.torn_tail })
}

fn fmt_us(us: f64) -> String {
    if us >= 1e6 {
        format!("{:.2}s", us / 1e6)
    } else if us >= 1e3 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{us:.1}µs")
    }
}

/// Per-phase wall-time table: one row per span name, with count, total,
/// mean, and max. Lanes are aggregated (a `compute` row sums all clients).
pub fn phase_table(rows: &[TraceRow]) -> String {
    // name → (count, total_us, max_us)
    let mut phases: BTreeMap<&str, (usize, f64, f64)> = BTreeMap::new();
    for r in rows.iter().filter(|r| r.is_span()) {
        let dur = r.dur_us.unwrap_or(0.0);
        let e = phases.entry(r.name.as_str()).or_insert((0, 0.0, 0.0));
        e.0 += 1;
        e.1 += dur;
        e.2 = e.2.max(dur);
    }
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<12} {:>8} {:>12} {:>12} {:>12}",
        "phase", "count", "total", "mean", "max"
    );
    let mut ordered: Vec<_> = phases.into_iter().collect();
    // Largest total first — the table answers "where does the time go".
    ordered.sort_by(|a, b| b.1 .1.total_cmp(&a.1 .1));
    for (name, (count, total, max)) in ordered {
        let mean = total / count.max(1) as f64;
        let _ = writeln!(
            out,
            "{name:<12} {count:>8} {:>12} {:>12} {:>12}",
            fmt_us(total),
            fmt_us(mean),
            fmt_us(max)
        );
    }
    out
}

/// Per-message-kind bit-flow table: one row per (direction, kind), with
/// message count, float/aux split, total bits, and share of its direction.
pub fn bits_table(rows: &[TraceRow]) -> String {
    // (dir, kind) → (msgs, floats, aux_bits, bits)
    let mut flows: BTreeMap<(String, String), (usize, f64, f64, f64)> = BTreeMap::new();
    let mut dir_total: BTreeMap<String, f64> = BTreeMap::new();
    for r in rows.iter().filter(|r| r.is_bits()) {
        let dir = r.dir.clone().unwrap_or_default();
        let kind = r.kind.clone().unwrap_or_default();
        let bits = r.bits.unwrap_or(0.0);
        let e = flows.entry((dir.clone(), kind)).or_insert((0, 0.0, 0.0, 0.0));
        e.0 += 1;
        e.1 += r.floats.unwrap_or(0.0);
        e.2 += r.aux_bits.unwrap_or(0.0);
        e.3 += bits;
        *dir_total.entry(dir).or_insert(0.0) += bits;
    }
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<5} {:<14} {:>8} {:>14} {:>14} {:>14} {:>7}",
        "dir", "kind", "msgs", "floats", "aux_bits", "bits", "share"
    );
    let mut ordered: Vec<_> = flows.into_iter().collect();
    // Group by direction, then largest flow first within each direction.
    ordered.sort_by(|a, b| {
        (&a.0 .0, b.1 .3)
            .partial_cmp(&(&b.0 .0, a.1 .3))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    for ((dir, kind), (msgs, floats, aux, bits)) in ordered {
        let share = 100.0 * bits / dir_total.get(&dir).copied().unwrap_or(f64::INFINITY);
        let _ = writeln!(
            out,
            "{dir:<5} {kind:<14} {msgs:>8} {floats:>14.0} {aux:>14.0} {bits:>14.0} {share:>6.1}%"
        );
    }
    for (dir, total) in dir_total {
        let _ = writeln!(
            out,
            "{dir:<5} {:<14} {:>8} {:>14} {:>14} {total:>14.0}",
            "(total)", "", "", ""
        );
    }
    out
}

/// Sweep-worker utilization: per `sweep:<w>` lane, cells executed, busy
/// time (sum of `cell` spans), and busy share of the trace wall-clock.
/// Empty when the trace has no sweep lanes (plain `repro run --trace`).
pub fn worker_table(rows: &[TraceRow]) -> String {
    let spans: Vec<&TraceRow> = rows
        .iter()
        .filter(|r| r.is_span() && r.name == "cell" && r.lane.starts_with("sweep:"))
        .collect();
    if spans.is_empty() {
        return String::new();
    }
    let t0 = spans.iter().map(|r| r.ts_us).fold(f64::INFINITY, f64::min);
    let t1 = spans
        .iter()
        .map(|r| r.ts_us + r.dur_us.unwrap_or(0.0))
        .fold(f64::NEG_INFINITY, f64::max);
    let wall = (t1 - t0).max(1e-9);
    let mut workers: BTreeMap<&str, (usize, f64)> = BTreeMap::new();
    for r in &spans {
        let e = workers.entry(r.lane.as_str()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += r.dur_us.unwrap_or(0.0);
    }
    let mut out = String::new();
    let _ = writeln!(out, "{:<10} {:>8} {:>12} {:>8}", "worker", "cells", "busy", "util");
    for (lane, (cells, busy)) in workers {
        let _ = writeln!(
            out,
            "{lane:<10} {cells:>8} {:>12} {:>7.1}%",
            fmt_us(busy),
            100.0 * busy / wall
        );
    }
    out
}

/// Numeric thread id for a lane string, for the Chrome export: `server` →
/// 0, `client:i` → 1 + i, `sweep:w` → 10000 + w (far from any client id).
fn lane_tid(lane: &str) -> usize {
    if let Some(i) = lane.strip_prefix("client:").and_then(|s| s.parse::<usize>().ok()) {
        return 1 + i;
    }
    if let Some(w) = lane.strip_prefix("sweep:").and_then(|s| s.parse::<usize>().ok()) {
        return 10_000 + w;
    }
    0
}

/// Process id for the Chrome export: cell `c` → `c + 1`; events outside
/// any cell (plain runs, sweep-level marks) → 0.
fn row_pid(row: &TraceRow) -> usize {
    row.cell.map(|c| c + 1).unwrap_or(0)
}

fn obj(kvs: Vec<(&str, Json)>) -> Json {
    Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Export a trace as Chrome trace-event JSON (the `{"traceEvents": [...]}`
/// object form). Spans become complete (`"X"`) events, bit-flow events and
/// marks become instants (`"i"`), and each (pid, lane) pair gets a
/// `thread_name` metadata record so the timeline is labelled.
pub fn chrome_trace(rows: &[TraceRow]) -> String {
    let mut events: Vec<Json> = Vec::with_capacity(rows.len() + 16);
    let mut lanes: BTreeMap<(usize, usize), String> = BTreeMap::new();
    for r in rows {
        let pid = row_pid(r);
        let tid = lane_tid(&r.lane);
        lanes.entry((pid, tid)).or_insert_with(|| r.lane.clone());
        let mut args: Vec<(&str, Json)> = Vec::new();
        if let Some(c) = r.cell {
            args.push(("cell", Json::num(c as f64)));
        }
        if let Some(rnd) = r.round {
            args.push(("round", Json::num(rnd as f64)));
        }
        if let Some(x) = r.exchange {
            args.push(("exchange", Json::num(x as f64)));
        }
        if let Some(i) = r.client {
            args.push(("client", Json::num(i as f64)));
        }
        if let Some(b) = r.bits {
            args.push(("bits", Json::num(b)));
        }
        if let Some(f) = r.floats {
            args.push(("floats", Json::num(f)));
        }
        if let Some(a) = r.aux_bits {
            args.push(("aux_bits", Json::num(a)));
        }
        if let Some(n) = &r.note {
            args.push(("note", Json::str(n.clone())));
        }
        let name = match (&r.ev[..], &r.dir, &r.kind) {
            ("bits", Some(dir), Some(kind)) => format!("{kind} {dir}"),
            _ => r.name.clone(),
        };
        let mut ev: Vec<(&str, Json)> = vec![
            ("name", Json::str(name)),
            ("ph", Json::str(if r.is_span() { "X" } else { "i" })),
            ("ts", Json::num(r.ts_us)),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
        ];
        if r.is_span() {
            ev.push(("dur", Json::num(r.dur_us.unwrap_or(0.0))));
        } else {
            // Instant scope: thread.
            ev.push(("s", Json::str("t")));
        }
        ev.push(("args", obj(args)));
        events.push(obj(ev));
    }
    for ((pid, tid), lane) in lanes {
        events.push(obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("args", obj(vec![("name", Json::str(lane))])),
        ]));
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
    .render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(line: &str) -> TraceRow {
        TraceRow::from_json(&Json::parse(line).unwrap()).unwrap()
    }

    fn fixture() -> Vec<TraceRow> {
        vec![
            row(r#"{"ev":"mark","name":"run","lane":"server","ts_us":0,"note":"label=BL1"}"#),
            row(r#"{"ev":"span","name":"round","lane":"server","ts_us":1,"dur_us":100,"round":0}"#),
            row(concat!(
                r#"{"ev":"span","name":"plan","lane":"server","ts_us":2,"dur_us":10,"#,
                r#""round":0,"exchange":0}"#
            )),
            row(concat!(
                r#"{"ev":"bits","name":"msg","lane":"server","ts_us":13,"round":0,"#,
                r#""exchange":0,"client":1,"dir":"down","kind":"model","#,
                r#""floats":10,"aux_bits":0,"bits":640}"#
            )),
            row(concat!(
                r#"{"ev":"span","name":"compute","lane":"client:1","ts_us":15,"#,
                r#""dur_us":60,"round":0,"exchange":0,"client":1}"#
            )),
            row(concat!(
                r#"{"ev":"bits","name":"msg","lane":"server","ts_us":80,"round":0,"#,
                r#""exchange":0,"client":1,"dir":"up","kind":"hess_delta","#,
                r#""floats":4,"aux_bits":64,"bits":320}"#
            )),
            row(r#"{"ev":"span","name":"cell","lane":"sweep:0","ts_us":0,"dur_us":120,"cell":3}"#),
        ]
    }

    #[test]
    fn parse_requires_base_fields() {
        assert!(TraceRow::from_json(&Json::parse(r#"{"name":"x"}"#).unwrap()).is_err());
        assert!(TraceRow::from_json(
            &Json::parse(r#"{"ev":"span","name":"x","lane":"server"}"#).unwrap()
        )
        .is_err());
        let r = row(r#"{"ev":"span","name":"x","lane":"server","ts_us":1.5,"dur_us":2.5}"#);
        assert_eq!(r.ts_us, 1.5);
        assert_eq!(r.dur_us, Some(2.5));
        assert_eq!(r.cell, None);
    }

    #[test]
    fn tables_cover_all_shapes() {
        let rows = fixture();
        let phases = phase_table(&rows);
        assert!(phases.contains("round"), "{phases}");
        assert!(phases.contains("plan"), "{phases}");
        assert!(phases.contains("compute"), "{phases}");
        let bits = bits_table(&rows);
        assert!(bits.contains("model"), "{bits}");
        assert!(bits.contains("hess_delta"), "{bits}");
        assert!(bits.contains("640"), "{bits}");
        let workers = worker_table(&rows);
        assert!(workers.contains("sweep:0"), "{workers}");
        assert!(workers.contains("100.0%"), "{workers}");
        // No sweep lanes → empty worker table.
        assert!(worker_table(&rows[..6]).is_empty());
    }

    #[test]
    fn chrome_export_is_valid_json_with_matching_counts() {
        let rows = fixture();
        let text = chrome_trace(&rows);
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let count = |ph: &str| {
            events
                .iter()
                .filter(|e| e.get("ph").and_then(Json::as_str) == Some(ph))
                .count()
        };
        let spans = rows.iter().filter(|r| r.is_span()).count();
        let instants = rows.len() - spans;
        assert_eq!(count("X"), spans);
        assert_eq!(count("i"), instants);
        assert!(count("M") >= 3, "one thread_name per (pid, lane)");
        // Spans carry durations; instants carry the thread scope marker.
        for e in events {
            match e.get("ph").and_then(Json::as_str) {
                Some("X") => assert!(e.get("dur").is_some()),
                Some("i") => assert_eq!(e.get("s").and_then(Json::as_str), Some("t")),
                _ => {}
            }
        }
        // The cell span lands in pid 4 (cell 3 + 1), the rest in pid 0.
        let cell_ev = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("cell"))
            .unwrap();
        assert_eq!(cell_ev.get("pid").unwrap().as_usize(), Some(4));
    }
}
