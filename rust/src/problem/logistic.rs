//! Native logistic-regression local objective (the paper's experimental
//! problem, eq. 16 data term):
//!
//! `f_i(x) = (1/m) Σ_j log(1 + exp(−b_{ij} a_{ij}ᵀ x))`
//!
//! with gradient `(1/m) Aᵀ u`, `u_j = −b_j σ(−b_j z_j)`, and Hessian
//! `(1/m) Aᵀ diag(σ(z_j)σ(−z_j)) A` (`z = A x`). This Rust implementation is
//! the correctness oracle for the PJRT-backed path and the engine for the
//! CPU baselines; the hot Hessian assembly shares [`Mat::gram_scaled`] with
//! the benchmarks.

use super::{LocalProblem, OracleScratch};
use crate::linalg::{Mat, Vector};

/// Numerically-stable `log(1 + e^t)`.
#[inline]
pub fn log1p_exp(t: f64) -> f64 {
    if t > 0.0 {
        t + (-t).exp().ln_1p()
    } else {
        t.exp().ln_1p()
    }
}

/// Numerically-stable sigmoid `σ(t) = 1/(1+e^{−t})`.
#[inline]
pub fn sigmoid(t: f64) -> f64 {
    if t >= 0.0 {
        1.0 / (1.0 + (-t).exp())
    } else {
        let e = t.exp();
        e / (1.0 + e)
    }
}

/// One client's logistic-regression objective.
#[derive(Clone, Debug)]
pub struct LogisticProblem {
    a: Mat,
    b: Vec<f64>,
}

impl LogisticProblem {
    pub fn new(a: Mat, b: Vec<f64>) -> Self {
        assert_eq!(a.rows(), b.len(), "feature/label count mismatch");
        assert!(b.iter().all(|&x| x == 1.0 || x == -1.0), "labels must be ±1");
        LogisticProblem { a, b }
    }

    /// Borrow the feature matrix (used by basis extraction).
    pub fn features(&self) -> &Mat {
        &self.a
    }

    /// Borrow the labels.
    pub fn labels(&self) -> &[f64] {
        &self.b
    }

    /// Margins `z = A x`.
    fn margins(&self, x: &[f64]) -> Vector {
        self.a.matvec(x)
    }

    /// The Hessian's diagonal weights `σ(z)σ(−z) / m` at margins `z`
    /// (label-independent: `φ″(t) = σ(t)σ(−t)`).
    pub fn hess_weights(&self, x: &[f64]) -> Vector {
        let m = self.a.rows() as f64;
        self.margins(x)
            .into_iter()
            .map(|z| sigmoid(z) * sigmoid(-z) / m)
            .collect()
    }
}

impl LocalProblem for LogisticProblem {
    fn dim(&self) -> usize {
        self.a.cols()
    }

    fn n_points(&self) -> usize {
        self.a.rows()
    }

    fn loss(&self, x: &[f64]) -> f64 {
        let z = self.margins(x);
        let m = self.a.rows() as f64;
        z.iter()
            .zip(&self.b)
            .map(|(&zi, &bi)| log1p_exp(-bi * zi))
            .sum::<f64>()
            / m
    }

    fn grad(&self, x: &[f64]) -> Vector {
        let z = self.margins(x);
        let m = self.a.rows() as f64;
        let u: Vector = z
            .iter()
            .zip(&self.b)
            .map(|(&zi, &bi)| -bi * sigmoid(-bi * zi) / m)
            .collect();
        self.a.matvec_t(&u)
    }

    fn hess(&self, x: &[f64]) -> Mat {
        let w = self.hess_weights(x);
        self.a.gram_scaled(&w)
    }

    fn grad_into(&self, x: &[f64], out: &mut Vector, scratch: &mut OracleScratch) {
        self.a.matvec_into(x, &mut scratch.margins);
        let m = self.a.rows() as f64;
        scratch.weights.clear();
        scratch
            .weights
            .extend(scratch.margins.iter().zip(&self.b).map(|(&zi, &bi)| -bi * sigmoid(-bi * zi) / m));
        self.a.matvec_t_into(&scratch.weights, out);
    }

    fn hess_into(&self, x: &[f64], out: &mut Mat, scratch: &mut OracleScratch) {
        self.a.matvec_into(x, &mut scratch.margins);
        let m = self.a.rows() as f64;
        scratch.weights.clear();
        scratch
            .weights
            .extend(scratch.margins.iter().map(|&z| sigmoid(z) * sigmoid(-z) / m));
        self.a.gram_scaled_into(&scratch.weights, out);
    }

    fn hess_vec(&self, x: &[f64], v: &[f64]) -> Vector {
        // O(md): Aᵀ (w ⊙ (A v)) without materializing the Hessian.
        let w = self.hess_weights(x);
        let av = self.a.matvec(v);
        let wav: Vector = w.iter().zip(&av).map(|(wi, ai)| wi * ai).collect();
        self.a.matvec_t(&wav)
    }

    fn loss_grad(&self, x: &[f64]) -> (f64, Vector) {
        let z = self.margins(x);
        let m = self.a.rows() as f64;
        let mut loss = 0.0;
        let mut u = vec![0.0; z.len()];
        for (j, (&zj, &bj)) in z.iter().zip(&self.b).enumerate() {
            loss += log1p_exp(-bj * zj);
            u[j] = -bj * sigmoid(-bj * zj) / m;
        }
        (loss / m, self.a.matvec_t(&u))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::finite_diff_grad;
    use crate::rng::Rng;

    fn random_problem(m: usize, d: usize, seed: u64) -> LogisticProblem {
        let mut rng = Rng::new(seed);
        let a = Mat::from_fn(m, d, |_, _| rng.normal() / (d as f64).sqrt());
        let b: Vec<f64> = (0..m).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        LogisticProblem::new(a, b)
    }

    #[test]
    fn stable_helpers() {
        assert!((log1p_exp(0.0) - 2f64.ln()).abs() < 1e-15);
        assert!((log1p_exp(800.0) - 800.0).abs() < 1e-9); // no overflow
        assert!(log1p_exp(-800.0).abs() < 1e-300);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!((sigmoid(800.0) - 1.0).abs() < 1e-15);
        assert!(sigmoid(-800.0) >= 0.0);
        assert!((sigmoid(3.0) + sigmoid(-3.0) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn loss_at_zero_is_log2() {
        let p = random_problem(30, 5, 1);
        assert!((p.loss(&vec![0.0; 5]) - 2f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn gradient_matches_finite_diff() {
        let p = random_problem(25, 6, 2);
        let mut rng = Rng::new(3);
        let x: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let g = p.grad(&x);
        let fd = finite_diff_grad(&|y| p.loss(y), &x, 1e-6);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn hessian_matches_finite_diff_of_grad() {
        let p = random_problem(20, 5, 4);
        let x = vec![0.2, -0.1, 0.3, 0.0, -0.4];
        let h = p.hess(&x);
        assert!(h.is_symmetric(1e-12));
        let eps = 1e-6;
        for j in 0..5 {
            let mut xp = x.clone();
            xp[j] += eps;
            let gp = p.grad(&xp);
            xp[j] -= 2.0 * eps;
            let gm = p.grad(&xp);
            for i in 0..5 {
                let fd = (gp[i] - gm[i]) / (2.0 * eps);
                assert!((h[(i, j)] - fd).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn hessian_is_psd() {
        let p = random_problem(40, 7, 5);
        let x = vec![0.1; 7];
        let e = crate::linalg::sym_eigen(&p.hess(&x));
        assert!(e.values.iter().all(|&l| l >= -1e-12));
    }

    #[test]
    fn hess_vec_matches_dense() {
        let p = random_problem(15, 6, 6);
        let mut rng = Rng::new(7);
        let x: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let hv1 = p.hess_vec(&x, &v);
        let hv2 = p.hess(&x).matvec(&v);
        for (a, b) in hv1.iter().zip(&hv2) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn loss_grad_fused_matches_separate() {
        let p = random_problem(18, 4, 8);
        let x = vec![0.3, -0.2, 0.5, 0.1];
        let (l, g) = p.loss_grad(&x);
        assert!((l - p.loss(&x)).abs() < 1e-14);
        for (a, b) in g.iter().zip(&p.grad(&x)) {
            assert!((a - b).abs() < 1e-14);
        }
    }

    #[test]
    fn hessian_in_data_span() {
        // The data Hessian must lie in span{a_j a_jᵀ} — the §2.3 basis test.
        let mut rng = Rng::new(9);
        let d = 10;
        let v = crate::basis::subspace::orthonormal_cols(d, 3, &mut rng);
        let mut a = Mat::zeros(12, d);
        for i in 0..12 {
            let c: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
            a.row_mut(i).copy_from_slice(&v.matvec(&c));
        }
        let b: Vec<f64> = (0..12).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let p = LogisticProblem::new(a, b);
        let h = p.hess(&vec![0.05; d]);
        let basis = crate::basis::SubspaceBasis::new(v);
        use crate::basis::HessianBasis;
        let rec = basis.decode(&basis.encode(&h));
        assert!((&rec - &h).fro_norm() < 1e-10 * (1.0 + h.fro_norm()));
    }

    #[test]
    #[should_panic]
    fn rejects_bad_labels() {
        LogisticProblem::new(Mat::zeros(2, 2), vec![1.0, 0.5]);
    }
}
