//! Local objective oracles.
//!
//! [`LocalProblem`] is the interface the coordinator uses on each client:
//! loss / gradient / Hessian of the *data term* `f_i(x)` (eq. 2). Per the
//! paper's formulation (16), the ridge regularizer `λ/2‖x‖²` lives at the
//! global objective level and is added by the server — keeping local
//! Hessians inside the data subspace so the §2.3 basis stays lossless.
//!
//! Implementations:
//! * [`LogisticProblem`] — native Rust logistic regression (the correctness
//!   oracle and CPU baseline);
//! * [`QuadraticProblem`] — quadratics for tests (Newton converges in one
//!   step, closed-form optima);
//! * `crate::runtime::PjrtProblem` (behind the `pjrt` cargo feature) — the
//!   production path: loss/grad/Hess evaluated by the AOT-compiled
//!   JAX/Pallas artifacts through PJRT.

mod logistic;
mod quadratic;

pub use logistic::{log1p_exp, sigmoid, LogisticProblem};
pub use quadratic::QuadraticProblem;

use crate::linalg::{Mat, Vector};

/// Caller-owned scratch for the allocation-free oracle calls
/// ([`LocalProblem::grad_into`] / [`LocalProblem::hess_into`]).
#[derive(Default)]
pub struct OracleScratch {
    /// Margin buffer `z = A x` (length `m`).
    pub margins: Vec<f64>,
    /// Per-point weight buffer (length `m`).
    pub weights: Vec<f64>,
}

/// A client's local data objective `f_i`.
///
/// Deliberately not `Send`/`Sync`: the PJRT-backed implementation holds
/// non-thread-safe client handles, and the coordinator is single-threaded by
/// design (the "network" is simulated in-process).
pub trait LocalProblem {
    /// Model dimension `d`.
    fn dim(&self) -> usize;

    /// Number of local data points `m` (0 if not data-based).
    fn n_points(&self) -> usize;

    /// Local loss `f_i(x)`.
    fn loss(&self, x: &[f64]) -> f64;

    /// Local gradient `∇f_i(x)`.
    fn grad(&self, x: &[f64]) -> Vector;

    /// Local Hessian `∇²f_i(x)` (symmetric `d×d`).
    fn hess(&self, x: &[f64]) -> Mat;

    /// [`LocalProblem::grad`] into caller-owned storage. Implementations
    /// must produce bit-identical values; the default delegates (and
    /// therefore still allocates) — hot oracles override it.
    fn grad_into(&self, x: &[f64], out: &mut Vector, scratch: &mut OracleScratch) {
        let _ = scratch;
        let g = self.grad(x);
        out.clear();
        out.extend_from_slice(&g);
    }

    /// [`LocalProblem::hess`] into caller-owned storage (same bit-identity
    /// contract as [`LocalProblem::grad_into`]).
    fn hess_into(&self, x: &[f64], out: &mut Mat, scratch: &mut OracleScratch) {
        let _ = scratch;
        out.copy_from(&self.hess(x));
    }

    /// Hessian–vector product `∇²f_i(x)·v`. Default: materialize the
    /// Hessian; implementations override with the `O(md)` streaming form
    /// (DINGO and GIANT-style methods live on this).
    fn hess_vec(&self, x: &[f64], v: &[f64]) -> Vector {
        self.hess(x).matvec(v)
    }

    /// Fused loss+gradient (one data pass); default calls both.
    fn loss_grad(&self, x: &[f64]) -> (f64, Vector) {
        (self.loss(x), self.grad(x))
    }
}

/// Global objective helper: `f(x) = (1/n) Σ f_i(x) + λ/2 ‖x‖²` over a set of
/// local problems, as in eq. (16).
pub struct GlobalObjective<'a, P: LocalProblem + ?Sized> {
    pub locals: &'a [Box<P>],
    pub lambda: f64,
}

impl<'a, P: LocalProblem + ?Sized> GlobalObjective<'a, P> {
    pub fn new(locals: &'a [Box<P>], lambda: f64) -> Self {
        GlobalObjective { locals, lambda }
    }

    pub fn dim(&self) -> usize {
        self.locals.first().map(|p| p.dim()).unwrap_or(0)
    }

    pub fn loss(&self, x: &[f64]) -> f64 {
        let n = self.locals.len() as f64;
        let data: f64 = self.locals.iter().map(|p| p.loss(x)).sum::<f64>() / n;
        data + 0.5 * self.lambda * crate::linalg::norm2_sq(x)
    }

    pub fn grad(&self, x: &[f64]) -> Vector {
        let n = self.locals.len() as f64;
        let mut g = vec![0.0; self.dim()];
        for p in self.locals.iter() {
            crate::linalg::axpy(1.0 / n, &p.grad(x), &mut g);
        }
        crate::linalg::axpy(self.lambda, x, &mut g);
        g
    }

    pub fn hess(&self, x: &[f64]) -> Mat {
        let n = self.locals.len() as f64;
        let d = self.dim();
        let mut h = Mat::zeros(d, d);
        for p in self.locals.iter() {
            h.add_scaled(1.0 / n, &p.hess(x));
        }
        h.add_diag(self.lambda);
        h
    }

    /// Exact Newton step from `x` (used for the `f(x*)` reference and the
    /// naive-Newton baselines).
    pub fn newton_step(&self, x: &[f64]) -> anyhow::Result<Vector> {
        let g = self.grad(x);
        let h = self.hess(x);
        let step = crate::linalg::cholesky_solve(&h, &g)
            .or_else(|_| crate::linalg::lu_solve(&h, &g))?;
        Ok(crate::linalg::sub(x, &step))
    }

    /// The paper's `f(x*)` convention (§6): the loss after 20 Newton
    /// iterations from zero.
    pub fn reference_optimum(&self) -> anyhow::Result<(Vector, f64)> {
        let mut x = vec![0.0; self.dim()];
        for _ in 0..20 {
            x = self.newton_step(&x)?;
        }
        let f = self.loss(&x);
        Ok((x, f))
    }
}

/// Finite-difference gradient check helper, shared by the oracle tests.
#[cfg(test)]
pub(crate) fn finite_diff_grad(f: &dyn Fn(&[f64]) -> f64, x: &[f64], eps: f64) -> Vector {
    let mut g = vec![0.0; x.len()];
    let mut xp = x.to_vec();
    for i in 0..x.len() {
        let orig = xp[i];
        xp[i] = orig + eps;
        let fp = f(&xp);
        xp[i] = orig - eps;
        let fm = f(&xp);
        xp[i] = orig;
        g[i] = (fp - fm) / (2.0 * eps);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{FederatedDataset, SyntheticSpec};

    fn small_locals() -> Vec<Box<dyn LocalProblem>> {
        let fed = FederatedDataset::synthetic(&SyntheticSpec {
            n_clients: 3,
            m_per_client: 20,
            dim: 8,
            intrinsic_dim: 4,
            noise: 0.0,
            seed: 100,
        });
        fed.clients
            .iter()
            .map(|c| Box::new(LogisticProblem::new(c.a.clone(), c.b.clone())) as Box<dyn LocalProblem>)
            .collect()
    }

    #[test]
    fn global_gradient_matches_finite_diff() {
        let locals = small_locals();
        let obj = GlobalObjective::new(&locals, 1e-2);
        let x: Vec<f64> = (0..8).map(|i| 0.1 * (i as f64) - 0.3).collect();
        let g = obj.grad(&x);
        let fd = finite_diff_grad(&|y| obj.loss(y), &x, 1e-6);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn global_hessian_matches_grad_fd() {
        let locals = small_locals();
        let obj = GlobalObjective::new(&locals, 1e-2);
        let x: Vec<f64> = (0..8).map(|i| 0.05 * (i as f64)).collect();
        let h = obj.hess(&x);
        let eps = 1e-6;
        for j in 0..8 {
            let mut xp = x.clone();
            xp[j] += eps;
            let gp = obj.grad(&xp);
            xp[j] -= 2.0 * eps;
            let gm = obj.grad(&xp);
            for i in 0..8 {
                let fd = (gp[i] - gm[i]) / (2.0 * eps);
                assert!((h[(i, j)] - fd).abs() < 1e-5, "H[{i}{j}]={} fd={fd}", h[(i, j)]);
            }
        }
    }

    #[test]
    fn newton_converges_and_reference_optimum() {
        let locals = small_locals();
        let obj = GlobalObjective::new(&locals, 1e-2);
        let (xstar, fstar) = obj.reference_optimum().unwrap();
        // Gradient at the reference optimum is numerically zero.
        let g = obj.grad(&xstar);
        assert!(crate::linalg::norm2(&g) < 1e-10, "‖∇f(x*)‖={}", crate::linalg::norm2(&g));
        // And f* is a lower bound along random directions.
        let mut rng = crate::rng::Rng::new(3);
        for _ in 0..5 {
            let pert: Vec<f64> = xstar.iter().map(|v| v + 0.01 * rng.normal()).collect();
            assert!(obj.loss(&pert) >= fstar - 1e-12);
        }
    }
}
