//! Quadratic local objective for tests and ablations:
//! `f(x) = ½ xᵀQx − cᵀx` with SPD `Q`. Newton converges in one exact step,
//! making algorithm regressions easy to localize.

use super::LocalProblem;
use crate::linalg::{Mat, Vector};

/// `½ xᵀQx − cᵀx` with symmetric `Q`.
#[derive(Clone, Debug)]
pub struct QuadraticProblem {
    q: Mat,
    c: Vector,
}

impl QuadraticProblem {
    pub fn new(q: Mat, c: Vector) -> Self {
        assert!(q.is_square() && q.rows() == c.len());
        assert!(q.is_symmetric(1e-10), "Q must be symmetric");
        QuadraticProblem { q, c }
    }

    /// Closed-form minimizer `Q⁻¹ c` (requires SPD `Q`).
    pub fn minimizer(&self) -> anyhow::Result<Vector> {
        crate::linalg::cholesky_solve(&self.q, &self.c)
    }
}

impl LocalProblem for QuadraticProblem {
    fn dim(&self) -> usize {
        self.c.len()
    }

    fn n_points(&self) -> usize {
        0
    }

    fn loss(&self, x: &[f64]) -> f64 {
        0.5 * crate::linalg::dot(x, &self.q.matvec(x)) - crate::linalg::dot(&self.c, x)
    }

    fn grad(&self, x: &[f64]) -> Vector {
        crate::linalg::sub(&self.q.matvec(x), &self.c)
    }

    fn hess(&self, _x: &[f64]) -> Mat {
        self.q.clone()
    }

    fn hess_vec(&self, _x: &[f64], v: &[f64]) -> Vector {
        self.q.matvec(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut q = b.transpose().matmul(&b);
        q.add_diag(1.0);
        q
    }

    #[test]
    fn gradient_zero_at_minimizer() {
        let q = spd(6, 1);
        let c: Vec<f64> = (0..6).map(|i| i as f64 - 2.0).collect();
        let p = QuadraticProblem::new(q, c);
        let xstar = p.minimizer().unwrap();
        assert!(crate::linalg::norm2(&p.grad(&xstar)) < 1e-9);
    }

    #[test]
    fn hessian_constant() {
        let p = QuadraticProblem::new(spd(4, 2), vec![1.0; 4]);
        let h1 = p.hess(&vec![0.0; 4]);
        let h2 = p.hess(&vec![5.0; 4]);
        assert_eq!(h1, h2);
    }

    #[test]
    fn gradient_matches_finite_diff() {
        let p = QuadraticProblem::new(spd(5, 3), vec![0.5, -1.0, 2.0, 0.0, 1.0]);
        let x = vec![0.3, 0.1, -0.7, 0.9, -0.2];
        let g = p.grad(&x);
        let fd = crate::problem::finite_diff_grad(&|y| p.loss(y), &x, 1e-6);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
