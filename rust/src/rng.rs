//! Deterministic pseudo-random number generation.
//!
//! The crate registry available in this environment does not include `rand`,
//! so we implement a small, well-tested PRNG kit from scratch. Determinism is
//! a feature here: every experiment in the paper harness is reproducible from
//! a single `u64` seed, and client `i` of a run derives its stream as
//! `seed ⊕ splitmix(i)` so runs are independent of scheduling order.
//!
//! The generator is xoshiro256**, seeded through SplitMix64 (the construction
//! recommended by the xoshiro authors).

/// FNV-1a over a byte string — the crate's one string-hash primitive, used
/// for sweep cell-seed derivation and run-config fingerprints. Not a PRNG,
/// but it lives here with the other deterministic mixing primitives.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// SplitMix64 step — used for seeding and for deriving per-client streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Cheap, high quality, deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a sub-entity (client id, round, ...).
    pub fn derive(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller (cached second value not kept; fine for
    /// our workloads).
    pub fn normal(&mut self) -> f64 {
        // Rejection-free Box–Muller.
        let mut u1 = self.uniform();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform integer in `[0, n)` via Lemire's method (unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Bernoulli(p) draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample `k` distinct indices from `[0, n)` uniformly (partial
    /// Fisher–Yates). Output is in sampling order.
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} items from a population of {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// [`Rng::sample_without_replacement`] into caller-owned storage:
    /// identical draws, identical output, no allocation within capacity.
    pub fn sample_without_replacement_into(&mut self, n: usize, k: usize, idx: &mut Vec<usize>) {
        assert!(k <= n, "cannot sample {k} items from a population of {n}");
        idx.clear();
        idx.extend(0..n);
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn derive_streams_are_independent() {
        let root = Rng::new(7);
        let mut c0 = root.derive(0);
        let mut c1 = root.derive(1);
        let same = (0..64).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn sample_without_replacement_distinct_and_in_range() {
        let mut r = Rng::new(13);
        for _ in 0..100 {
            let k = 1 + r.below(20);
            let n = k + r.below(30);
            let s = r.sample_without_replacement(n, k);
            assert_eq!(s.len(), k);
            let mut seen = std::collections::HashSet::new();
            for &i in &s {
                assert!(i < n);
                assert!(seen.insert(i), "duplicate index {i}");
            }
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(17);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
