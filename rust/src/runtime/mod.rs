//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text,
//! emitted once at build time by `python/compile/aot.py`) and serves local
//! loss/gradient/Hessian evaluations on the coordinator's hot path.
//!
//! Python never runs here — the interchange is HLO *text* (see
//! DESIGN.md and `python/compile/aot.py`: serialized `HloModuleProto`s from
//! jax ≥ 0.5 carry 64-bit instruction ids that this XLA build rejects; the
//! text parser reassigns ids and round-trips cleanly).
//!
//! Artifact contract (produced by `make artifacts`):
//! * `artifacts/manifest.txt` — lines `entry m d filename`, `#` comments;
//! * `logreg_lossgrad_{m}x{d}.hlo.txt` — `(A[m,d], b[m], x[d]) → (loss, ∇f)`
//!   (fused single data pass, f64);
//! * `logreg_hess_{m}x{d}.hlo.txt` — `(A[m,d], x[d]) → (∇²f,)` whose inner
//!   scaled-Gram product is the L1 Pallas kernel.

mod pjrt_problem;

pub use pjrt_problem::PjrtProblem;

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A parsed manifest row.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub entry: String,
    pub m: usize,
    pub d: usize,
    pub file: String,
}

/// Parse `manifest.txt` content.
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 4 {
            bail!("manifest line {}: expected 'entry m d file', got '{line}'", lineno + 1);
        }
        out.push(ManifestEntry {
            entry: parts[0].to_string(),
            m: parts[1].parse().with_context(|| format!("manifest line {}: bad m", lineno + 1))?,
            d: parts[2].parse().with_context(|| format!("manifest line {}: bad d", lineno + 1))?,
            file: parts[3].to_string(),
        });
    }
    Ok(out)
}

/// The PJRT executor: one CPU client, one compiled executable per artifact.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: BTreeMap<(String, usize, usize), xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Runtime {
    /// Load every artifact listed in `dir/manifest.txt` and compile it on
    /// the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let entries = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = BTreeMap::new();
        for e in &entries {
            let path = dir.join(&e.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", e.file))?;
            exes.insert((e.entry.clone(), e.m, e.d), exe);
        }
        Ok(Runtime { client, exes, dir: dir.to_path_buf() })
    }

    /// Artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Which `(m, d)` shapes are available for an entry point.
    pub fn shapes(&self, entry: &str) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .exes
            .keys()
            .filter(|(e, _, _)| e == entry)
            .map(|&(_, m, d)| (m, d))
            .collect();
        v.sort_unstable();
        v
    }

    /// Does an executable exist for this entry/shape?
    pub fn has(&self, entry: &str, m: usize, d: usize) -> bool {
        self.exes.contains_key(&(entry.to_string(), m, d))
    }

    /// Execute an entry point. `inputs` are f64 literals; the result tuple
    /// is decomposed into its elements.
    pub fn execute(
        &self,
        entry: &str,
        m: usize,
        d: usize,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(&(entry.to_string(), m, d))
            .with_context(|| {
                format!(
                    "no artifact for entry '{entry}' at shape ({m}, {d}); available: {:?}",
                    self.shapes(entry)
                )
            })?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // Multi-output entries lower to a tuple ROOT; single-output entries
        // (e.g. the Hessian) lower to a bare array.
        if result.shape()?.is_tuple() {
            Ok(result.to_tuple()?)
        } else {
            Ok(vec![result])
        }
    }
}

/// Build an f64 literal from a flat slice with a shape.
pub fn literal_f64(data: &[f64], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        Ok(lit)
    } else {
        Ok(lit.reshape(dims)?)
    }
}

/// Read an f64 literal back into a Vec.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f64>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let text = "# artifacts\nlogreg_lossgrad 30 10 logreg_lossgrad_30x10.hlo.txt\nlogreg_hess 30 10 h.hlo.txt\n\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].entry, "logreg_lossgrad");
        assert_eq!(m[0].m, 30);
        assert_eq!(m[0].d, 10);
        assert_eq!(m[1].file, "h.hlo.txt");
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(parse_manifest("just three fields\n").is_err());
        assert!(parse_manifest("e x 10 f.txt\n").is_err());
    }

    #[test]
    fn runtime_load_missing_dir_errors_helpfully() {
        let err = match Runtime::load(Path::new("/nonexistent/artifacts")) {
            Err(e) => e,
            Ok(_) => panic!("load of a nonexistent dir must fail"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
