//! [`PjrtProblem`] — a [`LocalProblem`] whose loss/gradient/Hessian are
//! evaluated by the AOT-compiled JAX/Pallas artifacts through PJRT.
//!
//! This is the production three-layer path: the L3 coordinator calls into
//! this type on its hot loop; the computation was authored in JAX (L2)
//! calling Pallas kernels (L1) and lowered once at build time. The feature
//! matrix and labels are uploaded as literals once per client and reused
//! across every round.

use super::{literal_f64, literal_to_vec, Runtime};
use crate::linalg::{Mat, Vector};
use crate::problem::LocalProblem;
use std::rc::Rc;

/// PJRT-backed logistic-regression local objective.
pub struct PjrtProblem {
    rt: Rc<Runtime>,
    /// Pre-built input literals for the data (uploaded once).
    a_lit: xla::Literal,
    b_lit: xla::Literal,
    /// Kept for basis extraction and fallbacks.
    a: Mat,
    m: usize,
    d: usize,
}

impl PjrtProblem {
    /// Wrap one client's shard. Fails if no artifact matches the shard's
    /// `(m, d)` shape.
    pub fn new(rt: Rc<Runtime>, a: Mat, b: Vec<f64>) -> anyhow::Result<Self> {
        let (m, d) = (a.rows(), a.cols());
        anyhow::ensure!(b.len() == m, "label count mismatch");
        anyhow::ensure!(
            rt.has("logreg_lossgrad", m, d) && rt.has("logreg_hess", m, d),
            "no artifacts for shape ({m}, {d}); available lossgrad shapes: {:?} — \
             add the shape to python/compile/aot.py SHAPES and re-run `make artifacts`",
            rt.shapes("logreg_lossgrad")
        );
        let a_lit = literal_f64(a.data(), &[m as i64, d as i64])?;
        let b_lit = literal_f64(&b, &[m as i64])?;
        Ok(PjrtProblem { rt, a_lit, b_lit, a, m, d })
    }

    /// The raw feature matrix (for subspace-basis extraction).
    pub fn features(&self) -> &Mat {
        &self.a
    }

    fn x_lit(&self, x: &[f64]) -> xla::Literal {
        // audit:allow(panic-safety): building a rank-1 f64 literal from a slice is infallible in the xla API.
        literal_f64(x, &[self.d as i64]).expect("1-D literal cannot fail")
    }
}

impl LocalProblem for PjrtProblem {
    fn dim(&self) -> usize {
        self.d
    }

    fn n_points(&self) -> usize {
        self.m
    }

    fn loss(&self, x: &[f64]) -> f64 {
        self.loss_grad(x).0
    }

    fn grad(&self, x: &[f64]) -> Vector {
        self.loss_grad(x).1
    }

    fn loss_grad(&self, x: &[f64]) -> (f64, Vector) {
        let out = self
            .rt
            .execute(
                "logreg_lossgrad",
                self.m,
                self.d,
                &[self.a_lit.clone(), self.b_lit.clone(), self.x_lit(x)],
            )
            // audit:allow(panic-safety): LocalProblem::loss_grad returns plain values; a PJRT executor failure after successful load is unrecoverable.
            .expect("PJRT lossgrad execution failed");
        // audit:allow(panic-safety): readback of literals the executor just produced.
        let loss = literal_to_vec(&out[0]).expect("loss readback")[0];
        // audit:allow(panic-safety): readback of literals the executor just produced.
        let grad = literal_to_vec(&out[1]).expect("grad readback");
        (loss, grad)
    }

    fn hess(&self, x: &[f64]) -> Mat {
        let out = self
            .rt
            .execute("logreg_hess", self.m, self.d, &[self.a_lit.clone(), self.x_lit(x)])
            // audit:allow(panic-safety): LocalProblem::hess returns a plain Mat; a PJRT executor failure after successful load is unrecoverable.
            .expect("PJRT hess execution failed");
        // audit:allow(panic-safety): readback of a literal the executor just produced.
        let data = literal_to_vec(&out[0]).expect("hess readback");
        let mut h = Mat::from_vec(self.d, self.d, data);
        // Enforce exact symmetry (XLA accumulation order can differ by ulps).
        h.symmetrize();
        h
    }
}

// PJRT execution tests live in `rust/tests/pjrt_integration.rs` (they need
// `make artifacts` to have run; the Makefile orders them correctly).
