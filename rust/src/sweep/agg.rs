//! Cross-seed aggregation and ranking of sweep results, plus the JSONL row
//! builders for the result sink and the resume planner.
//!
//! Cells that differ only in the seed axis share a `group` key; aggregation
//! reduces each group to mean/std of bits-to-target-gap (over the seeds that
//! reached each target), reach counts, and a mean final gap. Everything is
//! computed in declaration order from per-run quantities that are themselves
//! deterministic, so rendered summaries are byte-identical across `--jobs`
//! levels.
//!
//! Aggregation consumes [`RunRow`]s — the per-run slice of a `runs.jsonl`
//! row that feeds the statistics. A `RunRow` comes either fresh from an
//! executed [`CellResult`] or parsed back from disk ([`RunRow::from_json`]);
//! because the JSONL number format round-trips `f64`s exactly, both sources
//! aggregate to identical bytes. [`plan_resume`] diffs the current grid
//! expansion against loaded rows by [`SweepCell::key`] and schedules only
//! the missing or previously failed cells.

use super::exec::{CellResult, CellStatus};
use super::jsonl::Json;
use super::spec::SweepCell;
use anyhow::{Context, Result};
use std::cmp::Ordering;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Aggregate of one sweep group (same coordinates, all seeds).
#[derive(Clone, Debug, PartialEq)]
pub struct GroupSummary {
    pub group: String,
    /// Seed-axis size (runs attempted).
    pub n_runs: usize,
    /// Runs that completed without error/panic.
    pub n_ok: usize,
    /// Mean final gap over ok runs (`None` if none succeeded).
    pub final_gap_mean: Option<f64>,
    /// One aggregate per requested gap target, in target order.
    pub per_target: Vec<TargetAgg>,
}

/// Bits-to-reach aggregate for one gap target.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TargetAgg {
    pub target: f64,
    /// How many of the group's runs reached the target.
    pub reached: usize,
    /// Mean total (up+down+setup) bits/node over the runs that reached it.
    pub bits_mean: Option<f64>,
    /// Population standard deviation over the same runs.
    pub bits_std: Option<f64>,
}

fn mean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        None
    } else {
        Some(xs.iter().sum::<f64>() / xs.len() as f64)
    }
}

fn pop_std(xs: &[f64]) -> Option<f64> {
    let m = mean(xs)?;
    let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64;
    Some(var.sqrt())
}

/// One run's aggregation-relevant slice: what `runs.jsonl` stores per cell.
/// Built fresh from an executed [`CellResult`] ([`RunRow::from_result`]) or
/// recovered from disk ([`RunRow::from_json`]) when a sweep resumes.
#[derive(Clone, Debug, PartialEq)]
pub struct RunRow {
    /// Declaration-order cell id. [`plan_resume`] remaps ids loaded from
    /// disk onto the *current* expansion, so merged row sets sort back into
    /// declaration order regardless of completion order.
    pub id: usize,
    pub group: String,
    /// Seed-axis value (together with `group`: the stable cell key).
    pub data_seed: u64,
    /// Whether the run completed without error/panic.
    pub ok: bool,
    /// Fingerprint of the `RunConfig` the row was recorded under (0 when
    /// the row predates the field — such rows are never resumed).
    pub cfg_hash: u64,
    /// Final optimality gap (`None` for failed runs).
    pub final_gap: Option<f64>,
    /// `(gap target, total bits/node to first reach it)` in sweep-target
    /// order; `None` bits ⇒ target never reached.
    pub bits_to: Vec<(f64, Option<f64>)>,
}

impl RunRow {
    /// The stable cell key — matches [`SweepCell::key`].
    pub fn key(&self) -> String {
        format!("{} seed={}", self.group, self.data_seed)
    }

    /// Condense an executed result. Non-finite gaps are normalized to
    /// `None` so fresh rows and disk-parsed rows (where non-finite numbers
    /// serialize as `null`) aggregate identically.
    pub fn from_result(res: &CellResult, targets: &[f64]) -> RunRow {
        // Failed runs record no bits at all — matching their serialized
        // form, which omits the `bits_to` field entirely.
        let (final_gap, bits_to) = match res.history.as_ref() {
            Some(h) => (
                Some(h.final_gap()).filter(|g| g.is_finite()),
                targets.iter().map(|&t| (t, h.bits_to_reach(t))).collect(),
            ),
            None => (None, Vec::new()),
        };
        RunRow {
            id: res.id,
            group: res.group.clone(),
            data_seed: res.data_seed,
            ok: res.status.is_ok(),
            cfg_hash: res.cfg_hash,
            final_gap,
            bits_to,
        }
    }

    /// Parse a `runs.jsonl` row back (the inverse of [`run_row`] for the
    /// aggregation-relevant fields; extra fields are ignored).
    pub fn from_json(j: &Json) -> Result<RunRow> {
        let field = |k: &str| j.get(k).with_context(|| format!("run row missing '{k}'"));
        let group = field("group")?.as_str().context("'group' not a string")?.to_string();
        let data_seed = field("seed")?.as_usize().context("'seed' not a count")? as u64;
        let ok = field("status")?.as_str().context("'status' not a string")? == "ok";
        let id = field("cell")?.as_usize().context("'cell' not a count")?;
        // Absent/malformed fingerprints parse as 0: the row still aggregates
        // but can never match a real cell fingerprint, so it re-runs.
        let cfg_hash = j
            .get("cfg")
            .and_then(Json::as_str)
            .and_then(|s| u64::from_str_radix(s.trim_start_matches("0x"), 16).ok())
            .unwrap_or(0);
        let final_gap = j.get("final_gap").and_then(Json::as_f64);
        let bits_to = match j.get("bits_to") {
            None => Vec::new(),
            Some(arr) => arr
                .as_arr()
                .context("'bits_to' not an array")?
                .iter()
                .map(|t| {
                    let target = t
                        .get("target")
                        .and_then(Json::as_f64)
                        .context("bits_to entry missing 'target'")?;
                    Ok((target, t.get("total").and_then(Json::as_f64)))
                })
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(RunRow { id, group, data_seed, ok, cfg_hash, final_gap, bits_to })
    }

    /// Total bits to the given target (`None` if unreached or unrecorded).
    pub fn bits_for(&self, target: f64) -> Option<f64> {
        self.bits_to.iter().find(|(t, _)| *t == target).and_then(|(_, b)| *b)
    }

    /// Whether the row records every target in `targets` — guards resume
    /// against rows written under a different target set (exact `f64`
    /// comparison is sound because rendering round-trips exactly).
    pub fn covers(&self, targets: &[f64]) -> bool {
        targets.iter().all(|t| self.bits_to.iter().any(|(tt, _)| tt == t))
    }
}

/// Condense executed results (already in declaration order) to rows.
pub fn rows_from_results(results: &[CellResult], targets: &[f64]) -> Vec<RunRow> {
    results.iter().map(|r| RunRow::from_result(r, targets)).collect()
}

/// Reduce per-run rows (in declaration order — sort merged sets by
/// [`RunRow::id`] first) to per-group summaries. Groups appear in
/// first-declaration order.
pub fn aggregate(rows: &[RunRow], targets: &[f64]) -> Vec<GroupSummary> {
    let mut order: Vec<&str> = Vec::new();
    let mut buckets: BTreeMap<&str, Vec<&RunRow>> = BTreeMap::new();
    for r in rows {
        let entry = buckets.entry(r.group.as_str()).or_default();
        if entry.is_empty() {
            order.push(r.group.as_str());
        }
        entry.push(r);
    }
    order
        .iter()
        .map(|g| {
            let runs = &buckets[g];
            let ok: Vec<&&RunRow> = runs.iter().filter(|r| r.ok).collect();
            let gaps: Vec<f64> = ok.iter().filter_map(|r| r.final_gap).collect();
            let per_target = targets
                .iter()
                .map(|&t| {
                    let bits: Vec<f64> = ok.iter().filter_map(|r| r.bits_for(t)).collect();
                    TargetAgg {
                        target: t,
                        reached: bits.len(),
                        bits_mean: mean(&bits),
                        bits_std: pop_std(&bits),
                    }
                })
                .collect();
            GroupSummary {
                group: g.to_string(),
                n_runs: runs.len(),
                n_ok: ok.len(),
                final_gap_mean: mean(&gaps),
                per_target,
            }
        })
        .collect()
}

/// What a resumed sweep keeps versus re-runs.
#[derive(Clone, Debug)]
pub struct ResumePlan {
    /// Prior successful rows matching a current cell, ids remapped onto the
    /// current expansion, in declaration order. Merge these with fresh
    /// results before aggregating.
    pub done: Vec<RunRow>,
    /// For each entry of `done`, the index into the `prior` slice of the
    /// row that backs it — so callers compacting the on-disk file keep
    /// exactly the rows this plan selected, not merely the latest row per
    /// key (which could differ when an ok row is shadowed by a later
    /// failed one).
    pub kept_prior: Vec<usize>,
    /// Cells still to execute: never ran, previously failed, recorded
    /// under a different target set, or recorded under a different
    /// run configuration.
    pub todo: Vec<SweepCell>,
}

/// Diff the current expansion against rows recovered from `runs.jsonl`.
/// Matching is by the stable cell key *plus* the cell's full `RunConfig`
/// fingerprint — the group string only encodes the axis coordinates, so
/// without the fingerprint a resume with changed shared parameters
/// (`--rounds`, `--lambda`, `--target-gap`, `--max-bits`, `--master-seed`,
/// ...) would silently reuse rows computed under the old ones. When a key
/// appears more than once (an earlier resume re-ran a failed cell), the
/// last occurrence wins.
pub fn plan_resume(cells: &[SweepCell], prior: &[RunRow], targets: &[f64]) -> ResumePlan {
    let by_key: BTreeMap<String, (usize, u64)> =
        cells.iter().map(|c| (c.key(), (c.id, c.cfg.fingerprint()))).collect();
    let mut done: BTreeMap<usize, (usize, RunRow)> = BTreeMap::new();
    for (i, r) in prior.iter().enumerate() {
        if !r.ok || !r.covers(targets) {
            continue;
        }
        if let Some(&(id, fingerprint)) = by_key.get(&r.key()) {
            if r.cfg_hash != fingerprint {
                continue; // same coordinates, different run parameters
            }
            let mut row = r.clone();
            row.id = id;
            done.insert(id, (i, row));
        }
    }
    let todo: Vec<SweepCell> =
        cells.iter().filter(|c| !done.contains_key(&c.id)).cloned().collect();
    let mut pairs: Vec<(usize, RunRow)> = done.into_values().collect();
    pairs.sort_by_key(|(_, r)| r.id);
    let (kept_prior, done): (Vec<usize>, Vec<RunRow>) = pairs.into_iter().unzip();
    ResumePlan { done, kept_prior, todo }
}

fn cmp_opt(a: Option<f64>, b: Option<f64>) -> Ordering {
    match (a, b) {
        (Some(x), Some(y)) => x.partial_cmp(&y).unwrap_or(Ordering::Equal),
        (Some(_), None) => Ordering::Less, // reaching at all beats not reaching
        (None, Some(_)) => Ordering::Greater,
        (None, None) => Ordering::Equal,
    }
}

/// Best-cell ranking: indices into `summaries`, best first. A group is
/// better if it gets more seeds to the *strictest* target, then needs fewer
/// mean bits to get there; ties fall through to looser targets and finally
/// to the group name (total order ⇒ deterministic output).
pub fn ranked(summaries: &[GroupSummary]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..summaries.len()).collect();
    idx.sort_by(|&a, &b| {
        let (ga, gb) = (&summaries[a], &summaries[b]);
        let n = ga.per_target.len().min(gb.per_target.len());
        // Strictest target is last in SWEEP_TARGETS order.
        for t in (0..n).rev() {
            let (ta, tb) = (&ga.per_target[t], &gb.per_target[t]);
            let by_reached = tb.reached.cmp(&ta.reached);
            if by_reached != Ordering::Equal {
                return by_reached;
            }
            let by_bits = cmp_opt(ta.bits_mean, tb.bits_mean);
            if by_bits != Ordering::Equal {
                return by_bits;
            }
        }
        ga.group.cmp(&gb.group)
    });
    idx
}

/// JSONL row for one executed run (the streaming `runs.jsonl` sink).
pub fn run_row(res: &CellResult, targets: &[f64]) -> Json {
    let mut kvs: Vec<(String, Json)> = vec![
        ("cell".into(), Json::num(res.id as f64)),
        ("group".into(), Json::str(res.group.clone())),
        ("dataset".into(), Json::str(res.dataset.clone())),
        ("seed".into(), Json::num(res.data_seed as f64)),
        ("rng_seed".into(), Json::str(format!("{:#018x}", res.rng_seed))),
        ("cfg".into(), Json::str(format!("{:#018x}", res.cfg_hash))),
        (
            "status".into(),
            Json::str(match &res.status {
                CellStatus::Ok => "ok",
                CellStatus::Failed(_) => "failed",
            }),
        ),
    ];
    if let CellStatus::Failed(msg) = &res.status {
        kvs.push(("error".into(), Json::str(msg.clone())));
    }
    if let Some(s) = res.summary(targets) {
        kvs.push(("label".into(), Json::str(s.label)));
        kvs.push(("rounds".into(), Json::num(s.rounds as f64)));
        kvs.push(("final_gap".into(), Json::num(s.final_gap)));
        kvs.push(("bits_per_node".into(), Json::num(s.bits_per_node)));
        kvs.push(("bits_up_per_node".into(), Json::num(s.bits_up_per_node)));
        kvs.push((
            "bits_to".into(),
            Json::Arr(
                s.bits_to_targets
                    .iter()
                    .map(|t| {
                        Json::Obj(vec![
                            ("target".into(), Json::num(t.target)),
                            ("total".into(), Json::opt_num(t.total)),
                            ("uplink".into(), Json::opt_num(t.uplink)),
                        ])
                    })
                    .collect(),
            ),
        ));
    }
    kvs.push(("wall_ms".into(), Json::num(res.wall_ms)));
    Json::Obj(kvs)
}

impl GroupSummary {
    /// Serialize one summary row (the `summary.jsonl` sink).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("group".into(), Json::str(self.group.clone())),
            ("n_runs".into(), Json::num(self.n_runs as f64)),
            ("n_ok".into(), Json::num(self.n_ok as f64)),
            ("final_gap_mean".into(), Json::opt_num(self.final_gap_mean)),
            (
                "targets".into(),
                Json::Arr(
                    self.per_target
                        .iter()
                        .map(|t| {
                            Json::Obj(vec![
                                ("target".into(), Json::num(t.target)),
                                ("reached".into(), Json::num(t.reached as f64)),
                                ("bits_mean".into(), Json::opt_num(t.bits_mean)),
                                ("bits_std".into(), Json::opt_num(t.bits_std)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a summary row back (ignores unknown fields such as `rank`).
    pub fn from_json(j: &Json) -> Result<GroupSummary> {
        let field = |k: &str| j.get(k).with_context(|| format!("summary row missing '{k}'"));
        let group = field("group")?.as_str().context("'group' not a string")?.to_string();
        let n_runs = field("n_runs")?.as_usize().context("'n_runs' not a count")?;
        let n_ok = field("n_ok")?.as_usize().context("'n_ok' not a count")?;
        let final_gap_mean = field("final_gap_mean")?.as_f64();
        let per_target = field("targets")?
            .as_arr()
            .context("'targets' not an array")?
            .iter()
            .map(|t| {
                let tf = |k: &str| {
                    t.get(k).with_context(|| format!("target aggregate missing '{k}'"))
                };
                Ok(TargetAgg {
                    target: tf("target")?.as_f64().context("'target' not a number")?,
                    reached: tf("reached")?.as_usize().context("'reached' not a count")?,
                    bits_mean: tf("bits_mean")?.as_f64(),
                    bits_std: tf("bits_std")?.as_f64(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(GroupSummary { group, n_runs, n_ok, final_gap_mean, per_target })
    }
}

/// Render the ranked `summary.jsonl` text: one [`GroupSummary`] row per
/// line, best-first, with its 1-based `rank` injected. Both the fresh and
/// the resume path go through this, which is what the byte-identity
/// guarantee of resumed sweeps rests on.
pub fn summary_jsonl(summaries: &[GroupSummary], order: &[usize]) -> String {
    let mut text = String::new();
    for (pos, &i) in order.iter().enumerate() {
        let mut row = summaries[i].to_json();
        if let Json::Obj(kvs) = &mut row {
            kvs.insert(0, ("rank".into(), Json::num((pos + 1) as f64)));
        }
        text.push_str(&row.render());
        text.push('\n');
    }
    text
}

/// Terminal leaderboard for the end of a sweep.
pub fn summary_table(summaries: &[GroupSummary], order: &[usize]) -> String {
    let mut s = format!(
        "{:<4} {:<58} {:>6} {:>22} {:>14}\n",
        "rank", "cell", "ok", "bits@strictest (mean)", "final gap"
    );
    for (pos, &i) in order.iter().enumerate() {
        let g = &summaries[i];
        let strictest = g.per_target.last();
        let bits = strictest
            .and_then(|t| t.bits_mean.map(|m| format!("{m:.3e} (n={})", t.reached)))
            .unwrap_or_else(|| "—".into());
        let gap = g
            .final_gap_mean
            .map(|x| format!("{x:.2e}"))
            .unwrap_or_else(|| "—".into());
        let _ = writeln!(
            s,
            "{:<4} {:<58} {:>3}/{:<2} {:>22} {:>14}",
            pos + 1,
            g.group,
            g.n_ok,
            g.n_runs,
            bits,
            gap
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{History, RoundRecord};

    fn fake_result(id: usize, group: &str, seed: u64, gaps: &[f64]) -> CellResult {
        let mut h = History::new(group);
        for (i, &gap) in gaps.iter().enumerate() {
            h.push(RoundRecord {
                round: i,
                bits_up_per_node: 100.0 * (i + 1) as f64,
                bits_down_per_node: 0.0,
                gap,
                grad_norm: gap,
                dist_to_opt: gap,
            });
        }
        CellResult {
            id,
            group: group.into(),
            data_seed: seed,
            rng_seed: seed.wrapping_mul(0x9E37),
            dataset: "t".into(),
            status: CellStatus::Ok,
            // Matches the `cell()` helper below, which runs default configs.
            cfg_hash: crate::config::RunConfig::default().fingerprint(),
            history: Some(h),
            wall_ms: 1.0,
            dataset_cache_hit: false,
        }
    }

    fn failed_result(id: usize, group: &str, seed: u64) -> CellResult {
        CellResult {
            id,
            group: group.into(),
            data_seed: seed,
            rng_seed: 0,
            dataset: "t".into(),
            status: CellStatus::Failed("boom".into()),
            cfg_hash: crate::config::RunConfig::default().fingerprint(),
            history: None,
            wall_ms: 1.0,
            dataset_cache_hit: false,
        }
    }

    const T: [f64; 2] = [1e-2, 1e-6];

    #[test]
    fn aggregate_means_and_stds() {
        let results = vec![
            fake_result(0, "a", 1, &[1.0, 1e-3, 1e-7]), // reaches both at 200/300 bits
            fake_result(1, "a", 2, &[1.0, 1e-3, 1e-3]), // reaches 1e-2 at 200, never 1e-6
            failed_result(2, "a", 3),
            fake_result(3, "b", 1, &[1e-7]), // both targets at 100 bits
        ];
        let s = aggregate(&rows_from_results(&results, &T), &T);
        assert_eq!(s.len(), 2);
        let a = &s[0];
        assert_eq!(a.group, "a");
        assert_eq!(a.n_runs, 3);
        assert_eq!(a.n_ok, 2);
        assert_eq!(a.per_target[0].reached, 2);
        assert_eq!(a.per_target[0].bits_mean, Some(200.0));
        assert_eq!(a.per_target[0].bits_std, Some(0.0));
        assert_eq!(a.per_target[1].reached, 1);
        assert_eq!(a.per_target[1].bits_mean, Some(300.0));
        let gap_mean = (1e-7 + 1e-3) / 2.0;
        assert!((a.final_gap_mean.unwrap() - gap_mean).abs() < 1e-15);
        let b = &s[1];
        assert_eq!(b.n_runs, 1);
        assert_eq!(b.per_target[1].bits_mean, Some(100.0));
    }

    #[test]
    fn ranking_prefers_reach_then_bits() {
        let results = vec![
            fake_result(0, "slow-but-reaches", 1, &[1.0, 1e-3, 1e-3, 1e-3, 1e-7]), // 500 bits
            fake_result(1, "fast", 1, &[1e-7]),                                    // 100 bits
            fake_result(2, "never", 1, &[1.0, 1e-3]),
        ];
        let s = aggregate(&rows_from_results(&results, &T), &T);
        let order = ranked(&s);
        assert_eq!(s[order[0]].group, "fast");
        assert_eq!(s[order[1]].group, "slow-but-reaches");
        assert_eq!(s[order[2]].group, "never");
        let table = summary_table(&s, &order);
        assert!(table.contains("fast"));
        assert_eq!(table.lines().count(), 4);
    }

    #[test]
    fn summary_rows_roundtrip_through_jsonl() {
        let results = vec![
            fake_result(0, "a", 1, &[1.0, 1e-3, 1e-7]),
            fake_result(1, "a", 2, &[1.0, 1e-4, 1e-8]),
            failed_result(2, "b", 1),
        ];
        let summaries = aggregate(&rows_from_results(&results, &T), &T);
        for s in &summaries {
            let line = s.to_json().render();
            let parsed = GroupSummary::from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(&parsed, s);
            // Render → parse → render is byte-stable.
            assert_eq!(parsed.to_json().render(), line);
        }
        // Unknown fields (e.g. an injected rank) are tolerated.
        let mut j = summaries[0].to_json();
        if let Json::Obj(kvs) = &mut j {
            kvs.insert(0, ("rank".into(), Json::Num(1.0)));
        }
        let parsed = GroupSummary::from_json(&j).unwrap();
        assert_eq!(parsed, summaries[0]);
        // Missing fields are errors.
        assert!(GroupSummary::from_json(&Json::parse("{\"group\":\"x\"}").unwrap()).is_err());
    }

    #[test]
    fn run_row_shapes() {
        let ok = run_row(&fake_result(0, "a", 1, &[1e-7]), &T);
        assert_eq!(ok.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(ok.get("rounds").unwrap().as_usize(), Some(1));
        let bits_to = ok.get("bits_to").unwrap().as_arr().unwrap();
        assert_eq!(bits_to.len(), 2);
        assert_eq!(bits_to[0].get("total").unwrap().as_f64(), Some(100.0));
        let text = ok.render();
        assert_eq!(Json::parse(&text).unwrap(), ok);

        let bad = run_row(&failed_result(1, "b", 2), &T);
        assert_eq!(bad.get("status").unwrap().as_str(), Some("failed"));
        assert_eq!(bad.get("error").unwrap().as_str(), Some("boom"));
        assert!(bad.get("final_gap").is_none());
    }

    #[test]
    fn run_rows_roundtrip_through_jsonl() {
        for res in [
            fake_result(3, "a", 7, &[1.0, 1e-3, 1e-7]),
            fake_result(4, "a", 8, &[1.0]), // reaches neither target
            failed_result(5, "b", 9),
        ] {
            let fresh = RunRow::from_result(&res, &T);
            let parsed = RunRow::from_json(&run_row(&res, &T)).unwrap();
            assert_eq!(parsed, fresh);
            assert_eq!(parsed.key(), format!("{} seed={}", res.group, res.data_seed));
        }
        let ok = RunRow::from_result(&fake_result(0, "a", 1, &[1e-7]), &T);
        assert!(ok.covers(&T));
        assert!(!ok.covers(&[1e-2, 1e-9]));
        assert_eq!(ok.bits_for(1e-2), Some(100.0));
        assert_eq!(ok.bits_for(5e-5), None);
        let failed = RunRow::from_result(&failed_result(1, "b", 2), &T);
        assert!(!failed.ok);
        assert!(failed.final_gap.is_none());
        assert!(!failed.covers(&T)); // no bits recorded at all
    }

    #[test]
    fn aggregate_matches_from_fresh_and_parsed_rows() {
        let results = vec![
            fake_result(0, "a", 1, &[1.0, 1e-3, 1e-7]),
            fake_result(1, "a", 2, &[1.0, 1e-4, 1e-8]),
            failed_result(2, "b", 1),
        ];
        let fresh = aggregate(&rows_from_results(&results, &T), &T);
        let parsed_rows: Vec<RunRow> = results
            .iter()
            .map(|r| RunRow::from_json(&run_row(r, &T)).unwrap())
            .collect();
        let parsed = aggregate(&parsed_rows, &T);
        assert_eq!(fresh, parsed);
        // And the rendered summary bytes agree too.
        let order = ranked(&fresh);
        assert_eq!(summary_jsonl(&fresh, &order), summary_jsonl(&parsed, &ranked(&parsed)));
    }

    fn cell(id: usize, group: &str, seed: u64) -> SweepCell {
        use crate::sweep::spec::DatasetRef;
        use crate::data::SyntheticSpec;
        SweepCell {
            id,
            group: group.into(),
            data_seed: seed,
            dataset: DatasetRef::Synthetic(SyntheticSpec::default()),
            cfg: crate::config::RunConfig::default(),
        }
    }

    #[test]
    fn plan_resume_partitions_done_failed_and_stale() {
        let cells = vec![
            cell(0, "a", 1),
            cell(1, "a", 2),
            cell(2, "b", 1),
            cell(3, "b", 2),
        ];
        let prior = vec![
            // cell 0: completed.
            RunRow::from_result(&fake_result(99, "a", 1, &[1e-7]), &T),
            // cell 2: failed last time → re-run.
            RunRow::from_result(&failed_result(98, "b", 1), &T),
            // not in the current grid → ignored.
            RunRow::from_result(&fake_result(97, "zzz", 1, &[1e-7]), &T),
        ];
        let plan = plan_resume(&cells, &prior, &T);
        assert_eq!(plan.done.len(), 1);
        // Id remapped from the stale 99 onto the current expansion.
        assert_eq!(plan.done[0].id, 0);
        assert_eq!(plan.done[0].key(), "a seed=1");
        // The plan records which prior row backs the kept result.
        assert_eq!(plan.kept_prior, vec![0]);
        let todo_ids: Vec<usize> = plan.todo.iter().map(|c| c.id).collect();
        assert_eq!(todo_ids, vec![1, 2, 3]);
    }

    #[test]
    fn plan_resume_last_occurrence_wins_and_target_mismatch_reruns() {
        let cells = vec![cell(0, "a", 1), cell(1, "a", 2)];
        // Same key twice (a re-run after an earlier resume): last wins.
        let mut early = RunRow::from_result(&fake_result(0, "a", 1, &[1.0, 1e-7]), &T);
        early.final_gap = Some(0.5);
        let late = RunRow::from_result(&fake_result(0, "a", 1, &[1.0, 1e-7]), &T);
        let plan = plan_resume(&cells, &[early, late.clone()], &T);
        assert_eq!(plan.done, vec![late]);
        assert_eq!(plan.kept_prior, vec![1], "must point at the winning occurrence");
        assert_eq!(plan.todo.len(), 1);
        // A row recorded under different targets is not resumable.
        let other_targets = RunRow::from_result(&fake_result(1, "a", 2, &[1e-7]), &[1e-3]);
        let plan = plan_resume(&cells, &[other_targets], &T);
        assert!(plan.done.is_empty());
        assert_eq!(plan.todo.len(), 2);
    }

    #[test]
    fn plan_resume_ok_row_shadowed_by_later_failed_row_still_wins() {
        // "Last occurrence wins" applies among *resumable* rows only: a
        // failed row appended after an ok one (hand-merged files, odd
        // histories) must not shadow the completed result — and
        // kept_prior must point at the ok row so compaction keeps it.
        let cells = vec![cell(0, "a", 1)];
        let ok_row = RunRow::from_result(&fake_result(0, "a", 1, &[1e-7]), &T);
        let failed_row = RunRow::from_result(&failed_result(0, "a", 1), &T);
        let plan = plan_resume(&cells, &[ok_row.clone(), failed_row], &T);
        assert_eq!(plan.done, vec![ok_row]);
        assert_eq!(plan.kept_prior, vec![0]);
        assert!(plan.todo.is_empty());
    }

    #[test]
    fn plan_resume_empty_prior_runs_everything() {
        let cells = vec![cell(0, "a", 1), cell(1, "a", 2)];
        let plan = plan_resume(&cells, &[], &T);
        assert!(plan.done.is_empty());
        assert_eq!(plan.todo.len(), 2);
    }

    #[test]
    fn plan_resume_refuses_rows_from_different_run_parameters() {
        // Same group + seed, but the sweep's shared parameters changed
        // (e.g. --rounds): the group string can't see it, the config
        // fingerprint can.
        let mut cells = vec![cell(0, "a", 1), cell(1, "a", 2)];
        cells[0].cfg.rounds += 1;
        cells[1].cfg.rounds += 1;
        let prior = vec![
            RunRow::from_result(&fake_result(0, "a", 1, &[1e-7]), &T),
            RunRow::from_result(&fake_result(1, "a", 2, &[1e-7]), &T),
        ];
        let plan = plan_resume(&cells, &prior, &T);
        assert!(plan.done.is_empty());
        assert_eq!(plan.todo.len(), 2);
        // A pre-fingerprint row (hash 0) is likewise never resumed.
        let cells = vec![cell(0, "a", 1)];
        let mut legacy = RunRow::from_result(&fake_result(0, "a", 1, &[1e-7]), &T);
        legacy.cfg_hash = 0;
        let plan = plan_resume(&cells, &[legacy], &T);
        assert!(plan.done.is_empty());
    }
}
