//! Cross-seed aggregation and ranking of sweep results, plus the JSONL row
//! builders for the result sink.
//!
//! Cells that differ only in the seed axis share a `group` key; aggregation
//! reduces each group to mean/std of bits-to-target-gap (over the seeds that
//! reached each target), reach counts, and a mean final gap. Everything is
//! computed in declaration order from per-run quantities that are themselves
//! deterministic, so rendered summaries are byte-identical across `--jobs`
//! levels.

use super::exec::{CellResult, CellStatus};
use super::jsonl::Json;
use anyhow::{Context, Result};
use std::cmp::Ordering;
use std::collections::HashMap;
use std::fmt::Write as _;

/// Aggregate of one sweep group (same coordinates, all seeds).
#[derive(Clone, Debug, PartialEq)]
pub struct GroupSummary {
    pub group: String,
    /// Seed-axis size (runs attempted).
    pub n_runs: usize,
    /// Runs that completed without error/panic.
    pub n_ok: usize,
    /// Mean final gap over ok runs (`None` if none succeeded).
    pub final_gap_mean: Option<f64>,
    /// One aggregate per requested gap target, in target order.
    pub per_target: Vec<TargetAgg>,
}

/// Bits-to-reach aggregate for one gap target.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TargetAgg {
    pub target: f64,
    /// How many of the group's runs reached the target.
    pub reached: usize,
    /// Mean total (up+down+setup) bits/node over the runs that reached it.
    pub bits_mean: Option<f64>,
    /// Population standard deviation over the same runs.
    pub bits_std: Option<f64>,
}

fn mean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        None
    } else {
        Some(xs.iter().sum::<f64>() / xs.len() as f64)
    }
}

fn pop_std(xs: &[f64]) -> Option<f64> {
    let m = mean(xs)?;
    let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64;
    Some(var.sqrt())
}

/// Reduce per-run results (in declaration order) to per-group summaries.
/// Groups appear in first-declaration order.
pub fn aggregate(results: &[CellResult], targets: &[f64]) -> Vec<GroupSummary> {
    let mut order: Vec<&str> = Vec::new();
    let mut buckets: HashMap<&str, Vec<&CellResult>> = HashMap::new();
    for r in results {
        let entry = buckets.entry(r.group.as_str()).or_default();
        if entry.is_empty() {
            order.push(r.group.as_str());
        }
        entry.push(r);
    }
    order
        .iter()
        .map(|g| {
            let runs = &buckets[g];
            let ok: Vec<&&CellResult> = runs.iter().filter(|r| r.status.is_ok()).collect();
            let gaps: Vec<f64> = ok
                .iter()
                .filter_map(|r| r.history.as_ref().map(|h| h.final_gap()))
                .collect();
            let per_target = targets
                .iter()
                .map(|&t| {
                    let bits: Vec<f64> = ok
                        .iter()
                        .filter_map(|r| r.history.as_ref().and_then(|h| h.bits_to_reach(t)))
                        .collect();
                    TargetAgg {
                        target: t,
                        reached: bits.len(),
                        bits_mean: mean(&bits),
                        bits_std: pop_std(&bits),
                    }
                })
                .collect();
            GroupSummary {
                group: g.to_string(),
                n_runs: runs.len(),
                n_ok: ok.len(),
                final_gap_mean: mean(&gaps),
                per_target,
            }
        })
        .collect()
}

fn cmp_opt(a: Option<f64>, b: Option<f64>) -> Ordering {
    match (a, b) {
        (Some(x), Some(y)) => x.partial_cmp(&y).unwrap_or(Ordering::Equal),
        (Some(_), None) => Ordering::Less, // reaching at all beats not reaching
        (None, Some(_)) => Ordering::Greater,
        (None, None) => Ordering::Equal,
    }
}

/// Best-cell ranking: indices into `summaries`, best first. A group is
/// better if it gets more seeds to the *strictest* target, then needs fewer
/// mean bits to get there; ties fall through to looser targets and finally
/// to the group name (total order ⇒ deterministic output).
pub fn ranked(summaries: &[GroupSummary]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..summaries.len()).collect();
    idx.sort_by(|&a, &b| {
        let (ga, gb) = (&summaries[a], &summaries[b]);
        let n = ga.per_target.len().min(gb.per_target.len());
        // Strictest target is last in SWEEP_TARGETS order.
        for t in (0..n).rev() {
            let (ta, tb) = (&ga.per_target[t], &gb.per_target[t]);
            let by_reached = tb.reached.cmp(&ta.reached);
            if by_reached != Ordering::Equal {
                return by_reached;
            }
            let by_bits = cmp_opt(ta.bits_mean, tb.bits_mean);
            if by_bits != Ordering::Equal {
                return by_bits;
            }
        }
        ga.group.cmp(&gb.group)
    });
    idx
}

/// JSONL row for one executed run (the streaming `runs.jsonl` sink).
pub fn run_row(res: &CellResult, targets: &[f64]) -> Json {
    let mut kvs: Vec<(String, Json)> = vec![
        ("cell".into(), Json::num(res.id as f64)),
        ("group".into(), Json::str(res.group.clone())),
        ("dataset".into(), Json::str(res.dataset.clone())),
        ("seed".into(), Json::num(res.data_seed as f64)),
        ("rng_seed".into(), Json::str(format!("{:#018x}", res.rng_seed))),
        (
            "status".into(),
            Json::str(match &res.status {
                CellStatus::Ok => "ok",
                CellStatus::Failed(_) => "failed",
            }),
        ),
    ];
    if let CellStatus::Failed(msg) = &res.status {
        kvs.push(("error".into(), Json::str(msg.clone())));
    }
    if let Some(s) = res.summary(targets) {
        kvs.push(("label".into(), Json::str(s.label)));
        kvs.push(("rounds".into(), Json::num(s.rounds as f64)));
        kvs.push(("final_gap".into(), Json::num(s.final_gap)));
        kvs.push(("bits_per_node".into(), Json::num(s.bits_per_node)));
        kvs.push(("bits_up_per_node".into(), Json::num(s.bits_up_per_node)));
        kvs.push((
            "bits_to".into(),
            Json::Arr(
                s.bits_to_targets
                    .iter()
                    .map(|t| {
                        Json::Obj(vec![
                            ("target".into(), Json::num(t.target)),
                            ("total".into(), Json::opt_num(t.total)),
                            ("uplink".into(), Json::opt_num(t.uplink)),
                        ])
                    })
                    .collect(),
            ),
        ));
    }
    kvs.push(("wall_ms".into(), Json::num(res.wall_ms)));
    Json::Obj(kvs)
}

impl GroupSummary {
    /// Serialize one summary row (the `summary.jsonl` sink).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("group".into(), Json::str(self.group.clone())),
            ("n_runs".into(), Json::num(self.n_runs as f64)),
            ("n_ok".into(), Json::num(self.n_ok as f64)),
            ("final_gap_mean".into(), Json::opt_num(self.final_gap_mean)),
            (
                "targets".into(),
                Json::Arr(
                    self.per_target
                        .iter()
                        .map(|t| {
                            Json::Obj(vec![
                                ("target".into(), Json::num(t.target)),
                                ("reached".into(), Json::num(t.reached as f64)),
                                ("bits_mean".into(), Json::opt_num(t.bits_mean)),
                                ("bits_std".into(), Json::opt_num(t.bits_std)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a summary row back (ignores unknown fields such as `rank`).
    pub fn from_json(j: &Json) -> Result<GroupSummary> {
        let field = |k: &str| j.get(k).with_context(|| format!("summary row missing '{k}'"));
        let group = field("group")?.as_str().context("'group' not a string")?.to_string();
        let n_runs = field("n_runs")?.as_usize().context("'n_runs' not a count")?;
        let n_ok = field("n_ok")?.as_usize().context("'n_ok' not a count")?;
        let final_gap_mean = field("final_gap_mean")?.as_f64();
        let per_target = field("targets")?
            .as_arr()
            .context("'targets' not an array")?
            .iter()
            .map(|t| {
                let tf = |k: &str| {
                    t.get(k).with_context(|| format!("target aggregate missing '{k}'"))
                };
                Ok(TargetAgg {
                    target: tf("target")?.as_f64().context("'target' not a number")?,
                    reached: tf("reached")?.as_usize().context("'reached' not a count")?,
                    bits_mean: tf("bits_mean")?.as_f64(),
                    bits_std: tf("bits_std")?.as_f64(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(GroupSummary { group, n_runs, n_ok, final_gap_mean, per_target })
    }
}

/// Terminal leaderboard for the end of a sweep.
pub fn summary_table(summaries: &[GroupSummary], order: &[usize]) -> String {
    let mut s = format!(
        "{:<4} {:<58} {:>6} {:>22} {:>14}\n",
        "rank", "cell", "ok", "bits@strictest (mean)", "final gap"
    );
    for (pos, &i) in order.iter().enumerate() {
        let g = &summaries[i];
        let strictest = g.per_target.last();
        let bits = strictest
            .and_then(|t| t.bits_mean.map(|m| format!("{m:.3e} (n={})", t.reached)))
            .unwrap_or_else(|| "—".into());
        let gap = g
            .final_gap_mean
            .map(|x| format!("{x:.2e}"))
            .unwrap_or_else(|| "—".into());
        let _ = writeln!(
            s,
            "{:<4} {:<58} {:>3}/{:<2} {:>22} {:>14}",
            pos + 1,
            g.group,
            g.n_ok,
            g.n_runs,
            bits,
            gap
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{History, RoundRecord};

    fn fake_result(id: usize, group: &str, seed: u64, gaps: &[f64]) -> CellResult {
        let mut h = History::new(group);
        for (i, &gap) in gaps.iter().enumerate() {
            h.push(RoundRecord {
                round: i,
                bits_up_per_node: 100.0 * (i + 1) as f64,
                bits_down_per_node: 0.0,
                gap,
                grad_norm: gap,
                dist_to_opt: gap,
            });
        }
        CellResult {
            id,
            group: group.into(),
            data_seed: seed,
            rng_seed: seed.wrapping_mul(0x9E37),
            dataset: "t".into(),
            status: CellStatus::Ok,
            history: Some(h),
            wall_ms: 1.0,
        }
    }

    fn failed_result(id: usize, group: &str, seed: u64) -> CellResult {
        CellResult {
            id,
            group: group.into(),
            data_seed: seed,
            rng_seed: 0,
            dataset: "t".into(),
            status: CellStatus::Failed("boom".into()),
            history: None,
            wall_ms: 1.0,
        }
    }

    const T: [f64; 2] = [1e-2, 1e-6];

    #[test]
    fn aggregate_means_and_stds() {
        let results = vec![
            fake_result(0, "a", 1, &[1.0, 1e-3, 1e-7]), // reaches both at 200/300 bits
            fake_result(1, "a", 2, &[1.0, 1e-3, 1e-3]), // reaches 1e-2 at 200, never 1e-6
            failed_result(2, "a", 3),
            fake_result(3, "b", 1, &[1e-7]), // both targets at 100 bits
        ];
        let s = aggregate(&results, &T);
        assert_eq!(s.len(), 2);
        let a = &s[0];
        assert_eq!(a.group, "a");
        assert_eq!(a.n_runs, 3);
        assert_eq!(a.n_ok, 2);
        assert_eq!(a.per_target[0].reached, 2);
        assert_eq!(a.per_target[0].bits_mean, Some(200.0));
        assert_eq!(a.per_target[0].bits_std, Some(0.0));
        assert_eq!(a.per_target[1].reached, 1);
        assert_eq!(a.per_target[1].bits_mean, Some(300.0));
        let gap_mean = (1e-7 + 1e-3) / 2.0;
        assert!((a.final_gap_mean.unwrap() - gap_mean).abs() < 1e-15);
        let b = &s[1];
        assert_eq!(b.n_runs, 1);
        assert_eq!(b.per_target[1].bits_mean, Some(100.0));
    }

    #[test]
    fn ranking_prefers_reach_then_bits() {
        let results = vec![
            fake_result(0, "slow-but-reaches", 1, &[1.0, 1e-3, 1e-3, 1e-3, 1e-7]), // 500 bits
            fake_result(1, "fast", 1, &[1e-7]),                                    // 100 bits
            fake_result(2, "never", 1, &[1.0, 1e-3]),
        ];
        let s = aggregate(&results, &T);
        let order = ranked(&s);
        assert_eq!(s[order[0]].group, "fast");
        assert_eq!(s[order[1]].group, "slow-but-reaches");
        assert_eq!(s[order[2]].group, "never");
        let table = summary_table(&s, &order);
        assert!(table.contains("fast"));
        assert_eq!(table.lines().count(), 4);
    }

    #[test]
    fn summary_rows_roundtrip_through_jsonl() {
        let results = vec![
            fake_result(0, "a", 1, &[1.0, 1e-3, 1e-7]),
            fake_result(1, "a", 2, &[1.0, 1e-4, 1e-8]),
            failed_result(2, "b", 1),
        ];
        let summaries = aggregate(&results, &T);
        for s in &summaries {
            let line = s.to_json().render();
            let parsed = GroupSummary::from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(&parsed, s);
            // Render → parse → render is byte-stable.
            assert_eq!(parsed.to_json().render(), line);
        }
        // Unknown fields (e.g. an injected rank) are tolerated.
        let mut j = summaries[0].to_json();
        if let Json::Obj(kvs) = &mut j {
            kvs.insert(0, ("rank".into(), Json::Num(1.0)));
        }
        let parsed = GroupSummary::from_json(&j).unwrap();
        assert_eq!(parsed, summaries[0]);
        // Missing fields are errors.
        assert!(GroupSummary::from_json(&Json::parse("{\"group\":\"x\"}").unwrap()).is_err());
    }

    #[test]
    fn run_row_shapes() {
        let ok = run_row(&fake_result(0, "a", 1, &[1e-7]), &T);
        assert_eq!(ok.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(ok.get("rounds").unwrap().as_usize(), Some(1));
        let bits_to = ok.get("bits_to").unwrap().as_arr().unwrap();
        assert_eq!(bits_to.len(), 2);
        assert_eq!(bits_to[0].get("total").unwrap().as_f64(), Some(100.0));
        let text = ok.render();
        assert_eq!(Json::parse(&text).unwrap(), ok);

        let bad = run_row(&failed_result(1, "b", 2), &T);
        assert_eq!(bad.get("status").unwrap().as_str(), Some("failed"));
        assert_eq!(bad.get("error").unwrap().as_str(), Some("boom"));
        assert!(bad.get("final_gap").is_none());
    }
}
