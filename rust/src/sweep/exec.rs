//! Thread-pool executor for sweep cells.
//!
//! Federated runs are mutually independent, so the engine fans them out over
//! `jobs` OS threads (`std::thread::scope` + an atomic work cursor + an mpsc
//! results channel — no external dependencies). Each worker builds its *own*
//! dataset and problem instances from the cell's [`DatasetRef`] recipe,
//! because [`crate::problem::LocalProblem`] is intentionally non-`Sync` —
//! but memoizes built datasets in a *thread-local* cache keyed on
//! `(recipe, data_seed)`, so a grid of G groups × S seeds builds each
//! distinct dataset at most once per worker thread instead of once per cell.
//! Nothing in the cache ever crosses a thread boundary.
//!
//! Guarantees:
//! * **Determinism.** A cell's result is a pure function of the cell (its
//!   dataset recipe + `RunConfig`, including the derived seed); scheduling
//!   order cannot leak in. The cache preserves this: a dataset is itself a
//!   pure function of its cache key, so a hit returns exactly what a fresh
//!   build would. Results are returned in declaration order, so any
//!   downstream aggregation is byte-identical at `--jobs 1` and `--jobs N`.
//! * **Panic isolation.** A cell that panics (or returns an error, e.g. a
//!   diverging configuration) is recorded as `CellStatus::Failed` and the
//!   rest of the sweep proceeds.

use super::spec::{DatasetRef, SweepCell};
use crate::coordinator::run_federated_traced;
use crate::data::FederatedDataset;
use crate::metrics::{History, RunSummary};
use crate::obs::{CellScope, Ctx, Lane, Obs};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

/// Gap targets every sweep reports bits-to-reach for (the paper's summary
/// thresholds).
pub const SWEEP_TARGETS: [f64; 3] = [1e-4, 1e-7, 1e-10];

/// Terminal state of one cell.
#[derive(Clone, Debug, PartialEq)]
pub enum CellStatus {
    Ok,
    /// Run error or panic, with the message. The sweep continues.
    Failed(String),
}

impl CellStatus {
    pub fn is_ok(&self) -> bool {
        matches!(self, CellStatus::Ok)
    }
}

/// Outcome of one executed cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub id: usize,
    pub group: String,
    pub data_seed: u64,
    /// Derived RNG seed the run actually used (`cfg.seed`).
    pub rng_seed: u64,
    /// Name of the dataset as built (e.g. `a1a-s`).
    pub dataset: String,
    pub status: CellStatus,
    /// Fingerprint of the cell's full `RunConfig` ([`crate::config::RunConfig::fingerprint`]).
    /// Serialized with each row so `--resume` can refuse rows recorded
    /// under parameters the group string doesn't encode.
    pub cfg_hash: u64,
    /// Full run trace (`None` on failure).
    pub history: Option<History>,
    /// Wall-clock of this cell, for progress reporting only — never fed into
    /// aggregates (it would break cross-`--jobs` determinism).
    pub wall_ms: f64,
    /// Whether this cell's dataset came out of the worker's thread-local
    /// memo rather than being rebuilt (observability; never serialized).
    pub dataset_cache_hit: bool,
}

impl CellResult {
    /// Condensed metrics against `targets` (`None` on failure).
    pub fn summary(&self, targets: &[f64]) -> Option<RunSummary> {
        self.history.as_ref().map(|h| h.summarize(targets))
    }

    /// The run's history, or a contextful error naming the cell — use this
    /// instead of `history.as_ref().unwrap()` wherever a missing history is
    /// a bug worth a diagnosable message.
    pub fn require_history(&self) -> anyhow::Result<&History> {
        match (&self.history, &self.status) {
            (Some(h), _) => Ok(h),
            (None, CellStatus::Failed(msg)) => Err(anyhow::anyhow!(
                "cell {} (group {}, dataset {}, data_seed {}) failed: {msg}",
                self.id,
                self.group,
                self.dataset,
                self.data_seed
            )),
            (None, CellStatus::Ok) => Err(anyhow::anyhow!(
                "cell {} (group {}, dataset {}, data_seed {}) has status Ok but no history",
                self.id,
                self.group,
                self.dataset,
                self.data_seed
            )),
        }
    }
}

/// Worker count to use when the user didn't specify `--jobs`.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Execute every cell across `jobs` worker threads.
///
/// `on_done` fires on the calling thread in *completion* order as runs
/// finish — use it for progress lines and streaming JSONL sinks. The
/// returned vector is in *declaration* order (`cells[i]` ↦ `results[i]`),
/// independent of scheduling.
pub fn run_cells(
    cells: &[SweepCell],
    jobs: usize,
    on_done: impl FnMut(&CellResult),
) -> Vec<CellResult> {
    run_cells_obs(cells, jobs, Obs::noop(), on_done)
}

/// [`run_cells`] with a trace recorder observing the sweep: each cell gets
/// a `cell` span on its worker's `sweep:<w>` lane plus a `dataset_cache`
/// hit/miss mark, and every event emitted inside the cell's federated run
/// is stamped with the cell id (see [`CellScope`]).
pub fn run_cells_obs(
    cells: &[SweepCell],
    jobs: usize,
    obs: Obs<'_>,
    mut on_done: impl FnMut(&CellResult),
) -> Vec<CellResult> {
    if cells.is_empty() {
        return Vec::new();
    }
    let jobs = jobs.clamp(1, cells.len());
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, CellResult)>();
    let mut slots: Vec<Option<CellResult>> = cells.iter().map(|_| None).collect();
    std::thread::scope(|scope| {
        for w in 0..jobs {
            let tx = tx.clone();
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let res = run_cell(&cells[i], obs, w);
                if tx.send((i, res)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, res) in rx {
            on_done(&res);
            slots[i] = Some(res);
        }
    });
    slots.into_iter().flatten().collect()
}

thread_local! {
    /// Per-worker dataset memo (the ROADMAP's "dataset/problem cache for
    /// sweeps"): `(recipe key, data_seed)` → built dataset. Thread-local by
    /// design — `LocalProblem` (and anything downstream of a dataset) is
    /// non-`Sync`, so sharing across workers is off the table; worker
    /// threads die with the sweep, taking their memo with them.
    static DATASET_CACHE: RefCell<BTreeMap<(String, u64), Rc<FederatedDataset>>> =
        RefCell::new(BTreeMap::new());
}

/// Fetch (or build and memoize) the dataset for a recipe + seed on this
/// worker thread. Returns the dataset and whether it was a cache hit.
fn cached_dataset(ds: &DatasetRef, data_seed: u64) -> (Rc<FederatedDataset>, bool) {
    let key = (ds.cache_key(), data_seed);
    if let Some(fed) = DATASET_CACHE.with(|c| c.borrow().get(&key).cloned()) {
        return (fed, true);
    }
    // Build outside the borrow: dataset generation can be slow and (in
    // pathological configurations) can panic; the memo must stay usable.
    let fed = Rc::new(ds.build(data_seed));
    DATASET_CACHE.with(|c| c.borrow_mut().insert(key, Rc::clone(&fed)));
    (fed, false)
}

/// Run one cell with panic isolation.
fn run_cell(cell: &SweepCell, obs: Obs<'_>, worker: usize) -> CellResult {
    // audit:allow(determinism-clock): wall_ms is a diagnostic-only field; aggregation reads RunRow, which omits it, so byte-identity of summaries is unaffected.
    let start = Instant::now();
    // Everything recorded inside this cell (round loop, transport, the
    // marks below) carries the cell id, no matter how workers interleave.
    let scoped = CellScope::new(obs.rec, cell.id);
    let cell_obs = Obs::new(&scoped);
    let cell_span = cell_obs.span("cell", Lane::Sweep(worker), Ctx::default());
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        let (fed, cache_hit) = cached_dataset(&cell.dataset, cell.data_seed);
        cell_obs.mark(
            "dataset_cache",
            Lane::Sweep(worker),
            Ctx::default(),
            Some(if cache_hit { "hit" } else { "miss" }.to_string()),
        );
        let name = fed.name.clone();
        run_federated_traced(&fed, &cell.cfg, &scoped).map(|out| (name, cache_hit, out))
    }));
    drop(cell_span);
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    let (dataset, status, history, dataset_cache_hit) = match outcome {
        Ok(Ok((name, hit, out))) => (name, CellStatus::Ok, Some(out.history), hit),
        Ok(Err(e)) => (cell.dataset.name(), CellStatus::Failed(format!("{e:#}")), None, false),
        Err(payload) => {
            (cell.dataset.name(), CellStatus::Failed(panic_message(payload)), None, false)
        }
    };
    CellResult {
        id: cell.id,
        group: cell.group.clone(),
        data_seed: cell.data_seed,
        rng_seed: cell.cfg.seed,
        dataset,
        status,
        cfg_hash: cell.cfg.fingerprint(),
        history,
        wall_ms,
        dataset_cache_hit,
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic: <non-string payload>".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::CompressorSpec;
    use crate::config::{Algorithm, RunConfig};
    use crate::data::SyntheticSpec;
    use crate::sweep::spec::{DatasetRef, SweepSpec};

    fn tiny_spec() -> SweepSpec {
        SweepSpec {
            algos: vec![Algorithm::Bl1, Algorithm::FedNl],
            datasets: vec![DatasetRef::Synthetic(SyntheticSpec {
                n_clients: 3,
                m_per_client: 20,
                dim: 8,
                intrinsic_dim: 3,
                noise: 0.0,
                seed: 0,
            })],
            hess_comps: vec![CompressorSpec::TopK(3)],
            seeds: vec![1, 2],
            base: RunConfig { rounds: 40, target_gap: 1e-10, ..RunConfig::default() },
            ..SweepSpec::default()
        }
    }

    #[test]
    fn executor_matches_across_job_counts() {
        let cells = tiny_spec().expand();
        assert_eq!(cells.len(), 4);
        let serial = run_cells(&cells, 1, |_| {});
        let parallel = run_cells(&cells, 8, |_| {});
        assert_eq!(serial.len(), 4);
        assert_eq!(parallel.len(), 4);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.group, b.group);
            assert_eq!(a.status, b.status);
            assert!(a.status.is_ok(), "{:?}", a.status);
            // Bit-for-bit identical traces regardless of scheduling.
            let (ha, hb) = (a.require_history().unwrap(), b.require_history().unwrap());
            assert_eq!(ha.records, hb.records);
            assert_eq!(ha.setup_bits_per_node, hb.setup_bits_per_node);
        }
    }

    #[test]
    fn on_done_streams_every_cell_and_order_is_declaration_order() {
        let cells = tiny_spec().expand();
        let mut seen = Vec::new();
        let results = run_cells(&cells, 2, |r| seen.push(r.id));
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i);
        }
    }

    #[test]
    fn failed_cell_does_not_kill_the_sweep() {
        // A RankR *gradient* compressor panics in build_vec — a worst-case
        // in-cell failure (panic, not Err). The sweep must survive it.
        let mut cells = tiny_spec().expand();
        cells[1].cfg.algorithm = Algorithm::Diana;
        cells[1].cfg.grad_comp = CompressorSpec::RankR(1);
        let results = run_cells(&cells, 4, |_| {});
        assert_eq!(results.len(), 4);
        assert!(results[0].status.is_ok());
        assert!(!results[1].status.is_ok());
        assert!(results[1].history.is_none());
        match &results[1].status {
            CellStatus::Failed(msg) => assert!(msg.contains("panic"), "{msg}"),
            CellStatus::Ok => unreachable!(),
        }
        assert!(results[2].status.is_ok());
        assert!(results[3].status.is_ok());
    }

    #[test]
    fn empty_cell_list_is_a_noop() {
        let results = run_cells(&[], 4, |_| panic!("no cells, no callbacks"));
        assert!(results.is_empty());
    }

    #[test]
    fn dataset_cache_builds_each_distinct_dataset_once_per_worker() {
        // 2 algorithms × 2 seeds over one dataset recipe = 4 cells but only
        // 2 distinct (recipe, seed) datasets. A single worker gets a fresh
        // thread-local memo, so exactly 2 misses and 2 hits.
        let cells = tiny_spec().expand();
        assert_eq!(cells.len(), 4);
        let results = run_cells(&cells, 1, |_| {});
        let misses = results.iter().filter(|r| !r.dataset_cache_hit).count();
        let hits = results.iter().filter(|r| r.dataset_cache_hit).count();
        assert_eq!(misses, 2, "one build per distinct (recipe, data_seed)");
        assert_eq!(hits, 2);
        // More workers can only rebuild per thread, never per cell: misses
        // stay bounded by distinct-datasets × workers.
        let results = run_cells(&cells, 2, |_| {});
        let misses = results.iter().filter(|r| !r.dataset_cache_hit).count();
        assert!(misses <= 4, "misses={misses}");
    }

    #[test]
    fn dataset_cache_does_not_leak_across_recipes() {
        // Same seed axis, two different synthetic shapes → no key collision,
        // every cell still sees its own dataset (names differ by shape).
        let mut spec = tiny_spec();
        spec.datasets.push(DatasetRef::Synthetic(SyntheticSpec {
            n_clients: 3,
            m_per_client: 20,
            dim: 6,
            intrinsic_dim: 2,
            noise: 0.0,
            seed: 0,
        }));
        let cells = spec.expand();
        let results = run_cells(&cells, 1, |_| {});
        for (c, r) in cells.iter().zip(&results) {
            assert!(r.status.is_ok(), "{:?}", r.status);
            assert_eq!(r.dataset, c.dataset.build(c.data_seed).name);
        }
        let misses = results.iter().filter(|r| !r.dataset_cache_hit).count();
        assert_eq!(misses, 4, "2 shapes × 2 seeds");
    }

    #[test]
    fn cached_and_fresh_datasets_give_identical_results() {
        // Within one worker the 2nd seed-1 cell reuses the memoized dataset;
        // its trace must match the first worker's fresh build bit-for-bit.
        let cells = tiny_spec().expand();
        let serial = run_cells(&cells, 1, |_| {}); // hits within the worker
        let spread = run_cells(&cells, 4, |_| {}); // mostly fresh builds
        for (a, b) in serial.iter().zip(&spread) {
            let (ha, hb) = (a.require_history().unwrap(), b.require_history().unwrap());
            assert_eq!(ha.records, hb.records);
        }
    }
}
