//! Minimal JSON value model with rendering and parsing — the wire format of
//! the sweep result sink (`runs/<sweep>/runs.jsonl`, `summary.jsonl`) — plus
//! the crash-safe JSONL file primitives built on it: [`JsonlSink`] (durable
//! line-at-a-time appends) and [`load_jsonl`] (recovery that tolerates a torn
//! final line).
//!
//! `serde` is not part of this environment's crate registry, so the engine
//! ships its own small, deterministic implementation. Rendering is
//! byte-stable: object keys keep insertion order, numbers use Rust's shortest
//! round-trip `f64` formatting, and non-finite numbers serialize as `null`
//! (JSON has no encoding for them). That stability is what makes sweep
//! aggregates byte-identical across `--jobs` levels, and — because shortest
//! round-trip formatting parses back to the identical `f64` — what lets a
//! resumed sweep re-aggregate loaded rows bit-for-bit.

use anyhow::{bail, Context, Result};
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A JSON value. Objects preserve insertion order (deterministic output).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience constructor for string values.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// A number, mapping non-finite values to `Null`.
    pub fn num(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }

    /// `Some(x)` ⇒ number, `None` ⇒ null.
    pub fn opt_num(x: Option<f64>) -> Json {
        match x {
            Some(v) => Json::num(v),
            None => Json::Null,
        }
    }

    /// Render to compact JSON text (single line; no trailing newline).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse one JSON value from `text` (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {} of JSON input", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Number coerced to usize (round-trips integral counts).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs.as_slice()),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        let end = self.pos + word.len();
        if self.bytes.get(self.pos..end) == Some(word.as_bytes()) {
            self.pos = end;
            Ok(v)
        } else {
            bail!("malformed JSON literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => bail!("unexpected byte '{}' at {}", b as char, self.pos),
            None => bail!("unexpected end of JSON input"),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            // Fast-forward over the plain (unescaped, non-quote) span.
            while let Some(&b) = self.bytes.get(self.pos) {
                if b == b'"' || b == b'\\' {
                    break;
                }
                self.pos += 1;
            }
            s.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| anyhow::anyhow!("invalid UTF-8 in JSON string"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            s.push(cp);
                            continue; // unicode_escape advanced past the digits
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                _ => bail!("unterminated JSON string"),
            }
        }
    }

    /// Called with `pos` on the `u` of `\uXXXX`; consumes through the last
    /// hex digit (and a trailing surrogate pair if present).
    fn unicode_escape(&mut self) -> Result<char> {
        self.pos += 1; // past 'u'
        let hi = self.hex4()?;
        if (0xD800..0xDC00).contains(&hi) {
            // Surrogate pair: expect \uXXXX low half.
            if self.bytes.get(self.pos) == Some(&b'\\') && self.bytes.get(self.pos + 1) == Some(&b'u')
            {
                self.pos += 2;
                let lo = self.hex4()?;
                if !(0xDC00..0xE000).contains(&lo) {
                    bail!("invalid low surrogate in JSON string");
                }
                let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                return char::from_u32(cp).ok_or_else(|| anyhow::anyhow!("bad surrogate pair"));
            }
            bail!("lone high surrogate in JSON string");
        }
        char::from_u32(hi).ok_or_else(|| anyhow::anyhow!("bad \\u escape"))
    }

    fn hex4(&mut self) -> Result<u32> {
        let end = self.pos + 4;
        let Some(hex) = self.bytes.get(self.pos..end) else {
            bail!("truncated \\u escape");
        };
        let s = std::str::from_utf8(hex).map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
        self.pos = end;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(&b) = self.bytes.get(self.pos) {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        let x: f64 = text
            .parse()
            .map_err(|_| anyhow::anyhow!("bad JSON number '{text}' at byte {start}"))?;
        Ok(Json::Num(x))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

// ── Crash-safe JSONL files ──────────────────────────────────────────────

/// Line-at-a-time JSONL writer with two durability modes.
///
/// In the default (durable) mode each [`JsonlSink::push`] renders the row,
/// issues a *single* `write` of `line + '\n'`, and fsyncs (`sync_data`)
/// before returning — so after a crash or SIGKILL, at most the final line
/// of the file is torn, which is exactly the failure mode [`load_jsonl`]
/// recovers from. One fsync per row is noise next to the cost of the
/// federated run that produced it.
///
/// [`JsonlSink::create_buffered`] opens a high-throughput variant for
/// trace streams (thousands of rows per second, where a per-row fsync
/// would dominate): rows accumulate in memory and hit the file in ~64 KiB
/// chunks; call [`JsonlSink::flush`] to drain and fsync. A crash still
/// tears at most one line — chunks end on row boundaries — but may lose
/// the buffered tail, which is acceptable for traces and not for results.
pub struct JsonlSink {
    file: std::fs::File,
    /// Fsync every row (results) vs buffer in memory (traces).
    durable: bool,
    buf: String,
}

/// Buffered mode flushes to the file once this many bytes accumulate.
const SINK_BUF_BYTES: usize = 64 * 1024;

impl JsonlSink {
    /// Open `path` truncated (a fresh sweep).
    pub fn create(path: &Path) -> Result<JsonlSink> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        Ok(JsonlSink { file, durable: true, buf: String::new() })
    }

    /// Open `path` for appending (a resumed sweep; the file must already be
    /// compacted so no torn line precedes the new rows).
    pub fn append(path: &Path) -> Result<JsonlSink> {
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening {} for append", path.display()))?;
        Ok(JsonlSink { file, durable: true, buf: String::new() })
    }

    /// Open `path` truncated, in buffered (non-fsyncing) mode — for
    /// high-rate trace streams. Pair with [`JsonlSink::flush`].
    pub fn create_buffered(path: &Path) -> Result<JsonlSink> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        Ok(JsonlSink { file, durable: false, buf: String::new() })
    }

    /// Append one row: durably (write + fsync) in the default mode,
    /// into the memory buffer in buffered mode.
    pub fn push(&mut self, row: &Json) -> Result<()> {
        if self.durable {
            let mut line = row.render();
            line.push('\n');
            self.file.write_all(line.as_bytes())?;
            self.file.sync_data()?;
        } else {
            self.buf.push_str(&row.render());
            self.buf.push('\n');
            if self.buf.len() >= SINK_BUF_BYTES {
                self.file.write_all(self.buf.as_bytes())?;
                self.buf.clear();
            }
        }
        Ok(())
    }

    /// Drain any buffered rows to the file and fsync. A no-op beyond the
    /// fsync in durable mode.
    pub fn flush(&mut self) -> Result<()> {
        if !self.buf.is_empty() {
            self.file.write_all(self.buf.as_bytes())?;
            self.buf.clear();
        }
        self.file.sync_data()?;
        Ok(())
    }
}

/// Outcome of loading a JSONL file that may have been interrupted mid-write.
#[derive(Debug)]
pub struct JsonlLoad {
    /// Every successfully parsed row, in file order.
    pub rows: Vec<Json>,
    /// Whether a torn (unparseable or non-UTF-8) final line was dropped.
    pub torn_tail: bool,
}

/// Load a JSONL file, tolerating a torn *final* line — the signature a crash
/// leaves behind with [`JsonlSink`]'s single-write appends. Empty lines are
/// skipped; an unparseable line anywhere *before* the last one is real
/// corruption and an error.
pub fn load_jsonl(path: &Path) -> Result<JsonlLoad> {
    // Bytes, not a String: a torn write can split a multi-byte UTF-8
    // character, which must count as a torn tail rather than a read error.
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let lines: Vec<&[u8]> = bytes
        .split(|&b| b == b'\n')
        .filter(|l| !l.iter().all(|b| b.is_ascii_whitespace()))
        .collect();
    let mut rows = Vec::with_capacity(lines.len());
    let mut torn_tail = false;
    for (i, line) in lines.iter().enumerate() {
        let parsed = std::str::from_utf8(line)
            .map_err(anyhow::Error::from)
            .and_then(|text| Json::parse(text));
        match parsed {
            Ok(row) => rows.push(row),
            // A torn tail is the expected signature of a crash mid-append.
            Err(_) if i + 1 == lines.len() => torn_tail = true,
            Err(e) => {
                return Err(e.context(format!(
                    "corrupt JSONL line {} of {} (only the final line may be torn)",
                    i + 1,
                    path.display()
                )));
            }
        }
    }
    Ok(JsonlLoad { rows, torn_tail })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::Num(1.0).render(), "1");
        assert_eq!(Json::Num(0.5).render(), "0.5");
        assert_eq!(Json::num(f64::INFINITY).render(), "null");
        assert_eq!(Json::str("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn parse_scalars_and_containers() {
        assert_eq!(Json::parse(" null ").unwrap(), Json::Null);
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-1.5e-3").unwrap(), Json::Num(-1.5e-3));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
        assert_eq!(
            Json::parse("[1, 2, [3]]").unwrap(),
            Json::Arr(vec![Json::Num(1.0), Json::Num(2.0), Json::Arr(vec![Json::Num(3.0)])])
        );
        let obj = Json::parse("{\"a\": 1, \"b\": {\"c\": null}}").unwrap();
        assert_eq!(obj.get("a").unwrap().as_f64(), Some(1.0));
        assert!(obj.get("b").unwrap().get("c").unwrap().is_null());
        assert!(obj.get("zzz").is_none());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::Obj(vec![
            ("k\"ey".into(), Json::str("line1\nline2\ttab \\ slash")),
            ("ctrl".into(), Json::str("\u{0001}")),
            ("uni".into(), Json::str("π ≈ 3.14159, 𝕊")),
        ]);
        let text = original.render();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escapes_parse() {
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap(), Json::str("é"));
        // Surrogate pair (U+1D54A).
        assert_eq!(Json::parse("\"\\ud835\\udd4a\"").unwrap(), Json::str("𝕊"));
        assert!(Json::parse("\"\\ud835\"").is_err());
    }

    #[test]
    fn numbers_roundtrip_exactly() {
        for x in [0.0, 1.0, -1.0, 0.1, 1e-12, 3.0e8, 123456789.25, 5e-324] {
            let text = Json::Num(x).render();
            assert_eq!(Json::parse(&text).unwrap().as_f64(), Some(x), "{text}");
        }
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::str("3").as_usize(), None);
    }

    #[test]
    fn lone_surrogate_halves_are_rejected() {
        // High half with no low half following.
        assert!(Json::parse("\"\\ud835\"").is_err());
        assert!(Json::parse("\"\\ud835x\"").is_err());
        assert!(Json::parse("\"\\ud835\\n\"").is_err());
        // Low half on its own is not a valid scalar either.
        assert!(Json::parse("\"\\udc00\"").is_err());
        // High half followed by a non-low-surrogate escape.
        assert!(Json::parse("\"\\ud835\\u0041\"").is_err());
        // Two high halves in a row.
        assert!(Json::parse("\"\\ud835\\ud835\"").is_err());
    }

    #[test]
    fn truncated_unicode_escapes_are_rejected() {
        assert!(Json::parse("\"\\u\"").is_err());
        assert!(Json::parse("\"\\u00\"").is_err());
        assert!(Json::parse("\"\\u00g0\"").is_err());
        // Input ends mid-escape (the torn-line shape).
        assert!(Json::parse("\"\\u00").is_err());
        assert!(Json::parse("\"\\ud835\\u").is_err());
        assert!(Json::parse("\"\\ud835\\udc").is_err());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::num(f64::NAN), Json::Null);
        assert_eq!(Json::num(f64::INFINITY), Json::Null);
        assert_eq!(Json::num(f64::NEG_INFINITY), Json::Null);
        assert_eq!(Json::opt_num(Some(f64::NAN)), Json::Null);
        assert_eq!(Json::opt_num(Some(f64::NEG_INFINITY)), Json::Null);
        assert_eq!(Json::opt_num(None), Json::Null);
        assert_eq!(Json::opt_num(Some(2.5)), Json::Num(2.5));
        // A Num smuggled in non-finite still renders as null.
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
        assert_eq!(
            Json::Arr(vec![Json::opt_num(Some(f64::NAN)), Json::num(1.0)]).render(),
            "[null,1]"
        );
    }

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("bl_jsonl_{tag}_{}", std::process::id()))
    }

    #[test]
    fn sink_then_load_roundtrips() {
        let path = tmp_path("roundtrip");
        let rows = vec![
            Json::Obj(vec![("a".into(), Json::num(1.5))]),
            Json::Obj(vec![("b".into(), Json::str("π ≈ 3.14"))]),
        ];
        let mut sink = JsonlSink::create(&path).unwrap();
        for r in &rows {
            sink.push(r).unwrap();
        }
        drop(sink);
        let load = load_jsonl(&path).unwrap();
        assert!(!load.torn_tail);
        assert_eq!(load.rows, rows);
        // Appending after reopening keeps earlier rows intact.
        let mut sink = JsonlSink::append(&path).unwrap();
        sink.push(&Json::Null).unwrap();
        drop(sink);
        let load = load_jsonl(&path).unwrap();
        assert_eq!(load.rows.len(), 3);
        assert_eq!(load.rows[2], Json::Null);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn buffered_sink_holds_rows_until_flush() {
        let path = tmp_path("buffered");
        let mut sink = JsonlSink::create_buffered(&path).unwrap();
        let rows: Vec<Json> = (0..100)
            .map(|i| Json::Obj(vec![("i".into(), Json::num(i as f64))]))
            .collect();
        for r in &rows {
            sink.push(r).unwrap();
        }
        // Small rows stay in memory until flush — nothing on disk yet.
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        sink.flush().unwrap();
        let load = load_jsonl(&path).unwrap();
        assert!(!load.torn_tail);
        assert_eq!(load.rows, rows);
        // Pushing past the chunk threshold spills without an explicit flush.
        let big = Json::Obj(vec![("pad".into(), Json::str("x".repeat(70 * 1024)))]);
        sink.push(&big).unwrap();
        assert!(std::fs::metadata(&path).unwrap().len() > 70 * 1024);
        sink.flush().unwrap();
        let load = load_jsonl(&path).unwrap();
        assert_eq!(load.rows.len(), 101);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_drops_torn_final_line() {
        let path = tmp_path("torn");
        // Two good rows, then a crash mid-write of the third.
        std::fs::write(&path, "{\"a\":1}\n{\"a\":2}\n{\"a\":3,\"bits\":12").unwrap();
        let load = load_jsonl(&path).unwrap();
        assert!(load.torn_tail);
        assert_eq!(load.rows.len(), 2);
        assert_eq!(load.rows[1].get("a").unwrap().as_f64(), Some(2.0));

        // Torn inside a multi-byte UTF-8 character (π is 0xCF 0x80).
        std::fs::write(&path, b"{\"a\":1}\n{\"s\":\"\xcf".as_slice()).unwrap();
        let load = load_jsonl(&path).unwrap();
        assert!(load.torn_tail);
        assert_eq!(load.rows.len(), 1);

        // Torn mid-escape.
        std::fs::write(&path, "{\"a\":1}\n{\"s\":\"\\u00").unwrap();
        let load = load_jsonl(&path).unwrap();
        assert!(load.torn_tail);
        assert_eq!(load.rows.len(), 1);

        // A file that is nothing but a torn line recovers to zero rows.
        std::fs::write(&path, "{\"a\"").unwrap();
        let load = load_jsonl(&path).unwrap();
        assert!(load.torn_tail);
        assert!(load.rows.is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_tolerates_trailing_newline_and_blank_lines() {
        let path = tmp_path("blank");
        std::fs::write(&path, "{\"a\":1}\n\n{\"a\":2}\n").unwrap();
        let load = load_jsonl(&path).unwrap();
        assert!(!load.torn_tail);
        assert_eq!(load.rows.len(), 2);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_mid_file_corruption() {
        let path = tmp_path("corrupt");
        std::fs::write(&path, "{\"a\":1}\ngarbage!\n{\"a\":2}\n").unwrap();
        let err = load_jsonl(&path).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }
}
