//! The parallel sweep engine — declarative run grids, multi-threaded
//! execution, a streaming JSONL result sink, and cross-seed aggregation.
//!
//! The paper's claims are comparative: BL1/BL2/BL3 against the FedNL family
//! and first-order baselines, across datasets, compressors, bases,
//! participation levels and seeds. This module makes such comparisons a
//! first-class, parallel primitive instead of hand-written sequential loops:
//!
//! 1. **Declare** a grid: [`SweepSpec`] is a cartesian product over the
//!    comparison axes, expanded by [`SweepSpec::expand`] into concrete
//!    [`SweepCell`]s with deterministic per-cell seed derivation
//!    ([`derive_cell_seed`]).
//! 2. **Execute**: [`run_cells`] fans the cells out over a `std::thread`
//!    pool. Workers build their own dataset/problem handles (local problems
//!    are deliberately non-`Sync`), and a panicking or diverging cell is
//!    isolated as a [`CellStatus::Failed`] result instead of killing the
//!    sweep.
//! 3. **Sink**: each finished run can stream a [`Json`] row
//!    ([`run_row`]) to `runs/<sweep>/runs.jsonl` from the `on_done`
//!    callback.
//! 4. **Aggregate**: [`aggregate`] reduces seeds to per-group mean/std
//!    bits-to-target-gap, [`ranked`] orders the groups best-first, and
//!    [`GroupSummary::to_json`] rows form `summary.jsonl`. Aggregates are
//!    byte-identical at any `--jobs` level because every per-run quantity is
//!    a pure function of its cell.
//!
//! Driven from the CLI as `repro sweep --algo bl1,fednl --hess-comp
//! topk:1,topk:8 --seeds 1..3 --jobs 8`, and used by
//! [`crate::experiments`] to run every figure/table through the same
//! engine.

mod agg;
mod exec;
mod jsonl;
mod spec;

pub use agg::{aggregate, ranked, run_row, summary_table, GroupSummary, TargetAgg};
pub use exec::{default_jobs, run_cells, CellResult, CellStatus, SWEEP_TARGETS};
pub use jsonl::Json;
pub use spec::{
    derive_cell_seed, parse_axis, parse_bases, parse_datasets, parse_seeds, parse_taus,
    DatasetRef, SweepCell, SweepSpec,
};
