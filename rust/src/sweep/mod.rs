//! The parallel sweep engine — declarative run grids, multi-threaded
//! execution, a streaming JSONL result sink, and cross-seed aggregation.
//!
//! The paper's claims are comparative: BL1/BL2/BL3 against the FedNL family
//! and first-order baselines, across datasets, compressors, bases,
//! participation levels and seeds. This module makes such comparisons a
//! first-class, parallel primitive instead of hand-written sequential loops:
//!
//! 1. **Declare** a grid: [`SweepSpec`] is a cartesian product over the
//!    comparison axes, expanded by [`SweepSpec::expand`] into concrete
//!    [`SweepCell`]s with deterministic per-cell seed derivation
//!    ([`derive_cell_seed`]).
//! 2. **Execute**: [`run_cells`] fans the cells out over a `std::thread`
//!    pool. Workers build their own dataset/problem handles (local problems
//!    are deliberately non-`Sync`) but memoize built datasets in a
//!    thread-local cache keyed on `(recipe, data_seed)`, so a grid of G
//!    groups × S seeds builds each distinct dataset at most once per worker
//!    thread. A panicking or diverging cell is isolated as a
//!    [`CellStatus::Failed`] result instead of killing the sweep.
//! 3. **Sink**: each finished run can stream a [`Json`] row ([`run_row`])
//!    to `runs/<sweep>/runs.jsonl` from the `on_done` callback, through the
//!    durable [`JsonlSink`].
//! 4. **Aggregate**: [`aggregate`] reduces seeds to per-group mean/std
//!    bits-to-target-gap, [`ranked`] orders the groups best-first, and
//!    [`summary_jsonl`] renders them as `summary.jsonl`. Aggregates are
//!    byte-identical at any `--jobs` level because every per-run quantity is
//!    a pure function of its cell.
//!
//! Driven from the CLI as `repro sweep --algo bl1,fednl --hess-comp
//! topk:1,topk:8 --seeds 1..3 --jobs 8`, and used by
//! [`crate::experiments`] to run every figure/table through the same
//! engine.
//!
//! ## On-disk layout and resume
//!
//! Each sweep owns one directory, `runs/<name>/` (or `--out DIR`):
//!
//! * `runs.jsonl` — one row per executed run, in *completion* order. Rows
//!   are appended durably (a single `write` of the whole line, then fsync),
//!   so a crash or SIGKILL leaves at most a torn final line.
//! * `summary.jsonl` — one row per group (cross-seed aggregate plus its
//!   rank), best-first, rewritten whole when the sweep finishes.
//!
//! `repro sweep --resume` makes that layout restartable: it re-expands the
//! spec, recovers rows with [`load_jsonl`] (dropping a torn tail), matches
//! them to cells by the stable [`SweepCell::key`] *and* the cell's full
//! `RunConfig` fingerprint via [`plan_resume`], and executes only missing
//! or previously failed cells — completed cells are never re-run, and the
//! merged row set (sorted back into declaration order) re-aggregates to a
//! `summary.jsonl` byte-identical to an uninterrupted run's at any
//! `--jobs` level. Resuming with changed shared parameters (`--rounds`,
//! `--lambda`, `--target-gap`, `--max-bits`, `--master-seed`, ...) is safe:
//! the fingerprint refuses rows recorded under the old values and those
//! cells simply re-run. Before appending, the file is compacted to the
//! latest successful row per key so a torn tail or stale failed row never
//! precedes fresh appends.

mod agg;
mod exec;
mod jsonl;
mod spec;

pub use agg::{
    aggregate, plan_resume, ranked, rows_from_results, run_row, summary_jsonl, summary_table,
    GroupSummary, ResumePlan, RunRow, TargetAgg,
};
pub use exec::{default_jobs, run_cells, run_cells_obs, CellResult, CellStatus, SWEEP_TARGETS};
pub use jsonl::{load_jsonl, Json, JsonlLoad, JsonlSink};
pub use spec::{
    derive_cell_seed, parse_axis, parse_bases, parse_datasets, parse_seeds, parse_taus,
    DatasetRef, SweepCell, SweepSpec,
};
