//! Declarative sweep grids: a [`SweepSpec`] is a cartesian product over the
//! paper's comparison axes (algorithm × dataset × compressors × basis × ξ ×
//! τ × seed) that expands into concrete [`SweepCell`]s, each a fully resolved
//! `(dataset recipe, RunConfig)` pair with a deterministically derived RNG
//! seed.
//!
//! Two seeds matter per cell:
//! * the **seed axis** value (`SweepCell::data_seed`) drives the dataset
//!   generator, so every cell at the same seed-axis value sees *identical
//!   data* — method comparisons stay apples-to-apples;
//! * the **derived cell seed** (`RunConfig::seed`, from
//!   [`derive_cell_seed`]) drives the run's internal randomness (compressor
//!   sampling, participation draws) and is disjoint across cells, so no two
//!   cells share a random stream. Same spec ⇒ same derived seeds, always.

use crate::compressors::CompressorSpec;
use crate::config::{Algorithm, BasisKind, RunConfig};
use crate::data;
use crate::data::{DatasetEntry, FederatedDataset, SyntheticSpec};
use crate::rng::splitmix64;
use anyhow::{bail, Context, Result};

/// Where a sweep cell's dataset comes from. Cells carry a *recipe*, not
/// materialized data: every worker thread builds its own dataset and problem
/// instances because [`crate::problem::LocalProblem`] is deliberately
/// non-`Sync` (the PJRT implementation holds single-threaded client handles).
#[derive(Clone, Debug)]
pub enum DatasetRef {
    /// A Table-2 registry row, at laptop or paper scale.
    Registry { entry: DatasetEntry, full_scale: bool },
    /// An explicit synthetic shape (the `seed` field is overridden per cell).
    Synthetic(SyntheticSpec),
}

impl DatasetRef {
    /// Stable display name (matches the name the built dataset carries).
    /// Synthetic names carry their shape so that two different synthetic
    /// datasets in one sweep never collide in group strings — the cell key
    /// built from them is what `--resume` diffs against.
    pub fn name(&self) -> String {
        match self {
            DatasetRef::Registry { entry, full_scale } => {
                if *full_scale {
                    entry.name.to_string()
                } else {
                    format!("{}-s", entry.name)
                }
            }
            DatasetRef::Synthetic(s) => s.name(),
        }
    }

    /// Key under which sweep workers memoize the built dataset: the full
    /// recipe identity *minus* the per-cell seed (which keys the memo
    /// alongside it). Delegates to the data layer so the key stays in sync
    /// with what [`DatasetRef::build`] actually varies over.
    pub fn cache_key(&self) -> String {
        match self {
            DatasetRef::Registry { entry, full_scale } => entry.cache_key(*full_scale),
            DatasetRef::Synthetic(spec) => spec.shape_key(),
        }
    }

    /// Build the dataset with `data_seed` driving the generator.
    pub fn build(&self, data_seed: u64) -> FederatedDataset {
        match self {
            DatasetRef::Registry { entry, full_scale } => entry.build(data_seed, *full_scale),
            DatasetRef::Synthetic(spec) => {
                let mut s = *spec;
                s.seed = data_seed;
                FederatedDataset::synthetic(&s)
            }
        }
    }
}

/// One concrete run of a sweep.
#[derive(Clone, Debug)]
pub struct SweepCell {
    /// Position in expansion/declaration order (stable aggregation order).
    pub id: usize,
    /// Cell coordinates *minus* the seed axis — the cross-seed aggregation
    /// group key.
    pub group: String,
    /// Seed-axis value; also the dataset generator seed.
    pub data_seed: u64,
    pub dataset: DatasetRef,
    /// Fully resolved configuration; `cfg.seed` is the derived cell seed.
    pub cfg: RunConfig,
}

impl SweepCell {
    /// Full cell key (group + seed axis), unique within a sweep.
    pub fn key(&self) -> String {
        format!("{} seed={}", self.group, self.data_seed)
    }
}

/// Derive the RNG seed for one cell — a pure function of (master seed, cell
/// group key, seed-axis value). FNV-1a over the group string, mixed with the
/// other inputs and finalized through SplitMix64.
pub fn derive_cell_seed(master: u64, group: &str, seed_axis: u64) -> u64 {
    let h = crate::rng::fnv1a(group.as_bytes());
    let mut s = master
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ h
        ^ seed_axis.rotate_left(32);
    let a = splitmix64(&mut s);
    let b = splitmix64(&mut s);
    a ^ b.rotate_left(1)
}

/// A declarative run grid. Every `Vec` is one cartesian axis; `base` supplies
/// everything the axes don't cover (rounds, λ, stopping rules, ...).
///
/// Expansion order is fixed and documented: algorithm (outermost), dataset,
/// hessian compressor, model compressor, gradient compressor, basis, ξ (p),
/// τ, seed (innermost) — so consecutive cells are the same configuration at
/// different seeds.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub algos: Vec<Algorithm>,
    pub datasets: Vec<DatasetRef>,
    pub hess_comps: Vec<CompressorSpec>,
    pub model_comps: Vec<CompressorSpec>,
    pub grad_comps: Vec<CompressorSpec>,
    /// `None` ⇒ the algorithm's paper-default basis.
    pub bases: Vec<Option<BasisKind>>,
    /// Gradient-send probabilities ξ.
    pub ps: Vec<f64>,
    /// Participation levels τ (`None` ⇒ all clients).
    pub taus: Vec<Option<usize>>,
    /// Seed axis (dataset seeds; cell RNG seeds are derived from these).
    pub seeds: Vec<u64>,
    /// Template for non-axis configuration.
    pub base: RunConfig,
    /// Mixed into every derived cell seed; vary it to re-randomize a whole
    /// sweep without touching the seed axis.
    pub master_seed: u64,
}

impl Default for SweepSpec {
    fn default() -> Self {
        let base = RunConfig::default();
        SweepSpec {
            algos: vec![base.algorithm],
            datasets: vec![DatasetRef::Registry {
                // audit:allow(panic-safety): Default cannot return Result; "a1a" is a compile-time registry constant, pinned by data::tests.
                entry: data::find("a1a").expect("a1a in registry"),
                full_scale: false,
            }],
            hess_comps: vec![base.hess_comp.clone()],
            model_comps: vec![base.model_comp.clone()],
            grad_comps: vec![base.grad_comp.clone()],
            bases: vec![base.basis],
            ps: vec![base.p],
            taus: vec![base.tau],
            seeds: vec![1],
            base,
            master_seed: 0,
        }
    }
}

impl SweepSpec {
    /// Number of cells the spec expands to.
    pub fn n_cells(&self) -> usize {
        self.algos.len()
            * self.datasets.len()
            * self.hess_comps.len()
            * self.model_comps.len()
            * self.grad_comps.len()
            * self.bases.len()
            * self.ps.len()
            * self.taus.len()
            * self.seeds.len()
    }

    /// Expand the grid into concrete cells, in the documented axis order.
    pub fn expand(&self) -> Vec<SweepCell> {
        let mut cells = Vec::with_capacity(self.n_cells());
        for algo in &self.algos {
            for ds in &self.datasets {
                for hc in &self.hess_comps {
                    for mc in &self.model_comps {
                        for gc in &self.grad_comps {
                            for basis in &self.bases {
                                for &p in &self.ps {
                                    for &tau in &self.taus {
                                        let group = format!(
                                            "algo={algo} ds={} hess={hc} model={mc} grad={gc} basis={} p={p} tau={}",
                                            ds.name(),
                                            basis.map(|b| b.name()).unwrap_or("default"),
                                            tau.map(|t| t.to_string())
                                                .unwrap_or_else(|| "all".into()),
                                        );
                                        for &seed in &self.seeds {
                                            let cfg = RunConfig {
                                                algorithm: *algo,
                                                hess_comp: hc.clone(),
                                                model_comp: mc.clone(),
                                                grad_comp: gc.clone(),
                                                basis: *basis,
                                                p,
                                                tau,
                                                seed: derive_cell_seed(
                                                    self.master_seed,
                                                    &group,
                                                    seed,
                                                ),
                                                ..self.base.clone()
                                            };
                                            cells.push(SweepCell {
                                                id: cells.len(),
                                                group: group.clone(),
                                                data_seed: seed,
                                                dataset: ds.clone(),
                                                cfg,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        cells
    }
}

// ── CLI grid-syntax parsers ─────────────────────────────────────────────

/// Parse a comma-separated axis (`bl1,fednl`, `topk:1,rank:1`, `0.2,1.0`).
pub fn parse_axis<T>(s: &str) -> Result<Vec<T>>
where
    T: std::str::FromStr,
    T::Err: std::fmt::Display,
{
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(part.parse::<T>().map_err(|e| anyhow::anyhow!("'{part}': {e}"))?);
    }
    if out.is_empty() {
        bail!("empty axis '{s}'");
    }
    Ok(out)
}

/// Seed axis: either an inclusive range `1..5` (⇒ 1,2,3,4,5) or a comma
/// list `1,2,7`.
pub fn parse_seeds(s: &str) -> Result<Vec<u64>> {
    let t = s.trim();
    if let Some((a, b)) = t.split_once("..") {
        let lo: u64 = a.trim().parse().with_context(|| format!("bad seed range '{s}'"))?;
        let hi: u64 = b.trim().parse().with_context(|| format!("bad seed range '{s}'"))?;
        if hi < lo {
            bail!("seed range '{s}' is empty (use lo..hi, inclusive)");
        }
        if hi - lo >= 100_000 {
            bail!("seed range '{s}' has {} seeds; that is surely a typo", hi - lo + 1);
        }
        return Ok((lo..=hi).collect());
    }
    parse_axis::<u64>(t)
}

/// τ axis: `all` (full participation) or client counts, comma-separated.
pub fn parse_taus(s: &str) -> Result<Vec<Option<usize>>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if part.eq_ignore_ascii_case("all") {
            out.push(None);
        } else {
            out.push(Some(
                part.parse::<usize>().with_context(|| format!("bad tau '{part}'"))?,
            ));
        }
    }
    if out.is_empty() {
        bail!("empty tau axis '{s}'");
    }
    Ok(out)
}

/// Basis axis: `default` (per-algorithm paper default) or basis kinds.
pub fn parse_bases(s: &str) -> Result<Vec<Option<BasisKind>>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if part.eq_ignore_ascii_case("default") {
            out.push(None);
        } else {
            out.push(Some(part.parse::<BasisKind>()?));
        }
    }
    if out.is_empty() {
        bail!("empty basis axis '{s}'");
    }
    Ok(out)
}

/// Dataset axis: registry names (`a1a,w2a`) or `synth`, comma-separated.
pub fn parse_datasets(s: &str, full_scale: bool) -> Result<Vec<DatasetRef>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if part.eq_ignore_ascii_case("synth") {
            out.push(DatasetRef::Synthetic(SyntheticSpec::default()));
        } else {
            let entry = data::find(part)
                .with_context(|| format!("unknown dataset '{part}' (see `repro list`)"))?;
            out.push(DatasetRef::Registry { entry, full_scale });
        }
    }
    if out.is_empty() {
        bail!("empty dataset axis '{s}'");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_by_two() -> SweepSpec {
        SweepSpec {
            algos: vec![Algorithm::Bl1, Algorithm::FedNl],
            hess_comps: vec![CompressorSpec::TopK(1), CompressorSpec::TopK(8)],
            seeds: vec![1, 2, 3],
            ..SweepSpec::default()
        }
    }

    #[test]
    fn expansion_count_and_order() {
        let spec = two_by_two();
        assert_eq!(spec.n_cells(), 12);
        let cells = spec.expand();
        assert_eq!(cells.len(), 12);
        // ids are positions.
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.id, i);
        }
        // Seed is the innermost axis: cells 0..3 share a group.
        assert_eq!(cells[0].group, cells[1].group);
        assert_eq!(cells[0].group, cells[2].group);
        assert_ne!(cells[2].group, cells[3].group);
        assert_eq!(cells[0].data_seed, 1);
        assert_eq!(cells[1].data_seed, 2);
        assert_eq!(cells[2].data_seed, 3);
        // Algorithm is the outermost axis.
        assert_eq!(cells[0].cfg.algorithm, Algorithm::Bl1);
        assert_eq!(cells[11].cfg.algorithm, Algorithm::FedNl);
        // Axis overrides land in the config.
        assert_eq!(cells[0].cfg.hess_comp, CompressorSpec::TopK(1));
        assert_eq!(cells[3].cfg.hess_comp, CompressorSpec::TopK(8));
        // Non-axis template fields come from base.
        assert_eq!(cells[7].cfg.rounds, spec.base.rounds);
        // Keys are unique.
        let keys: std::collections::HashSet<String> =
            cells.iter().map(|c| c.key()).collect();
        assert_eq!(keys.len(), 12);
    }

    #[test]
    fn seed_derivation_is_deterministic_and_disjoint() {
        let spec = two_by_two();
        let a = spec.expand();
        let b = spec.expand();
        // Same spec ⇒ identical derived seeds.
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cfg.seed, y.cfg.seed);
        }
        // Disjoint across cells.
        let seeds: std::collections::HashSet<u64> = a.iter().map(|c| c.cfg.seed).collect();
        assert_eq!(seeds.len(), a.len(), "derived cell seeds must not collide");
        // Master seed re-randomizes everything.
        let spec2 = SweepSpec { master_seed: 99, ..two_by_two() };
        let c = spec2.expand();
        let changed = a.iter().zip(&c).filter(|(x, y)| x.cfg.seed != y.cfg.seed).count();
        assert_eq!(changed, a.len());
        // Pure-function sanity for the primitive itself.
        assert_eq!(derive_cell_seed(0, "g", 1), derive_cell_seed(0, "g", 1));
        assert_ne!(derive_cell_seed(0, "g", 1), derive_cell_seed(0, "g", 2));
        assert_ne!(derive_cell_seed(0, "g", 1), derive_cell_seed(0, "h", 1));
        assert_ne!(derive_cell_seed(0, "g", 1), derive_cell_seed(1, "g", 1));
    }

    #[test]
    fn dataset_ref_names_and_builds() {
        let reg = DatasetRef::Registry { entry: data::find("a1a").unwrap(), full_scale: false };
        assert_eq!(reg.name(), "a1a-s");
        let fed = reg.build(7);
        assert_eq!(fed.name, "a1a-s");
        assert_eq!(fed.n_clients(), 8);
        // Same data_seed ⇒ identical data; different ⇒ different.
        let fed2 = reg.build(7);
        assert_eq!(fed.clients[0].a, fed2.clients[0].a);
        let fed3 = reg.build(8);
        assert_ne!(fed.clients[0].a, fed3.clients[0].a);

        let synth = DatasetRef::Synthetic(SyntheticSpec { seed: 0, ..SyntheticSpec::default() });
        assert_eq!(synth.name(), "synth-n10-m100-d50-r10");
        assert_eq!(synth.name(), synth.build(3).name);
        assert_eq!(synth.build(3).n_clients(), SyntheticSpec::default().n_clients);
        // Noise is part of the name (it changes the data, so it must split
        // group strings and resume keys) and still matches the built name.
        let noisy = DatasetRef::Synthetic(SyntheticSpec { noise: 0.1, ..SyntheticSpec::default() });
        assert_eq!(noisy.name(), "synth-n10-m100-d50-r10-noise0.1");
        assert_eq!(noisy.name(), noisy.build(3).name);
    }

    #[test]
    fn cache_keys_separate_recipes_but_not_seeds() {
        let scaled = DatasetRef::Registry { entry: data::find("a1a").unwrap(), full_scale: false };
        let paper = DatasetRef::Registry { entry: data::find("a1a").unwrap(), full_scale: true };
        let other = DatasetRef::Registry { entry: data::find("w2a").unwrap(), full_scale: false };
        assert_ne!(scaled.cache_key(), paper.cache_key());
        assert_ne!(scaled.cache_key(), other.cache_key());

        let s1 = DatasetRef::Synthetic(SyntheticSpec { seed: 1, ..SyntheticSpec::default() });
        let s2 = DatasetRef::Synthetic(SyntheticSpec { seed: 2, ..SyntheticSpec::default() });
        // The spec's own seed is overridden per cell, so it must not split
        // the cache...
        assert_eq!(s1.cache_key(), s2.cache_key());
        // ...but every shape field must.
        let wider = DatasetRef::Synthetic(SyntheticSpec { dim: 51, ..SyntheticSpec::default() });
        let noisy = DatasetRef::Synthetic(SyntheticSpec { noise: 0.1, ..SyntheticSpec::default() });
        assert_ne!(s1.cache_key(), wider.cache_key());
        assert_ne!(s1.cache_key(), noisy.cache_key());
        assert_ne!(s1.cache_key(), scaled.cache_key());
    }

    #[test]
    fn parse_axis_forms() {
        let algos: Vec<Algorithm> = parse_axis("bl1, fednl,diana").unwrap();
        assert_eq!(algos, vec![Algorithm::Bl1, Algorithm::FedNl, Algorithm::Diana]);
        let comps: Vec<CompressorSpec> = parse_axis("topk:1,rank:2,rrank:1:16").unwrap();
        assert_eq!(
            comps,
            vec![
                CompressorSpec::TopK(1),
                CompressorSpec::RankR(2),
                CompressorSpec::RRank(1, Some(16))
            ]
        );
        let ps: Vec<f64> = parse_axis("1.0,0.5").unwrap();
        assert_eq!(ps, vec![1.0, 0.5]);
        assert!(parse_axis::<Algorithm>("bl1,warp").is_err());
        assert!(parse_axis::<f64>(" , ").is_err());
    }

    #[test]
    fn parse_seed_ranges() {
        assert_eq!(parse_seeds("1..5").unwrap(), vec![1, 2, 3, 4, 5]);
        assert_eq!(parse_seeds("7..7").unwrap(), vec![7]);
        assert_eq!(parse_seeds("3,1,4").unwrap(), vec![3, 1, 4]);
        assert!(parse_seeds("5..1").is_err());
        assert!(parse_seeds("a..b").is_err());
    }

    #[test]
    fn parse_tau_basis_dataset_axes() {
        assert_eq!(parse_taus("all,4").unwrap(), vec![None, Some(4)]);
        assert!(parse_taus("x").is_err());
        assert_eq!(
            parse_bases("default,psd").unwrap(),
            vec![None, Some(BasisKind::Psd)]
        );
        let ds = parse_datasets("a1a,w2a,synth", false).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds[0].name(), "a1a-s");
        assert_eq!(ds[2].name(), "synth-n10-m100-d50-r10");
        assert!(parse_datasets("atlantis", false).is_err());
        assert_eq!(parse_datasets("a1a", true).unwrap()[0].name(), "a1a");
    }
}
